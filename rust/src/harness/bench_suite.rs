//! The engine-throughput benchmark suite, as a library function so two
//! entry points share one set of cases:
//!
//! * `cargo bench --bench engine_throughput` — the classic, human-read
//!   bench binary;
//! * `sst-sched bench [--smoke] [--out BENCH_engine.json]` — the same
//!   suite, plus a machine-readable dump ([`crate::util::bench::Bench::
//!   to_json`]) that CI uploads on every run and the perf trajectory
//!   compares against the committed baseline.
//!
//! `--smoke` runs small sizes with one iteration so CI surfaces perf
//! breakage without multi-second runs; the full suite adds the
//! million-job streamed-SWF ingestion case (constant-memory scale path).

use crate::baseline::run_baseline;
use crate::core::event::{EventQueue, Priority};
use crate::core::time::SimTime;
use crate::job::{Job, WaitQueue};
use crate::resources::{AvailabilityProfile, Cluster, ResourceVector};
use crate::sched::{
    ArrivalOrder, ConservativeScheduler, Policy, RoundScratch, RunningJob, SchedInput, Scheduler,
};
use crate::sim::{run_policy, Simulation};
use crate::trace::{
    parse_gwf, parse_swf, stream_trace_file, Das2Model, FastTrace, SdscSp2Model, Workload,
};
use crate::util::bench::{section, Bench};
use std::cell::RefCell;
use std::io::Write as _;

/// Deterministic xorshift stream of (gap, priority) pairs shaped like a
/// fault+reservation job sim's event mix: mostly near-future holds
/// (completions, dispatches), a medium band (arrival batches), and a
/// far tail (long runtimes, repair instants, reservation windows) —
/// the mixed near/far horizon profile where bucketed queues earn their
/// keep and heaps pay a full sift per event.
fn queue_gap(state: &mut u64) -> (u64, u8) {
    *state ^= *state << 13;
    *state ^= *state >> 7;
    *state ^= *state << 17;
    let s = *state;
    let gap = match s % 16 {
        0..=9 => s % 64,
        10..=13 => 1_000 + s % 30_000,
        _ => 100_000 + s % 2_000_000,
    };
    (gap, ((s >> 33) % 4) as u8)
}

/// The DES core's event queue in isolation, ladder vs the binary heap
/// it replaced, on identical deterministic workloads: a scattered
/// pre-fill burst of `n/2` events, then hold-model churn (each pop
/// schedules one successor) until `n` events have passed through, then
/// a drain. 100k runs in the `--smoke` tier; the full suite adds 1M.
fn event_queue_cases(b: &mut Bench, n: usize) {
    let label = format!("queue/{}k-events/ladder", n / 1_000);
    b.case(&label, move || {
        let mut q: EventQueue<()> = EventQueue::new();
        let mut state = 0x9E37_79B9_7F4A_7C15u64;
        for _ in 0..n / 2 {
            let (gap, pri) = queue_gap(&mut state);
            q.push(SimTime(gap), Priority(pri), 0, ());
        }
        let mut pushed = n / 2;
        let mut pops = 0usize;
        while let Some(ev) = q.pop() {
            pops += 1;
            if pushed < n {
                let (gap, pri) = queue_gap(&mut state);
                q.push(SimTime(ev.time.ticks() + gap), Priority(pri), 0, ());
                pushed += 1;
            }
        }
        assert_eq!(pops, n, "ladder queue case lost events");
        pops
    });
    let label = format!("queue/{}k-events/heap", n / 1_000);
    b.case(&label, move || {
        // The seed engine's structure: a min-heap over the same
        // (time, priority, seq) total order.
        let mut q: std::collections::BinaryHeap<std::cmp::Reverse<(u64, u8, u64)>> =
            std::collections::BinaryHeap::new();
        let mut seq = 0u64;
        let mut state = 0x9E37_79B9_7F4A_7C15u64;
        for _ in 0..n / 2 {
            let (gap, pri) = queue_gap(&mut state);
            q.push(std::cmp::Reverse((gap, pri, seq)));
            seq += 1;
        }
        let mut pushed = n / 2;
        let mut pops = 0usize;
        while let Some(std::cmp::Reverse((t, _, _))) = q.pop() {
            pops += 1;
            if pushed < n {
                let (gap, pri) = queue_gap(&mut state);
                q.push(std::cmp::Reverse((t + gap, pri, seq)));
                seq += 1;
                pushed += 1;
            }
        }
        assert_eq!(pops, n, "heap queue case lost events");
        pops
    });
}

/// Scheduling-round planning cost at a deep queue: `queued` waiting jobs
/// on a fully busy machine with `running` release points. Measures one
/// conservative-backfill round (the planning-heaviest policy: one slot
/// search + reservation per queued job).
///
/// `incremental` reuses the maintained profile through the round scratch
/// (what the simulation core does now — allocation-free rounds); the
/// baseline re-sorts the raw release vector and folds it into a fresh
/// profile every round (what every round paid before the refactor).
fn sched_round_cases(b: &mut Bench, queued: usize, running: usize) {
    let nodes = 512usize;
    let cores_per_node = 16u64;
    let mut cluster = Cluster::homogeneous(nodes, cores_per_node, 0);
    let total = cluster.total_cores();
    // Fill the machine completely so no candidate can start: rounds pay
    // pure planning cost, and the cluster needs no reset between runs.
    let mut running_jobs: Vec<RunningJob> = Vec::with_capacity(running);
    let cores_each = total / running as u64;
    for i in 0..running {
        let j = Job::simple(1_000_000 + i as u64, 0, cores_each.max(1), 10);
        if let Some(a) = cluster.allocate(&j, crate::resources::AllocPolicy::FirstFit) {
            running_jobs.push(RunningJob {
                id: j.id,
                cores: a.cores(),
                est_end: SimTime(100 + (i as u64 % 97) * 50),
                start: SimTime(0),
                priority: 0,
            });
        }
    }
    // Mop up any remainder so free_cores == 0.
    while cluster.free_cores() > 0 {
        let j = Job::simple(2_000_000, 0, cluster.free_cores(), 10);
        let a = cluster.allocate(&j, crate::resources::AllocPolicy::FirstFit).unwrap();
        running_jobs.push(RunningJob {
            id: j.id,
            cores: a.cores(),
            est_end: SimTime(5_000),
            start: SimTime(0),
            priority: 0,
        });
    }
    let mut queue = WaitQueue::new();
    for i in 0..queued {
        let i = i as u64;
        queue.push(Job::with_estimate(i, 0, 1 + (i % 64), 100 + i % 900, 100 + i % 900));
    }
    let releases: Vec<(u64, u64)> =
        running_jobs.iter().map(|r| (r.est_end.ticks(), r.cores)).collect();
    let maintained =
        AvailabilityProfile::from_releases(0, cluster.free_cores(), total, &releases);

    let label = format!("round/cons-{queued}q-{running}r/incremental");
    {
        let mut cluster = cluster.clone();
        let queue = &queue;
        let running_jobs = &running_jobs;
        let maintained = &maintained;
        // The driver-owned scratch: after the first round, planning runs
        // allocation-free off these reused buffers.
        let scratch = RefCell::new(RoundScratch::default());
        b.case(&label, move || {
            // What a dispatch round costs now: overwrite the scratch
            // plan from the maintained timeline, plan every queued job.
            let input = SchedInput {
                now: SimTime(0),
                queue,
                running: running_jobs,
                profile: maintained,
                order: &ArrivalOrder,
                scratch: Some(&scratch),
            };
            ConservativeScheduler::new().schedule(&input, &mut cluster).len()
        });
    }
    let label = format!("round/cons-{queued}q-{running}r/rebuild-per-round");
    {
        let mut cluster = cluster.clone();
        let queue = &queue;
        let running_jobs = &running_jobs;
        let releases = &releases;
        b.case(&label, move || {
            // What a dispatch round cost before: gather + sort the raw
            // release vector and fold a fresh profile, then plan.
            let rebuilt = AvailabilityProfile::from_releases(
                0,
                cluster.free_cores(),
                total,
                releases,
            );
            let input = SchedInput {
                now: SimTime(0),
                queue,
                running: running_jobs,
                profile: &rebuilt,
                order: &ArrivalOrder,
                scratch: None,
            };
            ConservativeScheduler::new().schedule(&input, &mut cluster).len()
        });
    }
}

/// Memory-constrained scheduling round (multi-resource planning API),
/// plus the lazy-materialization pin: a memory-*tracking* profile over a
/// trace that carries no memory demands must never materialize its
/// memory timeline — the cores-only workload pays (near) zero for the
/// second dimension.
fn sched_round_mem_cases(b: &mut Bench, queued: usize) {
    let nodes = 512usize;
    let cores_per_node = 16u64;
    let mem_per_node = 4096u64;
    let cluster = Cluster::homogeneous(nodes, cores_per_node, mem_per_node);
    let total = ResourceVector::new(cluster.total_cores(), cluster.total_memory_mb());

    let queue_of = |mem: bool| {
        let mut q = WaitQueue::new();
        for i in 0..queued {
            let i = i as u64;
            let mut j = Job::with_estimate(i, 0, 1 + (i % 64), 100 + i % 900, 100 + i % 900);
            if mem {
                j.memory_mb = 256 + (i % 16) * 256;
            }
            q.push(j);
        }
        q
    };

    // Shared setup: the whole machine planned busy until t=500 (cores +
    // memory for the memory-carrying variant), so every slot lands in
    // the future — rounds pay pure planning cost and never mutate the
    // cluster between iterations.
    let profile_of = |mem: bool| {
        let mut p = AvailabilityProfile::new_v(
            0,
            ResourceVector::new(total.cores, total.memory_mb),
            total,
        );
        p.hold_v(
            0,
            500,
            ResourceVector::new(total.cores, if mem { total.memory_mb } else { 0 }),
        );
        p
    };

    // Lazy pin (asserted outside the timed loop): no memory demands ->
    // no memory timeline, even on a memory-tracking profile.
    assert!(
        !profile_of(false).has_memory_dimension(),
        "cores-only round must not materialize the memory dimension"
    );
    assert!(profile_of(true).has_memory_dimension());

    for (label, mem) in [("cores-only", false), ("memory", true)] {
        let mut cluster = cluster.clone();
        let queue = queue_of(mem);
        let profile = profile_of(mem);
        let scratch = RefCell::new(RoundScratch::default());
        let label = format!("round/cons-{queued}q-mem/{label}");
        b.case(&label, move || {
            let input = SchedInput {
                now: SimTime(0),
                queue: &queue,
                running: &[],
                profile: &profile,
                order: &ArrivalOrder,
                scratch: Some(&scratch),
            };
            ConservativeScheduler::new().schedule(&input, &mut cluster).len()
        });
    }
}

/// Streamed-SWF ingestion at scale: write `n` synthetic jobs as SWF to a
/// temp file line by line (never materializing a `Vec<Job>` on either
/// side), then run the simulator off a `JobStream` with per-job record
/// retention off — peak memory stays O(active jobs) regardless of `n`.
/// The non-smoke suite runs this at one million jobs.
fn streamed_swf_case(b: &mut Bench, n: usize) {
    let path = std::env::temp_dir().join(format!("sst_sched_bench_stream_{n}.swf"));
    {
        let f = std::fs::File::create(&path).expect("create bench trace");
        let mut w = std::io::BufWriter::new(f);
        writeln!(w, "; synthetic streamed-ingestion bench trace ({n} jobs)").unwrap();
        let mut submit = 0u64;
        for i in 0..n as u64 {
            submit += i % 7; // bursty-ish, nondecreasing arrivals
            let cores = 1 + (i % 16);
            let run = 60 + (i % 97) * 30;
            let est = run + (i % 5) * 60;
            writeln!(
                w,
                "{} {} -1 {} {} -1 -1 {} {} -1 1 {} {} -1 -1 -1 -1 -1",
                i + 1,
                submit,
                run,
                cores,
                cores,
                est,
                i % 100,
                i % 10
            )
            .unwrap();
        }
    }
    let label = format!("stream/swf-{n}-jobs/fcfs");
    let path_str = path.to_string_lossy().to_string();
    let expected = n as u64;
    b.case(&label, move || {
        let stream = stream_trace_file(&path_str).expect("open bench trace");
        let rep = Simulation::new(Workload::machine("stream-bench", 512, 16), Policy::Fcfs)
            .with_job_stream(Box::new(stream.map(|j| j.expect("bench trace parses"))))
            .with_retain_completed(false)
            .run(None);
        assert_eq!(rep.completed_count, expected, "streamed case lost jobs");
        rep.events
    });
    let _ = std::fs::remove_file(&path);
}

/// The trace-ingestion tier in isolation: one synthetic workload
/// written as SWF and GWF text and converted to binary stf, then parsed
/// end to end (file read included) by each reader — the scalar line
/// parsers, the zero-copy byte scanner, and the stf record decoder. No
/// simulation runs, so the cases measure pure ingestion cost; the
/// differential suite (`tests/prop_fastparse.rs`) guarantees all paths
/// yield the identical job sequence. Prints the stf-vs-scalar speedup —
/// the ratio the ingestion-tier acceptance bar (>= 3x) tracks.
fn ingest_cases(b: &mut Bench, n: usize) {
    let tag = if n >= 1_000_000 {
        format!("{}m", n / 1_000_000)
    } else {
        format!("{}k", n / 1_000)
    };
    let dir = std::env::temp_dir();
    let swf_path = dir.join(format!("sst_sched_bench_ingest_{n}.swf"));
    let gwf_path = dir.join(format!("sst_sched_bench_ingest_{n}.gwf"));
    let stf_path = dir.join(format!("sst_sched_bench_ingest_{n}.stf"));
    {
        let f = std::fs::File::create(&swf_path).expect("create ingest bench swf");
        let mut w = std::io::BufWriter::new(f);
        writeln!(w, "; synthetic ingestion bench trace ({n} jobs)").unwrap();
        let mut submit = 0u64;
        for i in 0..n as u64 {
            submit += i % 7;
            let cores = 1 + (i % 16);
            let run = 60 + (i % 97) * 30;
            let est = run + (i % 5) * 60;
            writeln!(
                w,
                "{} {} -1 {} {} -1 -1 {} {} -1 1 {} {} -1 -1 -1 -1 -1",
                i + 1,
                submit,
                run,
                cores,
                cores,
                est,
                i % 100,
                i % 10
            )
            .unwrap();
        }
    }
    {
        let f = std::fs::File::create(&gwf_path).expect("create ingest bench gwf");
        let mut w = std::io::BufWriter::new(f);
        writeln!(w, "# synthetic ingestion bench trace ({n} jobs)").unwrap();
        let mut submit = 0u64;
        for i in 0..n as u64 {
            submit += i % 7;
            let cores = 1 + (i % 16);
            let run = 60 + (i % 97) * 30;
            let est = run + (i % 5) * 60;
            writeln!(
                w,
                "{} {} 0 {}.0 {} -1 -1 {} {} -1 1 {} {} 14 -1",
                i + 1,
                submit,
                run,
                cores,
                cores,
                est,
                i % 100,
                i % 10
            )
            .unwrap();
        }
    }
    let st = crate::trace::stf::convert_trace_file(
        &swf_path.to_string_lossy(),
        &stf_path.to_string_lossy(),
    )
    .expect("convert ingest bench trace");
    assert_eq!(st.records as usize, n, "conversion lost records");

    let path = swf_path.to_string_lossy().to_string();
    let scalar = b
        .case(&format!("ingest/swf-{tag}-jobs/scalar"), move || {
            let text = std::fs::read_to_string(&path).expect("read bench swf");
            let jobs = parse_swf(&text).expect("bench swf parses");
            assert_eq!(jobs.len(), n, "scalar swf parse lost records");
            jobs.len()
        })
        .median();
    let path = swf_path.to_string_lossy().to_string();
    b.case(&format!("ingest/swf-{tag}-jobs/fast"), move || {
        let jobs = FastTrace::open(&path).and_then(|t| t.parse()).expect("bench swf scans");
        assert_eq!(jobs.len(), n, "fast swf parse lost records");
        jobs.len()
    });
    let path = gwf_path.to_string_lossy().to_string();
    b.case(&format!("ingest/gwf-{tag}-jobs/scalar"), move || {
        let text = std::fs::read_to_string(&path).expect("read bench gwf");
        let jobs = parse_gwf(&text).expect("bench gwf parses");
        assert_eq!(jobs.len(), n, "scalar gwf parse lost records");
        jobs.len()
    });
    let path = gwf_path.to_string_lossy().to_string();
    b.case(&format!("ingest/gwf-{tag}-jobs/fast"), move || {
        let jobs = FastTrace::open(&path).and_then(|t| t.parse()).expect("bench gwf scans");
        assert_eq!(jobs.len(), n, "fast gwf parse lost records");
        jobs.len()
    });
    let path = stf_path.to_string_lossy().to_string();
    let stf = b
        .case(&format!("ingest/stf-{tag}-jobs"), move || {
            let jobs = FastTrace::open(&path).and_then(|t| t.parse()).expect("bench stf decodes");
            assert_eq!(jobs.len(), n, "stf decode lost records");
            jobs.len()
        })
        .median();
    println!(
        "  -> stf decode vs scalar swf parse: {:.1}x",
        scalar.as_secs_f64() / stf.as_secs_f64().max(1e-12)
    );
    let _ = std::fs::remove_file(&swf_path);
    let _ = std::fs::remove_file(&gwf_path);
    let _ = std::fs::remove_file(&stf_path);
}

/// Sharded federation engine (Fig 5 on real cores): one DAS-2
/// federation, the same trace, at 1/2/4 shards — the speedup of the
/// 4-shard case over the 1-shard case is the paper's multi-core scaling
/// claim, measured on worker threads rather than modeled. The full
/// suite's job count puts the 1-shard case above 100k events.
fn sharded_federation_cases(b: &mut Bench, n: usize) {
    use crate::parallel::{run_sharded, RankSimOpts, ShardOpts};
    use crate::sim::{MetaScheduler, Routing};
    let jobs = Das2Model::default().generate(n, 1).scale_arrivals(0.5).jobs;
    let expected = jobs.len() as u64;
    for shards in [1usize, 2, 4] {
        let label = format!("shard/das2-{}k-jobs/shards-{shards}", n / 1_000);
        let jobs = jobs.clone();
        b.case(&label, move || {
            let opts = ShardOpts {
                clusters: MetaScheduler::das2_federation(
                    Routing::LeastLoaded,
                    Policy::FcfsBackfill,
                )
                .clusters,
                routing: Routing::LeastLoaded,
                policy: Policy::FcfsBackfill,
                shards,
                route_latency: 60,
                sim: RankSimOpts::default(),
            };
            let rep = run_sharded(&opts, jobs.clone(), true);
            assert_eq!(rep.total_completed() + rep.rejected, expected, "sharded case lost jobs");
            rep.total_events()
        });
    }
}

/// The serve daemon's request path in-process (no socket): a burst of
/// submit requests with a predict_wait every 64th — measures
/// [`ServerCore`] dispatch, the live engine stepping through each
/// arrival, and the snapshot-clone speculative run, i.e. the latency
/// budget of one daemon connection. The socket adds only transport on
/// top of this path (same `handle_line` code).
fn serve_request_cases(b: &mut Bench, submits: usize) {
    use crate::config::ExperimentConfig;
    use crate::runtime::serve::ServerCore;
    let label = format!("serve/{}-submits/in-process", submits);
    b.case(&label, move || {
        let mut core = ServerCore::new(ExperimentConfig {
            nodes: Some(64),
            cores_per_node: Some(8),
            ..ExperimentConfig::default()
        });
        let mut line = 0u64;
        let mut ok = 0usize;
        for i in 0..submits as u64 {
            line += 1;
            let r = core.handle_line(
                line,
                &format!(
                    r#"{{"req":"submit","at":{},"job":{{"cores":{},"runtime":{}}}}}"#,
                    i * 7,
                    1 + i % 8,
                    60 + (i % 97) * 30
                ),
            );
            assert!(r.get_bool_or("ok", false), "bench submit refused");
            ok += 1;
            if i % 64 == 63 {
                line += 1;
                let p = core.handle_line(
                    line,
                    &format!(
                        r#"{{"req":"predict_wait","job":{{"cores":{},"runtime":600}}}}"#,
                        1 + i % 8
                    ),
                );
                assert!(p.get_bool_or("ok", false), "bench predict refused");
                ok += 1;
            }
        }
        ok
    });
}

/// Crash-recovery cost: replay a write-ahead journal of `submits`
/// requests back into a live daemon. The journal is written once
/// outside the timed closure (compaction off, so the full request log
/// replays); each timed run is a complete [`recover`] — read + verify
/// the file, rebuild the sim, replay every surviving request. This is
/// the daemon's restart-latency budget; tracked so journal-format or
/// replay regressions show up as a number, not an incident.
fn serve_journal_replay_cases(b: &mut Bench, submits: usize) {
    use crate::config::{Durability, ExperimentConfig};
    use crate::runtime::{journal::Journal, recover, serve::ServerCore};
    let dir = std::env::temp_dir().join(format!("sst-bench-journal-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut cfg = ExperimentConfig {
        nodes: Some(64),
        cores_per_node: Some(8),
        ..ExperimentConfig::default()
    };
    cfg.serve.state_dir = Some(dir.to_string_lossy().into_owned());
    cfg.serve.durability = Durability::Off;
    cfg.serve.mark_interval = 0;
    let mut core = ServerCore::new(cfg.clone());
    core.attach_journal(
        Journal::create(&dir, cfg.semantic_hash(), cfg.serve.durability).expect("bench journal"),
    );
    for i in 0..submits as u64 {
        let r = core.handle_line(
            i + 1,
            &format!(
                r#"{{"req":"submit","at":{},"job":{{"cores":{},"runtime":{}}}}}"#,
                i * 7,
                1 + i % 8,
                60 + (i % 97) * 30
            ),
        );
        assert!(r.get_bool_or("ok", false), "bench journal submit refused");
    }
    drop(core); // graceful close: the journal flushes and syncs
    let label = format!("serve/journal-replay/{}-submits", submits);
    let rdir = dir.clone();
    b.case(&label, move || {
        let (core, report) = recover::recover(&cfg, &rdir).expect("bench recovery");
        assert_eq!(report.replayed_submits, submits, "bench journal lost submits");
        core.sim_names().len()
    });
    let _ = std::fs::remove_dir_all(&dir);
}

/// Build and run the whole suite; the caller reads/serializes
/// [`Bench::results`].
pub fn engine_throughput_suite(smoke: bool) -> Bench {
    let (das2_n, sp2_n, runs) = if smoke { (5_000, 3_000, 1) } else { (100_000, 50_000, 5) };

    section("event-driven simulator throughput");
    let das2 = Das2Model::default().generate(das2_n, 1).drop_infeasible();
    let sp2 = SdscSp2Model::default().generate(sp2_n, 1).drop_infeasible();
    let mut b = Bench::new(if smoke { 0 } else { 1 }, runs);

    let w = das2.clone();
    let r = b.case("sim/das2/fcfs", move || run_policy(w.clone(), Policy::Fcfs).events);
    let median = r.median();
    let events = run_policy(das2.clone(), Policy::Fcfs).events;
    println!(
        "  -> {:.2} M events/s",
        events as f64 / median.as_secs_f64().max(1e-12) / 1e6
    );

    let w = das2.clone();
    b.case("sim/das2/backfill", move || {
        run_policy(w.clone(), Policy::FcfsBackfill).events
    });
    let w = das2.clone();
    b.case("sim/das2/cons-backfill", move || {
        run_policy(w.clone(), Policy::ConservativeBackfill).events
    });
    let w = sp2.clone();
    b.case("sim/sp2/backfill", move || {
        run_policy(w.clone(), Policy::FcfsBackfill).events
    });

    section("event-queue throughput (ladder vs binary heap)");
    event_queue_cases(&mut b, 100_000);
    if !smoke {
        event_queue_cases(&mut b, 1_000_000);
    }

    section("scheduling-round planning cost (availability profile)");
    if smoke {
        sched_round_cases(&mut b, 2_000, 200);
    } else {
        sched_round_cases(&mut b, 10_000, 1_000);
        sched_round_cases(&mut b, 10_000, 5_000);
    }

    section("memory-constrained round (lazy second dimension)");
    sched_round_mem_cases(&mut b, if smoke { 2_000 } else { 10_000 });

    section("streamed trace ingestion (constant-memory scale path)");
    streamed_swf_case(&mut b, if smoke { 20_000 } else { 1_000_000 });

    section("trace-ingestion tier (scalar vs zero-copy vs binary stf)");
    ingest_cases(&mut b, if smoke { 100_000 } else { 1_000_000 });

    section("sharded federation engine (multi-domain PDES)");
    sharded_federation_cases(&mut b, if smoke { 8_000 } else { 25_000 });

    section("serve daemon request path (in-process)");
    serve_request_cases(&mut b, if smoke { 2_000 } else { 5_000 });

    section("serve crash recovery (journal replay)");
    serve_journal_replay_cases(&mut b, if smoke { 2_000 } else { 5_000 });

    section("baseline (CQsim-like) for comparison");
    let w = das2.clone();
    b.case("baseline/das2/fcfs", move || run_baseline(&w, Policy::Fcfs).events);

    section("workload generation");
    b.case("gen/das2", move || Das2Model::default().generate(das2_n, 1).jobs.len());
    b.case("gen/sp2", move || SdscSp2Model::default().generate(sp2_n, 1).jobs.len());
    b
}
