//! Experiment harness: one runner per paper figure (DESIGN.md §3).
//!
//! Every runner regenerates the series/rows its figure plots and returns
//! them as data (benches and tests reuse them); `print_*` companions
//! render the aligned-column tables the CLI shows. Absolute numbers
//! differ from the paper (synthetic trace calibrations, different
//! hardware) but the *shapes* are asserted in rust/tests/figures.rs:
//! who wins, by what rough factor, where the crossovers fall.

pub mod bench_suite;

use crate::baseline::run_baseline;
use crate::core::time::SimTime;
use crate::metrics::{correlation, mae, nmae, resample, wait_stats};
use crate::parallel::{run_jobs_parallel_modeled, run_workflow_parallel_modeled};
use crate::sched::Policy;
use crate::sim::run_policy;
use crate::trace::{Das2Model, SdscSp2Model, Workload};
use crate::util::table::{f, Table};
use crate::workflow::generators::{galactic_plane_wide, sipht};
use crate::workflow::WorkflowExecutor;

/// Validation series: ours vs the CQsim-like baseline on a common grid.
#[derive(Debug, Clone)]
pub struct ValidationSeries {
    pub what: &'static str,
    pub t: Vec<u64>,
    pub ours: Vec<f64>,
    pub baseline: Vec<f64>,
    pub nmae: f64,
    pub correlation: f64,
}

fn validation(
    what: &'static str,
    workload: &Workload,
    points: usize,
    pick: impl Fn(&crate::sim::SimReport) -> &crate::core::stats::TimeSeries,
    pick_base: impl Fn(&crate::baseline::BaselineReport) -> &crate::core::stats::TimeSeries,
) -> ValidationSeries {
    let ours_rep = run_policy(workload.clone(), Policy::Fcfs);
    let base_rep = run_baseline(workload, Policy::Fcfs);
    let t0 = SimTime::ZERO;
    let t1 = SimTime(ours_rep.end_time.ticks().max(base_rep.end_time.ticks()));
    let ours = resample(pick(&ours_rep), t0, t1, points);
    let baseline = resample(pick_base(&base_rep), t0, t1, points);
    let grid: Vec<u64> = (0..points)
        .map(|k| t1.ticks() * k as u64 / (points as u64 - 1).max(1))
        .collect();
    ValidationSeries {
        what,
        nmae: nmae(&ours, &baseline),
        correlation: correlation(&ours, &baseline),
        t: grid,
        ours,
        baseline,
    }
}

/// Fig 3(a): node occupancy over time, ours vs CQsim-like (DAS-2-like).
pub fn fig3a(jobs: usize, seed: u64, points: usize) -> ValidationSeries {
    let w = Das2Model::default().generate(jobs, seed).drop_infeasible();
    validation("occupied nodes", &w, points, |r| &r.occupancy, |b| &b.occupancy)
}

/// Fig 3(b): running jobs over time, ours vs CQsim-like (DAS-2-like).
pub fn fig3b(jobs: usize, seed: u64, points: usize) -> ValidationSeries {
    let w = Das2Model::default().generate(jobs, seed).drop_infeasible();
    validation("running jobs", &w, points, |r| &r.running, |b| &b.running)
}

pub fn print_validation(v: &ValidationSeries) {
    let mut t = Table::new(&["time", &format!("ours ({})", v.what), "cqsim-like"]);
    for i in 0..v.t.len() {
        t.row(&[v.t[i].to_string(), f(v.ours[i]), f(v.baseline[i])]);
    }
    t.print();
    println!("NMAE = {:.4}   correlation = {:.4}\n", v.nmae, v.correlation);
}

/// Fig 4(a): per-job wait-time validation, binned over submission order.
#[derive(Debug, Clone)]
pub struct WaitValidation {
    pub bins: Vec<usize>,
    pub ours: Vec<f64>,
    pub baseline: Vec<f64>,
    pub mae: f64,
    pub correlation: f64,
}

pub fn fig4a(jobs: usize, seed: u64, bins: usize) -> WaitValidation {
    // Arrivals compressed so queues actually form (zero-wait validation
    // would be vacuous).
    let w = Das2Model::default()
        .generate(jobs, seed)
        .scale_arrivals(0.45)
        .drop_infeasible();
    let ours = run_policy(w.clone(), Policy::Fcfs);
    let base = run_baseline(&w, Policy::Fcfs);
    // Mean wait per submit-order bin.
    let bin_means = |mut jobs: Vec<crate::job::Job>| -> Vec<f64> {
        jobs.sort_by_key(|j| (j.submit, j.id));
        let n = jobs.len().max(1);
        let mut out = vec![0.0; bins];
        let mut cnt = vec![0usize; bins];
        for (i, j) in jobs.iter().enumerate() {
            let b = (i * bins / n).min(bins - 1);
            if let Some(wt) = j.wait_time() {
                out[b] += wt.as_f64();
                cnt[b] += 1;
            }
        }
        for b in 0..bins {
            if cnt[b] > 0 {
                out[b] /= cnt[b] as f64;
            }
        }
        out
    };
    let o = bin_means(ours.completed);
    let b = bin_means(base.completed);
    WaitValidation {
        mae: mae(&o, &b),
        correlation: correlation(&o, &b),
        bins: (0..bins).collect(),
        ours: o,
        baseline: b,
    }
}

pub fn print_fig4a(v: &WaitValidation) {
    let mut t = Table::new(&["job bin", "ours mean wait (s)", "cqsim-like (s)"]);
    for i in 0..v.bins.len() {
        t.row(&[v.bins[i].to_string(), f(v.ours[i]), f(v.baseline[i])]);
    }
    t.print();
    println!("MAE = {:.2} s   correlation = {:.4}\n", v.mae, v.correlation);
}

/// Fig 4(b): the five scheduling algorithms compared.
#[derive(Debug, Clone)]
pub struct PolicyRow {
    pub policy: &'static str,
    pub mean_wait: f64,
    pub median_wait: f64,
    pub p95_wait: f64,
    pub mean_slowdown: f64,
    pub utilization: f64,
    pub makespan: u64,
}

pub fn fig4b(jobs: usize, seed: u64) -> Vec<PolicyRow> {
    // Higher load than the validation runs so policies separate.
    let w = Das2Model::default()
        .generate(jobs, seed)
        .scale_arrivals(0.45)
        .drop_infeasible();
    Policy::ALL
        .iter()
        .map(|&p| {
            let r = run_policy(w.clone(), p);
            let s = r.wait_stats();
            PolicyRow {
                policy: p.as_str(),
                mean_wait: s.mean_wait,
                median_wait: s.median_wait,
                p95_wait: s.p95_wait,
                mean_slowdown: s.mean_slowdown,
                utilization: r.mean_utilization,
                makespan: r.makespan().ticks(),
            }
        })
        .collect()
}

pub fn print_fig4b(rows: &[PolicyRow]) {
    let mut t = Table::new(&[
        "policy",
        "mean wait (s)",
        "median (s)",
        "p95 (s)",
        "slowdown",
        "utilization",
        "makespan (s)",
    ]);
    for r in rows {
        t.row(&[
            r.policy.to_string(),
            f(r.mean_wait),
            f(r.median_wait),
            f(r.p95_wait),
            f(r.mean_slowdown),
            format!("{:.3}", r.utilization),
            r.makespan.to_string(),
        ]);
    }
    t.print();
    println!();
}

/// Fig 5 rows: parallel scaling of the job simulator.
#[derive(Debug, Clone)]
pub struct ScaleRow {
    pub workload: String,
    pub jobs: usize,
    pub ranks: usize,
    pub wall_ms: f64,
    pub speedup: f64,
    pub events: u64,
    pub windows: u64,
}

/// Fig 5(a)/(b): wall-clock scaling across ranks for DAS-2-like (`sp2 =
/// false`) or SDSC-SP2-like (`sp2 = true`) workloads, across job scales.
pub fn fig5(sp2: bool, job_scales: &[usize], ranks_list: &[usize], seed: u64) -> Vec<ScaleRow> {
    let mut rows = Vec::new();
    for &jobs in job_scales {
        let w = if sp2 {
            SdscSp2Model::default().generate(jobs, seed).drop_infeasible()
        } else {
            Das2Model::default().generate(jobs, seed).drop_infeasible()
        };
        let mut base_ms = None;
        for &ranks in ranks_list {
            // Median of 3 runs for wall-clock stability.
            let mut walls = Vec::new();
            let mut last = None;
            for _ in 0..3 {
                // Lookahead = one simulated day: the partitioned clusters
                // share no links, so the sync period is a free knob; a
                // day mirrors how rarely independent clusters couple.
                // Modeled PDES wall time — this container has one CPU, so
                // speedup is computed from per-rank window times (see
                // run_parallel_modeled; substitution documented in
                // DESIGN.md).
                let rep = run_jobs_parallel_modeled(&w, Policy::FcfsBackfill, ranks, 86_400);
                walls.push(rep.wall.as_secs_f64() * 1e3);
                last = Some(rep);
            }
            walls.sort_by(|a, b| a.total_cmp(b));
            let wall_ms = walls[walls.len() / 2];
            let rep = last.unwrap();
            if ranks == ranks_list[0] {
                base_ms = Some(wall_ms);
            }
            rows.push(ScaleRow {
                workload: w.name.clone(),
                jobs,
                ranks,
                wall_ms,
                speedup: base_ms.unwrap_or(wall_ms) / wall_ms,
                events: rep.total_events(),
                windows: rep.windows,
            });
        }
    }
    rows
}

pub fn print_fig5(rows: &[ScaleRow]) {
    let mut t =
        Table::new(&["workload", "jobs", "ranks", "wall (ms)", "speedup", "events", "windows"]);
    for r in rows {
        t.row(&[
            r.workload.clone(),
            r.jobs.to_string(),
            r.ranks.to_string(),
            f(r.wall_ms),
            format!("{:.2}x", r.speedup),
            r.events.to_string(),
            r.windows.to_string(),
        ]);
    }
    t.print();
    println!();
}

/// Fig 6: workflow-simulation scaling (Galactic Plane). The real run
/// mosaics thousands of tiles per survey; width scales the per-survey
/// mosaic so the DAG is big enough for parallel execution to matter.
pub fn fig6(surveys: usize, ranks_list: &[usize], seed: u64) -> Vec<ScaleRow> {
    fig6_wide(surveys, 256, ranks_list, seed)
}

pub fn fig6_wide(
    surveys: usize,
    width: usize,
    ranks_list: &[usize],
    seed: u64,
) -> Vec<ScaleRow> {
    let w = galactic_plane_wide(surveys, width, seed, false);
    let total_cpu = 256u64;
    let mut rows = Vec::new();
    let mut base_ms = None;
    for &ranks in ranks_list {
        let mut walls = Vec::new();
        let mut last = None;
        for _ in 0..3 {
            // Modeled PDES wall time (single-CPU container; see fig5).
            let rep = run_workflow_parallel_modeled(&w, ranks, total_cpu, 5);
            walls.push(rep.wall.as_secs_f64() * 1e3);
            last = Some(rep);
        }
        walls.sort_by(|a, b| a.total_cmp(b));
        let wall_ms = walls[walls.len() / 2];
        let rep = last.unwrap();
        if base_ms.is_none() {
            base_ms = Some(wall_ms);
        }
        rows.push(ScaleRow {
            workload: format!("galactic-plane-{surveys}"),
            jobs: w.len(),
            ranks,
            wall_ms,
            speedup: base_ms.unwrap() / wall_ms,
            events: rep.total_events(),
            windows: rep.windows,
        });
    }
    rows
}

/// Fig 7: SIPHT workflow wait-time validation. The "real-life
/// measurement" reference is the published exact stage profile executed
/// on the reference pool; "ours" is the simulator running the sampled
/// (jittered) profile of the same workflow.
#[derive(Debug, Clone)]
pub struct SiphtRow {
    pub stage: String,
    pub tasks: usize,
    pub ref_wait: f64,
    pub ours_wait: f64,
}

#[derive(Debug, Clone)]
pub struct SiphtValidation {
    pub rows: Vec<SiphtRow>,
    pub mae: f64,
    pub ref_makespan: u64,
    pub ours_makespan: u64,
}

pub fn fig7(replicons: usize, cpu: u64, seed: u64) -> SiphtValidation {
    let reference = WorkflowExecutor::new(cpu, u64::MAX).run(sipht(replicons, seed, true));
    let ours = WorkflowExecutor::new(cpu, u64::MAX).run(sipht(replicons, seed, false));
    let wf = sipht(replicons, seed, true); // for stage lookup
    let mut stages: std::collections::BTreeMap<String, (usize, f64, f64)> = Default::default();
    for t in &reference.tasks {
        let stage = wf.tasks[&t.id].stage.clone();
        let e = stages.entry(stage).or_insert((0, 0.0, 0.0));
        e.0 += 1;
        e.1 += t.wait().as_f64();
    }
    for t in &ours.tasks {
        let stage = wf.tasks[&t.id].stage.clone();
        let e = stages.entry(stage).or_insert((0, 0.0, 0.0));
        e.2 += t.wait().as_f64();
    }
    let rows: Vec<SiphtRow> = stages
        .into_iter()
        .map(|(stage, (n, rw, ow))| SiphtRow {
            stage,
            tasks: n,
            ref_wait: rw / n.max(1) as f64,
            ours_wait: ow / n.max(1) as f64,
        })
        .collect();
    let r: Vec<f64> = rows.iter().map(|x| x.ref_wait).collect();
    let o: Vec<f64> = rows.iter().map(|x| x.ours_wait).collect();
    SiphtValidation {
        mae: mae(&o, &r),
        ref_makespan: reference.makespan.ticks(),
        ours_makespan: ours.makespan.ticks(),
        rows,
    }
}

pub fn print_fig7(v: &SiphtValidation) {
    let mut t = Table::new(&["stage", "tasks", "ref wait (s)", "ours wait (s)"]);
    for r in &v.rows {
        t.row(&[r.stage.clone(), r.tasks.to_string(), f(r.ref_wait), f(r.ours_wait)]);
    }
    t.print();
    println!(
        "MAE = {:.2} s   makespan ref {} s vs ours {} s\n",
        v.mae, v.ref_makespan, v.ours_makespan
    );
}

/// One row of a fault-tolerance comparison: a (policy, preemption mode)
/// pair run against a common seeded failure trace.
#[derive(Debug, Clone)]
pub struct FaultRow {
    pub policy: &'static str,
    pub mode: &'static str,
    pub mean_wait: f64,
    pub mean_utilization: f64,
    /// Goodput: useful core-seconds per available core-second (the
    /// headline metric — see `SimReport::mean_effective_utilization`).
    pub effective_utilization: f64,
    pub lost_work: f64,
    pub overhead_work: f64,
    pub failures: u64,
    pub preemptions: u64,
    pub requeues: u64,
    pub makespan: u64,
}

/// Shared environment of a `fault_comparison`: everything about the run
/// that is *not* the (policy, preemption) case under comparison — the
/// failure model, reservations, planning knobs, queue ordering and
/// memory awareness all apply to every case identically (so a CLI
/// `--order fair-share` or `--memory-aware` is honored by `sst-sched
/// faults` instead of silently ignored).
#[derive(Debug, Clone, Default)]
pub struct FaultCompareOpts<'a> {
    pub faults: crate::sim::FaultConfig,
    pub reservations: &'a [crate::sim::ReservationSpec],
    pub planning_horizon: crate::sim::Horizon,
    pub auto_horizon: crate::sim::AutoHorizonParams,
    pub order: Option<crate::sched::OrderKind>,
    pub fairshare_half_life: u64,
    pub mem_per_node: u64,
    pub memory_aware: bool,
}

/// Run every `(policy, preemption)` case against the *same* failure
/// trace (the injector stream is seeded per-run, not shared, so every
/// case sees identical failure instants, victims and repair times) and
/// report the comparison (fault/preemption subsystem; used by
/// examples/fault_tolerance.rs and the `faults` CLI command).
pub fn fault_comparison(
    workload: &Workload,
    opts: &FaultCompareOpts<'_>,
    cases: &[(Policy, crate::sched::PreemptionConfig)],
) -> Vec<FaultRow> {
    cases
        .iter()
        .map(|&(policy, preemption)| {
            let mut sim = crate::sim::Simulation::new(workload.clone(), policy)
                .with_faults(opts.faults)
                .with_preemption(preemption)
                .with_reservations(opts.reservations.to_vec())
                .with_horizon(opts.planning_horizon)
                .with_auto_horizon_params(opts.auto_horizon)
                .with_mem_per_node(opts.mem_per_node)
                .with_memory_aware(opts.memory_aware);
            if opts.fairshare_half_life > 0 {
                sim = sim.with_fairshare_half_life(opts.fairshare_half_life);
            }
            if let Some(order) = opts.order {
                sim = sim.with_order(order);
            }
            let r = sim.run(None);
            FaultRow {
                policy: r.policy,
                mode: r.preemption_mode,
                mean_wait: r.wait_stats().mean_wait,
                mean_utilization: r.mean_utilization,
                effective_utilization: r.mean_effective_utilization,
                lost_work: r.lost_work,
                overhead_work: r.overhead_work,
                failures: r.faults.failures,
                preemptions: r.faults.preemptions,
                requeues: r.faults.requeues,
                makespan: r.makespan().ticks(),
            }
        })
        .collect()
}

pub fn print_fault_rows(rows: &[FaultRow]) {
    let mut t = Table::new(&[
        "policy",
        "preemption",
        "mean wait (s)",
        "eff util",
        "util",
        "lost (core-s)",
        "overhead (core-s)",
        "fails",
        "evictions",
        "requeues",
        "makespan (s)",
    ]);
    for r in rows {
        t.row(&[
            r.policy.to_string(),
            r.mode.to_string(),
            f(r.mean_wait),
            format!("{:.3}", r.effective_utilization),
            format!("{:.3}", r.mean_utilization),
            f(r.lost_work),
            f(r.overhead_work),
            r.failures.to_string(),
            r.preemptions.to_string(),
            r.requeues.to_string(),
            r.makespan.to_string(),
        ]);
    }
    t.print();
    println!();
}

/// Summary of one plain `run` invocation (CLI).
pub fn print_run_report(r: &crate::sim::SimReport) {
    let s = wait_stats(&r.completed);
    println!("workload          {}", r.workload);
    println!("policy            {}", r.policy);
    if r.order != "arrival" {
        println!("queue order       {}", r.order);
    }
    println!("jobs completed    {}", s.jobs);
    println!("jobs rejected     {}", r.rejected);
    println!("DES events        {}", r.events);
    println!("dispatch rounds   {}", r.dispatches);
    println!("sim end time      {} s", r.end_time.ticks());
    println!("mean wait         {:.1} s", s.mean_wait);
    println!("median wait       {:.1} s", s.median_wait);
    println!("p95 wait          {:.1} s", s.p95_wait);
    println!("mean slowdown     {:.2}", s.mean_slowdown);
    println!("mean utilization  {:.3}", r.mean_utilization);
    if !r.memory_utilization.points().is_empty() {
        println!("mean memory util  {:.3}", r.mean_memory_utilization);
    }
    if !r.user_shares.is_empty() {
        let s = crate::metrics::share_stats(&r.user_shares);
        println!(
            "fair-share users  {} (max {:.0} core-s decayed, imbalance {:.2})",
            s.users, s.max_usage, s.imbalance
        );
    }
    // Fault/preemption outputs, only when the subsystem was active.
    if r.faults != crate::sim::FaultCounters::default() || r.preemption_mode != "none" {
        println!("preemption mode   {}", r.preemption_mode);
        println!("effective util    {:.3}", r.mean_effective_utilization);
        println!("node failures     {}", r.faults.failures);
        println!("node repairs      {}", r.faults.repairs);
        println!("preemptions       {}", r.faults.preemptions);
        println!("failure requeues  {}", r.faults.requeues);
        println!("reservations      {}", r.faults.reservations_started);
        if r.faults.reservations_short_nodes > 0 {
            println!("resv short nodes  {}", r.faults.reservations_short_nodes);
        }
        println!("lost work         {:.0} core-s", r.lost_work);
        println!("ckpt overhead     {:.0} core-s", r.overhead_work);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3a_validates_closely() {
        let v = fig3a(800, 3, 24);
        assert_eq!(v.ours.len(), 24);
        // Independent implementations must track each other closely.
        assert!(v.correlation > 0.9, "corr {}", v.correlation);
        assert!(v.nmae < 0.15, "nmae {}", v.nmae);
    }

    #[test]
    fn fig3b_validates_closely() {
        let v = fig3b(800, 3, 24);
        assert!(v.correlation > 0.9, "corr {}", v.correlation);
    }

    #[test]
    fn fig4a_waits_agree() {
        let v = fig4a(1500, 5, 10);
        assert!(v.ours.iter().sum::<f64>() > 0.0, "no waits formed — vacuous validation");
        assert!(v.correlation > 0.9, "corr {}", v.correlation);
    }

    #[test]
    fn fig4b_orders_policies_as_paper() {
        let rows = fig4b(1500, 11);
        let by = |name: &str| rows.iter().find(|r| r.policy == name).unwrap().clone();
        let bf = by("fcfs-backfill");
        let fcfs = by("fcfs");
        let sjf = by("sjf");
        let ljf = by("ljf");
        // Backfilling beats plain FCFS on wait.
        assert!(bf.mean_wait <= fcfs.mean_wait, "bf {} fcfs {}", bf.mean_wait, fcfs.mean_wait);
        // SJF minimizes mean wait among the blocking disciplines.
        assert!(sjf.mean_wait <= fcfs.mean_wait);
        // LJF is the worst on mean wait (paper: "less efficient").
        assert!(ljf.mean_wait >= sjf.mean_wait);
    }

    #[test]
    fn fig7_reference_and_ours_are_close() {
        let v = fig7(2, 8, 1);
        assert!(!v.rows.is_empty());
        // Same structure, jittered runtimes: makespans within 25%.
        let ratio = v.ours_makespan as f64 / v.ref_makespan as f64;
        assert!((0.75..1.25).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn fig6_completes_all_ranks() {
        let rows = fig6(2, &[1, 2], 1);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].jobs, rows[1].jobs);
    }

    #[test]
    fn fault_comparison_shares_one_failure_trace() {
        use crate::core::time::SimDuration;
        use crate::sched::{PreemptionConfig, PreemptionMode};
        let w = Das2Model::default().generate(500, 5).scale_arrivals(0.5).drop_infeasible();
        let faults =
            crate::sim::FaultConfig { mtbf: 5_000.0, mttr: 2_000.0, seed: 11, ..crate::sim::FaultConfig::default() };
        let ckpt = PreemptionConfig {
            mode: PreemptionMode::Checkpoint,
            checkpoint_overhead: SimDuration(30),
            restart_overhead: SimDuration(30),
            starvation_threshold: SimDuration::ZERO,
        };
        let rows = fault_comparison(
            &w,
            &FaultCompareOpts { faults, ..FaultCompareOpts::default() },
            &[(Policy::Fcfs, PreemptionConfig::default()), (Policy::FcfsBackfill, ckpt)],
        );
        assert_eq!(rows.len(), 2);
        // Identical injector stream => identical failure counts.
        assert_eq!(rows[0].failures, rows[1].failures);
        assert!(rows[0].failures > 0, "no failures injected — vacuous comparison");
        assert_eq!(rows[0].mode, "none");
        assert_eq!(rows[1].mode, "checkpoint");
    }
}
