//! Runtime simulation sanitizer: always-on (in debug) invariant checks
//! threaded through the scheduler component, the event queue, the
//! engine, and the sharded rank driver.
//!
//! Every check here guards a property the determinism/correctness
//! contract depends on:
//!
//! * **Conservation** — per-node core/memory sums equal the cluster's
//!   cached aggregates (the incremental allocate/release bookkeeping
//!   never drifts from per-node truth).
//! * **Profile oracle** — the incrementally maintained
//!   [`AvailabilityProfile`] equals a from-scratch rebuild every N
//!   dispatch rounds (the Timeline hold/release algebra is exact).
//! * **Pop order** — event-queue pops never go back in time, and equal
//!   `(time, priority)` pops arrive in strictly increasing `seq` (the
//!   total order every fingerprint rests on has no duplicate keys).
//! * **Segment accounting** — a completed job's executed time equals
//!   `runtime + overhead + lost` exactly (preemption/fault bookkeeping
//!   neither invents nor loses work).
//! * **Delivery bound** — sharded-run messages are delivered at or
//!   after the receiving rank's completed YAWNS window bound
//!   (conservative synchronization actually held).
//!
//! Checks are active when [`ACTIVE`] is true: every debug build, plus
//! release builds with `--features sanitize`. The checking code takes
//! plain data (samples, ticks, keys), so each invariant is unit-tested
//! by corrupting inputs directly. A violation panics with a structured
//! report — tick, site, invariant, expected vs got — instead of letting
//! a corrupted state produce a plausible-looking result.
//!
//! Global [`stats`] counters record how many times each invariant was
//! exercised; the end-to-end sanitize test asserts every counter moved.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::resources::{AvailabilityProfile, Cluster, NodeState};

/// Whether sanitizer checks run in this build: all debug builds, plus
/// release builds compiled with `--features sanitize`. Branches guarded
/// by this constant fold away entirely in ordinary release builds.
pub const ACTIVE: bool = cfg!(any(feature = "sanitize", debug_assertions));

/// Below this many events, conservation is checked on every event
/// (short tests get full coverage) ...
pub const EVENT_CHECK_DENSE: u64 = 1024;
/// ... above it, every this-many events (long runs stay fast).
pub const EVENT_CHECK_INTERVAL: u64 = 64;
/// Profile-vs-rebuild cadence, in dispatch rounds (the first round is
/// always checked so even tiny runs exercise the oracle).
pub const PROFILE_CHECK_INTERVAL: u64 = 64;

static CONSERVATION_CHECKS: AtomicU64 = AtomicU64::new(0);
static PROFILE_CHECKS: AtomicU64 = AtomicU64::new(0);
static SEGMENT_CHECKS: AtomicU64 = AtomicU64::new(0);
static POP_CHECKS: AtomicU64 = AtomicU64::new(0);
static ENGINE_TIME_CHECKS: AtomicU64 = AtomicU64::new(0);
static DELIVERY_CHECKS: AtomicU64 = AtomicU64::new(0);

/// How many times each invariant has been exercised, process-wide.
/// Counters only ever increase (tests snapshot before/after and assert
/// on the delta, so parallel test execution cannot break them).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SanStats {
    pub conservation: u64,
    pub profile: u64,
    pub segment: u64,
    pub pops: u64,
    pub engine_time: u64,
    pub delivery: u64,
}

pub fn stats() -> SanStats {
    SanStats {
        conservation: CONSERVATION_CHECKS.load(Ordering::Relaxed),
        profile: PROFILE_CHECKS.load(Ordering::Relaxed),
        segment: SEGMENT_CHECKS.load(Ordering::Relaxed),
        pops: POP_CHECKS.load(Ordering::Relaxed),
        engine_time: ENGINE_TIME_CHECKS.load(Ordering::Relaxed),
        delivery: DELIVERY_CHECKS.load(Ordering::Relaxed),
    }
}

/// Per-component cadence state: decides *when* the expensive checks run
/// (the checks themselves are free functions over plain data).
#[derive(Debug, Clone, Default)]
pub struct SimSanitizer {
    events: u64,
    dispatches: u64,
}

impl SimSanitizer {
    pub fn new() -> SimSanitizer {
        SimSanitizer::default()
    }

    /// Called once per handled event; true when conservation should be
    /// checked now (every event early on, then every
    /// [`EVENT_CHECK_INTERVAL`]).
    pub fn on_event(&mut self) -> bool {
        self.events += 1;
        self.events <= EVENT_CHECK_DENSE || self.events % EVENT_CHECK_INTERVAL == 0
    }

    /// Called once per dispatch round; true when the profile should be
    /// compared against a from-scratch rebuild (first round, then every
    /// [`PROFILE_CHECK_INTERVAL`]).
    pub fn on_dispatch(&mut self) -> bool {
        self.dispatches += 1;
        self.dispatches == 1 || self.dispatches % PROFILE_CHECK_INTERVAL == 0
    }
}

/// Structured failure report. `#[cold]` keeps the formatting machinery
/// off the checked hot paths.
#[cold]
#[inline(never)]
fn violation(invariant: &str, tick: u64, site: &str, detail: &str) -> ! {
    panic!(
        "sanitizer: simulation invariant violated\n  \
         invariant: {invariant}\n  \
         tick:      {tick}\n  \
         site:      {site}\n  \
         {detail}\n  \
         (a corrupted state would otherwise produce a plausible-looking result)"
    );
}

// ----- event order -----

/// Pop-order check for the event queue. `last` is the queue's record of
/// the previously popped key. Time must never decrease across pops, and
/// a pop with the same `(time, priority)` as the last one must carry a
/// strictly greater `seq` — i.e. the total order `(time, priority,
/// seq)` has no duplicate or reordered keys *within a priority class*.
/// A pop is allowed to have lower priority than its same-tick
/// predecessor: handlers legitimately push higher-urgency events at the
/// current tick.
pub fn check_pop_order(last: &mut Option<(u64, u8, u64)>, time: u64, priority: u8, seq: u64) {
    POP_CHECKS.fetch_add(1, Ordering::Relaxed);
    if let Some((lt, lp, ls)) = *last {
        if time < lt {
            violation(
                "event-queue pop time monotonicity",
                time,
                "EventQueue::pop",
                &format!("expected: time >= {lt}\n  got:       time {time} (after ({lt}, {lp}, {ls}))"),
            );
        }
        if time == lt && priority == lp && seq <= ls {
            violation(
                "event-queue unique (time, priority, seq) keys",
                time,
                "EventQueue::pop",
                &format!(
                    "expected: seq > {ls} at (time {lt}, priority {lp})\n  got:       seq {seq}"
                ),
            );
        }
    }
    *last = Some((time, priority, seq));
}

/// Engine-side check that a dequeued event is not earlier than the
/// current simulation time (replaces the old bare `debug_assert!`).
pub fn check_engine_time(now: u64, ev_time: u64) {
    ENGINE_TIME_CHECKS.fetch_add(1, Ordering::Relaxed);
    if ev_time < now {
        violation(
            "engine time monotonicity",
            now,
            "Engine event loop",
            &format!("expected: event time >= now {now}\n  got:       event time {ev_time}"),
        );
    }
}

// ----- conservation -----

/// A plain snapshot of a cluster's accounting state: per-node truth
/// plus the cached aggregates. Built by [`sample_cluster`]; checked by
/// [`check_conservation`]. Keeping it plain data lets tests corrupt a
/// field directly and prove the invariant trips.
#[derive(Debug, Clone)]
pub struct ConservationSample {
    /// Per node: (cores, free_cores, memory_mb, free_memory_mb, state).
    pub nodes: Vec<(u64, u64, u64, u64, NodeState)>,
    pub cached_free: u64,
    pub cached_busy: u64,
    pub cached_total: u64,
    pub cached_available: u64,
    pub cached_free_mem: u64,
    pub cached_total_mem: u64,
}

pub fn sample_cluster(c: &Cluster) -> ConservationSample {
    ConservationSample {
        nodes: c
            .nodes()
            .iter()
            .map(|n| (n.cores, n.free_cores, n.memory_mb, n.free_memory_mb, n.state))
            .collect(),
        cached_free: c.free_cores(),
        cached_busy: c.busy_cores(),
        cached_total: c.total_cores(),
        cached_available: c.available_cores(),
        cached_free_mem: c.free_memory_mb(),
        cached_total_mem: c.total_memory_mb(),
    }
}

/// Core/memory conservation: the cluster's cached aggregates equal the
/// per-node sums, and no node is over-freed. Mirrors
/// `Cluster::check_invariants` but over a plain sample, with a
/// structured report naming the first law that fails.
pub fn check_conservation(s: &ConservationSample, now: u64, site: &str) {
    CONSERVATION_CHECKS.fetch_add(1, Ordering::Relaxed);
    let mut free_up = 0u64;
    let mut busy = 0u64;
    let mut total = 0u64;
    let mut down = 0u64;
    let mut free_mem_up = 0u64;
    for &(cores, free, mem, free_mem, state) in &s.nodes {
        if free > cores || free_mem > mem {
            violation(
                "per-node bounds (free <= capacity)",
                now,
                site,
                &format!(
                    "expected: free_cores <= {cores} and free_memory_mb <= {mem}\n  \
                     got:       free_cores {free}, free_memory_mb {free_mem}"
                ),
            );
        }
        total += cores;
        busy += cores - free;
        if state == NodeState::Up {
            free_up += free;
            free_mem_up += free_mem;
        }
        if state == NodeState::Down {
            down += cores;
        }
    }
    let checks: [(&str, u64, u64); 5] = [
        ("free cores on Up nodes == cached free_cores", free_up, s.cached_free),
        ("allocated cores == cached busy_cores", busy, s.cached_busy),
        ("sum of node cores == cached total_cores", total, s.cached_total),
        ("total - Down capacity == available_cores", total - down, s.cached_available),
        ("free memory on Up nodes == cached free_memory_mb", free_mem_up, s.cached_free_mem),
    ];
    for (law, want, got) in checks {
        if want != got {
            violation(
                "core/memory conservation",
                now,
                site,
                &format!("law:       {law}\n  expected: {want}\n  got:       {got}"),
            );
        }
    }
    if s.cached_free > s.cached_total {
        violation(
            "core/memory conservation",
            now,
            site,
            &format!(
                "law:       free_cores <= total_cores\n  expected: <= {}\n  got:       {}",
                s.cached_total, s.cached_free
            ),
        );
    }
}

// ----- segment accounting -----

/// At job completion, executed time decomposes exactly into useful
/// runtime, checkpoint/restart overhead, and work lost to kills. All
/// arguments are ticks.
pub fn check_segment_accounting(
    job_id: u64,
    now: u64,
    executed: u64,
    runtime: u64,
    overhead: u64,
    lost: u64,
) {
    SEGMENT_CHECKS.fetch_add(1, Ordering::Relaxed);
    let decomposed = runtime + overhead + lost;
    if executed != decomposed {
        violation(
            "job segment accounting (executed == runtime + overhead + lost)",
            now,
            "SchedulerComponent::complete",
            &format!(
                "job:       {job_id}\n  \
                 expected: executed == {runtime} + {overhead} + {lost} == {decomposed}\n  \
                 got:       executed {executed}"
            ),
        );
    }
}

// ----- sharded delivery -----

/// A cross-rank message must arrive at or after the receiving rank's
/// last completed YAWNS window bound — deliveries inside an already
/// simulated window would be causality violations the conservative
/// protocol exists to prevent.
pub fn check_delivery(time: u64, window_bound: u64, shard: usize) {
    DELIVERY_CHECKS.fetch_add(1, Ordering::Relaxed);
    if time < window_bound {
        violation(
            "sharded delivery >= completed YAWNS window bound",
            time,
            "ShardRank::receive",
            &format!(
                "shard:     {shard}\n  \
                 expected: delivery time >= window bound {window_bound}\n  \
                 got:       delivery time {time}"
            ),
        );
    }
}

// ----- profile oracle -----

/// Value-wise equality of two availability profiles: equal `free_at` /
/// `free_memory_at` at every breakpoint of either profile (plus
/// just-after sentinels and `now`). Canonical step functions that agree
/// at the union of their breakpoints agree everywhere, and value-wise
/// comparison deliberately accepts representation differences — a
/// materialized-but-flat memory timeline versus an unmaterialized one
/// is the same function.
pub fn check_profile_match(
    actual: &AvailabilityProfile,
    expected: &AvailabilityProfile,
    now: u64,
    site: &str,
) {
    PROFILE_CHECKS.fetch_add(1, Ordering::Relaxed);
    if actual.total() != expected.total() {
        violation(
            "incremental profile == rebuilt profile",
            now,
            site,
            &format!(
                "expected: total {} cores\n  got:       total {} cores",
                expected.total(),
                actual.total()
            ),
        );
    }
    let mut times: Vec<u64> = Vec::with_capacity(2 * (actual.len() + expected.len()) + 2);
    times.push(now);
    times.push(now.saturating_add(1));
    for p in [actual, expected] {
        for &(t, _) in p.points() {
            times.push(t);
            times.push(t.saturating_add(1));
        }
        if let Some(mp) = p.mem_points() {
            for &(t, _) in mp {
                times.push(t);
                times.push(t.saturating_add(1));
            }
        }
    }
    times.sort_unstable();
    times.dedup();
    // Only the present and the future are contractual: the scheduler
    // never queries availability before `now`, and the incremental
    // profile legitimately keeps expired breakpoints a fresh rebuild
    // does not have.
    times.retain(|&t| t >= now);
    for &t in &times {
        let (a, e) = (actual.free_at(t), expected.free_at(t));
        if a != e {
            violation(
                "incremental profile == rebuilt profile",
                now,
                site,
                &format!("at t={t}:\n  expected: {e} free cores\n  got:       {a} free cores"),
            );
        }
        let (am, em) = (actual.free_memory_at(t), expected.free_memory_at(t));
        if am != em {
            violation(
                "incremental profile == rebuilt profile (memory dimension)",
                now,
                site,
                &format!("at t={t}:\n  expected: {em} free MB\n  got:       {am} free MB"),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resources::ResourceVector;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    fn trips<F: FnOnce()>(f: F) -> bool {
        catch_unwind(AssertUnwindSafe(f)).is_err()
    }

    // ---- pop order ----

    #[test]
    fn pop_order_accepts_legal_sequences() {
        let mut last = None;
        check_pop_order(&mut last, 10, 1, 5);
        check_pop_order(&mut last, 10, 1, 9); // same key class, seq up
        check_pop_order(&mut last, 10, 2, 3); // same tick, lower urgency
        check_pop_order(&mut last, 10, 0, 11); // same tick, handler pushed urgent
        check_pop_order(&mut last, 42, 3, 1); // time advances, seq resets
    }

    #[test]
    fn pop_order_trips_on_time_regression_and_dup_keys() {
        assert!(trips(|| {
            let mut last = Some((100, 1, 5));
            check_pop_order(&mut last, 99, 1, 6);
        }));
        assert!(trips(|| {
            let mut last = Some((100, 1, 5));
            check_pop_order(&mut last, 100, 1, 5); // duplicate key
        }));
        assert!(trips(|| {
            let mut last = Some((100, 1, 5));
            check_pop_order(&mut last, 100, 1, 4); // reordered within class
        }));
    }

    #[test]
    fn engine_time_trips_on_backwards_event() {
        check_engine_time(50, 50);
        check_engine_time(50, 51);
        assert!(trips(|| check_engine_time(50, 49)));
    }

    // ---- conservation ----

    fn sample_of(cluster: &Cluster) -> ConservationSample {
        sample_cluster(cluster)
    }

    #[test]
    fn conservation_passes_on_consistent_cluster() {
        let c = Cluster::homogeneous(4, 8, 1024);
        check_conservation(&sample_of(&c), 0, "test");
    }

    #[test]
    fn conservation_trips_on_each_corruption() {
        let c = Cluster::homogeneous(4, 8, 1024);
        let clean = sample_of(&c);

        let mut s = clean.clone();
        s.cached_free += 1; // phantom free core
        assert!(trips(|| check_conservation(&s, 7, "test")));

        let mut s = clean.clone();
        s.cached_busy += 3; // phantom allocation
        assert!(trips(|| check_conservation(&s, 7, "test")));

        let mut s = clean.clone();
        s.nodes[0].1 = s.nodes[0].0 + 1; // node over-freed
        assert!(trips(|| check_conservation(&s, 7, "test")));

        let mut s = clean.clone();
        s.cached_available -= 8; // down accounting drift
        assert!(trips(|| check_conservation(&s, 7, "test")));

        let mut s = clean;
        s.cached_free_mem -= 1; // memory drift
        assert!(trips(|| check_conservation(&s, 7, "test")));
    }

    // ---- segment accounting ----

    #[test]
    fn segment_accounting_exact() {
        check_segment_accounting(1, 100, 120, 100, 5, 15);
        assert!(trips(|| check_segment_accounting(1, 100, 121, 100, 5, 15)));
        assert!(trips(|| check_segment_accounting(1, 100, 119, 100, 5, 15)));
    }

    // ---- delivery ----

    #[test]
    fn delivery_bound_checked() {
        check_delivery(60, 60, 0);
        check_delivery(61, 60, 0);
        assert!(trips(|| check_delivery(59, 60, 1)));
    }

    // ---- profile oracle ----

    #[test]
    fn profile_match_accepts_identical_and_equivalent_profiles() {
        let mut a = AvailabilityProfile::new(0, 20, 32);
        let mut e = AvailabilityProfile::new(0, 20, 32);
        a.hold(10, 50, 8);
        e.rebuild(0, 20, vec![(10, -8), (50, 8)]);
        check_profile_match(&a, &e, 0, "test");
    }

    #[test]
    fn profile_match_accepts_materialized_flat_memory_vs_none() {
        let total = ResourceVector::new(32, 4096);
        let free = ResourceVector::new(32, 4096);
        let mut a = AvailabilityProfile::new_v(0, free, total);
        let e = AvailabilityProfile::new_v(0, free, total);
        // Materialize a's memory timeline, then cancel it exactly: the
        // representations differ (Some flat vs None) but the functions
        // are equal, and the value-wise compare must accept that.
        a.hold_v(10, 50, ResourceVector::new(0, 512));
        a.release_v(10, 50, ResourceVector::new(0, 512));
        check_profile_match(&a, &e, 0, "test");
    }

    #[test]
    fn profile_match_trips_on_core_and_memory_skew() {
        let mut a = AvailabilityProfile::new(0, 20, 32);
        let e = AvailabilityProfile::new(0, 20, 32);
        a.hold(10, 50, 1); // one phantom held core
        assert!(trips(|| check_profile_match(&a, &e, 0, "test")));

        let total = ResourceVector::new(32, 4096);
        let free = ResourceVector::new(32, 4096);
        let mut am = AvailabilityProfile::new_v(0, free, total);
        let em = AvailabilityProfile::new_v(0, free, total);
        am.hold_v(10, 50, ResourceVector::new(0, 256)); // memory-only skew
        assert!(trips(|| check_profile_match(&am, &em, 0, "test")));
    }

    // ---- cadence ----

    #[test]
    fn cadence_checks_first_dispatch_and_then_interval() {
        let mut s = SimSanitizer::new();
        assert!(s.on_dispatch()); // round 1 always checked
        let mut checked = 0;
        for _ in 0..(2 * PROFILE_CHECK_INTERVAL) {
            if s.on_dispatch() {
                checked += 1;
            }
        }
        assert_eq!(checked, 2);
    }

    #[test]
    fn cadence_is_dense_early_then_sampled() {
        let mut s = SimSanitizer::new();
        for _ in 0..EVENT_CHECK_DENSE {
            assert!(s.on_event());
        }
        let later: u64 = (0..10 * EVENT_CHECK_INTERVAL).filter(|_| s.on_event()).count() as u64;
        assert_eq!(later, 10);
    }

    #[test]
    fn stats_counters_move() {
        let before = stats();
        check_engine_time(1, 2);
        let mut last = None;
        check_pop_order(&mut last, 1, 0, 1);
        let after = stats();
        assert!(after.engine_time > before.engine_time);
        assert!(after.pops > before.pops);
    }
}
