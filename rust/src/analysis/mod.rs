//! Correctness tooling for the determinism contract.
//!
//! The crate's headline guarantee — byte-identical fingerprints across
//! runs, shard counts, and ingestion formats — is a *global* property:
//! one hasher-ordered iteration or NaN-swallowing comparator anywhere in
//! a decision path silently voids it. This module makes the contract
//! enforceable instead of aspirational, in two layers:
//!
//! * [`lint`] — a dependency-free static pass over `rust/src/` that
//!   flags determinism hazards at review time (`HashMap`/`HashSet`
//!   iteration in decision modules, `partial_cmp` comparators,
//!   wall-clock reads outside measurement code, ambient randomness).
//!   `rust/tests/lint.rs` runs it as part of `cargo test`.
//! * [`sanitizer`] — runtime invariant checks threaded through the
//!   scheduler component, the event queue, the engine tick loop, and
//!   the sharded rank driver. Always on under `debug_assertions`;
//!   forced on in release builds with `--features sanitize`. A violated
//!   invariant panics with a structured report instead of corrupting a
//!   result.

pub mod lint;
pub mod sanitizer;
