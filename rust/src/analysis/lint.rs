//! Repo-specific determinism lint: a self-contained, dependency-free
//! line scanner over `rust/src/`.
//!
//! This is not a general Rust linter — it knows this crate's
//! determinism contract and nothing else. Every rule exists because the
//! hazard it matches has either bitten the repo before or would void
//! the byte-identical-fingerprint guarantee silently:
//!
//! | rule id        | hazard |
//! |----------------|--------|
//! | `hash-iter`    | `HashMap`/`HashSet` iteration in a decision-path module (`sched/`, `sim/`, `core/`, `parallel/`, `resources/`, `workflow/`): hasher order leaks into decisions |
//! | `partial-cmp`  | `.partial_cmp(..)` call sites (typically `.unwrap()`d in comparators): NaN either panics or silently reorders — use `total_cmp` or integer keys |
//! | `wall-clock`   | `Instant::now` / `SystemTime` outside measurement code (`harness/`, `util/bench.rs`, `parallel/` timing, `main.rs`): wall time must never reach simulation state |
//! | `ambient-rand` | `thread_rng` / `rand::random` / entropy-seeded state anywhere: all randomness must flow from the seeded simulation RNG |
//!
//! # Escapes
//!
//! A `hash-iter` site whose result is *demonstrably order-folded* —
//! a commutative fold (`.sum()`, `.count()`, `.any(..)`, ...) or a sort
//! within the next few lines — passes automatically. Everything else
//! needs an explicit escape comment, either trailing the offending line
//! or on a comment line directly above it:
//!
//! ```text
//! // lint:allow(hash-iter, deltas are sorted inside Timeline rebuild)
//! for entry in self.running.values_mut() { ... }
//! ```
//!
//! The reason is mandatory and must not contain `)` (the scanner is a
//! line scanner, not a parser). An allow that names an unknown rule or
//! carries no reason is itself a finding (`bad-allow`); an allow whose
//! target line has no matching violation is a finding (`unused-allow`)
//! so escapes cannot rot in place.
//!
//! # Matching model
//!
//! The scanner strips `//` comments, tracks which identifiers in a file
//! are declared as `HashMap`/`HashSet` (struct fields, `let` bindings,
//! typed parameters), and only flags iteration *on those names* — a
//! slice parameter that happens to be called `running` is not a hash
//! map. Method-chain receivers are resolved across line breaks, so
//! rustfmt's `self\n.usage\n.iter()` shape is still caught.

use std::fmt;
use std::fs;
use std::path::Path;

/// One determinism rule: stable id (the `lint:allow` key) + contract.
pub struct Rule {
    pub id: &'static str,
    pub doc: &'static str,
}

/// The rule registry; ids are the only valid `lint:allow` keys.
pub const RULES: &[Rule] = &[
    Rule {
        id: "hash-iter",
        doc: "no HashMap/HashSet iteration in decision-path modules \
              (sched/, sim/, core/, parallel/, resources/, workflow/) \
              unless order-folded, sorted nearby, or lint:allow'd",
    },
    Rule {
        id: "partial-cmp",
        doc: "no .partial_cmp(..) call sites — comparators must use \
              total_cmp or integer keys so NaN cannot reorder or panic",
    },
    Rule {
        id: "wall-clock",
        doc: "no Instant::now/SystemTime outside harness/, util/bench.rs, \
              parallel/ timing, and main.rs — wall time never reaches \
              simulation state",
    },
    Rule {
        id: "ambient-rand",
        doc: "no thread_rng/rand::random/entropy-seeded state anywhere — \
              randomness flows from the seeded simulation RNG only",
    },
];

/// Modules whose iteration order is decision-carrying.
const DECISION_DIRS: &[&str] =
    &["sched/", "sim/", "core/", "parallel/", "resources/", "workflow/"];

/// Where wall-clock reads are legitimate (measurement, CLI timing).
const WALL_CLOCK_DIRS: &[&str] = &["harness/", "parallel/"];
const WALL_CLOCK_FILES: &[&str] = &["util/bench.rs", "main.rs"];

/// Iteration methods that expose hasher order.
const ITER_METHODS: &[&str] =
    &[".iter()", ".iter_mut()", ".values()", ".values_mut()", ".keys()", ".drain("];

/// Tokens that mark a candidate as order-folded when they appear on the
/// candidate line or within the next few lines of the same expression:
/// commutative folds, or a sort that canonicalizes the collected result.
const FOLD_TOKENS: &[&str] =
    &["sort", ".sum", ".count(", ".fold(", ".any(", ".all(", ".min(", ".max("];

/// How many lines past the candidate the fold heuristic looks.
const FOLD_WINDOW: usize = 4;

/// Randomness entry points that bypass the seeded RNG.
const RAND_TOKENS: &[&str] =
    &["thread_rng", "rand::random", "from_entropy", "RandomState::new"];

/// One lint violation, printable as `file:line: rule-id — message`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: {} — {}", self.file, self.line, self.rule, self.message)
    }
}

/// Scan every `.rs` file under this crate's `src/` (except `analysis/`
/// itself, whose rule fixtures would self-flag). The `tests/lint.rs`
/// driver fails on any returned finding.
pub fn run_repo_lint() -> Vec<Finding> {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let mut files = Vec::new();
    collect_rs_files(&root, &root, &mut files);
    files.sort();
    let mut findings = Vec::new();
    for rel in &files {
        if rel.starts_with("analysis/") {
            continue;
        }
        let content = fs::read_to_string(root.join(rel)).unwrap_or_default();
        findings.extend(scan_file(rel, &content));
    }
    findings
}

fn collect_rs_files(root: &Path, dir: &Path, out: &mut Vec<String>) {
    let Ok(entries) = fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(root, &path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            if let Ok(rel) = path.strip_prefix(root) {
                out.push(rel.to_string_lossy().replace('\\', "/"));
            }
        }
    }
}

/// An escape comment waiting to be matched against a violation.
struct Allow {
    rule: &'static str,
    line: usize,
    used: bool,
}

/// Scan one file's source. `rel` is the path relative to `src/` with
/// `/` separators — it selects which rules apply.
pub fn scan_file(rel: &str, content: &str) -> Vec<Finding> {
    let raw: Vec<&str> = content.lines().collect();
    let code: Vec<String> = raw.iter().map(|l| strip_comment(l).to_string()).collect();
    let decision = DECISION_DIRS.iter().any(|d| rel.starts_with(d));
    let wall_ok = WALL_CLOCK_DIRS.iter().any(|d| rel.starts_with(d))
        || WALL_CLOCK_FILES.contains(&rel);
    let hash_names = collect_hash_names(&code);

    let mut findings = Vec::new();
    let mut pending: Vec<Allow> = Vec::new();
    for (i, rawline) in raw.iter().enumerate() {
        let line_no = i + 1;
        let mut allows = parse_allows(rel, rawline, line_no, &mut findings);
        let trimmed = rawline.trim_start();
        if trimmed.is_empty() || trimmed.starts_with("//") {
            // Comment-only (or blank) line: its allows apply to the
            // next code line.
            pending.append(&mut allows);
            continue;
        }
        allows.append(&mut pending);

        let mut candidates: Vec<(&'static str, String)> = Vec::new();
        let cl = &code[i];
        if decision {
            hash_iter_candidates(&code, i, &hash_names, &mut candidates);
        }
        if cl.contains(".partial_cmp(") {
            candidates.push((
                "partial-cmp",
                "`.partial_cmp(..)` call site — use `total_cmp` or an integer key \
                 so NaN cannot reorder or panic"
                    .to_string(),
            ));
        }
        if !wall_ok && (cl.contains("Instant::now") || cl.contains("SystemTime")) {
            candidates.push((
                "wall-clock",
                "wall-clock read outside measurement code — simulation state must \
                 only see simulated time"
                    .to_string(),
            ));
        }
        for tok in RAND_TOKENS {
            if cl.contains(tok) {
                candidates.push((
                    "ambient-rand",
                    format!("`{tok}` bypasses the seeded simulation RNG"),
                ));
            }
        }

        for (rule, message) in candidates {
            if let Some(a) = allows.iter_mut().find(|a| a.rule == rule) {
                a.used = true;
                continue;
            }
            if rule == "hash-iter" && order_folded(&code, i) {
                continue;
            }
            findings.push(Finding { file: rel.to_string(), line: line_no, rule, message });
        }
        for a in allows {
            if !a.used {
                findings.push(unused_allow(rel, &a));
            }
        }
    }
    for a in pending {
        findings.push(unused_allow(rel, &a));
    }
    findings
}

fn unused_allow(rel: &str, a: &Allow) -> Finding {
    Finding {
        file: rel.to_string(),
        line: a.line,
        rule: "unused-allow",
        message: format!(
            "lint:allow({}) matches no violation on its target line — remove it",
            a.rule
        ),
    }
}

/// Cut a line at its `//` comment (line scanner: string literals that
/// contain `//` are not handled, which only under-matches).
fn strip_comment(line: &str) -> &str {
    match line.find("//") {
        Some(p) => &line[..p],
        None => line,
    }
}

/// Parse every `lint:allow(rule, reason)` on a raw line. Malformed
/// escapes (unknown rule, missing reason, unterminated) are reported as
/// `bad-allow` findings instead of silently suppressing anything.
fn parse_allows(
    rel: &str,
    rawline: &str,
    line_no: usize,
    findings: &mut Vec<Finding>,
) -> Vec<Allow> {
    const KEY: &str = "lint:allow(";
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(p) = rawline[from..].find(KEY) {
        let at = from + p + KEY.len();
        from = at;
        let bad = |message: String| Finding {
            file: rel.to_string(),
            line: line_no,
            rule: "bad-allow",
            message,
        };
        let Some(close) = rawline[at..].find(')') else {
            findings.push(bad("unterminated lint:allow escape".to_string()));
            break;
        };
        let inner = &rawline[at..at + close];
        let (rule_id, reason) = match inner.split_once(',') {
            Some((r, why)) => (r.trim(), why.trim()),
            None => (inner.trim(), ""),
        };
        let Some(rule) = RULES.iter().find(|r| r.id == rule_id) else {
            findings.push(bad(format!("unknown rule id `{rule_id}` in lint:allow")));
            continue;
        };
        if reason.is_empty() {
            findings.push(bad(format!(
                "lint:allow({rule_id}) needs a reason: lint:allow({rule_id}, why)"
            )));
            continue;
        }
        out.push(Allow { rule: rule.id, line: line_no, used: false });
    }
    out
}

/// Identifiers declared as `HashMap`/`HashSet` in this file: struct
/// fields and typed params (`name: [&[mut ]]HashMap<`), plus `let`
/// bindings (`let [mut] name = HashMap::..`).
fn collect_hash_names(code: &[String]) -> Vec<String> {
    let mut names: Vec<String> = Vec::new();
    let mut push = |names: &mut Vec<String>, n: String| {
        if !n.is_empty() && !names.iter().any(|x| x == &n) {
            names.push(n);
        }
    };
    for l in code {
        for ty in ["HashMap", "HashSet"] {
            let mut from = 0;
            while let Some(p) = l[from..].find(ty) {
                let at = from + p;
                from = at + ty.len();
                let before = &l[..at];
                let after = &l[at + ty.len()..];
                if after.starts_with('<') {
                    if let Some(n) = ident_before_colon(before) {
                        push(&mut names, n);
                    }
                }
                if before.trim_end().ends_with('=') {
                    if let Some(n) = let_binding_name(before) {
                        push(&mut names, n);
                    }
                }
            }
        }
    }
    names
}

/// `... name: [&[mut ]]` immediately before a `HashMap<`/`HashSet<`.
fn ident_before_colon(before: &str) -> Option<String> {
    let mut s = before.trim_end();
    loop {
        if let Some(r) = s.strip_suffix('&') {
            s = r.trim_end();
        } else if let Some(r) = s.strip_suffix("mut") {
            // Only the keyword, not an identifier ending in "mut".
            if r.ends_with(|c: char| c.is_ascii_alphanumeric() || c == '_') {
                return None;
            }
            s = r.trim_end();
        } else {
            break;
        }
    }
    let s = s.strip_suffix(':')?.trim_end();
    let name = trailing_ident(s);
    if name.is_empty() {
        None
    } else {
        Some(name)
    }
}

/// `let [mut] name` out of the text before an `=` that introduces a
/// `HashMap`/`HashSet` value.
fn let_binding_name(before: &str) -> Option<String> {
    let p = before.rfind("let ")?;
    let mut rest = before[p + 4..].trim_start();
    if let Some(r) = rest.strip_prefix("mut ") {
        rest = r.trim_start();
    }
    let end = rest
        .find(|c: char| !(c.is_ascii_alphanumeric() || c == '_'))
        .unwrap_or(rest.len());
    if end == 0 {
        None
    } else {
        Some(rest[..end].to_string())
    }
}

/// Trailing identifier of `s` (empty if `s` does not end in one).
fn trailing_ident(s: &str) -> String {
    let bytes = s.as_bytes();
    let mut j = s.len();
    while j > 0 && (bytes[j - 1].is_ascii_alphanumeric() || bytes[j - 1] == b'_') {
        j -= 1;
    }
    s[j..].to_string()
}

/// Receiver identifier of a method call at `code[li][col..]`, resolved
/// across rustfmt chain breaks: when nothing but whitespace precedes the
/// `.` on its line, the receiver is the trailing identifier of the
/// previous non-blank code line (`self\n.usage\n.iter()` -> `usage`).
fn receiver_ident(code: &[String], li: usize, col: usize) -> String {
    let mut li = li;
    let mut s: String = code[li][..col].to_string();
    loop {
        let t = s.trim_end();
        if t.is_empty() {
            if li == 0 {
                return String::new();
            }
            li -= 1;
            s = code[li].clone();
            continue;
        }
        return trailing_ident(t);
    }
}

/// Collect `hash-iter` candidates on line `i`: iteration methods whose
/// receiver is a declared hash name, and `for .. in [&]name {` loops.
fn hash_iter_candidates(
    code: &[String],
    i: usize,
    names: &[String],
    out: &mut Vec<(&'static str, String)>,
) {
    let l = &code[i];
    for m in ITER_METHODS {
        let mut from = 0;
        while let Some(p) = l[from..].find(m) {
            let at = from + p;
            from = at + m.len();
            let recv = receiver_ident(code, i, at);
            if names.iter().any(|n| n == &recv) {
                out.push((
                    "hash-iter",
                    format!(
                        "`{recv}{m}..` iterates a HashMap/HashSet in a decision-path \
                         module — fold the order away, sort the result, or \
                         lint:allow(hash-iter, reason)"
                    ),
                ));
            }
        }
    }
    let mut from = 0;
    while let Some(p) = l[from..].find(" in ") {
        let at = from + p;
        from = at + 4;
        if !l[..at].contains("for") {
            continue;
        }
        let mut rest = l[at + 4..].trim_start();
        while let Some(r) = rest.strip_prefix('&') {
            rest = r.trim_start();
        }
        if let Some(r) = rest.strip_prefix("mut ") {
            rest = r.trim_start();
        }
        if let Some(r) = rest.strip_prefix("self.") {
            rest = r;
        }
        let end = rest
            .find(|c: char| !(c.is_ascii_alphanumeric() || c == '_'))
            .unwrap_or(rest.len());
        let (name, tail) = rest.split_at(end);
        let tail = tail.trim_start();
        if (tail.is_empty() || tail.starts_with('{')) && names.iter().any(|n| n == name) {
            out.push((
                "hash-iter",
                format!(
                    "`for .. in {name}` iterates a HashMap/HashSet in a decision-path \
                     module — fold the order away, sort the result, or \
                     lint:allow(hash-iter, reason)"
                ),
            ));
        }
    }
}

/// Whether a candidate on line `i` is demonstrably order-folded: a
/// commutative fold or a canonicalizing sort on the candidate line or
/// within the next [`FOLD_WINDOW`] lines.
fn order_folded(code: &[String], i: usize) -> bool {
    code.iter()
        .skip(i)
        .take(FOLD_WINDOW + 1)
        .any(|l| FOLD_TOKENS.iter().any(|t| l.contains(t)))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.rule).collect()
    }

    // ---- hash-iter ----

    #[test]
    fn hash_iter_flags_declared_map_iteration_in_decision_module() {
        let src = "struct S { running: HashMap<u64, u32> }\n\
                   fn f(s: &S) -> Vec<u32> {\n\
                   \x20   s.running.values().cloned().collect()\n\
                   }\n";
        let f = scan_file("sched/x.rs", src);
        assert_eq!(rules_of(&f), vec!["hash-iter"]);
        assert_eq!(f[0].line, 3);
    }

    #[test]
    fn hash_iter_flags_for_in_loop() {
        let src = "struct S { claimed: HashMap<usize, usize> }\n\
                   fn f(s: &S) {\n\
                   \x20   for (k, v) in &s.claimed {\n\
                   \x20       drop((k, v));\n\
                   \x20   }\n\
                   }\n";
        // `&s.claimed` ends in ident `claimed` followed by ` {`.
        let f = scan_file("sim/x.rs", src);
        assert_eq!(rules_of(&f), vec!["hash-iter"]);
    }

    #[test]
    fn hash_iter_resolves_receiver_across_chain_breaks() {
        let src = "struct S { usage: HashMap<u32, u32> }\n\
                   fn f(s: &S) -> Vec<u32> {\n\
                   \x20   s.usage\n\
                   \x20       .iter()\n\
                   \x20       .map(|(_, v)| *v)\n\
                   \x20       .collect()\n\
                   }\n";
        let f = scan_file("sched/x.rs", src);
        assert_eq!(rules_of(&f), vec!["hash-iter"]);
        assert_eq!(f[0].line, 4);
    }

    #[test]
    fn hash_iter_ignores_non_hash_receivers_with_hashlike_names() {
        // A slice parameter named like a hash field elsewhere in the
        // repo must not flag: tracking is per-file.
        let src = "fn f(running: &[u32]) -> u32 {\n\
                   \x20   let mut t = 0;\n\
                   \x20   for r in running.iter() {\n\
                   \x20       t += *r;\n\
                   \x20   }\n\
                   \x20   t\n\
                   }\n";
        assert!(scan_file("sched/x.rs", src).is_empty());
    }

    #[test]
    fn hash_iter_ignores_non_decision_modules() {
        let src = "struct S { m: HashMap<u64, u32> }\n\
                   fn f(s: &S) -> Vec<u32> { s.m.values().cloned().collect() }\n";
        assert!(scan_file("util/x.rs", src).is_empty());
        assert!(scan_file("trace/x.rs", src).is_empty());
    }

    #[test]
    fn hash_iter_accepts_order_folded_sites() {
        let sum = "struct S { m: HashMap<u64, u32> }\n\
                   fn f(s: &S) -> u32 { s.m.values().sum() }\n";
        assert!(scan_file("sched/x.rs", sum).is_empty());
        let sorted = "struct S { m: HashMap<u64, u32> }\n\
                      fn f(s: &S) -> Vec<u32> {\n\
                      \x20   let mut v: Vec<u32> = s.m.values().cloned().collect();\n\
                      \x20   v.sort_unstable();\n\
                      \x20   v\n\
                      }\n";
        assert!(scan_file("sched/x.rs", sorted).is_empty());
    }

    #[test]
    fn hash_iter_flags_let_bound_maps() {
        let src = "fn f() -> Vec<u32> {\n\
                   \x20   let mut m = HashMap::new();\n\
                   \x20   m.insert(1u64, 2u32);\n\
                   \x20   m.values().cloned().collect()\n\
                   }\n";
        let f = scan_file("core/x.rs", src);
        assert_eq!(rules_of(&f), vec!["hash-iter"]);
        assert_eq!(f[0].line, 4);
    }

    // ---- lint:allow ----

    #[test]
    fn allow_on_preceding_comment_line_suppresses() {
        let src = "struct S { m: HashMap<u64, u32> }\n\
                   fn f(s: &S) -> Vec<u32> {\n\
                   \x20   // lint:allow(hash-iter, order folded downstream by caller)\n\
                   \x20   s.m.values().cloned().collect()\n\
                   }\n";
        assert!(scan_file("sched/x.rs", src).is_empty());
    }

    #[test]
    fn trailing_allow_suppresses() {
        let src = "fn f(a: f64, b: f64) -> std::cmp::Ordering {\n\
                   \x20   a.partial_cmp(&b).unwrap() // lint:allow(partial-cmp, fixture)\n\
                   }\n";
        assert!(scan_file("metrics/x.rs", src).is_empty());
    }

    #[test]
    fn unused_allow_is_a_finding() {
        let src = "// lint:allow(hash-iter, nothing here iterates)\n\
                   fn f() {}\n";
        let f = scan_file("sched/x.rs", src);
        assert_eq!(rules_of(&f), vec!["unused-allow"]);
    }

    #[test]
    fn bad_allow_unknown_rule_and_missing_reason() {
        let src = "// lint:allow(no-such-rule, why)\n\
                   // lint:allow(hash-iter)\n\
                   fn f() {}\n";
        let f = scan_file("sched/x.rs", src);
        assert_eq!(rules_of(&f), vec!["bad-allow", "bad-allow"]);
    }

    // ---- partial-cmp ----

    #[test]
    fn partial_cmp_call_sites_flag_everywhere() {
        let src = "fn f(mut v: Vec<f64>) {\n\
                   \x20   v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n\
                   }\n";
        assert_eq!(rules_of(&scan_file("metrics/x.rs", src)), vec!["partial-cmp"]);
        assert_eq!(rules_of(&scan_file("harness/x.rs", src)), vec!["partial-cmp"]);
    }

    #[test]
    fn partial_cmp_trait_impl_definition_is_not_a_call_site() {
        let src = "impl PartialOrd for K {\n\
                   \x20   fn partial_cmp(&self, other: &Self) -> Option<Ordering> {\n\
                   \x20       Some(self.cmp(other))\n\
                   \x20   }\n\
                   }\n";
        assert!(scan_file("core/x.rs", src).is_empty());
    }

    #[test]
    fn total_cmp_passes() {
        let src = "fn f(mut v: Vec<f64>) { v.sort_by(|a, b| a.total_cmp(b)); }\n";
        assert!(scan_file("sched/x.rs", src).is_empty());
    }

    // ---- wall-clock ----

    #[test]
    fn wall_clock_flags_decision_code_but_not_measurement_code() {
        let src = "fn f() { let t = std::time::Instant::now(); drop(t); }\n";
        assert_eq!(rules_of(&scan_file("sim/x.rs", src)), vec!["wall-clock"]);
        assert_eq!(rules_of(&scan_file("trace/x.rs", src)), vec!["wall-clock"]);
        assert!(scan_file("harness/x.rs", src).is_empty());
        assert!(scan_file("parallel/x.rs", src).is_empty());
        assert!(scan_file("util/bench.rs", src).is_empty());
        assert!(scan_file("main.rs", src).is_empty());
    }

    #[test]
    fn wall_clock_in_comments_is_ignored() {
        let src = "// Instant::now would be a hazard here\nfn f() {}\n";
        assert!(scan_file("sim/x.rs", src).is_empty());
    }

    // ---- ambient-rand ----

    #[test]
    fn ambient_randomness_flags_everywhere() {
        let src = "fn f() { let x = rand::random::<u64>(); drop(x); }\n";
        assert_eq!(rules_of(&scan_file("harness/x.rs", src)), vec!["ambient-rand"]);
        let src2 = "fn g() { let mut r = thread_rng(); drop(&mut r); }\n";
        assert_eq!(rules_of(&scan_file("util/x.rs", src2)), vec!["ambient-rand"]);
    }

    // ---- the repo itself ----

    #[test]
    fn repo_rule_ids_are_unique_and_documented() {
        for (i, r) in RULES.iter().enumerate() {
            assert!(!r.doc.is_empty());
            assert!(RULES.iter().skip(i + 1).all(|o| o.id != r.id), "dup id {}", r.id);
        }
    }
}
