//! sst-sched CLI — the launcher for the job-scheduling / workflow
//! simulator (see README.md for a tour).
//!
//! ```text
//! sst-sched run   [--workload das2|sdsc-sp2] [--trace f.swf|f.gwf|f.stf]
//!                 [--jobs N] [--policy P] [--accel native|xla]
//!                 [--ranks R] [--lookahead S] [--seed S]
//!                 [--fast-parse]              # zero-copy trace ingestion
//!                 [--config experiment.json]
//! sst-sched serve [--socket sst-sched.sock] [--max-sims N] # JSON-lines daemon
//! sst-sched check <experiment.json>           # static config validation
//! sst-sched convert <in.swf|in.gwf> <out.stf> # re-encode a trace as binary stf
//! sst-sched fig   3a|3b|4a|4b|5a|5b|6|7       # regenerate a paper figure
//! sst-sched workflow --spec wf.json | --gen sipht|montage|epigenomics|...
//! sst-sched trace-info --trace f.swf|--workload das2 [--jobs N]
//! sst-sched policies
//! ```

use anyhow::{bail, Context, Result};
use sst_sched::config::{ExperimentConfig, WorkloadSource};
use sst_sched::core::time::SimDuration;
use sst_sched::harness;
use sst_sched::runtime::Accel;
use sst_sched::sched::{Policy, PreemptionConfig, PreemptionMode};
use sst_sched::sim::Simulation;
use sst_sched::trace::synth::stats;
use sst_sched::util::cli::Args;
use sst_sched::util::table::{f, Table};
use sst_sched::workflow::generators as wfgen;
use sst_sched::workflow::{WorkflowExecutor, WorkflowSpec};

const USAGE: &str = "\
sst-sched — scalable HPC job scheduling & resource management simulator

USAGE:
  sst-sched run [--workload das2|sdsc-sp2] [--trace file.swf|file.gwf|file.stf]
                [--stream]  # constant-memory trace ingestion (--trace only)
                [--fast-parse]  # zero-copy byte-scanner ingestion (--trace only)
                [--jobs N] [--policy fcfs|sjf|ljf|fcfs-bestfit|fcfs-backfill|cons-backfill]
                [--order arrival|shortest|longest|fair-share]  # queue ordering
                [--half-life TICKS]  # fair-share usage-decay half-life
                [--mem MB] [--memory-aware]  # per-node memory + memory planning
                [--accel native|xla] [--ranks R] [--lookahead SECONDS]
                [--shards N]  # sharded multi-domain federation engine
                [--routing rr|ll|bf] [--route-latency S]  # federation knobs
                [--seed S] [--arrival-scale F] [--config experiment.json]
                [--mtbf S] [--mttr S] [--faults-seed S] [--faults-until T]
                [--faults-dist exp|weibull] [--faults-shape K]
                [--preemption none|kill|checkpoint] [--ckpt-overhead S]
                [--restart-overhead S] [--starvation S] [--priority-bands N]
                [--horizon TICKS|auto|exact]  # availability-planning horizon
  sst-sched serve [--socket PATH] [--max-sims N] [--queue-depth N]
                [--state-dir DIR]  # write-ahead journal -> crash-safe daemon
                [--resume DIR]     # recover sims by replaying DIR's journal
                [--durability strict|batched|off] [--mark-interval N]
                [--nodes N] [--cores C] [--policy P] [--seed S] ...
                # scheduler-as-a-service daemon: JSON-lines over a Unix
                # socket (submit | predict_wait | status | metrics |
                # shutdown — see docs/PROTOCOL.md); drains on SIGTERM;
                # persistence/recovery semantics in docs/OPERATIONS.md
  sst-sched faults [--workload ...] [--jobs N] [--mtbf S] [--mttr S] ...
                # policy x preemption-mode comparison on one failure trace
  sst-sched bench [--smoke] [--out BENCH_engine.json]
                # engine_throughput suite -> machine-readable perf JSON
  sst-sched check <experiment.json>
                # static config validation: reports EVERY semantic finding at
                # once (reservation overlap/size, fault sanity, federation,
                # trace path/format) without running anything
  sst-sched convert <in.swf|in.gwf|in.stf> <out.stf>
                # re-encode any readable trace as compact binary stf
  sst-sched fig <3a|3b|4a|4b|5a|5b|6|7> [--jobs N] [--seed S]
  sst-sched workflow (--spec wf.json | --gen sipht|montage|galactic|
                      epigenomics|cybershake|ligo) [--scale K] [--cpu C]
                     [--ranks R] [--seed S]
  sst-sched trace-info (--workload das2|sdsc-sp2 | --trace FILE) [--jobs N]
  sst-sched policies
  sst-sched help
";

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(raw) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(raw: Vec<String>) -> Result<()> {
    let args = Args::parse(raw)?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "run" => cmd_run(&args),
        "serve" => cmd_serve(&args),
        "check" => cmd_check(&args),
        "bench" => cmd_bench(&args),
        "convert" => cmd_convert(&args),
        "faults" => cmd_faults(&args),
        "fig" => cmd_fig(&args),
        "workflow" => cmd_workflow(&args),
        "trace-info" => cmd_trace_info(&args),
        "policies" => {
            let mut t = Table::new(&["policy", "description"]);
            t.row(&["fcfs".into(), "first-come first-served (blocking)".into()]);
            t.row(&["sjf".into(), "shortest estimated runtime first".into()]);
            t.row(&["ljf".into(), "longest estimated runtime first".into()]);
            t.row(&["fcfs-bestfit".into(), "FCFS order, tightest-node placement".into()]);
            t.row(&["fcfs-backfill".into(), "EASY backfilling (default)".into()]);
            t.row(&["cons-backfill".into(), "conservative backfilling (all-job reservations)".into()]);
            t.print();
            println!();
            let mut t = Table::new(&["order (--order)", "description"]);
            t.row(&["arrival".into(), "queue order (every policy's default except sjf/ljf)".into()]);
            t.row(&["shortest".into(), "ascending runtime estimate (sjf's default)".into()]);
            t.row(&["longest".into(), "descending runtime estimate (ljf's default)".into()]);
            t.row(&["fair-share".into(), "usage-decayed per-user share (--half-life)".into()]);
            t.print();
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

/// Build an ExperimentConfig from `--config` + CLI overrides.
fn config_from(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::load(path)?,
        None => ExperimentConfig::default(),
    };
    if let Some(tr) = args.get("trace") {
        // Case-insensitive extension routing — the same
        // `TraceFormat::from_path` rule every trace opener applies, so
        // `DAS2.GWF` no longer silently parses as SWF.
        cfg.source = match sst_sched::trace::TraceFormat::from_path(tr) {
            sst_sched::trace::TraceFormat::Gwf => WorkloadSource::Gwf(tr.to_string()),
            sst_sched::trace::TraceFormat::Stf => WorkloadSource::Stf(tr.to_string()),
            sst_sched::trace::TraceFormat::Swf => WorkloadSource::Swf(tr.to_string()),
        };
        cfg.jobs = 0; // whole trace unless --jobs
    } else if let Some(w) = args.get("workload") {
        cfg.source = match w {
            "das2" => WorkloadSource::Das2,
            "sdsc-sp2" | "sp2" => WorkloadSource::SdscSp2,
            other => bail!("unknown --workload {other:?} (das2|sdsc-sp2, or use --trace)"),
        };
    }
    cfg.jobs = args.usize_or("jobs", cfg.jobs)?;
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    cfg.arrival_scale = args.f64_or("arrival-scale", cfg.arrival_scale)?;
    if let Some(p) = args.get("policy") {
        cfg.policy = p.parse().map_err(|e: String| anyhow::anyhow!(e))?;
    }
    cfg.accel = args.str_or("accel", &cfg.accel);
    cfg.ranks = args.usize_or("ranks", cfg.ranks)?;
    cfg.lookahead = args.u64_or("lookahead", cfg.lookahead)?;
    // Sharded federation engine (`--shards 0` = off).
    cfg.shards = args.usize_or("shards", cfg.shards)?;
    if let Some(r) = args.get("routing") {
        cfg.routing = r.parse().map_err(|e: String| anyhow::anyhow!(e))?;
    }
    cfg.route_latency = args.u64_or("route-latency", cfg.route_latency)?;
    if cfg.route_latency == 0 {
        bail!("--route-latency must be >= 1 (it is the conservative lookahead)");
    }
    if let Some(n) = args.get("nodes") {
        cfg.nodes = Some(n.parse().context("--nodes expects an integer")?);
    }
    if let Some(c) = args.get("cores") {
        cfg.cores_per_node = Some(c.parse().context("--cores expects an integer")?);
    }
    cfg.mem_per_node = args.u64_or("mem", cfg.mem_per_node)?;
    // Queue-ordering seam: ordering composes with every policy.
    if let Some(o) = args.get("order") {
        cfg.order = Some(o.parse().map_err(|e: String| anyhow::anyhow!(e))?);
    }
    cfg.fairshare_half_life = args.u64_or("half-life", cfg.fairshare_half_life)?;
    if cfg.fairshare_half_life == 0 {
        bail!("--half-life must be > 0");
    }
    if args.flag("memory-aware") {
        cfg.memory_aware = true;
    }
    if args.flag("fast-parse") {
        cfg.fast_parse = true;
    }
    // Fault/preemption knobs (fault subsystem).
    cfg.faults.mtbf = args.f64_or("mtbf", cfg.faults.mtbf)?;
    cfg.faults.mttr = args.f64_or("mttr", cfg.faults.mttr)?;
    cfg.faults.seed = args.u64_or("faults-seed", cfg.faults.seed)?;
    if let Some(u) = args.get("faults-until") {
        cfg.faults.until = Some(u.parse().context("--faults-until expects an integer")?);
    }
    if let Some(d) = args.get("faults-dist") {
        cfg.faults.distribution = d.parse().map_err(|e: String| anyhow::anyhow!(e))?;
    }
    cfg.faults.shape = args.f64_or("faults-shape", cfg.faults.shape)?;
    if cfg.faults.shape < 0.1 {
        bail!("--faults-shape must be >= 0.1 (tiny shapes collapse the gap scale)");
    }
    if let Some(h) = args.get("horizon") {
        cfg.planning_horizon = h.parse().map_err(|e: String| anyhow::anyhow!(e))?;
    }
    if let Some(m) = args.get("preemption") {
        cfg.preemption.mode = m.parse().map_err(|e: String| anyhow::anyhow!(e))?;
    }
    cfg.preemption.checkpoint_overhead =
        SimDuration(args.u64_or("ckpt-overhead", cfg.preemption.checkpoint_overhead.ticks())?);
    cfg.preemption.restart_overhead =
        SimDuration(args.u64_or("restart-overhead", cfg.preemption.restart_overhead.ticks())?);
    cfg.preemption.starvation_threshold =
        SimDuration(args.u64_or("starvation", cfg.preemption.starvation_threshold.ticks())?);
    cfg.priority_bands = args.u64_or("priority-bands", cfg.priority_bands as u64)? as u8;
    Ok(cfg)
}

/// Scheduler-as-a-service daemon (`sst-sched serve`): host named,
/// resumable simulations behind a JSON-lines Unix socket. Shares the
/// full `--config`/CLI knob surface with `run`, plus the daemon knobs
/// (`serve.*` config section / `--socket`, `--max-sims`,
/// `--queue-depth`). Runs until a `shutdown` request or SIGTERM/SIGINT,
/// then drains gracefully.
fn cmd_serve(args: &Args) -> Result<()> {
    let mut cfg = config_from(args)?;
    if let Some(s) = args.get("socket") {
        cfg.serve.socket = s.to_string();
    }
    cfg.serve.max_sims = args.usize_or("max-sims", cfg.serve.max_sims)?;
    cfg.serve.queue_depth = args.usize_or("queue-depth", cfg.serve.queue_depth)?;
    // Persistence knobs: `--state-dir DIR` starts a fresh journal,
    // `--resume DIR` replays an existing one (both set serve.state_dir;
    // resume flips the recovery path).
    if let Some(d) = args.get("state-dir") {
        cfg.serve.state_dir = Some(d.to_string());
    }
    let resume = args.get("resume").map(|d| d.to_string());
    if let Some(d) = &resume {
        if cfg.serve.state_dir.as_deref().is_some_and(|s| s != d) {
            bail!("--state-dir and --resume point at different directories; pass one");
        }
        cfg.serve.state_dir = Some(d.clone());
    }
    if let Some(dur) = args.get("durability") {
        cfg.serve.durability = dur.parse().map_err(|e: String| anyhow::anyhow!(e))?;
    }
    cfg.serve.mark_interval = args.u64_or("mark-interval", cfg.serve.mark_interval)?;
    args.reject_unknown()?;
    if cfg.serve.max_sims == 0 {
        bail!("--max-sims must be >= 1");
    }
    if cfg.serve.queue_depth == 0 {
        bail!("--queue-depth must be >= 1");
    }
    #[cfg(unix)]
    {
        sst_sched::runtime::serve::serve_opts(cfg, resume.is_some())
    }
    #[cfg(not(unix))]
    {
        let _ = resume;
        bail!("serve needs Unix domain sockets, unavailable on this platform")
    }
}

/// Static config validation (`sst-sched check <config.json>`): parse the
/// experiment file and report every semantic problem in one pass — no
/// simulation runs. Prints "ok" and exits 0 when clean; lists every
/// finding and exits nonzero otherwise (never fail-fast, so one check
/// run fixes one config).
fn cmd_check(args: &Args) -> Result<()> {
    let path = args
        .positional
        .get(1)
        .cloned()
        .context("usage: sst-sched check <experiment.json>")?;
    args.reject_unknown()?;
    let text =
        std::fs::read_to_string(&path).with_context(|| format!("reading config {path:?}"))?;
    let findings = ExperimentConfig::check(&text)?;
    if findings.is_empty() {
        println!("{path}: ok");
        return Ok(());
    }
    for m in &findings {
        eprintln!("{path}: {m}");
    }
    bail!("{} finding(s) in {path}", findings.len());
}

/// Run the engine_throughput suite and write machine-readable results —
/// the `BENCH_engine.json` file the perf trajectory and the CI perf gate
/// consume.
fn cmd_bench(args: &Args) -> Result<()> {
    let smoke = args.flag("smoke");
    let out = args.str_or("out", "BENCH_engine.json");
    args.reject_unknown()?;
    let b = harness::bench_suite::engine_throughput_suite(smoke);
    let json = b.to_json("engine_throughput", smoke);
    std::fs::write(&out, json.to_pretty()).with_context(|| format!("writing {out:?}"))?;
    println!("\nwrote {} ({} cases)", out, b.results().len());
    Ok(())
}

/// Re-encode any readable trace (SWF/GWF text, or stf itself) as the
/// compact binary stf format — the cheapest format to replay (fixed
/// 32-byte records, no text parsing; see `trace::stf`). Conversion
/// streams through the byte scanner and checks the submit-sorted
/// invariant on every record, so a written stf file is replayable by
/// construction.
fn cmd_convert(args: &Args) -> Result<()> {
    let usage = "usage: sst-sched convert <in.swf|in.gwf|in.stf> <out.stf>";
    let input = args.positional.get(1).cloned().context(usage)?;
    let output = args.positional.get(2).cloned().context(usage)?;
    args.reject_unknown()?;
    if sst_sched::trace::TraceFormat::from_path(&output) != sst_sched::trace::TraceFormat::Stf {
        bail!("convert writes stf; the output must end in .stf (got {output:?})");
    }
    let t0 = std::time::Instant::now();
    let st = sst_sched::trace::stf::convert_trace_file(&input, &output)?;
    println!(
        "wrote {}: {} records, {} bytes, machine {} nodes x {} cores ({:.1} ms)",
        output,
        st.records,
        st.bytes,
        st.machine.0,
        st.machine.1,
        t0.elapsed().as_secs_f64() * 1e3
    );
    Ok(())
}

/// Apply every config knob shared by the eager and streamed run paths —
/// one chain, so a future knob cannot silently apply to only one of
/// them.
fn configure_sim(sim: Simulation, cfg: &ExperimentConfig) -> Simulation {
    let mut sim = sim
        .with_seed(cfg.seed)
        .with_faults(cfg.faults)
        .with_preemption(cfg.preemption)
        .with_reservations(cfg.reservations.clone())
        .with_horizon(cfg.planning_horizon)
        .with_auto_horizon_params(cfg.auto_horizon)
        .with_mem_per_node(cfg.mem_per_node)
        .with_memory_aware(cfg.memory_aware)
        .with_fairshare_half_life(cfg.fairshare_half_life);
    if let Some(order) = cfg.order {
        sim = sim.with_order(order);
    }
    sim
}

/// Constant-memory run: the trace is parsed one record at a time and fed
/// to the simulator as simulated time reaches each arrival — peak RSS is
/// O(active jobs), not O(trace). Per-job lifecycle records are dropped
/// (scalar aggregates survive), which is what makes million-job traces
/// practical.
fn cmd_run_streamed(cfg: &ExperimentConfig) -> Result<()> {
    let path = match &cfg.source {
        WorkloadSource::Swf(p) | WorkloadSource::Gwf(p) | WorkloadSource::Stf(p) => p.clone(),
        _ => bail!("--stream needs --trace FILE (streaming reads a trace incrementally)"),
    };
    if cfg.ranks > 1 {
        bail!("--stream is single-rank (partitioning needs the whole trace up front)");
    }
    if (cfg.arrival_scale - 1.0).abs() > 1e-12 {
        bail!("--arrival-scale needs the eager path (it rewrites every submit time)");
    }
    if cfg.faults.enabled() && cfg.faults.until.is_none() {
        // The eager path derives the injector horizon from the full job
        // list; a stream cannot, so the builder watches the stream's
        // last-seen submission AND the scheduler's last-activity time:
        // the injector stops 4 x mttr past whichever is later. Arrival
        // droughts with queued or running work therefore keep the fault
        // chain alive; only a fully idle machine with an exhausted-
        // looking stream winds it down.
        eprintln!(
            "note: streamed fault run without --faults-until — deriving the injector \
             horizon from max(stream's last-seen submission, last engine activity) \
             + 4 x mttr slack"
        );
    }
    // One opener for every format: `.stf` and `--fast-parse` take the
    // byte scanner, plain text takes the scalar line stream; either way
    // an stf trace's machine comes from its header, text formats from
    // the format default.
    let (raw_stream, (def_nodes, def_cores)) =
        sst_sched::trace::open_trace_stream_with_machine(&path, cfg.fast_parse)?;
    let nodes = cfg.nodes.unwrap_or(def_nodes);
    let cores = cfg.cores_per_node.unwrap_or(def_cores);
    let take = if cfg.jobs > 0 { cfg.jobs } else { usize::MAX };
    // A mid-stream parse error cannot abort the running simulation, so
    // it ends the stream and is re-raised after the run — a corrupt
    // trace must fail the command, not exit 0 with partial results. The
    // stored message carries the offending line number and byte offset
    // (the stream wraps its parse errors with both).
    let ingest_error = std::sync::Arc::new(std::sync::Mutex::new(None::<String>));
    let ingest_flag = ingest_error.clone();
    // Same derived priority bands the eager path applies in
    // build_workload — `--priority-bands` must not be silently ignored.
    let bands = cfg.priority_bands;
    let stream = raw_stream
        .map_while(move |r| match r {
            Ok(job) => Some(job),
            Err(e) => {
                *ingest_flag.lock().unwrap() = Some(format!("{e:#}"));
                None
            }
        })
        .map(move |mut job| {
            if bands > 0 {
                job.priority = (job.user % bands as u32) as u8;
            }
            job
        })
        .take(take);
    print!("workload {path}: streamed onto {nodes} nodes x {cores} cores");
    if cfg.jobs > 0 {
        print!(" (first {} jobs)", cfg.jobs);
    }
    println!();
    let accel: Accel = cfg.accel.parse().map_err(|e: String| anyhow::anyhow!(e))?;
    let mut sim = configure_sim(
        Simulation::new(sst_sched::trace::Workload::machine(&path, nodes, cores), cfg.policy),
        cfg,
    )
    .with_job_stream(Box::new(stream))
    .with_retain_completed(false);
    if cfg.policy == Policy::FcfsBackfill {
        // Same scorer-backend plumbing as the eager path — `--accel`
        // must not be silently ignored here.
        let sched = sst_sched::runtime::backfill_with_accel(accel)?;
        println!("scorer backend    {}", sched.scorer_backend());
        sim = sim.with_scheduler(Box::new(sched));
    }
    let t0 = std::time::Instant::now();
    let rep = sim.run(None);
    let wall = t0.elapsed();
    if let Some(e) = ingest_error.lock().unwrap().take() {
        bail!(
            "trace ingestion failed after {} completed jobs: {e}",
            rep.completed_count
        );
    }
    println!("policy            {}", rep.policy);
    println!("jobs completed    {}", rep.completed_count);
    println!("jobs rejected     {}", rep.rejected);
    println!("DES events        {}", rep.events);
    println!("dispatch rounds   {}", rep.dispatches);
    println!("sim end time      {} s", rep.end_time.ticks());
    println!("mean wait         {:.1} s", rep.mean_wait_overall());
    println!("mean utilization  {:.3}", rep.mean_utilization);
    println!("wall time         {:.1} ms", wall.as_secs_f64() * 1e3);
    println!("event rate        {:.0} ev/s", rep.events as f64 / wall.as_secs_f64().max(1e-9));
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let stream = args.flag("stream");
    args.reject_unknown()?;
    if stream {
        return cmd_run_streamed(&cfg);
    }
    let workload = cfg.build_workload()?;
    println!(
        "workload {}: {} jobs on {} nodes x {} cores (offered load {:.2})",
        workload.name,
        workload.jobs.len(),
        workload.nodes,
        workload.cores_per_node,
        workload.offered_load()
    );
    if cfg.shards > 0 {
        if cfg.ranks > 1 {
            bail!("--shards and --ranks are different engines; pick one");
        }
        return run_sharded_cli(&cfg, &workload);
    }
    if cfg.ranks > 1 {
        let opts = sst_sched::parallel::RankSimOpts {
            seed: cfg.seed,
            faults: cfg.faults,
            preemption: cfg.preemption,
            reservations: cfg.reservations.clone(),
            planning_horizon: cfg.planning_horizon,
            auto_horizon: cfg.auto_horizon,
            order: cfg.order,
            fairshare_half_life: cfg.fairshare_half_life,
            mem_per_node: cfg.mem_per_node,
            memory_aware: cfg.memory_aware,
        };
        let rep = sst_sched::parallel::run_jobs_parallel_opts(
            &workload,
            cfg.policy,
            cfg.ranks,
            cfg.lookahead,
            &opts,
            true,
        );
        println!("ranks             {}", rep.ranks);
        println!("windows           {}", rep.windows);
        println!("wall time         {:.1} ms", rep.wall.as_secs_f64() * 1e3);
        println!("events            {}", rep.total_events());
        println!("event rate        {:.0} ev/s", rep.event_rate());
        println!("jobs completed    {}", rep.total_completed());
        println!("mean wait         {:.1} s", rep.mean_wait());
        return Ok(());
    }
    let accel: Accel = cfg.accel.parse().map_err(|e: String| anyhow::anyhow!(e))?;
    let mut sim = configure_sim(Simulation::new(workload, cfg.policy), &cfg);
    if cfg.policy == Policy::FcfsBackfill {
        let sched = sst_sched::runtime::backfill_with_accel(accel)?;
        println!("scorer backend    {}", sched.scorer_backend());
        sim = sim.with_scheduler(Box::new(sched));
    }
    let t0 = std::time::Instant::now();
    let rep = sim.run(None);
    let wall = t0.elapsed();
    harness::print_run_report(&rep);
    println!("wall time         {:.1} ms", wall.as_secs_f64() * 1e3);
    println!("event rate        {:.0} ev/s", rep.events as f64 / wall.as_secs_f64().max(1e-9));
    Ok(())
}

/// Sharded multi-domain federation run (`--shards N`): the workload's
/// jobs are routed across the DAS-2 federation, each cluster an
/// autonomous scheduler domain on the conservative sharded engine. The
/// decision fingerprint is byte-identical for every shard count; this
/// command asserts it against a serial (1-shard, single-threaded)
/// reference run.
fn run_sharded_cli(cfg: &ExperimentConfig, workload: &sst_sched::trace::Workload) -> Result<()> {
    use sst_sched::parallel::{run_sharded, RankSimOpts, ShardOpts};
    use sst_sched::sim::MetaScheduler;
    let clusters = MetaScheduler::das2_federation(cfg.routing, cfg.policy).clusters;
    let opts = ShardOpts {
        clusters,
        routing: cfg.routing,
        policy: cfg.policy,
        shards: cfg.shards,
        route_latency: cfg.route_latency,
        sim: RankSimOpts {
            seed: cfg.seed,
            faults: cfg.faults,
            preemption: cfg.preemption,
            reservations: cfg.reservations.clone(),
            planning_horizon: cfg.planning_horizon,
            auto_horizon: cfg.auto_horizon,
            order: cfg.order,
            fairshare_half_life: cfg.fairshare_half_life,
            mem_per_node: cfg.mem_per_node,
            memory_aware: cfg.memory_aware,
        },
    };
    let rep = run_sharded(&opts, workload.jobs.clone(), true);
    let serial = run_sharded(&ShardOpts { shards: 1, ..opts.clone() }, workload.jobs.clone(), false);
    println!("shards            {}", rep.shards);
    println!("domains           {}", rep.domains.len());
    println!("routing           {}", rep.routing.as_str());
    println!("route latency     {} s (= lookahead)", rep.route_latency);
    println!("windows           {}", rep.windows);
    println!("wall time         {:.1} ms", rep.wall.as_secs_f64() * 1e3);
    println!("events            {}", rep.total_events());
    println!("event rate        {:.0} ev/s", rep.event_rate());
    println!("jobs routed       {}", rep.routed);
    println!("jobs rejected     {}", rep.rejected);
    println!("jobs completed    {}", rep.total_completed());
    println!("mean wait         {:.1} s", rep.mean_wait());
    println!("decision fp       {:016x}", rep.fingerprint());
    let matches = rep.fingerprint() == serial.fingerprint();
    println!(
        "serial fp         {:016x} ({})",
        serial.fingerprint(),
        if matches { "match" } else { "MISMATCH" }
    );
    if !matches {
        bail!("sharded decisions diverged from the serial engine");
    }
    Ok(())
}

/// Compare scheduling policies with and without preemption under one
/// seeded failure trace (fault/preemption subsystem).
fn cmd_faults(args: &Args) -> Result<()> {
    let mut cfg = config_from(args)?;
    args.reject_unknown()?;
    if !cfg.faults.enabled() {
        // A faults comparison without faults is vacuous; give the demo
        // sensible defaults (mean one failure per ~8 simulated hours).
        cfg.faults.mtbf = 28_800.0;
        cfg.faults.mttr = 3_600.0;
    }
    let workload = cfg.build_workload()?;
    println!(
        "workload {}: {} jobs on {} nodes x {} cores; faults mtbf={:.0}s mttr={:.0}s seed={}\n",
        workload.name,
        workload.jobs.len(),
        workload.nodes,
        workload.cores_per_node,
        cfg.faults.mtbf,
        cfg.faults.mttr,
        cfg.faults.seed,
    );
    let ckpt = if cfg.preemption.enabled() {
        cfg.preemption
    } else {
        PreemptionConfig {
            mode: PreemptionMode::Checkpoint,
            checkpoint_overhead: SimDuration(60),
            restart_overhead: SimDuration(30),
            starvation_threshold: SimDuration::ZERO,
        }
    };
    let mut cases = vec![
        (Policy::Fcfs, PreemptionConfig::default()),
        (Policy::Fcfs, ckpt),
        (Policy::FcfsBackfill, PreemptionConfig::default()),
        (Policy::FcfsBackfill, ckpt),
    ];
    // An explicitly requested policy joins the comparison rather than
    // being silently ignored.
    if !matches!(cfg.policy, Policy::Fcfs | Policy::FcfsBackfill) {
        cases.push((cfg.policy, PreemptionConfig::default()));
        cases.push((cfg.policy, ckpt));
    }
    let rows = harness::fault_comparison(
        &workload,
        &harness::FaultCompareOpts {
            faults: cfg.faults,
            reservations: &cfg.reservations,
            planning_horizon: cfg.planning_horizon,
            auto_horizon: cfg.auto_horizon,
            order: cfg.order,
            fairshare_half_life: cfg.fairshare_half_life,
            mem_per_node: cfg.mem_per_node,
            memory_aware: cfg.memory_aware,
        },
        &cases,
    );
    harness::print_fault_rows(&rows);
    Ok(())
}

fn cmd_fig(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .context("usage: sst-sched fig <3a|3b|4a|4b|5a|5b|6|7>")?;
    let jobs = args.usize_or("jobs", 0)?;
    let seed = args.u64_or("seed", 1)?;
    args.reject_unknown()?;
    let nz = |d: usize| if jobs == 0 { d } else { jobs };
    match which {
        "3a" => {
            println!("Fig 3(a): node occupancy over time — ours vs CQsim-like\n");
            harness::print_validation(&harness::fig3a(nz(10_000), seed, 24));
        }
        "3b" => {
            println!("Fig 3(b): running jobs over time — ours vs CQsim-like\n");
            harness::print_validation(&harness::fig3b(nz(10_000), seed, 24));
        }
        "4a" => {
            println!("Fig 4(a): wait-time validation — ours vs CQsim-like\n");
            harness::print_fig4a(&harness::fig4a(nz(10_000), seed, 20));
        }
        "4b" => {
            println!("Fig 4(b): scheduling-algorithm comparison (DAS-2-like, high load)\n");
            harness::print_fig4b(&harness::fig4b(nz(8_000), seed));
        }
        "5a" => {
            println!("Fig 5(a): parallel scaling, DAS-2-like\n");
            let scales = if jobs == 0 { vec![20_000, 50_000, 100_000] } else { vec![jobs] };
            harness::print_fig5(&harness::fig5(false, &scales, &[1, 2, 4, 8], seed));
        }
        "5b" => {
            println!("Fig 5(b): parallel scaling, SDSC-SP2-like\n");
            let scales = if jobs == 0 { vec![50_000] } else { vec![jobs] };
            harness::print_fig5(&harness::fig5(true, &scales, &[1, 2, 4, 8], seed));
        }
        "6" => {
            println!("Fig 6: workflow-simulation scaling (Galactic Plane)\n");
            harness::print_fig5(&harness::fig6(17, &[1, 2, 4, 8], seed));
        }
        "7" => {
            println!("Fig 7: SIPHT workflow wait-time validation\n");
            harness::print_fig7(&harness::fig7(4, 8, seed));
        }
        other => bail!("unknown figure {other:?} (3a|3b|4a|4b|5a|5b|6|7)"),
    }
    Ok(())
}

fn cmd_workflow(args: &Args) -> Result<()> {
    let seed = args.u64_or("seed", 1)?;
    let scale = args.usize_or("scale", 0)?;
    let cpu = args.u64_or("cpu", 16)?;
    let ranks = args.usize_or("ranks", 1)?;
    let workflow = if let Some(path) = args.get("spec") {
        let spec = WorkflowSpec::load(path)?;
        println!(
            "loaded {:?}: {} tasks, pool cpu={} mem={} MB, policy {}",
            path,
            spec.workflow.len(),
            spec.cpu_available,
            spec.memory_available_mb,
            spec.scheduling_policy
        );
        spec.workflow
    } else {
        let gen = args.str_or("gen", "");
        let nz = |d: usize| if scale == 0 { d } else { scale };
        match gen.as_str() {
            "sipht" => wfgen::sipht(nz(1), seed, false),
            "montage" => wfgen::montage(nz(20), seed, false),
            "galactic" | "galactic-plane" => wfgen::galactic_plane(nz(17), seed, false),
            "epigenomics" => wfgen::epigenomics(nz(4), 4, seed, false),
            "cybershake" => wfgen::cybershake(nz(10), seed, false),
            "ligo" => wfgen::ligo_inspiral(nz(10), seed, false),
            "" => bail!("workflow needs --spec FILE or --gen NAME"),
            other => bail!("unknown generator {other:?}"),
        }
    };
    args.reject_unknown()?;
    println!(
        "workflow {}: {} tasks, {} edges, depth {}, critical path {:.0} s, total work {:.0} s",
        workflow.name,
        workflow.len(),
        workflow.dag.num_edges(),
        workflow.dag.depth().unwrap(),
        workflow.critical_path_time(),
        workflow.total_work()
    );
    if ranks > 1 {
        let rep = sst_sched::parallel::run_workflow_parallel(&workflow, ranks, cpu, 5);
        println!("ranks        {}", rep.ranks);
        println!("windows      {}", rep.windows);
        println!("wall time    {:.1} ms", rep.wall.as_secs_f64() * 1e3);
        println!("tasks done   {}", rep.total_completed());
        println!("makespan     {} s", rep.end_time());
        println!("mean wait    {:.1} s", rep.mean_wait());
    } else {
        let rep = WorkflowExecutor::new(cpu, u64::MAX).run(workflow);
        println!("makespan     {} s", rep.makespan.ticks());
        println!("peak cpu     {}", rep.peak_cpu);
        println!("mean wait    {:.1} s", rep.mean_wait());
        println!("max wait     {:.1} s", rep.max_wait());
    }
    Ok(())
}

fn cmd_trace_info(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    args.reject_unknown()?;
    let w = cfg.build_workload()?;
    let s = stats(&w.jobs);
    let mut t = Table::new(&["metric", "value"]);
    t.row(&["workload".into(), w.name.clone()]);
    t.row(&["jobs".into(), s.jobs.to_string()]);
    t.row(&["machine".into(), format!("{} nodes x {} cores", w.nodes, w.cores_per_node)]);
    t.row(&["mean cores/job".into(), f(s.mean_cores)]);
    t.row(&["median runtime (s)".into(), f(s.median_runtime)]);
    t.row(&["mean runtime (s)".into(), f(s.mean_runtime)]);
    t.row(&["mean interarrival (s)".into(), f(s.mean_interarrival)]);
    t.row(&["power-of-two sizes".into(), format!("{:.0}%", s.pow2_fraction * 100.0)]);
    t.row(&["offered load".into(), f(w.offered_load())]);
    t.print();
    Ok(())
}
