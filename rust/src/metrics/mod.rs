//! Scheduling metrics and series comparison.
//!
//! Everything the paper's figures report: wait-time summaries (Fig 4),
//! node-occupancy and running-job time series (Fig 3), utilization, plus
//! the comparison statistics (MAE / RMSE / correlation) used to quantify
//! "our simulator closely matches CQsim".

use crate::core::stats::TimeSeries;
use crate::core::time::SimTime;
use crate::job::Job;
use crate::sched::UserShare;

/// Summary of a fair-share usage snapshot (`SimReport::user_shares`).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct ShareStats {
    pub users: usize,
    /// Largest decayed usage across users (core-seconds).
    pub max_usage: f64,
    /// Sum of decayed usage across users.
    pub total_usage: f64,
    /// max / mean usage — 1.0 is perfectly even, large values mean one
    /// user dominates the decayed-usage ledger.
    pub imbalance: f64,
}

/// Summarize a per-user share snapshot.
pub fn share_stats(shares: &[UserShare]) -> ShareStats {
    if shares.is_empty() {
        return ShareStats::default();
    }
    let total: f64 = shares.iter().map(|s| s.usage).sum();
    let max = shares.iter().map(|s| s.usage).fold(0.0, f64::max);
    let mean = total / shares.len() as f64;
    ShareStats {
        users: shares.len(),
        max_usage: max,
        total_usage: total,
        imbalance: if mean > 0.0 { max / mean } else { 1.0 },
    }
}

/// Wait/turnaround summary over completed jobs.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct WaitStats {
    pub jobs: usize,
    pub mean_wait: f64,
    pub median_wait: f64,
    pub p95_wait: f64,
    pub max_wait: f64,
    pub mean_turnaround: f64,
    /// Mean bounded slowdown (tau = 10 s).
    pub mean_slowdown: f64,
}

/// Summarize completed jobs (jobs without a start/end are skipped).
pub fn wait_stats(jobs: &[Job]) -> WaitStats {
    let mut waits: Vec<f64> = Vec::with_capacity(jobs.len());
    let mut turn = 0.0;
    let mut slow = 0.0;
    for j in jobs {
        let (Some(w), Some(t), Some(s)) =
            (j.wait_time(), j.turnaround(), j.bounded_slowdown(10.0))
        else {
            continue;
        };
        waits.push(w.as_f64());
        turn += t.as_f64();
        slow += s;
    }
    if waits.is_empty() {
        return WaitStats::default();
    }
    waits.sort_by(|a, b| a.total_cmp(b));
    let n = waits.len();
    WaitStats {
        jobs: n,
        mean_wait: waits.iter().sum::<f64>() / n as f64,
        median_wait: waits[n / 2],
        p95_wait: waits[((n - 1) as f64 * 0.95).round() as usize],
        max_wait: waits[n - 1],
        mean_turnaround: turn / n as f64,
        mean_slowdown: slow / n as f64,
    }
}

/// Resample a step-function time series onto a uniform grid of `points`
/// samples spanning [t0, t1] (sample-and-hold).
pub fn resample(series: &TimeSeries, t0: SimTime, t1: SimTime, points: usize) -> Vec<f64> {
    let pts = series.points();
    let mut out = Vec::with_capacity(points);
    if pts.is_empty() || points == 0 || t1 <= t0 {
        out.resize(points, 0.0);
        return out;
    }
    let span = (t1 - t0).as_f64();
    let mut idx = 0usize;
    let mut current = 0.0;
    for k in 0..points {
        let t = t0.ticks() as f64 + span * k as f64 / (points - 1).max(1) as f64;
        while idx < pts.len() && (pts[idx].0.ticks() as f64) <= t {
            current = pts[idx].1;
            idx += 1;
        }
        out.push(current);
    }
    out
}

/// Mean absolute error between equal-length series.
pub fn mae(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum::<f64>() / a.len() as f64
}

/// Root-mean-square error between equal-length series.
pub fn rmse(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    (a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum::<f64>() / a.len() as f64).sqrt()
}

/// Pearson correlation; 0.0 when either side is constant.
pub fn correlation(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len() as f64;
    if a.is_empty() {
        return 0.0;
    }
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for (x, y) in a.iter().zip(b) {
        cov += (x - ma) * (y - mb);
        va += (x - ma).powi(2);
        vb += (y - mb).powi(2);
    }
    if va == 0.0 || vb == 0.0 {
        return 0.0;
    }
    cov / (va.sqrt() * vb.sqrt())
}

/// Normalized MAE: MAE / mean(|reference|); 0 when the reference is flat 0.
pub fn nmae(ours: &[f64], reference: &[f64]) -> f64 {
    let m = reference.iter().map(|x| x.abs()).sum::<f64>() / reference.len().max(1) as f64;
    if m == 0.0 {
        0.0
    } else {
        mae(ours, reference) / m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::time::SimTime;

    fn done_job(id: u64, submit: u64, start: u64, runtime: u64) -> Job {
        let mut j = Job::simple(id, submit, 4, runtime);
        j.state = crate::job::JobState::Queued;
        j.mark_started(SimTime(start));
        j.mark_completed(SimTime(start + runtime));
        j
    }

    #[test]
    fn wait_stats_basic() {
        let jobs = vec![done_job(1, 0, 10, 100), done_job(2, 0, 30, 100)];
        let s = wait_stats(&jobs);
        assert_eq!(s.jobs, 2);
        assert_eq!(s.mean_wait, 20.0);
        assert_eq!(s.max_wait, 30.0);
        assert_eq!(s.mean_turnaround, (110.0 + 130.0) / 2.0);
        assert!(s.mean_slowdown >= 1.0);
    }

    #[test]
    fn wait_stats_skips_incomplete() {
        let mut pending = Job::simple(3, 0, 1, 10);
        pending.state = crate::job::JobState::Queued;
        let jobs = vec![done_job(1, 0, 5, 10), pending];
        assert_eq!(wait_stats(&jobs).jobs, 1);
    }

    #[test]
    fn wait_stats_empty() {
        assert_eq!(wait_stats(&[]).jobs, 0);
    }

    #[test]
    fn percentiles_ordered() {
        let jobs: Vec<Job> =
            (0..100).map(|i| done_job(i, 0, i * 10, 50)).collect();
        let s = wait_stats(&jobs);
        assert!(s.median_wait <= s.p95_wait);
        assert!(s.p95_wait <= s.max_wait);
        assert_eq!(s.max_wait, 990.0);
    }

    #[test]
    fn resample_holds_steps() {
        let mut s = TimeSeries::new();
        s.record(SimTime(0), 1.0);
        s.record(SimTime(50), 2.0);
        let r = resample(&s, SimTime(0), SimTime(100), 5);
        assert_eq!(r, vec![1.0, 1.0, 2.0, 2.0, 2.0]);
    }

    #[test]
    fn resample_before_first_point_is_zero() {
        let mut s = TimeSeries::new();
        s.record(SimTime(80), 5.0);
        let r = resample(&s, SimTime(0), SimTime(100), 5);
        assert_eq!(r, vec![0.0, 0.0, 0.0, 0.0, 5.0]);
    }

    #[test]
    fn resample_empty_series() {
        let s = TimeSeries::new();
        assert_eq!(resample(&s, SimTime(0), SimTime(10), 3), vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn error_metrics() {
        let a = vec![1.0, 2.0, 3.0];
        let b = vec![1.0, 2.0, 5.0];
        assert!((mae(&a, &b) - 2.0 / 3.0).abs() < 1e-12);
        assert!((rmse(&a, &b) - (4.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert!((correlation(&a, &a) - 1.0).abs() < 1e-12);
        let neg: Vec<f64> = a.iter().map(|x| -x).collect();
        assert!((correlation(&a, &neg) + 1.0).abs() < 1e-12);
        assert_eq!(correlation(&a, &[1.0, 1.0, 1.0]), 0.0);
    }

    #[test]
    fn share_stats_summarizes() {
        assert_eq!(share_stats(&[]), ShareStats::default());
        let shares = [
            UserShare { user: 1, group: 0, usage: 300.0 },
            UserShare { user: 2, group: 0, usage: 100.0 },
        ];
        let s = share_stats(&shares);
        assert_eq!(s.users, 2);
        assert_eq!(s.max_usage, 300.0);
        assert_eq!(s.total_usage, 400.0);
        assert!((s.imbalance - 1.5).abs() < 1e-12);
    }

    #[test]
    fn nmae_normalizes() {
        let r = vec![10.0, 10.0];
        let o = vec![11.0, 9.0];
        assert!((nmae(&o, &r) - 0.1).abs() < 1e-12);
        assert_eq!(nmae(&o, &[0.0, 0.0]), 0.0);
    }
}
