//! CQsim-like baseline simulator — the validation comparator.
//!
//! The paper validates its SST component against CQsim, a *separate*,
//! simpler, Python event-loop cluster-scheduling simulator. To reproduce
//! that methodology the comparator here is deliberately an independent
//! implementation: a flat two-event loop (submit / end) over a single
//! processor pool, with its own re-implementations of all six policies.
//! It shares no scheduling or accounting code with `crate::sched` /
//! `crate::sim` — agreement between the two is evidence of correctness,
//! exactly as CQsim-vs-SST agreement is in the paper (Figs 3, 4a).
//!
//! Structural differences from the component simulator (mirroring real
//! CQsim vs SST differences): flat loop instead of components/links,
//! processor-pool accounting instead of per-node maps, and queue
//! rescanning instead of event-driven dispatch guards.

use crate::core::stats::TimeSeries;
use crate::core::time::SimTime;
use crate::job::{Job, JobState};
use crate::metrics::{wait_stats, WaitStats};
use crate::sched::Policy;
use crate::trace::Workload;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Baseline run report (mirrors `sim::SimReport`'s validation surface).
#[derive(Debug, Clone)]
pub struct BaselineReport {
    pub policy: &'static str,
    pub completed: Vec<Job>,
    pub rejected: u64,
    pub events: u64,
    pub end_time: SimTime,
    /// (t, occupied nodes), nodes estimated as ceil(busy procs / ppn).
    pub occupancy: TimeSeries,
    /// (t, running jobs).
    pub running: TimeSeries,
}

impl BaselineReport {
    pub fn wait_stats(&self) -> WaitStats {
        wait_stats(&self.completed)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum EvKind {
    /// Index into the running table.
    End(usize),
    /// Index into the submit-ordered job vector.
    Submit(usize),
}

/// The CQsim-like simulator.
pub struct BaselineSim {
    policy: Policy,
    total_procs: u64,
    procs_per_node: u64,
}

impl BaselineSim {
    pub fn new(policy: Policy, workload: &Workload) -> BaselineSim {
        BaselineSim {
            policy,
            total_procs: workload.total_cores(),
            procs_per_node: workload.cores_per_node.max(1),
        }
    }

    /// Run the whole workload.
    pub fn run(&self, workload: &Workload) -> BaselineReport {
        let jobs = &workload.jobs;
        // Event heap: (time, kind, seq); End sorts before Submit at equal
        // times (resources free up first), as in CQsim.
        let mut heap: BinaryHeap<Reverse<(u64, EvKind, u64)>> = BinaryHeap::new();
        let mut seq = 0u64;
        for (i, j) in jobs.iter().enumerate() {
            heap.push(Reverse((j.submit.ticks(), EvKind::Submit(i), seq)));
            seq += 1;
        }

        let mut free = self.total_procs;
        let mut waiting: Vec<Job> = Vec::new(); // arrival order
        let mut running: Vec<Option<Job>> = Vec::new();
        let mut running_count = 0u64;
        let mut completed: Vec<Job> = Vec::with_capacity(jobs.len());
        let mut rejected = 0u64;
        let mut events = 0u64;
        let mut now = 0u64;
        let mut occupancy = TimeSeries::new();
        let mut running_series = TimeSeries::new();

        while let Some(Reverse((t, kind, _))) = heap.pop() {
            events += 1;
            now = t;
            match kind {
                EvKind::Submit(i) => {
                    let mut j = jobs[i].clone();
                    if j.cores > self.total_procs || j.cores == 0 {
                        rejected += 1;
                        continue;
                    }
                    j.state = JobState::Queued;
                    waiting.push(j);
                }
                EvKind::End(slot) => {
                    let mut j = running[slot].take().expect("end for empty slot");
                    free += j.cores;
                    running_count -= 1;
                    j.state = JobState::Completed;
                    j.end = Some(SimTime(now));
                    completed.push(j);
                }
            }
            // Scheduling pass after every event (CQsim style: rescan).
            let started = self.schedule_pass(now, &mut waiting, &mut free, &running);
            for mut j in started {
                j.state = JobState::Running;
                j.start = Some(SimTime(now));
                let end = now + j.runtime.ticks();
                let slot = running.iter().position(|s| s.is_none()).unwrap_or_else(|| {
                    running.push(None);
                    running.len() - 1
                });
                heap.push(Reverse((end, EvKind::End(slot), seq)));
                seq += 1;
                running[slot] = Some(j);
                running_count += 1;
            }
            let busy = self.total_procs - free;
            occupancy.record(SimTime(now), busy.div_ceil(self.procs_per_node) as f64);
            running_series.record(SimTime(now), running_count as f64);
        }

        BaselineReport {
            policy: self.policy.as_str(),
            completed,
            rejected,
            events,
            end_time: SimTime(now),
            occupancy,
            running: running_series,
        }
    }

    /// One scheduling pass: pick jobs to start now; mutates `waiting` and
    /// `free`. Independent re-implementation of the five policies.
    fn schedule_pass(
        &self,
        now: u64,
        waiting: &mut Vec<Job>,
        free: &mut u64,
        running: &[Option<Job>],
    ) -> Vec<Job> {
        let mut started = Vec::new();
        match self.policy {
            Policy::Fcfs | Policy::FcfsBestFit => {
                // Single pool: best-fit placement degenerates to FCFS, as
                // the paper observes ("does not significantly improve job
                // completion times").
                while let Some(j) = waiting.first() {
                    if j.cores <= *free {
                        *free -= j.cores;
                        started.push(waiting.remove(0));
                    } else {
                        break;
                    }
                }
            }
            Policy::Sjf | Policy::Ljf => loop {
                if waiting.is_empty() {
                    break;
                }
                // Pick the extreme estimate; ties by arrival order.
                let pick = if self.policy == Policy::Sjf {
                    (0..waiting.len()).min_by_key(|&i| (waiting[i].est_runtime, i)).unwrap()
                } else {
                    (0..waiting.len())
                        .max_by_key(|&i| (waiting[i].est_runtime, Reverse(i)))
                        .unwrap()
                };
                if waiting[pick].cores <= *free {
                    *free -= waiting[pick].cores;
                    started.push(waiting.remove(pick));
                } else {
                    break; // blocking discipline
                }
            },
            Policy::ConservativeBackfill => {
                // Independent conservative backfilling: recompute every
                // job's earliest slot against a simple (time, free) event
                // list; start only jobs whose slot is `now`.
                let mut events: Vec<(u64, i64)> = running
                    .iter()
                    .flatten()
                    .map(|j| {
                        let end =
                            j.start.map(|s| s.ticks()).unwrap_or(now) + j.est_runtime.ticks();
                        (end, j.cores as i64)
                    })
                    .collect();
                let mut free_now = *free as i64;
                let mut k = 0;
                while k < waiting.len() {
                    let (cores, est) =
                        (waiting[k].cores as i64, waiting[k].est_runtime.ticks().max(1));
                    // Earliest start: scan candidate starts = now + event
                    // times; feasible if free >= cores over [s, s+est).
                    let mut cands: Vec<u64> = vec![now];
                    cands.extend(events.iter().map(|e| e.0));
                    cands.sort_unstable();
                    let slot = cands.into_iter().find(|&s| {
                        // free at time t = free_now + releases(<=t) - reserved overlaps
                        let horizon = s.saturating_add(est);
                        // check at every breakpoint within [s, horizon)
                        let mut check_points: Vec<u64> = vec![s];
                        check_points.extend(
                            events.iter().map(|e| e.0).filter(|&t| t > s && t < horizon),
                        );
                        check_points.into_iter().all(|t| {
                            let mut f = free_now;
                            for &(et, ec) in &events {
                                if et <= t {
                                    f += ec;
                                }
                            }
                            f >= cores
                        })
                    });
                    match slot {
                        Some(s) if s == now => {
                            free_now -= cores;
                            // Model its own future release.
                            events.push((now + est, cores));
                            *free -= waiting[k].cores;
                            started.push(waiting.remove(k));
                        }
                        Some(s) => {
                            // Reserve: consume cores over [s, s+est) by
                            // adding a negative event at s and a release
                            // at s+est.
                            events.push((s, -cores));
                            events.push((s + est, cores));
                            k += 1;
                        }
                        None => {
                            k += 1;
                        }
                    }
                }
            }
            Policy::FcfsBackfill => {
                // FCFS phase.
                while let Some(j) = waiting.first() {
                    if j.cores <= *free {
                        *free -= j.cores;
                        started.push(waiting.remove(0));
                    } else {
                        break;
                    }
                }
                if waiting.is_empty() {
                    return started;
                }
                // EASY reservation for the head.
                let head_cores = waiting[0].cores;
                let mut releases: Vec<(u64, u64)> = running
                    .iter()
                    .flatten()
                    .map(|j| {
                        (
                            j.start.map(|s| s.ticks()).unwrap_or(now) + j.est_runtime.ticks(),
                            j.cores,
                        )
                    })
                    .collect();
                for j in &started {
                    releases.push((now + j.est_runtime.ticks(), j.cores));
                }
                releases.sort_unstable();
                let mut avail = *free;
                let mut shadow = now;
                let mut i = 0;
                while avail < head_cores && i < releases.len() {
                    avail += releases[i].1;
                    shadow = releases[i].0;
                    i += 1;
                }
                if avail < head_cores {
                    return started; // infeasible head
                }
                let mut extra = avail - head_cores;
                // Backfill pass over the rest, arrival order.
                let mut k = 1;
                while k < waiting.len() {
                    let j = &waiting[k];
                    let fits = j.cores <= *free;
                    let short = now + j.est_runtime.ticks() <= shadow;
                    let small = j.cores <= extra;
                    if fits && (short || small) {
                        if !short {
                            extra -= j.cores;
                        }
                        *free -= j.cores;
                        started.push(waiting.remove(k));
                    } else {
                        k += 1;
                    }
                }
            }
        }
        started
    }
}

/// Convenience: run a workload through the baseline under `policy`.
pub fn run_baseline(workload: &Workload, policy: Policy) -> BaselineReport {
    BaselineSim::new(policy, workload).run(workload)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wl(jobs: Vec<Job>, nodes: usize, ppn: u64) -> Workload {
        Workload::new("t", jobs, nodes, ppn)
    }

    #[test]
    fn fcfs_simple() {
        let w = wl(
            vec![
                Job::simple(1, 0, 4, 100),
                Job::simple(2, 0, 4, 100),
                Job::simple(3, 10, 8, 50),
            ],
            2,
            4,
        );
        let r = run_baseline(&w, Policy::Fcfs);
        assert_eq!(r.completed.len(), 3);
        let j3 = r.completed.iter().find(|j| j.id == 3).unwrap();
        assert_eq!(j3.start, Some(SimTime(100)));
        assert_eq!(r.end_time, SimTime(150));
    }

    #[test]
    fn rejects_oversized() {
        let w = wl(vec![Job::simple(1, 0, 100, 10)], 2, 4);
        let r = run_baseline(&w, Policy::Fcfs);
        assert_eq!(r.rejected, 1);
        assert!(r.completed.is_empty());
    }

    #[test]
    fn backfill_reorders_but_protects_head() {
        let w = wl(
            vec![
                Job::with_estimate(1, 0, 4, 100, 100),
                Job::with_estimate(2, 1, 8, 100, 100),
                Job::with_estimate(3, 2, 4, 50, 50),
            ],
            1,
            8,
        );
        let bf = run_baseline(&w, Policy::FcfsBackfill);
        let fc = run_baseline(&w, Policy::Fcfs);
        let find = |r: &BaselineReport, id: u64| -> SimTime {
            r.completed.iter().find(|j| j.id == id).unwrap().start.unwrap()
        };
        assert!(find(&bf, 3) < find(&fc, 3));
        assert_eq!(find(&bf, 2), find(&fc, 2), "head delayed by backfill");
    }

    #[test]
    fn sjf_and_ljf_differ() {
        let w = wl(
            vec![
                Job::with_estimate(1, 0, 4, 100, 100),
                Job::with_estimate(2, 1, 4, 10, 10),
                Job::with_estimate(3, 1, 4, 200, 200),
            ],
            1,
            4,
        );
        let sjf = run_baseline(&w, Policy::Sjf);
        let ljf = run_baseline(&w, Policy::Ljf);
        assert!(sjf.wait_stats().mean_wait < ljf.wait_stats().mean_wait);
    }

    #[test]
    fn conservation_all_jobs_accounted() {
        let w = crate::trace::Das2Model::default().generate(2000, 5);
        let r = run_baseline(&w, Policy::FcfsBackfill);
        assert_eq!(r.completed.len() as u64 + r.rejected, 2000);
        for j in &r.completed {
            let s = j.start.unwrap();
            assert!(s >= j.submit);
            assert_eq!(j.end.unwrap(), s + j.runtime);
        }
    }

    #[test]
    fn occupancy_returns_to_zero() {
        let w = wl(vec![Job::simple(1, 0, 4, 10), Job::simple(2, 5, 2, 20)], 2, 4);
        let r = run_baseline(&w, Policy::Fcfs);
        assert_eq!(r.occupancy.points().last().unwrap().1, 0.0);
    }

    #[test]
    fn agrees_with_component_simulator_on_fcfs() {
        // The core validation property (paper Figs 3/4a): independent
        // implementations agree on per-job start times under FCFS.
        let w = crate::trace::Das2Model::default().generate(500, 8);
        let ours = crate::sim::run_policy(w.clone(), Policy::Fcfs);
        let base = run_baseline(&w, Policy::Fcfs);
        assert_eq!(ours.completed.len(), base.completed.len());
        let mut a: Vec<(u64, SimTime)> =
            ours.completed.iter().map(|j| (j.id, j.start.unwrap())).collect();
        let mut b: Vec<(u64, SimTime)> =
            base.completed.iter().map(|j| (j.id, j.start.unwrap())).collect();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b, "start-time disagreement between independent simulators");
    }
}
