//! LIGO Inspiral Analysis workflow generator (gravitational-wave binary
//! inspiral search; completes the Juve et al. profile set).
//!
//! Two-pass structure: template banks feed matched-filter inspirals,
//! coincidence (Thinca) joins detector groups, trigger banks re-filter,
//! and a second coincidence pass concludes. Stage means (seconds):
//! TmpltBank 18.1, Inspiral 460.2, Thinca 5.1, TrigBank 5.1.

use super::Builder;
use crate::workflow::Workflow;

/// LIGO Inspiral over `segments` data segments, grouped `group` per
/// Thinca coincidence.
pub fn ligo_inspiral(segments: usize, seed: u64, exact: bool) -> Workflow {
    ligo_grouped(segments, 5, seed, exact)
}

/// Full-parameter variant.
pub fn ligo_grouped(segments: usize, group: usize, seed: u64, exact: bool) -> Workflow {
    let n = segments.max(1);
    let g = group.max(1);
    let mut b = Builder::new(seed ^ 0x7160_1160, exact);

    // Pass 1: bank -> inspiral per segment.
    let mut inspirals = Vec::new();
    for _ in 0..n {
        let bank = b.task("TmpltBank", 18.1, 1, 512, vec![]);
        inspirals.push(b.task("Inspiral", 460.2, 1, 1024, vec![bank]));
    }

    // Thinca coincidence per group of segments.
    let mut thincas = Vec::new();
    for chunk in inspirals.chunks(g) {
        thincas.push(b.task("Thinca", 5.1, 1, 512, chunk.to_vec()));
    }

    // Pass 2: per group, trigger bank -> second inspiral fan -> Thinca2.
    for &th in &thincas {
        let trig = b.task("TrigBank", 5.1, 1, 512, vec![th]);
        let mut pass2 = Vec::new();
        for _ in 0..g.min(n) {
            pass2.push(b.task("Inspiral2", 460.2, 1, 1024, vec![trig]));
        }
        let _th2 = b.task("Thinca2", 5.1, 1, 512, pass2);
    }
    b.build(6, "ligo-inspiral")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_count() {
        let w = ligo_grouped(10, 5, 1, true);
        // Pass1: 10 banks + 10 inspirals. 2 thincas. Per thinca: 1 trig +
        // 5 inspiral2 + 1 thinca2 = 7 -> 14.
        assert_eq!(w.len(), 20 + 2 + 14);
    }

    #[test]
    fn thinca2_leaves() {
        let w = ligo_grouped(10, 5, 1, true);
        let leaves = w.dag.leaves();
        assert_eq!(leaves.len(), 2);
        for l in leaves {
            assert_eq!(w.tasks[&l].stage, "Thinca2");
        }
    }

    #[test]
    fn two_pass_depth() {
        let w = ligo_grouped(10, 5, 1, true);
        // bank -> inspiral -> thinca -> trig -> inspiral2 -> thinca2.
        assert_eq!(w.dag.depth(), Some(5));
    }

    #[test]
    fn critical_path_includes_both_inspiral_passes() {
        let w = ligo_grouped(5, 5, 1, true);
        assert!(w.critical_path_time() >= 2.0 * 460.0);
    }

    #[test]
    fn partial_last_group() {
        let w = ligo_grouped(7, 5, 1, true);
        // Two thinca groups: 5 + 2.
        let thincas = w.tasks.values().filter(|t| t.stage == "Thinca").count();
        assert_eq!(thincas, 2);
    }
}
