//! SIPHT workflow generator (paper Fig 7).
//!
//! SIPHT searches for small untranslated RNAs (sRNAs) in bacterial
//! replicons (Juve 2014, Pegasus gallery). One replicon's sub-workflow is
//! ~31 tasks: a fan of Patser motif searches concatenated into one file,
//! three independent terminator/motif predictions joined by the SRNA
//! prediction, a fan of BLAST comparisons, and a final annotation join.
//!
//! Stage runtime means (seconds) from the published SIPHT profile:
//! Patser 0.96, Patser_concate 0.03->1, Transterm 32.3, Findterm 594.9,
//! RNAMotif 25.6, SRNA 12.4, FFN_parse 0.7->1, Blast 3311.1,
//! Blast_synteny 3.6, Blast_candidate 0.6->1, Blast_QRNA 440.8,
//! Blast_paralogues 0.7->1, SRNA_annotate 0.14->1.

use super::Builder;
use crate::workflow::Workflow;

/// Number of Patser tasks per replicon in the published workflow.
const PATSER_FAN: usize = 21;

/// SIPHT over `replicons` bacterial replicons (the gallery instance is 1;
/// larger values model the multi-replicon campaigns the project ran).
pub fn sipht(replicons: usize, seed: u64, exact: bool) -> Workflow {
    let r = replicons.max(1);
    let mut b = Builder::new(seed ^ 0x51B117, exact);
    let mut annotates = Vec::new();
    for _ in 0..r {
        // Patser fan -> concatenation.
        let patsers = b.stage("patser", PATSER_FAN, 0.96, 1, 128, &[]);
        let concat = b.task("patser_concate", 1.0, 1, 128, patsers);

        // Independent predictions.
        let transterm = b.task("transterm", 32.3, 1, 512, vec![]);
        let findterm = b.task("findterm", 594.9, 1, 1024, vec![]);
        let rnamotif = b.task("rnamotif", 25.6, 1, 512, vec![]);

        // SRNA prediction joins the three.
        let srna = b.task("srna", 12.4, 1, 512, vec![transterm, findterm, rnamotif]);

        // FFN parse + BLAST fan.
        let ffn = b.task("ffn_parse", 1.0, 1, 256, vec![srna]);
        let blast = b.task("blast", 3311.1, 1, 2048, vec![srna, ffn]);
        let synteny = b.task("blast_synteny", 3.6, 1, 512, vec![srna, ffn]);
        let candidate = b.task("blast_candidate", 1.0, 1, 256, vec![srna]);
        let qrna = b.task("blast_qrna", 440.8, 1, 1024, vec![srna, ffn]);
        let paralogues = b.task("blast_paralogues", 1.0, 1, 256, vec![srna]);

        // Final annotation joins everything (incl. the Patser concat).
        let annotate = b.task(
            "srna_annotate",
            1.0,
            1,
            256,
            vec![concat, blast, synteny, candidate, qrna, paralogues],
        );
        annotates.push(annotate);
    }
    b.build(7, "sipht")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_replicon_task_count() {
        let w = sipht(1, 1, true);
        // 21 patser + concat + 3 predictions + srna + ffn + 5 blasts +
        // annotate = 33.
        assert_eq!(w.len(), 33);
        let h = w.stage_histogram();
        assert_eq!(h["patser"], PATSER_FAN);
        assert_eq!(h["blast"], 1);
        assert_eq!(h["srna_annotate"], 1);
    }

    #[test]
    fn annotate_is_the_only_leaf() {
        let w = sipht(1, 2, true);
        let leaves = w.dag.leaves();
        assert_eq!(leaves.len(), 1);
        assert_eq!(w.tasks[&leaves[0]].stage, "srna_annotate");
    }

    #[test]
    fn blast_dominates_critical_path() {
        let w = sipht(1, 1, true);
        // Critical path must include the 3311 s blast.
        assert!(w.critical_path_time() >= 3311.0);
        // findterm (594.9) -> srna -> blast -> annotate ~ 3920.
        assert!(w.critical_path_time() < 4200.0);
    }

    #[test]
    fn replicons_scale_independently() {
        let w = sipht(3, 1, true);
        assert_eq!(w.len(), 3 * 33);
        assert_eq!(w.dag.leaves().len(), 3);
        // Parallel replicons: critical path equals single replicon's.
        let single = sipht(1, 1, true);
        assert!((w.critical_path_time() - single.critical_path_time()).abs() < 1e-9);
    }

    #[test]
    fn srna_joins_three_predictions() {
        let w = sipht(1, 3, true);
        let (id, _) = w.tasks.iter().find(|(_, t)| t.stage == "srna").unwrap();
        let stages: Vec<String> = w
            .dag
            .parents_of(*id)
            .iter()
            .map(|p| w.tasks[p].stage.clone())
            .collect();
        for s in ["transterm", "findterm", "rnamotif"] {
            assert!(stages.iter().any(|x| x == s), "srna missing parent {s}");
        }
    }
}
