//! CyberShake workflow generator (seismic hazard characterization; part
//! of the Juve et al. profile set the paper's workflow component targets).
//!
//! Per site: strain Green tensors are extracted, a large fan of
//! seismogram syntheses runs per rupture variation, peak intensities are
//! computed per seismogram, and two zip joins collect outputs. Stage
//! means (seconds): ExtractSGT 110.5, SeismogramSynthesis 48.2, ZipSeis
//! 150.1, PeakValCalcOkaya 1.0, ZipPSA 265.3.

use super::Builder;
use crate::workflow::Workflow;

/// CyberShake with `sites` SGT pairs; each site fans into `variations`
/// seismogram syntheses (default profile uses a large fan; scaled here).
pub fn cybershake(sites: usize, seed: u64, exact: bool) -> Workflow {
    cybershake_fan(sites, 8, seed, exact)
}

/// Full-parameter variant.
pub fn cybershake_fan(sites: usize, variations: usize, seed: u64, exact: bool) -> Workflow {
    let s = sites.max(1);
    let v = variations.max(1);
    let mut b = Builder::new(seed ^ 0xC4B3_54AE, exact);
    let mut seis_all = Vec::new();
    let mut peaks_all = Vec::new();
    for _ in 0..s {
        let sgt = b.task("ExtractSGT", 110.5, 1, 2048, vec![]);
        for _ in 0..v {
            let seis = b.task("SeismogramSynthesis", 48.2, 1, 1024, vec![sgt]);
            let peak = b.task("PeakValCalcOkaya", 1.0, 1, 256, vec![seis]);
            seis_all.push(seis);
            peaks_all.push(peak);
        }
    }
    let _zip_seis = b.task("ZipSeis", 150.1, 1, 1024, seis_all);
    let _zip_psa = b.task("ZipPSA", 265.3, 1, 1024, peaks_all);
    b.build(5, "cybershake")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_count() {
        let w = cybershake_fan(10, 8, 1, true);
        // 10 SGT + 80 seis + 80 peak + 2 zips.
        assert_eq!(w.len(), 10 + 80 + 80 + 2);
    }

    #[test]
    fn two_zip_leaves() {
        let w = cybershake(4, 1, true);
        let mut stages: Vec<String> =
            w.dag.leaves().iter().map(|l| w.tasks[l].stage.clone()).collect();
        stages.sort();
        assert_eq!(stages, vec!["ZipPSA".to_string(), "ZipSeis".to_string()]);
    }

    #[test]
    fn wide_and_shallow() {
        let w = cybershake_fan(10, 8, 1, true);
        // SGT -> seis -> peak -> zip = 3 edges deep.
        assert_eq!(w.dag.depth(), Some(3));
    }

    #[test]
    fn every_peak_has_one_seismogram_parent() {
        let w = cybershake_fan(3, 2, 1, true);
        for (id, t) in w.tasks.iter().filter(|(_, t)| t.stage == "PeakValCalcOkaya") {
            let parents = w.dag.parents_of(*id);
            assert_eq!(parents.len(), 1, "peak {id} parents {parents:?}");
            assert_eq!(w.tasks[&parents[0]].stage, "SeismogramSynthesis");
            let _ = t;
        }
    }
}
