//! Montage / Galactic Plane workflow generator.
//!
//! Montage builds astronomical image mosaics; the paper's Fig 6 runs the
//! *Galactic Plane* workflow — Montage applied to 17 sky surveys with all
//! pixels reprojected to a common scale. Structure per Juve et al. 2013:
//!
//! ```text
//! mProjectPP (W)  -> mDiffFit (~3W/2 overlaps) -> mConcatFit (1)
//!   -> mBgModel (1) -> mBackground (W) -> mImgtbl (1) -> mAdd (1)
//!   -> mShrink (1) -> mJPEG (1)
//! ```
//!
//! Stage runtime means (seconds) from the published Montage profile:
//! mProjectPP 1.73, mDiffFit 0.66, mConcatFit 143, mBgModel 384,
//! mBackground 1.72, mImgtbl 2.5, mAdd 282, mShrink 66, mJPEG 0.7.

use super::Builder;
use crate::workflow::Workflow;

/// Montage over `width` input images. `exact` disables runtime jitter.
pub fn montage(width: usize, seed: u64, exact: bool) -> Workflow {
    montage_named(width, seed, exact, 1, "montage")
}

fn montage_named(width: usize, seed: u64, exact: bool, id: u64, name: &str) -> Workflow {
    let w = width.max(2);
    let mut b = Builder::new(seed ^ 0x4D07A6E, exact);

    // mProjectPP: one per input image.
    let projects = b.stage("mProjectPP", w, 1.73, 1, 512, &[]);

    // mDiffFit: one per overlapping image pair. A strip mosaic overlaps
    // neighbours; model ~1.5 overlaps per image: (i, i+1) pairs plus every
    // second (i, i+2) pair.
    let mut diffs = Vec::new();
    for i in 0..w - 1 {
        diffs.push(b.task(
            "mDiffFit",
            0.66,
            1,
            256,
            vec![projects[i], projects[i + 1]],
        ));
        if i % 2 == 0 && i + 2 < w {
            diffs.push(b.task(
                "mDiffFit",
                0.66,
                1,
                256,
                vec![projects[i], projects[i + 2]],
            ));
        }
    }

    // Fit concatenation and background model: global joins.
    let concat = b.task("mConcatFit", 143.0, 1, 1024, diffs.clone());
    let bg_model = b.task("mBgModel", 384.0, 1, 1024, vec![concat]);

    // mBackground: per image, needs its projection and the model.
    let backgrounds: Vec<_> = projects
        .iter()
        .map(|&p| b.task("mBackground", 1.72, 1, 512, vec![p, bg_model]))
        .collect();

    let imgtbl = b.task("mImgtbl", 2.5, 1, 512, backgrounds.clone());
    let add = b.task("mAdd", 282.0, 1, 2048, vec![imgtbl]);
    let shrink = b.task("mShrink", 66.0, 1, 1024, vec![add]);
    let _jpeg = b.task("mJPEG", 0.7, 1, 256, vec![shrink]);

    b.build(id, name)
}

/// Galactic Plane: `surveys` independent Montage mosaics (the paper's run
/// uses 17 surveys) merged under a final tile-aggregation task.
pub fn galactic_plane(surveys: usize, seed: u64, exact: bool) -> Workflow {
    galactic_plane_wide(surveys, 8, seed, exact)
}

/// Galactic Plane with `width` images per survey mosaic (scaling knob for
/// the Fig 6 experiments; the real run mosaics thousands of tiles).
pub fn galactic_plane_wide(surveys: usize, width: usize, seed: u64, exact: bool) -> Workflow {
    let s = surveys.max(1);
    let width = width.max(2);
    let mut b = Builder::new(seed ^ 0x6A1AC71C, exact);
    let mut mosaic_leaves = Vec::new();
    for k in 0..s {
        // Inline one Montage per survey through the same builder so ids
        // stay unique.
        let projects = b.stage("mProjectPP", width, 1.73, 1, 512, &[]);
        let mut diffs = Vec::new();
        for i in 0..projects.len() - 1 {
            diffs.push(b.task(
                "mDiffFit",
                0.66,
                1,
                256,
                vec![projects[i], projects[i + 1]],
            ));
        }
        let concat = b.task("mConcatFit", 143.0, 1, 1024, diffs);
        let bg = b.task("mBgModel", 384.0, 1, 1024, vec![concat]);
        let backs: Vec<_> = projects
            .iter()
            .map(|&p| b.task("mBackground", 1.72, 1, 512, vec![p, bg]))
            .collect();
        let imgtbl = b.task("mImgtbl", 2.5, 1, 512, backs);
        let add = b.task("mAdd", 282.0, 1, 2048, vec![imgtbl]);
        let _ = k;
        mosaic_leaves.push(add);
    }
    let _merge = b.task("gp-merge", 120.0, 2, 4096, mosaic_leaves);
    b.build(17, "galactic-plane")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn montage_shape() {
        let w = montage(20, 1, true);
        let h = w.stage_histogram();
        assert_eq!(h["mProjectPP"], 20);
        assert_eq!(h["mBackground"], 20);
        assert_eq!(h["mConcatFit"], 1);
        assert_eq!(h["mBgModel"], 1);
        assert_eq!(h["mAdd"], 1);
        assert!(h["mDiffFit"] >= 19, "diffs = {}", h["mDiffFit"]);
        // Chain mConcatFit -> mBgModel -> ... -> mJPEG bounds depth.
        assert!(w.dag.depth().unwrap() >= 7);
    }

    #[test]
    fn montage_entry_and_exit() {
        let w = montage(10, 2, true);
        // All roots are projections; single JPEG leaf.
        for r in w.dag.roots() {
            assert_eq!(w.tasks[&r].stage, "mProjectPP");
        }
        let leaves = w.dag.leaves();
        assert_eq!(leaves.len(), 1);
        assert_eq!(w.tasks[&leaves[0]].stage, "mJPEG");
    }

    #[test]
    fn galactic_plane_scales_with_surveys() {
        let small = galactic_plane(2, 1, true);
        let large = galactic_plane(6, 1, true);
        assert!(large.len() > small.len() * 2);
        // Single global merge leaf.
        assert_eq!(large.dag.leaves().len(), 1);
    }

    #[test]
    fn background_depends_on_model_and_projection() {
        let w = montage(6, 3, true);
        let (id, _) = w
            .tasks
            .iter()
            .find(|(_, t)| t.stage == "mBackground")
            .expect("has backgrounds");
        let parents = w.dag.parents_of(*id);
        let stages: Vec<&str> =
            parents.iter().map(|p| w.tasks[p].stage.as_str()).collect();
        assert!(stages.contains(&"mProjectPP"));
        assert!(stages.contains(&"mBgModel"));
    }
}
