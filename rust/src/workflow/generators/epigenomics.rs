//! Epigenomics workflow generator (paper §4.1: "4seq, 5seq, 6seq"
//! epigenomic sequencing pipelines).
//!
//! The USC Epigenome Center pipeline maps methylation states: per
//! sequence lane the read file is split into chunks, each chunk passes a
//! filter -> convert -> reformat -> map chain, per-lane maps merge, and
//! the global merge feeds indexing and pileup. "4seq/5seq/6seq" = number
//! of lanes. Structure and stage means (seconds) per Juve et al. 2013:
//! fastQSplit 34.9, filterContams 2.5, sol2sanger 0.5->1, fast2bfq 1.4,
//! map 201.9, mapMerge (lane) 11.0, mapMerge (global) 60.0, maqIndex
//! 40.1, pileup 55.9.

use super::Builder;
use crate::workflow::Workflow;

/// Epigenomics with `lanes` sequence lanes (4/5/6 in the paper) and
/// `splits` chunks per lane.
pub fn epigenomics(lanes: usize, splits: usize, seed: u64, exact: bool) -> Workflow {
    let l = lanes.max(1);
    let s = splits.max(1);
    let mut b = Builder::new(seed ^ 0xE916E0, exact);
    let mut lane_merges = Vec::new();
    for _ in 0..l {
        let split = b.task("fastQSplit", 34.9, 1, 512, vec![]);
        let mut maps = Vec::new();
        for _ in 0..s {
            let filter = b.task("filterContams", 2.5, 1, 256, vec![split]);
            let sol = b.task("sol2sanger", 1.0, 1, 256, vec![filter]);
            let bfq = b.task("fast2bfq", 1.4, 1, 256, vec![sol]);
            let map = b.task("map", 201.9, 1, 1024, vec![bfq]);
            maps.push(map);
        }
        lane_merges.push(b.task("mapMerge", 11.0, 1, 512, maps));
    }
    let global_merge = b.task("mapMergeGlobal", 60.0, 1, 1024, lane_merges);
    let index = b.task("maqIndex", 40.1, 1, 1024, vec![global_merge]);
    let _pileup = b.task("pileup", 55.9, 1, 1024, vec![index]);
    b.build(4, &format!("epigenomics-{l}seq"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_count_formula() {
        // Per lane: 1 split + 4*s chain tasks + 1 merge; plus 3 global.
        for (lanes, splits) in [(4usize, 4usize), (5, 4), (6, 8)] {
            let w = epigenomics(lanes, splits, 1, true);
            assert_eq!(w.len(), lanes * (2 + 4 * splits) + 3);
        }
    }

    #[test]
    fn four_five_six_seq_grow_monotonically() {
        let n4 = epigenomics(4, 4, 1, true).len();
        let n5 = epigenomics(5, 4, 1, true).len();
        let n6 = epigenomics(6, 4, 1, true).len();
        assert!(n4 < n5 && n5 < n6);
    }

    #[test]
    fn pipeline_depth() {
        let w = epigenomics(4, 4, 1, true);
        // split -> filter -> sol -> bfq -> map -> laneMerge -> globalMerge
        // -> index -> pileup = 8 edges.
        assert_eq!(w.dag.depth(), Some(8));
    }

    #[test]
    fn pileup_is_single_leaf() {
        let w = epigenomics(5, 3, 2, true);
        let leaves = w.dag.leaves();
        assert_eq!(leaves.len(), 1);
        assert_eq!(w.tasks[&leaves[0]].stage, "pileup");
    }

    #[test]
    fn map_stage_dominates_work() {
        let w = epigenomics(4, 4, 1, true);
        let map_work: f64 = w
            .tasks
            .values()
            .filter(|t| t.stage == "map")
            .map(|t| t.execution_time.as_f64())
            .sum();
        assert!(map_work > 0.8 * w.total_work(), "map fraction too small");
    }

    #[test]
    fn lanes_are_parallel_until_global_merge() {
        let w = epigenomics(4, 2, 1, true);
        // Critical path ~ one lane's chain + global tail, far below the
        // serial total.
        assert!(w.critical_path_time() < w.total_work() / 3.0);
    }
}
