//! Pegasus-gallery workflow generators (paper §4: Galactic Plane /
//! Montage, SIPHT, Epigenomics 4seq/5seq/6seq; plus CyberShake and
//! LIGO-Inspiral for coverage).
//!
//! The real Pegasus DAX files are not redistributable; these generators
//! reproduce the published DAG *shapes* and per-stage runtime profiles
//! from Juve et al. 2013, "Characterizing and Profiling Scientific
//! Workflows" (the paper's own workflow reference). Stage means are
//! tabulated per generator; each task's runtime is the stage mean
//! jittered lognormally (cv ~ 0.2) unless `exact` profiles are requested
//! (used as the "real-life measurement" reference in Fig 7).

pub mod cybershake;
pub mod epigenomics;
pub mod ligo;
pub mod montage;
pub mod sipht;

pub use cybershake::cybershake;
pub use epigenomics::epigenomics;
pub use ligo::ligo_inspiral;
pub use montage::{galactic_plane, galactic_plane_wide, montage};
pub use sipht::sipht;

use crate::core::rng::Rng;
use crate::workflow::task::{Task, TaskId};
use crate::workflow::Workflow;

/// Incremental workflow builder used by all generators.
pub(crate) struct Builder {
    tasks: Vec<Task>,
    next_id: TaskId,
    rng: Rng,
    /// When true, stage means are used exactly (reference profiles).
    exact: bool,
}

impl Builder {
    pub fn new(seed: u64, exact: bool) -> Builder {
        Builder { tasks: Vec::new(), next_id: 1, rng: Rng::new(seed), exact }
    }

    /// Add one task of `stage` with mean runtime `mean_s` seconds and the
    /// given deps; returns its id.
    pub fn task(
        &mut self,
        stage: &str,
        mean_s: f64,
        cpu: u64,
        mem_mb: u64,
        deps: Vec<TaskId>,
    ) -> TaskId {
        let id = self.next_id;
        self.next_id += 1;
        let runtime = if self.exact {
            mean_s.max(1.0).round() as u64
        } else {
            // Lognormal jitter around the stage mean with cv ~= 0.2:
            // sigma^2 = ln(1 + cv^2), mu = ln(mean) - sigma^2/2.
            let cv2: f64 = 0.04;
            let sigma = (1.0 + cv2).ln().sqrt();
            let mu = mean_s.max(1.0).ln() - sigma * sigma / 2.0;
            self.rng.lognormal(mu, sigma).round().max(1.0) as u64
        };
        self.tasks
            .push(Task::new(id, runtime, cpu, mem_mb).with_deps(deps).with_stage(stage));
        id
    }

    /// Add `n` identical-stage tasks; returns their ids.
    pub fn stage(
        &mut self,
        stage: &str,
        n: usize,
        mean_s: f64,
        cpu: u64,
        mem_mb: u64,
        deps: &[TaskId],
    ) -> Vec<TaskId> {
        (0..n).map(|_| self.task(stage, mean_s, cpu, mem_mb, deps.to_vec())).collect()
    }


    pub fn build(self, id: u64, name: &str) -> Workflow {
        Workflow::new(id, name, self.tasks).expect("generator produced invalid DAG")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_generators_produce_valid_dags() {
        // Every generator must yield an acyclic, connected-enough DAG with
        // the advertised scale.
        let cases: Vec<(&str, Workflow)> = vec![
            ("montage", montage(20, 1, false)),
            ("galactic", galactic_plane(2, 1, false)),
            ("sipht", sipht(1, 1, false)),
            ("epigenomics-4seq", epigenomics(4, 4, 1, false)),
            ("cybershake", cybershake(10, 1, false)),
            ("ligo", ligo_inspiral(10, 1, false)),
        ];
        for (name, w) in cases {
            assert!(w.dag.is_acyclic(), "{name} has a cycle");
            assert!(w.len() > 5, "{name} suspiciously small: {}", w.len());
            assert!(!w.dag.roots().is_empty(), "{name} has no entry tasks");
            assert!(!w.dag.leaves().is_empty(), "{name} has no exit tasks");
            assert!(w.critical_path_time() > 0.0);
            assert!(w.critical_path_time() <= w.total_work());
        }
    }

    #[test]
    fn exact_profiles_are_deterministic_across_seeds() {
        let a = sipht(1, 1, true);
        let b = sipht(1, 999, true);
        for (x, y) in a.tasks.values().zip(b.tasks.values()) {
            assert_eq!(x.execution_time, y.execution_time);
        }
    }

    #[test]
    fn jittered_profiles_vary_with_seed_but_not_structure() {
        let a = montage(16, 1, false);
        let b = montage(16, 2, false);
        assert_eq!(a.len(), b.len());
        assert_eq!(a.dag.num_edges(), b.dag.num_edges());
        assert!(
            a.tasks.values().zip(b.tasks.values()).any(|(x, y)| x.execution_time
                != y.execution_time),
            "seeds produced identical runtimes"
        );
    }

    #[test]
    fn builder_jitter_stays_near_mean() {
        let mut b = Builder::new(7, false);
        let ids = b.stage("s", 2000, 100.0, 1, 0, &[]);
        let w = b.build(1, "jitter");
        let mean: f64 = ids
            .iter()
            .map(|id| w.tasks[id].execution_time.as_f64())
            .sum::<f64>()
            / ids.len() as f64;
        assert!((mean - 100.0).abs() < 5.0, "mean {mean}");
    }
}
