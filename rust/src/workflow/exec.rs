//! Event-driven workflow execution (paper §3.2 "Scheduling and
//! Execution"): ready tasks are started FCFS whenever CPU and memory
//! allow; completions trigger dependents; the run drives a small
//! discrete-event loop identical in semantics to the SST integration but
//! self-contained for workflow-only experiments (Figs 6, 7).

use crate::core::time::{SimDuration, SimTime};
use crate::workflow::manager::WorkflowManager;
use crate::workflow::task::TaskId;
use crate::workflow::Workflow;
use std::collections::BinaryHeap;

/// Per-task outcome.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TaskTimes {
    pub id: TaskId,
    pub ready: SimTime,
    pub start: SimTime,
    pub end: SimTime,
}

impl TaskTimes {
    pub fn wait(&self) -> SimDuration {
        self.start - self.ready
    }
}

/// Result of executing one workflow.
#[derive(Debug, Clone)]
pub struct WorkflowReport {
    pub name: String,
    pub makespan: SimDuration,
    pub tasks: Vec<TaskTimes>,
    /// Peak concurrent CPU use observed.
    pub peak_cpu: u64,
    pub events: u64,
}

impl WorkflowReport {
    pub fn mean_wait(&self) -> f64 {
        if self.tasks.is_empty() {
            return 0.0;
        }
        self.tasks.iter().map(|t| t.wait().as_f64()).sum::<f64>() / self.tasks.len() as f64
    }

    pub fn max_wait(&self) -> f64 {
        self.tasks.iter().map(|t| t.wait().as_f64()).fold(0.0, f64::max)
    }

    /// Wait times grouped by the order tasks completed (Fig 7's series).
    pub fn waits_in_completion_order(&self) -> Vec<f64> {
        let mut ts = self.tasks.clone();
        ts.sort_by_key(|t| (t.end, t.id));
        ts.iter().map(|t| t.wait().as_f64()).collect()
    }
}

/// FCFS workflow executor over a (cpu, memory) pool.
#[derive(Debug, Clone)]
pub struct WorkflowExecutor {
    pub cpu: u64,
    pub memory_mb: u64,
}

impl WorkflowExecutor {
    pub fn new(cpu: u64, memory_mb: u64) -> WorkflowExecutor {
        WorkflowExecutor { cpu: cpu.max(1), memory_mb }
    }

    /// Run the workflow to completion; panics if any task's requirements
    /// exceed the pool (validated up front with a clear message).
    pub fn run(&self, workflow: Workflow) -> WorkflowReport {
        for t in workflow.tasks.values() {
            assert!(
                t.resources.cpu <= self.cpu && t.resources.memory_mb <= self.memory_mb,
                "task {} needs (cpu {}, mem {}) but pool is (cpu {}, mem {})",
                t.id,
                t.resources.cpu,
                t.resources.memory_mb,
                self.cpu,
                self.memory_mb
            );
        }
        let name = workflow.name.clone();
        let mut mgr = WorkflowManager::new(workflow, SimTime::ZERO);
        let mut now = SimTime::ZERO;
        let mut free_cpu = self.cpu;
        let mut free_mem = self.memory_mb;
        let mut peak_cpu = 0u64;
        let mut events = 0u64;
        // Completion min-heap: (end_time, task) — Reverse for min.
        let mut completions: BinaryHeap<std::cmp::Reverse<(SimTime, TaskId)>> = BinaryHeap::new();
        let mut done: Vec<TaskTimes> = Vec::with_capacity(mgr.workflow().len());

        loop {
            // Start ready tasks FCFS (id order = submission order) while
            // resources allow. A blocked head does not block smaller
            // later tasks (task scheduling here is list-FCFS, as basic
            // workflow engines do).
            let ready = mgr.ready_tasks();
            for id in ready {
                let (cpu, mem, dur) = {
                    let t = &mgr.workflow().tasks[&id];
                    (t.resources.cpu, t.resources.memory_mb, t.execution_time)
                };
                if cpu <= free_cpu && mem <= free_mem {
                    free_cpu -= cpu;
                    free_mem -= mem;
                    mgr.mark_started(id, now);
                    completions.push(std::cmp::Reverse((now + dur, id)));
                    events += 1;
                }
            }
            peak_cpu = peak_cpu.max(self.cpu - free_cpu);

            // Advance to the next completion.
            let Some(std::cmp::Reverse((t_end, id))) = completions.pop() else {
                break;
            };
            debug_assert!(t_end >= now);
            now = t_end;
            events += 1;
            {
                let t = &mgr.workflow().tasks[&id];
                free_cpu += t.resources.cpu;
                free_mem += t.resources.memory_mb;
            }
            mgr.mark_completed(id, now);
            debug_assert!(mgr.check_invariants());
            let t = &mgr.workflow().tasks[&id];
            done.push(TaskTimes {
                id,
                ready: t.ready_at.expect("ran => was ready"),
                start: t.start.expect("ran => started"),
                end: now,
            });
        }
        assert!(mgr.all_done(), "deadlock: {} of {} tasks completed (resource starvation?)",
            mgr.num_completed(), mgr.workflow().len());
        done.sort_by_key(|t| t.id);
        WorkflowReport { name, makespan: now - SimTime::ZERO, tasks: done, peak_cpu, events }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::task::Task;

    fn listing2_workflow() -> Workflow {
        Workflow::new(
            1,
            "listing2",
            vec![
                Task::new(1, 100, 2, 1024),
                Task::new(2, 150, 1, 512).with_deps(vec![1]),
                Task::new(3, 200, 1, 512).with_deps(vec![1]),
                Task::new(4, 300, 2, 1024).with_deps(vec![2, 3]),
            ],
        )
        .unwrap()
    }

    #[test]
    fn listing2_with_ample_resources_hits_critical_path() {
        let r = WorkflowExecutor::new(10, 8192).run(listing2_workflow());
        // 1 (100) -> max(150, 200) -> 4 (300) = 600.
        assert_eq!(r.makespan, SimDuration(600));
        assert_eq!(r.tasks.len(), 4);
        // Tasks 2 and 3 run concurrently.
        assert_eq!(r.peak_cpu, 2);
        assert_eq!(r.mean_wait(), 0.0);
    }

    #[test]
    fn cpu_bottleneck_serializes_parallel_stage() {
        // Pool of 1 CPU: tasks 2 and 3 must serialize.
        let r = WorkflowExecutor::new(2, 8192).run(listing2_workflow());
        // 1(100, 2cpu) -> 2&3 in parallel (1 cpu each fits in 2) -> 4.
        assert_eq!(r.makespan, SimDuration(600));
        let r1 = WorkflowExecutor::new(1, 8192).run(Workflow::new(
            1,
            "narrow",
            vec![
                Task::new(1, 100, 1, 0),
                Task::new(2, 150, 1, 0).with_deps(vec![1]),
                Task::new(3, 200, 1, 0).with_deps(vec![1]),
                Task::new(4, 300, 1, 0).with_deps(vec![2, 3]),
            ],
        )
        .unwrap());
        // Everything serial: 100+150+200+300.
        assert_eq!(r1.makespan, SimDuration(750));
        // One of tasks 2/3 waited for the other.
        assert!(r1.max_wait() > 0.0);
    }

    #[test]
    fn dependencies_strictly_respected() {
        let r = WorkflowExecutor::new(10, 8192).run(listing2_workflow());
        let by_id: std::collections::BTreeMap<_, _> =
            r.tasks.iter().map(|t| (t.id, *t)).collect();
        assert!(by_id[&2].start >= by_id[&1].end);
        assert!(by_id[&3].start >= by_id[&1].end);
        assert!(by_id[&4].start >= by_id[&2].end.max(by_id[&3].end));
    }

    #[test]
    fn memory_constraint_blocks_concurrency() {
        // Two independent tasks, each needs all memory: must serialize.
        let w = Workflow::new(
            1,
            "mem",
            vec![Task::new(1, 50, 1, 1000), Task::new(2, 50, 1, 1000)],
        )
        .unwrap();
        let r = WorkflowExecutor::new(8, 1000).run(w);
        assert_eq!(r.makespan, SimDuration(100));
    }

    #[test]
    #[should_panic(expected = "needs")]
    fn oversized_task_panics_clearly() {
        let w = Workflow::new(1, "big", vec![Task::new(1, 10, 64, 0)]).unwrap();
        WorkflowExecutor::new(2, 100).run(w);
    }

    #[test]
    fn single_task_workflow() {
        let w = Workflow::new(1, "one", vec![Task::new(1, 42, 1, 0)]).unwrap();
        let r = WorkflowExecutor::new(1, 0).run(w);
        assert_eq!(r.makespan, SimDuration(42));
        assert_eq!(r.tasks[0].wait(), SimDuration(0));
    }
}
