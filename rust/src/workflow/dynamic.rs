//! Dynamic workflow scheduling + preemption (paper §5 future work: "we
//! aim to enhance SST's workflow management by integrating dynamic
//! scheduling and preemption capabilities").
//!
//! Three task-ordering disciplines over the ready set:
//!
//! * [`TaskOrder::Fcfs`] — the paper's baseline (ready order).
//! * [`TaskOrder::CriticalPath`] — upward-rank priority: a task's rank is
//!   its execution time plus the maximum rank of its dependents (the
//!   HEFT ranking restricted to one homogeneous pool), so tasks on the
//!   critical path run first.
//! * [`TaskOrder::WidestFirst`] — most-dependents-first (fan-out heavy
//!   tasks unblock the most work).
//!
//! Preemption (optional): when a ready task's priority exceeds a running
//! task's by more than a threshold, the running task is checkpointed
//! (paused; remaining time preserved) and the cores handed over — the
//! capability the paper's `preemption` spec flag reserves.

use crate::core::time::SimTime;
use crate::workflow::exec::{TaskTimes, WorkflowReport};
use crate::workflow::manager::WorkflowManager;
use crate::workflow::task::TaskId;
use crate::workflow::Workflow;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, BTreeMap};

/// Ready-set ordering discipline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskOrder {
    Fcfs,
    CriticalPath,
    WidestFirst,
}

impl std::str::FromStr for TaskOrder {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "fcfs" | "static" => Ok(TaskOrder::Fcfs),
            "critical-path" | "cp" | "heft" => Ok(TaskOrder::CriticalPath),
            "widest-first" | "fanout" => Ok(TaskOrder::WidestFirst),
            other => Err(format!("unknown task order {other:?}")),
        }
    }
}

/// Upward rank per task: exec + max over children of their rank.
pub fn upward_ranks(wf: &Workflow) -> BTreeMap<TaskId, f64> {
    let order = wf.dag.topo_sort().expect("workflow validated acyclic");
    let mut rank: BTreeMap<TaskId, f64> = BTreeMap::new();
    for &id in order.iter().rev() {
        let best_child = wf
            .dag
            .children(id)
            .iter()
            .map(|c| rank[c])
            .fold(0.0f64, f64::max);
        rank.insert(id, wf.tasks[&id].execution_time.as_f64() + best_child);
    }
    rank
}

/// Dynamic workflow executor with pluggable ordering and optional
/// preemption.
#[derive(Debug, Clone)]
pub struct DynamicExecutor {
    pub cpu: u64,
    pub order: TaskOrder,
    /// Enable priority preemption.
    pub preemption: bool,
    /// A ready task must beat a running task's priority by this factor
    /// to preempt it (hysteresis against thrashing).
    pub preempt_factor: f64,
}

#[derive(Debug, Clone, Copy)]
struct Running {
    id: TaskId,
    cpu: u64,
    /// Work remaining at `since`.
    remaining: u64,
    since: u64,
    priority: f64,
}

impl DynamicExecutor {
    pub fn new(cpu: u64, order: TaskOrder) -> DynamicExecutor {
        DynamicExecutor { cpu: cpu.max(1), order, preemption: false, preempt_factor: 4.0 }
    }

    pub fn with_preemption(mut self) -> DynamicExecutor {
        self.preemption = true;
        self
    }

    fn priority(&self, id: TaskId, ranks: &BTreeMap<TaskId, f64>, wf: &Workflow) -> f64 {
        match self.order {
            // FCFS = flat priority; the comparator's id tie-break gives
            // submission order, matching the static executor exactly.
            // (Priorities must stay non-negative: the multiplicative
            // preemption hysteresis is only meaningful on that scale.)
            TaskOrder::Fcfs => 0.0,
            TaskOrder::CriticalPath => ranks[&id],
            TaskOrder::WidestFirst => wf.dag.children(id).len() as f64,
        }
    }

    /// Run to completion. Preempted tasks resume with their remaining
    /// time (checkpoint model); every completion/ready event re-evaluates
    /// the schedule (the "dynamic" part).
    pub fn run(&self, workflow: Workflow) -> WorkflowReport {
        for t in workflow.tasks.values() {
            assert!(t.resources.cpu <= self.cpu, "task {} exceeds pool", t.id);
        }
        let name = workflow.name.clone();
        let ranks = upward_ranks(&workflow);
        let wf_copy = workflow.clone();
        let mut mgr = WorkflowManager::new(workflow, SimTime::ZERO);
        let mut now = 0u64;
        let mut free = self.cpu;
        let mut peak = 0u64;
        let mut events = 0u64;
        // Ready pool: (priority, ready_at, id). Ordering applied on pick.
        let mut ready: Vec<(f64, u64, TaskId)> = mgr
            .ready_tasks()
            .into_iter()
            .map(|id| (self.priority(id, &ranks, &wf_copy), 0u64, id))
            .collect();
        // Paused tasks (preempted): remaining work.
        let mut paused: BTreeMap<TaskId, u64> = BTreeMap::new();
        let mut running: Vec<Running> = Vec::new();
        // Completion heap keyed by absolute end time.
        let mut heap: BinaryHeap<Reverse<(u64, TaskId)>> = BinaryHeap::new();
        let mut done: Vec<TaskTimes> = Vec::new();
        let mut first_start: BTreeMap<TaskId, u64> = BTreeMap::new();

        loop {
            // Pick ready tasks by priority (desc), tie by id (submission
            // order — identical to the static executor under Fcfs).
            ready.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.2.cmp(&b.2)));
            let mut k = 0;
            while k < ready.len() {
                let (prio, _ready_at, id) = ready[k];
                let need = wf_copy.tasks[&id].resources.cpu;
                if need <= free {
                    // Start (or resume).
                    let remaining = paused
                        .remove(&id)
                        .unwrap_or(wf_copy.tasks[&id].execution_time.ticks());
                    if !mgr.is_ready(id) {
                        // resuming a preempted task: manager already
                        // considers it running.
                    } else {
                        mgr.mark_started(id, SimTime(now));
                    }
                    first_start.entry(id).or_insert(now);
                    free -= need;
                    running.push(Running { id, cpu: need, remaining, since: now, priority: prio });
                    heap.push(Reverse((now + remaining.max(1), id)));
                    ready.remove(k);
                    events += 1;
                } else if self.preemption {
                    // Try to preempt the lowest-priority running victim.
                    let victim = running
                        .iter()
                        .enumerate()
                        .filter(|(_, r)| r.cpu >= need)
                        .min_by(|a, b| a.1.priority.total_cmp(&b.1.priority));
                    match victim {
                        // Strict dominance on the non-negative priority
                        // scale; `prio > v.priority` guards the zero case
                        // so equal-priority tasks can never ping-pong.
                        Some((vi, v))
                            if prio > v.priority && prio > v.priority * self.preempt_factor => {
                            let v = running.remove(vi);
                            let elapsed = now - v.since;
                            let left = v.remaining.saturating_sub(elapsed).max(1);
                            paused.insert(v.id, left);
                            // Invalidate its completion (lazy: skip on pop).
                            free += v.cpu;
                            ready.push((v.priority, now, v.id));
                            events += 1;
                            // Re-sort and retry this slot.
                            ready.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.2.cmp(&b.2)));
                            continue;
                        }
                        _ => k += 1,
                    }
                } else {
                    k += 1;
                }
            }
            peak = peak.max(self.cpu - free);

            let Some(Reverse((t_end, id))) = heap.pop() else { break };
            // Lazy invalidation: completion valid only if still running
            // with a matching end time.
            let Some(pos) = running
                .iter()
                .position(|r| r.id == id && r.since + r.remaining.max(1) == t_end)
            else {
                continue; // stale (preempted)
            };
            now = t_end;
            events += 1;
            let r = running.remove(pos);
            free += r.cpu;
            let newly = mgr.mark_completed(id, SimTime(now));
            for nid in newly {
                ready.push((self.priority(nid, &ranks, &wf_copy), now, nid));
            }
            let task = &mgr.workflow().tasks[&id];
            done.push(TaskTimes {
                id,
                ready: task.ready_at.expect("completed => was ready"),
                start: SimTime(first_start[&id]),
                end: SimTime(now),
            });
        }
        assert!(mgr.all_done(), "dynamic executor deadlocked");
        done.sort_by_key(|t| t.id);
        WorkflowReport {
            name,
            makespan: SimTime(now) - SimTime::ZERO,
            tasks: done,
            peak_cpu: peak,
            events,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::generators::{epigenomics, montage, sipht};
    use crate::workflow::task::Task;
    use crate::workflow::WorkflowExecutor;

    fn chain_plus_fan() -> Workflow {
        // Critical chain 1->2->3 (100 each) plus 6 independent 10s tasks.
        let mut tasks = vec![
            Task::new(1, 100, 1, 0),
            Task::new(2, 100, 1, 0).with_deps(vec![1]),
            Task::new(3, 100, 1, 0).with_deps(vec![2]),
        ];
        for id in 10..16 {
            tasks.push(Task::new(id, 10, 1, 0));
        }
        Workflow::new(1, "chain+fan", tasks).unwrap()
    }

    #[test]
    fn upward_ranks_decrease_along_edges() {
        let w = sipht(1, 1, true);
        let ranks = upward_ranks(&w);
        for id in w.dag.nodes() {
            for &c in w.dag.children(id) {
                assert!(ranks[&id] > ranks[&c], "rank({id}) <= rank({c})");
            }
        }
    }

    #[test]
    fn critical_path_order_starts_chain_first() {
        // 1 CPU: FCFS (id order) would also pick task 1 first here, so
        // craft ids so FCFS picks a fan task first.
        let mut tasks = vec![Task::new(1, 10, 1, 0)]; // fan task, low id
        tasks.push(Task::new(2, 100, 1, 0)); // chain head
        tasks.push(Task::new(3, 100, 1, 0).with_deps(vec![2]));
        let w = Workflow::new(1, "t", tasks).unwrap();
        let cp = DynamicExecutor::new(1, TaskOrder::CriticalPath).run(w.clone());
        let fc = DynamicExecutor::new(1, TaskOrder::Fcfs).run(w);
        let start = |r: &WorkflowReport, id| r.tasks.iter().find(|t| t.id == id).unwrap().start;
        // CP runs the 200-rank chain head before the 10-rank fan task.
        assert_eq!(start(&cp, 2).ticks(), 0);
        assert_eq!(start(&fc, 1).ticks(), 0);
        // And CP's makespan is never worse.
        assert!(cp.makespan <= fc.makespan);
    }

    #[test]
    fn cp_at_least_as_good_as_fcfs_on_gallery() {
        for w in [montage(32, 1, true), sipht(2, 1, true), epigenomics(4, 4, 1, true)] {
            let cp = DynamicExecutor::new(8, TaskOrder::CriticalPath).run(w.clone());
            let fc = DynamicExecutor::new(8, TaskOrder::Fcfs).run(w.clone());
            assert!(
                cp.makespan.ticks() <= fc.makespan.ticks() + fc.makespan.ticks() / 10,
                "{}: cp {} fcfs {}",
                w.name,
                cp.makespan.ticks(),
                fc.makespan.ticks()
            );
        }
    }

    #[test]
    fn fcfs_dynamic_matches_static_executor() {
        // With FCFS ordering and no preemption, the dynamic executor is
        // semantically the static one.
        for w in [montage(16, 1, true), chain_plus_fan()] {
            let dynamic = DynamicExecutor::new(4, TaskOrder::Fcfs).run(w.clone());
            let fixed = WorkflowExecutor::new(4, u64::MAX).run(w);
            assert_eq!(dynamic.makespan, fixed.makespan);
        }
    }

    #[test]
    fn preemption_respects_dependencies_and_finishes() {
        let w = sipht(2, 1, true);
        let n = w.len();
        let rep = DynamicExecutor::new(4, TaskOrder::CriticalPath)
            .with_preemption()
            .run(w.clone());
        assert_eq!(rep.tasks.len(), n);
        let by_id: BTreeMap<_, _> = rep.tasks.iter().map(|t| (t.id, *t)).collect();
        for id in w.dag.nodes() {
            for &c in w.dag.children(id) {
                assert!(by_id[&c].start >= by_id[&id].end, "dep {id}->{c} violated");
            }
        }
    }

    #[test]
    fn preemption_helps_critical_chain_under_contention() {
        // Pool of 1: a low-priority long fan task is running when the
        // chain head becomes ready; preemption switches to the chain.
        let mut tasks = vec![
            Task::new(1, 1000, 1, 0), // long, low rank (leaf)
            Task::new(2, 5, 1, 0),    // gate for the chain
        ];
        // Chain of 5 x 100 hanging off task 2: high upward rank.
        let mut prev = 2u64;
        for id in 3..8 {
            tasks.push(Task::new(id, 100, 1, 0).with_deps(vec![prev]));
            prev = id;
        }
        let w = Workflow::new(1, "preempt", tasks).unwrap();
        let no_p = DynamicExecutor::new(1, TaskOrder::CriticalPath).run(w.clone());
        let with_p = DynamicExecutor::new(1, TaskOrder::CriticalPath)
            .with_preemption()
            .run(w);
        assert!(
            with_p.makespan <= no_p.makespan,
            "preemption made it worse: {} vs {}",
            with_p.makespan.ticks(),
            no_p.makespan.ticks()
        );
    }

    #[test]
    fn widest_first_runs_fanout_roots_early() {
        let w = montage(24, 1, true);
        let rep = DynamicExecutor::new(4, TaskOrder::WidestFirst).run(w.clone());
        assert_eq!(rep.tasks.len(), w.len());
    }
}
