//! Workflow management (paper §3): DAG task dependencies, the JSON input
//! specification (Listing 2), ready-set scheduling, and generators for
//! the Pegasus workflows the paper evaluates.
//!
//! * [`task`] — the task model (§3.1).
//! * [`dag`] — adjacency-list DAG with cycle detection / topo / critical
//!   path (§3.2).
//! * [`spec`] — the JSON input format (Listing 2) loader/writer.
//! * [`manager`] — the Workflow Management module: dependency tracking,
//!   completion triggers, ready-task detection.
//! * [`exec`] — event-driven workflow execution on a bounded resource
//!   pool (FCFS task scheduling, as in the paper).
//! * [`generators`] — Montage/Galactic-Plane, SIPHT, Epigenomics
//!   (4seq/5seq/6seq), CyberShake and LIGO-Inspiral shaped DAGs with
//!   published stage profiles (Juve et al. 2013).

pub mod dag;
pub mod dynamic;
pub mod exec;
pub mod generators;
pub mod manager;
pub mod spec;
pub mod task;

pub use dag::Dag;
pub use dynamic::{DynamicExecutor, TaskOrder};
pub use exec::{WorkflowExecutor, WorkflowReport};
pub use manager::WorkflowManager;
pub use spec::WorkflowSpec;
pub use task::{Task, TaskId, TaskResources, TaskState};

use std::collections::BTreeMap;

/// A workflow: identified task set + derived DAG (paper §3.2: `tasks`,
/// `workflow_id`, `dependencies`).
#[derive(Debug, Clone)]
pub struct Workflow {
    pub id: u64,
    pub name: String,
    pub tasks: BTreeMap<TaskId, Task>,
    pub dag: Dag,
}

impl Workflow {
    /// Build from tasks; derives the DAG from each task's dependency list.
    /// Fails on dangling dependencies or cycles.
    pub fn new(id: u64, name: &str, tasks: Vec<Task>) -> Result<Workflow, String> {
        let mut map = BTreeMap::new();
        let mut dag = Dag::new();
        for t in tasks {
            dag.ensure_node(t.id);
            if map.insert(t.id, t).is_some() {
                return Err(format!("duplicate task id in workflow {name:?}"));
            }
        }
        let ids: Vec<TaskId> = map.keys().copied().collect();
        for id in ids {
            let deps = map[&id].dependencies.clone();
            for d in deps {
                if !map.contains_key(&d) {
                    return Err(format!("task {id} depends on unknown task {d}"));
                }
                dag.add_edge(d, id);
            }
        }
        dag.validate()?;
        Ok(Workflow { id, name: name.to_string(), tasks: map, dag })
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Sum of task execution times (serial makespan).
    pub fn total_work(&self) -> f64 {
        self.tasks.values().map(|t| t.execution_time.as_f64()).sum()
    }

    /// Critical-path time (lower bound on makespan with infinite
    /// resources).
    pub fn critical_path_time(&self) -> f64 {
        self.dag
            .critical_path(|id| self.tasks[&id].execution_time.as_f64())
            .expect("workflow validated acyclic")
    }

    /// Tasks per stage label (reporting).
    pub fn stage_histogram(&self) -> BTreeMap<String, usize> {
        let mut h: BTreeMap<String, usize> = BTreeMap::new();
        for t in self.tasks.values() {
            *h.entry(t.stage.clone()).or_insert(0) += 1;
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn listing2() -> Workflow {
        Workflow::new(
            1,
            "listing2",
            vec![
                Task::new(1, 100, 2, 1024),
                Task::new(2, 150, 1, 512).with_deps(vec![1]),
                Task::new(3, 200, 1, 512).with_deps(vec![1]),
                Task::new(4, 300, 2, 1024).with_deps(vec![2, 3]),
            ],
        )
        .unwrap()
    }

    #[test]
    fn builds_dag_from_tasks() {
        let w = listing2();
        assert_eq!(w.len(), 4);
        assert_eq!(w.dag.roots(), vec![1]);
        assert_eq!(w.dag.leaves(), vec![4]);
        assert_eq!(w.total_work(), 750.0);
        assert_eq!(w.critical_path_time(), 600.0);
    }

    #[test]
    fn dangling_dependency_rejected() {
        let err = Workflow::new(1, "bad", vec![Task::new(1, 10, 1, 0).with_deps(vec![9])])
            .unwrap_err();
        assert!(err.contains("unknown task 9"));
    }

    #[test]
    fn cycle_rejected() {
        let err = Workflow::new(
            1,
            "cyc",
            vec![
                Task::new(1, 10, 1, 0).with_deps(vec![2]),
                Task::new(2, 10, 1, 0).with_deps(vec![1]),
            ],
        )
        .unwrap_err();
        assert!(err.contains("cycle"));
    }

    #[test]
    fn duplicate_ids_rejected() {
        let err =
            Workflow::new(1, "dup", vec![Task::new(1, 10, 1, 0), Task::new(1, 20, 1, 0)])
                .unwrap_err();
        assert!(err.contains("duplicate"));
    }
}
