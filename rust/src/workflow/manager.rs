//! The Workflow Management module (paper §3.2): tracks dependency
//! satisfaction, detects ready tasks, and triggers dependents when a task
//! completes — "once we detect that the state for a task is 'completed',
//! we trigger the rest of the tasks that have a dependency on it".

use crate::core::time::SimTime;
use crate::workflow::task::{TaskId, TaskState};
use crate::workflow::Workflow;
use std::collections::BTreeSet;

/// Runtime dependency tracker for one workflow.
#[derive(Debug, Clone)]
pub struct WorkflowManager {
    workflow: Workflow,
    /// Remaining unsatisfied dependency count per task (indexed by id).
    pending: std::collections::BTreeMap<TaskId, usize>,
    ready: BTreeSet<TaskId>,
    completed: BTreeSet<TaskId>,
    running: BTreeSet<TaskId>,
}

impl WorkflowManager {
    /// Wrap a validated workflow; tasks with no dependencies become ready
    /// immediately (at t=0 / workflow submission).
    pub fn new(workflow: Workflow, now: SimTime) -> WorkflowManager {
        let mut pending = std::collections::BTreeMap::new();
        let mut ready = BTreeSet::new();
        let mut wf = workflow;
        for (&id, task) in wf.tasks.iter_mut() {
            let deg = task.dependencies.len();
            pending.insert(id, deg);
            if deg == 0 {
                ready.insert(id);
                task.state = TaskState::Ready;
                task.ready_at = Some(now);
            }
        }
        WorkflowManager {
            workflow: wf,
            pending,
            ready,
            completed: BTreeSet::new(),
            running: BTreeSet::new(),
        }
    }

    pub fn workflow(&self) -> &Workflow {
        &self.workflow
    }

    /// Tasks whose dependencies are all satisfied and that have not
    /// started, in id order (FCFS task scheduling, as the paper uses).
    pub fn ready_tasks(&self) -> Vec<TaskId> {
        self.ready.iter().copied().collect()
    }

    pub fn is_ready(&self, id: TaskId) -> bool {
        self.ready.contains(&id)
    }

    /// Mark a ready task as started.
    pub fn mark_started(&mut self, id: TaskId, now: SimTime) {
        assert!(self.ready.remove(&id), "task {id} started but not ready");
        self.running.insert(id);
        let t = self.workflow.tasks.get_mut(&id).unwrap();
        t.state = TaskState::Running;
        t.start = Some(now);
    }

    /// Mark a running task completed; returns the newly ready dependents
    /// (the paper's completion trigger).
    pub fn mark_completed(&mut self, id: TaskId, now: SimTime) -> Vec<TaskId> {
        assert!(self.running.remove(&id), "task {id} completed but not running");
        self.completed.insert(id);
        {
            let t = self.workflow.tasks.get_mut(&id).unwrap();
            t.state = TaskState::Completed;
            t.end = Some(now);
        }
        let mut newly = Vec::new();
        for &child in self.workflow.dag.children(id).to_vec().iter() {
            let p = self.pending.get_mut(&child).unwrap();
            debug_assert!(*p > 0);
            *p -= 1;
            if *p == 0 {
                self.ready.insert(child);
                let t = self.workflow.tasks.get_mut(&child).unwrap();
                t.state = TaskState::Ready;
                t.ready_at = Some(now);
                newly.push(child);
            }
        }
        newly
    }

    pub fn num_completed(&self) -> usize {
        self.completed.len()
    }

    pub fn num_running(&self) -> usize {
        self.running.len()
    }

    pub fn all_done(&self) -> bool {
        self.completed.len() == self.workflow.len()
    }

    /// Invariant: a task never becomes ready before all dependencies
    /// completed, and states partition the task set.
    pub fn check_invariants(&self) -> bool {
        let counts = self.ready.len() + self.running.len() + self.completed.len();
        if counts > self.workflow.len() {
            return false;
        }
        for &id in &self.ready {
            let t = &self.workflow.tasks[&id];
            if !t.dependencies.iter().all(|d| self.completed.contains(d)) {
                return false;
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::task::Task;

    fn diamond_mgr() -> WorkflowManager {
        let w = Workflow::new(
            1,
            "d",
            vec![
                Task::new(1, 100, 2, 0),
                Task::new(2, 150, 1, 0).with_deps(vec![1]),
                Task::new(3, 200, 1, 0).with_deps(vec![1]),
                Task::new(4, 300, 2, 0).with_deps(vec![2, 3]),
            ],
        )
        .unwrap();
        WorkflowManager::new(w, SimTime(0))
    }

    #[test]
    fn roots_ready_immediately() {
        let m = diamond_mgr();
        assert_eq!(m.ready_tasks(), vec![1]);
        assert!(m.check_invariants());
    }

    #[test]
    fn completion_triggers_dependents() {
        let mut m = diamond_mgr();
        m.mark_started(1, SimTime(0));
        let newly = m.mark_completed(1, SimTime(100));
        assert_eq!(newly, vec![2, 3]);
        assert_eq!(m.ready_tasks(), vec![2, 3]);
        assert!(m.check_invariants());
    }

    #[test]
    fn join_waits_for_all_parents() {
        let mut m = diamond_mgr();
        m.mark_started(1, SimTime(0));
        m.mark_completed(1, SimTime(100));
        m.mark_started(2, SimTime(100));
        m.mark_started(3, SimTime(100));
        let newly = m.mark_completed(2, SimTime(250));
        assert!(newly.is_empty(), "task 4 must wait for 3 as well");
        let newly = m.mark_completed(3, SimTime(300));
        assert_eq!(newly, vec![4]);
        assert!(m.check_invariants());
    }

    #[test]
    fn all_done_after_full_run() {
        let mut m = diamond_mgr();
        for id in [1u64, 2, 3, 4] {
            // Run serially; deps always satisfied in this order.
            while !m.is_ready(id) {
                panic!("task {id} not ready when expected");
            }
            m.mark_started(id, SimTime(0));
            m.mark_completed(id, SimTime(1));
        }
        assert!(m.all_done());
        assert_eq!(m.num_completed(), 4);
    }

    #[test]
    #[should_panic]
    fn starting_unready_task_panics() {
        let mut m = diamond_mgr();
        m.mark_started(4, SimTime(0));
    }

    #[test]
    fn timestamps_recorded() {
        let mut m = diamond_mgr();
        m.mark_started(1, SimTime(5));
        m.mark_completed(1, SimTime(105));
        let t1 = &m.workflow().tasks[&1];
        assert_eq!(t1.start, Some(SimTime(5)));
        assert_eq!(t1.end, Some(SimTime(105)));
        let t2 = &m.workflow().tasks[&2];
        assert_eq!(t2.ready_at, Some(SimTime(105)));
    }
}
