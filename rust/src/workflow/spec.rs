//! Workflow input specification (paper §3.3, Listing 2): a JSON document
//! with `tasks`, `resources_available`, `scheduling_policy`, `preemption`.

use crate::util::json::Json;
use crate::workflow::task::Task;
use crate::workflow::Workflow;
use anyhow::{anyhow, Context, Result};

/// Parsed workflow specification.
#[derive(Debug, Clone)]
pub struct WorkflowSpec {
    pub workflow: Workflow,
    /// Resource pool the workflow runs in.
    pub cpu_available: u64,
    pub memory_available_mb: u64,
    /// "Static" (FCFS among ready tasks) is what the paper supports.
    pub scheduling_policy: String,
    pub preemption: bool,
}

impl WorkflowSpec {
    /// Parse the Listing-2 JSON text.
    pub fn parse(text: &str) -> Result<WorkflowSpec> {
        let v = Json::parse(text).context("parsing workflow spec JSON")?;
        Self::from_json(&v)
    }

    pub fn from_json(v: &Json) -> Result<WorkflowSpec> {
        let tasks_json = v
            .get("tasks")
            .and_then(|t| t.as_arr())
            .ok_or_else(|| anyhow!("workflow spec missing \"tasks\" array"))?;
        let mut tasks = Vec::with_capacity(tasks_json.len());
        for (i, tj) in tasks_json.iter().enumerate() {
            tasks.push(
                Task::from_json(tj).ok_or_else(|| anyhow!("malformed task at index {i}"))?,
            );
        }
        let workflow = Workflow::new(
            v.get_u64_or("workflow_id", 1),
            v.get_str_or("name", "workflow"),
            tasks,
        )
        .map_err(|e| anyhow!(e))?;
        let res = v.get("resources_available");
        let cpu = res.map(|r| r.get_u64_or("cpu", 1)).unwrap_or(1);
        let mem = res.map(|r| r.get_u64_or("memory", u64::MAX)).unwrap_or(u64::MAX);
        Ok(WorkflowSpec {
            workflow,
            cpu_available: cpu.max(1),
            memory_available_mb: mem,
            scheduling_policy: v.get_str_or("scheduling_policy", "Static").to_string(),
            preemption: v.get_bool_or("preemption", false),
        })
    }

    /// Load from a file path.
    pub fn load(path: &str) -> Result<WorkflowSpec> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading workflow spec {path:?}"))?;
        Self::parse(&text)
    }

    /// Serialize back to Listing-2 JSON.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("workflow_id", Json::num(self.workflow.id as f64)),
            ("name", Json::str(self.workflow.name.clone())),
            (
                "tasks",
                Json::Arr(self.workflow.tasks.values().map(|t| t.to_json()).collect()),
            ),
            (
                "resources_available",
                Json::obj(vec![
                    ("cpu", Json::num(self.cpu_available as f64)),
                    ("memory", Json::num(self.memory_available_mb as f64)),
                ]),
            ),
            ("scheduling_policy", Json::str(self.scheduling_policy.clone())),
            ("preemption", Json::Bool(self.preemption)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper Listing 2, verbatim structure.
    pub const LISTING2: &str = r#"{
        "tasks": [
            {"id": 1, "execution_time": 100, "resources": {"cpu": 2, "memory": 1024}, "dependencies": []},
            {"id": 2, "execution_time": 150, "resources": {"cpu": 1, "memory": 512}, "dependencies": [1]},
            {"id": 3, "execution_time": 200, "resources": {"cpu": 1, "memory": 512}, "dependencies": [1]},
            {"id": 4, "execution_time": 300, "resources": {"cpu": 2, "memory": 1024}, "dependencies": [2, 3]}
        ],
        "resources_available": {"cpu": 10, "memory": 8192},
        "scheduling_policy": "Static",
        "preemption": false
    }"#;

    #[test]
    fn parses_paper_listing2() {
        let spec = WorkflowSpec::parse(LISTING2).unwrap();
        assert_eq!(spec.workflow.len(), 4);
        assert_eq!(spec.cpu_available, 10);
        assert_eq!(spec.memory_available_mb, 8192);
        assert_eq!(spec.scheduling_policy, "Static");
        assert!(!spec.preemption);
        assert_eq!(spec.workflow.dag.roots(), vec![1]);
        assert_eq!(spec.workflow.tasks[&4].dependencies, vec![2, 3]);
    }

    #[test]
    fn roundtrip() {
        let spec = WorkflowSpec::parse(LISTING2).unwrap();
        let text = spec.to_json().to_pretty();
        let back = WorkflowSpec::parse(&text).unwrap();
        assert_eq!(back.workflow.len(), 4);
        assert_eq!(back.cpu_available, 10);
        assert_eq!(back.workflow.tasks[&2].execution_time.ticks(), 150);
    }

    #[test]
    fn missing_tasks_is_error() {
        assert!(WorkflowSpec::parse(r#"{"resources_available": {"cpu": 1}}"#).is_err());
    }

    #[test]
    fn malformed_task_is_error() {
        let e = WorkflowSpec::parse(r#"{"tasks": [{"id": 1}]}"#).unwrap_err();
        assert!(e.to_string().contains("malformed task"));
    }

    #[test]
    fn cyclic_spec_is_error() {
        let text = r#"{"tasks": [
            {"id": 1, "execution_time": 1, "dependencies": [2]},
            {"id": 2, "execution_time": 1, "dependencies": [1]}
        ]}"#;
        assert!(WorkflowSpec::parse(text).is_err());
    }

    #[test]
    fn defaults_for_missing_pool() {
        let spec = WorkflowSpec::parse(
            r#"{"tasks": [{"id": 1, "execution_time": 1, "dependencies": []}]}"#,
        )
        .unwrap();
        assert_eq!(spec.cpu_available, 1);
        assert_eq!(spec.scheduling_policy, "Static");
    }
}
