//! DAG machinery: adjacency-list dependency graph, cycle detection,
//! topological order, ready-set computation (paper §3.2 "DAG
//! Representation" — adjacency lists, chosen for large sparse workflows).

use crate::workflow::task::TaskId;
use std::collections::{BTreeMap, BTreeSet, VecDeque};

/// Directed acyclic dependency graph over task ids.
///
/// Edge `a -> b` means "b depends on a" (a must finish before b starts).
#[derive(Debug, Clone, Default)]
pub struct Dag {
    /// dependents (out-edges): a -> tasks unblocked by a.
    children: BTreeMap<TaskId, Vec<TaskId>>,
    /// dependency count (in-degree) per task.
    indegree: BTreeMap<TaskId, usize>,
}

impl Dag {
    pub fn new() -> Dag {
        Dag::default()
    }

    /// Build from (task, dependencies) pairs. Every mentioned id becomes a
    /// node. Duplicate edges are kept once.
    pub fn from_dependencies(deps: &[(TaskId, &[TaskId])]) -> Dag {
        let mut dag = Dag::new();
        for (t, ds) in deps {
            dag.ensure_node(*t);
            for d in ds.iter() {
                dag.add_edge(*d, *t);
            }
        }
        dag
    }

    pub fn ensure_node(&mut self, id: TaskId) {
        self.children.entry(id).or_default();
        self.indegree.entry(id).or_insert(0);
    }

    /// Add dependency edge `before -> after`; ignores exact duplicates.
    pub fn add_edge(&mut self, before: TaskId, after: TaskId) {
        self.ensure_node(before);
        self.ensure_node(after);
        let kids = self.children.get_mut(&before).unwrap();
        if kids.contains(&after) {
            return;
        }
        kids.push(after);
        *self.indegree.get_mut(&after).unwrap() += 1;
    }

    pub fn num_nodes(&self) -> usize {
        self.children.len()
    }

    pub fn num_edges(&self) -> usize {
        self.children.values().map(|v| v.len()).sum()
    }

    /// Tasks unblocked by `id`.
    pub fn children(&self, id: TaskId) -> &[TaskId] {
        self.children.get(&id).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Number of direct dependencies of `id`.
    pub fn indegree(&self, id: TaskId) -> usize {
        self.indegree.get(&id).copied().unwrap_or(0)
    }

    /// Entry tasks (no dependencies), in id order.
    pub fn roots(&self) -> Vec<TaskId> {
        self.indegree.iter().filter(|(_, &d)| d == 0).map(|(&id, _)| id).collect()
    }

    /// Exit tasks (nothing depends on them), in id order.
    pub fn leaves(&self) -> Vec<TaskId> {
        self.children.iter().filter(|(_, v)| v.is_empty()).map(|(&id, _)| id).collect()
    }

    /// Kahn topological sort; `None` if the graph has a cycle.
    pub fn topo_sort(&self) -> Option<Vec<TaskId>> {
        let mut indeg = self.indegree.clone();
        let mut q: VecDeque<TaskId> =
            indeg.iter().filter(|(_, &d)| d == 0).map(|(&id, _)| id).collect();
        let mut order = Vec::with_capacity(self.num_nodes());
        while let Some(id) = q.pop_front() {
            order.push(id);
            for &c in self.children(id) {
                let d = indeg.get_mut(&c).unwrap();
                *d -= 1;
                if *d == 0 {
                    q.push_back(c);
                }
            }
        }
        if order.len() == self.num_nodes() {
            Some(order)
        } else {
            None
        }
    }

    pub fn is_acyclic(&self) -> bool {
        self.topo_sort().is_some()
    }

    /// Longest path length in edges (the DAG's depth = critical-path hop
    /// count); `None` on cycles.
    pub fn depth(&self) -> Option<usize> {
        let order = self.topo_sort()?;
        let mut dist: BTreeMap<TaskId, usize> = BTreeMap::new();
        let mut max = 0;
        for id in order {
            let d = *dist.get(&id).unwrap_or(&0);
            for &c in self.children(id) {
                let e = dist.entry(c).or_insert(0);
                *e = (*e).max(d + 1);
                max = max.max(*e);
            }
        }
        Some(max)
    }

    /// Critical path weight with per-task costs; `None` on cycles.
    pub fn critical_path(&self, cost: impl Fn(TaskId) -> f64) -> Option<f64> {
        let order = self.topo_sort()?;
        let mut finish: BTreeMap<TaskId, f64> = BTreeMap::new();
        let mut best = 0.0f64;
        for id in order {
            let start = self
                .parents_of(id)
                .iter()
                .map(|p| *finish.get(p).unwrap_or(&0.0))
                .fold(0.0f64, f64::max);
            let f = start + cost(id);
            best = best.max(f);
            finish.insert(id, f);
        }
        Some(best)
    }

    /// Direct dependencies of `id` (computed; adjacency stores children).
    pub fn parents_of(&self, id: TaskId) -> Vec<TaskId> {
        self.children
            .iter()
            .filter(|(_, kids)| kids.contains(&id))
            .map(|(&p, _)| p)
            .collect()
    }

    /// All ids.
    pub fn nodes(&self) -> impl Iterator<Item = TaskId> + '_ {
        self.children.keys().copied()
    }

    /// Validate that every dependency of every node exists (no dangling
    /// ids can occur by construction) and the graph is acyclic; returns a
    /// human-readable error otherwise.
    pub fn validate(&self) -> Result<(), String> {
        if !self.is_acyclic() {
            // Identify one offending node set for the message.
            let in_topo: BTreeSet<TaskId> = self.topo_sort().unwrap_or_default().into_iter().collect();
            let stuck: Vec<TaskId> = self.nodes().filter(|n| !in_topo.contains(n)).collect();
            return Err(format!("dependency cycle involving tasks {stuck:?}"));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's Listing-2 example: 1 -> {2,3} -> 4.
    fn diamond() -> Dag {
        Dag::from_dependencies(&[(1, &[]), (2, &[1]), (3, &[1]), (4, &[2, 3])])
    }

    #[test]
    fn diamond_structure() {
        let d = diamond();
        assert_eq!(d.num_nodes(), 4);
        assert_eq!(d.num_edges(), 4);
        assert_eq!(d.roots(), vec![1]);
        assert_eq!(d.leaves(), vec![4]);
        assert_eq!(d.indegree(4), 2);
        assert_eq!(d.children(1), &[2, 3]);
        assert_eq!(d.parents_of(4), vec![2, 3]);
    }

    #[test]
    fn topo_respects_dependencies() {
        let d = diamond();
        let order = d.topo_sort().unwrap();
        let pos = |id| order.iter().position(|&x| x == id).unwrap();
        assert!(pos(1) < pos(2));
        assert!(pos(1) < pos(3));
        assert!(pos(2) < pos(4));
        assert!(pos(3) < pos(4));
    }

    #[test]
    fn cycle_detected() {
        let mut d = diamond();
        d.add_edge(4, 1);
        assert!(!d.is_acyclic());
        assert!(d.topo_sort().is_none());
        let err = d.validate().unwrap_err();
        assert!(err.contains("cycle"));
    }

    #[test]
    fn self_loop_is_cycle() {
        let mut d = Dag::new();
        d.add_edge(1, 1);
        assert!(!d.is_acyclic());
    }

    #[test]
    fn duplicate_edges_ignored() {
        let mut d = Dag::new();
        d.add_edge(1, 2);
        d.add_edge(1, 2);
        assert_eq!(d.num_edges(), 1);
        assert_eq!(d.indegree(2), 1);
    }

    #[test]
    fn depth_and_critical_path() {
        let d = diamond();
        assert_eq!(d.depth(), Some(2));
        // Costs: 1=100, 2=150, 3=200, 4=300 (paper Listing 2).
        let costs = |id: TaskId| match id {
            1 => 100.0,
            2 => 150.0,
            3 => 200.0,
            4 => 300.0,
            _ => 0.0,
        };
        // Critical path 1 -> 3 -> 4 = 600.
        assert_eq!(d.critical_path(costs), Some(600.0));
    }

    #[test]
    fn empty_dag() {
        let d = Dag::new();
        assert_eq!(d.topo_sort(), Some(vec![]));
        assert_eq!(d.depth(), Some(0));
        assert!(d.roots().is_empty());
    }

    #[test]
    fn disconnected_components() {
        let d = Dag::from_dependencies(&[(1, &[]), (2, &[1]), (10, &[]), (11, &[10])]);
        assert_eq!(d.roots(), vec![1, 10]);
        assert_eq!(d.topo_sort().unwrap().len(), 4);
    }
}
