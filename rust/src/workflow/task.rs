//! Workflow tasks (paper §3.1).
//!
//! A task is the unit of a workflow: execution time, resource
//! requirements, dependency list, and lifecycle state. Mirrors the
//! attributes the paper calls out: `task_id`, `execution_time`,
//! `resource_requirements`, `dependencies`, `state`.

use crate::core::time::{SimDuration, SimTime};
use crate::util::json::Json;

/// Unique task identifier within a workflow.
pub type TaskId = u64;

/// Task lifecycle (paper §3.1 "state").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskState {
    /// Dependencies not yet satisfied.
    Waiting,
    /// Dependencies satisfied, queued for resources.
    Ready,
    /// Executing.
    Running,
    /// Finished.
    Completed,
}

/// Resource requirements of a task (paper: CPU cycles, memory, I/O).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TaskResources {
    pub cpu: u64,
    pub memory_mb: u64,
}

/// One computational job within a workflow.
#[derive(Debug, Clone)]
pub struct Task {
    pub id: TaskId,
    /// Estimated execution time (from computational complexity or
    /// historical data — for generated Pegasus-like workflows this is the
    /// published per-stage profile).
    pub execution_time: SimDuration,
    pub resources: TaskResources,
    /// Task ids that must complete before this task starts.
    pub dependencies: Vec<TaskId>,
    pub state: TaskState,
    /// Stage label (e.g. "mProject", "blast") for reporting.
    pub stage: String,
    /// Set when the task becomes ready / starts / ends.
    pub ready_at: Option<SimTime>,
    pub start: Option<SimTime>,
    pub end: Option<SimTime>,
}

impl Task {
    pub fn new(id: TaskId, execution_time: u64, cpu: u64, memory_mb: u64) -> Task {
        Task {
            id,
            execution_time: SimDuration(execution_time),
            resources: TaskResources { cpu, memory_mb },
            dependencies: Vec::new(),
            state: TaskState::Waiting,
            stage: String::new(),
            ready_at: None,
            start: None,
            end: None,
        }
    }

    pub fn with_deps(mut self, deps: Vec<TaskId>) -> Task {
        self.dependencies = deps;
        self
    }

    pub fn with_stage(mut self, stage: &str) -> Task {
        self.stage = stage.to_string();
        self
    }

    /// Wait between becoming ready and starting (paper Fig 7 metric).
    pub fn wait_time(&self) -> Option<SimDuration> {
        match (self.ready_at, self.start) {
            (Some(r), Some(s)) => Some(s - r),
            _ => None,
        }
    }

    /// Listing-2 JSON form.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::num(self.id as f64)),
            ("execution_time", Json::num(self.execution_time.ticks() as f64)),
            (
                "resources",
                Json::obj(vec![
                    ("cpu", Json::num(self.resources.cpu as f64)),
                    ("memory", Json::num(self.resources.memory_mb as f64)),
                ]),
            ),
            (
                "dependencies",
                Json::Arr(self.dependencies.iter().map(|d| Json::num(*d as f64)).collect()),
            ),
            ("stage", Json::str(self.stage.clone())),
        ])
    }

    /// Parse the Listing-2 JSON form.
    pub fn from_json(v: &Json) -> Option<Task> {
        let id = v.get("id")?.as_u64()?;
        let exec = v.get("execution_time")?.as_u64()?;
        let res = v.get("resources");
        let cpu = res.map(|r| r.get_u64_or("cpu", 1)).unwrap_or(1);
        let mem = res.map(|r| r.get_u64_or("memory", 0)).unwrap_or(0);
        let deps = v
            .get("dependencies")
            .and_then(|d| d.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_u64()).collect())
            .unwrap_or_default();
        let mut t = Task::new(id, exec, cpu.max(1), mem).with_deps(deps);
        t.stage = v.get_str_or("stage", "").to_string();
        Some(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let t = Task::new(4, 300, 2, 1024).with_deps(vec![2, 3]).with_stage("mAdd");
        let back = Task::from_json(&Json::parse(&t.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.id, 4);
        assert_eq!(back.execution_time, SimDuration(300));
        assert_eq!(back.resources, TaskResources { cpu: 2, memory_mb: 1024 });
        assert_eq!(back.dependencies, vec![2, 3]);
        assert_eq!(back.stage, "mAdd");
    }

    #[test]
    fn paper_listing2_task_parses() {
        let v = Json::parse(
            r#"{"id": 2, "execution_time": 150, "resources": {"cpu": 1, "memory": 512}, "dependencies": [1]}"#,
        )
        .unwrap();
        let t = Task::from_json(&v).unwrap();
        assert_eq!(t.id, 2);
        assert_eq!(t.resources.cpu, 1);
        assert_eq!(t.dependencies, vec![1]);
    }

    #[test]
    fn missing_resources_default() {
        let t = Task::from_json(&Json::parse(r#"{"id": 1, "execution_time": 5}"#).unwrap())
            .unwrap();
        assert_eq!(t.resources.cpu, 1);
        assert_eq!(t.resources.memory_mb, 0);
        assert!(t.dependencies.is_empty());
    }

    #[test]
    fn wait_time_requires_both_stamps() {
        let mut t = Task::new(1, 10, 1, 0);
        assert_eq!(t.wait_time(), None);
        t.ready_at = Some(SimTime(5));
        t.start = Some(SimTime(12));
        assert_eq!(t.wait_time(), Some(SimDuration(7)));
    }
}
