//! In-repo substrates that would normally come from crates: JSON
//! (parser/writer), a micro-benchmark harness, and a tiny property-testing
//! helper. The offline vendored crate set only covers the `xla` bridge, so
//! these are first-class, tested modules rather than dependencies.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod table;

pub use json::{Json, JsonError};
