//! Micro-benchmark harness used by `rust/benches/*` (criterion is not in
//! the offline crate set; this provides the part of it we need: warmup,
//! repeated timed runs, and robust summary statistics).

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub runs: Vec<Duration>,
}

impl BenchResult {
    pub fn min(&self) -> Duration {
        self.runs.iter().copied().min().unwrap_or_default()
    }

    pub fn median(&self) -> Duration {
        let mut r = self.runs.clone();
        r.sort();
        r[r.len() / 2]
    }

    pub fn mean(&self) -> Duration {
        let total: Duration = self.runs.iter().sum();
        total / self.runs.len().max(1) as u32
    }

    /// Pretty line, e.g. `fig5/ranks=4   median 12.3ms  min 11.9ms  (5 runs)`.
    pub fn line(&self) -> String {
        format!(
            "{:<40} median {:>10}  min {:>10}  ({} runs)",
            self.name,
            fmt_dur(self.median()),
            fmt_dur(self.min()),
            self.runs.len()
        )
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.1}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.1}ms", ns as f64 / 1e6)
    } else {
        format!("{:.2}s", ns as f64 / 1e9)
    }
}

/// Benchmark runner: warms up, then times `runs` executions.
pub struct Bench {
    warmup: usize,
    runs: usize,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench::new(1, 5)
    }
}

impl Bench {
    pub fn new(warmup: usize, runs: usize) -> Bench {
        Bench { warmup, runs, results: Vec::new() }
    }

    /// Time `f`; a `std::hint::black_box`-style sink is applied to the
    /// closure's return value so the work is not optimized away.
    pub fn case<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut runs = Vec::with_capacity(self.runs);
        for _ in 0..self.runs {
            let t0 = Instant::now();
            std::hint::black_box(f());
            runs.push(t0.elapsed());
        }
        let r = BenchResult { name: name.to_string(), runs };
        println!("{}", r.line());
        self.results.push(r);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Print a section header in bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let mut b = Bench::new(0, 3);
        let mut count = 0u64;
        b.case("noop", || {
            count += 1;
            count
        });
        assert_eq!(count, 3);
        assert_eq!(b.results().len(), 1);
        assert_eq!(b.results()[0].runs.len(), 3);
    }

    #[test]
    fn warmup_not_counted() {
        let mut b = Bench::new(2, 1);
        let mut count = 0u64;
        b.case("noop", || {
            count += 1;
        });
        assert_eq!(count, 3); // 2 warmup + 1 timed
        assert_eq!(b.results()[0].runs.len(), 1);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500ns");
        assert_eq!(fmt_dur(Duration::from_micros(1500)), "1.5ms");
        assert_eq!(fmt_dur(Duration::from_secs(2)), "2.00s");
    }

    #[test]
    fn median_and_min() {
        let r = BenchResult {
            name: "x".into(),
            runs: vec![
                Duration::from_millis(3),
                Duration::from_millis(1),
                Duration::from_millis(2),
            ],
        };
        assert_eq!(r.min(), Duration::from_millis(1));
        assert_eq!(r.median(), Duration::from_millis(2));
    }
}
