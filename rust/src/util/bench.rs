//! Micro-benchmark harness used by `rust/benches/*` (criterion is not in
//! the offline crate set; this provides the part of it we need: warmup,
//! repeated timed runs, robust summary statistics, and a
//! machine-readable JSON dump — the `BENCH_engine.json` schema the CI
//! perf trajectory consumes).

use crate::util::json::Json;
use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub runs: Vec<Duration>,
}

impl BenchResult {
    pub fn min(&self) -> Duration {
        self.runs.iter().copied().min().unwrap_or_default()
    }

    /// Median run time; `Duration::ZERO` on an empty result set (like
    /// every other statistic here — an unguarded index panicked once).
    pub fn median(&self) -> Duration {
        if self.runs.is_empty() {
            return Duration::ZERO;
        }
        let mut r = self.runs.clone();
        r.sort();
        r[r.len() / 2]
    }

    pub fn mean(&self) -> Duration {
        let total: Duration = self.runs.iter().sum();
        total / self.runs.len().max(1) as u32
    }

    /// Nearest-rank percentile (`pct` in 0..=100); `Duration::ZERO` on
    /// an empty result set.
    fn percentile(&self, pct: usize) -> Duration {
        if self.runs.is_empty() {
            return Duration::ZERO;
        }
        let mut r = self.runs.clone();
        r.sort();
        r[(r.len() - 1) * pct / 100]
    }

    pub fn p10(&self) -> Duration {
        self.percentile(10)
    }

    pub fn p90(&self) -> Duration {
        self.percentile(90)
    }

    /// Machine-readable summary of this case. Schema: `name`, `runs`
    /// (count), and `median_ns`/`mean_ns`/`min_ns`/`p10_ns`/`p90_ns`
    /// in nanoseconds.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("runs", Json::num(self.runs.len() as f64)),
            ("median_ns", Json::num(self.median().as_nanos() as f64)),
            ("mean_ns", Json::num(self.mean().as_nanos() as f64)),
            ("min_ns", Json::num(self.min().as_nanos() as f64)),
            ("p10_ns", Json::num(self.p10().as_nanos() as f64)),
            ("p90_ns", Json::num(self.p90().as_nanos() as f64)),
        ])
    }

    /// Pretty line, e.g. `fig5/ranks=4   median 12.3ms  min 11.9ms  (5 runs)`.
    pub fn line(&self) -> String {
        format!(
            "{:<40} median {:>10}  min {:>10}  ({} runs)",
            self.name,
            fmt_dur(self.median()),
            fmt_dur(self.min()),
            self.runs.len()
        )
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.1}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.1}ms", ns as f64 / 1e6)
    } else {
        format!("{:.2}s", ns as f64 / 1e9)
    }
}

/// Benchmark runner: warms up, then times `runs` executions.
pub struct Bench {
    warmup: usize,
    runs: usize,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench::new(1, 5)
    }
}

impl Bench {
    pub fn new(warmup: usize, runs: usize) -> Bench {
        Bench { warmup, runs, results: Vec::new() }
    }

    /// Time `f`; a `std::hint::black_box`-style sink is applied to the
    /// closure's return value so the work is not optimized away.
    pub fn case<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut runs = Vec::with_capacity(self.runs);
        for _ in 0..self.runs {
            let t0 = Instant::now();
            std::hint::black_box(f());
            runs.push(t0.elapsed());
        }
        let r = BenchResult { name: name.to_string(), runs };
        println!("{}", r.line());
        self.results.push(r);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Machine-readable dump of every case — the `BENCH_engine.json`
    /// schema (`sst-sched bench` writes it, the CI perf gate and the
    /// perf trajectory consume it).
    pub fn to_json(&self, suite: &str, smoke: bool) -> Json {
        Json::obj(vec![
            ("schema", Json::str("sst-sched-bench-v1")),
            ("suite", Json::str(suite)),
            ("smoke", Json::Bool(smoke)),
            ("cases", Json::Arr(self.results.iter().map(|r| r.to_json()).collect())),
        ])
    }
}

/// Print a section header in bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let mut b = Bench::new(0, 3);
        let mut count = 0u64;
        b.case("noop", || {
            count += 1;
            count
        });
        assert_eq!(count, 3);
        assert_eq!(b.results().len(), 1);
        assert_eq!(b.results()[0].runs.len(), 3);
    }

    #[test]
    fn warmup_not_counted() {
        let mut b = Bench::new(2, 1);
        let mut count = 0u64;
        b.case("noop", || {
            count += 1;
        });
        assert_eq!(count, 3); // 2 warmup + 1 timed
        assert_eq!(b.results()[0].runs.len(), 1);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500ns");
        assert_eq!(fmt_dur(Duration::from_micros(1500)), "1.5ms");
        assert_eq!(fmt_dur(Duration::from_secs(2)), "2.00s");
    }

    #[test]
    fn median_and_min() {
        let r = BenchResult {
            name: "x".into(),
            runs: vec![
                Duration::from_millis(3),
                Duration::from_millis(1),
                Duration::from_millis(2),
            ],
        };
        assert_eq!(r.min(), Duration::from_millis(1));
        assert_eq!(r.median(), Duration::from_millis(2));
    }

    #[test]
    fn empty_result_set_reports_zero_everywhere() {
        // `median` indexed r[len/2] unguarded and panicked on an empty
        // result set; every statistic must degrade to zero instead.
        let r = BenchResult { name: "empty".into(), runs: Vec::new() };
        assert_eq!(r.median(), Duration::ZERO);
        assert_eq!(r.mean(), Duration::ZERO);
        assert_eq!(r.min(), Duration::ZERO);
        assert_eq!(r.p10(), Duration::ZERO);
        assert_eq!(r.p90(), Duration::ZERO);
        assert!(r.line().contains("0 runs"));
    }

    #[test]
    fn percentiles_order_and_json_schema() {
        let r = BenchResult {
            name: "x".into(),
            runs: (1..=10u64).map(Duration::from_millis).collect(),
        };
        assert_eq!(r.p10(), Duration::from_millis(1));
        assert_eq!(r.p90(), Duration::from_millis(9));
        assert!(r.p10() <= r.median() && r.median() <= r.p90());
        let j = r.to_json();
        assert_eq!(j.get("name").and_then(|v| v.as_str()), Some("x"));
        assert_eq!(j.get("runs").and_then(|v| v.as_u64()), Some(10));
        for key in ["median_ns", "mean_ns", "min_ns", "p10_ns", "p90_ns"] {
            assert!(j.get(key).and_then(|v| v.as_f64()).unwrap() > 0.0, "missing {key}");
        }
    }

    #[test]
    fn suite_json_wraps_cases() {
        let mut b = Bench::new(0, 2);
        b.case("a", || 1u64);
        b.case("b", || 2u64);
        let j = b.to_json("engine_throughput", true);
        assert_eq!(j.get("schema").and_then(|v| v.as_str()), Some("sst-sched-bench-v1"));
        assert_eq!(j.get("suite").and_then(|v| v.as_str()), Some("engine_throughput"));
        assert_eq!(j.get("smoke").and_then(|v| v.as_bool()), Some(true));
        assert_eq!(j.get("cases").and_then(|v| v.as_arr()).unwrap().len(), 2);
    }
}
