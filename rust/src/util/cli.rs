//! Minimal command-line parsing (clap is not in the offline crate set).
//!
//! Supports `--key value`, `--key=value`, bare `--flag`, and positional
//! arguments, with typed getters and an unknown-option check so typos
//! fail loudly instead of being ignored.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Parsed arguments: positionals + options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    /// Options the program has asked about (for unknown-option check).
    seen: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args> {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if rest.is_empty() {
                    bail!("bare `--` is not supported");
                }
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.options.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    fn mark(&self, key: &str) {
        self.seen.borrow_mut().insert(key.to_string());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        Ok(self.u64_or(key, default as u64)? as usize)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{key} expects a number, got {v:?}")),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        self.get(key).map(|v| v != "false").unwrap_or(false)
    }

    /// Error if any provided option was never consulted (likely a typo).
    pub fn reject_unknown(&self) -> Result<()> {
        let seen = self.seen.borrow();
        let unknown: Vec<&String> =
            self.options.keys().filter(|k| !seen.contains(*k)).collect();
        if !unknown.is_empty() {
            bail!("unknown option(s): {unknown:?}");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["fig", "3a", "--jobs", "500", "--policy=sjf", "--quiet"]);
        assert_eq!(a.positional, vec!["fig", "3a"]);
        assert_eq!(a.u64_or("jobs", 0).unwrap(), 500);
        assert_eq!(a.str_or("policy", "fcfs"), "sjf");
        assert!(a.flag("quiet"));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.u64_or("jobs", 7).unwrap(), 7);
        assert_eq!(a.f64_or("scale", 1.5).unwrap(), 1.5);
    }

    #[test]
    fn bad_number_errors() {
        let a = parse(&["--jobs", "many"]);
        assert!(a.u64_or("jobs", 0).is_err());
    }

    #[test]
    fn unknown_option_check() {
        let a = parse(&["--jobs", "5", "--polcy", "sjf"]);
        let _ = a.u64_or("jobs", 0);
        let err = a.reject_unknown().unwrap_err().to_string();
        assert!(err.contains("polcy"), "{err}");
        let _ = a.get("polcy");
        assert!(a.reject_unknown().is_ok());
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = parse(&["--offset", "-5"]);
        // "-5" doesn't start with --, so it's a value.
        assert_eq!(a.str_or("offset", ""), "-5");
    }
}
