//! Tiny property-testing helper (proptest is not in the offline crate
//! set). Drives a closure with many seeded random cases; on failure it
//! reports the seed so the case can be replayed deterministically.
//!
//! Used by the invariant suites in rust/tests/prop_*.rs.

use crate::core::rng::Rng;

/// Default number of cases per property.
pub const DEFAULT_CASES: usize = 256;

/// Run `prop` for `cases` seeded inputs. The closure receives a fresh
/// deterministic [`Rng`] per case and returns `Err(msg)` to fail.
/// Panics with the failing seed on the first failure.
pub fn check_n(name: &str, cases: usize, mut prop: impl FnMut(&mut Rng) -> Result<(), String>) {
    // Base seed fixed for reproducibility; vary per case.
    for case in 0..cases {
        let seed = 0x5EED_0000u64 ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property {name:?} failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// [`check_n`] with the default case count.
pub fn check(name: &str, prop: impl FnMut(&mut Rng) -> Result<(), String>) {
    check_n(name, DEFAULT_CASES, prop);
}

/// Replay a single seed (paste from a failure message while debugging).
pub fn replay(seed: u64, mut prop: impl FnMut(&mut Rng) -> Result<(), String>) {
    let mut rng = Rng::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("replay of seed {seed:#x} failed: {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check_n("count", 10, |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 10);
    }

    #[test]
    fn failing_property_panics_with_seed() {
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            check_n("fail", 10, |rng| {
                let _ = rng.next_u64();
                Err("boom".into())
            })
        }));
        let msg = *r.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("seed"), "{msg}");
        assert!(msg.contains("boom"));
    }

    #[test]
    fn cases_get_distinct_randomness() {
        let mut firsts = std::collections::HashSet::new();
        check_n("distinct", 20, |rng| {
            firsts.insert(rng.next_u64());
            Ok(())
        });
        assert_eq!(firsts.len(), 20);
    }
}
