//! Aligned-column text tables for harness output (the rows/series the
//! paper's figures plot, printed the way a paper table reads).

/// A simple text table with a header row and aligned columns.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        debug_assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_display(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate().take(cols) {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, cell) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<width$}", cell, width = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (cols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with sensible precision for tables.
pub fn f(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() >= 1000.0 {
        format!("{x:.0}")
    } else if x.abs() >= 10.0 {
        format!("{x:.1}")
    } else {
        format!("{x:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["policy", "wait"]);
        t.row(&["fcfs".into(), "123.4".into()]);
        t.row(&["fcfs-backfill".into(), "56.7".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("policy"));
        assert!(lines[2].starts_with("fcfs "));
        // Columns align: "wait" starts at the same offset in all rows.
        let off = lines[0].find("wait").unwrap();
        assert_eq!(&lines[2][off..off + 5], "123.4");
    }

    #[test]
    fn float_formatting() {
        assert_eq!(f(0.0), "0");
        assert_eq!(f(1234.6), "1235");
        assert_eq!(f(12.34), "12.3");
        assert_eq!(f(1.2345), "1.234");
    }
}
