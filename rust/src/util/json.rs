//! Minimal JSON: value type, recursive-descent parser, writer.
//!
//! The build is fully offline (no serde facade in the vendored crate set),
//! and the simulator needs JSON in three places: the workflow input spec
//! (paper Listing 2), the config system, and TaskEvent serialization. This
//! is a straightforward, well-tested implementation of just that — RFC 8259
//! minus the exotica we never produce (we parse \uXXXX escapes, emit UTF-8
//! directly).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use BTreeMap so output is deterministically
/// ordered (stable diffs, reproducible artifacts).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors ----

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ---- accessors ----

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= u64::MAX as f64 => {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` for missing keys on objects,
    /// `None` for non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// `get` chained with a default.
    pub fn get_u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.as_u64()).unwrap_or(default)
    }

    pub fn get_f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn get_str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default)
    }

    pub fn get_bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    // ---- parsing ----

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after value"));
        }
        Ok(v)
    }

    // ---- writing ----

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.fract() == 0.0 && x.abs() < 1e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        out.push_str(&format!("{x}"));
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character {:?}", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| self.err("truncated \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.pos;
                    let rest = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError { offset: start, message: format!("bad number {text:?}") })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_paper_listing2() {
        // The paper's workflow input format (Listing 2) must parse.
        let text = r#"{
            "tasks": [
                {"id": 1, "execution_time": 100, "resources": {"cpu": 2, "memory": 1024}, "dependencies": []},
                {"id": 2, "execution_time": 150, "resources": {"cpu": 1, "memory": 512}, "dependencies": [1]}
            ],
            "resources_available": {"cpu": 10, "memory": 8192},
            "scheduling_policy": "Static",
            "preemption": false
        }"#;
        let v = Json::parse(text).unwrap();
        let tasks = v.get("tasks").unwrap().as_arr().unwrap();
        assert_eq!(tasks.len(), 2);
        assert_eq!(tasks[1].get_u64_or("id", 0), 2);
        assert_eq!(tasks[1].get("dependencies").unwrap().as_arr().unwrap()[0].as_u64(), Some(1));
        assert!(!v.get_bool_or("preemption", true));
    }

    #[test]
    fn escapes_roundtrip() {
        let s = "line1\nline2\t\"quoted\" \\ slash ünïcode";
        let v = Json::Str(s.to_string());
        let text = v.to_string();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn unicode_escape_parses() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let v = Json::obj(vec![
            ("n", Json::num(1.5)),
            ("arr", Json::Arr(vec![Json::num(1), Json::Null])),
            ("s", Json::str("x")),
            ("b", Json::Bool(true)),
            ("o", Json::obj(vec![("k", Json::num(2))])),
        ]);
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        assert_eq!(Json::parse(&v.to_pretty()).unwrap(), v);
    }

    #[test]
    fn integers_written_without_fraction() {
        assert_eq!(Json::num(5).to_string(), "5");
        assert_eq!(Json::num(5.25).to_string(), "5.25");
    }

    #[test]
    fn errors_carry_offset() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert_eq!(e.offset, 6);
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn as_u64_rejects_negatives_and_fractions() {
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(1.5).as_u64(), None);
        assert_eq!(Json::Num(7.0).as_u64(), Some(7));
    }

    #[test]
    fn getters_with_defaults() {
        let v = Json::parse(r#"{"x": 3}"#).unwrap();
        assert_eq!(v.get_u64_or("x", 9), 3);
        assert_eq!(v.get_u64_or("y", 9), 9);
        assert_eq!(v.get_str_or("s", "d"), "d");
        assert_eq!(v.get_f64_or("x", 0.0), 3.0);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::Arr(vec![]).to_string(), "[]");
        assert_eq!(Json::Obj(BTreeMap::new()).to_pretty(), "{}\n");
    }
}
