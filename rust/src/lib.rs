//! # sst-sched
//!
//! Scalable HPC job scheduling and resource management on a conservative
//! parallel discrete-event core — a from-scratch reproduction of
//! *"Scalable HPC Job Scheduling and Resource Management in SST"*
//! (Abdurahman et al., WSC 2024).
//!
//! The crate is layered like the paper's system:
//!
//! * [`core`] — payload-generic discrete-event engine (SST-Core
//!   analogue): deterministic event queue, components, latency links,
//!   statistics, reproducible RNG. The queue
//!   ([`core::event::EventQueue`]) is a **ladder queue** (tiered
//!   calendar structure, amortized O(1) push/pop) rather than a binary
//!   heap: a sorted *bottom* rung for the near future drained by
//!   `Vec::pop` and filled by one batched unstable sort per bucket;
//!   bucketed upper *rungs* for the far future that nest — an
//!   oversized bucket spawns a child rung subdividing exactly its time
//!   range; and an unsorted *top* tail beyond the outermost rung.
//!   **Determinism contract**: every event key `(time, priority, seq)`
//!   is unique, so the total order is strict and the ladder's pop
//!   sequence is byte-identical to the heap it replaced — same-key
//!   FIFO included (`rust/tests/prop_queue.rs` pins it against a heap
//!   oracle; the golden fault+reservation fingerprint pins the engine
//!   end to end). **Degeneration**: small batches and single-timestamp
//!   storms skip the rung machinery and sort straight into the bottom —
//!   plain sorted-vec behavior, which is also the whole story for tiny
//!   simulations. The engine tick loop (`core::engine`) and both
//!   parallel rank drivers ride the prepared bottom: window pops
//!   (`pop_before`/`pop_at_or_before`) are one cached time compare, no
//!   sift, no tuple re-comparison, and `parallel::workflow_rank` shares
//!   the same queue type instead of a private heap.
//! * [`job`], [`resources`], [`sched`] — the job-scheduling component:
//!   job lifecycle, per-node core/memory accounting (paper Algorithm 1),
//!   and the scheduling algorithms. Since the multi-resource/ordering
//!   redesign a policy is two orthogonal choices: a *planner*
//!   (`sched::BlockingScheduler` for FCFS/SJF/LJF/BestFit, EASY
//!   backfill, conservative backfill) and a *queue ordering*
//!   ([`sched::QueueOrder`], `sched::order`: arrival, shortest,
//!   longest, usage-decayed fair share keyed on `Job::user`/`group`
//!   with a configurable half-life). `--order fair-share` composes
//!   with every planner.
//! * **planning layer** ([`resources::profile::AvailabilityProfile`],
//!   [`resources::ResourceVector`]) — the unified availability
//!   timeline, generalized to multi-resource demands: one incremental
//!   free-capacity step function *per active dimension* (cores always;
//!   memory lazily materialized, so cores-only workloads pay nothing),
//!   sharing one signed breakpoint algebra with binary-searched
//!   O(log n + k) slot queries (`earliest_slot_v`/`can_place_v`).
//!   Writers: the simulation core only — `sim::SchedulerComponent`
//!   subtracts a vector hold at every job start, releases the remainder
//!   at completion/eviction, feeds reservation windows and
//!   failure/repair capacity transitions in, and resyncs both
//!   dimensions from authoritative cluster state on the rare capacity
//!   events. Readers: every policy, through `sched::SchedInput::
//!   profile` — *all* head admission routes through one `can_place_v`
//!   query (so even the blocking disciplines refuse to start into a
//!   future reservation or outage window; on monotone timelines the
//!   check is elided and decisions are bit-identical to the scalar
//!   planner), EASY derives its shadow time/extra cores from it, and
//!   conservative backfilling clones it into a per-round scratch plan.
//!   Policies never mutate the shared timeline. The `planning.horizon`
//!   config knob bounds timeline fidelity; 0 (default) is exact;
//!   `--memory-aware` (with `mem_per_node > 0`) turns on the memory
//!   dimension.
//! * fault/preemption/reservation subsystem (beyond the paper; AccaSim-
//!   and Reuther-et-al-style scenario diversity): node lifecycle states
//!   (`Up`/`Draining`/`Down`/`Reserved`) with seeded exponential
//!   MTBF/MTTR failure injection ([`sim::FaultInjector`]), advance
//!   reservations, and a preemption-capable policy layer
//!   ([`sched::PreemptiveScheduler`]) that composes checkpoint/restart
//!   or kill-and-requeue eviction with every scheduling algorithm.
//!   Config surface: `faults.{mtbf,mttr,seed,until}`,
//!   `preemption.{mode,checkpoint_overhead,restart_overhead,
//!   starvation_threshold}`, `reservations[{start,duration,nodes}]`.
//!   New outputs: preemption/requeue/failure/repair counts, lost and
//!   checkpointed work (core-seconds), and goodput-based effective
//!   utilization (see `sim::SimReport`).
//! * **scale path** (million-job throughput): four coordinated pieces
//!   keep single-rank runs fast and bounded-memory at archive scale.
//!   (1) *Streaming ingestion* — [`trace::JobStream`] parses one SWF/GWF
//!   record at a time off any `BufRead` (the eager `parse_swf`/
//!   `parse_gwf` are thin collects over the same per-line parsers;
//!   property-tested equal), and `Simulation::with_job_stream` +
//!   [`trace::Workload::machine`] feed the arrival queue incrementally
//!   with a one-job lookahead, so peak RSS is O(active jobs), not
//!   O(trace); `with_retain_completed(false)` drops per-job records AND
//!   the unbounded per-event metric series, keeping scalar aggregates
//!   (`SimReport::completed_count`, `mean_wait_overall`, incremental
//!   time-weighted utilization/goodput means). (2) *Ingestion tier* —
//!   when even per-line text parsing is the limiter, `--fast-parse`
//!   switches text traces to [`trace::fast`]: one loaded buffer, SWAR
//!   newline splitting, branchless ASCII numeric parsing, zero
//!   per-record allocations; and `sst-sched convert` re-encodes any
//!   trace as the binary [`trace::stf`] format (fixed 32-byte records,
//!   submit-sorted checked on write), whose reader is a cast-free field
//!   decode — the format the bench and serve paths prefer. *Parity
//!   contract*: scanner and scalar parser yield the identical job
//!   sequence and identical first-error position (line + byte offset),
//!   enforced by the differential suite in `tests/prop_fastparse.rs`
//!   and a cross-format run-fingerprint test — so use text for
//!   interchange, `--fast-parse` for big text replays, stf for repeated
//!   replay at scale, and trust the results to be bit-identical either
//!   way. (3) *Auto-horizon* — `planning.horizon`
//!   accepts `"auto"` ([`sim::Horizon::Auto`]): exact planning while the
//!   queue is shallow, and at deep queues the timeline clamp is derived
//!   from live queue depth and the median runtime estimate each resync,
//!   bounding breakpoint count without a hand-tuned tick value.
//!   (4) *Allocation-free rounds* — [`sched::RoundScratch`], owned by
//!   the scheduler component and threaded through `SchedInput::scratch`,
//!   hosts the order views, backfill candidate columns and the scratch
//!   plan (overwritten via `AvailabilityProfile::copy_from`), so
//!   steady-state dispatch rounds reuse buffers instead of allocating.
//!   The numbers are durable: `sst-sched bench [--smoke]` runs the
//!   engine_throughput suite (including a million-job streamed-SWF case
//!   in full mode, ladder-vs-heap event-queue cases at 100k smoke /
//!   1M full over mixed near/far horizons, and `ingest/*` cases that
//!   time scalar vs fast vs stf parsing of the same trace) and writes
//!   `BENCH_engine.json` — schema
//!   `sst-sched-bench-v1`: `{schema, suite, smoke, cases: [{name, runs,
//!   median_ns, mean_ns, min_ns, p10_ns, p90_ns}]}` — which CI uploads
//!   on every run and diffs against the committed baseline (advisory
//!   >25% warning).
//! * [`workflow`] — the workflow-management component (paper §3): DAG task
//!   dependencies, JSON input spec, ready-set scheduling, and generators
//!   for the Pegasus workflows the paper evaluates (Montage/Galactic
//!   Plane, SIPHT, Epigenomics, ...).
//! * [`trace`] — SWF/GWF trace I/O plus DAS-2-like and SDSC-SP2-like
//!   synthetic workload models.
//! * [`baseline`] — an independent CQsim-like flat event-loop simulator
//!   used as the validation comparator (paper Figs 3, 4a).
//! * [`parallel`] — conservative parallel engine: YAWNS-style lookahead
//!   windows over threads standing in for MPI ranks (Figs 5, 6). The
//!   sharded federation engine (`parallel::shard`) runs each cluster of
//!   a multi-domain federation as a full simulator instance on a rank,
//!   with meta-scheduler routing delivered as conservative cross-rank
//!   messages; decision fingerprints are byte-identical across shard
//!   counts, so `--shards N` is a speedup knob, never a semantics knob.
//! * [`runtime`] — execution services: the PJRT bridge executing the
//!   AOT-compiled JAX/Pallas queue-scoring artifact from the scheduler
//!   hot path (`--accel xla`), and [`runtime::serve`] — the
//!   scheduler-as-a-service daemon (`sst-sched serve`): named,
//!   long-lived resumable simulations behind a JSON-lines Unix-socket
//!   protocol (`submit`/`predict_wait`/`status`/`metrics`/`shutdown`,
//!   see `docs/PROTOCOL.md`) with bounded per-connection queues,
//!   explicit backpressure replies, `--max-sims` admission control and
//!   graceful SIGTERM drain. `--state-dir` adds crash safety: a
//!   write-ahead journal ([`runtime::journal`]) records every mutating
//!   request before it is applied, and `--resume`
//!   ([`runtime::recover`]) rebuilds the exact pre-crash daemon by
//!   deterministic replay (`docs/OPERATIONS.md`).
//! * [`sim`] — the component wiring: job source, scheduler, resource
//!   manager, executor, statistics collector. Since the serve PR,
//!   `Simulation::build()` yields a resumable [`sim::SimInstance`]
//!   state machine — `step_until`/`submit`/`snapshot`/`resume` — whose
//!   snapshot→resume→run fingerprint is byte-identical to an
//!   uninterrupted run (`rust/tests/snapshot.rs`); `predict_wait`
//!   speculation rides that clone.
//! * [`metrics`], [`config`], [`harness`] — reporting, configuration, and
//!   per-figure experiment runners.
//!
//! User-facing documentation lives at the repository root: `README.md`
//! (quickstart, subcommands, ingestion-tier guidance),
//! `docs/ARCHITECTURE.md` (module map, determinism layers, serve
//! lifecycle), `docs/PROTOCOL.md` (the serve wire protocol, whose
//! examples are round-tripped verbatim by `rust/tests/serve.rs`) and
//! `docs/OPERATIONS.md` (running the daemon durably: journal format,
//! durability modes, recovery semantics).
//!
//! ## Determinism contract & correctness tooling
//!
//! The headline guarantee — byte-identical result fingerprints across
//! runs, shard counts, and ingestion formats — is enforced by two
//! always-available layers in [`analysis`], not just by end-to-end
//! golden tests:
//!
//! * **Static lint** ([`analysis::lint`], run by `cargo test` via
//!   `rust/tests/lint.rs`): flags `HashMap`/`HashSet` iteration in the
//!   decision-path modules (`sched/`, `sim/`, `core/`, `parallel/`,
//!   `resources/`, `workflow/`) unless the result is order-folded or
//!   sorted; `.partial_cmp(..)` call sites anywhere (use `total_cmp` or
//!   integer keys); `Instant::now`/`SystemTime` outside `harness/`,
//!   `parallel/` timing, `util/bench.rs`, and `main.rs`; and any
//!   ambient randomness (`thread_rng` etc. — randomness flows from the
//!   seeded simulation RNG only). A genuine exception is annotated in
//!   place as `// lint:allow(<rule-id>, <reason>)` — trailing the line
//!   or on the comment line directly above it. The reason is mandatory
//!   and must not contain `)` (the lint is a line scanner); an allow
//!   that no longer matches a violation is itself an error, so escapes
//!   cannot rot.
//! * **Runtime sanitizer** ([`analysis::sanitizer`]): on in every debug
//!   build, and forced on in release with `--features sanitize`. At
//!   event boundaries it checks core/memory conservation against
//!   per-node truth, the incremental [`resources::AvailabilityProfile`]
//!   against a from-scratch rebuild, event-queue pop-order
//!   monotonicity with unique `(time, priority, seq)` keys, job
//!   segment accounting (`executed == runtime + overhead + lost`), and
//!   sharded delivery against the YAWNS window bound. A violation
//!   panics with a structured report (tick, site, invariant, expected
//!   vs got). Run a release scenario under
//!   `cargo run --release --features sanitize -- run cfg.json` before
//!   blessing new goldens or landing changes to the scheduler core,
//!   the event queue, or the profile algebra.
//!
//! **Crash safety is the determinism contract's third dividend** (after
//! cross-shard equality and snapshot/resume): because a hosted sim's
//! future is a pure function of the experiment config and its ordered
//! request history, the serve daemon never checkpoints scheduler
//! internals — it write-ahead journals request *lines* and recovers by
//! replaying them. The chaos harness (`rust/tests/crash_recovery.rs`)
//! turns that into an equality assertion: for randomized crash points,
//! torn journal tails, and every durability mode, the recovered
//! daemon's per-sim fingerprints are byte-identical to an uncrashed
//! reference. Any nondeterminism anywhere in the stack would show up
//! there as a recovery divergence.

pub mod analysis;
pub mod baseline;
pub mod config;
pub mod core;
pub mod harness;
pub mod job;
pub mod metrics;
pub mod parallel;
pub mod resources;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod trace;
pub mod util;
pub mod workflow;
