//! Shortest Job First (paper §2.1): minimizes average wait time by
//! prioritizing short jobs; hinges on the user runtime *estimate* (the
//! scheduler cannot see actual runtimes — Smith 1978).
//!
//! Since the queue-ordering redesign SJF is not a separate algorithm:
//! it is the [`BlockingScheduler`](crate::sched::BlockingScheduler)
//! walking the queue under [`ShortestFirst`](crate::sched::ShortestFirst)
//! (`Policy::Sjf.default_order()`). This module keeps the policy's
//! behavioural tests against the collapsed implementation.

#[cfg(test)]
mod tests {
    use crate::core::time::SimTime;
    use crate::job::{Job, WaitQueue};
    use crate::resources::Cluster;
    use crate::sched::order::order_by_estimate;
    use crate::sched::{Policy, SchedInput, Scheduler, ShortestFirst};

    fn input<'a>(queue: &'a WaitQueue) -> SchedInput<'a> {
        SchedInput {
            now: SimTime(100),
            queue,
            running: &[],
            profile: &crate::resources::AvailabilityProfile::EMPTY,
            order: &ShortestFirst,
            scratch: None,
        }
    }

    #[test]
    fn shortest_estimate_first() {
        let mut q = WaitQueue::new();
        q.push(Job::with_estimate(1, 0, 2, 100, 500));
        q.push(Job::with_estimate(2, 1, 2, 100, 10));
        q.push(Job::with_estimate(3, 2, 2, 100, 50));
        let mut c = Cluster::homogeneous(1, 4, 0);
        let allocs = Policy::Sjf.build().schedule(&input(&q), &mut c);
        // Only 4 cores: shortest two (jobs 2 and 3) start, blocking at job 1.
        assert_eq!(allocs.iter().map(|a| a.job_id).collect::<Vec<_>>(), vec![2, 3]);
    }

    #[test]
    fn ties_break_by_arrival() {
        let mut q = WaitQueue::new();
        q.push(Job::with_estimate(9, 5, 1, 10, 42));
        q.push(Job::with_estimate(3, 1, 1, 10, 42));
        assert_eq!(order_by_estimate(&q, false), vec![3, 9]);
    }

    #[test]
    fn blocking_on_short_but_wide_job() {
        let mut q = WaitQueue::new();
        q.push(Job::with_estimate(1, 0, 100, 10, 1)); // shortest but huge
        q.push(Job::with_estimate(2, 1, 1, 10, 1000));
        let mut c = Cluster::homogeneous(2, 4, 0);
        // Job 1 infeasible (100 > 8 total) -> skipped; job 2 starts.
        let allocs = Policy::Sjf.build().schedule(&input(&q), &mut c);
        assert_eq!(allocs.iter().map(|a| a.job_id).collect::<Vec<_>>(), vec![2]);
    }
}
