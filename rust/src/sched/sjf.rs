//! Shortest Job First (paper §2.1): minimizes average wait time by
//! prioritizing short jobs; hinges on the user runtime *estimate* (the
//! scheduler cannot see actual runtimes — Smith 1978).

use crate::job::JobId;
use crate::resources::{AllocPolicy, Allocation, Cluster};
use crate::sched::fcfs::run_ordered_ids;
use crate::sched::{SchedInput, Scheduler};

/// SJF: queue viewed in ascending estimated-runtime order, blocking
/// discipline. Ties break by (submit, id) so runs are deterministic.
#[derive(Debug, Default)]
pub struct SjfScheduler;

impl SjfScheduler {
    pub fn new() -> Self {
        SjfScheduler
    }
}

pub(crate) fn order_by_estimate(input: &SchedInput<'_>, longest_first: bool) -> Vec<JobId> {
    let mut jobs: Vec<(u64, u64, JobId)> = input
        .queue
        .iter()
        .map(|j| (j.est_runtime.ticks(), j.submit.ticks(), j.id))
        .collect();
    if longest_first {
        jobs.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2)));
    } else {
        jobs.sort();
    }
    jobs.into_iter().map(|(_, _, id)| id).collect()
}

impl Scheduler for SjfScheduler {
    fn uses_running_info(&self) -> bool {
        false
    }

    fn name(&self) -> &'static str {
        "sjf"
    }

    fn schedule(&mut self, input: &SchedInput<'_>, cluster: &mut Cluster) -> Vec<Allocation> {
        let order = order_by_estimate(input, false);
        run_ordered_ids(&order, input, cluster, AllocPolicy::FirstFit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::time::SimTime;
    use crate::job::{Job, WaitQueue};

    fn input<'a>(queue: &'a WaitQueue) -> SchedInput<'a> {
        SchedInput {
            now: SimTime(100),
            queue,
            running: &[],
            profile: &crate::resources::AvailabilityProfile::EMPTY,
        }
    }

    #[test]
    fn shortest_estimate_first() {
        let mut q = WaitQueue::new();
        q.push(Job::with_estimate(1, 0, 2, 100, 500));
        q.push(Job::with_estimate(2, 1, 2, 100, 10));
        q.push(Job::with_estimate(3, 2, 2, 100, 50));
        let mut c = Cluster::homogeneous(1, 4, 0);
        let allocs = SjfScheduler::new().schedule(&input(&q), &mut c);
        // Only 4 cores: shortest two (jobs 2 and 3) start, blocking at job 1.
        assert_eq!(allocs.iter().map(|a| a.job_id).collect::<Vec<_>>(), vec![2, 3]);
    }

    #[test]
    fn ties_break_by_arrival() {
        let mut q = WaitQueue::new();
        q.push(Job::with_estimate(9, 5, 1, 10, 42));
        q.push(Job::with_estimate(3, 1, 1, 10, 42));
        let order = order_by_estimate(&input(&q), false);
        assert_eq!(order, vec![3, 9]);
    }

    #[test]
    fn blocking_on_short_but_wide_job() {
        let mut q = WaitQueue::new();
        q.push(Job::with_estimate(1, 0, 100, 10, 1)); // shortest but huge
        q.push(Job::with_estimate(2, 1, 1, 10, 1000));
        let mut c = Cluster::homogeneous(2, 4, 0);
        // Job 1 infeasible (100 > 8 total) -> skipped; job 2 starts.
        let allocs = SjfScheduler::new().schedule(&input(&q), &mut c);
        assert_eq!(allocs.iter().map(|a| a.job_id).collect::<Vec<_>>(), vec![2]);
    }
}
