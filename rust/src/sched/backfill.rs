//! FCFS with EASY Backfilling (paper §2.1): the head of the queue gets a
//! reservation at the earliest time enough cores free up (the *shadow
//! time*); jobs behind it may start out of order iff they cannot delay
//! that reservation — they either finish before the shadow time or use
//! only the *extra* cores the head will not need.
//!
//! Candidate ranking and feasibility pre-filtering run through a
//! [`QueueScorer`] — the batched O(Q x N) computation that the L1 Pallas
//! kernel implements. The default is the pure-Rust [`NativeScorer`];
//! `--accel xla` swaps in the AOT-compiled artifact. Final admission is
//! re-checked in exact integer arithmetic, so scorer backend choice can
//! never change a scheduling decision (asserted by rust/tests/xla_parity).

use crate::core::time::SimTime;
use crate::resources::{AllocPolicy, Allocation, Cluster};
use crate::sched::scorer::{NativeScorer, QueueScorer, ScoreParams};
use crate::sched::{SchedInput, Scheduler};

/// EASY backfilling scheduler.
pub struct BackfillScheduler {
    scorer: Box<dyn QueueScorer>,
    /// Scoring weights (aging, waste) — see ScoreParams.
    pub aging_weight: f32,
    pub waste_weight: f32,
}

impl Default for BackfillScheduler {
    fn default() -> Self {
        Self::new()
    }
}

impl BackfillScheduler {
    pub fn new() -> Self {
        BackfillScheduler {
            scorer: Box::new(NativeScorer::new()),
            aging_weight: 1.0,
            waste_weight: 0.5,
        }
    }

    /// Use a specific scorer backend (e.g. `runtime::XlaScorer`).
    pub fn with_scorer(scorer: Box<dyn QueueScorer>) -> Self {
        BackfillScheduler { scorer, aging_weight: 1.0, waste_weight: 0.5 }
    }

    pub fn scorer_backend(&self) -> &'static str {
        self.scorer.backend()
    }

    /// Shadow-time computation: walk running-job releases (by *estimated*
    /// end) until the head job fits. Returns (shadow_time, extra_cores):
    /// the head's reservation start and the cores it leaves unused then.
    fn reservation(
        head_cores: u64,
        free_now: u64,
        releases: &mut Vec<(SimTime, u64)>,
        now: SimTime,
    ) -> Option<(SimTime, u64)> {
        releases.sort();
        let mut avail = free_now;
        let mut shadow = now;
        let mut i = 0;
        while avail < head_cores {
            if i >= releases.len() {
                return None; // head can never fit (infeasible)
            }
            avail += releases[i].1;
            shadow = releases[i].0;
            i += 1;
        }
        Some((shadow, avail - head_cores))
    }
}

impl Scheduler for BackfillScheduler {
    fn name(&self) -> &'static str {
        "fcfs-backfill"
    }

    fn schedule(&mut self, input: &SchedInput<'_>, cluster: &mut Cluster) -> Vec<Allocation> {
        let mut out = Vec::new();

        // Phase 1 — plain FCFS from the head while jobs fit. Lazy single
        // pass: under a blocked head this touches only the prefix, never
        // the whole queue (§Perf).
        let mut queue_iter = input.queue.iter();
        let mut phase1_releases: Vec<(SimTime, u64)> = Vec::new();
        let mut head = None;
        for job in queue_iter.by_ref() {
            if !cluster.feasible(job) {
                continue;
            }
            match cluster.allocate(job, AllocPolicy::FirstFit) {
                Some(a) => {
                    phase1_releases.push((input.now + job.est_runtime, a.cores()));
                    out.push(a);
                }
                None => {
                    head = Some(job);
                    break;
                }
            }
        }
        let Some(head) = head else { return out };

        // Phase 2 — the head is blocked: compute its reservation from
        // running jobs plus phase-1 starts (both hold cores until their
        // estimated ends).
        let mut releases: Vec<(SimTime, u64)> =
            input.running.iter().map(|r| (r.est_end, r.cores)).collect();
        releases.extend(phase1_releases);
        let Some((shadow, extra)) =
            Self::reservation(head.cores, cluster.free_cores(), &mut releases, input.now)
        else {
            return out; // head infeasible; nothing more to do
        };

        // Phase 3 — score the candidates behind the head (the batched
        // O(Q x N) inner loop -> scorer / Pallas kernel).
        let cands: Vec<&crate::job::Job> = queue_iter.collect();
        if cands.is_empty() {
            return out;
        }
        let mut req = Vec::with_capacity(cands.len());
        let mut est = Vec::with_capacity(cands.len());
        let mut wait = Vec::with_capacity(cands.len());
        for j in &cands {
            req.push(j.cores as f32);
            est.push(j.est_runtime.as_f64() as f32);
            wait.push((input.now - j.submit).as_f64() as f32);
        }
        let params = ScoreParams {
            shadow_time: (shadow - input.now).as_f64() as f32,
            extra_cores: extra as f32,
            aging_weight: self.aging_weight,
            waste_weight: self.waste_weight,
        };
        let scores = self.scorer.score(&req, &est, &wait, &cluster.free_vec(), params);

        // Rank candidates by priority (desc); ties keep arrival order.
        let mut order: Vec<usize> = (0..cands.len()).collect();
        order.sort_by(|&a, &b| {
            scores.priority[b]
                .partial_cmp(&scores.priority[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });

        // Phase 4 — admit candidates; exact integer re-check is
        // authoritative so f32 scoring can never change a decision.
        let mut remaining_extra = extra;
        for &ci in &order {
            if scores.backfill_ok[ci] != 1.0 {
                continue;
            }
            let job = cands[ci];
            if job.cores > cluster.free_cores() {
                continue;
            }
            let finishes_by_shadow = input.now + job.est_runtime <= shadow;
            let within_extra = job.cores <= remaining_extra;
            if !finishes_by_shadow && !within_extra {
                continue;
            }
            if let Some(a) = cluster.allocate(job, AllocPolicy::FirstFit) {
                if !finishes_by_shadow {
                    remaining_extra -= job.cores;
                }
                out.push(a);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::{Job, JobId, WaitQueue};
    use crate::sched::RunningJob;

    fn run(
        queue: &WaitQueue,
        running: &[RunningJob],
        cluster: &mut Cluster,
        now: u64,
    ) -> Vec<JobId> {
        let input = SchedInput { now: SimTime(now), queue, running };
        BackfillScheduler::new()
            .schedule(&input, cluster)
            .iter()
            .map(|a| a.job_id)
            .collect()
    }

    #[test]
    fn backfills_short_job_past_blocked_head() {
        // Machine: 8 cores. Running: 4 cores until t=100.
        // Head wants 8 (blocked until 100). Short job wants 4 for 50s:
        // finishes by the shadow time -> backfilled.
        let mut c = Cluster::homogeneous(1, 8, 0);
        let ra = c.allocate(&Job::simple(99, 0, 4, 100), AllocPolicy::FirstFit).unwrap();
        let running = [RunningJob { id: 99, cores: 4, est_end: SimTime(100), start: SimTime(0), priority: 0 }];
        let mut q = WaitQueue::new();
        q.push(Job::with_estimate(1, 0, 8, 100, 100)); // head, blocked
        q.push(Job::with_estimate(2, 1, 4, 50, 50)); // backfill candidate
        let started = run(&q, &running, &mut c, 0);
        assert_eq!(started, vec![2]);
        c.release(&ra);
    }

    #[test]
    fn does_not_delay_head_reservation() {
        // Same as above but the candidate runs for 200s > shadow 100 and
        // extra = 0 (head takes the whole machine) -> must NOT backfill.
        let mut c = Cluster::homogeneous(1, 8, 0);
        let _ra = c.allocate(&Job::simple(99, 0, 4, 100), AllocPolicy::FirstFit).unwrap();
        let running = [RunningJob { id: 99, cores: 4, est_end: SimTime(100), start: SimTime(0), priority: 0 }];
        let mut q = WaitQueue::new();
        q.push(Job::with_estimate(1, 0, 8, 100, 100));
        q.push(Job::with_estimate(2, 1, 4, 200, 200));
        let started = run(&q, &running, &mut c, 0);
        assert!(started.is_empty());
    }

    #[test]
    fn long_candidate_on_extra_cores_is_fine() {
        // Machine: 8 cores, 4 running until t=100. Head wants 6 at shadow
        // -> extra = 8-6 = 2. A 2-core long job may run indefinitely.
        let mut c = Cluster::homogeneous(1, 8, 0);
        let _ra = c.allocate(&Job::simple(99, 0, 4, 100), AllocPolicy::FirstFit).unwrap();
        let running = [RunningJob { id: 99, cores: 4, est_end: SimTime(100), start: SimTime(0), priority: 0 }];
        let mut q = WaitQueue::new();
        q.push(Job::with_estimate(1, 0, 6, 100, 100)); // head: blocked (only 4 free)
        q.push(Job::with_estimate(2, 1, 2, 10_000, 10_000)); // long but small
        let started = run(&q, &running, &mut c, 0);
        assert_eq!(started, vec![2]);
    }

    #[test]
    fn extra_budget_is_consumed() {
        // extra = 2; two 2-core long candidates: only the first backfills.
        let mut c = Cluster::homogeneous(1, 8, 0);
        let _ra = c.allocate(&Job::simple(99, 0, 4, 100), AllocPolicy::FirstFit).unwrap();
        let running = [RunningJob { id: 99, cores: 4, est_end: SimTime(100), start: SimTime(0), priority: 0 }];
        let mut q = WaitQueue::new();
        q.push(Job::with_estimate(1, 0, 6, 100, 100)); // head
        q.push(Job::with_estimate(2, 1, 2, 10_000, 10_000));
        q.push(Job::with_estimate(3, 2, 2, 10_000, 10_000));
        let started = run(&q, &running, &mut c, 0);
        assert_eq!(started, vec![2]);
    }

    #[test]
    fn fcfs_phase_starts_fitting_heads() {
        let mut c = Cluster::homogeneous(1, 8, 0);
        let mut q = WaitQueue::new();
        q.push(Job::simple(1, 0, 4, 10));
        q.push(Job::simple(2, 1, 4, 10));
        let started = run(&q, &[], &mut c, 0);
        assert_eq!(started, vec![1, 2]);
        assert_eq!(c.free_cores(), 0);
    }

    #[test]
    fn phase1_jobs_count_toward_shadow() {
        // Empty machine, 8 cores. Job 1 (4c, est 100) starts in phase 1.
        // Head job 2 wants 8 -> shadow = 100 (when job 1 releases), extra =
        // 8-8=0. Candidate job 3 (4c, est 200) must not start.
        let mut c = Cluster::homogeneous(1, 8, 0);
        let mut q = WaitQueue::new();
        q.push(Job::with_estimate(1, 0, 4, 100, 100));
        q.push(Job::with_estimate(2, 1, 8, 100, 100));
        q.push(Job::with_estimate(3, 2, 4, 200, 200));
        let started = run(&q, &[], &mut c, 0);
        assert_eq!(started, vec![1]);
    }

    #[test]
    fn reservation_math() {
        let mut rel = vec![(SimTime(50), 2u64), (SimTime(30), 2), (SimTime(90), 4)];
        let (shadow, extra) =
            BackfillScheduler::reservation(6, 2, &mut rel, SimTime(0)).unwrap();
        // avail: 2 -> +2@30 -> +2@50 = 6 >= 6 at t=50.
        assert_eq!(shadow, SimTime(50));
        assert_eq!(extra, 0);
        let mut rel2 = vec![(SimTime(10), 8u64)];
        let (shadow2, extra2) =
            BackfillScheduler::reservation(4, 0, &mut rel2, SimTime(0)).unwrap();
        assert_eq!(shadow2, SimTime(10));
        assert_eq!(extra2, 4);
        assert!(BackfillScheduler::reservation(100, 0, &mut vec![], SimTime(0)).is_none());
    }

    #[test]
    fn aging_prefers_older_candidate_when_budget_tight() {
        // extra = 2; candidates arrived at t=1 (older) and t=50 — the
        // older one wins the single slot because aging raises priority.
        let mut c = Cluster::homogeneous(1, 8, 0);
        let _ra = c.allocate(&Job::simple(99, 0, 4, 100), AllocPolicy::FirstFit).unwrap();
        let running = [RunningJob { id: 99, cores: 4, est_end: SimTime(100), start: SimTime(0), priority: 0 }];
        let mut q = WaitQueue::new();
        q.push(Job::with_estimate(1, 0, 6, 100, 100)); // head
        q.push(Job::with_estimate(3, 50, 2, 10_000, 10_000)); // newer first in queue
        q.push(Job::with_estimate(2, 1, 2, 10_000, 10_000)); // older but later slot
        let started = run(&q, &running, &mut c, 60);
        assert_eq!(started, vec![2]);
    }
}
