//! FCFS with EASY Backfilling (paper §2.1): the head of the queue gets a
//! reservation at the earliest time enough resources free up (the *shadow
//! time*); jobs behind it may start out of order iff they cannot delay
//! that reservation — they either finish before the shadow time or use
//! only the *extra* cores the head will not need. "Head" and "behind"
//! are defined by `SchedInput::order`, so any [`QueueOrder`] — including
//! usage-decayed fair share — composes with the backfill machinery
//! unchanged.
//!
//! Planning runs against the shared availability timeline
//! ([`AvailabilityProfile`], `SchedInput::profile`), multi-resource
//! since the `ResourceVector` redesign: the shadow time is the head's
//! earliest contiguous slot across *every tracked dimension*
//! (`earliest_slot_v` — a memory-blocked head no longer reserves "now"),
//! and every candidate is checked against the timeline for its whole
//! estimated run (`can_place_v`), so backfill respects future advance
//! reservations, down/draining capacity windows and planned memory
//! pressure. On a cores-only profile with no such windows (monotone
//! releases) the decisions match the classic release-walk, with one
//! deliberate exception: when several releases share the shadow instant,
//! `extra` counts all of them — the textbook EASY definition (free cores
//! at the shadow time minus the head's request); the old walk stopped
//! mid-tick and undercounted.
//!
//! Candidate ranking and feasibility pre-filtering run through a
//! [`QueueScorer`] — the batched O(Q x N) computation that the L1 Pallas
//! kernel implements. The default is the pure-Rust [`NativeScorer`];
//! `--accel xla` swaps in the AOT-compiled artifact. Final admission is
//! re-checked in exact integer arithmetic, so scorer backend choice can
//! never change a scheduling decision (asserted by rust/tests/xla_parity).

use crate::job::{Job, JobId};
use crate::resources::{AllocPolicy, Allocation, AvailabilityProfile, Cluster};
use crate::sched::fcfs::{borrow_scratch, run_ordered};
use crate::sched::scorer::{NativeScorer, QueueScorer, ScoreParams};
use crate::sched::{QueueOrder, RoundScratch, SchedInput, Scheduler};

/// EASY backfilling scheduler.
pub struct BackfillScheduler {
    scorer: Box<dyn QueueScorer>,
    /// Scoring weights (aging, waste) — see ScoreParams.
    pub aging_weight: f32,
    pub waste_weight: f32,
}

impl Default for BackfillScheduler {
    fn default() -> Self {
        Self::new()
    }
}

impl BackfillScheduler {
    pub fn new() -> Self {
        BackfillScheduler {
            scorer: Box::new(NativeScorer::new()),
            aging_weight: 1.0,
            waste_weight: 0.5,
        }
    }

    /// Use a specific scorer backend (e.g. `runtime::XlaScorer`).
    pub fn with_scorer(scorer: Box<dyn QueueScorer>) -> Self {
        BackfillScheduler { scorer, aging_weight: 1.0, waste_weight: 0.5 }
    }

    pub fn scorer_backend(&self) -> &'static str {
        self.scorer.backend()
    }
}

impl Scheduler for BackfillScheduler {
    fn name(&self) -> &'static str {
        "fcfs-backfill"
    }

    /// Future availability comes from `SchedInput::profile`; the
    /// running-job snapshot is not needed (§Perf: the driver skips it).
    fn uses_running_info(&self) -> bool {
        false
    }

    /// Cloneable exactly when the scorer backend is (the native scorer
    /// is; accelerator clients are not) — see
    /// [`crate::sched::QueueScorer::clone_box`].
    fn clone_box(&self) -> Option<Box<dyn Scheduler>> {
        Some(Box::new(BackfillScheduler {
            scorer: self.scorer.clone_box()?,
            aging_weight: self.aging_weight,
            waste_weight: self.waste_weight,
        }))
    }

    fn schedule(&mut self, input: &SchedInput<'_>, cluster: &mut Cluster) -> Vec<Allocation> {
        let mut local = RoundScratch::default();
        let mut guard = None;
        let scratch = borrow_scratch(input, &mut guard, &mut local);
        let RoundScratch { order_ids, order_keys, cand_ids, req, est, wait, rank, plan } = scratch;
        if input.order.order_into(input.queue, input.now, order_ids, order_keys) {
            let mut it =
                order_ids.iter().map(|id| input.queue.get(*id).expect("ordered id not in queue"));
            self.run_round(input, cluster, &mut it, cand_ids, req, est, wait, rank, plan)
        } else {
            let mut it = input.queue.iter();
            self.run_round(input, cluster, &mut it, cand_ids, req, est, wait, rank, plan)
        }
    }
}

impl BackfillScheduler {
    /// One EASY round over an already-resolved queue order. The buffer
    /// arguments are the round scratch ([`RoundScratch`] fields): every
    /// one is cleared (or overwritten via `copy_from`) before use, so
    /// reuse cannot leak state between rounds.
    #[allow(clippy::too_many_arguments)]
    fn run_round<'a>(
        &mut self,
        input: &SchedInput<'a>,
        cluster: &mut Cluster,
        queue_iter: &mut dyn Iterator<Item = &'a Job>,
        cand_ids: &mut Vec<JobId>,
        req: &mut Vec<f32>,
        est: &mut Vec<f32>,
        wait: &mut Vec<f32>,
        rank: &mut Vec<usize>,
        plan: &mut AvailabilityProfile,
    ) -> Vec<Allocation> {
        let now = input.now.ticks();

        // Phase 1 — the blocking pass in queue order while jobs fit
        // (shared with the blocking disciplines: profile-admitted, so a
        // would-be starter colliding with a future window blocks here).
        // Lazy single pass: under a blocked head this touches only the
        // prefix, never the whole queue (§Perf).
        let run = run_ordered(&mut *queue_iter, input, cluster, AllocPolicy::FirstFit, plan);
        let mut out = run.allocs;
        let Some(head_id) = run.blocked else { return out };
        let head = input.queue.get(head_id).expect("blocked head not in queue");

        // Scratch plan for this round: the shared timeline plus this
        // round's own starts. `run_ordered` already built it in strict
        // mode; otherwise lay the phase-1 holds now — the copy is
        // O(breakpoints), paid only when the head actually blocks.
        if !run.plan_built {
            plan.copy_from(input.profile);
            for a in &out {
                let job = input.queue.get(a.job_id).expect("phase-1 start not in queue");
                plan.hold_v(now, now.saturating_add(job.est_runtime.ticks().max(1)), a.demand());
            }
        }

        // Phase 2 — the head is blocked: its reservation starts at the
        // earliest slot where it can run its whole estimate in every
        // tracked dimension (with future reservation/outage windows or
        // planned memory pressure, the first instant enough cores free
        // up is no longer necessarily a slot it can keep).
        let head_est = head.est_runtime.ticks().max(1);
        let Some(shadow) = plan.earliest_slot_v(now, head.demand(), head_est) else {
            return out; // head exceeds eventual capacity; nothing more to do
        };
        let extra = plan.free_at(shadow).saturating_sub(head.cores);
        // Lay the head's own reservation into the plan: with capacity
        // windows after the shadow (non-monotone profiles), a candidate
        // fitting the classic extra budget could still collide with
        // head + window later — can_place below must see the head's
        // claim. On monotone profiles this changes no decision (a
        // within-extra candidate always clears it).
        plan.hold_v(shadow, shadow.saturating_add(head_est), head.demand());

        // Phase 3 — score the candidates behind the head (the batched
        // O(Q x N) inner loop -> scorer / Pallas kernel). The candidate
        // columns live in the round scratch.
        cand_ids.clear();
        req.clear();
        est.clear();
        wait.clear();
        for j in queue_iter {
            cand_ids.push(j.id);
            req.push(j.cores as f32);
            est.push(j.est_runtime.as_f64() as f32);
            wait.push((input.now - j.submit).as_f64() as f32);
        }
        if cand_ids.is_empty() {
            return out;
        }
        let params = ScoreParams {
            shadow_time: (shadow - now) as f32,
            extra_cores: extra as f32,
            aging_weight: self.aging_weight,
            waste_weight: self.waste_weight,
        };
        let scores =
            self.scorer.score(&req[..], &est[..], &wait[..], &cluster.free_vec(), params);

        // Rank candidates by priority (desc); ties keep queue order.
        rank.clear();
        rank.extend(0..cand_ids.len());
        rank.sort_by(|&a, &b| {
            scores.priority[b].total_cmp(&scores.priority[a]).then(a.cmp(&b))
        });

        // Phase 4 — admit candidates; exact integer re-check is
        // authoritative so f32 scoring can never change a decision.
        let mut remaining_extra = extra;
        for &ci in rank.iter() {
            if scores.backfill_ok[ci] != 1.0 {
                continue;
            }
            let job = input.queue.get(cand_ids[ci]).expect("candidate not in queue");
            if job.cores > cluster.free_cores() {
                continue;
            }
            let cand_est = job.est_runtime.ticks().max(1);
            let finishes_by_shadow = now + cand_est <= shadow;
            let within_extra = job.cores <= remaining_extra;
            if !finishes_by_shadow && !within_extra {
                continue;
            }
            // The candidate must fit the availability timeline for its
            // whole estimated run in every tracked dimension — this is
            // what makes EASY refuse a start that would collide with a
            // future advance reservation, a planned capacity outage, or
            // the head's own memory claim.
            if !plan.can_place_v(now, cand_est, job.demand()) {
                continue;
            }
            if let Some(a) = cluster.allocate(job, AllocPolicy::FirstFit) {
                if !finishes_by_shadow {
                    remaining_extra -= job.cores;
                }
                plan.hold_v(now, now + cand_est, a.demand());
                out.push(a);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::time::SimTime;
    use crate::job::{Job, JobId, WaitQueue};
    use crate::sched::{ArrivalOrder, RunningJob};

    /// Profile matching a cluster with `running` holding cores until
    /// their estimated ends (what the simulation core maintains).
    fn profile_of(cluster: &Cluster, running: &[RunningJob], now: u64) -> AvailabilityProfile {
        let releases: Vec<(u64, u64)> =
            running.iter().map(|r| (r.est_end.ticks(), r.cores)).collect();
        AvailabilityProfile::from_releases(
            now,
            cluster.free_cores(),
            cluster.total_cores(),
            &releases,
        )
    }

    fn run(
        queue: &WaitQueue,
        running: &[RunningJob],
        cluster: &mut Cluster,
        now: u64,
    ) -> Vec<JobId> {
        let profile = profile_of(cluster, running, now);
        let input = SchedInput {
            now: SimTime(now),
            queue,
            running,
            profile: &profile,
            order: &ArrivalOrder,
            scratch: None,
        };
        BackfillScheduler::new()
            .schedule(&input, cluster)
            .iter()
            .map(|a| a.job_id)
            .collect()
    }

    #[test]
    fn backfills_short_job_past_blocked_head() {
        // Machine: 8 cores. Running: 4 cores until t=100.
        // Head wants 8 (blocked until 100). Short job wants 4 for 50s:
        // finishes by the shadow time -> backfilled.
        let mut c = Cluster::homogeneous(1, 8, 0);
        let ra = c.allocate(&Job::simple(99, 0, 4, 100), AllocPolicy::FirstFit).unwrap();
        let running = [RunningJob { id: 99, cores: 4, est_end: SimTime(100), start: SimTime(0), priority: 0 }];
        let mut q = WaitQueue::new();
        q.push(Job::with_estimate(1, 0, 8, 100, 100)); // head, blocked
        q.push(Job::with_estimate(2, 1, 4, 50, 50)); // backfill candidate
        let started = run(&q, &running, &mut c, 0);
        assert_eq!(started, vec![2]);
        c.release(&ra);
    }

    #[test]
    fn does_not_delay_head_reservation() {
        // Same as above but the candidate runs for 200s > shadow 100 and
        // extra = 0 (head takes the whole machine) -> must NOT backfill.
        let mut c = Cluster::homogeneous(1, 8, 0);
        let _ra = c.allocate(&Job::simple(99, 0, 4, 100), AllocPolicy::FirstFit).unwrap();
        let running = [RunningJob { id: 99, cores: 4, est_end: SimTime(100), start: SimTime(0), priority: 0 }];
        let mut q = WaitQueue::new();
        q.push(Job::with_estimate(1, 0, 8, 100, 100));
        q.push(Job::with_estimate(2, 1, 4, 200, 200));
        let started = run(&q, &running, &mut c, 0);
        assert!(started.is_empty());
    }

    #[test]
    fn long_candidate_on_extra_cores_is_fine() {
        // Machine: 8 cores, 4 running until t=100. Head wants 6 at shadow
        // -> extra = 8-6 = 2. A 2-core long job may run indefinitely.
        let mut c = Cluster::homogeneous(1, 8, 0);
        let _ra = c.allocate(&Job::simple(99, 0, 4, 100), AllocPolicy::FirstFit).unwrap();
        let running = [RunningJob { id: 99, cores: 4, est_end: SimTime(100), start: SimTime(0), priority: 0 }];
        let mut q = WaitQueue::new();
        q.push(Job::with_estimate(1, 0, 6, 100, 100)); // head: blocked (only 4 free)
        q.push(Job::with_estimate(2, 1, 2, 10_000, 10_000)); // long but small
        let started = run(&q, &running, &mut c, 0);
        assert_eq!(started, vec![2]);
    }

    #[test]
    fn extra_budget_is_consumed() {
        // extra = 2; two 2-core long candidates: only the first backfills.
        let mut c = Cluster::homogeneous(1, 8, 0);
        let _ra = c.allocate(&Job::simple(99, 0, 4, 100), AllocPolicy::FirstFit).unwrap();
        let running = [RunningJob { id: 99, cores: 4, est_end: SimTime(100), start: SimTime(0), priority: 0 }];
        let mut q = WaitQueue::new();
        q.push(Job::with_estimate(1, 0, 6, 100, 100)); // head
        q.push(Job::with_estimate(2, 1, 2, 10_000, 10_000));
        q.push(Job::with_estimate(3, 2, 2, 10_000, 10_000));
        let started = run(&q, &running, &mut c, 0);
        assert_eq!(started, vec![2]);
    }

    #[test]
    fn fcfs_phase_starts_fitting_heads() {
        let mut c = Cluster::homogeneous(1, 8, 0);
        let mut q = WaitQueue::new();
        q.push(Job::simple(1, 0, 4, 10));
        q.push(Job::simple(2, 1, 4, 10));
        let started = run(&q, &[], &mut c, 0);
        assert_eq!(started, vec![1, 2]);
        assert_eq!(c.free_cores(), 0);
    }

    #[test]
    fn phase1_jobs_count_toward_shadow() {
        // Empty machine, 8 cores. Job 1 (4c, est 100) starts in phase 1.
        // Head job 2 wants 8 -> shadow = 100 (when job 1 releases), extra =
        // 8-8=0. Candidate job 3 (4c, est 200) must not start.
        let mut c = Cluster::homogeneous(1, 8, 0);
        let mut q = WaitQueue::new();
        q.push(Job::with_estimate(1, 0, 4, 100, 100));
        q.push(Job::with_estimate(2, 1, 8, 100, 100));
        q.push(Job::with_estimate(3, 2, 4, 200, 200));
        let started = run(&q, &[], &mut c, 0);
        assert_eq!(started, vec![1]);
    }

    #[test]
    fn reservation_math_via_profile() {
        // The shadow/extra pair now comes from the availability profile.
        let p = AvailabilityProfile::from_releases(
            0,
            2,
            8,
            &[(50, 2), (30, 2), (90, 2)],
        );
        // avail: 2 -> 4@30 -> 6@50 >= 6 at t=50.
        assert_eq!(p.earliest_slot(0, 6, 1), Some(50));
        assert_eq!(p.free_at(50).saturating_sub(6), 0);
        let p2 = AvailabilityProfile::from_releases(0, 0, 8, &[(10, 8)]);
        assert_eq!(p2.earliest_slot(0, 4, 1), Some(10));
        assert_eq!(p2.free_at(10).saturating_sub(4), 4);
        // Infeasible request never finds a slot.
        assert_eq!(
            AvailabilityProfile::from_releases(0, 0, 8, &[]).earliest_slot(0, 100, 1),
            None
        );
    }

    #[test]
    fn aging_prefers_older_candidate_when_budget_tight() {
        // extra = 2; candidates arrived at t=1 (older) and t=50 — the
        // older one wins the single slot because aging raises priority.
        let mut c = Cluster::homogeneous(1, 8, 0);
        let _ra = c.allocate(&Job::simple(99, 0, 4, 100), AllocPolicy::FirstFit).unwrap();
        let running = [RunningJob { id: 99, cores: 4, est_end: SimTime(100), start: SimTime(0), priority: 0 }];
        let mut q = WaitQueue::new();
        q.push(Job::with_estimate(1, 0, 6, 100, 100)); // head
        q.push(Job::with_estimate(3, 50, 2, 10_000, 10_000)); // newer first in queue
        q.push(Job::with_estimate(2, 1, 2, 10_000, 10_000)); // older but later slot
        let started = run(&q, &running, &mut c, 60);
        assert_eq!(started, vec![2]);
    }

    #[test]
    fn refuses_candidate_colliding_with_future_reservation() {
        // 8-core machine, 4 running until t=100, head wants 8. A future
        // advance reservation holds the whole machine over [30, 130).
        // Candidate (4c, est 50) finishes by the classic shadow and fits
        // free cores now — the release-walk EASY admitted it — but its
        // run [0, 50) collides with the reservation window: refused.
        let mut c = Cluster::homogeneous(2, 4, 0);
        let _ra = c.allocate(&Job::simple(99, 0, 4, 100), AllocPolicy::FirstFit).unwrap();
        let running = [RunningJob { id: 99, cores: 4, est_end: SimTime(100), start: SimTime(0), priority: 0 }];
        let mut profile = profile_of(&c, &running, 0);
        profile.add_reservation_hold(30, 130, 8);
        let mut q = WaitQueue::new();
        q.push(Job::with_estimate(1, 0, 8, 100, 100)); // head, blocked
        q.push(Job::with_estimate(2, 1, 4, 50, 50)); // would collide
        let input = SchedInput {
            now: SimTime(0),
            queue: &q,
            running: &running,
            profile: &profile,
            order: &ArrivalOrder,
            scratch: None,
        };
        let started: Vec<JobId> = BackfillScheduler::new()
            .schedule(&input, &mut c)
            .iter()
            .map(|a| a.job_id)
            .collect();
        assert!(started.is_empty(), "candidate must not collide with the reservation");

        // A short candidate that clears the window start is still fine.
        let mut q2 = WaitQueue::new();
        q2.push(Job::with_estimate(1, 0, 8, 100, 100));
        q2.push(Job::with_estimate(3, 1, 4, 30, 30)); // done exactly at t=30
        let input = SchedInput {
            now: SimTime(0),
            queue: &q2,
            running: &running,
            profile: &profile,
            order: &ArrivalOrder,
            scratch: None,
        };
        let started: Vec<JobId> = BackfillScheduler::new()
            .schedule(&input, &mut c)
            .iter()
            .map(|a| a.job_id)
            .collect();
        assert_eq!(started, vec![3]);
    }

    #[test]
    fn shadow_respects_reservation_window() {
        // Head's reservation lands after the hold window, not at the
        // first instant enough cores free up inside it.
        let mut c = Cluster::homogeneous(1, 8, 0);
        let _ra = c.allocate(&Job::simple(99, 0, 4, 100), AllocPolicy::FirstFit).unwrap();
        let running = [RunningJob { id: 99, cores: 4, est_end: SimTime(100), start: SimTime(0), priority: 0 }];
        let mut profile = profile_of(&c, &running, 0);
        profile.add_reservation_hold(120, 200, 8);
        // Head (8c, est 100): release at 100 gives 8 free, but only for
        // 20 ticks before the reservation window — slot slides to 200.
        assert_eq!(profile.earliest_slot(0, 8, 100), Some(200));
    }

    #[test]
    fn memory_blocked_head_gets_true_shadow() {
        // Single node: 8 cores, 1000 MB. j1 runs [0, 100) with 4 cores
        // and 800 MB. Head j2 (4c, 800 MB) fits cores now but not
        // memory: the memory-aware shadow is 100, so candidate j3
        // (4c, 100 MB, est 200) fits the head's extra cores AND the
        // memory timeline -> backfilled at t=0. A cores-only planner put
        // the shadow at `now` and refused it (extra = 0).
        use crate::resources::ResourceVector;
        let mut c = Cluster::homogeneous(1, 8, 1000);
        let j1 = Job::with_memory(99, 0, 4, 800, 100);
        let ra = c.allocate(&j1, AllocPolicy::FirstFit).unwrap();
        let mut profile = AvailabilityProfile::new_v(
            0,
            ResourceVector::new(c.free_cores(), c.free_memory_mb()),
            ResourceVector::new(c.total_cores(), c.total_memory_mb()),
        );
        profile.hold_v(0, 100, ra.demand());
        let mut q = WaitQueue::new();
        q.push(Job::with_memory(1, 0, 4, 800, 100)); // head: memory-blocked
        q.push(Job::with_memory(2, 1, 4, 100, 200)); // fits extra + memory
        let input = SchedInput {
            now: SimTime(0),
            queue: &q,
            running: &[],
            profile: &profile,
            order: &ArrivalOrder,
            scratch: None,
        };
        let started: Vec<JobId> = BackfillScheduler::new()
            .schedule(&input, &mut c)
            .iter()
            .map(|a| a.job_id)
            .collect();
        assert_eq!(started, vec![2]);

        // A long candidate whose memory would collide with the head's
        // future memory claim is refused even though it fits right now:
        // free memory is 400 at t=0 (enough for its 300), but at the
        // shadow the head holds 800 MB, leaving 200 < 300.
        let mut c2 = Cluster::homogeneous(1, 8, 1000);
        let j1b = Job::with_memory(98, 0, 4, 600, 100);
        let ra2 = c2.allocate(&j1b, AllocPolicy::FirstFit).unwrap();
        let mut profile2 = AvailabilityProfile::new_v(
            0,
            ResourceVector::new(c2.free_cores(), c2.free_memory_mb()),
            ResourceVector::new(c2.total_cores(), c2.total_memory_mb()),
        );
        profile2.hold_v(0, 100, ra2.demand());
        let mut q2 = WaitQueue::new();
        q2.push(Job::with_memory(1, 0, 4, 800, 100)); // head: memory-blocked
        q2.push(Job::with_memory(4, 1, 2, 300, 10_000)); // long; 300 MB > 200 free after shadow
        let input2 = SchedInput {
            now: SimTime(0),
            queue: &q2,
            running: &[],
            profile: &profile2,
            order: &ArrivalOrder,
            scratch: None,
        };
        let started2: Vec<JobId> = BackfillScheduler::new()
            .schedule(&input2, &mut c2)
            .iter()
            .map(|a| a.job_id)
            .collect();
        assert!(
            started2.is_empty(),
            "long candidate must not squat on memory the head will claim"
        );
    }
}
