//! Longest Job First (paper §2.1): expedites long jobs at the cost of
//! short-job wait times; included as the deliberately-worse comparator in
//! Fig 4(b).

use crate::resources::{AllocPolicy, Allocation, Cluster};
use crate::sched::fcfs::run_ordered_ids;
use crate::sched::sjf::order_by_estimate;
use crate::sched::{SchedInput, Scheduler};

/// LJF: queue viewed in descending estimated-runtime order, blocking
/// discipline. Ties break by (submit, id).
#[derive(Debug, Default)]
pub struct LjfScheduler;

impl LjfScheduler {
    pub fn new() -> Self {
        LjfScheduler
    }
}

impl Scheduler for LjfScheduler {
    fn uses_running_info(&self) -> bool {
        false
    }

    fn name(&self) -> &'static str {
        "ljf"
    }

    fn schedule(&mut self, input: &SchedInput<'_>, cluster: &mut Cluster) -> Vec<Allocation> {
        let order = order_by_estimate(input, true);
        run_ordered_ids(&order, input, cluster, AllocPolicy::FirstFit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::time::SimTime;
    use crate::job::{Job, WaitQueue};

    fn input<'a>(queue: &'a WaitQueue) -> SchedInput<'a> {
        SchedInput {
            now: SimTime(100),
            queue,
            running: &[],
            profile: &crate::resources::AvailabilityProfile::EMPTY,
        }
    }

    #[test]
    fn longest_estimate_first() {
        let mut q = WaitQueue::new();
        q.push(Job::with_estimate(1, 0, 2, 100, 500));
        q.push(Job::with_estimate(2, 1, 2, 100, 10));
        q.push(Job::with_estimate(3, 2, 2, 100, 50));
        let mut c = Cluster::homogeneous(1, 4, 0);
        let allocs = LjfScheduler::new().schedule(&input(&q), &mut c);
        assert_eq!(allocs.iter().map(|a| a.job_id).collect::<Vec<_>>(), vec![1, 3]);
    }

    #[test]
    fn opposite_of_sjf() {
        let mut q = WaitQueue::new();
        for (id, est) in [(1u64, 10u64), (2, 20), (3, 30)] {
            q.push(Job::with_estimate(id, id, 1, 5, est));
        }
        let sjf = order_by_estimate(&input(&q), false);
        let ljf = order_by_estimate(&input(&q), true);
        let mut rev = ljf.clone();
        rev.reverse();
        assert_eq!(sjf, rev);
    }

    #[test]
    fn ljf_ties_break_by_arrival() {
        let mut q = WaitQueue::new();
        q.push(Job::with_estimate(9, 5, 1, 10, 42));
        q.push(Job::with_estimate(3, 1, 1, 10, 42));
        let order = order_by_estimate(&input(&q), true);
        assert_eq!(order, vec![3, 9]);
    }
}
