//! Longest Job First (paper §2.1): expedites long jobs at the cost of
//! short-job wait times; included as the deliberately-worse comparator in
//! Fig 4(b).
//!
//! Like SJF, LJF is the [`BlockingScheduler`](crate::sched::BlockingScheduler)
//! under [`LongestFirst`](crate::sched::LongestFirst)
//! (`Policy::Ljf.default_order()`); this module keeps its behavioural
//! tests.

#[cfg(test)]
mod tests {
    use crate::core::time::SimTime;
    use crate::job::{Job, WaitQueue};
    use crate::resources::Cluster;
    use crate::sched::order::order_by_estimate;
    use crate::sched::{LongestFirst, Policy, SchedInput, Scheduler};

    fn input<'a>(queue: &'a WaitQueue) -> SchedInput<'a> {
        SchedInput {
            now: SimTime(100),
            queue,
            running: &[],
            profile: &crate::resources::AvailabilityProfile::EMPTY,
            order: &LongestFirst,
            scratch: None,
        }
    }

    #[test]
    fn longest_estimate_first() {
        let mut q = WaitQueue::new();
        q.push(Job::with_estimate(1, 0, 2, 100, 500));
        q.push(Job::with_estimate(2, 1, 2, 100, 10));
        q.push(Job::with_estimate(3, 2, 2, 100, 50));
        let mut c = Cluster::homogeneous(1, 4, 0);
        let allocs = Policy::Ljf.build().schedule(&input(&q), &mut c);
        assert_eq!(allocs.iter().map(|a| a.job_id).collect::<Vec<_>>(), vec![1, 3]);
    }

    #[test]
    fn opposite_of_sjf() {
        let mut q = WaitQueue::new();
        for (id, est) in [(1u64, 10u64), (2, 20), (3, 30)] {
            q.push(Job::with_estimate(id, id, 1, 5, est));
        }
        let sjf = order_by_estimate(&q, false);
        let ljf = order_by_estimate(&q, true);
        let mut rev = ljf.clone();
        rev.reverse();
        assert_eq!(sjf, rev);
    }

    #[test]
    fn ljf_ties_break_by_arrival() {
        let mut q = WaitQueue::new();
        q.push(Job::with_estimate(9, 5, 1, 10, 42));
        q.push(Job::with_estimate(3, 1, 1, 10, 42));
        assert_eq!(order_by_estimate(&q, true), vec![3, 9]);
    }
}
