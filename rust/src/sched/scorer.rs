//! Queue scoring: the vectorizable inner loop of best-fit / backfill.
//!
//! [`QueueScorer`] abstracts the batched computation the L2 JAX model
//! performs (python/compile/model.py): per-job single-node best-fit waste,
//! backfill feasibility under the EASY shadow constraint, and an
//! aging-weighted priority. Two implementations exist:
//!
//! * [`NativeScorer`] (here) — pure Rust, the default; bit-compatible with
//!   the oracle in python/compile/kernels/ref.py.
//! * `runtime::XlaScorer` — executes the AOT-compiled HLO artifact on the
//!   PJRT CPU client; selected with `--accel xla`.
//!
//! A scheduler using either must make identical decisions; the parity test
//! in rust/tests/xla_parity.rs asserts the outputs agree.

/// Sentinel for "fits on no single node" — mirrors kernels/scores.py.
pub const NOFIT: f32 = 1.0e9;

/// Waste surrogate charged to jobs that must span nodes — mirrors
/// model.py SPAN_COST.
pub const SPAN_COST: f32 = 128.0;

/// Scalar parameters of one scoring call — mirrors model.py `params`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScoreParams {
    /// Time until the EASY head-job reservation can start (seconds).
    pub shadow_time: f32,
    /// Cores free even after the head job's reservation.
    pub extra_cores: f32,
    /// Weight on accumulated wait in the priority.
    pub aging_weight: f32,
    /// Weight on waste in the priority.
    pub waste_weight: f32,
}

impl Default for ScoreParams {
    fn default() -> Self {
        ScoreParams { shadow_time: 0.0, extra_cores: 0.0, aging_weight: 1.0, waste_weight: 0.5 }
    }
}

impl ScoreParams {
    pub fn as_array(&self) -> [f32; 4] {
        [self.shadow_time, self.extra_cores, self.aging_weight, self.waste_weight]
    }
}

/// Scorer output, one entry per queue slot.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Scores {
    /// Single-node best-fit slack; NOFIT if no single node fits the job.
    pub waste: Vec<f32>,
    /// 1.0 iff the job fits in total free cores AND satisfies the EASY
    /// shadow constraint (short enough or small enough).
    pub backfill_ok: Vec<f32>,
    /// Aging-weighted rank; candidates are considered in descending order.
    pub priority: Vec<f32>,
}

/// Batched queue scoring.
pub trait QueueScorer {
    /// `job_req[q]` cores, `job_est[q]` estimated runtime, `job_wait[q]`
    /// accumulated wait, `node_free[n]` free cores per node. All slices of
    /// the same q resp. n; implementations may pad internally.
    fn score(
        &mut self,
        job_req: &[f32],
        job_est: &[f32],
        job_wait: &[f32],
        node_free: &[f32],
        params: ScoreParams,
    ) -> Scores;

    /// Human-readable backend name ("native" / "xla").
    fn backend(&self) -> &'static str;

    /// Deep-copy for simulation snapshots; `None` (the default) for
    /// backends whose state cannot be duplicated — the XLA/PJRT client
    /// owns device buffers a clone could not share safely.
    fn clone_box(&self) -> Option<Box<dyn QueueScorer>> {
        None
    }
}

/// Pure-Rust scorer; the semantics mirror python/compile/kernels/ref.py
/// exactly (same constants, same formula, f32 arithmetic).
#[derive(Debug, Clone, Default)]
pub struct NativeScorer;

impl NativeScorer {
    pub fn new() -> Self {
        NativeScorer
    }
}

impl QueueScorer for NativeScorer {
    fn score(
        &mut self,
        job_req: &[f32],
        job_est: &[f32],
        job_wait: &[f32],
        node_free: &[f32],
        params: ScoreParams,
    ) -> Scores {
        let q = job_req.len();
        debug_assert_eq!(job_est.len(), q);
        debug_assert_eq!(job_wait.len(), q);
        let total_free: f32 = node_free.iter().sum();
        let mut out = Scores {
            waste: Vec::with_capacity(q),
            backfill_ok: Vec::with_capacity(q),
            priority: Vec::with_capacity(q),
        };
        for i in 0..q {
            let req = job_req[i];
            // L1 kernel equivalent: min non-negative slack over nodes.
            let mut waste = NOFIT;
            for &free in node_free {
                let slack = free - req;
                if slack >= 0.0 && slack < waste {
                    waste = slack;
                }
            }
            let single = waste < NOFIT * 0.5;
            let fits_total = req <= total_free;
            let short_enough = job_est[i] <= params.shadow_time;
            let small_enough = req <= params.extra_cores;
            let ok = fits_total && (short_enough || small_enough);
            let span_penalty = if single { waste } else { SPAN_COST };
            let priority = params.aging_weight * job_wait[i]
                - params.waste_weight * span_penalty
                - if fits_total { 0.0 } else { NOFIT };
            out.waste.push(waste);
            out.backfill_ok.push(if ok { 1.0 } else { 0.0 });
            out.priority.push(priority);
        }
        out
    }

    fn backend(&self) -> &'static str {
        "native"
    }

    fn clone_box(&self) -> Option<Box<dyn QueueScorer>> {
        Some(Box::new(self.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(shadow: f32, extra: f32) -> ScoreParams {
        ScoreParams { shadow_time: shadow, extra_cores: extra, aging_weight: 1.0, waste_weight: 0.5 }
    }

    #[test]
    fn waste_is_min_slack() {
        let mut s = NativeScorer::new();
        let out = s.score(&[4.0], &[10.0], &[0.0], &[8.0, 5.0, 3.0], params(100.0, 0.0));
        assert_eq!(out.waste, vec![1.0]); // 5-4
    }

    #[test]
    fn nofit_when_no_single_node() {
        let mut s = NativeScorer::new();
        let out = s.score(&[10.0], &[10.0], &[0.0], &[8.0, 5.0], params(100.0, 0.0));
        assert_eq!(out.waste, vec![NOFIT]);
        // Still backfillable: fits in total (13 free) and short enough.
        assert_eq!(out.backfill_ok, vec![1.0]);
    }

    #[test]
    fn too_big_for_machine_blocks() {
        let mut s = NativeScorer::new();
        let out = s.score(&[100.0], &[1.0], &[0.0], &[8.0, 5.0], params(1e9, 1e9));
        assert_eq!(out.backfill_ok, vec![0.0]);
        assert!(out.priority[0] <= -NOFIT * 0.5);
    }

    #[test]
    fn shadow_constraint() {
        let mut s = NativeScorer::new();
        // est 50 > shadow 10, req 4 > extra 2 -> not backfillable.
        let out = s.score(&[4.0], &[50.0], &[0.0], &[8.0], params(10.0, 2.0));
        assert_eq!(out.backfill_ok, vec![0.0]);
        // est 50 > shadow 10 but req 4 <= extra 4 -> backfillable.
        let out = s.score(&[4.0], &[50.0], &[0.0], &[8.0], params(10.0, 4.0));
        assert_eq!(out.backfill_ok, vec![1.0]);
    }

    #[test]
    fn aging_raises_priority() {
        let mut s = NativeScorer::new();
        let out = s.score(
            &[2.0, 2.0],
            &[10.0, 10.0],
            &[0.0, 500.0],
            &[8.0],
            params(100.0, 8.0),
        );
        assert!(out.priority[1] > out.priority[0]);
    }

    #[test]
    fn span_cost_applied_to_spanning_jobs() {
        let mut s = NativeScorer::new();
        // Job 0 fits single-node with waste 0; job 1 spans (waste NOFIT).
        let out = s.score(
            &[8.0, 12.0],
            &[10.0, 10.0],
            &[0.0, 0.0],
            &[8.0, 8.0],
            params(100.0, 16.0),
        );
        let p0 = -0.5 * 0.0;
        let p1 = -0.5 * SPAN_COST;
        assert_eq!(out.priority[0], p0);
        assert_eq!(out.priority[1], p1);
    }

    #[test]
    fn empty_queue() {
        let mut s = NativeScorer::new();
        let out = s.score(&[], &[], &[], &[8.0], params(1.0, 1.0));
        assert!(out.waste.is_empty() && out.backfill_ok.is_empty() && out.priority.is_empty());
    }
}
