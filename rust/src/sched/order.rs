//! The queue-ordering seam: *who goes first* as a first-class,
//! swappable knob, decoupled from *how the planner admits them*.
//!
//! AccaSim (Galleguillos et al. 2018) argues a dispatching-research
//! simulator earns its keep by making the ordering policy pluggable;
//! "Scalable System Scheduling for HPC and Big Data" (Reuther et al.
//! 2017) singles out fair-share ordering as the piece separating toy
//! queue models from production schedulers. This module provides both:
//! a [`QueueOrder`] trait every planner consumes through
//! `SchedInput::order`, the three classic orderings
//! ([`ArrivalOrder`], [`ShortestFirst`], [`LongestFirst`]) that collapse
//! FCFS/SJF/LJF into one blocking planner, and a usage-decayed
//! [`FairShare`] (Slurm-style half-life decay, keyed on
//! `Job::user`/`group`) that thereby composes with *every* planner —
//! blocking, EASY and conservative backfilling alike.
//!
//! Usage accounting is driven by the simulation core: the scheduler
//! component calls [`QueueOrder::record_usage`] whenever a run segment
//! ends (completion, preemption, failure kill), charging the machine
//! time the segment actually consumed. Ordering itself never mutates
//! state, so repeated runs are byte-identical.

use crate::core::time::SimTime;
use crate::job::{Job, JobId, WaitQueue};
use std::collections::HashMap;

/// How a round walks the wait queue.
///
/// `Arrival` stays lazy — the planner iterates the queue in place and a
/// blocked head costs O(1), the FCFS fast path the DES hot loop relies
/// on. Every other ordering materializes the id list it sorted.
pub enum QueueView {
    Arrival,
    Ids(Vec<JobId>),
}

impl QueueView {
    /// Iterate `queue` in this view's order.
    pub fn iter<'a>(&'a self, queue: &'a WaitQueue) -> Box<dyn Iterator<Item = &'a Job> + 'a> {
        match self {
            QueueView::Arrival => Box::new(queue.iter()),
            QueueView::Ids(ids) => Box::new(
                ids.iter().map(move |id| queue.get(*id).expect("ordered id not in queue")),
            ),
        }
    }
}

/// A decayed per-user usage entry (metrics snapshot).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct UserShare {
    pub user: u32,
    pub group: u32,
    /// Decayed core-seconds charged to this user at snapshot time.
    pub usage: f64,
}

/// A queue-ordering policy: a pure function from (queue, now) to a
/// dispatch order, plus optional usage-accounting hooks the simulation
/// driver feeds (only [`FairShare`] uses them).
pub trait QueueOrder {
    fn name(&self) -> &'static str;

    /// Write this round's dispatch order into `ids` (cleared first).
    /// Returns `false` when the queue should be walked in place (arrival
    /// order — the lazy path where a blocked head costs O(1)); `ids` is
    /// left empty in that case. Both buffers come from the driver's
    /// per-round scratch ([`crate::sched::RoundScratch`]): `ids` is the
    /// materialized order, `keys` the sort-key column the ordering sorts
    /// in — so ordered rounds are zero-alloc like the arrival path
    /// instead of materializing a fresh tuple vector every dispatch.
    fn order_into(
        &self,
        queue: &WaitQueue,
        now: SimTime,
        ids: &mut Vec<JobId>,
        keys: &mut Vec<(u64, u64, JobId)>,
    ) -> bool;

    /// Allocating convenience wrapper around [`QueueOrder::order_into`]
    /// (tests and one-shot callers; the simulator threads reusable
    /// buffers through `SchedInput::scratch` instead).
    fn view(&self, queue: &WaitQueue, now: SimTime) -> QueueView {
        let mut ids = Vec::new();
        let mut keys = Vec::new();
        if self.order_into(queue, now, &mut ids, &mut keys) {
            QueueView::Ids(ids)
        } else {
            QueueView::Arrival
        }
    }

    /// Driver callback: a run segment of a job owned by `user`/`group`
    /// ended at `now` after consuming `cores` for `seconds` ticks.
    fn record_usage(&mut self, _user: u32, _group: u32, _cores: u64, _seconds: u64, _now: SimTime) {
    }

    /// Decayed per-user usage at `now` (empty for stateless orderings).
    fn usage_snapshot(&self, _now: SimTime) -> Vec<UserShare> {
        Vec::new()
    }

    /// Deep-copy this ordering — including accumulated fair-share usage
    /// — for simulation snapshots
    /// ([`crate::core::engine::Engine::snapshot`]). Every ordering is
    /// plain data, so unlike [`crate::sched::Scheduler::clone_box`]
    /// this is total.
    fn clone_box(&self) -> Box<dyn QueueOrder>;
}

/// Arrival order (FCFS view): the queue as it stands.
#[derive(Debug, Default, Clone, Copy)]
pub struct ArrivalOrder;

impl QueueOrder for ArrivalOrder {
    fn name(&self) -> &'static str {
        "arrival"
    }

    fn order_into(
        &self,
        _queue: &WaitQueue,
        _now: SimTime,
        ids: &mut Vec<JobId>,
        _keys: &mut Vec<(u64, u64, JobId)>,
    ) -> bool {
        ids.clear();
        false
    }

    fn clone_box(&self) -> Box<dyn QueueOrder> {
        Box::new(*self)
    }
}

/// Ascending estimated runtime (SJF view); ties break by (submit, id)
/// so runs are deterministic.
#[derive(Debug, Default, Clone, Copy)]
pub struct ShortestFirst;

/// Descending estimated runtime (LJF view); ties break by (submit, id).
#[derive(Debug, Default, Clone, Copy)]
pub struct LongestFirst;

/// Fill `ids` with queue ids sorted by estimate (shared by SJF/LJF).
/// `keys` is the reusable sort-key column from the round scratch —
/// ordered rounds build and sort it in place, allocating nothing in
/// steady state (keys are unique in `id`, so the unstable sort is a
/// total order).
fn order_by_estimate_into(
    queue: &WaitQueue,
    longest_first: bool,
    ids: &mut Vec<JobId>,
    keys: &mut Vec<(u64, u64, JobId)>,
) {
    ids.clear();
    keys.clear();
    keys.extend(queue.iter().map(|j| (j.est_runtime.ticks(), j.submit.ticks(), j.id)));
    if longest_first {
        keys.sort_unstable_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2)));
    } else {
        keys.sort_unstable();
    }
    ids.extend(keys.iter().map(|&(_, _, id)| id));
}

/// Queue ids sorted by estimate (tests and one-shot callers).
pub(crate) fn order_by_estimate(queue: &WaitQueue, longest_first: bool) -> Vec<JobId> {
    let mut ids = Vec::new();
    let mut keys = Vec::new();
    order_by_estimate_into(queue, longest_first, &mut ids, &mut keys);
    ids
}

impl QueueOrder for ShortestFirst {
    fn name(&self) -> &'static str {
        "shortest"
    }

    fn order_into(
        &self,
        queue: &WaitQueue,
        _now: SimTime,
        ids: &mut Vec<JobId>,
        keys: &mut Vec<(u64, u64, JobId)>,
    ) -> bool {
        order_by_estimate_into(queue, false, ids, keys);
        true
    }

    fn clone_box(&self) -> Box<dyn QueueOrder> {
        Box::new(*self)
    }
}

impl QueueOrder for LongestFirst {
    fn name(&self) -> &'static str {
        "longest"
    }

    fn order_into(
        &self,
        queue: &WaitQueue,
        _now: SimTime,
        ids: &mut Vec<JobId>,
        keys: &mut Vec<(u64, u64, JobId)>,
    ) -> bool {
        order_by_estimate_into(queue, true, ids, keys);
        true
    }

    fn clone_box(&self) -> Box<dyn QueueOrder> {
        Box::new(*self)
    }
}

/// Usage-decayed fair-share ordering (the Slurm
/// `PriorityDecayHalfLife` model): every (user, group) accumulates the
/// core-seconds its jobs consume, the accumulation decays by half every
/// `half_life` ticks, and the queue is walked in ascending decayed
/// usage — users who have consumed least go first, and a once-greedy
/// user's penalty fades instead of starving them forever.
///
/// Ties (including all-zero usage at cold start) break by (submit, id),
/// so a fair-share order over untouched users degenerates to arrival
/// order and stays deterministic.
#[derive(Clone)]
pub struct FairShare {
    /// Half-life in ticks; 0 disables decay (pure accumulated usage).
    half_life: f64,
    /// (user, group) -> (accumulated usage at `last`, last update tick).
    usage: HashMap<(u32, u32), (f64, u64)>,
}

impl FairShare {
    pub fn new(half_life_ticks: u64) -> FairShare {
        FairShare { half_life: half_life_ticks as f64, usage: HashMap::new() }
    }

    fn decay(&self, value: f64, from: u64, to: u64) -> f64 {
        if self.half_life <= 0.0 || to <= from {
            return value;
        }
        value * (-((to - from) as f64) / self.half_life).exp2()
    }

    /// Decayed usage of (user, group) at `now` (read-only: ordering
    /// never mutates state).
    pub fn effective_usage(&self, user: u32, group: u32, now: SimTime) -> f64 {
        match self.usage.get(&(user, group)) {
            None => 0.0,
            Some(&(v, last)) => self.decay(v, last, now.ticks()),
        }
    }
}

impl QueueOrder for FairShare {
    fn name(&self) -> &'static str {
        "fair-share"
    }

    fn order_into(
        &self,
        queue: &WaitQueue,
        now: SimTime,
        ids: &mut Vec<JobId>,
        keys: &mut Vec<(u64, u64, JobId)>,
    ) -> bool {
        ids.clear();
        keys.clear();
        keys.extend(queue.iter().map(|j| {
            let usage = self.effective_usage(j.user, j.group, now);
            // Decayed usage is finite and non-negative (sums and
            // positive scalings of non-negative charges), and for such
            // values the IEEE bit pattern orders exactly like
            // `total_cmp` — so the reusable u64 key column serves the
            // float ordering too. `<= 0.0` also folds a (theoretical)
            // -0.0 onto the zero key.
            let key = if usage <= 0.0 { 0 } else { usage.to_bits() };
            (key, j.submit.ticks(), j.id)
        }));
        keys.sort_unstable();
        ids.extend(keys.iter().map(|&(_, _, id)| id));
        true
    }

    fn record_usage(&mut self, user: u32, group: u32, cores: u64, seconds: u64, now: SimTime) {
        // Decay the existing accumulation to `now` through the same
        // formula reads use, then add the new charge.
        let decayed = self.effective_usage(user, group, now);
        self.usage
            .insert((user, group), (decayed + (cores as f64) * (seconds as f64), now.ticks()));
    }

    fn usage_snapshot(&self, now: SimTime) -> Vec<UserShare> {
        let mut out: Vec<UserShare> = self
            .usage
            // lint:allow(hash-iter, snapshot sorted by user and group before returning)
            .iter()
            .map(|(&(user, group), &(v, last))| UserShare {
                user,
                group,
                usage: self.decay(v, last, now.ticks()),
            })
            .collect();
        out.sort_by(|a, b| (a.user, a.group).cmp(&(b.user, b.group)));
        out
    }

    fn clone_box(&self) -> Box<dyn QueueOrder> {
        Box::new(self.clone())
    }
}

/// Ordering selector (config/CLI surface: `scheduler.order`, `--order`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum OrderKind {
    /// Arrival order (the FCFS view; also the classic backfill order).
    #[default]
    Arrival,
    ShortestFirst,
    LongestFirst,
    /// Usage-decayed fair share (see [`FairShare`]).
    FairShare,
}

impl OrderKind {
    pub const ALL: [OrderKind; 4] = [
        OrderKind::Arrival,
        OrderKind::ShortestFirst,
        OrderKind::LongestFirst,
        OrderKind::FairShare,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            OrderKind::Arrival => "arrival",
            OrderKind::ShortestFirst => "shortest",
            OrderKind::LongestFirst => "longest",
            OrderKind::FairShare => "fair-share",
        }
    }

    /// Instantiate the ordering. `fairshare_half_life` (ticks) only
    /// matters for [`OrderKind::FairShare`].
    pub fn build(self, fairshare_half_life: u64) -> Box<dyn QueueOrder> {
        match self {
            OrderKind::Arrival => Box::new(ArrivalOrder),
            OrderKind::ShortestFirst => Box::new(ShortestFirst),
            OrderKind::LongestFirst => Box::new(LongestFirst),
            OrderKind::FairShare => Box::new(FairShare::new(fairshare_half_life)),
        }
    }
}

impl std::str::FromStr for OrderKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "arrival" | "fifo" => Ok(OrderKind::Arrival),
            "shortest" | "shortest-first" | "sjf" => Ok(OrderKind::ShortestFirst),
            "longest" | "longest-first" | "ljf" => Ok(OrderKind::LongestFirst),
            "fair-share" | "fairshare" | "fair_share" => Ok(OrderKind::FairShare),
            other => {
                let expected: Vec<&str> = OrderKind::ALL.iter().map(|o| o.as_str()).collect();
                Err(format!("unknown order {other:?} (expected {})", expected.join("|")))
            }
        }
    }
}

impl std::fmt::Display for OrderKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q_with(jobs: &[(u64, u64, u64)]) -> WaitQueue {
        // (id, submit, est)
        let mut q = WaitQueue::new();
        for &(id, submit, est) in jobs {
            q.push(Job::with_estimate(id, submit, 1, est, est));
        }
        q
    }

    fn ids(view: QueueView, q: &WaitQueue) -> Vec<JobId> {
        view.iter(q).map(|j| j.id).collect()
    }

    #[test]
    fn order_kind_roundtrip_and_aliases() {
        for o in OrderKind::ALL {
            assert_eq!(o.as_str().parse::<OrderKind>().unwrap(), o);
        }
        assert_eq!("fairshare".parse::<OrderKind>().unwrap(), OrderKind::FairShare);
        assert_eq!("sjf".parse::<OrderKind>().unwrap(), OrderKind::ShortestFirst);
        let err = "mystery".parse::<OrderKind>().unwrap_err();
        assert!(err.contains("fair-share"), "{err}");
    }

    #[test]
    fn classic_views() {
        let q = q_with(&[(1, 0, 50), (2, 1, 10), (3, 2, 90)]);
        assert_eq!(ids(ArrivalOrder.view(&q, SimTime(0)), &q), vec![1, 2, 3]);
        assert_eq!(ids(ShortestFirst.view(&q, SimTime(0)), &q), vec![2, 1, 3]);
        assert_eq!(ids(LongestFirst.view(&q, SimTime(0)), &q), vec![3, 1, 2]);
    }

    #[test]
    fn estimate_ties_break_by_arrival() {
        let q = q_with(&[(9, 5, 42), (3, 1, 42)]);
        assert_eq!(order_by_estimate(&q, false), vec![3, 9]);
        assert_eq!(order_by_estimate(&q, true), vec![3, 9]);
    }

    #[test]
    fn fairshare_cold_start_is_arrival_order() {
        let q = q_with(&[(1, 0, 50), (2, 1, 10)]);
        let fs = FairShare::new(3600);
        assert_eq!(ids(fs.view(&q, SimTime(100)), &q), vec![1, 2]);
    }

    #[test]
    fn fairshare_prefers_light_users_and_decays() {
        let mut q = WaitQueue::new();
        let mut j1 = Job::with_estimate(1, 0, 4, 100, 100);
        j1.user = 7;
        let mut j2 = Job::with_estimate(2, 5, 4, 100, 100);
        j2.user = 9;
        q.push(j1);
        q.push(j2);
        let mut fs = FairShare::new(1_000);
        // User 7 consumed 400 core-seconds; user 9 nothing.
        fs.record_usage(7, 0, 4, 100, SimTime(100));
        assert_eq!(ids(fs.view(&q, SimTime(100)), &q), vec![2, 1]);
        // One half-life halves the penalty...
        let u = fs.effective_usage(7, 0, SimTime(1_100));
        assert!((u - 200.0).abs() < 1e-9, "half-life decay: {u}");
        // ...and after many half-lives the ordering is back to arrival
        // (usage fades; the submit tie-break takes over only at exact
        // equality, so check relative magnitude instead).
        assert!(fs.effective_usage(7, 0, SimTime(100_000)) < 1e-9);
        let snap = fs.usage_snapshot(SimTime(1_100));
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].user, 7);
    }

    #[test]
    fn fairshare_bit_key_orders_like_total_cmp() {
        // The reusable u64 key column sorts usages by IEEE bit pattern;
        // for the non-negative finite values fair share produces that
        // must order exactly like `total_cmp` (with -0.0 folded onto 0).
        let key = |u: f64| if u <= 0.0 { 0u64 } else { u.to_bits() };
        let vals = [0.0, 1e-300, 1e-9, 0.5, 1.0, 1.5, 400.0, 3.7e5, 1e12, f64::MAX];
        for &a in &vals {
            for &b in &vals {
                assert_eq!(
                    key(a).cmp(&key(b)),
                    a.total_cmp(&b),
                    "bit key diverged from total_cmp for ({a}, {b})"
                );
            }
        }
        // A (theoretical) negative zero folds onto the zero key.
        assert_eq!(key(-0.0), key(0.0));
    }

    #[test]
    fn fairshare_zero_half_life_never_decays() {
        let mut fs = FairShare::new(0);
        fs.record_usage(1, 0, 2, 50, SimTime(0));
        assert_eq!(fs.effective_usage(1, 0, SimTime(1_000_000)), 100.0);
    }
}
