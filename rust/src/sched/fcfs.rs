//! First-Come-First-Served (paper §2.1): jobs start strictly in arrival
//! order; a head job that does not fit blocks everything behind it.

use crate::job::{Job, JobId};
use crate::resources::{AllocPolicy, Allocation, Cluster};
use crate::sched::{SchedInput, Scheduler};

/// Start jobs following `order`; stop at the first one that does not fit
/// (blocking discipline shared by FCFS / SJF / LJF / BestFit). Jobs that
/// can never fit the machine are skipped, not blocked on — the driver
/// rejects them at submission, but a defensive skip keeps the scheduler
/// total.
///
/// Lazy over the order iterator: under a blocked head the scheduler does
/// O(1) work instead of materializing the whole queue (the difference is
/// ~1.6x end-to-end on queue-heavy SP2 workloads — EXPERIMENTS.md §Perf).
pub(crate) fn run_ordered<'a>(
    order: impl IntoIterator<Item = &'a Job>,
    cluster: &mut Cluster,
    policy: AllocPolicy,
) -> Vec<Allocation> {
    let mut out = Vec::new();
    for job in order {
        if !cluster.feasible(job) {
            continue;
        }
        match cluster.allocate(job, policy) {
            Some(a) => out.push(a),
            None => break,
        }
    }
    out
}

/// Materialized-id variant for schedulers that must sort first (SJF/LJF).
pub(crate) fn run_ordered_ids(
    order: &[JobId],
    input: &SchedInput<'_>,
    cluster: &mut Cluster,
    policy: AllocPolicy,
) -> Vec<Allocation> {
    run_ordered(
        order.iter().map(|id| input.queue.get(*id).expect("scheduler got id not in queue")),
        cluster,
        policy,
    )
}

/// Strict FCFS with first-fit placement.
#[derive(Debug, Default)]
pub struct FcfsScheduler;

impl FcfsScheduler {
    pub fn new() -> Self {
        FcfsScheduler
    }
}

impl Scheduler for FcfsScheduler {
    fn uses_running_info(&self) -> bool {
        false
    }

    fn name(&self) -> &'static str {
        "fcfs"
    }

    fn schedule(&mut self, input: &SchedInput<'_>, cluster: &mut Cluster) -> Vec<Allocation> {
        run_ordered(input.queue.iter(), cluster, AllocPolicy::FirstFit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::time::SimTime;
    use crate::job::{Job, WaitQueue};

    pub(crate) fn input<'a>(queue: &'a WaitQueue) -> SchedInput<'a> {
        SchedInput {
            now: SimTime(100),
            queue,
            running: &[],
            profile: &crate::resources::AvailabilityProfile::EMPTY,
        }
    }

    #[test]
    fn starts_in_arrival_order() {
        let mut q = WaitQueue::new();
        q.push(Job::simple(1, 0, 4, 10));
        q.push(Job::simple(2, 1, 4, 10));
        let mut c = Cluster::homogeneous(2, 4, 0);
        let mut s = FcfsScheduler::new();
        let allocs = s.schedule(&input(&q), &mut c);
        assert_eq!(allocs.iter().map(|a| a.job_id).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(c.free_cores(), 0);
    }

    #[test]
    fn head_blocks_queue() {
        let mut q = WaitQueue::new();
        q.push(Job::simple(1, 0, 8, 10)); // needs whole machine
        q.push(Job::simple(2, 1, 1, 10)); // would fit, must wait
        let mut c = Cluster::homogeneous(2, 4, 0);
        // Occupy one core so job 1 cannot start.
        let blocker = c.allocate(&Job::simple(99, 0, 1, 1), AllocPolicy::FirstFit).unwrap();
        let mut s = FcfsScheduler::new();
        let allocs = s.schedule(&input(&q), &mut c);
        assert!(allocs.is_empty(), "FCFS must not leapfrog the head");
        c.release(&blocker);
        let allocs = s.schedule(&input(&q), &mut c);
        assert_eq!(allocs.iter().map(|a| a.job_id).collect::<Vec<_>>(), vec![1]);
    }

    #[test]
    fn infeasible_job_skipped_not_blocking() {
        let mut q = WaitQueue::new();
        q.push(Job::simple(1, 0, 1000, 10)); // bigger than machine
        q.push(Job::simple(2, 1, 2, 10));
        let mut c = Cluster::homogeneous(2, 4, 0);
        let mut s = FcfsScheduler::new();
        let allocs = s.schedule(&input(&q), &mut c);
        assert_eq!(allocs.iter().map(|a| a.job_id).collect::<Vec<_>>(), vec![2]);
    }

    #[test]
    fn empty_queue_no_allocs() {
        let q = WaitQueue::new();
        let mut c = Cluster::homogeneous(2, 4, 0);
        assert!(FcfsScheduler::new().schedule(&input(&q), &mut c).is_empty());
    }
}
