//! The blocking discipline (paper §2.1): jobs start strictly in queue
//! order; a head that cannot start blocks everything behind it. FCFS,
//! SJF, LJF and FCFS+BestFit are all this one scheduler — they differ
//! only in the [`QueueOrder`](crate::sched::QueueOrder) the round walks
//! the queue in (`SchedInput::order`) and, for BestFit, the placement
//! policy.
//!
//! Head admission routes through the shared availability timeline
//! (`SchedInput::profile`): on a timeline with capacity windows ahead
//! (pending advance reservations, planned outages) a head whose whole
//! estimated run would collide is *blocked*, not started — the blocking
//! disciplines are reservation- and outage-aware exactly like the
//! backfilling planners. On a monotone timeline (pure release streams,
//! i.e. every fault-free and reservation-free run) the admission check
//! is implied by the exact `Cluster::allocate` check, so the round runs
//! the classic allocate-only loop and is bit-identical to — and as fast
//! as — the scalar-era scheduler.

use crate::job::{Job, JobId};
use crate::resources::{AllocPolicy, Allocation, AvailabilityProfile, Cluster};
use crate::sched::{QueueOrder, RoundScratch, SchedInput, Scheduler};

/// Result of one ordered admission pass.
pub(crate) struct OrderedRun {
    /// Allocations committed, in decision order.
    pub allocs: Vec<Allocation>,
    /// Whether the scratch plan was built (strict / non-monotone mode):
    /// the caller's `plan` buffer then holds the shared timeline with
    /// this round's starts laid in — backfill reuses it for its shadow
    /// math instead of re-cloning.
    pub plan_built: bool,
    /// The job that blocked the pass (the backfill head), if any.
    pub blocked: Option<JobId>,
}

/// Start jobs following `order`; stop at the first one that cannot start
/// (blocking discipline shared by FCFS / SJF / LJF / BestFit and the
/// backfill phase 1). Jobs that can never fit the machine are skipped,
/// not blocked on — the driver rejects them at submission, but a
/// defensive skip keeps the scheduler total.
///
/// Lazy over the order iterator: under a blocked head the scheduler does
/// O(1) work instead of materializing the whole queue (the difference is
/// ~1.6x end-to-end on queue-heavy SP2 workloads — EXPERIMENTS.md §Perf).
/// The iterator is left positioned just past the blocked head so
/// backfill can keep consuming candidates from it. `plan` is the round's
/// reusable scratch buffer; it is overwritten (not cloned) on demand.
pub(crate) fn run_ordered<'a>(
    order: &mut dyn Iterator<Item = &'a Job>,
    input: &SchedInput<'_>,
    cluster: &mut Cluster,
    policy: AllocPolicy,
    plan: &mut AvailabilityProfile,
) -> OrderedRun {
    let profile = input.profile;
    // Strict admission only when the timeline carries capacity windows
    // ahead (non-monotone). On monotone timelines fitting now implies
    // fitting forever, so `Cluster::allocate` alone decides — the
    // classic loop, no plan copy, no scan beyond this one monotone check.
    let strict = !profile.is_empty() && !profile.is_monotone();
    let now = input.now.ticks();
    let mut allocs = Vec::new();
    let mut plan_built = false;
    let mut blocked = None;
    for job in order {
        if !cluster.feasible(job) {
            continue;
        }
        // Plan with at least one tick, like every other planner path —
        // a zero-estimate job must still be admission-checked at `now`
        // and leave a footprint the rest of the round can see.
        let est = job.est_runtime.ticks().max(1);
        if strict {
            let admit: &AvailabilityProfile = if plan_built { plan } else { profile };
            if !admit.can_place_v(now, est, job.demand()) {
                blocked = Some(job.id);
                break;
            }
        }
        match cluster.allocate(job, policy) {
            Some(a) => {
                if strict {
                    if !plan_built {
                        plan.copy_from(profile);
                        plan_built = true;
                    }
                    plan.hold_v(now, now.saturating_add(est), a.demand());
                }
                allocs.push(a);
            }
            None => {
                blocked = Some(job.id);
                break;
            }
        }
    }
    OrderedRun { allocs, plan_built, blocked }
}

/// Borrow the driver's round scratch, or fall back to `local` when the
/// input carries none (unit tests, ad-hoc callers). Returns a guard that
/// must stay alive while the `&mut RoundScratch` is used — callers write
/// `let mut guard = ...; let scratch = borrow_scratch(input, &mut guard, &mut local);`.
pub(crate) fn borrow_scratch<'a, 's>(
    input: &SchedInput<'a>,
    guard: &'s mut Option<std::cell::RefMut<'a, RoundScratch>>,
    local: &'s mut RoundScratch,
) -> &'s mut RoundScratch {
    *guard = input.scratch.map(|c| c.borrow_mut());
    match guard.as_deref_mut() {
        Some(s) => s,
        None => local,
    }
}

/// The blocking scheduler: queue order in, allocations out, stop at the
/// first blocked job. `name` is the policy identity it reports (FCFS,
/// SJF and LJF differ only in `SchedInput::order`).
#[derive(Debug, Clone, Copy)]
pub struct BlockingScheduler {
    name: &'static str,
    alloc: AllocPolicy,
}

impl BlockingScheduler {
    pub fn new(name: &'static str, alloc: AllocPolicy) -> Self {
        BlockingScheduler { name, alloc }
    }
}

impl Default for BlockingScheduler {
    fn default() -> Self {
        BlockingScheduler::new("fcfs", AllocPolicy::FirstFit)
    }
}

impl Scheduler for BlockingScheduler {
    fn uses_running_info(&self) -> bool {
        false
    }

    fn name(&self) -> &'static str {
        self.name
    }

    fn clone_box(&self) -> Option<Box<dyn Scheduler>> {
        Some(Box::new(*self))
    }

    fn schedule(&mut self, input: &SchedInput<'_>, cluster: &mut Cluster) -> Vec<Allocation> {
        let mut local = RoundScratch::default();
        let mut guard = None;
        let scratch = borrow_scratch(input, &mut guard, &mut local);
        let RoundScratch { order_ids, order_keys, plan, .. } = scratch;
        if input.order.order_into(input.queue, input.now, order_ids, order_keys) {
            let mut it =
                order_ids.iter().map(|id| input.queue.get(*id).expect("ordered id not in queue"));
            run_ordered(&mut it, input, cluster, self.alloc, plan).allocs
        } else {
            let mut it = input.queue.iter();
            run_ordered(&mut it, input, cluster, self.alloc, plan).allocs
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::time::SimTime;
    use crate::job::{Job, WaitQueue};
    use crate::sched::ArrivalOrder;

    pub(crate) fn input<'a>(queue: &'a WaitQueue) -> SchedInput<'a> {
        SchedInput {
            now: SimTime(100),
            queue,
            running: &[],
            profile: &crate::resources::AvailabilityProfile::EMPTY,
            order: &ArrivalOrder,
            scratch: None,
        }
    }

    fn fcfs() -> BlockingScheduler {
        BlockingScheduler::new("fcfs", AllocPolicy::FirstFit)
    }

    #[test]
    fn starts_in_arrival_order() {
        let mut q = WaitQueue::new();
        q.push(Job::simple(1, 0, 4, 10));
        q.push(Job::simple(2, 1, 4, 10));
        let mut c = Cluster::homogeneous(2, 4, 0);
        let allocs = fcfs().schedule(&input(&q), &mut c);
        assert_eq!(allocs.iter().map(|a| a.job_id).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(c.free_cores(), 0);
    }

    #[test]
    fn head_blocks_queue() {
        let mut q = WaitQueue::new();
        q.push(Job::simple(1, 0, 8, 10)); // needs whole machine
        q.push(Job::simple(2, 1, 1, 10)); // would fit, must wait
        let mut c = Cluster::homogeneous(2, 4, 0);
        // Occupy one core so job 1 cannot start.
        let blocker = c.allocate(&Job::simple(99, 0, 1, 1), AllocPolicy::FirstFit).unwrap();
        let mut s = fcfs();
        let allocs = s.schedule(&input(&q), &mut c);
        assert!(allocs.is_empty(), "FCFS must not leapfrog the head");
        c.release(&blocker);
        let allocs = s.schedule(&input(&q), &mut c);
        assert_eq!(allocs.iter().map(|a| a.job_id).collect::<Vec<_>>(), vec![1]);
    }

    #[test]
    fn infeasible_job_skipped_not_blocking() {
        let mut q = WaitQueue::new();
        q.push(Job::simple(1, 0, 1000, 10)); // bigger than machine
        q.push(Job::simple(2, 1, 2, 10));
        let mut c = Cluster::homogeneous(2, 4, 0);
        let allocs = fcfs().schedule(&input(&q), &mut c);
        assert_eq!(allocs.iter().map(|a| a.job_id).collect::<Vec<_>>(), vec![2]);
    }

    #[test]
    fn empty_queue_no_allocs() {
        let q = WaitQueue::new();
        let mut c = Cluster::homogeneous(2, 4, 0);
        assert!(fcfs().schedule(&input(&q), &mut c).is_empty());
    }

    #[test]
    fn head_refuses_future_reservation_window() {
        // 8 cores all free *now*, but a reservation takes the machine
        // over [130, 230): a 100-tick head starting at 100 would collide
        // and must wait — the reservation-aware blocking discipline.
        let mut profile = AvailabilityProfile::new(100, 8, 8);
        profile.add_reservation_hold(130, 230, 8);
        let mut q = WaitQueue::new();
        q.push(Job::with_estimate(1, 0, 8, 100, 100));
        q.push(Job::with_estimate(2, 1, 1, 5, 5)); // blocked behind the head
        let mut c = Cluster::homogeneous(2, 4, 0);
        let inp = SchedInput {
            now: SimTime(100),
            queue: &q,
            running: &[],
            profile: &profile,
            order: &ArrivalOrder,
            scratch: None,
        };
        assert!(fcfs().schedule(&inp, &mut c).is_empty(), "head must wait out the window");
        assert_eq!(c.free_cores(), 8, "cluster untouched");
        // A head that clears the window start is admitted.
        let mut q2 = WaitQueue::new();
        q2.push(Job::with_estimate(3, 0, 8, 30, 30)); // done exactly at 130
        let inp = SchedInput {
            now: SimTime(100),
            queue: &q2,
            running: &[],
            profile: &profile,
            order: &ArrivalOrder,
            scratch: None,
        };
        let allocs = fcfs().schedule(&inp, &mut c);
        assert_eq!(allocs.iter().map(|a| a.job_id).collect::<Vec<_>>(), vec![3]);
    }

    #[test]
    fn strict_admission_sees_same_round_starts() {
        // 8 free, window [110, 120) holds 4 (4 stay free inside it).
        // Two 4-core 50-tick jobs: the first fits through the window's
        // residual capacity, the second would need 8 inside it — the
        // scratch plan with the first start laid in must refuse it.
        let mut profile = AvailabilityProfile::new(100, 8, 8);
        profile.add_reservation_hold(110, 120, 4);
        let mut q = WaitQueue::new();
        q.push(Job::with_estimate(1, 0, 4, 50, 50));
        q.push(Job::with_estimate(2, 1, 4, 50, 50));
        let mut c = Cluster::homogeneous(1, 8, 0);
        let inp = SchedInput {
            now: SimTime(100),
            queue: &q,
            running: &[],
            profile: &profile,
            order: &ArrivalOrder,
            scratch: None,
        };
        let allocs = fcfs().schedule(&inp, &mut c);
        assert_eq!(allocs.iter().map(|a| a.job_id).collect::<Vec<_>>(), vec![1]);
    }
}
