//! Job scheduling algorithms (paper §2.1), redesigned around two
//! orthogonal seams:
//!
//! * **ordering** — *who is considered first*: a [`QueueOrder`]
//!   ([`order`] module) handed to every round through
//!   [`SchedInput::order`]. FCFS/SJF/LJF are one [`BlockingScheduler`]
//!   under three orderings, and the backfilling planners accept any
//!   ordering for head selection — so usage-decayed [`FairShare`]
//!   composes with every planner.
//! * **planning** — *what may start now*: the shared availability
//!   timeline ([`crate::resources::AvailabilityProfile`], multi-resource
//!   since the `ResourceVector` redesign) through
//!   [`SchedInput::profile`]. Every policy's head admission routes
//!   through one `can_place_v` query, which is what makes even the
//!   blocking disciplines refuse to start into a future advance
//!   reservation or outage window.
//!
//! A scheduler is a pure decision procedure: given the wait queue, the
//! ordering, the timeline and the cluster, it performs allocations and
//! returns them. It never mutates jobs, the queue or the shared profile
//! — the simulation driver owns lifecycle transitions, profile
//! maintenance and fair-share usage accounting — so the same scheduler
//! implementations run unchanged inside the event-driven simulator, the
//! CQsim-like baseline, and the parallel engine.

pub mod backfill;
pub mod bestfit;
pub mod conservative;
pub mod fcfs;
pub mod ljf;
pub mod order;
pub mod preempt;
pub mod scorer;
pub mod sjf;

pub use backfill::BackfillScheduler;
pub use conservative::ConservativeScheduler;
pub use fcfs::BlockingScheduler;
pub use order::{
    ArrivalOrder, FairShare, LongestFirst, OrderKind, QueueOrder, QueueView, ShortestFirst,
    UserShare,
};
pub use preempt::{PreemptionConfig, PreemptionMode, PreemptiveScheduler};
pub use scorer::{NativeScorer, QueueScorer, ScoreParams, Scores, NOFIT, SPAN_COST};

use crate::core::time::SimTime;
use crate::job::{JobId, WaitQueue};
use crate::resources::{AllocPolicy, Allocation, AvailabilityProfile, Cluster};
use std::str::FromStr;

/// What the scheduler knows about a running job (for shadow-time math and
/// eviction decisions).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RunningJob {
    pub id: JobId,
    pub cores: u64,
    /// Estimated end = start + user estimate (backfilling trusts estimates,
    /// not actual runtimes — it cannot see the future).
    pub est_end: SimTime,
    /// Start of the current run segment (eviction prefers the youngest
    /// segments — least sunk work).
    pub start: SimTime,
    /// Job priority; preemption only ever evicts strictly lower values.
    pub priority: u8,
}

/// Reusable per-round scratch space, owned by the simulation driver and
/// threaded to every policy through [`SchedInput::scratch`].
///
/// Before this existed, every dispatch round re-materialized its order
/// view, re-collected the backfill candidate arrays and re-cloned the
/// availability timeline into a scratch plan — pure allocator churn on
/// the DES hot path at deep queues. Every buffer here is *cleared* (or
/// overwritten via [`AvailabilityProfile::copy_from`]), never shrunk, at
/// the start of the round that uses it, so reuse is pure plumbing:
/// decisions are bit-identical to fresh allocations (pinned by the
/// determinism regressions).
#[derive(Default)]
pub struct RoundScratch {
    /// Materialized queue order (non-arrival orderings).
    pub order_ids: Vec<JobId>,
    /// Sort-key column the ordered views sort in place of a transient
    /// per-round tuple vector: `(primary key, submit, id)`. SJF/LJF use
    /// the runtime estimate as primary key; fair share uses the decayed
    /// usage's IEEE bit pattern (order-identical to `total_cmp` for the
    /// non-negative values usage can take). Arrival order leaves it
    /// untouched.
    pub order_keys: Vec<(u64, u64, JobId)>,
    /// Backfill candidates behind the blocked head.
    pub cand_ids: Vec<JobId>,
    /// Scorer input columns: requested cores / runtime estimates / waits.
    pub req: Vec<f32>,
    pub est: Vec<f32>,
    pub wait: Vec<f32>,
    /// Candidate indices ranked by score.
    pub rank: Vec<usize>,
    /// The round's scratch plan: the shared timeline plus this round's
    /// tentative holds, overwritten in place instead of cloned.
    pub plan: AvailabilityProfile,
}

/// Scheduler input for one invocation.
pub struct SchedInput<'a> {
    pub now: SimTime,
    pub queue: &'a WaitQueue,
    /// Running-job identities — read by the preemption layer for victim
    /// selection. Planning policies do not walk this: future
    /// availability comes from `profile`.
    pub running: &'a [RunningJob],
    /// The shared availability timeline (free resources from `now` into
    /// the future), maintained incrementally by the simulation core. This
    /// is how every policy sees future reservations and down/draining
    /// windows; policies must not mutate it — lay tentative reservations
    /// on the scratch plan instead.
    pub profile: &'a AvailabilityProfile,
    /// The queue ordering this round dispatches under (resolved by the
    /// driver: the CLI/config override, or the policy's natural order).
    pub order: &'a dyn QueueOrder,
    /// Driver-owned per-round scratch ([`RoundScratch`]); `None` (unit
    /// tests, ad-hoc callers) makes the scheduler fall back to a fresh
    /// local scratch for the round.
    pub scratch: Option<&'a std::cell::RefCell<RoundScratch>>,
}

/// A scheduling algorithm.
pub trait Scheduler {
    fn name(&self) -> &'static str;

    /// Decide which queued jobs start now; allocations are committed on
    /// `cluster` and returned in decision order.
    fn schedule(&mut self, input: &SchedInput<'_>, cluster: &mut Cluster) -> Vec<Allocation>;

    /// Phase 0 of a dispatch round: running jobs this policy wants
    /// evicted *before* allocation (preemption-capable policies only —
    /// see [`PreemptiveScheduler`]). The driver checkpoints/requeues the
    /// victims, then calls [`Scheduler::schedule`] on the freed cluster.
    fn preempt(&mut self, _input: &SchedInput<'_>, _cluster: &Cluster) -> Vec<JobId> {
        Vec::new()
    }

    /// Whether the algorithm reads `SchedInput::running`. Since the
    /// availability-profile refactor only the preemption layer does —
    /// planning policies read `SchedInput::profile` instead — so the
    /// driver skips building the running-job snapshot for every stock
    /// policy (§Perf). Defaults to true for third-party schedulers.
    fn uses_running_info(&self) -> bool {
        true
    }

    /// Deep-copy this scheduler for a simulation snapshot
    /// ([`crate::core::engine::Engine::snapshot`]). `None` means the
    /// policy holds state that cannot be duplicated (e.g. a backfill
    /// scorer bound to an external accelerator client); snapshotting
    /// such a simulation fails with a clear error instead of silently
    /// sharing state. Every stock policy returns `Some`.
    fn clone_box(&self) -> Option<Box<dyn Scheduler>> {
        None
    }
}

/// Policy selector (config/CLI surface).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Policy {
    Fcfs,
    Sjf,
    Ljf,
    FcfsBestFit,
    #[default]
    FcfsBackfill,
    /// Conservative backfilling: reservations for every queued job.
    ConservativeBackfill,
}

impl Policy {
    pub const ALL: [Policy; 6] = [
        Policy::Fcfs,
        Policy::Sjf,
        Policy::Ljf,
        Policy::FcfsBestFit,
        Policy::FcfsBackfill,
        Policy::ConservativeBackfill,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            Policy::Fcfs => "fcfs",
            Policy::Sjf => "sjf",
            Policy::Ljf => "ljf",
            Policy::FcfsBestFit => "fcfs-bestfit",
            Policy::FcfsBackfill => "fcfs-backfill",
            Policy::ConservativeBackfill => "cons-backfill",
        }
    }

    /// The ordering this policy dispatches under when the user does not
    /// override it (`--order` / `scheduler.order`). SJF/LJF *are* the
    /// blocking planner under a non-arrival ordering.
    pub fn default_order(self) -> OrderKind {
        match self {
            Policy::Sjf => OrderKind::ShortestFirst,
            Policy::Ljf => OrderKind::LongestFirst,
            _ => OrderKind::Arrival,
        }
    }

    /// Instantiate the scheduler for this policy with the default
    /// (native) scorer. The ordering is orthogonal: pair with
    /// [`Policy::default_order`] (or an override) when driving it.
    pub fn build(self) -> Box<dyn Scheduler> {
        match self {
            Policy::Fcfs => Box::new(BlockingScheduler::new("fcfs", AllocPolicy::FirstFit)),
            Policy::Sjf => Box::new(BlockingScheduler::new("sjf", AllocPolicy::FirstFit)),
            Policy::Ljf => Box::new(BlockingScheduler::new("ljf", AllocPolicy::FirstFit)),
            Policy::FcfsBestFit => {
                Box::new(BlockingScheduler::new("fcfs-bestfit", AllocPolicy::BestFit))
            }
            Policy::FcfsBackfill => Box::new(BackfillScheduler::new()),
            Policy::ConservativeBackfill => Box::new(ConservativeScheduler::new()),
        }
    }
}

impl FromStr for Policy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "fcfs" => Ok(Policy::Fcfs),
            "sjf" => Ok(Policy::Sjf),
            "ljf" => Ok(Policy::Ljf),
            "fcfs-bestfit" | "bestfit" | "best-fit" => Ok(Policy::FcfsBestFit),
            "fcfs-backfill" | "backfill" | "easy" => Ok(Policy::FcfsBackfill),
            "cons-backfill" | "conservative" => Ok(Policy::ConservativeBackfill),
            other => {
                // Keep the expected-values list in lockstep with
                // `Policy::ALL` — a hand-written list drifted once
                // (cons-backfill was missing).
                let expected: Vec<&str> = Policy::ALL.iter().map(|p| p.as_str()).collect();
                Err(format!("unknown policy {other:?} (expected {})", expected.join("|")))
            }
        }
    }
}

impl std::fmt::Display for Policy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_roundtrip() {
        for p in Policy::ALL {
            assert_eq!(p.as_str().parse::<Policy>().unwrap(), p);
        }
    }

    #[test]
    fn policy_aliases() {
        assert_eq!("easy".parse::<Policy>().unwrap(), Policy::FcfsBackfill);
        assert_eq!("best-fit".parse::<Policy>().unwrap(), Policy::FcfsBestFit);
        assert!("mystery".parse::<Policy>().is_err());
    }

    #[test]
    fn policy_error_lists_every_policy() {
        let err = "magic".parse::<Policy>().unwrap_err();
        for p in Policy::ALL {
            assert!(
                err.contains(p.as_str()),
                "error message must list {} (stay in sync with Policy::ALL): {err}",
                p.as_str()
            );
        }
    }

    #[test]
    fn build_matches_name() {
        assert_eq!(Policy::Fcfs.build().name(), "fcfs");
        assert_eq!(Policy::Sjf.build().name(), "sjf");
        assert_eq!(Policy::Ljf.build().name(), "ljf");
        assert_eq!(Policy::FcfsBestFit.build().name(), "fcfs-bestfit");
        assert_eq!(Policy::FcfsBackfill.build().name(), "fcfs-backfill");
        assert_eq!(Policy::ConservativeBackfill.build().name(), "cons-backfill");
    }

    #[test]
    fn default_orders_reflect_policy_identity() {
        assert_eq!(Policy::Fcfs.default_order(), OrderKind::Arrival);
        assert_eq!(Policy::Sjf.default_order(), OrderKind::ShortestFirst);
        assert_eq!(Policy::Ljf.default_order(), OrderKind::LongestFirst);
        assert_eq!(Policy::FcfsBackfill.default_order(), OrderKind::Arrival);
        assert_eq!(Policy::ConservativeBackfill.default_order(), OrderKind::Arrival);
    }
}
