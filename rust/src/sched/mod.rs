//! Job scheduling algorithms (paper §2.1).
//!
//! The paper's five policies — FCFS, SJF, LJF, FCFS+BestFit,
//! FCFS+Backfilling (EASY) — plus conservative backfilling as the
//! classic ablation comparator. A scheduler is a pure decision procedure: given
//! the wait queue (arrival order), the shared availability timeline
//! ([`crate::resources::AvailabilityProfile`], future free cores) and the
//! cluster, it performs allocations and returns them. It never mutates jobs,
//! the queue or the shared profile — the simulation driver owns lifecycle
//! transitions and profile maintenance — so the same scheduler
//! implementations run unchanged inside the event-driven simulator, the
//! CQsim-like baseline, and the parallel engine.

pub mod backfill;
pub mod bestfit;
pub mod conservative;
pub mod fcfs;
pub mod ljf;
pub mod preempt;
pub mod scorer;
pub mod sjf;

pub use backfill::BackfillScheduler;
pub use conservative::ConservativeScheduler;
pub use bestfit::BestFitScheduler;
pub use fcfs::FcfsScheduler;
pub use ljf::LjfScheduler;
pub use preempt::{PreemptionConfig, PreemptionMode, PreemptiveScheduler};
pub use scorer::{NativeScorer, QueueScorer, ScoreParams, Scores, NOFIT, SPAN_COST};
pub use sjf::SjfScheduler;

use crate::core::time::SimTime;
use crate::job::{JobId, WaitQueue};
use crate::resources::{Allocation, AvailabilityProfile, Cluster};
use std::str::FromStr;

/// What the scheduler knows about a running job (for shadow-time math and
/// eviction decisions).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RunningJob {
    pub id: JobId,
    pub cores: u64,
    /// Estimated end = start + user estimate (backfilling trusts estimates,
    /// not actual runtimes — it cannot see the future).
    pub est_end: SimTime,
    /// Start of the current run segment (eviction prefers the youngest
    /// segments — least sunk work).
    pub start: SimTime,
    /// Job priority; preemption only ever evicts strictly lower values.
    pub priority: u8,
}

/// Scheduler input for one invocation.
pub struct SchedInput<'a> {
    pub now: SimTime,
    pub queue: &'a WaitQueue,
    /// Running-job identities — read by the preemption layer for victim
    /// selection. Planning policies do not walk this: future
    /// availability comes from `profile`.
    pub running: &'a [RunningJob],
    /// The shared availability timeline (free cores from `now` into the
    /// future), maintained incrementally by the simulation core. This is
    /// how backfilling sees future reservations and down/draining
    /// windows; policies must not mutate it — clone into a scratch plan
    /// to lay tentative reservations.
    pub profile: &'a AvailabilityProfile,
}

/// A scheduling algorithm.
pub trait Scheduler {
    fn name(&self) -> &'static str;

    /// Decide which queued jobs start now; allocations are committed on
    /// `cluster` and returned in decision order.
    fn schedule(&mut self, input: &SchedInput<'_>, cluster: &mut Cluster) -> Vec<Allocation>;

    /// Phase 0 of a dispatch round: running jobs this policy wants
    /// evicted *before* allocation (preemption-capable policies only —
    /// see [`PreemptiveScheduler`]). The driver checkpoints/requeues the
    /// victims, then calls [`Scheduler::schedule`] on the freed cluster.
    fn preempt(&mut self, _input: &SchedInput<'_>, _cluster: &Cluster) -> Vec<JobId> {
        Vec::new()
    }

    /// Whether the algorithm reads `SchedInput::running`. Since the
    /// availability-profile refactor only the preemption layer does —
    /// planning policies read `SchedInput::profile` instead — so the
    /// driver skips building the running-job snapshot for every stock
    /// policy (§Perf). Defaults to true for third-party schedulers.
    fn uses_running_info(&self) -> bool {
        true
    }
}

/// Policy selector (config/CLI surface).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Policy {
    Fcfs,
    Sjf,
    Ljf,
    FcfsBestFit,
    #[default]
    FcfsBackfill,
    /// Conservative backfilling: reservations for every queued job.
    ConservativeBackfill,
}

impl Policy {
    pub const ALL: [Policy; 6] = [
        Policy::Fcfs,
        Policy::Sjf,
        Policy::Ljf,
        Policy::FcfsBestFit,
        Policy::FcfsBackfill,
        Policy::ConservativeBackfill,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            Policy::Fcfs => "fcfs",
            Policy::Sjf => "sjf",
            Policy::Ljf => "ljf",
            Policy::FcfsBestFit => "fcfs-bestfit",
            Policy::FcfsBackfill => "fcfs-backfill",
            Policy::ConservativeBackfill => "cons-backfill",
        }
    }

    /// Instantiate the scheduler for this policy with the default
    /// (native) scorer.
    pub fn build(self) -> Box<dyn Scheduler> {
        match self {
            Policy::Fcfs => Box::new(FcfsScheduler::new()),
            Policy::Sjf => Box::new(SjfScheduler::new()),
            Policy::Ljf => Box::new(LjfScheduler::new()),
            Policy::FcfsBestFit => Box::new(BestFitScheduler::new()),
            Policy::FcfsBackfill => Box::new(BackfillScheduler::new()),
            Policy::ConservativeBackfill => Box::new(ConservativeScheduler::new()),
        }
    }
}

impl FromStr for Policy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "fcfs" => Ok(Policy::Fcfs),
            "sjf" => Ok(Policy::Sjf),
            "ljf" => Ok(Policy::Ljf),
            "fcfs-bestfit" | "bestfit" | "best-fit" => Ok(Policy::FcfsBestFit),
            "fcfs-backfill" | "backfill" | "easy" => Ok(Policy::FcfsBackfill),
            "cons-backfill" | "conservative" => Ok(Policy::ConservativeBackfill),
            other => Err(format!(
                "unknown policy {other:?} (expected fcfs|sjf|ljf|fcfs-bestfit|fcfs-backfill)"
            )),
        }
    }
}

impl std::fmt::Display for Policy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_roundtrip() {
        for p in Policy::ALL {
            assert_eq!(p.as_str().parse::<Policy>().unwrap(), p);
        }
    }

    #[test]
    fn policy_aliases() {
        assert_eq!("easy".parse::<Policy>().unwrap(), Policy::FcfsBackfill);
        assert_eq!("best-fit".parse::<Policy>().unwrap(), Policy::FcfsBestFit);
        assert!("mystery".parse::<Policy>().is_err());
    }

    #[test]
    fn build_matches_name() {
        assert_eq!(Policy::Fcfs.build().name(), "fcfs");
        assert_eq!(Policy::Sjf.build().name(), "sjf");
        assert_eq!(Policy::Ljf.build().name(), "ljf");
        assert_eq!(Policy::FcfsBestFit.build().name(), "fcfs-bestfit");
        assert_eq!(Policy::FcfsBackfill.build().name(), "fcfs-backfill");
        assert_eq!(Policy::ConservativeBackfill.build().name(), "cons-backfill");
    }
}
