//! Conservative backfilling: every queued job gets a reservation (not
//! just the head, as in EASY). A job may start now only if its earliest
//! feasible slot *is* now given all earlier arrivals' reservations — so
//! no job is ever delayed by a later arrival, at the cost of fewer
//! backfill opportunities. The paper lists richer backfilling among the
//! techniques its simulator is meant to host; this is the classic
//! comparator (Mu'alem & Feitelson 2001) and an ablation point for the
//! EASY scheduler.

use crate::resources::{AllocPolicy, Allocation, Cluster};
use crate::sched::{SchedInput, Scheduler};

/// Future free-core profile: breakpoints (time, free) with free constant
/// until the next breakpoint; last entry extends to infinity.
#[derive(Debug, Clone)]
pub(crate) struct Profile {
    points: Vec<(u64, u64)>,
}

impl Profile {
    /// Build from current free cores and (est_end, cores) releases.
    pub fn new(now: u64, free_now: u64, releases: &mut Vec<(u64, u64)>) -> Profile {
        releases.sort_unstable();
        let mut points = vec![(now, free_now)];
        for &(t, c) in releases.iter() {
            let last = *points.last().unwrap();
            let t = t.max(now);
            if t == last.0 {
                points.last_mut().unwrap().1 = last.1 + c;
            } else {
                points.push((t, last.1 + c));
            }
        }
        Profile { points }
    }

    /// Earliest time >= `from` at which `cores` are free continuously for
    /// `duration`. The profile is finite and ends at full capacity, so a
    /// feasible job always finds a slot.
    pub fn earliest_slot(&self, from: u64, cores: u64, duration: u64) -> Option<u64> {
        let n = self.points.len();
        for i in 0..n {
            let (t_i, _) = self.points[i];
            let start = t_i.max(from);
            // Check [start, start+duration) against every overlapping
            // segment.
            let end = start.saturating_add(duration);
            let ok = self
                .points
                .iter()
                .enumerate()
                .all(|(j, &(t_j, free_j))| {
                    let seg_start = t_j;
                    let seg_end =
                        self.points.get(j + 1).map(|p| p.0).unwrap_or(u64::MAX);
                    // Segment overlaps the candidate interval?
                    if seg_end <= start || seg_start >= end {
                        true
                    } else {
                        free_j >= cores
                    }
                });
            if ok {
                return Some(start);
            }
        }
        None
    }

    /// Reserve `cores` over [start, start+duration): subtract from every
    /// overlapping segment, splitting breakpoints as needed.
    pub fn reserve(&mut self, start: u64, cores: u64, duration: u64) {
        let end = start.saturating_add(duration);
        self.split_at(start);
        self.split_at(end);
        for p in self.points.iter_mut() {
            if p.0 >= start && p.0 < end {
                debug_assert!(p.1 >= cores, "reservation over-subscribes profile");
                p.1 -= cores;
            }
        }
    }

    fn split_at(&mut self, t: u64) {
        if t == u64::MAX {
            return;
        }
        match self.points.binary_search_by_key(&t, |p| p.0) {
            Ok(_) => {}
            Err(idx) => {
                if idx == 0 {
                    return; // before profile start: nothing to split
                }
                let free = self.points[idx - 1].1;
                self.points.insert(idx, (t, free));
            }
        }
    }

    #[cfg(test)]
    fn free_at(&self, t: u64) -> u64 {
        let mut free = self.points[0].1;
        for &(pt, pf) in &self.points {
            if pt <= t {
                free = pf;
            }
        }
        free
    }
}

/// Conservative backfilling scheduler.
#[derive(Debug, Default)]
pub struct ConservativeScheduler;

impl ConservativeScheduler {
    pub fn new() -> Self {
        ConservativeScheduler
    }
}

impl Scheduler for ConservativeScheduler {
    fn name(&self) -> &'static str {
        "cons-backfill"
    }

    fn schedule(&mut self, input: &SchedInput<'_>, cluster: &mut Cluster) -> Vec<Allocation> {
        let now = input.now.ticks();
        let mut releases: Vec<(u64, u64)> =
            input.running.iter().map(|r| (r.est_end.ticks(), r.cores)).collect();
        let mut profile = Profile::new(now, cluster.free_cores(), &mut releases);
        let mut out = Vec::new();
        for job in input.queue.iter() {
            if !cluster.feasible(job) {
                continue;
            }
            let est = job.est_runtime.ticks().max(1);
            let Some(start) = profile.earliest_slot(now, job.cores, est) else {
                continue; // cannot happen for feasible jobs (profile ends full)
            };
            profile.reserve(start, job.cores, est);
            if start == now {
                if let Some(a) = cluster.allocate(job, AllocPolicy::FirstFit) {
                    out.push(a);
                } else {
                    // Profile said "fits now" but placement failed — can
                    // only happen on per-node memory constraints; treat
                    // as reserved-for-later.
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::time::SimTime;
    use crate::job::{Job, WaitQueue};
    use crate::sched::{Policy, RunningJob};

    #[test]
    fn profile_slots_and_reservations() {
        // 4 free now, +4 at t=100.
        let mut p = Profile::new(0, 4, &mut vec![(100, 4)]);
        assert_eq!(p.free_at(0), 4);
        assert_eq!(p.free_at(100), 8);
        // 6 cores for 50: earliest at t=100.
        assert_eq!(p.earliest_slot(0, 6, 50), Some(100));
        // 4 cores for 1000: now.
        assert_eq!(p.earliest_slot(0, 4, 1000), Some(0));
        p.reserve(0, 4, 1000);
        assert_eq!(p.free_at(0), 0);
        assert_eq!(p.free_at(100), 4);
        assert_eq!(p.free_at(1000), 8);
        // 6-core job now has to wait until the first reservation ends.
        assert_eq!(p.earliest_slot(0, 6, 10), Some(1000));
    }

    #[test]
    fn starts_only_jobs_with_immediate_slots() {
        let mut c = Cluster::homogeneous(1, 8, 0);
        let _r = c.allocate(&Job::simple(99, 0, 4, 100), AllocPolicy::FirstFit).unwrap();
        let running = [RunningJob { id: 99, cores: 4, est_end: SimTime(100), start: SimTime(0), priority: 0 }];
        let mut q = WaitQueue::new();
        q.push(Job::with_estimate(1, 0, 8, 100, 100)); // reserved at t=100
        q.push(Job::with_estimate(2, 1, 4, 50, 50)); // fits now & by t=100
        let input = SchedInput { now: SimTime(0), queue: &q, running: &running };
        let started: Vec<u64> = ConservativeScheduler::new()
            .schedule(&input, &mut c)
            .iter()
            .map(|a| a.job_id)
            .collect();
        assert_eq!(started, vec![2]);
    }

    #[test]
    fn never_delays_any_reservation() {
        // EASY would backfill job 3 (2 cores, long) against head job 2's
        // extra cores; conservative must NOT if it delays job 2's...
        // actually stronger: job 3 long on cores reserved by *job 4*'s
        // reservation must not start.
        let mut c = Cluster::homogeneous(1, 8, 0);
        let _r = c.allocate(&Job::simple(99, 0, 4, 100), AllocPolicy::FirstFit).unwrap();
        let running = [RunningJob { id: 99, cores: 4, est_end: SimTime(100), start: SimTime(0), priority: 0 }];
        let mut q = WaitQueue::new();
        q.push(Job::with_estimate(1, 0, 6, 100, 100)); // reserved t=100 (extra 2)
        q.push(Job::with_estimate(2, 1, 2, 300, 300)); // reserved t=100..? fits extra at 100
        q.push(Job::with_estimate(3, 2, 2, 10_000, 10_000));
        let input = SchedInput { now: SimTime(0), queue: &q, running: &running };
        let started: Vec<u64> = ConservativeScheduler::new()
            .schedule(&input, &mut c)
            .iter()
            .map(|a| a.job_id)
            .collect();
        // Job 2's reservation lands at t=100 on the extra cores; job 3
        // would then collide with it until t=400, and with the full
        // machine being busy, its earliest slot is not "now": nothing
        // starts... unless a slot exists now: 4 cores free now; job 2
        // needs 2 for 300 -> interval [0,300) has 4 free until 100 then
        // depends on reservations: job 1 reserved [100,200) on 6 cores
        // leaves 2; job 2 CAN run [0,300)? [100,200) has 8-6=2 free, job
        // 2 takes them -> yes, job 2 starts now. Job 3 then finds zero
        // free in [100,200): waits.
        assert_eq!(started, vec![2]);
    }

    #[test]
    fn conservative_no_worse_than_fcfs_for_head() {
        // Degenerates to FCFS when nothing can backfill.
        let mut c = Cluster::homogeneous(1, 4, 0);
        let mut q = WaitQueue::new();
        q.push(Job::with_estimate(1, 0, 4, 100, 100));
        q.push(Job::with_estimate(2, 1, 4, 100, 100));
        let input = SchedInput { now: SimTime(0), queue: &q, running: &[] };
        let started: Vec<u64> = ConservativeScheduler::new()
            .schedule(&input, &mut c)
            .iter()
            .map(|a| a.job_id)
            .collect();
        assert_eq!(started, vec![1]);
    }

    #[test]
    fn end_to_end_conservative_vs_easy() {
        // On a contended workload conservative waits are >= EASY's for
        // the backfilled jobs but no head job is ever delayed.
        let w = crate::trace::Das2Model::default()
            .generate(2_000, 13)
            .scale_arrivals(0.4)
            .drop_infeasible();
        let easy = crate::sim::run_policy(w.clone(), Policy::FcfsBackfill);
        let cons = crate::sim::run_policy(w, Policy::ConservativeBackfill);
        assert_eq!(cons.completed.len(), easy.completed.len());
        let mw = |r: &crate::sim::SimReport| r.wait_stats().mean_wait;
        // Conservative is more cautious: mean wait at least EASY's minus
        // noise (it cannot beat EASY by much on this workload family).
        assert!(mw(&cons) + 1e-9 >= mw(&easy) * 0.8, "cons {} easy {}", mw(&cons), mw(&easy));
    }

    #[test]
    fn profile_split_is_stable() {
        let mut p = Profile::new(10, 8, &mut vec![(20, 4), (30, 4)]);
        p.reserve(15, 2, 10); // splits at 15 and 25
        assert_eq!(p.free_at(10), 8);
        assert_eq!(p.free_at(15), 6);
        assert_eq!(p.free_at(20), 10);
        assert_eq!(p.free_at(25), 12);
        assert_eq!(p.free_at(30), 16);
    }
}
