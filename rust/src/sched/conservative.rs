//! Conservative backfilling: every queued job gets a reservation (not
//! just the head, as in EASY). A job may start now only if its earliest
//! feasible slot *is* now given all earlier jobs' reservations — so no
//! job is ever delayed by one the ordering ranks behind it, at the cost
//! of fewer backfill opportunities. "Earlier" is `SchedInput::order`:
//! under fair share the reservation ladder is built in decayed-usage
//! order, so light users reserve first. The paper lists richer
//! backfilling among the techniques its simulator is meant to host; this
//! is the classic comparator (Mu'alem & Feitelson 2001) and an ablation
//! point for the EASY scheduler.
//!
//! Planning runs on the shared availability timeline
//! ([`AvailabilityProfile`], `SchedInput::profile`): the round clones it
//! into a scratch plan and lays one multi-resource reservation per
//! queued job with the binary-searched `earliest_slot_v` — so
//! reservations, outage windows and (on memory-aware machines) planned
//! memory pressure bound every slot.

use crate::job::Job;
use crate::resources::{AllocPolicy, Allocation, AvailabilityProfile, Cluster};
use crate::sched::fcfs::borrow_scratch;
use crate::sched::{QueueOrder, RoundScratch, SchedInput, Scheduler};

/// Conservative backfilling scheduler.
#[derive(Debug, Default, Clone, Copy)]
pub struct ConservativeScheduler;

impl ConservativeScheduler {
    pub fn new() -> Self {
        ConservativeScheduler
    }
}

impl Scheduler for ConservativeScheduler {
    fn name(&self) -> &'static str {
        "cons-backfill"
    }

    /// Future availability comes from `SchedInput::profile`; the
    /// running-job snapshot is not needed (§Perf: the driver skips it).
    fn uses_running_info(&self) -> bool {
        false
    }

    fn clone_box(&self) -> Option<Box<dyn Scheduler>> {
        Some(Box::new(*self))
    }

    fn schedule(&mut self, input: &SchedInput<'_>, cluster: &mut Cluster) -> Vec<Allocation> {
        let mut local = RoundScratch::default();
        let mut guard = None;
        let scratch = borrow_scratch(input, &mut guard, &mut local);
        let RoundScratch { order_ids, order_keys, plan, .. } = scratch;
        // Scratch plan: the shared timeline overwritten in place (no
        // per-round clone — the reservation-ladder holds below land on
        // the reusable buffer).
        plan.copy_from(input.profile);
        if input.order.order_into(input.queue, input.now, order_ids, order_keys) {
            let mut it =
                order_ids.iter().map(|id| input.queue.get(*id).expect("ordered id not in queue"));
            Self::run_round(input, cluster, &mut it, plan)
        } else {
            let mut it = input.queue.iter();
            Self::run_round(input, cluster, &mut it, plan)
        }
    }
}

impl ConservativeScheduler {
    fn run_round<'a>(
        input: &SchedInput<'a>,
        cluster: &mut Cluster,
        order: &mut dyn Iterator<Item = &'a Job>,
        plan: &mut AvailabilityProfile,
    ) -> Vec<Allocation> {
        let now = input.now.ticks();
        let mut out = Vec::new();
        for job in order {
            if !cluster.feasible(job) {
                continue;
            }
            let est = job.est_runtime.ticks().max(1);
            let Some(start) = plan.earliest_slot_v(now, job.demand(), est) else {
                continue; // cannot happen for feasible jobs (timeline ends full)
            };
            plan.hold_v(start, start.saturating_add(est), job.demand());
            if start == now {
                if let Some(a) = cluster.allocate(job, AllocPolicy::FirstFit) {
                    out.push(a);
                } else {
                    // The timeline said "fits now" but placement failed —
                    // per-node memory fragmentation or a job overrunning
                    // its estimate; its reservation stays in the plan.
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::time::SimTime;
    use crate::job::{Job, WaitQueue};
    use crate::sched::{ArrivalOrder, Policy, RunningJob};

    fn profile_of(cluster: &Cluster, running: &[RunningJob], now: u64) -> AvailabilityProfile {
        let releases: Vec<(u64, u64)> =
            running.iter().map(|r| (r.est_end.ticks(), r.cores)).collect();
        AvailabilityProfile::from_releases(
            now,
            cluster.free_cores(),
            cluster.total_cores(),
            &releases,
        )
    }

    fn run(
        queue: &WaitQueue,
        running: &[RunningJob],
        cluster: &mut Cluster,
        now: u64,
    ) -> Vec<u64> {
        let profile = profile_of(cluster, running, now);
        let input = SchedInput {
            now: SimTime(now),
            queue,
            running,
            profile: &profile,
            order: &ArrivalOrder,
            scratch: None,
        };
        ConservativeScheduler::new()
            .schedule(&input, cluster)
            .iter()
            .map(|a| a.job_id)
            .collect()
    }

    #[test]
    fn profile_slots_and_reservations() {
        // 4 free now, +4 at t=100 (the old private-profile smoke test,
        // now exercising the shared planner).
        let mut p = AvailabilityProfile::from_releases(0, 4, 8, &[(100, 4)]);
        assert_eq!(p.free_at(0), 4);
        assert_eq!(p.free_at(100), 8);
        // 6 cores for 50: earliest at t=100.
        assert_eq!(p.earliest_slot(0, 6, 50), Some(100));
        // 4 cores for 1000: now.
        assert_eq!(p.earliest_slot(0, 4, 1000), Some(0));
        p.hold(0, 1000, 4);
        assert_eq!(p.free_at(0), 0);
        assert_eq!(p.free_at(100), 4);
        assert_eq!(p.free_at(1000), 8);
        // 6-core job now has to wait until the first reservation ends.
        assert_eq!(p.earliest_slot(0, 6, 10), Some(1000));
    }

    #[test]
    fn starts_only_jobs_with_immediate_slots() {
        let mut c = Cluster::homogeneous(1, 8, 0);
        let _r = c.allocate(&Job::simple(99, 0, 4, 100), AllocPolicy::FirstFit).unwrap();
        let running = [RunningJob { id: 99, cores: 4, est_end: SimTime(100), start: SimTime(0), priority: 0 }];
        let mut q = WaitQueue::new();
        q.push(Job::with_estimate(1, 0, 8, 100, 100)); // reserved at t=100
        q.push(Job::with_estimate(2, 1, 4, 50, 50)); // fits now & by t=100
        let started = run(&q, &running, &mut c, 0);
        assert_eq!(started, vec![2]);
    }

    #[test]
    fn never_delays_any_reservation() {
        // EASY would backfill job 3 (2 cores, long) against head job 2's
        // extra cores; conservative must NOT if it delays job 2's...
        // actually stronger: job 3 long on cores reserved by *job 4*'s
        // reservation must not start.
        let mut c = Cluster::homogeneous(1, 8, 0);
        let _r = c.allocate(&Job::simple(99, 0, 4, 100), AllocPolicy::FirstFit).unwrap();
        let running = [RunningJob { id: 99, cores: 4, est_end: SimTime(100), start: SimTime(0), priority: 0 }];
        let mut q = WaitQueue::new();
        q.push(Job::with_estimate(1, 0, 6, 100, 100)); // reserved t=100 (extra 2)
        q.push(Job::with_estimate(2, 1, 2, 300, 300)); // reserved t=100..? fits extra at 100
        q.push(Job::with_estimate(3, 2, 2, 10_000, 10_000));
        // Job 2's reservation lands on the extra cores; job 3 would then
        // collide with it and with job 1's window — only job 2 can start
        // now (4 free; its whole [0,300) window keeps >= 2 free).
        let started = run(&q, &running, &mut c, 0);
        assert_eq!(started, vec![2]);
    }

    #[test]
    fn conservative_no_worse_than_fcfs_for_head() {
        // Degenerates to FCFS when nothing can backfill.
        let mut c = Cluster::homogeneous(1, 4, 0);
        let mut q = WaitQueue::new();
        q.push(Job::with_estimate(1, 0, 4, 100, 100));
        q.push(Job::with_estimate(2, 1, 4, 100, 100));
        let started = run(&q, &[], &mut c, 0);
        assert_eq!(started, vec![1]);
    }

    #[test]
    fn plans_around_future_reservation() {
        // 8 free cores, but an advance reservation holds the whole
        // machine over [40, 140): a 100-tick job cannot start now even
        // though the cores are free at this instant.
        let mut c = Cluster::homogeneous(1, 8, 0);
        let mut profile = AvailabilityProfile::new(0, 8, 8);
        profile.add_reservation_hold(40, 140, 8);
        let mut q = WaitQueue::new();
        q.push(Job::with_estimate(1, 0, 8, 100, 100)); // collides: waits for 140
        q.push(Job::with_estimate(2, 1, 8, 40, 40)); // exactly clears the window start
        let input = SchedInput {
            now: SimTime(0),
            queue: &q,
            running: &[],
            profile: &profile,
            order: &ArrivalOrder,
            scratch: None,
        };
        let started: Vec<u64> = ConservativeScheduler::new()
            .schedule(&input, &mut c)
            .iter()
            .map(|a| a.job_id)
            .collect();
        // Job 1 is reserved at t=140; job 2 fits [0, 40) *and* does not
        // collide with job 1's reservation -> starts now.
        assert_eq!(started, vec![2]);
    }

    #[test]
    fn memory_bounds_reservation_slots() {
        use crate::resources::ResourceVector;
        // Single node, 8 cores, 1000 MB; 700 MB held until t=100. A
        // 500 MB job's slot is t=100 even though its cores are free now.
        let mut c = Cluster::homogeneous(1, 8, 1000);
        let running = Job::with_memory(99, 0, 2, 700, 100);
        let _r = c.allocate(&running, AllocPolicy::FirstFit).unwrap();
        let mut profile = AvailabilityProfile::new_v(
            0,
            ResourceVector::new(c.free_cores(), c.free_memory_mb()),
            ResourceVector::new(c.total_cores(), c.total_memory_mb()),
        );
        profile.hold_v(0, 100, ResourceVector::new(2, 700));
        let mut q = WaitQueue::new();
        q.push(Job::with_memory(1, 0, 2, 500, 50)); // memory-blocked until 100
        q.push(Job::with_memory(2, 1, 2, 100, 50)); // fits both dims now
        let input = SchedInput {
            now: SimTime(0),
            queue: &q,
            running: &[],
            profile: &profile,
            order: &ArrivalOrder,
            scratch: None,
        };
        let started: Vec<u64> = ConservativeScheduler::new()
            .schedule(&input, &mut c)
            .iter()
            .map(|a| a.job_id)
            .collect();
        assert_eq!(started, vec![2], "memory-blocked job must wait for its slot");
    }

    #[test]
    fn end_to_end_conservative_vs_easy() {
        // On a contended workload conservative waits are >= EASY's for
        // the backfilled jobs but no head job is ever delayed.
        let w = crate::trace::Das2Model::default()
            .generate(2_000, 13)
            .scale_arrivals(0.4)
            .drop_infeasible();
        let easy = crate::sim::run_policy(w.clone(), Policy::FcfsBackfill);
        let cons = crate::sim::run_policy(w, Policy::ConservativeBackfill);
        assert_eq!(cons.completed.len(), easy.completed.len());
        let mw = |r: &crate::sim::SimReport| r.wait_stats().mean_wait;
        // Conservative is more cautious: mean wait at least EASY's minus
        // noise (it cannot beat EASY by much on this workload family).
        assert!(mw(&cons) + 1e-9 >= mw(&easy) * 0.8, "cons {} easy {}", mw(&cons), mw(&easy));
    }
}
