//! FCFS with Best Fit (paper §2.1): arrival order, but placement picks the
//! node whose free-core count most closely matches the request, minimizing
//! fragmentation. Completion-time behaviour stays FCFS-like (the paper
//! notes Best Fit "does not significantly improve job completion times");
//! what improves is resource matching.
//!
//! Since the queue-ordering redesign this too is the
//! [`BlockingScheduler`](crate::sched::BlockingScheduler) — arrival order
//! plus `AllocPolicy::BestFit` placement; this module keeps its
//! behavioural tests.

#[cfg(test)]
mod tests {
    use crate::core::time::SimTime;
    use crate::job::{Job, WaitQueue};
    use crate::resources::{AllocPolicy, Cluster};
    use crate::sched::{ArrivalOrder, Policy, SchedInput, Scheduler};

    fn input<'a>(queue: &'a WaitQueue) -> SchedInput<'a> {
        SchedInput {
            now: SimTime(0),
            queue,
            running: &[],
            profile: &crate::resources::AvailabilityProfile::EMPTY,
            order: &ArrivalOrder,
            scratch: None,
        }
    }

    #[test]
    fn placement_minimizes_slack() {
        let mut q = WaitQueue::new();
        q.push(Job::simple(1, 0, 4, 10));
        let mut c = Cluster::heterogeneous(&[(16, 0), (4, 0), (8, 0)]);
        let allocs = Policy::FcfsBestFit.build().schedule(&input(&q), &mut c);
        assert_eq!(allocs.len(), 1);
        // Node 1 has exactly 4 free cores: the tightest fit.
        assert_eq!(allocs[0].taken, vec![(1, 4, 0)]);
    }

    #[test]
    fn order_is_still_fcfs() {
        let mut q = WaitQueue::new();
        q.push(Job::with_estimate(1, 0, 2, 10, 1000)); // long, first
        q.push(Job::with_estimate(2, 1, 2, 10, 1)); // short, second
        let mut c = Cluster::homogeneous(1, 2, 0);
        let allocs = Policy::FcfsBestFit.build().schedule(&input(&q), &mut c);
        // Only room for one: the FIRST, not the shortest.
        assert_eq!(allocs.iter().map(|a| a.job_id).collect::<Vec<_>>(), vec![1]);
    }

    #[test]
    fn blocking_like_fcfs() {
        let mut q = WaitQueue::new();
        q.push(Job::simple(1, 0, 8, 10));
        q.push(Job::simple(2, 1, 1, 10));
        let mut c = Cluster::homogeneous(2, 4, 0);
        let blocker = c.allocate(&Job::simple(99, 0, 1, 1), AllocPolicy::FirstFit).unwrap();
        let allocs = Policy::FcfsBestFit.build().schedule(&input(&q), &mut c);
        assert!(allocs.is_empty());
        c.release(&blocker);
    }
}
