//! Preemption-capable policy layer (fault/preemption subsystem).
//!
//! Real HPC schedulers bridge batch and interactive workloads by letting
//! high-priority work evict low-priority work under a checkpoint/restart
//! contract (Reuther et al. 2017); simulators become research vehicles
//! once dispatching decisions can be revisited like this (AccaSim,
//! Galleguillos et al. 2018). [`PreemptiveScheduler`] adds that layer on
//! top of *any* existing [`Scheduler`] — FCFS, SJF, LJF, BestFit, EASY
//! and conservative backfilling all compose with it unchanged:
//!
//! * the inner policy keeps making the start decisions;
//! * before each round, the wrapper may name running victims to evict
//!   (`Scheduler::preempt`) when the oldest eligible waiting job has
//!   starved past a threshold and strictly lower-priority work occupies
//!   the cores it needs;
//! * the simulation driver (not this module) owns the actual eviction:
//!   checkpoint/requeue the victims, charge the overheads from
//!   [`PreemptionConfig`], then run the inner policy on the freed
//!   cluster. The driver reuses the same config to decide what happens
//!   to jobs hit by node failures and advance reservations.

use crate::core::time::SimDuration;
use crate::job::JobId;
use crate::resources::Cluster;
use crate::sched::{SchedInput, Scheduler};

/// What eviction does to a running job.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PreemptionMode {
    /// No planned eviction; jobs killed by failures lose all progress.
    #[default]
    None,
    /// Evict by killing: the victim requeues and starts over. Failure
    /// victims also start over.
    Kill,
    /// Checkpoint/restart: evicted jobs keep their progress and are
    /// charged `checkpoint_overhead + restart_overhead` extra ticks;
    /// failure victims resume from the periodic checkpoint for
    /// `restart_overhead` (the fault-tolerance contract of Reuther et
    /// al. 2017's preemption mechanisms).
    Checkpoint,
}

impl PreemptionMode {
    pub fn as_str(self) -> &'static str {
        match self {
            PreemptionMode::None => "none",
            PreemptionMode::Kill => "kill",
            PreemptionMode::Checkpoint => "checkpoint",
        }
    }
}

impl std::str::FromStr for PreemptionMode {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "none" | "off" => Ok(PreemptionMode::None),
            "kill" => Ok(PreemptionMode::Kill),
            "checkpoint" | "ckpt" => Ok(PreemptionMode::Checkpoint),
            other => Err(format!(
                "unknown preemption mode {other:?} (expected none|kill|checkpoint)"
            )),
        }
    }
}

impl std::fmt::Display for PreemptionMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Knobs of the preemption layer (config surface `preemption.*`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PreemptionConfig {
    pub mode: PreemptionMode,
    /// Ticks charged for writing a checkpoint at eviction.
    pub checkpoint_overhead: SimDuration,
    /// Ticks charged for restoring from the checkpoint at restart.
    pub restart_overhead: SimDuration,
    /// Evict for a waiting job only after it has starved this long
    /// (ticks); 0 disables starvation-driven eviction, leaving only
    /// failure- and reservation-driven preemption active.
    pub starvation_threshold: SimDuration,
}

impl PreemptionConfig {
    pub fn enabled(&self) -> bool {
        self.mode != PreemptionMode::None
    }

    /// Whether evicted jobs keep their progress.
    pub fn keeps_progress(&self) -> bool {
        self.mode == PreemptionMode::Checkpoint
    }

    /// Total overhead charged per eviction (zero in kill mode — the
    /// price there is the lost progress itself).
    pub fn eviction_overhead(&self) -> SimDuration {
        match self.mode {
            PreemptionMode::Checkpoint => self.checkpoint_overhead + self.restart_overhead,
            _ => SimDuration::ZERO,
        }
    }
}

/// Wraps any scheduler with starvation-driven eviction.
pub struct PreemptiveScheduler {
    inner: Box<dyn Scheduler>,
    name: &'static str,
    cfg: PreemptionConfig,
    /// Thrash guard: the starver the last eviction round paid for. An
    /// inner policy that hands the freed cores to *other* jobs (SJF
    /// restarting the just-evicted shortest victim, say) must not buy
    /// eviction after eviction for a starver it never starts: one round
    /// per starvation episode. Cleared once the starver leaves the
    /// queue (it started), so a later re-queue can earn a new round.
    last_eviction: Option<JobId>,
}

impl PreemptiveScheduler {
    pub fn new(inner: Box<dyn Scheduler>, cfg: PreemptionConfig) -> PreemptiveScheduler {
        let name = inner.name();
        PreemptiveScheduler { inner, name, cfg, last_eviction: None }
    }
}

impl Scheduler for PreemptiveScheduler {
    /// The policy identity stays the inner algorithm's; preemption is a
    /// mode, reported separately by the simulation driver.
    fn name(&self) -> &'static str {
        self.name
    }

    fn schedule(&mut self, input: &SchedInput<'_>, cluster: &mut Cluster) -> Vec<crate::resources::Allocation> {
        self.inner.schedule(input, cluster)
    }

    /// Cloneable exactly when the wrapped policy is.
    fn clone_box(&self) -> Option<Box<dyn Scheduler>> {
        Some(Box::new(PreemptiveScheduler {
            inner: self.inner.clone_box()?,
            name: self.name,
            cfg: self.cfg,
            last_eviction: self.last_eviction,
        }))
    }

    fn preempt(&mut self, input: &SchedInput<'_>, cluster: &Cluster) -> Vec<JobId> {
        if !self.cfg.enabled() || self.cfg.starvation_threshold == SimDuration::ZERO {
            return Vec::new();
        }
        // The starving job: oldest waiting job that is feasible on the
        // machine. (Queue order is arrival order.)
        let Some(starving) = input.queue.iter().find(|j| cluster.feasible(j)) else {
            return Vec::new();
        };
        if input.now - starving.submit < self.cfg.starvation_threshold {
            return Vec::new();
        }
        if starving.cores <= cluster.free_cores() {
            return Vec::new(); // it will start this round anyway
        }
        if let Some(id) = self.last_eviction {
            if input.queue.get(id).is_none() {
                // The job we last evicted for is no longer waiting — the
                // eviction worked (or it completed); arm a new round.
                self.last_eviction = None;
            }
        }
        if self.last_eviction == Some(starving.id) {
            return Vec::new(); // this starvation episode already had its round
        }
        // Candidate victims: strictly lower priority, youngest current
        // segment first (least sunk work), ids as the final tie-break so
        // the choice is deterministic.
        let mut victims: Vec<_> = input
            .running
            .iter()
            .filter(|r| r.priority < starving.priority)
            .collect();
        victims.sort_by(|a, b| {
            a.priority
                .cmp(&b.priority)
                .then(b.start.cmp(&a.start))
                .then(b.id.cmp(&a.id))
        });
        let mut freed = cluster.free_cores();
        let mut chosen = Vec::new();
        for v in victims {
            if freed >= starving.cores {
                break;
            }
            freed += v.cores;
            chosen.push(v.id);
        }
        if freed >= starving.cores {
            self.last_eviction = Some(starving.id);
            chosen
        } else {
            Vec::new() // eviction would not unblock the starver; don't thrash
        }
    }

    /// The wrapper itself only needs the running set while starvation
    /// eviction can actually fire; otherwise defer to the inner policy
    /// so e.g. preemptive FCFS keeps skipping the snapshot (§Perf).
    fn uses_running_info(&self) -> bool {
        self.cfg.starvation_threshold > SimDuration::ZERO || self.inner.uses_running_info()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::time::SimTime;
    use crate::job::{Job, WaitQueue};
    use crate::sched::{Policy, RunningJob};

    fn cfg(threshold: u64) -> PreemptionConfig {
        PreemptionConfig {
            mode: PreemptionMode::Checkpoint,
            checkpoint_overhead: SimDuration(10),
            restart_overhead: SimDuration(5),
            starvation_threshold: SimDuration(threshold),
        }
    }

    fn running(id: u64, cores: u64, start: u64, priority: u8) -> RunningJob {
        RunningJob { id, cores, est_end: SimTime(start + 1000), start: SimTime(start), priority }
    }

    #[test]
    fn mode_parses_and_roundtrips() {
        for m in [PreemptionMode::None, PreemptionMode::Kill, PreemptionMode::Checkpoint] {
            assert_eq!(m.as_str().parse::<PreemptionMode>().unwrap(), m);
        }
        assert_eq!("ckpt".parse::<PreemptionMode>().unwrap(), PreemptionMode::Checkpoint);
        assert!("shoot".parse::<PreemptionMode>().is_err());
    }

    #[test]
    fn eviction_overhead_by_mode() {
        assert_eq!(cfg(1).eviction_overhead(), SimDuration(15));
        let kill = PreemptionConfig { mode: PreemptionMode::Kill, ..cfg(1) };
        assert_eq!(kill.eviction_overhead(), SimDuration::ZERO);
        assert!(!PreemptionConfig::default().enabled());
    }

    #[test]
    fn evicts_youngest_lowest_priority_until_starver_fits() {
        // 8-core machine, fully busy with priority-0 work; a priority-2
        // job starving past the threshold needs 4 cores.
        let mut c = crate::resources::Cluster::homogeneous(2, 4, 0);
        let a1 = c.allocate(&Job::simple(10, 0, 4, 1000), crate::resources::AllocPolicy::FirstFit).unwrap();
        let a2 = c.allocate(&Job::simple(11, 0, 4, 1000), crate::resources::AllocPolicy::FirstFit).unwrap();
        let _ = (a1, a2);
        let mut q = WaitQueue::new();
        let mut starver = Job::simple(1, 0, 4, 100);
        starver.priority = 2;
        q.push(starver);
        let run = [running(10, 4, 0, 0), running(11, 4, 50, 0)];
        let input = SchedInput {
            now: SimTime(500),
            queue: &q,
            running: &run,
            profile: &crate::resources::AvailabilityProfile::EMPTY,
            order: &crate::sched::ArrivalOrder,
            scratch: None,
        };
        let mut s = PreemptiveScheduler::new(Policy::Fcfs.build(), cfg(100));
        // Youngest segment (job 11, started at 50) goes first, and one
        // victim is enough for a 4-core starver.
        assert_eq!(s.preempt(&input, &c), vec![11]);
    }

    #[test]
    fn does_not_evict_equal_or_higher_priority() {
        let mut c = crate::resources::Cluster::homogeneous(1, 4, 0);
        let _a = c.allocate(&Job::simple(10, 0, 4, 1000), crate::resources::AllocPolicy::FirstFit).unwrap();
        let mut q = WaitQueue::new();
        q.push(Job::simple(1, 0, 4, 100)); // priority 0, same as victim
        let run = [running(10, 4, 0, 0)];
        let input = SchedInput {
            now: SimTime(500),
            queue: &q,
            running: &run,
            profile: &crate::resources::AvailabilityProfile::EMPTY,
            order: &crate::sched::ArrivalOrder,
            scratch: None,
        };
        let mut s = PreemptiveScheduler::new(Policy::Fcfs.build(), cfg(100));
        assert!(s.preempt(&input, &c).is_empty());
    }

    #[test]
    fn no_eviction_below_threshold_or_when_it_cannot_help() {
        let mut c = crate::resources::Cluster::homogeneous(1, 4, 0);
        let _a = c.allocate(&Job::simple(10, 0, 4, 1000), crate::resources::AllocPolicy::FirstFit).unwrap();
        let mut q = WaitQueue::new();
        let mut j = Job::simple(1, 450, 4, 100);
        j.priority = 2;
        q.push(j);
        let run = [running(10, 4, 0, 0)];
        let input = SchedInput {
            now: SimTime(500),
            queue: &q,
            running: &run,
            profile: &crate::resources::AvailabilityProfile::EMPTY,
            order: &crate::sched::ArrivalOrder,
            scratch: None,
        };
        let mut s = PreemptiveScheduler::new(Policy::Fcfs.build(), cfg(100));
        // Waited only 50 < 100 threshold.
        assert!(s.preempt(&input, &c).is_empty());

        // Starved, but victims cannot free enough cores: 8-core ask on a
        // 4-core machine is infeasible and must be skipped entirely.
        let mut q2 = WaitQueue::new();
        let mut big = Job::simple(2, 0, 8, 100);
        big.priority = 2;
        q2.push(big);
        let input2 = SchedInput {
            now: SimTime(500),
            queue: &q2,
            running: &run,
            profile: &crate::resources::AvailabilityProfile::EMPTY,
            order: &crate::sched::ArrivalOrder,
            scratch: None,
        };
        assert!(s.preempt(&input2, &c).is_empty());
    }

    #[test]
    fn wrapper_keeps_inner_name_and_decisions() {
        let mut s = PreemptiveScheduler::new(Policy::Fcfs.build(), cfg(0));
        assert_eq!(s.name(), "fcfs");
        let mut c = crate::resources::Cluster::homogeneous(1, 4, 0);
        let mut q = WaitQueue::new();
        q.push(Job::simple(1, 0, 2, 10));
        let input = SchedInput {
            now: SimTime(0),
            queue: &q,
            running: &[],
            profile: &crate::resources::AvailabilityProfile::EMPTY,
            order: &crate::sched::ArrivalOrder,
            scratch: None,
        };
        // Threshold 0 disables starvation eviction entirely.
        assert!(s.preempt(&input, &c).is_empty());
        let allocs = s.schedule(&input, &mut c);
        assert_eq!(allocs.len(), 1);
    }
}
