//! The simulation components (paper Fig 1), extended with the
//! fault/preemption/reservation subsystem: the scheduler component owns
//! every capacity transition (node failure/repair, reservation claims)
//! and the job-interruption bookkeeping, while `sim::faults` only
//! generates the timed stimuli.

use crate::core::component::{Component, Ctx};
use crate::core::event::{ComponentId, Priority};
use crate::core::stats::TimeSeries;
use crate::core::time::{SimDuration, SimTime};
use crate::job::{Job, JobId, WaitQueue};
use crate::resources::{Allocation, Cluster, NodeState};
use crate::sched::{PreemptionConfig, RunningJob, SchedInput, Scheduler};
use crate::sim::faults::ReservationSpec;
use crate::sim::Ev;
use std::any::Any;
use std::collections::HashMap;

/// Replays a workload as timed `Submit` events (incremental: one
/// self-event per distinct arrival time, so memory stays O(1) in the
/// event queue even for million-job traces).
pub struct JobSource {
    /// Jobs in submit order (reversed internally for O(1) pop).
    jobs: Vec<Job>,
    /// Where submissions go (the scheduler). Set by the builder.
    pub target: ComponentId,
    emitted: u64,
}

impl JobSource {
    pub fn new(mut jobs: Vec<Job>) -> JobSource {
        jobs.sort_by_key(|j| (j.submit, j.id));
        jobs.reverse();
        JobSource { jobs, target: 0, emitted: 0 }
    }

    fn emit_due(&mut self, ctx: &mut Ctx<Ev>) {
        let now = ctx.now();
        while let Some(j) = self.jobs.last() {
            if j.submit > now {
                break;
            }
            let job = self.jobs.pop().unwrap();
            self.emitted += 1;
            ctx.send(self.target, Priority::ARRIVE, Ev::Submit(Box::new(job)));
        }
        if let Some(next) = self.jobs.last() {
            let delay = next.submit - now;
            ctx.schedule_self(delay, Priority::ARRIVE, Ev::NextArrival);
        }
    }
}

impl Component<Ev> for JobSource {
    fn name(&self) -> &str {
        "source"
    }

    fn init(&mut self, ctx: &mut Ctx<Ev>) {
        if let Some(first) = self.jobs.last() {
            let delay = first.submit - ctx.now();
            ctx.schedule_self(delay, Priority::ARRIVE, Ev::NextArrival);
        }
    }

    fn handle(&mut self, ev: Ev, ctx: &mut Ctx<Ev>) {
        match ev {
            Ev::NextArrival => self.emit_due(ctx),
            other => panic!("source got unexpected event {other:?}"),
        }
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn Any {
        self
    }
}

/// Counters of the fault/preemption/reservation subsystem, all zero for
/// fault-free runs.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultCounters {
    /// Node failures applied.
    pub failures: u64,
    /// Node repairs applied.
    pub repairs: u64,
    /// Planned evictions (policy- or reservation-driven).
    pub preemptions: u64,
    /// Failure kills that sent a running job back to the queue.
    pub requeues: u64,
    /// Reservations that came due.
    pub reservations_started: u64,
    /// Claimed nodes that had to drain because preemption was off.
    pub reservations_degraded: u64,
    /// Requested reservation nodes that could not be claimed at all
    /// (not enough Up, unclaimed nodes when the reservation came due).
    pub reservations_short_nodes: u64,
    /// Times a running job was observed on a non-`Up` node (must stay 0;
    /// audited after every capacity transition).
    pub invariant_violations: u64,
}

/// Why a running job is being interrupted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum InterruptReason {
    /// Node failure. Under `PreemptionMode::Checkpoint` jobs are
    /// periodically checkpointed, so the victim resumes with its progress
    /// intact for a `restart_overhead` charge; under any other mode the
    /// unplanned kill loses all progress.
    Failure,
    /// Planned eviction: checkpointed under `PreemptionMode::Checkpoint`
    /// (checkpoint + restart overhead), killed under `PreemptionMode::Kill`.
    Eviction,
}

/// Job Scheduling + Resource Management (paper Fig 1): wait queue, the
/// scheduling algorithm, cluster accounting, lifecycle bookkeeping and
/// event-driven metric recording — plus node lifecycle transitions and
/// preemption for the fault subsystem.
pub struct SchedulerComponent {
    pub cluster: Cluster,
    scheduler: Box<dyn Scheduler>,
    queue: WaitQueue,
    /// Running jobs: id -> (job, allocation, estimated end).
    running: HashMap<JobId, (Job, Allocation, SimTime)>,
    pub completed: Vec<Job>,
    pub rejected: u64,
    pub executor: ComponentId,
    dispatch_pending: bool,
    pub dispatches: u64,
    pub occupancy: TimeSeries,
    pub running_series: TimeSeries,
    pub util_series: TimeSeries,
    /// (t, busy / non-failed cores) — fault subsystem metric.
    pub effective_util_series: TimeSeries,
    /// (t, non-failed cores) — denominator series for the goodput-based
    /// mean effective utilization.
    pub avail_series: TimeSeries,
    /// Preemption knobs; also applied to failure and reservation kills.
    pub preemption: PreemptionConfig,
    /// Advance reservations (specs; claims happen when each comes due).
    pub reservations: Vec<ReservationSpec>,
    /// node id -> reservation index that currently claims it.
    claimed: HashMap<usize, usize>,
    pub fault_counters: FaultCounters,
    /// Core-seconds of progress discarded by kills/failures.
    pub lost_work: f64,
    /// Core-seconds of checkpoint/restart overhead charged.
    pub overhead_work: f64,
    /// Earliest pending starvation-deadline dispatch timer (dispatches
    /// are event-driven, so a starving job needs a timed wake-up for its
    /// eviction round).
    starvation_timer: Option<SimTime>,
}

impl SchedulerComponent {
    pub fn new(cluster: Cluster, scheduler: Box<dyn Scheduler>) -> SchedulerComponent {
        SchedulerComponent {
            cluster,
            scheduler,
            queue: WaitQueue::new(),
            running: HashMap::new(),
            completed: Vec::new(),
            rejected: 0,
            executor: 0,
            dispatch_pending: false,
            dispatches: 0,
            occupancy: TimeSeries::new(),
            running_series: TimeSeries::new(),
            util_series: TimeSeries::new(),
            effective_util_series: TimeSeries::new(),
            avail_series: TimeSeries::new(),
            preemption: PreemptionConfig::default(),
            reservations: Vec::new(),
            claimed: HashMap::new(),
            fault_counters: FaultCounters::default(),
            lost_work: 0.0,
            overhead_work: 0.0,
            starvation_timer: None,
        }
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    fn request_dispatch(&mut self, ctx: &mut Ctx<Ev>) {
        if !self.dispatch_pending {
            self.dispatch_pending = true;
            ctx.schedule_self(
                crate::core::time::SimDuration(0),
                Priority::SCHEDULE,
                Ev::Dispatch,
            );
        }
    }

    fn record_series(&mut self, now: SimTime) {
        self.occupancy.record(now, self.cluster.occupied_nodes() as f64);
        self.running_series.record(now, self.running.len() as f64);
        self.util_series.record(now, self.cluster.utilization());
        self.effective_util_series.record(now, self.cluster.effective_utilization());
        self.avail_series.record(now, self.cluster.available_cores() as f64);
    }

    fn snapshot_running(&self) -> Vec<RunningJob> {
        self.running
            .values()
            .map(|(j, a, est_end)| RunningJob {
                id: j.id,
                cores: a.cores(),
                est_end: *est_end,
                start: j.last_start.unwrap_or(SimTime::ZERO),
                priority: j.priority,
            })
            .collect()
    }

    /// Ids of running jobs whose allocation touches any node in `nodes`,
    /// ascending (deterministic kill order).
    fn occupants_of(&self, nodes: &[usize]) -> Vec<JobId> {
        let mut ids: Vec<JobId> = self
            .running
            .iter()
            .filter(|(_, (_, a, _))| a.taken.iter().any(|&(nid, _, _)| nodes.contains(&nid)))
            .map(|(&id, _)| id)
            .collect();
        ids.sort_unstable();
        ids
    }

    /// Interrupt a running job: release its cores, charge the accounting
    /// for `reason`, and put it back in the wait queue (at the tail — a
    /// preempted job re-queues like a fresh submission, as in AccaSim).
    fn interrupt_job(&mut self, id: JobId, reason: InterruptReason, ctx: &mut Ctx<Ev>) {
        let Some((mut job, alloc, _est)) = self.running.remove(&id) else {
            return;
        };
        let now = ctx.now();
        let cores = alloc.cores() as f64;
        let elapsed = job.last_start.map(|s| now - s).unwrap_or(SimDuration::ZERO);
        self.cluster.release(&alloc);
        let keep_progress = self.preemption.keeps_progress();
        let overhead = match (keep_progress, reason) {
            (true, InterruptReason::Eviction) => self.preemption.eviction_overhead(),
            // The periodic checkpoint already exists when a node dies;
            // the resumed segment only pays the restore cost.
            (true, InterruptReason::Failure) => self.preemption.restart_overhead,
            (false, _) => SimDuration::ZERO,
        };
        job.record_interruption(now, keep_progress, overhead);
        match reason {
            InterruptReason::Failure => {
                job.fail_count += 1;
                self.fault_counters.requeues += 1;
            }
            InterruptReason::Eviction => {
                job.preempt_count += 1;
                self.fault_counters.preemptions += 1;
            }
        }
        if keep_progress {
            self.overhead_work += overhead.as_f64() * cores;
        } else {
            self.lost_work += elapsed.as_f64() * cores;
        }
        self.queue.push(job);
        self.request_dispatch(ctx);
    }

    /// Count running jobs placed on nodes that no longer accept work —
    /// must always be zero (`Draining` keeps its occupants on purpose;
    /// only `Down` nodes may never host a running job).
    fn audit_placements(&mut self) {
        for (_, (_, a, _)) in self.running.iter() {
            for &(nid, _, _) in &a.taken {
                if self.cluster.node_state(nid) == NodeState::Down {
                    self.fault_counters.invariant_violations += 1;
                }
            }
        }
    }

    /// Apply a node failure: kill occupants, take the node down, and
    /// schedule its repair.
    fn fail_node(&mut self, victim_draw: u64, repair_after: SimDuration, ctx: &mut Ctx<Ev>) {
        let mut candidates: Vec<usize> = (0..self.cluster.num_nodes())
            .filter(|&i| self.cluster.node_state(i) != NodeState::Down)
            .collect();
        if candidates.is_empty() {
            return; // whole machine already down; nothing to fail
        }
        let node = candidates.swap_remove((victim_draw % candidates.len() as u64) as usize);
        self.fault_counters.failures += 1;
        self.cluster.set_node_state(node, NodeState::Down);
        for id in self.occupants_of(&[node]) {
            self.interrupt_job(id, InterruptReason::Failure, ctx);
        }
        ctx.schedule_self(repair_after, Priority::COMPLETE, Ev::NodeUp { node });
        self.audit_placements();
        self.record_series(ctx.now());
        if !self.queue.is_empty() {
            self.request_dispatch(ctx);
        }
    }

    /// Apply a node repair: the node rejoins as `Up`, or as `Reserved`
    /// when a still-active reservation claims it.
    fn repair_node(&mut self, node: usize, ctx: &mut Ctx<Ev>) {
        self.fault_counters.repairs += 1;
        let state = if self.claimed.contains_key(&node) {
            NodeState::Reserved
        } else {
            NodeState::Up
        };
        self.cluster.set_node_state(node, state);
        self.audit_placements();
        self.record_series(ctx.now());
        if !self.queue.is_empty() {
            self.request_dispatch(ctx);
        }
    }

    /// A reservation comes due: claim nodes (idle first, then least
    /// loaded; ids break ties). With preemption the occupants are
    /// evicted and the nodes go straight to `Reserved`; without it the
    /// occupied ones drain — they finish their jobs but accept no new
    /// work, degrading the reservation.
    fn start_reservation(&mut self, res: usize, ctx: &mut Ctx<Ev>) {
        self.fault_counters.reservations_started += 1;
        let want = self.reservations[res].nodes;
        let mut up: Vec<usize> = (0..self.cluster.num_nodes())
            .filter(|&i| {
                self.cluster.node_state(i) == NodeState::Up && !self.claimed.contains_key(&i)
            })
            .collect();
        up.sort_by_key(|&i| (self.cluster.nodes()[i].busy_cores(), i));
        let claim: Vec<usize> = up.into_iter().take(want).collect();
        // A shortfall (failed or already-claimed nodes) must be visible
        // to the operator, not silently truncated.
        self.fault_counters.reservations_short_nodes += (want - claim.len()) as u64;
        if self.preemption.enabled() {
            for id in self.occupants_of(&claim) {
                self.interrupt_job(id, InterruptReason::Eviction, ctx);
            }
        }
        for &node in &claim {
            self.claimed.insert(node, res);
            if self.cluster.nodes()[node].is_idle() {
                self.cluster.set_node_state(node, NodeState::Reserved);
            } else {
                self.cluster.set_node_state(node, NodeState::Draining);
                self.fault_counters.reservations_degraded += 1;
            }
        }
        self.audit_placements();
        self.record_series(ctx.now());
    }

    /// A reservation expires: its nodes (wherever they drained or were
    /// repaired to) return to service.
    fn end_reservation(&mut self, res: usize, ctx: &mut Ctx<Ev>) {
        let nodes: Vec<usize> = self
            .claimed
            .iter()
            .filter(|&(_, &r)| r == res)
            .map(|(&n, _)| n)
            .collect();
        for node in nodes {
            self.claimed.remove(&node);
            if self.cluster.node_state(node) != NodeState::Down {
                self.cluster.set_node_state(node, NodeState::Up);
            }
        }
        self.audit_placements();
        self.record_series(ctx.now());
        if !self.queue.is_empty() {
            self.request_dispatch(ctx);
        }
    }

    /// A draining node whose last occupant left flips to `Reserved` for
    /// the reservation that claimed it.
    fn settle_drained_nodes(&mut self, alloc_nodes: &[usize]) {
        for &node in alloc_nodes {
            if self.claimed.contains_key(&node)
                && self.cluster.node_state(node) == NodeState::Draining
                && self.cluster.nodes()[node].is_idle()
            {
                self.cluster.set_node_state(node, NodeState::Reserved);
            }
        }
    }

    fn dispatch(&mut self, ctx: &mut Ctx<Ev>) {
        self.dispatch_pending = false;
        self.dispatches += 1;
        let now = ctx.now();
        // Phase 0 — policy-driven preemption (fault subsystem): the
        // scheduler may evict strictly lower-priority running jobs for a
        // starving waiting job before the allocation pass. The snapshot
        // is built at most once per round and reused by the allocation
        // pass unless evictions invalidated it (snapshots are O(running)
        // on the DES hot path).
        let evictions_possible = self.preemption.enabled()
            && self.preemption.starvation_threshold > SimDuration::ZERO;
        let mut running_info: Vec<RunningJob> =
            if evictions_possible || self.scheduler.uses_running_info() {
                self.snapshot_running()
            } else {
                Vec::new()
            };
        if evictions_possible {
            let victims = {
                let input = SchedInput { now, queue: &self.queue, running: &running_info };
                self.scheduler.preempt(&input, &self.cluster)
            };
            if !victims.is_empty() {
                for id in victims {
                    self.interrupt_job(id, InterruptReason::Eviction, ctx);
                }
                running_info = if self.scheduler.uses_running_info() {
                    self.snapshot_running()
                } else {
                    Vec::new()
                };
            }
        }
        let allocations = {
            let input = SchedInput { now, queue: &self.queue, running: &running_info };
            self.scheduler.schedule(&input, &mut self.cluster)
        };
        for alloc in allocations {
            let mut job = self
                .queue
                .remove(alloc.job_id)
                .expect("scheduler allocated a job not in the queue");
            job.mark_started(now);
            let est_end = now + job.est_remaining();
            ctx.send(
                self.executor,
                Priority::DEFAULT,
                Ev::Start {
                    job_id: job.id,
                    runtime: job.remaining,
                    incarnation: job.incarnation,
                },
            );
            self.running.insert(job.id, (job, alloc, est_end));
        }
        // Starvation timer: wake up when the oldest feasible waiter
        // crosses the threshold so its eviction round actually runs.
        if self.starvation_timer == Some(now) {
            self.starvation_timer = None;
        }
        if self.preemption.enabled()
            && self.preemption.starvation_threshold > SimDuration::ZERO
        {
            let deadline = self
                .queue
                .iter()
                .find(|j| self.cluster.feasible(j))
                .map(|j| j.submit + self.preemption.starvation_threshold);
            if let Some(deadline) = deadline {
                let timer_ok =
                    self.starvation_timer.map_or(true, |t| t > deadline || t <= now);
                if deadline > now && timer_ok {
                    self.starvation_timer = Some(deadline);
                    ctx.schedule_self(deadline - now, Priority::SCHEDULE, Ev::Dispatch);
                }
            }
        }
        self.record_series(now);
        // Sanity: cached aggregates stay consistent (cheap check).
        debug_assert!(self.cluster.check_invariants());
    }

    fn complete(&mut self, job_id: JobId, incarnation: u32, ctx: &mut Ctx<Ev>) {
        // Stale completions are expected under preemption: the segment
        // that scheduled them was interrupted and the job re-queued.
        let current = self.running.get(&job_id).map(|(j, _, _)| j.incarnation);
        if current != Some(incarnation) {
            return;
        }
        let now = ctx.now();
        let (mut job, alloc, _) = self
            .running
            .remove(&job_id)
            .expect("completion for unknown job");
        self.cluster.release(&alloc);
        job.mark_completed(now);
        self.completed.push(job);
        self.settle_drained_nodes(&alloc.node_ids());
        self.record_series(now);
        if !self.queue.is_empty() {
            self.request_dispatch(ctx);
        }
    }
}

impl Component<Ev> for SchedulerComponent {
    fn name(&self) -> &str {
        "scheduler"
    }

    fn handle(&mut self, ev: Ev, ctx: &mut Ctx<Ev>) {
        match ev {
            Ev::Submit(job) => {
                if !self.cluster.feasible(&job) {
                    self.rejected += 1;
                    return;
                }
                self.queue.push(*job);
                self.request_dispatch(ctx);
            }
            Ev::Dispatch => self.dispatch(ctx),
            Ev::Complete { job_id, incarnation } => self.complete(job_id, incarnation, ctx),
            Ev::NodeFail { victim_draw, repair_after } => {
                self.fail_node(victim_draw, repair_after, ctx)
            }
            Ev::NodeUp { node } => self.repair_node(node, ctx),
            Ev::ReserveStart { res } => self.start_reservation(res, ctx),
            Ev::ReserveEnd { res } => self.end_reservation(res, ctx),
            other => panic!("scheduler got unexpected event {other:?}"),
        }
    }

    fn finish(&mut self, ctx: &mut Ctx<Ev>) {
        // Close the series at the end of the run.
        let now = ctx.now();
        self.record_series(now);
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn Any {
        self
    }
}

/// Job Executor (paper Fig 1): turns a dispatched job into a completion
/// after its actual remaining runtime, echoing the segment incarnation so
/// the scheduler can discard completions of preempted segments.
pub struct JobExecutor {
    pub scheduler: ComponentId,
    pub executed: u64,
}

impl JobExecutor {
    pub fn new(scheduler: ComponentId) -> JobExecutor {
        JobExecutor { scheduler, executed: 0 }
    }
}

impl Component<Ev> for JobExecutor {
    fn name(&self) -> &str {
        "executor"
    }

    fn handle(&mut self, ev: Ev, ctx: &mut Ctx<Ev>) {
        match ev {
            Ev::Start { job_id, runtime, incarnation } => {
                self.executed += 1;
                ctx.send_after(
                    self.scheduler,
                    runtime,
                    Priority::COMPLETE,
                    Ev::Complete { job_id, incarnation },
                );
            }
            other => panic!("executor got unexpected event {other:?}"),
        }
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn source_orders_and_batches() {
        let jobs = vec![
            Job::simple(2, 10, 1, 5),
            Job::simple(1, 10, 1, 5),
            Job::simple(3, 20, 1, 5),
        ];
        let s = JobSource::new(jobs);
        // Reversed internal order: last = earliest (id 1 at t=10).
        assert_eq!(s.jobs.last().unwrap().id, 1);
        assert_eq!(s.jobs.first().unwrap().id, 3);
    }

    #[test]
    fn executor_counts() {
        let e = JobExecutor::new(0);
        assert_eq!(e.executed, 0);
    }
}
