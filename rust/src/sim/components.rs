//! The simulation components (paper Fig 1).

use crate::core::component::{Component, Ctx};
use crate::core::event::{ComponentId, Priority};
use crate::core::stats::TimeSeries;
use crate::core::time::SimTime;
use crate::job::{Job, JobId, WaitQueue};
use crate::resources::{Allocation, Cluster};
use crate::sched::{RunningJob, SchedInput, Scheduler};
use crate::sim::Ev;
use std::any::Any;
use std::collections::HashMap;

/// Replays a workload as timed `Submit` events (incremental: one
/// self-event per distinct arrival time, so memory stays O(1) in the
/// event queue even for million-job traces).
pub struct JobSource {
    /// Jobs in submit order (reversed internally for O(1) pop).
    jobs: Vec<Job>,
    /// Where submissions go (the scheduler). Set by the builder.
    pub target: ComponentId,
    emitted: u64,
}

impl JobSource {
    pub fn new(mut jobs: Vec<Job>) -> JobSource {
        jobs.sort_by_key(|j| (j.submit, j.id));
        jobs.reverse();
        JobSource { jobs, target: 0, emitted: 0 }
    }

    fn emit_due(&mut self, ctx: &mut Ctx<Ev>) {
        let now = ctx.now();
        while let Some(j) = self.jobs.last() {
            if j.submit > now {
                break;
            }
            let job = self.jobs.pop().unwrap();
            self.emitted += 1;
            ctx.send(self.target, Priority::ARRIVE, Ev::Submit(Box::new(job)));
        }
        if let Some(next) = self.jobs.last() {
            let delay = next.submit - now;
            ctx.schedule_self(delay, Priority::ARRIVE, Ev::NextArrival);
        }
    }
}

impl Component<Ev> for JobSource {
    fn name(&self) -> &str {
        "source"
    }

    fn init(&mut self, ctx: &mut Ctx<Ev>) {
        if let Some(first) = self.jobs.last() {
            let delay = first.submit - ctx.now();
            ctx.schedule_self(delay, Priority::ARRIVE, Ev::NextArrival);
        }
    }

    fn handle(&mut self, ev: Ev, ctx: &mut Ctx<Ev>) {
        match ev {
            Ev::NextArrival => self.emit_due(ctx),
            other => panic!("source got unexpected event {other:?}"),
        }
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn Any {
        self
    }
}

/// Job Scheduling + Resource Management (paper Fig 1): wait queue, the
/// scheduling algorithm, cluster accounting, lifecycle bookkeeping and
/// event-driven metric recording.
pub struct SchedulerComponent {
    pub cluster: Cluster,
    scheduler: Box<dyn Scheduler>,
    queue: WaitQueue,
    /// Running jobs: id -> (job, allocation, estimated end).
    running: HashMap<JobId, (Job, Allocation, SimTime)>,
    pub completed: Vec<Job>,
    pub rejected: u64,
    pub executor: ComponentId,
    dispatch_pending: bool,
    pub dispatches: u64,
    pub occupancy: TimeSeries,
    pub running_series: TimeSeries,
    pub util_series: TimeSeries,
}

impl SchedulerComponent {
    pub fn new(cluster: Cluster, scheduler: Box<dyn Scheduler>) -> SchedulerComponent {
        SchedulerComponent {
            cluster,
            scheduler,
            queue: WaitQueue::new(),
            running: HashMap::new(),
            completed: Vec::new(),
            rejected: 0,
            executor: 0,
            dispatch_pending: false,
            dispatches: 0,
            occupancy: TimeSeries::new(),
            running_series: TimeSeries::new(),
            util_series: TimeSeries::new(),
        }
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    fn request_dispatch(&mut self, ctx: &mut Ctx<Ev>) {
        if !self.dispatch_pending {
            self.dispatch_pending = true;
            ctx.schedule_self(
                crate::core::time::SimDuration(0),
                Priority::SCHEDULE,
                Ev::Dispatch,
            );
        }
    }

    fn record_series(&mut self, now: SimTime) {
        self.occupancy.record(now, self.cluster.occupied_nodes() as f64);
        self.running_series.record(now, self.running.len() as f64);
        self.util_series.record(now, self.cluster.utilization());
    }

    fn dispatch(&mut self, ctx: &mut Ctx<Ev>) {
        self.dispatch_pending = false;
        self.dispatches += 1;
        let now = ctx.now();
        let running_info: Vec<RunningJob> = if self.scheduler.uses_running_info() {
            self.running
                .values()
                .map(|(j, a, est_end)| RunningJob { id: j.id, cores: a.cores(), est_end: *est_end })
                .collect()
        } else {
            Vec::new()
        };
        let allocations = {
            let input = SchedInput { now, queue: &self.queue, running: &running_info };
            self.scheduler.schedule(&input, &mut self.cluster)
        };
        for alloc in allocations {
            let mut job = self
                .queue
                .remove(alloc.job_id)
                .expect("scheduler allocated a job not in the queue");
            job.mark_started(now);
            let est_end = now + job.est_runtime;
            ctx.send(
                self.executor,
                Priority::DEFAULT,
                Ev::Start { job_id: job.id, runtime: job.runtime },
            );
            self.running.insert(job.id, (job, alloc, est_end));
        }
        self.record_series(now);
        // Sanity: cached aggregates stay consistent (cheap check).
        debug_assert!(self.cluster.check_invariants());
    }

    fn complete(&mut self, job_id: JobId, ctx: &mut Ctx<Ev>) {
        let now = ctx.now();
        let (mut job, alloc, _) = self
            .running
            .remove(&job_id)
            .expect("completion for unknown job");
        self.cluster.release(&alloc);
        job.mark_completed(now);
        self.completed.push(job);
        self.record_series(now);
        if !self.queue.is_empty() {
            self.request_dispatch(ctx);
        }
    }
}

impl Component<Ev> for SchedulerComponent {
    fn name(&self) -> &str {
        "scheduler"
    }

    fn handle(&mut self, ev: Ev, ctx: &mut Ctx<Ev>) {
        match ev {
            Ev::Submit(job) => {
                if !self.cluster.feasible(&job) {
                    self.rejected += 1;
                    return;
                }
                self.queue.push(*job);
                self.request_dispatch(ctx);
            }
            Ev::Dispatch => self.dispatch(ctx),
            Ev::Complete { job_id } => self.complete(job_id, ctx),
            other => panic!("scheduler got unexpected event {other:?}"),
        }
    }

    fn finish(&mut self, ctx: &mut Ctx<Ev>) {
        // Close the series at the end of the run.
        let now = ctx.now();
        self.record_series(now);
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn Any {
        self
    }
}

/// Job Executor (paper Fig 1): turns a dispatched job into a completion
/// after its actual runtime.
pub struct JobExecutor {
    pub scheduler: ComponentId,
    pub executed: u64,
}

impl JobExecutor {
    pub fn new(scheduler: ComponentId) -> JobExecutor {
        JobExecutor { scheduler, executed: 0 }
    }
}

impl Component<Ev> for JobExecutor {
    fn name(&self) -> &str {
        "executor"
    }

    fn handle(&mut self, ev: Ev, ctx: &mut Ctx<Ev>) {
        match ev {
            Ev::Start { job_id, runtime } => {
                self.executed += 1;
                ctx.send_after(
                    self.scheduler,
                    runtime,
                    Priority::COMPLETE,
                    Ev::Complete { job_id },
                );
            }
            other => panic!("executor got unexpected event {other:?}"),
        }
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn source_orders_and_batches() {
        let jobs = vec![
            Job::simple(2, 10, 1, 5),
            Job::simple(1, 10, 1, 5),
            Job::simple(3, 20, 1, 5),
        ];
        let s = JobSource::new(jobs);
        // Reversed internal order: last = earliest (id 1 at t=10).
        assert_eq!(s.jobs.last().unwrap().id, 1);
        assert_eq!(s.jobs.first().unwrap().id, 3);
    }

    #[test]
    fn executor_counts() {
        let e = JobExecutor::new(0);
        assert_eq!(e.executed, 0);
    }
}
