//! The simulation components (paper Fig 1), extended with the
//! fault/preemption/reservation subsystem: the scheduler component owns
//! every capacity transition (node failure/repair, reservation claims)
//! and the job-interruption bookkeeping, while `sim::faults` only
//! generates the timed stimuli.
//!
//! The component also owns the *availability timeline*
//! ([`AvailabilityProfile`]): the free-core step function from now into
//! the future that every planning policy reads. It is maintained
//! incrementally on the hot path (job start subtracts a hold until the
//! estimated end, completion/eviction releases the remainder) and
//! resynced from authoritative cluster state only on the rare capacity
//! transitions (node failure/repair, reservation claim/expiry), so
//! scheduling rounds no longer sort and rebuild release vectors.

use crate::analysis::sanitizer;
use crate::core::component::{Component, Ctx};
use crate::core::event::{ComponentId, Priority};
use crate::core::stats::TimeSeries;
use crate::core::time::{SimDuration, SimTime};
use crate::job::{Job, JobId, WaitQueue};
use crate::resources::{Allocation, AvailabilityProfile, Cluster, NodeState, ResourceVector};
use crate::sched::{
    ArrivalOrder, PreemptionConfig, QueueOrder, RoundScratch, RunningJob, SchedInput, Scheduler,
    UserShare,
};
use crate::sim::faults::ReservationSpec;
use crate::sim::{Ev, Horizon};
use std::any::Any;
use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Queue depth at or below which `Horizon::Auto` plans exactly — the
/// timeline stays short on its own when few jobs wait, so clamping
/// would only cost fidelity. Default of `planning.auto_shallow_queue`.
pub const AUTO_SHALLOW_QUEUE: usize = 256;
/// Auto clamp length: this many *median queue runtime estimates* of
/// lookahead. Deep enough that shadow times and candidate admission
/// windows stay faithful (estimates beyond the clamp are the heavy
/// tail no backfill decision reaches), shallow enough to bound
/// breakpoint count at million-job queue depths. Default of
/// `planning.auto_horizon_estimates`.
pub const AUTO_HORIZON_ESTIMATES: u64 = 32;
/// Auto clamp floor in ticks (one simulated hour) — degenerate queues
/// of sub-minute jobs must not collapse the timeline to a sliver.
/// Default of `planning.auto_min_horizon`.
pub const AUTO_MIN_HORIZON: u64 = 3_600;

/// Tunables of the [`Horizon::Auto`] law, exposed as the
/// `planning.auto_*` config keys so the constants above are defaults,
/// not destiny (they are engineering picks; real archive traces may
/// want a different depth/lookahead trade).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AutoHorizonParams {
    /// Queue depth at or below which auto plans exactly
    /// (`planning.auto_shallow_queue`).
    pub shallow_queue: usize,
    /// Clamp length in median queue runtime estimates
    /// (`planning.auto_horizon_estimates`, >= 1).
    pub estimates: u64,
    /// Clamp floor in ticks (`planning.auto_min_horizon`).
    pub min_horizon: u64,
}

impl Default for AutoHorizonParams {
    fn default() -> Self {
        AutoHorizonParams {
            shallow_queue: AUTO_SHALLOW_QUEUE,
            estimates: AUTO_HORIZON_ESTIMATES,
            min_horizon: AUTO_MIN_HORIZON,
        }
    }
}

/// Where a [`JobSource`]'s jobs come from.
enum JobFeed {
    /// Eagerly loaded jobs in *reverse* submit order (O(1) pop off the
    /// back) — the classic path.
    Eager(Vec<Job>),
    /// Pull-based stream with a one-job lookahead: the constant-memory
    /// ingestion path for million-job traces. The stream must yield jobs
    /// in nondecreasing submit order (archive traces are submit-sorted);
    /// a late record is emitted immediately rather than reordered.
    Stream { next: Option<Box<Job>>, iter: Box<dyn Iterator<Item = Job> + Send> },
}

/// Replays a workload as timed `Submit` events (incremental: one
/// self-event per distinct arrival time, so memory stays O(1) in the
/// event queue even for million-job traces). With a streamed feed
/// ([`JobSource::from_stream`]) the *trace* stays out of memory too:
/// at most one job is buffered ahead of the simulation clock.
pub struct JobSource {
    feed: JobFeed,
    /// Where submissions go (the scheduler). Set by the builder.
    pub target: ComponentId,
    emitted: u64,
}

impl JobSource {
    pub fn new(mut jobs: Vec<Job>) -> JobSource {
        jobs.sort_by_key(|j| (j.submit, j.id));
        jobs.reverse();
        JobSource { feed: JobFeed::Eager(jobs), target: 0, emitted: 0 }
    }

    /// Streamed feed: jobs are pulled one at a time as simulated time
    /// reaches them — the trace is never materialized (type-level: the
    /// lookahead is an `Option<Box<Job>>`, there is no `Vec<Job>` to
    /// grow). The stream must be sorted by submit time.
    pub fn from_stream(iter: Box<dyn Iterator<Item = Job> + Send>) -> JobSource {
        JobSource { feed: JobFeed::Stream { next: None, iter }, target: 0, emitted: 0 }
    }

    /// Jobs emitted so far.
    pub fn emitted(&self) -> u64 {
        self.emitted
    }

    /// Jobs currently buffered outside the engine: the whole remaining
    /// trace on the eager path, at most one on the streamed path — the
    /// bounded-memory pin the scale tests assert.
    pub fn buffered(&self) -> usize {
        match &self.feed {
            JobFeed::Eager(v) => v.len(),
            JobFeed::Stream { next, .. } => usize::from(next.is_some()),
        }
    }

    /// Submit time of the next job, pulling the stream's lookahead if
    /// needed. `None` when the feed is exhausted.
    fn peek_submit(&mut self) -> Option<SimTime> {
        match &mut self.feed {
            JobFeed::Eager(v) => v.last().map(|j| j.submit),
            JobFeed::Stream { next, iter } => {
                if next.is_none() {
                    *next = iter.next().map(Box::new);
                }
                next.as_ref().map(|j| j.submit)
            }
        }
    }

    fn pop_next(&mut self) -> Option<Box<Job>> {
        match &mut self.feed {
            JobFeed::Eager(v) => v.pop().map(Box::new),
            JobFeed::Stream { next, iter } => {
                if next.is_none() {
                    *next = iter.next().map(Box::new);
                }
                next.take()
            }
        }
    }

    fn emit_due(&mut self, ctx: &mut Ctx<Ev>) {
        let now = ctx.now();
        while let Some(submit) = self.peek_submit() {
            if submit > now {
                break;
            }
            let job = self.pop_next().unwrap();
            self.emitted += 1;
            ctx.send(self.target, Priority::ARRIVE, Ev::Submit(job));
        }
        if let Some(next) = self.peek_submit() {
            let delay = next - now;
            ctx.schedule_self(delay, Priority::ARRIVE, Ev::NextArrival);
        }
    }
}

impl Component<Ev> for JobSource {
    fn name(&self) -> &str {
        "source"
    }

    fn init(&mut self, ctx: &mut Ctx<Ev>) {
        let now = ctx.now();
        if let Some(first) = self.peek_submit() {
            let delay = first - now;
            ctx.schedule_self(delay, Priority::ARRIVE, Ev::NextArrival);
        }
    }

    fn handle(&mut self, ev: Ev, ctx: &mut Ctx<Ev>) {
        match ev {
            Ev::NextArrival => self.emit_due(ctx),
            other => panic!("source got unexpected event {other:?}"),
        }
    }

    /// Eager feeds copy their remaining jobs; a pull-based stream
    /// cannot be rewound or duplicated, so streamed runs are not
    /// snapshotable (the engine reports this source by name).
    fn snapshot_box(&self) -> Option<Box<dyn Component<Ev>>> {
        match &self.feed {
            JobFeed::Eager(v) => Some(Box::new(JobSource {
                feed: JobFeed::Eager(v.clone()),
                target: self.target,
                emitted: self.emitted,
            })),
            JobFeed::Stream { .. } => None,
        }
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn Any {
        self
    }
}

/// Counters of the fault/preemption/reservation subsystem, all zero for
/// fault-free runs.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultCounters {
    /// Node failures applied.
    pub failures: u64,
    /// Node repairs applied.
    pub repairs: u64,
    /// Planned evictions (policy- or reservation-driven).
    pub preemptions: u64,
    /// Failure kills that sent a running job back to the queue.
    pub requeues: u64,
    /// Reservations that came due.
    pub reservations_started: u64,
    /// Claimed nodes that had to drain because preemption was off.
    pub reservations_degraded: u64,
    /// Requested reservation nodes that could not be claimed at all
    /// (not enough Up, unclaimed nodes when the reservation came due).
    pub reservations_short_nodes: u64,
    /// Times a running job was observed on a non-`Up` node (must stay 0;
    /// audited after every capacity transition).
    pub invariant_violations: u64,
}

/// Why a running job is being interrupted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum InterruptReason {
    /// Node failure. Under `PreemptionMode::Checkpoint` jobs are
    /// periodically checkpointed, so the victim resumes with its progress
    /// intact for a `restart_overhead` charge; under any other mode the
    /// unplanned kill loses all progress.
    Failure,
    /// Planned eviction: checkpointed under `PreemptionMode::Checkpoint`
    /// (checkpoint + restart overhead), killed under `PreemptionMode::Kill`.
    Eviction,
}

/// One running job with its exact profile footprint.
#[derive(Clone)]
struct RunningEntry {
    job: Job,
    alloc: Allocation,
    /// Estimated end of the current segment (start + estimate).
    est_end: SimTime,
    /// The `(release_time, demand)` deltas this job currently contributes
    /// to the availability timeline — released verbatim when the job
    /// leaves, so incremental maintenance is an exact inverse of the
    /// holds it placed. Rewritten by `resync_profile` on capacity
    /// transitions (a draining node hands its portion back later). The
    /// memory component is zero unless the run is memory-aware.
    hold: Vec<(u64, ResourceVector)>,
}

/// Job Scheduling + Resource Management (paper Fig 1): wait queue, the
/// scheduling algorithm, cluster accounting, lifecycle bookkeeping and
/// event-driven metric recording — plus node lifecycle transitions and
/// preemption for the fault subsystem.
pub struct SchedulerComponent {
    pub cluster: Cluster,
    scheduler: Box<dyn Scheduler>,
    /// The queue ordering every round dispatches under (the policy's
    /// natural order, or the `--order` override); also the sink for
    /// fair-share usage accounting on segment end.
    queue_order: Box<dyn QueueOrder>,
    /// Plan memory as a second timeline dimension (holds carry the
    /// allocation's memory footprint; resync encodes memory deltas).
    /// Forced off when the machine tracks no memory.
    pub memory_aware: bool,
    queue: WaitQueue,
    /// Running jobs by id, with their availability-timeline footprint.
    running: HashMap<JobId, RunningEntry>,
    /// The shared availability timeline every planning policy reads
    /// (`SchedInput::profile`).
    profile: AvailabilityProfile,
    /// Planning-horizon policy (`planning.horizon`): hold releases are
    /// coalesced to at most `now + effective_horizon`, bounding timeline
    /// length on huge running sets at the cost of fidelity past the
    /// horizon. `Exact` = unlimited timeline (the default); `Auto`
    /// derives the clamp from live queue state (see
    /// [`SchedulerComponent::derive_auto_horizon`]).
    horizon: Horizon,
    /// The clamp currently in force, in ticks (0 = exact). Equals the
    /// fixed horizon, or the last auto derivation.
    effective_horizon: u64,
    /// Queue depth when the auto horizon was last derived (staleness
    /// check — re-derive when the depth halves or doubles).
    auto_depth: usize,
    /// `Horizon::Auto` tunables (`planning.auto_*`).
    auto_params: AutoHorizonParams,
    /// Reusable per-round scratch (order views, candidate buffers, the
    /// scratch plan) — threaded to every policy via `SchedInput::scratch`
    /// so steady-state dispatch rounds allocate nothing.
    scratch: RefCell<RoundScratch>,
    /// Reusable running-jobs snapshot buffer (preemption layer only).
    running_scratch: Vec<RunningJob>,
    /// Failed node -> known repair instant (the timeline promises the
    /// capacity back at that time).
    pending_repairs: HashMap<usize, u64>,
    /// Reservations whose start has not fired yet still hold planned
    /// capacity windows in the timeline.
    resv_pending: Vec<bool>,
    /// Planned hold size per reservation, computed once (node capacities
    /// are immutable after construction).
    resv_plan_cores: Vec<u64>,
    /// Memory analogue of `resv_plan_cores` (memory-aware runs only).
    resv_plan_mem: Vec<u64>,
    /// When the timeline was last rebuilt from authoritative state. With
    /// a finite horizon, events clamped away at one resync must re-enter
    /// as time approaches them, so dispatch refreshes every horizon/2
    /// ticks of simulated progress.
    last_resync: u64,
    /// Capacity transitions (node failure/repair, reservation
    /// claim/expiry, departures touching non-`Up` nodes) no longer
    /// resync eagerly — they raise this flag, and the next dispatch
    /// round (the only profile reader) rebuilds once before deciding.
    /// A same-tick fault/repair storm of k transitions thus pays one
    /// O(running) resync instead of k, and the decision-time profile is
    /// identical: resync-from-authoritative-state at the dispatch
    /// instant sees exactly the state the k eager rebuilds would have
    /// converged to (pinned by the fault fingerprint regressions).
    profile_stale: bool,
    /// Completed jobs with their full lifecycle records. Streaming-scale
    /// runs turn retention off (`retain_completed = false`) so memory
    /// stays O(active jobs); the scalar aggregates below survive either
    /// way.
    pub completed: Vec<Job>,
    /// Whether completed jobs (and the unbounded per-event metric
    /// series) are retained. When off — the streaming-scale mode — the
    /// incremental time-weighted aggregates below are the durable
    /// output, so nothing in the component grows with trace length.
    pub retain_completed: bool,
    /// Jobs completed over the run (counted even when not retained).
    pub completed_count: u64,
    /// Sum of completed jobs' wait times in ticks (streaming aggregate).
    pub wait_ticks_total: f64,
    /// Useful core-seconds delivered (runtime x cores per completion) —
    /// the goodput numerator, O(1) memory.
    pub useful_work: f64,
    /// Incremental time-weighted aggregates, maintained in lock-step
    /// with the metric series: integral of utilization resp. available
    /// cores over time, the step values last recorded, the first/last
    /// record instants, and the availability integral snapshotted at the
    /// most recent completion (the goodput denominator).
    first_record_t: Option<u64>,
    last_record_t: u64,
    last_util: f64,
    last_mem_util: f64,
    last_avail: f64,
    util_integral: f64,
    mem_util_integral: f64,
    avail_integral: f64,
    avail_integral_at_completion: f64,
    pub rejected: u64,
    pub executor: ComponentId,
    dispatch_pending: bool,
    pub dispatches: u64,
    pub occupancy: TimeSeries,
    pub running_series: TimeSeries,
    pub util_series: TimeSeries,
    /// (t, busy memory / total memory) — recorded only on memory-aware
    /// runs (empty otherwise).
    pub mem_util_series: TimeSeries,
    /// (t, busy / non-failed cores) — fault subsystem metric.
    pub effective_util_series: TimeSeries,
    /// (t, non-failed cores) — denominator series for the goodput-based
    /// mean effective utilization.
    pub avail_series: TimeSeries,
    /// Preemption knobs; also applied to failure and reservation kills.
    pub preemption: PreemptionConfig,
    /// Advance reservations (specs; claims happen when each comes due).
    pub reservations: Vec<ReservationSpec>,
    /// node id -> reservation index that currently claims it.
    claimed: HashMap<usize, usize>,
    pub fault_counters: FaultCounters,
    /// Core-seconds of progress discarded by kills/failures.
    pub lost_work: f64,
    /// Core-seconds of checkpoint/restart overhead charged.
    pub overhead_work: f64,
    /// Earliest pending starvation-deadline dispatch timer (dispatches
    /// are event-driven, so a starving job needs a timed wake-up for its
    /// eviction round).
    starvation_timer: Option<SimTime>,
    /// Last-activity watermark shared with the fault injector on
    /// streamed runs: advanced to `now` after every handled event that
    /// leaves the machine non-idle (queued or running work), so the
    /// derived injection horizon tracks a draining backlog through
    /// arrival droughts. Written only inside the single-threaded event
    /// loop — deterministic.
    pub activity_mark: Option<Arc<AtomicU64>>,
    /// Runtime sanitizer cadence state (checks are no-ops unless
    /// `sanitizer::ACTIVE` — every debug build, `--features sanitize`
    /// in release). The sanitizer only ever *reads* simulation state,
    /// so sanitize-on and sanitize-off runs make identical decisions.
    san: sanitizer::SimSanitizer,
}

impl SchedulerComponent {
    pub fn new(cluster: Cluster, scheduler: Box<dyn Scheduler>) -> SchedulerComponent {
        let profile = AvailabilityProfile::new(0, cluster.free_cores(), cluster.total_cores());
        SchedulerComponent {
            cluster,
            scheduler,
            queue_order: Box::new(ArrivalOrder),
            memory_aware: false,
            queue: WaitQueue::new(),
            running: HashMap::new(),
            profile,
            horizon: Horizon::Exact,
            effective_horizon: 0,
            auto_depth: 0,
            auto_params: AutoHorizonParams::default(),
            scratch: RefCell::new(RoundScratch::default()),
            running_scratch: Vec::new(),
            pending_repairs: HashMap::new(),
            resv_pending: Vec::new(),
            resv_plan_cores: Vec::new(),
            resv_plan_mem: Vec::new(),
            last_resync: 0,
            profile_stale: false,
            completed: Vec::new(),
            retain_completed: true,
            completed_count: 0,
            wait_ticks_total: 0.0,
            useful_work: 0.0,
            first_record_t: None,
            last_record_t: 0,
            last_util: 0.0,
            last_mem_util: 0.0,
            last_avail: 0.0,
            util_integral: 0.0,
            mem_util_integral: 0.0,
            avail_integral: 0.0,
            avail_integral_at_completion: 0.0,
            rejected: 0,
            executor: 0,
            dispatch_pending: false,
            dispatches: 0,
            occupancy: TimeSeries::new(),
            running_series: TimeSeries::new(),
            util_series: TimeSeries::new(),
            mem_util_series: TimeSeries::new(),
            effective_util_series: TimeSeries::new(),
            avail_series: TimeSeries::new(),
            preemption: PreemptionConfig::default(),
            reservations: Vec::new(),
            claimed: HashMap::new(),
            fault_counters: FaultCounters::default(),
            lost_work: 0.0,
            overhead_work: 0.0,
            starvation_timer: None,
            activity_mark: None,
            san: sanitizer::SimSanitizer::new(),
        }
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    fn request_dispatch(&mut self, ctx: &mut Ctx<Ev>) {
        if !self.dispatch_pending {
            self.dispatch_pending = true;
            ctx.schedule_self(
                crate::core::time::SimDuration(0),
                Priority::SCHEDULE,
                Ev::Dispatch,
            );
        }
    }

    fn record_series(&mut self, now: SimTime) {
        // Incremental time-weighted aggregates first (O(1) memory): the
        // previous step value held from `last_record_t` until now.
        let nowt = now.ticks();
        if self.first_record_t.is_none() {
            self.first_record_t = Some(nowt);
        }
        let dt = nowt.saturating_sub(self.last_record_t) as f64;
        self.util_integral += self.last_util * dt;
        self.mem_util_integral += self.last_mem_util * dt;
        self.avail_integral += self.last_avail * dt;
        self.last_record_t = nowt;
        self.last_util = self.cluster.utilization();
        self.last_mem_util =
            if self.memory_aware { self.cluster.memory_utilization() } else { 0.0 };
        self.last_avail = self.cluster.available_cores() as f64;
        if !self.retain_completed {
            // Streaming-scale mode: the per-event series would grow
            // O(events) with the trace; the aggregates above are the
            // durable output instead.
            return;
        }
        self.occupancy.record(now, self.cluster.occupied_nodes() as f64);
        self.running_series.record(now, self.running.len() as f64);
        self.util_series.record(now, self.cluster.utilization());
        self.effective_util_series.record(now, self.cluster.effective_utilization());
        self.avail_series.record(now, self.cluster.available_cores() as f64);
        if self.memory_aware {
            self.mem_util_series.record(now, self.cluster.memory_utilization());
        }
    }

    /// Time-weighted mean utilization from the incremental aggregates —
    /// same law as `TimeSeries::time_weighted_mean` (integral from the
    /// first record to `end`, over that span). Streaming-scale runs read
    /// this; retained runs read their full series.
    pub fn streaming_mean_utilization(&self, end: SimTime) -> f64 {
        let Some(first) = self.first_record_t else { return 0.0 };
        let endt = end.ticks();
        let span = endt.saturating_sub(first) as f64;
        if span == 0.0 {
            return self.last_util;
        }
        let tail = endt.saturating_sub(self.last_record_t) as f64 * self.last_util;
        (self.util_integral + tail) / span
    }

    /// Memory analogue of [`SchedulerComponent::streaming_mean_utilization`]
    /// (0 on runs that never tracked memory).
    pub fn streaming_mean_memory_utilization(&self, end: SimTime) -> f64 {
        let Some(first) = self.first_record_t else { return 0.0 };
        let endt = end.ticks();
        let span = endt.saturating_sub(first) as f64;
        if span == 0.0 {
            return self.last_mem_util;
        }
        let tail = endt.saturating_sub(self.last_record_t) as f64 * self.last_mem_util;
        (self.mem_util_integral + tail) / span
    }

    /// Goodput from the incremental aggregates: useful core-seconds per
    /// available core-second up to the last completion.
    pub fn streaming_effective_utilization(&self) -> f64 {
        if self.avail_integral_at_completion > 0.0 {
            self.useful_work / self.avail_integral_at_completion
        } else {
            0.0
        }
    }

    /// The availability timeline (read-only view for tests/tools).
    pub fn profile(&self) -> &AvailabilityProfile {
        &self.profile
    }

    /// Install the queue ordering (the builder resolves override vs
    /// policy default).
    pub fn set_queue_order(&mut self, order: Box<dyn QueueOrder>) {
        self.queue_order = order;
    }

    /// Install the planning-horizon policy (builder).
    pub fn set_horizon(&mut self, horizon: Horizon) {
        self.horizon = horizon;
        self.effective_horizon = match horizon {
            Horizon::Fixed(t) => t,
            Horizon::Exact | Horizon::Auto => 0,
        };
    }

    /// The clamp currently in force, in ticks (0 = exact) — tests and
    /// observability.
    pub fn effective_horizon(&self) -> u64 {
        self.effective_horizon
    }

    /// Install the `Horizon::Auto` tunables (builder; `planning.auto_*`).
    pub fn set_auto_params(&mut self, params: AutoHorizonParams) {
        self.auto_params = params;
    }

    /// Auto-horizon law (`planning.horizon = "auto"`): exact planning
    /// while the queue is shallow; past `auto_params.shallow_queue`
    /// waiters the timeline is clamped to `auto_params.estimates`
    /// median runtime estimates (floored at `auto_params.min_horizon`),
    /// so timeline length tracks the depth of planning the rounds
    /// actually exploit instead of the tail of every running job's
    /// estimate. Derived from queue state only — byte-deterministic
    /// across runs. Defaults: [`AUTO_SHALLOW_QUEUE`],
    /// [`AUTO_HORIZON_ESTIMATES`], [`AUTO_MIN_HORIZON`]
    /// (`planning.auto_*` overrides them).
    fn derive_auto_horizon(&mut self) {
        self.auto_depth = self.queue.len();
        if self.auto_depth <= self.auto_params.shallow_queue {
            self.effective_horizon = 0;
            return;
        }
        let mut ests: Vec<u64> =
            self.queue.iter().map(|j| j.est_runtime.ticks().max(1)).collect();
        let mid = ests.len() / 2;
        let (_, median, _) = ests.select_nth_unstable(mid);
        self.effective_horizon = (*median)
            .saturating_mul(self.auto_params.estimates.max(1))
            .max(self.auto_params.min_horizon);
    }

    /// Whether the auto horizon should be re-derived: the queue depth
    /// has halved or doubled since the last derivation (amortized O(1)
    /// triggers per queue push, so the O(queue) median stays off the
    /// steady-state dispatch path).
    fn auto_horizon_stale(&self) -> bool {
        if self.horizon != Horizon::Auto {
            return false;
        }
        let depth = self.queue.len().max(1);
        let last = self.auto_depth.max(1);
        depth >= last * 2 || depth * 2 <= last
    }

    /// Decayed per-user usage at `now` (empty unless the ordering
    /// tracks usage — fair share).
    pub fn user_shares(&self, now: SimTime) -> Vec<UserShare> {
        self.queue_order.usage_snapshot(now)
    }

    /// Fill `out` with the running-set snapshot (cleared first). An
    /// associated fn over the map so the caller can hold the reusable
    /// buffer (`running_scratch`) while `self.running` stays borrowed.
    fn fill_running_snapshot(running: &HashMap<JobId, RunningEntry>, out: &mut Vec<RunningJob>) {
        out.clear();
        // lint:allow(hash-iter, snapshot is sorted by job id below so hasher order never escapes)
        out.extend(running.values().map(|e| RunningJob {
            id: e.job.id,
            cores: e.alloc.cores(),
            est_end: e.est_end,
            start: e.job.last_start.unwrap_or(SimTime::ZERO),
            priority: e.job.priority,
        }));
        // Consumers (the preemption layer's victim selection) see the
        // running set in ascending job-id order, never hasher order.
        out.sort_unstable_by_key(|r| r.id);
    }

    /// Ids of running jobs whose allocation touches any node in `nodes`,
    /// ascending (deterministic kill order).
    fn occupants_of(&self, nodes: &[usize]) -> Vec<JobId> {
        let mut ids: Vec<JobId> = self
            .running
            .iter()
            .filter(|(_, e)| e.alloc.taken.iter().any(|&(nid, _, _)| nodes.contains(&nid)))
            .map(|(&id, _)| id)
            .collect();
        ids.sort_unstable();
        ids
    }

    /// Hand a departing job's timeline footprint back. When every node
    /// of the allocation is `Up`, the stored hold deltas are reversed
    /// exactly (hot path); otherwise part of the cores return to a
    /// drained/failed node instead of the schedulable pool, so the
    /// timeline must be rebuilt from authoritative state — flagged, and
    /// performed once by the next dispatch round (rare path).
    fn release_profile_hold(
        &mut self,
        alloc: &Allocation,
        hold: &[(u64, ResourceVector)],
        now: SimTime,
    ) {
        let all_up = alloc
            .taken
            .iter()
            .all(|&(nid, _, _)| self.cluster.node_state(nid) == NodeState::Up);
        if all_up {
            let nowt = now.ticks();
            for &(end, d) in hold {
                self.profile.release_v(nowt, end, d);
            }
        } else {
            self.profile_stale = true;
        }
    }

    /// Interrupt a running job: release its cores, charge the accounting
    /// for `reason`, and put it back in the wait queue (at the tail — a
    /// preempted job re-queues like a fresh submission, as in AccaSim).
    fn interrupt_job(&mut self, id: JobId, reason: InterruptReason, ctx: &mut Ctx<Ev>) {
        let Some(RunningEntry { mut job, alloc, hold, .. }) = self.running.remove(&id) else {
            return;
        };
        let now = ctx.now();
        let cores = alloc.cores() as f64;
        let elapsed = job.last_start.map(|s| now - s).unwrap_or(SimDuration::ZERO);
        self.cluster.release(&alloc);
        self.release_profile_hold(&alloc, &hold, now);
        // Fair-share accounting: the interrupted segment consumed real
        // machine time even if its progress is later discarded.
        self.queue_order
            .record_usage(job.user, job.group, alloc.cores(), elapsed.ticks(), now);
        let keep_progress = self.preemption.keeps_progress();
        let overhead = match (keep_progress, reason) {
            (true, InterruptReason::Eviction) => self.preemption.eviction_overhead(),
            // The periodic checkpoint already exists when a node dies;
            // the resumed segment only pays the restore cost.
            (true, InterruptReason::Failure) => self.preemption.restart_overhead,
            (false, _) => SimDuration::ZERO,
        };
        job.record_interruption(now, keep_progress, overhead);
        match reason {
            InterruptReason::Failure => {
                job.fail_count += 1;
                self.fault_counters.requeues += 1;
            }
            InterruptReason::Eviction => {
                job.preempt_count += 1;
                self.fault_counters.preemptions += 1;
            }
        }
        if keep_progress {
            self.overhead_work += overhead.as_f64() * cores;
        } else {
            self.lost_work += elapsed.as_f64() * cores;
        }
        self.queue.push(job);
        self.request_dispatch(ctx);
    }

    /// Count running jobs placed on nodes that no longer accept work —
    /// must always be zero (`Draining` keeps its occupants on purpose;
    /// only `Down` nodes may never host a running job).
    fn audit_placements(&mut self) {
        // lint:allow(hash-iter, commutative violation count - iteration order cannot affect it)
        for e in self.running.values() {
            for &(nid, _, _) in &e.alloc.taken {
                if self.cluster.node_state(nid) == NodeState::Down {
                    self.fault_counters.invariant_violations += 1;
                }
            }
        }
    }

    /// End instant of reservation `res` (fixed by its spec).
    fn resv_end(reservations: &[ReservationSpec], res: usize) -> u64 {
        let r = &reservations[res];
        r.start.saturating_add(r.duration)
    }

    /// The single clamp rule for the planning horizon — used by both the
    /// incremental hold on job start and the resync re-encoding, which
    /// must agree for stored holds to reverse exactly. (Associated fn,
    /// not a method: resync calls it while `running` is mutably
    /// borrowed.)
    fn clamp_to_horizon(horizon: u64, now: u64, t: u64) -> u64 {
        if horizon == 0 {
            t
        } else {
            t.min(now.saturating_add(horizon))
        }
    }

    /// Rebuild the availability timeline from authoritative state: the
    /// cluster's current free pool plus every known future capacity
    /// delta. Called on capacity transitions (node failure/repair,
    /// reservation claim/expiry, departures touching non-`Up` nodes) —
    /// the rare path; steady-state rounds maintain the timeline
    /// incrementally. Also rewrites each running entry's hold deltas so
    /// later incremental releases reverse exactly what this encoding
    /// promised.
    fn resync_profile(&mut self, now: SimTime) {
        let nowt = now.ticks();
        // Auto horizon: a resync re-derives the clamp when queue depth
        // has drifted (the staleness law), so the re-encoding below and
        // all later incremental holds agree on one horizon until the
        // next derivation. Gated on staleness because fault-heavy runs
        // resync often — an O(queue) median on every repair would put
        // the cost right back on the path this mode optimizes.
        if self.auto_horizon_stale() {
            self.derive_auto_horizon();
        }
        let horizon = self.effective_horizon;
        let mem_aware = self.memory_aware;
        let clamp = |t: u64| Self::clamp_to_horizon(horizon, nowt, t);
        let resv_ends: Vec<u64> =
            (0..self.reservations.len()).map(|r| Self::resv_end(&self.reservations, r)).collect();
        let mut deltas: Vec<(u64, i64)> = Vec::with_capacity(self.running.len() + 8);
        let mut mem_deltas: Vec<(u64, i64)> = Vec::new();
        // Running jobs: resources rejoin the pool at the estimated end —
        // per node, because a draining node hands its portion back only
        // once both the job and the claiming reservation are done.
        // lint:allow(hash-iter, deltas are sorted inside the Timeline rebuild - order never escapes)
        for entry in self.running.values_mut() {
            entry.hold.clear();
            let est = entry.est_end.ticks();
            for &(nid, c, m) in &entry.alloc.taken {
                let t = match self.cluster.node_state(nid) {
                    NodeState::Up => est,
                    NodeState::Draining => match self.claimed.get(&nid) {
                        Some(&res) => est.max(resv_ends[res]),
                        None => est,
                    },
                    // Occupants never survive on `Down` nodes (killed
                    // first) and `Reserved` nodes are idle by
                    // construction; their cores never rejoin via the job.
                    NodeState::Down | NodeState::Reserved => continue,
                };
                let t = clamp(t);
                let m = if mem_aware { m } else { 0 };
                if t > nowt {
                    match entry.hold.iter_mut().find(|h| h.0 == t) {
                        Some(h) => h.1 = h.1.add(ResourceVector::new(c, m)),
                        None => entry.hold.push((t, ResourceVector::new(c, m))),
                    }
                } else {
                    // Overrun past the estimate: the timeline already
                    // counts these resources free (planning estimate
                    // semantics — same as the rebuild it replaces).
                    deltas.push((nowt, c as i64));
                    if m > 0 {
                        mem_deltas.push((nowt, m as i64));
                    }
                }
            }
            deltas.extend(entry.hold.iter().map(|&(t, d)| (t, d.cores as i64)));
            mem_deltas.extend(
                entry.hold.iter().filter(|h| h.1.memory_mb > 0).map(|&(t, d)| (t, d.memory_mb as i64)),
            );
        }
        self.push_capacity_deltas(nowt, horizon, &mut deltas, &mut mem_deltas);
        if mem_aware {
            self.profile.rebuild_v(
                nowt,
                ResourceVector::new(self.cluster.free_cores(), self.cluster.free_memory_mb()),
                deltas,
                mem_deltas,
            );
        } else {
            self.profile.rebuild(nowt, self.cluster.free_cores(), deltas);
        }
        self.last_resync = nowt;
        self.profile_stale = false;
    }

    /// Non-running capacity deltas shared by [`Self::resync_profile`]
    /// and the sanitizer's read-only rebuild oracle: claimed nodes,
    /// pending repairs, and future reservation windows. Read-only over
    /// `self`, so the oracle path cannot perturb simulation state.
    fn push_capacity_deltas(
        &self,
        nowt: u64,
        horizon: u64,
        deltas: &mut Vec<(u64, i64)>,
        mem_deltas: &mut Vec<(u64, i64)>,
    ) {
        let mem_aware = self.memory_aware;
        let clamp = |t: u64| Self::clamp_to_horizon(horizon, nowt, t);
        let resv_ends: Vec<u64> =
            (0..self.reservations.len()).map(|r| Self::resv_end(&self.reservations, r)).collect();
        // Claimed nodes: the unoccupied portion returns when the
        // reservation expires.
        // lint:allow(hash-iter, deltas are sorted inside the Timeline rebuild - order never escapes)
        for (&nid, &res) in &self.claimed {
            let node = &self.cluster.nodes()[nid];
            match node.state {
                NodeState::Reserved | NodeState::Draining => {
                    let t = clamp(resv_ends[res]);
                    if t > nowt {
                        if node.free_cores > 0 {
                            deltas.push((t, node.free_cores as i64));
                        }
                        if mem_aware && node.free_memory_mb > 0 {
                            mem_deltas.push((t, node.free_memory_mb as i64));
                        }
                    }
                }
                // Down claimed nodes return via their repair below.
                NodeState::Down | NodeState::Up => {}
            }
        }
        // Failed nodes: full capacity back at the known repair instant
        // (or at reservation expiry when a claim will grab the node on
        // repair, whichever is later).
        // lint:allow(hash-iter, deltas are sorted inside the Timeline rebuild - order never escapes)
        for (&nid, &t_repair) in &self.pending_repairs {
            let t = match self.claimed.get(&nid) {
                Some(&res) => t_repair.max(resv_ends[res]),
                None => t_repair,
            };
            let t = clamp(t);
            if t > nowt {
                deltas.push((t, self.cluster.nodes()[nid].cores as i64));
                if mem_aware && self.cluster.nodes()[nid].memory_mb > 0 {
                    mem_deltas.push((t, self.cluster.nodes()[nid].memory_mb as i64));
                }
            }
        }
        // Future reservations: planned capacity windows.
        for (res, spec) in self.reservations.iter().enumerate() {
            if !self.resv_pending.get(res).copied().unwrap_or(false) {
                continue;
            }
            let cores = self.resv_plan_cores.get(res).copied().unwrap_or(0);
            let start = clamp(spec.start.max(nowt));
            let end = clamp(resv_ends[res]);
            if start < end && cores > 0 {
                deltas.push((start, -(cores as i64)));
                deltas.push((end, cores as i64));
            }
            let mem = if mem_aware { self.resv_plan_mem.get(res).copied().unwrap_or(0) } else { 0 };
            if start < end && mem > 0 {
                mem_deltas.push((start, -(mem as i64)));
                mem_deltas.push((end, mem as i64));
            }
        }
    }

    /// Sanitizer oracle: rebuild an availability profile from scratch —
    /// re-deriving every running entry's capacity-return deltas from its
    /// allocation, estimated end and current node states (the exact
    /// encoding `resync_profile` uses), plus the shared capacity deltas —
    /// and require it to equal the incrementally maintained one,
    /// value-wise. Read-only (unlike `resync_profile`, which rewrites
    /// stored entry holds), so running it cannot change any later
    /// decision: sanitize-on runs stay byte-identical to sanitize-off
    /// runs. It must re-derive rather than replay stored holds because a
    /// resync drops overrun holds from storage (they become immediate
    /// free capacity); replaying storage would go blind to those. Only
    /// meaningful on exact-horizon, non-stale profiles — clamped-horizon
    /// resyncs legitimately re-encode with a fresher clamp (see
    /// ROADMAP), and a stale profile is rebuilt at dispatch before
    /// anyone reads it.
    fn verify_profile_against_rebuild(&self, now: SimTime) {
        let nowt = now.ticks();
        let horizon = self.effective_horizon;
        let clamp = |t: u64| Self::clamp_to_horizon(horizon, nowt, t);
        let resv_ends: Vec<u64> =
            (0..self.reservations.len()).map(|r| Self::resv_end(&self.reservations, r)).collect();
        let mut deltas: Vec<(u64, i64)> = Vec::with_capacity(self.running.len() + 8);
        let mut mem_deltas: Vec<(u64, i64)> = Vec::new();
        // lint:allow(hash-iter, deltas are sorted inside the Timeline rebuild - order never escapes)
        for entry in self.running.values() {
            let est = entry.est_end.ticks();
            for &(nid, c, m) in &entry.alloc.taken {
                let t = match self.cluster.node_state(nid) {
                    NodeState::Up => est,
                    NodeState::Draining => match self.claimed.get(&nid) {
                        Some(&res) => est.max(resv_ends[res]),
                        None => est,
                    },
                    NodeState::Down | NodeState::Reserved => continue,
                };
                // Past-the-estimate overruns count free from `now` on
                // (planning-estimate semantics, same as resync).
                let t = clamp(t).max(nowt);
                deltas.push((t, c as i64));
                let m = if self.memory_aware { m } else { 0 };
                if m > 0 {
                    mem_deltas.push((t, m as i64));
                }
            }
        }
        self.push_capacity_deltas(nowt, horizon, &mut deltas, &mut mem_deltas);
        let total =
            ResourceVector::new(self.cluster.total_cores(), self.cluster.total_memory_mb());
        let free =
            ResourceVector::new(self.cluster.free_cores(), self.cluster.free_memory_mb());
        let mut expected = if self.memory_aware {
            AvailabilityProfile::new_v(nowt, free, total)
        } else {
            AvailabilityProfile::new(nowt, free.cores, total.cores)
        };
        if self.memory_aware {
            expected.rebuild_v(nowt, free, deltas, mem_deltas);
        } else {
            expected.rebuild(nowt, free.cores, deltas);
        }
        sanitizer::check_profile_match(&self.profile, &expected, nowt, "dispatch boundary");
    }

    /// Test-only corruption hook: skew the live timeline by one phantom
    /// held core so tests can prove the profile invariant actually
    /// trips. Never called outside tests.
    #[cfg(any(debug_assertions, feature = "sanitize"))]
    pub fn sanitizer_skew_hold_for_test(&mut self, now: u64) {
        self.profile.hold_v(now, now.saturating_add(1_000), ResourceVector::new(1, 0));
    }

    /// Test-only trigger: run the profile-vs-rebuild oracle right now,
    /// regardless of the sampling cadence.
    #[cfg(any(debug_assertions, feature = "sanitize"))]
    pub fn sanitizer_verify_profile_for_test(&mut self, now: u64) {
        if self.profile_stale {
            self.resync_profile(SimTime(now));
        }
        self.verify_profile_against_rebuild(SimTime(now));
    }

    /// Apply a node failure: kill occupants, take the node down, and
    /// schedule its repair.
    fn fail_node(&mut self, victim_draw: u64, repair_after: SimDuration, ctx: &mut Ctx<Ev>) {
        let mut candidates: Vec<usize> = (0..self.cluster.num_nodes())
            .filter(|&i| self.cluster.node_state(i) != NodeState::Down)
            .collect();
        if candidates.is_empty() {
            return; // whole machine already down; nothing to fail
        }
        let node = candidates.swap_remove((victim_draw % candidates.len() as u64) as usize);
        self.fault_counters.failures += 1;
        self.cluster.set_node_state(node, NodeState::Down);
        self.pending_repairs.insert(node, (ctx.now() + repair_after).ticks());
        // The occupant kills below mark the profile stale (their nodes
        // are Down now); the next dispatch rebuilds once — a same-tick
        // failure storm pays one resync total, not one per transition.
        for id in self.occupants_of(&[node]) {
            self.interrupt_job(id, InterruptReason::Failure, ctx);
        }
        self.profile_stale = true;
        ctx.schedule_self(repair_after, Priority::COMPLETE, Ev::NodeUp { node });
        self.audit_placements();
        self.record_series(ctx.now());
        if !self.queue.is_empty() {
            self.request_dispatch(ctx);
        }
    }

    /// Apply a node repair: the node rejoins as `Up`, or as `Reserved`
    /// when a still-active reservation claims it.
    fn repair_node(&mut self, node: usize, ctx: &mut Ctx<Ev>) {
        self.fault_counters.repairs += 1;
        self.pending_repairs.remove(&node);
        let state = if self.claimed.contains_key(&node) {
            NodeState::Reserved
        } else {
            NodeState::Up
        };
        self.cluster.set_node_state(node, state);
        self.profile_stale = true;
        self.audit_placements();
        self.record_series(ctx.now());
        if !self.queue.is_empty() {
            self.request_dispatch(ctx);
        }
    }

    /// A reservation comes due: claim nodes (idle first, then least
    /// loaded; ids break ties). With preemption the occupants are
    /// evicted and the nodes go straight to `Reserved`; without it the
    /// occupied ones drain — they finish their jobs but accept no new
    /// work, degrading the reservation.
    fn start_reservation(&mut self, res: usize, ctx: &mut Ctx<Ev>) {
        self.fault_counters.reservations_started += 1;
        if let Some(p) = self.resv_pending.get_mut(res) {
            *p = false; // the planned window becomes an actual claim
        }
        let want = self.reservations[res].nodes;
        let mut up: Vec<usize> = (0..self.cluster.num_nodes())
            .filter(|&i| {
                self.cluster.node_state(i) == NodeState::Up && !self.claimed.contains_key(&i)
            })
            .collect();
        up.sort_by_key(|&i| (self.cluster.nodes()[i].busy_cores(), i));
        let claim: Vec<usize> = up.into_iter().take(want).collect();
        // A shortfall (failed or already-claimed nodes) must be visible
        // to the operator, not silently truncated.
        self.fault_counters.reservations_short_nodes += (want - claim.len()) as u64;
        if self.preemption.enabled() {
            // The deferred resync (next dispatch) covers these
            // departures too — evicted occupants requeue, so a dispatch
            // at this tick is guaranteed.
            for id in self.occupants_of(&claim) {
                self.interrupt_job(id, InterruptReason::Eviction, ctx);
            }
        }
        for &node in &claim {
            self.claimed.insert(node, res);
            if self.cluster.nodes()[node].is_idle() {
                self.cluster.set_node_state(node, NodeState::Reserved);
            } else {
                self.cluster.set_node_state(node, NodeState::Draining);
                self.fault_counters.reservations_degraded += 1;
            }
        }
        self.profile_stale = true;
        self.audit_placements();
        self.record_series(ctx.now());
    }

    /// A reservation expires: its nodes (wherever they drained or were
    /// repaired to) return to service.
    fn end_reservation(&mut self, res: usize, ctx: &mut Ctx<Ev>) {
        let mut nodes: Vec<usize> = self
            .claimed
            .iter()
            .filter(|&(_, &r)| r == res)
            .map(|(&n, _)| n)
            .collect();
        nodes.sort_unstable(); // deterministic release order
        for node in nodes {
            self.claimed.remove(&node);
            if self.cluster.node_state(node) != NodeState::Down {
                self.cluster.set_node_state(node, NodeState::Up);
            }
        }
        if let Some(p) = self.resv_pending.get_mut(res) {
            *p = false; // defensive: an end without a start is spent too
        }
        self.profile_stale = true;
        self.audit_placements();
        self.record_series(ctx.now());
        if !self.queue.is_empty() {
            self.request_dispatch(ctx);
        }
    }

    /// A draining node whose last occupant left flips to `Reserved` for
    /// the reservation that claimed it.
    fn settle_drained_nodes(&mut self, alloc_nodes: &[usize]) {
        for &node in alloc_nodes {
            if self.claimed.contains_key(&node)
                && self.cluster.node_state(node) == NodeState::Draining
                && self.cluster.nodes()[node].is_idle()
            {
                self.cluster.set_node_state(node, NodeState::Reserved);
            }
        }
    }

    fn dispatch(&mut self, ctx: &mut Ctx<Ev>) {
        self.dispatch_pending = false;
        self.dispatches += 1;
        let now = ctx.now();
        // The availability timeline tracks "from now on"; drop history.
        self.profile.advance(now.ticks());
        // Rebuild the timeline when (a) a capacity transition since the
        // last round left it stale — the deferred-resync flag, one
        // rebuild however many same-tick transitions raised it; (b) the
        // auto horizon must re-derive (queue depth drifted a factor of
        // two from the last derivation); or (c) a finite horizon is due
        // its time refresh: events clamped away at the last resync
        // (reservation windows, far-out releases) must re-enter the
        // timeline as time approaches them — every horizon/2 ticks of
        // progress guarantees at least half a horizon of advance notice
        // while keeping resyncs rare.
        if self.profile_stale
            || self.auto_horizon_stale()
            || (self.effective_horizon > 0
                && now.ticks().saturating_sub(self.last_resync)
                    >= (self.effective_horizon / 2).max(1))
        {
            self.resync_profile(now);
        }
        // Phase 0 — policy-driven preemption (fault subsystem): the
        // scheduler may evict strictly lower-priority running jobs for a
        // starving waiting job before the allocation pass. The snapshot
        // is filled into a reusable buffer at most once per round and
        // reused by the allocation pass unless evictions invalidated it
        // (snapshots are O(running) on the DES hot path). Planning
        // policies read the timeline instead and skip the snapshot
        // entirely.
        let evictions_possible = self.preemption.enabled()
            && self.preemption.starvation_threshold > SimDuration::ZERO;
        let mut running_info = std::mem::take(&mut self.running_scratch);
        running_info.clear();
        if evictions_possible || self.scheduler.uses_running_info() {
            Self::fill_running_snapshot(&self.running, &mut running_info);
        }
        if evictions_possible {
            let victims = {
                let input = SchedInput {
                    now,
                    queue: &self.queue,
                    running: &running_info,
                    profile: &self.profile,
                    order: &*self.queue_order,
                    scratch: Some(&self.scratch),
                };
                self.scheduler.preempt(&input, &self.cluster)
            };
            if !victims.is_empty() {
                for id in victims {
                    self.interrupt_job(id, InterruptReason::Eviction, ctx);
                }
                running_info.clear();
                if self.scheduler.uses_running_info() {
                    Self::fill_running_snapshot(&self.running, &mut running_info);
                }
                if self.profile_stale {
                    // A victim sat on a non-`Up` node: its release could
                    // not be reversed incrementally, and the allocation
                    // pass below reads the profile — rebuild now.
                    self.resync_profile(now);
                }
            }
        }
        let allocations = {
            let input = SchedInput {
                now,
                queue: &self.queue,
                running: &running_info,
                profile: &self.profile,
                order: &*self.queue_order,
                scratch: Some(&self.scratch),
            };
            self.scheduler.schedule(&input, &mut self.cluster)
        };
        self.running_scratch = running_info;
        for alloc in allocations {
            let mut job = self
                .queue
                .remove(alloc.job_id)
                .expect("scheduler allocated a job not in the queue");
            job.mark_started(now);
            let est_end = now + job.est_remaining();
            // Incremental timeline update: the job holds its resources
            // until the estimated end (clamped to the planning horizon).
            let nowt = now.ticks();
            let planned = Self::clamp_to_horizon(self.effective_horizon, nowt, est_end.ticks());
            let mut hold = Vec::new();
            if planned > nowt {
                let d = ResourceVector::new(
                    alloc.cores(),
                    if self.memory_aware { alloc.memory_mb() } else { 0 },
                );
                self.profile.hold_v(nowt, planned, d);
                hold.push((planned, d));
            }
            ctx.send(
                self.executor,
                Priority::DEFAULT,
                Ev::Start {
                    job_id: job.id,
                    runtime: job.remaining,
                    incarnation: job.incarnation,
                },
            );
            self.running.insert(job.id, RunningEntry { job, alloc, est_end, hold });
        }
        // Starvation timer: wake up when the oldest feasible waiter
        // crosses the threshold so its eviction round actually runs.
        if self.starvation_timer == Some(now) {
            self.starvation_timer = None;
        }
        if self.preemption.enabled()
            && self.preemption.starvation_threshold > SimDuration::ZERO
        {
            let deadline = self
                .queue
                .iter()
                .find(|j| self.cluster.feasible(j))
                .map(|j| j.submit + self.preemption.starvation_threshold);
            if let Some(deadline) = deadline {
                let timer_ok =
                    self.starvation_timer.map_or(true, |t| t > deadline || t <= now);
                if deadline > now && timer_ok {
                    self.starvation_timer = Some(deadline);
                    ctx.schedule_self(deadline - now, Priority::SCHEDULE, Ev::Dispatch);
                }
            }
        }
        self.record_series(now);
        // Sanity: cached aggregates stay consistent (cheap check).
        debug_assert!(self.cluster.check_invariants());
        // Sanitizer: the incremental timeline equals a from-scratch
        // rebuild. Exact-horizon only — clamped resyncs legitimately
        // re-encode with a fresher clamp — and never on a stale profile
        // (it gets rebuilt before the next read anyway).
        if sanitizer::ACTIVE
            && self.horizon == Horizon::Exact
            && !self.profile_stale
            && self.san.on_dispatch()
        {
            self.verify_profile_against_rebuild(now);
        }
    }

    fn complete(&mut self, job_id: JobId, incarnation: u32, ctx: &mut Ctx<Ev>) {
        // Stale completions are expected under preemption: the segment
        // that scheduled them was interrupted and the job re-queued.
        let current = self.running.get(&job_id).map(|e| e.job.incarnation);
        if current != Some(incarnation) {
            return;
        }
        let now = ctx.now();
        let RunningEntry { mut job, alloc, hold, .. } = self
            .running
            .remove(&job_id)
            .expect("completion for unknown job");
        self.cluster.release(&alloc);
        self.release_profile_hold(&alloc, &hold, now);
        // Fair-share accounting on job end: charge the machine time the
        // final segment actually consumed.
        let elapsed = job.last_start.map(|s| now - s).unwrap_or(SimDuration::ZERO);
        self.queue_order
            .record_usage(job.user, job.group, alloc.cores(), elapsed.ticks(), now);
        job.mark_completed(now);
        if sanitizer::ACTIVE {
            sanitizer::check_segment_accounting(
                job.id,
                now.ticks(),
                job.executed.ticks(),
                job.runtime.ticks(),
                job.overhead.ticks(),
                job.lost.ticks(),
            );
        }
        self.completed_count += 1;
        if let Some(wt) = job.wait_time() {
            self.wait_ticks_total += wt.as_f64();
        }
        self.useful_work += job.runtime.as_f64() * job.cores as f64;
        if self.retain_completed {
            self.completed.push(job);
        }
        self.settle_drained_nodes(&alloc.node_ids());
        self.record_series(now);
        // Goodput denominator: available core-seconds up to this (the
        // latest) completion.
        self.avail_integral_at_completion = self.avail_integral;
        if !self.queue.is_empty() {
            self.request_dispatch(ctx);
        }
    }
}

impl Component<Ev> for SchedulerComponent {
    fn name(&self) -> &str {
        "scheduler"
    }

    fn init(&mut self, ctx: &mut Ctx<Ev>) {
        // Memory awareness is inert on machines that track no memory —
        // that (and only that) keeps cores-only runs on the scalar path.
        self.memory_aware = self.memory_aware && self.cluster.total_memory_mb() > 0;
        if self.memory_aware {
            self.profile = AvailabilityProfile::new_v(
                ctx.now().ticks(),
                ResourceVector::new(self.cluster.free_cores(), self.cluster.free_memory_mb()),
                ResourceVector::new(self.cluster.total_cores(), self.cluster.total_memory_mb()),
            );
        }
        // Seed the availability timeline: declared reservations hold
        // planned capacity windows from the start, which is how backfill
        // plans around them before they claim a single node.
        self.resv_pending = vec![true; self.reservations.len()];
        self.resv_plan_cores = self
            .reservations
            .iter()
            .map(|r| self.cluster.reservation_plan_cores(r.nodes))
            .collect();
        self.resv_plan_mem = self
            .reservations
            .iter()
            .map(|r| self.cluster.reservation_plan_mem(r.nodes))
            .collect();
        self.resync_profile(ctx.now());
    }

    fn handle(&mut self, ev: Ev, ctx: &mut Ctx<Ev>) {
        match ev {
            Ev::Submit(job) => {
                if !self.cluster.feasible(&job) {
                    self.rejected += 1;
                } else {
                    self.queue.push(*job);
                    self.request_dispatch(ctx);
                }
            }
            Ev::Dispatch => self.dispatch(ctx),
            Ev::Complete { job_id, incarnation } => self.complete(job_id, incarnation, ctx),
            Ev::NodeFail { victim_draw, repair_after } => {
                self.fail_node(victim_draw, repair_after, ctx)
            }
            Ev::NodeUp { node } => self.repair_node(node, ctx),
            Ev::ReserveStart { res } => self.start_reservation(res, ctx),
            Ev::ReserveEnd { res } => self.end_reservation(res, ctx),
            other => panic!("scheduler got unexpected event {other:?}"),
        }
        // Sanitizer: core/memory conservation against per-node truth at
        // event boundaries (every event early, then sampled).
        if sanitizer::ACTIVE && self.san.on_event() {
            let sample = sanitizer::sample_cluster(&self.cluster);
            sanitizer::check_conservation(&sample, ctx.now().ticks(), "scheduler event boundary");
        }
        if let Some(mark) = &self.activity_mark {
            if !self.queue.is_empty() || !self.running.is_empty() {
                mark.fetch_max(ctx.now().ticks(), Ordering::Relaxed);
            }
        }
    }

    fn finish(&mut self, ctx: &mut Ctx<Ev>) {
        // Close the series at the end of the run.
        let now = ctx.now();
        self.record_series(now);
    }

    /// Field-by-field deep copy. Fails (`None`) when the scheduling
    /// policy is non-cloneable (accelerator-backed scorer) or when an
    /// activity watermark is attached: the watermark `Arc` is *shared*
    /// with the fault injector, and a copy would either alias it
    /// (speculation perturbs the live run) or split it (clone behavior
    /// diverges) — and it only exists on streamed runs, which the job
    /// source already refuses to snapshot.
    fn snapshot_box(&self) -> Option<Box<dyn Component<Ev>>> {
        if self.activity_mark.is_some() {
            return None;
        }
        Some(Box::new(SchedulerComponent {
            cluster: self.cluster.clone(),
            scheduler: self.scheduler.clone_box()?,
            queue_order: self.queue_order.clone_box(),
            memory_aware: self.memory_aware,
            queue: self.queue.clone(),
            running: self.running.clone(),
            profile: self.profile.clone(),
            horizon: self.horizon,
            effective_horizon: self.effective_horizon,
            auto_depth: self.auto_depth,
            auto_params: self.auto_params,
            // Pure per-round scratch: every buffer is cleared or
            // overwritten at the start of the round that uses it, so a
            // fresh default is decision-identical.
            scratch: RefCell::new(RoundScratch::default()),
            running_scratch: Vec::new(),
            pending_repairs: self.pending_repairs.clone(),
            resv_pending: self.resv_pending.clone(),
            resv_plan_cores: self.resv_plan_cores.clone(),
            resv_plan_mem: self.resv_plan_mem.clone(),
            last_resync: self.last_resync,
            profile_stale: self.profile_stale,
            completed: self.completed.clone(),
            retain_completed: self.retain_completed,
            completed_count: self.completed_count,
            wait_ticks_total: self.wait_ticks_total,
            useful_work: self.useful_work,
            first_record_t: self.first_record_t,
            last_record_t: self.last_record_t,
            last_util: self.last_util,
            last_mem_util: self.last_mem_util,
            last_avail: self.last_avail,
            util_integral: self.util_integral,
            mem_util_integral: self.mem_util_integral,
            avail_integral: self.avail_integral,
            avail_integral_at_completion: self.avail_integral_at_completion,
            rejected: self.rejected,
            executor: self.executor,
            dispatch_pending: self.dispatch_pending,
            dispatches: self.dispatches,
            occupancy: self.occupancy.clone(),
            running_series: self.running_series.clone(),
            util_series: self.util_series.clone(),
            mem_util_series: self.mem_util_series.clone(),
            effective_util_series: self.effective_util_series.clone(),
            avail_series: self.avail_series.clone(),
            preemption: self.preemption,
            reservations: self.reservations.clone(),
            claimed: self.claimed.clone(),
            fault_counters: self.fault_counters,
            lost_work: self.lost_work,
            overhead_work: self.overhead_work,
            starvation_timer: self.starvation_timer,
            activity_mark: None,
            san: self.san.clone(),
        }))
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn Any {
        self
    }
}

/// Job Executor (paper Fig 1): turns a dispatched job into a completion
/// after its actual remaining runtime, echoing the segment incarnation so
/// the scheduler can discard completions of preempted segments.
pub struct JobExecutor {
    pub scheduler: ComponentId,
    pub executed: u64,
}

impl JobExecutor {
    pub fn new(scheduler: ComponentId) -> JobExecutor {
        JobExecutor { scheduler, executed: 0 }
    }
}

impl Component<Ev> for JobExecutor {
    fn name(&self) -> &str {
        "executor"
    }

    fn handle(&mut self, ev: Ev, ctx: &mut Ctx<Ev>) {
        match ev {
            Ev::Start { job_id, runtime, incarnation } => {
                self.executed += 1;
                ctx.send_after(
                    self.scheduler,
                    runtime,
                    Priority::COMPLETE,
                    Ev::Complete { job_id, incarnation },
                );
            }
            other => panic!("executor got unexpected event {other:?}"),
        }
    }

    fn snapshot_box(&self) -> Option<Box<dyn Component<Ev>>> {
        Some(Box::new(JobExecutor { scheduler: self.scheduler, executed: self.executed }))
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn source_orders_and_batches() {
        let jobs = vec![
            Job::simple(2, 10, 1, 5),
            Job::simple(1, 10, 1, 5),
            Job::simple(3, 20, 1, 5),
        ];
        let mut s = JobSource::new(jobs);
        assert_eq!(s.buffered(), 3, "eager feed holds the whole trace");
        // Sorted feed: earliest (id 1 at t=10) pops first.
        assert_eq!(s.peek_submit(), Some(SimTime(10)));
        assert_eq!(s.pop_next().unwrap().id, 1);
        assert_eq!(s.pop_next().unwrap().id, 2);
        assert_eq!(s.pop_next().unwrap().id, 3);
        assert!(s.pop_next().is_none());
    }

    #[test]
    fn streamed_source_buffers_exactly_one_job() {
        let jobs = vec![Job::simple(1, 0, 1, 5), Job::simple(2, 10, 1, 5)];
        let mut s = JobSource::from_stream(Box::new(jobs.into_iter()));
        assert_eq!(s.buffered(), 0);
        assert_eq!(s.peek_submit(), Some(SimTime(0)));
        assert_eq!(s.buffered(), 1, "streamed lookahead is exactly one job");
        assert_eq!(s.pop_next().unwrap().id, 1);
        assert_eq!(s.peek_submit(), Some(SimTime(10)));
        assert_eq!(s.buffered(), 1);
        assert_eq!(s.pop_next().unwrap().id, 2);
        assert_eq!(s.peek_submit(), None);
        assert_eq!(s.buffered(), 0);
    }

    #[test]
    fn executor_counts() {
        let e = JobExecutor::new(0);
        assert_eq!(e.executed, 0);
    }
}
