//! Multi-cluster operation (paper §5 future work: "heterogeneous job and
//! multi-cluster operation"): a meta-scheduler routes arriving jobs to
//! one of several autonomous clusters, each running its own scheduler —
//! the way DAS-2 itself was operated (five clusters, per-cluster queues).
//!
//! Since the sharded-engine PR, `MetaScheduler::run` no longer buckets
//! jobs up front and simulates each cluster serially: it delegates to
//! [`crate::parallel::run_sharded`], where the router is a rank-0
//! component of a conservative PDES and every routing decision becomes
//! a timestamped cross-rank message. The incremental routing state
//! lives in [`RouterState`] so the batch `route()` helper and the
//! sharded engine share one implementation (and one set of fixes).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::core::time::SimTime;
use crate::job::Job;
use crate::metrics::{wait_stats, WaitStats};
use crate::parallel::{run_sharded, RankSimOpts, ShardOpts};
use crate::sched::Policy;

/// Routing policy of the meta-scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Routing {
    /// Cycle through clusters (ignores state).
    RoundRobin,
    /// Send to the cluster with the least outstanding core-seconds.
    LeastLoaded,
    /// Send to the *smallest* cluster that can ever fit the job
    /// (best-fit at cluster granularity; keeps big machines free for
    /// big jobs).
    BestFitCluster,
}

impl Routing {
    /// Canonical name, matching what `FromStr` accepts.
    pub fn as_str(&self) -> &'static str {
        match self {
            Routing::RoundRobin => "round-robin",
            Routing::LeastLoaded => "least-loaded",
            Routing::BestFitCluster => "best-fit-cluster",
        }
    }
}

impl std::str::FromStr for Routing {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "round-robin" | "rr" => Ok(Routing::RoundRobin),
            "least-loaded" | "ll" => Ok(Routing::LeastLoaded),
            "best-fit-cluster" | "bf" => Ok(Routing::BestFitCluster),
            other => Err(format!("unknown routing {other:?}")),
        }
    }
}

/// A cluster description within the federation.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub name: String,
    pub nodes: usize,
    pub cores_per_node: u64,
}

impl ClusterSpec {
    pub fn total_cores(&self) -> u64 {
        self.nodes as u64 * self.cores_per_node
    }
}

/// Outstanding (not-yet-completed) work charged to one cluster, in
/// est-based core-seconds. The meta-scheduler cannot see actual
/// runtimes, so completions are *estimated*: a job charged at time `t`
/// with estimate `e` is assumed gone at `t + e`.
///
/// Represented as `Σ cores·end − busy_cores·now` over unexpired jobs,
/// which equals the remaining est-based core-ticks at `now` and lets
/// expiry pop a min-heap instead of rescanning.
struct ClusterLoad {
    /// Min-heap of (estimated end, cores) for charged jobs.
    ends: BinaryHeap<Reverse<(u64, u64)>>,
    weighted_end: f64,
    busy_cores: f64,
}

impl ClusterLoad {
    fn new() -> ClusterLoad {
        ClusterLoad { ends: BinaryHeap::new(), weighted_end: 0.0, busy_cores: 0.0 }
    }

    fn expire(&mut self, now: u64) {
        while let Some(&Reverse((end, cores))) = self.ends.peek() {
            if end > now {
                break;
            }
            self.ends.pop();
            self.weighted_end -= (end as f64) * (cores as f64);
            self.busy_cores -= cores as f64;
        }
    }

    fn outstanding(&mut self, now: u64) -> f64 {
        self.expire(now);
        (self.weighted_end - self.busy_cores * now as f64).max(0.0)
    }

    fn charge(&mut self, now: u64, cores: u64, est_ticks: u64) {
        let end = now.saturating_add(est_ticks.max(1));
        self.ends.push(Reverse((end, cores)));
        self.weighted_end += (end as f64) * (cores as f64);
        self.busy_cores += cores as f64;
    }
}

/// Incremental routing state: feed jobs one at a time (in submit
/// order) and get a cluster index back. This is the single source of
/// truth for routing decisions — the batch [`MetaScheduler::route`]
/// and the sharded engine's rank-0 router both drive it.
pub struct RouterState {
    routing: Routing,
    caps: Vec<u64>,
    /// Round-robin cursors, one per *fit-set size* (1..=n clusters).
    /// Fit sets here are determined solely by a core threshold, so two
    /// fit sets of equal size are the same set — a cursor per size is
    /// a cursor per distinct set, and mixed big/small traffic no
    /// longer strides one shared counter (the old bias: step 2 mod an
    /// even fit-set size starved half the clusters).
    rr_cursors: Vec<usize>,
    /// Estimated outstanding load (LeastLoaded only).
    loads: Vec<ClusterLoad>,
    now: u64,
}

impl RouterState {
    pub fn new(clusters: &[ClusterSpec], routing: Routing) -> RouterState {
        let caps: Vec<u64> = clusters.iter().map(|c| c.total_cores()).collect();
        let loads = if routing == Routing::LeastLoaded {
            caps.iter().map(|_| ClusterLoad::new()).collect()
        } else {
            Vec::new()
        };
        RouterState {
            routing,
            rr_cursors: vec![0usize; caps.len() + 1],
            caps,
            loads,
            now: 0,
        }
    }

    /// Route one job (jobs must arrive in nondecreasing submit order
    /// for LeastLoaded decay to be meaningful). `None` = fits no
    /// cluster.
    pub fn route_one(&mut self, j: &Job) -> Option<usize> {
        self.now = self.now.max(j.submit.ticks());
        let fits: Vec<usize> =
            (0..self.caps.len()).filter(|&i| j.cores <= self.caps[i]).collect();
        if fits.is_empty() {
            return None;
        }
        let pick = match self.routing {
            Routing::RoundRobin => {
                let cur = &mut self.rr_cursors[fits.len()];
                let p = fits[*cur % fits.len()];
                *cur += 1;
                p
            }
            Routing::LeastLoaded => {
                // Lowest outstanding-load fraction; ties go to the
                // lowest index (fits is ascending, strict < keeps the
                // first minimum).
                let mut best = fits[0];
                let mut best_frac = f64::INFINITY;
                for &i in &fits {
                    let frac = self.loads[i].outstanding(self.now) / self.caps[i] as f64;
                    if frac < best_frac {
                        best_frac = frac;
                        best = i;
                    }
                }
                self.loads[best].charge(self.now, j.cores, j.est_runtime.ticks());
                best
            }
            Routing::BestFitCluster => {
                fits.iter().copied().min_by_key(|&i| (self.caps[i], i)).unwrap()
            }
        };
        Some(pick)
    }
}

/// Result of a federated run.
#[derive(Debug, Clone)]
pub struct MultiClusterReport {
    pub routing: Routing,
    pub per_cluster: Vec<(String, WaitStats, f64)>, // (name, waits, utilization)
    pub all_jobs: Vec<Job>,
    pub rejected: u64,
    pub end_time: SimTime,
    /// FNV-1a digest of routing decisions + per-domain schedules —
    /// byte-identical across shard counts.
    pub fingerprint: u64,
}

impl MultiClusterReport {
    pub fn wait_stats(&self) -> WaitStats {
        wait_stats(&self.all_jobs)
    }
}

/// The meta-scheduler: routes jobs to autonomous clusters (no job
/// migration — as on the real DAS-2) and runs the federation on the
/// sharded PDES engine.
pub struct MetaScheduler {
    pub clusters: Vec<ClusterSpec>,
    pub routing: Routing,
    pub policy: Policy,
}

impl MetaScheduler {
    pub fn new(clusters: Vec<ClusterSpec>, routing: Routing, policy: Policy) -> MetaScheduler {
        assert!(!clusters.is_empty());
        MetaScheduler { clusters, routing, policy }
    }

    /// DAS-2's actual federation: one 72-node head cluster + four
    /// 32-node clusters, dual-CPU nodes.
    pub fn das2_federation(routing: Routing, policy: Policy) -> MetaScheduler {
        let mut clusters = vec![ClusterSpec {
            name: "vu-head".into(),
            nodes: 72,
            cores_per_node: 2,
        }];
        for site in ["leiden", "uva", "delft", "utrecht"] {
            clusters.push(ClusterSpec { name: site.into(), nodes: 32, cores_per_node: 2 });
        }
        MetaScheduler::new(clusters, routing, policy)
    }

    /// Route every job to a cluster index; `None` = rejected (fits no
    /// cluster).
    pub fn route(&self, jobs: &[Job]) -> Vec<Option<usize>> {
        let mut state = RouterState::new(&self.clusters, self.routing);
        jobs.iter().map(|j| state.route_one(j)).collect()
    }

    /// Run the full federation on `jobs`, on the sharded engine with
    /// one shard (serial execution, identical decisions to any other
    /// shard count).
    pub fn run(&self, jobs: &[Job]) -> MultiClusterReport {
        run_sharded(
            &ShardOpts {
                clusters: self.clusters.clone(),
                routing: self.routing,
                policy: self.policy,
                shards: 1,
                route_latency: 1,
                sim: RankSimOpts::default(),
            },
            jobs.to_vec(),
            false,
        )
        .into_multicluster()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::Das2Model;

    fn federation(routing: Routing) -> MetaScheduler {
        MetaScheduler::das2_federation(routing, Policy::FcfsBackfill)
    }

    fn jobs(n: usize, seed: u64) -> Vec<Job> {
        Das2Model::default().generate(n, seed).scale_arrivals(0.3).jobs
    }

    #[test]
    fn all_jobs_routed_or_rejected() {
        let m = federation(Routing::LeastLoaded);
        let js = jobs(2_000, 1);
        let routes = m.route(&js);
        for (j, r) in js.iter().zip(&routes) {
            match r {
                Some(i) => assert!(j.cores <= m.clusters[*i].total_cores()),
                None => assert!(j.cores > 144), // fits nowhere
            }
        }
    }

    #[test]
    fn best_fit_cluster_prefers_small_machines() {
        let m = federation(Routing::BestFitCluster);
        let mut j = Job::simple(1, 0, 16, 100);
        j.est_runtime = crate::core::time::SimDuration(100);
        let routes = m.route(&[j]);
        // 16 cores fits the 64-core site clusters: picks one of them,
        // never the 144-core head.
        assert_ne!(routes[0], Some(0));
    }

    #[test]
    fn big_jobs_only_fit_the_head_cluster() {
        let m = federation(Routing::BestFitCluster);
        let j = Job::simple(1, 0, 100, 100);
        assert_eq!(m.route(&[j]), vec![Some(0)]);
    }

    #[test]
    fn round_robin_spreads() {
        let m = federation(Routing::RoundRobin);
        let js: Vec<Job> = (0..100).map(|i| Job::simple(i, i, 2, 60)).collect();
        let routes = m.route(&js);
        let mut counts = vec![0usize; 5];
        for r in routes.into_iter().flatten() {
            counts[r] += 1;
        }
        assert!(counts.iter().all(|&c| c == 20), "{counts:?}");
    }

    #[test]
    fn round_robin_mixed_sizes_feeds_every_fitting_cluster() {
        // Regression for the rotation bias: with one shared counter,
        // alternating big (head-only) and small (fits-all) jobs made
        // the small-job picks stride 2 mod 4 over the site clusters —
        // half of them never received work. Per-fit-set cursors keep
        // each rotation dense.
        let head = ClusterSpec { name: "head".into(), nodes: 128, cores_per_node: 2 };
        let mut clusters = vec![head];
        for s in ["s1", "s2", "s3"] {
            clusters.push(ClusterSpec { name: s.into(), nodes: 32, cores_per_node: 2 });
        }
        let m = MetaScheduler::new(clusters, Routing::RoundRobin, Policy::Fcfs);
        let js: Vec<Job> = (0..80)
            .map(|i| Job::simple(i, i, if i % 2 == 0 { 128 } else { 2 }, 60))
            .collect();
        let routes = m.route(&js);
        let mut big = vec![0usize; 4];
        let mut small = vec![0usize; 4];
        for (j, r) in js.iter().zip(routes) {
            let i = r.expect("everything fits somewhere");
            if j.cores == 128 {
                big[i] += 1;
            } else {
                small[i] += 1;
            }
        }
        // 40 big jobs rotate over the one-element fit set {head}; 40
        // small jobs rotate densely over all four clusters.
        assert_eq!(big, vec![40, 0, 0, 0], "big jobs only fit the head");
        assert_eq!(small, vec![10, 10, 10, 10], "small rotation must be dense");
    }

    #[test]
    fn least_loaded_decays_past_completions() {
        // Regression: the old implementation charged load forever, so
        // a single early job biased routing for the rest of the trace.
        // With est-based decay, a burst arriving long after the early
        // job's estimated completion sees two empty clusters and
        // alternates between them.
        let clusters = vec![
            ClusterSpec { name: "a".into(), nodes: 32, cores_per_node: 2 },
            ClusterSpec { name: "b".into(), nodes: 32, cores_per_node: 2 },
        ];
        let m = MetaScheduler::new(clusters, Routing::LeastLoaded, Policy::Fcfs);
        let mut js = vec![Job::simple(0, 0, 64, 1_000)];
        for i in 0..10u64 {
            js.push(Job::simple(1 + i, 50_000 + i, 16, 100));
        }
        let routes = m.route(&js);
        assert_eq!(routes[0], Some(0), "empty tie goes to the lowest index");
        let mut late = vec![0usize; 2];
        for r in routes[1..].iter().flatten() {
            late[*r] += 1;
        }
        // Old behavior: the stale 64_000 core-second charge on cluster
        // 0 pushed all ten late jobs onto cluster 1 ([0, 10]). Decayed:
        // the early job expired at t=1_000, both clusters are empty at
        // t=50_000, and the burst alternates.
        assert_eq!(late, vec![5, 5], "late burst must balance after decay");
    }

    #[test]
    fn federated_run_completes_everything_feasible() {
        for routing in [Routing::RoundRobin, Routing::LeastLoaded, Routing::BestFitCluster] {
            let m = federation(routing);
            let js = jobs(3_000, 2);
            let rep = m.run(&js);
            assert_eq!(rep.all_jobs.len() as u64 + rep.rejected, 3_000, "{routing:?}");
            assert_eq!(rep.per_cluster.len(), 5);
        }
    }

    #[test]
    fn least_loaded_beats_round_robin_on_wait() {
        // State-aware routing should not be (much) worse than blind
        // routing — typically better under load skew.
        let js = jobs(6_000, 3);
        let ll = federation(Routing::LeastLoaded).run(&js).wait_stats().mean_wait;
        let rr = federation(Routing::RoundRobin).run(&js).wait_stats().mean_wait;
        assert!(ll <= rr * 1.1, "least-loaded {ll} much worse than round-robin {rr}");
    }

    #[test]
    fn deterministic() {
        let js = jobs(1_000, 4);
        let a = federation(Routing::LeastLoaded).run(&js);
        let b = federation(Routing::LeastLoaded).run(&js);
        assert_eq!(a.wait_stats(), b.wait_stats());
        assert_eq!(a.end_time, b.end_time);
        assert_eq!(a.fingerprint, b.fingerprint);
    }
}
