//! Multi-cluster operation (paper §5 future work: "heterogeneous job and
//! multi-cluster operation"): a meta-scheduler routes arriving jobs to
//! one of several autonomous clusters, each running its own scheduler —
//! the way DAS-2 itself was operated (five clusters, per-cluster queues).

use crate::core::time::SimTime;
use crate::job::Job;
use crate::metrics::{wait_stats, WaitStats};
use crate::sched::Policy;
use crate::sim::run_policy;
use crate::trace::Workload;

/// Routing policy of the meta-scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Routing {
    /// Cycle through clusters (ignores state).
    RoundRobin,
    /// Send to the cluster with the least outstanding core-seconds.
    LeastLoaded,
    /// Send to the *smallest* cluster that can ever fit the job
    /// (best-fit at cluster granularity; keeps big machines free for
    /// big jobs).
    BestFitCluster,
}

impl std::str::FromStr for Routing {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "round-robin" | "rr" => Ok(Routing::RoundRobin),
            "least-loaded" | "ll" => Ok(Routing::LeastLoaded),
            "best-fit-cluster" | "bf" => Ok(Routing::BestFitCluster),
            other => Err(format!("unknown routing {other:?}")),
        }
    }
}

/// A cluster description within the federation.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub name: String,
    pub nodes: usize,
    pub cores_per_node: u64,
}

impl ClusterSpec {
    pub fn total_cores(&self) -> u64 {
        self.nodes as u64 * self.cores_per_node
    }
}

/// Result of a federated run.
#[derive(Debug, Clone)]
pub struct MultiClusterReport {
    pub routing: Routing,
    pub per_cluster: Vec<(String, WaitStats, f64)>, // (name, waits, utilization)
    pub all_jobs: Vec<Job>,
    pub rejected: u64,
    pub end_time: SimTime,
}

impl MultiClusterReport {
    pub fn wait_stats(&self) -> WaitStats {
        wait_stats(&self.all_jobs)
    }
}

/// The meta-scheduler: route then simulate each cluster independently
/// (clusters are autonomous; no job migration — as on the real DAS-2).
pub struct MetaScheduler {
    pub clusters: Vec<ClusterSpec>,
    pub routing: Routing,
    pub policy: Policy,
}

impl MetaScheduler {
    pub fn new(clusters: Vec<ClusterSpec>, routing: Routing, policy: Policy) -> MetaScheduler {
        assert!(!clusters.is_empty());
        MetaScheduler { clusters, routing, policy }
    }

    /// DAS-2's actual federation: one 72-node head cluster + four
    /// 32-node clusters, dual-CPU nodes.
    pub fn das2_federation(routing: Routing, policy: Policy) -> MetaScheduler {
        let mut clusters = vec![ClusterSpec {
            name: "vu-head".into(),
            nodes: 72,
            cores_per_node: 2,
        }];
        for site in ["leiden", "uva", "delft", "utrecht"] {
            clusters.push(ClusterSpec { name: site.into(), nodes: 32, cores_per_node: 2 });
        }
        MetaScheduler::new(clusters, routing, policy)
    }

    /// Route every job to a cluster index; `None` = rejected (fits no
    /// cluster).
    pub fn route(&self, jobs: &[Job]) -> Vec<Option<usize>> {
        let caps: Vec<u64> = self.clusters.iter().map(|c| c.total_cores()).collect();
        let mut rr = 0usize;
        // Outstanding load per cluster in core-seconds (est based — the
        // meta-scheduler cannot see actual runtimes).
        let mut load = vec![0f64; self.clusters.len()];
        jobs.iter()
            .map(|j| {
                let fits: Vec<usize> =
                    (0..caps.len()).filter(|&i| j.cores <= caps[i]).collect();
                if fits.is_empty() {
                    return None;
                }
                let pick = match self.routing {
                    Routing::RoundRobin => {
                        // Next fitting cluster in cyclic order.
                        let p = fits[rr % fits.len()];
                        rr += 1;
                        p
                    }
                    Routing::LeastLoaded => fits
                        .iter()
                        .copied()
                        .min_by(|&a, &b| {
                            (load[a] / caps[a] as f64)
                                .partial_cmp(&(load[b] / caps[b] as f64))
                                .unwrap()
                                .then(a.cmp(&b))
                        })
                        .unwrap(),
                    Routing::BestFitCluster => fits
                        .iter()
                        .copied()
                        .min_by_key(|&i| (caps[i], i))
                        .unwrap(),
                };
                load[pick] += j.cores as f64 * j.est_runtime.as_f64();
                Some(pick)
            })
            .collect()
    }

    /// Run the full federation on `jobs`.
    pub fn run(&self, jobs: &[Job]) -> MultiClusterReport {
        let routes = self.route(jobs);
        let mut buckets: Vec<Vec<Job>> = vec![Vec::new(); self.clusters.len()];
        let mut rejected = 0u64;
        for (j, r) in jobs.iter().zip(&routes) {
            match r {
                Some(i) => buckets[*i].push(j.clone()),
                None => rejected += 1,
            }
        }
        let mut per_cluster = Vec::new();
        let mut all_jobs = Vec::new();
        let mut end = SimTime::ZERO;
        for (spec, bucket) in self.clusters.iter().zip(buckets) {
            let w = Workload::new(&spec.name, bucket, spec.nodes, spec.cores_per_node);
            let rep = run_policy(w, self.policy);
            per_cluster.push((
                spec.name.clone(),
                wait_stats(&rep.completed),
                rep.mean_utilization,
            ));
            end = end.max(rep.end_time);
            all_jobs.extend(rep.completed);
        }
        MultiClusterReport { routing: self.routing, per_cluster, all_jobs, rejected, end_time: end }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::Das2Model;

    fn federation(routing: Routing) -> MetaScheduler {
        MetaScheduler::das2_federation(routing, Policy::FcfsBackfill)
    }

    fn jobs(n: usize, seed: u64) -> Vec<Job> {
        Das2Model::default().generate(n, seed).scale_arrivals(0.3).jobs
    }

    #[test]
    fn all_jobs_routed_or_rejected() {
        let m = federation(Routing::LeastLoaded);
        let js = jobs(2_000, 1);
        let routes = m.route(&js);
        for (j, r) in js.iter().zip(&routes) {
            match r {
                Some(i) => assert!(j.cores <= m.clusters[*i].total_cores()),
                None => assert!(j.cores > 144), // fits nowhere
            }
        }
    }

    #[test]
    fn best_fit_cluster_prefers_small_machines() {
        let m = federation(Routing::BestFitCluster);
        let mut j = Job::simple(1, 0, 16, 100);
        j.est_runtime = crate::core::time::SimDuration(100);
        let routes = m.route(&[j]);
        // 16 cores fits the 64-core site clusters: picks one of them,
        // never the 144-core head.
        assert_ne!(routes[0], Some(0));
    }

    #[test]
    fn big_jobs_only_fit_the_head_cluster() {
        let m = federation(Routing::BestFitCluster);
        let j = Job::simple(1, 0, 100, 100);
        assert_eq!(m.route(&[j]), vec![Some(0)]);
    }

    #[test]
    fn round_robin_spreads() {
        let m = federation(Routing::RoundRobin);
        let js: Vec<Job> = (0..100).map(|i| Job::simple(i, i, 2, 60)).collect();
        let routes = m.route(&js);
        let mut counts = vec![0usize; 5];
        for r in routes.into_iter().flatten() {
            counts[r] += 1;
        }
        assert!(counts.iter().all(|&c| c == 20), "{counts:?}");
    }

    #[test]
    fn federated_run_completes_everything_feasible() {
        for routing in [Routing::RoundRobin, Routing::LeastLoaded, Routing::BestFitCluster] {
            let m = federation(routing);
            let js = jobs(3_000, 2);
            let rep = m.run(&js);
            assert_eq!(rep.all_jobs.len() as u64 + rep.rejected, 3_000, "{routing:?}");
            assert_eq!(rep.per_cluster.len(), 5);
        }
    }

    #[test]
    fn least_loaded_beats_round_robin_on_wait() {
        // State-aware routing should not be (much) worse than blind
        // routing — typically better under load skew.
        let js = jobs(6_000, 3);
        let ll = federation(Routing::LeastLoaded).run(&js).wait_stats().mean_wait;
        let rr = federation(Routing::RoundRobin).run(&js).wait_stats().mean_wait;
        assert!(ll <= rr * 1.1, "least-loaded {ll} much worse than round-robin {rr}");
    }

    #[test]
    fn deterministic() {
        let js = jobs(1_000, 4);
        let a = federation(Routing::LeastLoaded).run(&js);
        let b = federation(Routing::LeastLoaded).run(&js);
        assert_eq!(a.wait_stats(), b.wait_stats());
        assert_eq!(a.end_time, b.end_time);
    }
}
