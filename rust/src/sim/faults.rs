//! Fault injection and advance reservations (fault/preemption subsystem).
//!
//! [`FaultInjector`] is a first-class simulation component wired next to
//! the job source: it turns a seeded exponential MTBF/MTTR model and a
//! list of [`ReservationSpec`]s into timed engine events for the
//! scheduler. The injector owns a *private* RNG stream seeded from
//! [`FaultConfig::seed`], so the failure trace — failure instants, victim
//! draws, repair durations — is identical across scheduling policies and
//! preemption modes. That is what makes "policy A vs policy B under the
//! same failure trace" comparisons (examples/fault_tolerance.rs)
//! meaningful, and it keeps seeded runs bit-reproducible across runs and
//! rank counts (rust/tests/integration.rs, rust/tests/prop_faults.rs).
//!
//! The injector generates *timing*; the scheduler component owns all
//! state transitions (which node goes down, which jobs die, when the
//! node returns) so capacity bookkeeping lives in exactly one place.

use crate::core::component::{Component, Ctx};
use crate::core::event::{ComponentId, Priority};
use crate::core::rng::Rng;
use crate::core::time::{SimDuration, SimTime};
use crate::sim::Ev;
use std::any::Any;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Inter-failure gap distribution (`faults.distribution`).
///
/// `Exp` is the classic memoryless MTBF model and the bit-identical
/// default. `Weibull` adds a shape knob: HPC failure studies (Schroeder
/// & Gibson 2006) fit Weibull shapes of ~0.7–0.8 — a decreasing hazard
/// where failures cluster after each failure — while shape > 1 models
/// wear-out. Shape 1 reduces to the exponential. Repairs stay
/// exponential under either model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FaultDistribution {
    #[default]
    Exp,
    Weibull,
}

impl FaultDistribution {
    pub fn as_str(self) -> &'static str {
        match self {
            FaultDistribution::Exp => "exp",
            FaultDistribution::Weibull => "weibull",
        }
    }
}

impl std::str::FromStr for FaultDistribution {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "exp" | "exponential" => Ok(FaultDistribution::Exp),
            "weibull" => Ok(FaultDistribution::Weibull),
            other => Err(format!(
                "unknown failure distribution {other:?} (expected exp|weibull)"
            )),
        }
    }
}

impl std::fmt::Display for FaultDistribution {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Failure-model knobs (config surface `faults.*`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultConfig {
    /// Mean time between cluster-wide failure events, in ticks.
    /// 0 disables fault injection.
    pub mtbf: f64,
    /// Mean time to repair a failed node, in ticks (exponential).
    pub mttr: f64,
    /// Seed of the injector's private RNG stream.
    pub seed: u64,
    /// Stop injecting new failures after this tick; `None` lets the
    /// simulation builder derive a horizon from the workload (last
    /// submission plus a few repair times), which keeps the event queue
    /// finite — failures chain repair and next-failure events forever
    /// otherwise.
    pub until: Option<u64>,
    /// Inter-failure gap distribution; `Exp` keeps the seeded trace
    /// bit-identical to the pre-Weibull model.
    pub distribution: FaultDistribution,
    /// Weibull shape k (`faults.shape`); the scale is derived so the
    /// mean gap stays `mtbf` (scale = mtbf / Γ(1 + 1/k)). Ignored by
    /// `Exp`.
    pub shape: f64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            mtbf: 0.0,
            mttr: 3_600.0,
            seed: 0xFA017,
            until: None,
            distribution: FaultDistribution::Exp,
            shape: 1.0,
        }
    }
}

impl FaultConfig {
    pub fn enabled(&self) -> bool {
        self.mtbf > 0.0
    }
}

/// Γ(x) for x > 0 (Lanczos approximation, g = 7): scales the Weibull so
/// its mean equals the configured MTBF.
fn gamma_fn(x: f64) -> f64 {
    use std::f64::consts::PI;
    const G: f64 = 7.0;
    const C: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // Reflection; shapes >= ~0.67 never reach this branch.
        PI / ((PI * x).sin() * gamma_fn(1.0 - x))
    } else {
        let x = x - 1.0;
        let mut a = C[0];
        for (i, &c) in C.iter().enumerate().skip(1) {
            a += c / (x + i as f64);
        }
        let t = x + G + 0.5;
        (2.0 * PI).sqrt() * t.powf(x + 0.5) * (-t).exp() * a
    }
}

/// One advance reservation: `nodes` whole nodes held from `start` for
/// `duration` ticks (config surface `reservations[]`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReservationSpec {
    pub start: u64,
    pub duration: u64,
    pub nodes: usize,
}

/// The fault-injection component.
pub struct FaultInjector {
    /// Where capacity events go (the scheduler). Set by the builder.
    pub scheduler: ComponentId,
    cfg: FaultConfig,
    until: SimTime,
    rng: Rng,
    reservations: Vec<ReservationSpec>,
    /// Streamed-run horizon watermark: the stream's last-seen submit
    /// (advanced by the job source as it pulls records). When set, the
    /// injection horizon is `max(watermark, last engine activity) +
    /// 4 x mttr`, re-read at each failure instant — the fixed `until`
    /// is ignored. Updates happen inside the single-threaded event
    /// loop, so reads are deterministic.
    stream_watermark: Option<Arc<AtomicU64>>,
    /// Last time the scheduler had queued or running work (advanced by
    /// the scheduler component on every event it handles with a
    /// non-idle machine). Folded into the dynamic horizon so an
    /// arrival drought longer than `4 x mttr` mid-trace — or a backlog
    /// still draining after the stream ends — keeps the fault chain
    /// alive while the engine has work, instead of ending injection
    /// early (the pre-fix behavior, carried in ROADMAP since PR 5).
    activity_mark: Option<Arc<AtomicU64>>,
    /// Drawn instant of the next failure (dynamic mode only): wake-ups
    /// may fire *before* it when the derived horizon clamps the sleep —
    /// see [`FaultInjector::schedule_dynamic_wake`]. `None` = chain
    /// ended.
    next_fault_due: Option<SimTime>,
    /// Failure events injected (for reporting).
    pub injected: u64,
}

impl FaultInjector {
    pub fn new(
        cfg: FaultConfig,
        until: SimTime,
        reservations: Vec<ReservationSpec>,
    ) -> FaultInjector {
        let rng = Rng::new(cfg.seed);
        FaultInjector {
            scheduler: 0,
            cfg,
            until,
            rng,
            reservations,
            stream_watermark: None,
            activity_mark: None,
            next_fault_due: None,
            injected: 0,
        }
    }

    /// Derive the injection horizon from a stream watermark instead of
    /// the fixed `until` (see the field docs; used by the simulation
    /// builder for streamed runs without `faults.until`).
    pub fn with_stream_watermark(mut self, watermark: Arc<AtomicU64>) -> FaultInjector {
        self.stream_watermark = Some(watermark);
        self
    }

    /// Also fold the scheduler's last-activity time into the dynamic
    /// horizon (see the `activity_mark` field docs); only meaningful
    /// together with [`FaultInjector::with_stream_watermark`].
    pub fn with_activity_watermark(mut self, activity: Arc<AtomicU64>) -> FaultInjector {
        self.activity_mark = Some(activity);
        self
    }

    /// The injection horizon as of now: fixed, or derived from
    /// `max(stream's last-seen submission, scheduler's last activity)`
    /// plus the same `4 x mttr` slack the eager path derives from the
    /// full job list. The activity term keeps failures flowing while a
    /// backlog drains through an arrival drought.
    fn horizon_now(&self) -> SimTime {
        match &self.stream_watermark {
            None => self.until,
            Some(w) => {
                let mut base = w.load(Ordering::Relaxed);
                if let Some(a) = &self.activity_mark {
                    base = base.max(a.load(Ordering::Relaxed));
                }
                SimTime(base) + SimDuration::from_f64(4.0 * self.cfg.mttr)
            }
        }
    }

    /// Exponential draw in whole ticks, at least 1 (repairs, and the
    /// `exp` failure model).
    fn draw(&mut self, mean: f64) -> SimDuration {
        let d = SimDuration::from_f64(self.rng.exponential(1.0 / mean.max(1e-9)));
        if d == SimDuration::ZERO {
            SimDuration(1)
        } else {
            d
        }
    }

    /// Inter-failure gap under the configured distribution, at least 1
    /// tick. Both arms consume exactly one uniform draw, so switching
    /// distributions never desynchronizes the victim/repair stream.
    fn draw_gap(&mut self) -> SimDuration {
        match self.cfg.distribution {
            FaultDistribution::Exp => self.draw(self.cfg.mtbf),
            FaultDistribution::Weibull => {
                // Config/CLI enforce shape >= 0.1; this floor only
                // guards programmatic construction from a scale collapse.
                let k = self.cfg.shape.max(0.1);
                let scale = self.cfg.mtbf.max(1e-9) / gamma_fn(1.0 + 1.0 / k);
                let d = SimDuration::from_f64(self.rng.weibull(k, scale));
                if d == SimDuration::ZERO {
                    SimDuration(1)
                } else {
                    d
                }
            }
        }
    }

    fn schedule_next_failure(&mut self, ctx: &mut Ctx<Ev>) {
        if !self.cfg.enabled() {
            return;
        }
        let gap = self.draw_gap();
        if self.stream_watermark.is_some() {
            // Dynamic (streamed) horizon: the bound grows as the stream
            // is ingested, so the drawn instant cannot be judged at
            // schedule time. Record it and sleep toward it in
            // horizon-clamped steps.
            let due = ctx.now() + gap;
            self.next_fault_due = Some(due);
            self.schedule_dynamic_wake(ctx, due);
            return;
        }
        if ctx.now() + gap > self.until {
            return; // injection horizon reached; let the queue drain
        }
        ctx.schedule_self(gap, Priority::COMPLETE, Ev::NextFault);
    }

    /// Dynamic-mode sleep toward `due`, clamped to just past the
    /// current derived bound: if the stream moves on meanwhile, the
    /// wake-up re-derives and resumes toward `due`; if not, the chain
    /// ends having overshot the last activity by at most one tick past
    /// `watermark + 4 x mttr` (the eager law's endpoint) — never by a
    /// full unbounded exponential gap, which would drag `end_time` (and
    /// the streaming utilization means it denominates) past the run.
    /// Failure *instants* are unaffected: injection only ever happens
    /// at exactly `due`, and the stop decision matches the unclamped
    /// fire-time check (a stagnant horizon means the stream is
    /// exhausted *and* the machine has drained — the one-job lookahead
    /// keeps the watermark ahead of the clock while arrivals remain,
    /// and the activity term keeps the horizon moving while work does).
    fn schedule_dynamic_wake(&mut self, ctx: &mut Ctx<Ev>, due: SimTime) {
        let now = ctx.now();
        let bound = self.horizon_now();
        if now > bound {
            self.next_fault_due = None; // past the derived horizon: stop
            return;
        }
        let wake = due.min(SimTime(bound.ticks().saturating_add(1)));
        // `wake > now`: `due = now + gap` with gap >= 1, and
        // `bound + 1 > now` since `now <= bound`.
        ctx.schedule_self(wake - now, Priority::COMPLETE, Ev::NextFault);
    }
}

impl Component<Ev> for FaultInjector {
    fn name(&self) -> &str {
        "faults"
    }

    fn init(&mut self, ctx: &mut Ctx<Ev>) {
        // Reservations are part of the experiment definition: emit their
        // start/end transitions up front (they are few and fixed).
        for (idx, r) in self.reservations.iter().enumerate() {
            ctx.send_after(
                self.scheduler,
                SimDuration(r.start),
                Priority::COMPLETE,
                Ev::ReserveStart { res: idx },
            );
            ctx.send_after(
                self.scheduler,
                SimDuration(r.start.saturating_add(r.duration)),
                Priority::COMPLETE,
                Ev::ReserveEnd { res: idx },
            );
        }
        self.schedule_next_failure(ctx);
    }

    fn handle(&mut self, ev: Ev, ctx: &mut Ctx<Ev>) {
        match ev {
            Ev::NextFault => {
                if self.stream_watermark.is_some() {
                    let Some(due) = self.next_fault_due else {
                        return; // chain already ended
                    };
                    if ctx.now() < due {
                        // Horizon-clamped wake-up, not the drawn
                        // instant: re-derive and resume or stop.
                        self.schedule_dynamic_wake(ctx, due);
                        return;
                    }
                    if ctx.now() > self.horizon_now() {
                        // The drawn instant lies past the derived
                        // horizon: arrivals are more than 4 x mttr
                        // behind — stop the chain, let the queue drain.
                        self.next_fault_due = None;
                        return;
                    }
                    self.next_fault_due = None;
                }
                self.injected += 1;
                // The victim draw rides along so the scheduler (which
                // knows the current node states) can pick deterministically
                // without consuming shared engine randomness.
                let victim_draw = self.rng.next_u64();
                let repair_after = self.draw(self.cfg.mttr);
                ctx.send(
                    self.scheduler,
                    Priority::COMPLETE,
                    Ev::NodeFail { victim_draw, repair_after },
                );
                self.schedule_next_failure(ctx);
            }
            other => panic!("fault injector got unexpected event {other:?}"),
        }
    }

    /// Deep copy, including the injector's private RNG mid-stream.
    /// Watermark-driven injectors (`Arc`s shared with the job stream
    /// and scheduler) are not snapshotable — those only exist on
    /// streamed runs, which the job source refuses to snapshot anyway.
    fn snapshot_box(&self) -> Option<Box<dyn Component<Ev>>> {
        if self.stream_watermark.is_some() || self.activity_mark.is_some() {
            return None;
        }
        Some(Box::new(FaultInjector {
            scheduler: self.scheduler,
            cfg: self.cfg,
            until: self.until,
            rng: self.rng.clone(),
            reservations: self.reservations.clone(),
            stream_watermark: None,
            activity_mark: None,
            next_fault_due: self.next_fault_due,
            injected: self.injected,
        }))
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_config_injects_nothing() {
        let cfg = FaultConfig::default();
        assert!(!cfg.enabled());
        let mut engine: crate::core::engine::Engine<Ev> = crate::core::engine::Engine::new(1);
        let id = engine.add(Box::new(FaultInjector::new(cfg, SimTime(10_000), Vec::new())));
        let r = engine.run(None);
        assert_eq!(r.events, 0);
        assert_eq!(engine.get::<FaultInjector>(id).unwrap().injected, 0);
    }

    #[test]
    fn failure_trace_is_seed_deterministic() {
        let trace = |seed: u64| {
            let mut inj = FaultInjector::new(
                FaultConfig { mtbf: 500.0, mttr: 100.0, seed, ..FaultConfig::default() },
                SimTime(1_000_000),
                Vec::new(),
            );
            let gaps: Vec<u64> = (0..16).map(|_| inj.draw(500.0).ticks()).collect();
            gaps
        };
        assert_eq!(trace(7), trace(7));
        assert_ne!(trace(7), trace(8));
    }

    #[test]
    fn draws_are_positive_and_mean_scaled() {
        let mut inj = FaultInjector::new(
            FaultConfig { mtbf: 1000.0, mttr: 50.0, seed: 3, ..FaultConfig::default() },
            SimTime::MAX,
            Vec::new(),
        );
        let n = 4000;
        let sum: u64 = (0..n).map(|_| inj.draw(1000.0).ticks()).sum();
        let mean = sum as f64 / n as f64;
        assert!((700.0..1300.0).contains(&mean), "mean {mean}");
        assert!((0..200).all(|_| inj.draw(0.5).ticks() >= 1), "draws must be >= 1 tick");
    }

    #[test]
    fn distribution_parses_and_roundtrips() {
        for d in [FaultDistribution::Exp, FaultDistribution::Weibull] {
            assert_eq!(d.as_str().parse::<FaultDistribution>().unwrap(), d);
        }
        assert_eq!(
            "exponential".parse::<FaultDistribution>().unwrap(),
            FaultDistribution::Exp
        );
        assert!("pareto".parse::<FaultDistribution>().is_err());
    }

    #[test]
    fn exp_path_is_bit_identical_with_distribution_field_defaulted() {
        // The Weibull option must not perturb existing exponential
        // seeds: draw_gap under `Exp` consumes the same stream as the
        // pre-Weibull draw().
        let gaps = |cfg: FaultConfig| {
            let mut inj = FaultInjector::new(cfg, SimTime::MAX, Vec::new());
            (0..64).map(|_| inj.draw_gap().ticks()).collect::<Vec<u64>>()
        };
        let base = FaultConfig { mtbf: 700.0, mttr: 100.0, seed: 9, ..FaultConfig::default() };
        let via_draw = {
            let mut inj = FaultInjector::new(base, SimTime::MAX, Vec::new());
            (0..64).map(|_| inj.draw(700.0).ticks()).collect::<Vec<u64>>()
        };
        assert_eq!(gaps(base), via_draw, "exp gap stream changed");
        // And an explicit shape knob on the exp path changes nothing.
        assert_eq!(gaps(FaultConfig { shape: 3.0, ..base }), via_draw);
    }

    #[test]
    fn weibull_gaps_mean_matches_mtbf() {
        let mut inj = FaultInjector::new(
            FaultConfig {
                mtbf: 1000.0,
                mttr: 50.0,
                seed: 11,
                distribution: FaultDistribution::Weibull,
                shape: 0.7,
                ..FaultConfig::default()
            },
            SimTime::MAX,
            Vec::new(),
        );
        let n = 6000;
        let sum: u64 = (0..n).map(|_| inj.draw_gap().ticks()).sum();
        let mean = sum as f64 / n as f64;
        // Shape 0.7 is heavy-tailed; allow a generous band around the
        // configured mean.
        assert!((600.0..1500.0).contains(&mean), "weibull mean {mean}");
        assert!((0..200).all(|_| inj.draw_gap().ticks() >= 1));
    }

    #[test]
    fn weibull_shape_one_approximates_exponential() {
        // k = 1 reduces the Weibull to the exponential with the same
        // mean (scale = mtbf / Γ(2) = mtbf); sample means must agree.
        let mean_of = |distribution, shape| {
            let mut inj = FaultInjector::new(
                FaultConfig {
                    mtbf: 800.0,
                    mttr: 50.0,
                    seed: 5,
                    distribution,
                    shape,
                    ..FaultConfig::default()
                },
                SimTime::MAX,
                Vec::new(),
            );
            (0..6000).map(|_| inj.draw_gap().ticks()).sum::<u64>() as f64 / 6000.0
        };
        let e = mean_of(FaultDistribution::Exp, 1.0);
        let w = mean_of(FaultDistribution::Weibull, 1.0);
        assert!((e - w).abs() < 60.0, "exp {e} vs weibull(k=1) {w}");
    }

    #[test]
    fn gamma_fn_known_values() {
        for (x, want) in [(1.0, 1.0), (2.0, 1.0), (3.0, 2.0), (4.0, 6.0), (0.5, 1.7724538509055159)] {
            let got = gamma_fn(x);
            assert!((got - want).abs() < 1e-9 * want.max(1.0), "Γ({x}) = {got}, want {want}");
        }
        // Γ(1 + 1/0.7) ≈ Γ(2.42857) ≈ 1.26607.
        let g = gamma_fn(1.0 + 1.0 / 0.7);
        assert!((g - 1.266).abs() < 0.01, "Γ(2.4286) = {g}");
    }
}
