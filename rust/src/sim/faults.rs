//! Fault injection and advance reservations (fault/preemption subsystem).
//!
//! [`FaultInjector`] is a first-class simulation component wired next to
//! the job source: it turns a seeded exponential MTBF/MTTR model and a
//! list of [`ReservationSpec`]s into timed engine events for the
//! scheduler. The injector owns a *private* RNG stream seeded from
//! [`FaultConfig::seed`], so the failure trace — failure instants, victim
//! draws, repair durations — is identical across scheduling policies and
//! preemption modes. That is what makes "policy A vs policy B under the
//! same failure trace" comparisons (examples/fault_tolerance.rs)
//! meaningful, and it keeps seeded runs bit-reproducible across runs and
//! rank counts (rust/tests/integration.rs, rust/tests/prop_faults.rs).
//!
//! The injector generates *timing*; the scheduler component owns all
//! state transitions (which node goes down, which jobs die, when the
//! node returns) so capacity bookkeeping lives in exactly one place.

use crate::core::component::{Component, Ctx};
use crate::core::event::{ComponentId, Priority};
use crate::core::rng::Rng;
use crate::core::time::{SimDuration, SimTime};
use crate::sim::Ev;
use std::any::Any;

/// Failure-model knobs (config surface `faults.*`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultConfig {
    /// Mean time between cluster-wide failure events, in ticks
    /// (exponential inter-failure gaps). 0 disables fault injection.
    pub mtbf: f64,
    /// Mean time to repair a failed node, in ticks (exponential).
    pub mttr: f64,
    /// Seed of the injector's private RNG stream.
    pub seed: u64,
    /// Stop injecting new failures after this tick; `None` lets the
    /// simulation builder derive a horizon from the workload (last
    /// submission plus a few repair times), which keeps the event queue
    /// finite — failures chain repair and next-failure events forever
    /// otherwise.
    pub until: Option<u64>,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig { mtbf: 0.0, mttr: 3_600.0, seed: 0xFA017, until: None }
    }
}

impl FaultConfig {
    pub fn enabled(&self) -> bool {
        self.mtbf > 0.0
    }
}

/// One advance reservation: `nodes` whole nodes held from `start` for
/// `duration` ticks (config surface `reservations[]`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReservationSpec {
    pub start: u64,
    pub duration: u64,
    pub nodes: usize,
}

/// The fault-injection component.
pub struct FaultInjector {
    /// Where capacity events go (the scheduler). Set by the builder.
    pub scheduler: ComponentId,
    cfg: FaultConfig,
    until: SimTime,
    rng: Rng,
    reservations: Vec<ReservationSpec>,
    /// Failure events injected (for reporting).
    pub injected: u64,
}

impl FaultInjector {
    pub fn new(
        cfg: FaultConfig,
        until: SimTime,
        reservations: Vec<ReservationSpec>,
    ) -> FaultInjector {
        let rng = Rng::new(cfg.seed);
        FaultInjector { scheduler: 0, cfg, until, rng, reservations, injected: 0 }
    }

    /// Exponential draw in whole ticks, at least 1.
    fn draw(&mut self, mean: f64) -> SimDuration {
        let d = SimDuration::from_f64(self.rng.exponential(1.0 / mean.max(1e-9)));
        if d == SimDuration::ZERO {
            SimDuration(1)
        } else {
            d
        }
    }

    fn schedule_next_failure(&mut self, ctx: &mut Ctx<Ev>) {
        if !self.cfg.enabled() {
            return;
        }
        let gap = self.draw(self.cfg.mtbf);
        if ctx.now() + gap > self.until {
            return; // injection horizon reached; let the queue drain
        }
        ctx.schedule_self(gap, Priority::COMPLETE, Ev::NextFault);
    }
}

impl Component<Ev> for FaultInjector {
    fn name(&self) -> &str {
        "faults"
    }

    fn init(&mut self, ctx: &mut Ctx<Ev>) {
        // Reservations are part of the experiment definition: emit their
        // start/end transitions up front (they are few and fixed).
        for (idx, r) in self.reservations.iter().enumerate() {
            ctx.send_after(
                self.scheduler,
                SimDuration(r.start),
                Priority::COMPLETE,
                Ev::ReserveStart { res: idx },
            );
            ctx.send_after(
                self.scheduler,
                SimDuration(r.start.saturating_add(r.duration)),
                Priority::COMPLETE,
                Ev::ReserveEnd { res: idx },
            );
        }
        self.schedule_next_failure(ctx);
    }

    fn handle(&mut self, ev: Ev, ctx: &mut Ctx<Ev>) {
        match ev {
            Ev::NextFault => {
                self.injected += 1;
                // The victim draw rides along so the scheduler (which
                // knows the current node states) can pick deterministically
                // without consuming shared engine randomness.
                let victim_draw = self.rng.next_u64();
                let repair_after = self.draw(self.cfg.mttr);
                ctx.send(
                    self.scheduler,
                    Priority::COMPLETE,
                    Ev::NodeFail { victim_draw, repair_after },
                );
                self.schedule_next_failure(ctx);
            }
            other => panic!("fault injector got unexpected event {other:?}"),
        }
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_config_injects_nothing() {
        let cfg = FaultConfig::default();
        assert!(!cfg.enabled());
        let mut engine: crate::core::engine::Engine<Ev> = crate::core::engine::Engine::new(1);
        let id = engine.add(Box::new(FaultInjector::new(cfg, SimTime(10_000), Vec::new())));
        let r = engine.run(None);
        assert_eq!(r.events, 0);
        assert_eq!(engine.get::<FaultInjector>(id).unwrap().injected, 0);
    }

    #[test]
    fn failure_trace_is_seed_deterministic() {
        let trace = |seed: u64| {
            let mut inj = FaultInjector::new(
                FaultConfig { mtbf: 500.0, mttr: 100.0, seed, until: None },
                SimTime(1_000_000),
                Vec::new(),
            );
            let gaps: Vec<u64> = (0..16).map(|_| inj.draw(500.0).ticks()).collect();
            gaps
        };
        assert_eq!(trace(7), trace(7));
        assert_ne!(trace(7), trace(8));
    }

    #[test]
    fn draws_are_positive_and_mean_scaled() {
        let mut inj = FaultInjector::new(
            FaultConfig { mtbf: 1000.0, mttr: 50.0, seed: 3, until: None },
            SimTime::MAX,
            Vec::new(),
        );
        let n = 4000;
        let sum: u64 = (0..n).map(|_| inj.draw(1000.0).ticks()).sum();
        let mean = sum as f64 / n as f64;
        assert!((700.0..1300.0).contains(&mean), "mean {mean}");
        assert!((0..200).all(|_| inj.draw(0.5).ticks() >= 1), "draws must be >= 1 tick");
    }
}
