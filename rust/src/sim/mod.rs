//! The job-scheduling simulation (paper Fig 1): SST-style component
//! wiring of Job Source -> Job Scheduling + Resource Management -> Job
//! Executor, over the discrete-event core.
//!
//! * `JobSource` replays a [`Workload`] as timed submission events.
//! * `SchedulerComponent` owns the wait queue, the cluster (Resource
//!   Management) and the policy (Job Scheduling); on every arrival or
//!   completion it re-runs the scheduling algorithm and dispatches.
//! * `JobExecutor` simulates execution: a dispatched job completes after
//!   its actual runtime and the completion event flows back.
//!
//! All lifecycle metrics (occupancy / running / utilization series, wait
//! times) are recorded event-driven — no sampling error.

pub mod components;
pub mod faults;
pub mod multicluster;

pub use components::{AutoHorizonParams, FaultCounters, JobExecutor, JobSource, SchedulerComponent};
pub use faults::{FaultConfig, FaultDistribution, FaultInjector, ReservationSpec};
pub use multicluster::{ClusterSpec, MetaScheduler, MultiClusterReport, RouterState, Routing};

use crate::core::engine::Engine;
use crate::core::stats::TimeSeries;
use crate::core::time::{SimDuration, SimTime};
use crate::job::Job;
use crate::metrics::{wait_stats, WaitStats};
use crate::resources::Cluster;
use crate::sched::{OrderKind, Policy, PreemptionConfig, PreemptiveScheduler, Scheduler, UserShare};
use crate::trace::Workload;

/// Default fair-share half-life (ticks =~ seconds): one day, the order
/// of magnitude production schedulers use for usage decay.
pub const DEFAULT_FAIRSHARE_HALF_LIFE: u64 = 86_400;

/// Planning-horizon policy for the availability timeline
/// (`planning.horizon` / `--horizon`).
///
/// The horizon clamps how far into the future the timeline encodes
/// capacity changes: hold releases beyond `now + horizon` coalesce onto
/// the horizon breakpoint, bounding timeline length at the cost of
/// fidelity past it. `Auto` is the scale mode: the component derives the
/// clamp from live queue depth and the median runtime estimate each
/// resync — exact planning when the queue is shallow, bounded timeline
/// length when millions of jobs pile up (see
/// [`components::AUTO_SHALLOW_QUEUE`] and friends for the law).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Horizon {
    /// Unlimited timeline — exact planning (the default; config `0` or
    /// `"exact"`).
    #[default]
    Exact,
    /// Fixed clamp in ticks.
    Fixed(u64),
    /// Clamp derived from live queue state (config `"auto"`).
    Auto,
}

impl Horizon {
    /// Normalize a tick count: a zero fixed horizon *is* exact planning.
    pub fn fixed(ticks: u64) -> Horizon {
        if ticks == 0 {
            Horizon::Exact
        } else {
            Horizon::Fixed(ticks)
        }
    }
}

impl std::str::FromStr for Horizon {
    type Err = String;

    fn from_str(s: &str) -> Result<Horizon, String> {
        let t = s.trim();
        match t.to_ascii_lowercase().as_str() {
            "auto" => Ok(Horizon::Auto),
            "exact" => Ok(Horizon::Exact),
            other => other.parse::<u64>().map(Horizon::fixed).map_err(|_| {
                format!("planning horizon must be a tick count, \"auto\" or \"exact\" (got {t:?})")
            }),
        }
    }
}

impl std::fmt::Display for Horizon {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Horizon::Exact => f.write_str("exact"),
            Horizon::Fixed(t) => write!(f, "{t}"),
            Horizon::Auto => f.write_str("auto"),
        }
    }
}

/// Event payload exchanged between simulation components.
#[derive(Debug, Clone)]
pub enum Ev {
    /// Source -> scheduler: a job arrives (paper: TaskEvent). Boxed to
    /// keep the event enum small — heap sift copies are the DES hot path
    /// (§Perf: +9% throughput).
    Submit(Box<Job>),
    /// Source self-event: emit the next arrival.
    NextArrival,
    /// Scheduler self-event: run the scheduling algorithm.
    Dispatch,
    /// Scheduler -> executor: job started; executor simulates runtime.
    /// `incarnation` tags the run segment so a completion from a segment
    /// that was later preempted is recognizably stale.
    Start { job_id: u64, runtime: SimDuration, incarnation: u32 },
    /// Executor -> scheduler: job finished; release resources.
    Complete { job_id: u64, incarnation: u32 },
    /// Injector self-event: emit the next failure.
    NextFault,
    /// Injector -> scheduler: fail one node now. `victim_draw` picks the
    /// victim among currently failable nodes; `repair_after` is the
    /// pre-drawn repair duration.
    NodeFail { victim_draw: u64, repair_after: SimDuration },
    /// Scheduler self-event: a failed node comes back.
    NodeUp { node: usize },
    /// Injector -> scheduler: reservation `res` comes due.
    ReserveStart { res: usize },
    /// Injector -> scheduler: reservation `res` expires.
    ReserveEnd { res: usize },
}

/// Completed-run report.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub policy: &'static str,
    pub workload: String,
    /// All jobs that completed, with timestamps. Empty (regardless of
    /// how many jobs ran) when the simulation dropped per-job records
    /// (`retain_completed = false`, the streaming-scale path) — use
    /// `completed_count` and [`SimReport::mean_wait_overall`] there.
    pub completed: Vec<Job>,
    /// Jobs completed over the run, counted even when not retained.
    pub completed_count: u64,
    /// Sum of completed jobs' wait times in ticks (streaming aggregate —
    /// survives `retain_completed = false`).
    pub wait_ticks_total: f64,
    pub rejected: u64,
    /// DES events processed.
    pub events: u64,
    /// Simulated end time (last event; with fault injection this may
    /// trail the last completion by pending repairs).
    pub end_time: SimTime,
    /// (t, occupied nodes) — paper Fig 3(a).
    pub occupancy: TimeSeries,
    /// (t, running jobs) — paper Fig 3(b).
    pub running: TimeSeries,
    /// (t, busy cores / total).
    pub utilization: TimeSeries,
    /// Time-weighted mean utilization over the run.
    pub mean_utilization: f64,
    /// (t, busy memory / total memory) — empty unless the run was
    /// memory-aware.
    pub memory_utilization: TimeSeries,
    /// Time-weighted mean memory utilization (0 when untracked).
    pub mean_memory_utilization: f64,
    /// The queue ordering the run dispatched under.
    pub order: &'static str,
    /// Decayed per-user usage at the end of the run (empty unless the
    /// ordering tracks usage — fair share).
    pub user_shares: Vec<UserShare>,
    /// (t, busy cores / non-failed cores) — the operator's instantaneous
    /// view during outages (fault/preemption subsystem).
    pub effective_utilization: TimeSeries,
    /// *Effective* (goodput) utilization: useful core-seconds delivered
    /// (each completed job's runtime x cores, once — redone work and
    /// checkpoint overhead do not count) per available core-second
    /// (non-failed capacity integrated from the first event to the last
    /// completion). Raw busy-time utilization rewards failure-induced
    /// rework; this metric measures what the machine actually delivered.
    pub mean_effective_utilization: f64,
    /// Scheduler invocations (dispatch rounds).
    pub dispatches: u64,
    /// Fault/preemption/reservation counters (all zero for fault-free runs).
    pub faults: FaultCounters,
    /// Core-seconds of progress discarded by kills and failures.
    pub lost_work: f64,
    /// Core-seconds of checkpoint/restart overhead charged.
    pub overhead_work: f64,
    /// Preemption mode the run used (reporting only).
    pub preemption_mode: &'static str,
}

impl SimReport {
    pub fn wait_stats(&self) -> WaitStats {
        wait_stats(&self.completed)
    }

    /// Mean wait over *every* completed job, from the streaming
    /// aggregates — identical to `wait_stats().mean_wait` on runs that
    /// retained per-job records, and the only wait metric available on
    /// streaming-scale runs that did not.
    pub fn mean_wait_overall(&self) -> f64 {
        if self.completed_count == 0 {
            0.0
        } else {
            self.wait_ticks_total / self.completed_count as f64
        }
    }

    /// Makespan: last completion minus first submission.
    pub fn makespan(&self) -> SimDuration {
        let first = self.completed.iter().map(|j| j.submit).min().unwrap_or(SimTime::ZERO);
        let last = self.completed.iter().filter_map(|j| j.end).max().unwrap_or(self.end_time);
        last - first
    }

    /// Canonical byte-exact digest of everything the run measured:
    /// per-job lifecycle tuples plus every counter and float (as IEEE
    /// bits). Two runs are "the same" iff their fingerprints match —
    /// the determinism regression tests compare these strings.
    pub fn fingerprint(&self) -> String {
        use std::fmt::Write as _;
        let mut jobs: Vec<&Job> = self.completed.iter().collect();
        jobs.sort_by_key(|j| j.id);
        let mut out = String::with_capacity(64 + jobs.len() * 32);
        let _ = write!(
            out,
            "policy={} wl={} rejected={} end={} dispatches={} \
             failures={} repairs={} preemptions={} requeues={} reservations={} \
             lost={:016x} overhead={:016x} util={:016x} eutil={:016x}",
            self.policy,
            self.workload,
            self.rejected,
            self.end_time.ticks(),
            self.dispatches,
            self.faults.failures,
            self.faults.repairs,
            self.faults.preemptions,
            self.faults.requeues,
            self.faults.reservations_started,
            self.lost_work.to_bits(),
            self.overhead_work.to_bits(),
            self.mean_utilization.to_bits(),
            self.mean_effective_utilization.to_bits(),
        );
        for j in jobs {
            let _ = write!(
                out,
                "\n{}:{}:{}:{}:{}:{}:{}:{}:{}",
                j.id,
                j.start.map(|t| t.ticks()).unwrap_or(u64::MAX),
                j.end.map(|t| t.ticks()).unwrap_or(u64::MAX),
                j.executed.ticks(),
                j.overhead.ticks(),
                j.lost.ticks(),
                j.preempt_count,
                j.fail_count,
                j.cores,
            );
        }
        out
    }
}

/// Simulation builder.
pub struct Simulation {
    pub workload: Workload,
    pub policy: Policy,
    /// Scheduler override (e.g. XLA-accelerated backfill); defaults to
    /// `policy.build()`.
    pub scheduler: Option<Box<dyn Scheduler>>,
    /// Dispatch link latency (scheduler -> executor), ticks.
    pub dispatch_latency: u64,
    pub seed: u64,
    /// Memory per node (MB); 0 disables memory accounting.
    pub mem_per_node: u64,
    /// Node failure model; `FaultConfig::default()` injects nothing.
    pub faults: FaultConfig,
    /// Preemption layer; `PreemptionConfig::default()` is mode `none`.
    pub preemption: PreemptionConfig,
    /// Advance reservations, applied in declaration order.
    pub reservations: Vec<ReservationSpec>,
    /// Planning-horizon policy for the availability timeline
    /// (`planning.horizon`): see [`Horizon`].
    pub planning_horizon: Horizon,
    /// `Horizon::Auto` tunables (`planning.auto_*`); inert unless
    /// `planning_horizon` is [`Horizon::Auto`].
    pub auto_horizon_params: AutoHorizonParams,
    /// Streamed job feed (constant-memory million-job ingestion): when
    /// set, the source pulls jobs from this iterator one at a time as
    /// simulated time reaches them instead of replaying
    /// `workload.jobs` — pair with [`crate::trace::Workload::machine`].
    /// The stream must yield jobs in nondecreasing submit order. Fault
    /// injection cannot see the last submission of a stream up front, so
    /// a streamed fault run either sets `faults.until` explicitly or
    /// gets a *derived* horizon: the builder threads the stream's
    /// last-seen submit and the scheduler's last-activity time to the
    /// injector as watermarks, and injection stops once the clock
    /// passes `max(watermark, last activity) + 4 x mttr` — the eager
    /// path's law, extended so a backlog draining through an arrival
    /// drought keeps seeing failures.
    pub job_stream: Option<Box<dyn Iterator<Item = Job> + Send>>,
    /// Whether completed jobs keep their per-job lifecycle records in
    /// the report (default). Streaming-scale runs turn this off so peak
    /// memory is O(active jobs); scalar aggregates
    /// (`SimReport::completed_count`, mean wait) survive either way.
    pub retain_completed: bool,
    /// Queue-ordering override (`scheduler.order` / `--order`); `None`
    /// uses the policy's natural order (SJF = shortest-first, etc.).
    pub order: Option<OrderKind>,
    /// Fair-share usage-decay half-life in ticks (`fairshare.half_life`).
    pub fairshare_half_life: u64,
    /// Plan memory as a second availability-timeline dimension
    /// (`--memory-aware`); inert unless `mem_per_node > 0`.
    pub memory_aware: bool,
}

impl Simulation {
    pub fn new(workload: Workload, policy: Policy) -> Simulation {
        Simulation {
            workload,
            policy,
            scheduler: None,
            dispatch_latency: 0,
            seed: 1,
            mem_per_node: 0,
            faults: FaultConfig::default(),
            preemption: PreemptionConfig::default(),
            reservations: Vec::new(),
            planning_horizon: Horizon::Exact,
            auto_horizon_params: AutoHorizonParams::default(),
            job_stream: None,
            retain_completed: true,
            order: None,
            fairshare_half_life: DEFAULT_FAIRSHARE_HALF_LIFE,
            memory_aware: false,
        }
    }

    pub fn with_scheduler(mut self, s: Box<dyn Scheduler>) -> Simulation {
        self.scheduler = Some(s);
        self
    }

    pub fn with_order(mut self, order: OrderKind) -> Simulation {
        self.order = Some(order);
        self
    }

    pub fn with_fairshare_half_life(mut self, half_life: u64) -> Simulation {
        self.fairshare_half_life = half_life;
        self
    }

    pub fn with_mem_per_node(mut self, mem_per_node: u64) -> Simulation {
        self.mem_per_node = mem_per_node;
        self
    }

    pub fn with_memory_aware(mut self, on: bool) -> Simulation {
        self.memory_aware = on;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Simulation {
        self.seed = seed;
        self
    }

    pub fn with_faults(mut self, faults: FaultConfig) -> Simulation {
        self.faults = faults;
        self
    }

    pub fn with_preemption(mut self, cfg: PreemptionConfig) -> Simulation {
        self.preemption = cfg;
        self
    }

    pub fn with_reservations(mut self, reservations: Vec<ReservationSpec>) -> Simulation {
        self.reservations = reservations;
        self
    }

    /// Fixed planning horizon in ticks (0 = exact) — the classic knob;
    /// see [`Simulation::with_horizon`] for the full policy surface.
    pub fn with_planning_horizon(mut self, horizon: u64) -> Simulation {
        self.planning_horizon = Horizon::fixed(horizon);
        self
    }

    pub fn with_horizon(mut self, horizon: Horizon) -> Simulation {
        self.planning_horizon = horizon;
        self
    }

    /// Override the `Horizon::Auto` tunables (`planning.auto_*`).
    pub fn with_auto_horizon_params(mut self, params: AutoHorizonParams) -> Simulation {
        self.auto_horizon_params = params;
        self
    }

    /// Feed jobs from a stream instead of `workload.jobs` (see the
    /// [`Simulation::job_stream`] field docs).
    pub fn with_job_stream(mut self, stream: Box<dyn Iterator<Item = Job> + Send>) -> Simulation {
        self.job_stream = Some(stream);
        self
    }

    /// Toggle per-job record retention (see
    /// [`Simulation::retain_completed`]).
    pub fn with_retain_completed(mut self, retain: bool) -> Simulation {
        self.retain_completed = retain;
        self
    }

    /// Wire the component graph without running (windowed/parallel use).
    pub fn build(self) -> SimInstance {
        let Simulation {
            workload,
            policy,
            scheduler,
            dispatch_latency,
            seed,
            mem_per_node,
            faults,
            preemption,
            reservations,
            planning_horizon,
            auto_horizon_params,
            job_stream,
            retain_completed,
            order,
            fairshare_half_life,
            memory_aware,
        } = self;
        let cluster =
            Cluster::homogeneous(workload.nodes, workload.cores_per_node, mem_per_node);
        let mut scheduler = scheduler.unwrap_or_else(|| policy.build());
        if preemption.enabled() {
            scheduler = Box::new(PreemptiveScheduler::new(scheduler, preemption));
        }
        let policy_name = scheduler.name();
        let wl_name = workload.name.clone();
        // Fault-injection horizon: explicit, or last submission plus a
        // few repair times so late-running jobs still see failures but
        // the failure/repair chain terminates.
        let last_submit = workload.jobs.iter().map(|j| j.submit).max().unwrap_or(SimTime::ZERO);
        let until = match faults.until {
            Some(t) => SimTime(t),
            None => last_submit + SimDuration::from_f64(4.0 * faults.mttr),
        };
        let wire_injector = faults.enabled() || !reservations.is_empty();
        // Streamed feed with faults but no explicit `faults.until`: the
        // last submission is unknowable up front, so the injector gets a
        // *watermark* — the stream's last-seen submit, advanced as jobs
        // are pulled — and derives its horizon dynamically (same
        // `+ 4 x mttr` slack as the eager derivation above). The update
        // happens inside the single-threaded event loop, so runs stay
        // byte-deterministic.
        let mut stream_watermark = None;
        let job_stream = match job_stream {
            Some(stream) if faults.enabled() && faults.until.is_none() => {
                let mark = std::sync::Arc::new(std::sync::atomic::AtomicU64::new(0));
                let sink = std::sync::Arc::clone(&mark);
                stream_watermark = Some(mark);
                let watched = stream.inspect(move |j: &Job| {
                    sink.fetch_max(j.submit.ticks(), std::sync::atomic::Ordering::Relaxed);
                });
                Some(Box::new(watched) as Box<dyn Iterator<Item = Job> + Send>)
            }
            other => other,
        };

        let mut engine: Engine<Ev> = Engine::new(seed);
        let source = match job_stream {
            Some(stream) => engine.add(Box::new(JobSource::from_stream(stream))),
            None => engine.add(Box::new(JobSource::new(workload.jobs))),
        };
        let sched = engine.add(Box::new(SchedulerComponent::new(cluster, scheduler)));
        let exec = engine.add(Box::new(JobExecutor::new(sched)));
        // Wiring (paper Fig 1): source -> scheduler -> executor -> scheduler.
        engine.connect(source, sched, SimDuration(0));
        engine.connect(sched, exec, SimDuration(dispatch_latency));
        engine.connect(exec, sched, SimDuration(0));
        // Tell source + executor where to send.
        engine.get_mut::<JobSource>(source).unwrap().target = sched;
        engine.get_mut::<JobExecutor>(exec).unwrap().scheduler = sched;
        let order_kind = order.unwrap_or_else(|| policy.default_order());
        {
            let s = engine.get_mut::<SchedulerComponent>(sched).unwrap();
            s.executor = exec;
            s.preemption = preemption;
            s.reservations = reservations.clone();
            s.set_horizon(planning_horizon);
            s.set_auto_params(auto_horizon_params);
            s.memory_aware = memory_aware;
            s.retain_completed = retain_completed;
            s.set_queue_order(order_kind.build(fairshare_half_life));
        }
        if wire_injector {
            let mut injector = FaultInjector::new(faults, until, reservations);
            if let Some(mark) = stream_watermark {
                injector = injector.with_stream_watermark(mark);
                // Pair the stream watermark with a last-activity mark
                // from the scheduler, so the derived horizon follows a
                // backlog draining through an arrival drought instead
                // of ending injection `4 x mttr` after the last-seen
                // submission (the drought bug carried since PR 5).
                let activity = std::sync::Arc::new(std::sync::atomic::AtomicU64::new(0));
                engine.get_mut::<SchedulerComponent>(sched).unwrap().activity_mark =
                    Some(std::sync::Arc::clone(&activity));
                injector = injector.with_activity_watermark(activity);
            }
            let inj = engine.add(Box::new(injector));
            engine.connect(inj, sched, SimDuration(0));
            engine.get_mut::<FaultInjector>(inj).unwrap().scheduler = sched;
        }
        SimInstance {
            engine,
            sched_id: sched,
            policy_name,
            workload_name: wl_name,
            order_name: order_kind.as_str(),
        }
    }

    /// Run to completion (or `horizon`) and report.
    pub fn run(self, horizon: Option<SimTime>) -> SimReport {
        let mut inst = self.build();
        let run = inst.engine.run(horizon);
        inst.report(run.events, run.end_time)
    }
}

/// A wired simulation that can be stepped in conservative windows (used
/// by the parallel engine) or run to completion.
pub struct SimInstance {
    pub engine: Engine<Ev>,
    sched_id: crate::core::event::ComponentId,
    policy_name: &'static str,
    workload_name: String,
    order_name: &'static str,
}

impl SimInstance {
    /// Earliest pending event time.
    pub fn next_time(&mut self) -> Option<SimTime> {
        self.engine.next_event_time()
    }

    /// Process all events strictly before `bound`; returns events handled.
    pub fn run_window(&mut self, bound: SimTime) -> u64 {
        self.engine.run_window(bound)
    }

    /// Inject a job arrival at `time`, exactly as the wired `JobSource`
    /// would emit it (same target, same `Priority::ARRIVE`), so external
    /// feeders — the sharded federation router — produce the same event
    /// order as an in-graph source. `time` must be >= the engine clock;
    /// within one timestamp, injection order is arrival order (the
    /// queue's insertion sequence breaks the tie).
    pub fn submit(&mut self, time: SimTime, job: Job) {
        self.engine.schedule(
            time,
            crate::core::event::Priority::ARRIVE,
            self.sched_id,
            Ev::Submit(Box::new(job)),
        );
    }

    /// Process every event with time `<= bound` (inclusive) without
    /// running finish hooks; the instance stays live and can be stepped
    /// again. Returns events handled. The event sequence is exactly the
    /// one an uninterrupted run would process — stepping is a pause
    /// point, not a behavioural fork.
    pub fn step_until(&mut self, bound: SimTime) -> u64 {
        self.engine.step_until(bound)
    }

    /// Current engine clock (time of the last processed event).
    pub fn now(&self) -> SimTime {
        self.engine.now()
    }

    /// Live wait-queue depth.
    pub fn queue_len(&self) -> usize {
        self.sched().queue_len()
    }

    /// Jobs currently running.
    pub fn running_len(&self) -> usize {
        self.sched().running_len()
    }

    /// Jobs completed so far.
    pub fn completed_count(&self) -> u64 {
        self.sched().completed_count
    }

    /// Stable name of the scheduling policy driving this instance.
    pub fn policy_name(&self) -> &'static str {
        self.policy_name
    }

    fn sched(&self) -> &SchedulerComponent {
        self.engine.get::<SchedulerComponent>(self.sched_id).expect("scheduler component")
    }

    /// Deep-copy the live instance into a [`SimSnapshot`] that can be
    /// resumed independently. Fails (naming the offending component)
    /// when any component holds non-snapshotable state — a non-rewindable
    /// job stream, a stream watermark shared with the fault injector, or
    /// an accelerator-backed scorer. Resuming the snapshot and running it
    /// produces a byte-identical [`SimReport::fingerprint`] to the
    /// original run — the clone preserves the event queue's sequence
    /// counter, so even tie-breaking is reproduced.
    pub fn snapshot(&self) -> Result<SimSnapshot, String> {
        Ok(SimSnapshot {
            engine: self.engine.snapshot()?,
            sched_id: self.sched_id,
            policy_name: self.policy_name,
            workload_name: self.workload_name.clone(),
            order_name: self.order_name,
        })
    }

    /// Reconstruct a live instance from a snapshot (the inverse of
    /// [`SimInstance::snapshot`]).
    pub fn resume(snap: SimSnapshot) -> SimInstance {
        SimInstance {
            engine: snap.engine,
            sched_id: snap.sched_id,
            policy_name: snap.policy_name,
            workload_name: snap.workload_name,
            order_name: snap.order_name,
        }
    }

    /// Close statistics and extract the report.
    pub fn finalize(mut self) -> SimReport {
        self.engine.finish();
        let events = self.engine.events_processed();
        let end = self.engine.now();
        self.report(events, end)
    }

    /// Drain every remaining event (or stop at `horizon`) and report —
    /// the stepping-world equivalent of [`Simulation::run`], used to
    /// play a resumed [`SimSnapshot`] forward to its end state.
    pub fn run_to_completion(mut self, horizon: Option<SimTime>) -> SimReport {
        let run = self.engine.run(horizon);
        self.report(run.events, run.end_time)
    }

    fn report(&mut self, events: u64, end_time: SimTime) -> SimReport {
        let sched = self.sched_id;
        let s = self.engine.get_mut::<SchedulerComponent>(sched).unwrap();
        let utilization = std::mem::take(&mut s.util_series);
        // Streaming-scale runs record no series; their incremental
        // aggregates carry the same time-weighted law.
        let mean_utilization = if utilization.points().is_empty() {
            s.streaming_mean_utilization(end_time)
        } else {
            utilization.time_weighted_mean(end_time)
        };
        let memory_utilization = std::mem::take(&mut s.mem_util_series);
        let mean_memory_utilization = if memory_utilization.points().is_empty() {
            // Zero for untracked memory; the incremental aggregate for
            // memory-aware streaming-scale runs.
            s.streaming_mean_memory_utilization(end_time)
        } else {
            memory_utilization.time_weighted_mean(end_time)
        };
        let user_shares = s.user_shares(end_time);
        let effective_utilization = std::mem::take(&mut s.effective_util_series);
        let completed = std::mem::take(&mut s.completed);
        // Goodput: useful core-seconds / available core-seconds up to
        // the last completion (see the SimReport field docs).
        let last_completion =
            completed.iter().filter_map(|j| j.end).max().unwrap_or(end_time);
        let useful: f64 =
            completed.iter().map(|j| j.runtime.as_f64() * j.cores as f64).sum();
        let avail_series = std::mem::take(&mut s.avail_series);
        let avail_integral = series_integral(&avail_series, last_completion);
        let mean_effective_utilization = if completed.is_empty() && s.completed_count > 0 {
            // Streaming-scale run: per-job records were dropped; the
            // component accumulated the goodput terms incrementally.
            s.streaming_effective_utilization()
        } else if avail_integral > 0.0 {
            useful / avail_integral
        } else {
            0.0
        };
        SimReport {
            policy: self.policy_name,
            workload: self.workload_name.clone(),
            completed,
            completed_count: s.completed_count,
            wait_ticks_total: s.wait_ticks_total,
            rejected: s.rejected,
            events,
            end_time,
            occupancy: std::mem::take(&mut s.occupancy),
            running: std::mem::take(&mut s.running_series),
            utilization,
            mean_utilization,
            memory_utilization,
            mean_memory_utilization,
            order: self.order_name,
            user_shares,
            effective_utilization,
            mean_effective_utilization,
            dispatches: s.dispatches,
            faults: s.fault_counters,
            lost_work: s.lost_work,
            overhead_work: s.overhead_work,
            preemption_mode: s.preemption.mode.as_str(),
        }
    }
}

/// A paused deep copy of a running [`SimInstance`], produced by
/// [`SimInstance::snapshot`] and revived by [`SimInstance::resume`] (or
/// [`SimSnapshot::resume`]). Snapshots are independent: stepping a
/// resumed copy cannot perturb the original, which is what lets the
/// serve daemon answer speculative "when would this job start?" queries
/// against a clone of the live timeline.
pub struct SimSnapshot {
    engine: Engine<Ev>,
    sched_id: crate::core::event::ComponentId,
    policy_name: &'static str,
    workload_name: String,
    order_name: &'static str,
}

impl SimSnapshot {
    /// Revive the snapshot into a live instance (consumes the snapshot;
    /// take another [`SimInstance::snapshot`] first to keep a copy).
    pub fn resume(self) -> SimInstance {
        SimInstance::resume(self)
    }
}

/// Integral of a step-function series from its first point to `until`
/// (samples hold until the next one; points at or past `until` are
/// clipped — unlike `time_weighted_mean`, which assumes the horizon is
/// past the last sample).
fn series_integral(series: &TimeSeries, until: SimTime) -> f64 {
    let pts = series.points();
    let mut total = 0.0;
    for w in pts.windows(2) {
        if w[0].0 >= until {
            break;
        }
        let hi = w[1].0.min(until);
        total += w[0].1 * (hi - w[0].0).as_f64();
    }
    if let Some(&(t, v)) = pts.last() {
        if until > t {
            total += v * (until - t).as_f64();
        }
    }
    total
}

/// Convenience: run `workload` under `policy` with defaults.
pub fn run_policy(workload: Workload, policy: Policy) -> SimReport {
    Simulation::new(workload, policy).run(None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::Workload;

    fn tiny_workload() -> Workload {
        // 2 nodes x 4 cores. Three jobs: two fill the machine, third waits.
        Workload::new(
            "tiny",
            vec![
                Job::simple(1, 0, 4, 100),
                Job::simple(2, 0, 4, 100),
                Job::simple(3, 10, 8, 50),
            ],
            2,
            4,
        )
    }

    #[test]
    fn fcfs_end_to_end() {
        let r = run_policy(tiny_workload(), Policy::Fcfs);
        assert_eq!(r.completed.len(), 3);
        assert_eq!(r.rejected, 0);
        let by_id: std::collections::BTreeMap<u64, &Job> =
            r.completed.iter().map(|j| (j.id, j)).collect();
        // Jobs 1, 2 start immediately; job 3 waits for both to finish.
        assert_eq!(by_id[&1].start, Some(SimTime(0)));
        assert_eq!(by_id[&2].start, Some(SimTime(0)));
        assert_eq!(by_id[&3].start, Some(SimTime(100)));
        assert_eq!(by_id[&3].end, Some(SimTime(150)));
        assert_eq!(r.end_time, SimTime(150));
    }

    #[test]
    fn occupancy_series_tracks_usage() {
        let r = run_policy(tiny_workload(), Policy::Fcfs);
        // At t=0 both nodes occupied; at 100 job 3 takes both; at 150 zero.
        let last = r.occupancy.points().last().unwrap();
        assert_eq!(last.0, SimTime(150));
        assert_eq!(last.1, 0.0);
        let max = r.occupancy.points().iter().map(|p| p.1).fold(0.0, f64::max);
        assert_eq!(max, 2.0);
    }

    #[test]
    fn infeasible_job_rejected() {
        let w = Workload::new("rej", vec![Job::simple(1, 0, 100, 10)], 2, 4);
        let r = run_policy(w, Policy::Fcfs);
        assert_eq!(r.completed.len(), 0);
        assert_eq!(r.rejected, 1);
    }

    #[test]
    fn all_policies_complete_everything() {
        for p in Policy::ALL {
            let r = run_policy(tiny_workload(), p);
            assert_eq!(r.completed.len(), 3, "{p} lost jobs");
            assert_eq!(r.rejected, 0);
            // Conservation: every completed job has start <= end.
            for j in &r.completed {
                assert!(j.start.unwrap() <= j.end.unwrap());
                assert!(j.start.unwrap() >= j.submit);
            }
        }
    }

    #[test]
    fn backfill_beats_fcfs_on_classic_scenario() {
        // 8-core machine. J1 takes 4 cores 100s. J2 (head) needs 8 (waits).
        // J3 needs 4 for 50s: backfill starts it now; FCFS makes it wait.
        let w = || {
            Workload::new(
                "bf",
                vec![
                    Job::with_estimate(1, 0, 4, 100, 100),
                    Job::with_estimate(2, 1, 8, 100, 100),
                    Job::with_estimate(3, 2, 4, 50, 50),
                ],
                1,
                8,
            )
        };
        let fcfs = run_policy(w(), Policy::Fcfs);
        let bf = run_policy(w(), Policy::FcfsBackfill);
        let wait3 = |r: &SimReport| {
            r.completed.iter().find(|j| j.id == 3).unwrap().wait_time().unwrap().ticks()
        };
        assert!(wait3(&bf) < wait3(&fcfs), "backfill {} !< fcfs {}", wait3(&bf), wait3(&fcfs));
        // Head job 2 must not be delayed by the backfill.
        let start2 = |r: &SimReport| {
            r.completed.iter().find(|j| j.id == 2).unwrap().start.unwrap()
        };
        assert_eq!(start2(&bf), start2(&fcfs));
    }

    #[test]
    fn sjf_prefers_short_jobs_under_contention() {
        // One 4-core machine; three jobs arrive together.
        let w = |_| {
            Workload::new(
                "sjf",
                vec![
                    Job::with_estimate(1, 0, 4, 100, 100),
                    Job::with_estimate(2, 1, 4, 10, 10),
                    Job::with_estimate(3, 1, 4, 200, 200),
                ],
                1,
                4,
            )
        };
        let sjf = run_policy(w(()), Policy::Sjf);
        let stats = sjf.wait_stats();
        let ljf = run_policy(w(()), Policy::Ljf);
        assert!(stats.mean_wait < ljf.wait_stats().mean_wait);
    }

    #[test]
    fn deterministic_runs() {
        let a = run_policy(tiny_workload(), Policy::FcfsBackfill);
        let b = run_policy(tiny_workload(), Policy::FcfsBackfill);
        assert_eq!(a.events, b.events);
        assert_eq!(a.end_time, b.end_time);
        let ids = |r: &SimReport| -> Vec<(u64, Option<SimTime>)> {
            r.completed.iter().map(|j| (j.id, j.start)).collect()
        };
        assert_eq!(ids(&a), ids(&b));
    }

    #[test]
    fn dispatch_latency_delays_starts() {
        let mut sim = Simulation::new(tiny_workload(), Policy::Fcfs);
        sim.dispatch_latency = 5;
        let r = sim.run(None);
        let j1 = r.completed.iter().find(|j| j.id == 1).unwrap();
        // Start is stamped at dispatch; execution begins at the executor
        // after the link latency, so completion shifts by 5.
        assert_eq!(j1.end, Some(SimTime(105)));
    }
}
