//! Job model: the unit of work the scheduler manages.
//!
//! Mirrors the paper's `TaskEvent` (Listing 1): every arriving job is
//! encapsulated as a serializable event instance carrying a unique id and
//! detailed resource requirements, and moves through the lifecycle
//! submitted -> queued -> running -> completed.

pub mod queue;

pub use queue::WaitQueue;

use crate::core::time::{SimDuration, SimTime};
use crate::util::json::Json;

/// Unique job identifier.
pub type JobId = u64;

/// Lifecycle state (paper §2: submission, execution, completion).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum JobState {
    /// Known to the system but not yet in the wait queue.
    Submitted,
    /// In the wait queue.
    Queued,
    /// Executing on allocated nodes.
    Running,
    /// Finished; resources reclaimed.
    Completed,
    /// Rejected (e.g. requests more cores than the machine has).
    Rejected,
}

/// A job: static description + mutable lifecycle timestamps.
///
/// This is the `TaskEvent` of the paper: it is the payload serialized
/// across components ([`Job::to_json`]/[`Job::from_json`] stand in for
/// SST's serialization macros, paper Listing 1).
#[derive(Debug, Clone)]
pub struct Job {
    pub id: JobId,
    /// Submission (arrival) time.
    pub submit: SimTime,
    /// Requested cores (trace "processors").
    pub cores: u64,
    /// Requested memory in MB (0 = unspecified).
    pub memory_mb: u64,
    /// User-provided runtime estimate — what backfilling trusts.
    pub est_runtime: SimDuration,
    /// Actual runtime — what execution takes.
    pub runtime: SimDuration,
    /// Trace user id (0 = unknown).
    pub user: u32,
    /// Trace group/project id (0 = unknown).
    pub group: u32,
    pub state: JobState,
    /// Set when the job starts running.
    pub start: Option<SimTime>,
    /// Set when the job completes.
    pub end: Option<SimTime>,
}

impl Job {
    /// Build a job in `Submitted` state. `est_runtime` is clamped to at
    /// least the actual runtime when the trace under-estimates? No —
    /// traces legitimately contain under-estimates (jobs killed at the
    /// estimate); we preserve both fields as given and let execution use
    /// min(est, actual) semantics in the executor if configured.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: JobId,
        submit: SimTime,
        cores: u64,
        memory_mb: u64,
        est_runtime: SimDuration,
        runtime: SimDuration,
        user: u32,
        group: u32,
    ) -> Job {
        Job {
            id,
            submit,
            cores,
            memory_mb,
            est_runtime,
            runtime,
            user,
            group,
            state: JobState::Submitted,
            start: None,
            end: None,
        }
    }

    /// Minimal constructor for tests and synthetic workloads.
    pub fn simple(id: JobId, submit: u64, cores: u64, runtime: u64) -> Job {
        Job::new(
            id,
            SimTime(submit),
            cores,
            0,
            SimDuration(runtime),
            SimDuration(runtime),
            0,
            0,
        )
    }

    /// Same as [`simple`] but with a distinct user estimate.
    pub fn with_estimate(id: JobId, submit: u64, cores: u64, runtime: u64, est: u64) -> Job {
        Job::new(
            id,
            SimTime(submit),
            cores,
            0,
            SimDuration(est),
            SimDuration(runtime),
            0,
            0,
        )
    }

    /// Wait time: start - submit. None if not started.
    pub fn wait_time(&self) -> Option<SimDuration> {
        self.start.map(|s| s - self.submit)
    }

    /// Turnaround: end - submit. None if not completed.
    pub fn turnaround(&self) -> Option<SimDuration> {
        self.end.map(|e| e - self.submit)
    }

    /// Bounded slowdown with threshold tau (standard scheduling metric):
    /// max(1, turnaround / max(runtime, tau)).
    pub fn bounded_slowdown(&self, tau: f64) -> Option<f64> {
        self.turnaround().map(|t| {
            let denom = (self.runtime.as_f64()).max(tau);
            (t.as_f64() / denom).max(1.0)
        })
    }

    /// Core-seconds consumed.
    pub fn core_seconds(&self) -> f64 {
        self.cores as f64 * self.runtime.as_f64()
    }

    /// Mark started: Queued/Submitted -> Running. Panics on bad transition
    /// in debug builds (lifecycle invariant).
    pub fn mark_started(&mut self, now: SimTime) {
        debug_assert!(
            matches!(self.state, JobState::Queued | JobState::Submitted),
            "job {} started from state {:?}",
            self.id,
            self.state
        );
        self.state = JobState::Running;
        self.start = Some(now);
    }

    /// Mark completed: Running -> Completed.
    pub fn mark_completed(&mut self, now: SimTime) {
        debug_assert!(
            self.state == JobState::Running,
            "job {} completed from state {:?}",
            self.id,
            self.state
        );
        self.state = JobState::Completed;
        self.end = Some(now);
    }

    /// TaskEvent serialization (paper Listing 1): encode the full event
    /// state so it transfers losslessly across components/ranks.
    pub fn to_json(&self) -> Json {
        let state = match self.state {
            JobState::Submitted => "submitted",
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Completed => "completed",
            JobState::Rejected => "rejected",
        };
        let mut pairs = vec![
            ("id", Json::num(self.id as f64)),
            ("submit", Json::num(self.submit.ticks() as f64)),
            ("cores", Json::num(self.cores as f64)),
            ("memory_mb", Json::num(self.memory_mb as f64)),
            ("est_runtime", Json::num(self.est_runtime.ticks() as f64)),
            ("runtime", Json::num(self.runtime.ticks() as f64)),
            ("user", Json::num(self.user as f64)),
            ("group", Json::num(self.group as f64)),
            ("state", Json::str(state)),
        ];
        if let Some(s) = self.start {
            pairs.push(("start", Json::num(s.ticks() as f64)));
        }
        if let Some(e) = self.end {
            pairs.push(("end", Json::num(e.ticks() as f64)));
        }
        Json::obj(pairs)
    }

    /// Inverse of [`Job::to_json`]. Returns `None` on malformed input.
    pub fn from_json(v: &Json) -> Option<Job> {
        let state = match v.get_str_or("state", "submitted") {
            "queued" => JobState::Queued,
            "running" => JobState::Running,
            "completed" => JobState::Completed,
            "rejected" => JobState::Rejected,
            _ => JobState::Submitted,
        };
        Some(Job {
            id: v.get("id")?.as_u64()?,
            submit: SimTime(v.get("submit")?.as_u64()?),
            cores: v.get("cores")?.as_u64()?,
            memory_mb: v.get_u64_or("memory_mb", 0),
            est_runtime: SimDuration(v.get_u64_or("est_runtime", 0)),
            runtime: SimDuration(v.get_u64_or("runtime", 0)),
            user: v.get_u64_or("user", 0) as u32,
            group: v.get_u64_or("group", 0) as u32,
            state,
            start: v.get("start").and_then(|x| x.as_u64()).map(SimTime),
            end: v.get("end").and_then(|x| x.as_u64()).map(SimTime),
        })
    }
}

/// A scheduling decision: start this job on these nodes now.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement {
    pub job_id: JobId,
    /// Node indices receiving the allocation.
    pub nodes: Vec<usize>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_and_metrics() {
        let mut j = Job::simple(1, 100, 8, 50);
        assert_eq!(j.state, JobState::Submitted);
        assert_eq!(j.wait_time(), None);
        j.state = JobState::Queued;
        j.mark_started(SimTime(130));
        assert_eq!(j.state, JobState::Running);
        assert_eq!(j.wait_time(), Some(SimDuration(30)));
        j.mark_completed(SimTime(180));
        assert_eq!(j.state, JobState::Completed);
        assert_eq!(j.turnaround(), Some(SimDuration(80)));
        assert_eq!(j.core_seconds(), 400.0);
    }

    #[test]
    fn bounded_slowdown_floors_at_one() {
        let mut j = Job::simple(1, 0, 1, 100);
        j.state = JobState::Queued;
        j.mark_started(SimTime(0));
        j.mark_completed(SimTime(100));
        assert_eq!(j.bounded_slowdown(10.0), Some(1.0));
    }

    #[test]
    fn bounded_slowdown_uses_tau_for_tiny_jobs() {
        let mut j = Job::simple(1, 0, 1, 1);
        j.state = JobState::Queued;
        j.mark_started(SimTime(99));
        j.mark_completed(SimTime(100));
        // turnaround=100, denom=max(1, 10)=10 -> 10.0
        assert_eq!(j.bounded_slowdown(10.0), Some(10.0));
    }

    #[test]
    fn task_event_serialization_roundtrip() {
        // Paper Listing 1: TaskEvent serialization across components.
        let mut j = Job::with_estimate(7, 5, 16, 300, 600);
        j.state = JobState::Queued;
        j.mark_started(SimTime(50));
        let text = j.to_json().to_string();
        let back = Job::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.id, 7);
        assert_eq!(back.cores, 16);
        assert_eq!(back.est_runtime, SimDuration(600));
        assert_eq!(back.runtime, SimDuration(300));
        assert_eq!(back.state, JobState::Running);
        assert_eq!(back.start, Some(SimTime(50)));
        assert_eq!(back.end, None);
    }

    #[test]
    fn from_json_rejects_malformed() {
        assert!(Job::from_json(&Json::parse(r#"{"id": 1}"#).unwrap()).is_none());
        assert!(Job::from_json(&Json::parse(r#"{"id": -1, "submit": 0, "cores": 1}"#).unwrap())
            .is_none());
    }

    #[test]
    #[cfg(debug_assertions)]
    fn bad_transition_panics_in_debug() {
        let mut j = Job::simple(1, 0, 1, 1);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            j.mark_completed(SimTime(5)); // never started
        }));
        assert!(r.is_err());
    }
}
