//! Job model: the unit of work the scheduler manages.
//!
//! Mirrors the paper's `TaskEvent` (Listing 1): every arriving job is
//! encapsulated as a serializable event instance carrying a unique id and
//! detailed resource requirements, and moves through the lifecycle
//! submitted -> queued -> running -> completed.

pub mod queue;

pub use queue::WaitQueue;

use crate::core::time::{SimDuration, SimTime};
use crate::util::json::Json;

/// Unique job identifier.
pub type JobId = u64;

/// Lifecycle state (paper §2: submission, execution, completion).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum JobState {
    /// Known to the system but not yet in the wait queue.
    Submitted,
    /// In the wait queue.
    Queued,
    /// Executing on allocated nodes.
    Running,
    /// Finished; resources reclaimed.
    Completed,
    /// Rejected (e.g. requests more cores than the machine has).
    Rejected,
}

/// A job: static description + mutable lifecycle timestamps.
///
/// This is the `TaskEvent` of the paper: it is the payload serialized
/// across components ([`Job::to_json`]/[`Job::from_json`] stand in for
/// SST's serialization macros, paper Listing 1).
#[derive(Debug, Clone)]
pub struct Job {
    pub id: JobId,
    /// Submission (arrival) time.
    pub submit: SimTime,
    /// Requested cores (trace "processors").
    pub cores: u64,
    /// Requested memory in MB (0 = unspecified).
    pub memory_mb: u64,
    /// User-provided runtime estimate — what backfilling trusts.
    pub est_runtime: SimDuration,
    /// Actual runtime — what execution takes.
    pub runtime: SimDuration,
    /// Trace user id (0 = unknown).
    pub user: u32,
    /// Trace group/project id (0 = unknown).
    pub group: u32,
    /// Scheduling priority (fault/preemption subsystem): higher values
    /// are more important; preemptive policies only evict strictly
    /// lower-priority work. Traces default to 0.
    pub priority: u8,
    pub state: JobState,
    /// Set when the job first starts running (wait time = start - submit,
    /// also for jobs that are later preempted and restarted).
    pub start: Option<SimTime>,
    /// Set when the job completes.
    pub end: Option<SimTime>,
    /// Start of the current run segment (equals `start` for jobs that
    /// were never preempted).
    pub last_start: Option<SimTime>,
    /// Work still to execute. Initially the actual runtime; preemption
    /// and failure rewrite it (see `record_interruption`).
    pub remaining: SimDuration,
    /// Machine time consumed across all run segments so far.
    pub executed: SimDuration,
    /// Checkpoint/restart overhead charged so far.
    pub overhead: SimDuration,
    /// Progress discarded by kills (failures or non-checkpointed
    /// eviction).
    pub lost: SimDuration,
    /// Planned evictions suffered (preemptive policies, reservations).
    pub preempt_count: u32,
    /// Node-failure kills suffered.
    pub fail_count: u32,
    /// Dispatch generation: bumped every time the job is (re)started so
    /// stale completion events from a cancelled segment are ignored.
    pub incarnation: u32,
}

impl Job {
    /// Build a job in `Submitted` state. `est_runtime` is clamped to at
    /// least the actual runtime when the trace under-estimates? No —
    /// traces legitimately contain under-estimates (jobs killed at the
    /// estimate); we preserve both fields as given and let execution use
    /// min(est, actual) semantics in the executor if configured.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: JobId,
        submit: SimTime,
        cores: u64,
        memory_mb: u64,
        est_runtime: SimDuration,
        runtime: SimDuration,
        user: u32,
        group: u32,
    ) -> Job {
        Job {
            id,
            submit,
            cores,
            memory_mb,
            est_runtime,
            runtime,
            user,
            group,
            priority: 0,
            state: JobState::Submitted,
            start: None,
            end: None,
            last_start: None,
            remaining: runtime,
            executed: SimDuration::ZERO,
            overhead: SimDuration::ZERO,
            lost: SimDuration::ZERO,
            preempt_count: 0,
            fail_count: 0,
            incarnation: 0,
        }
    }

    /// Minimal constructor for tests and synthetic workloads.
    pub fn simple(id: JobId, submit: u64, cores: u64, runtime: u64) -> Job {
        Job::new(
            id,
            SimTime(submit),
            cores,
            0,
            SimDuration(runtime),
            SimDuration(runtime),
            0,
            0,
        )
    }

    /// Same as [`simple`] but with a distinct user estimate.
    pub fn with_estimate(id: JobId, submit: u64, cores: u64, runtime: u64, est: u64) -> Job {
        Job::new(
            id,
            SimTime(submit),
            cores,
            0,
            SimDuration(est),
            SimDuration(runtime),
            0,
            0,
        )
    }

    /// Same as [`simple`] but with a memory demand (tests and examples).
    pub fn with_memory(id: JobId, submit: u64, cores: u64, memory_mb: u64, runtime: u64) -> Job {
        Job::new(
            id,
            SimTime(submit),
            cores,
            memory_mb,
            SimDuration(runtime),
            SimDuration(runtime),
            0,
            0,
        )
    }

    /// The aggregate multi-resource demand this job places on the
    /// machine — what the planning layer plans in.
    pub fn demand(&self) -> crate::resources::ResourceVector {
        crate::resources::ResourceVector::new(self.cores, self.memory_mb)
    }

    /// Wait time: start - submit. None if not started.
    pub fn wait_time(&self) -> Option<SimDuration> {
        self.start.map(|s| s - self.submit)
    }

    /// Turnaround: end - submit. None if not completed.
    pub fn turnaround(&self) -> Option<SimDuration> {
        self.end.map(|e| e - self.submit)
    }

    /// Bounded slowdown with threshold tau (standard scheduling metric):
    /// max(1, turnaround / max(runtime, tau)).
    pub fn bounded_slowdown(&self, tau: f64) -> Option<f64> {
        self.turnaround().map(|t| {
            let denom = (self.runtime.as_f64()).max(tau);
            (t.as_f64() / denom).max(1.0)
        })
    }

    /// Core-seconds consumed.
    pub fn core_seconds(&self) -> f64 {
        self.cores as f64 * self.runtime.as_f64()
    }

    /// Mark started: Queued/Submitted -> Running. Panics on bad transition
    /// in debug builds (lifecycle invariant). `start` keeps the *first*
    /// start (wait-time metric); `last_start` tracks the current segment
    /// and the incarnation counter invalidates any stale completion.
    pub fn mark_started(&mut self, now: SimTime) {
        debug_assert!(
            matches!(self.state, JobState::Queued | JobState::Submitted),
            "job {} started from state {:?}",
            self.id,
            self.state
        );
        self.state = JobState::Running;
        if self.start.is_none() {
            self.start = Some(now);
        }
        self.last_start = Some(now);
        self.incarnation += 1;
    }

    /// Mark completed: Running -> Completed.
    pub fn mark_completed(&mut self, now: SimTime) {
        debug_assert!(
            self.state == JobState::Running,
            "job {} completed from state {:?}",
            self.id,
            self.state
        );
        self.state = JobState::Completed;
        self.end = Some(now);
        if let Some(s) = self.last_start {
            self.executed = self.executed + (now - s);
        }
        self.remaining = SimDuration::ZERO;
    }

    /// Record an interruption of the current run segment at `now`
    /// (Running -> Queued; the driver re-enqueues the job).
    ///
    /// With `keep_progress` (checkpointed eviction) the work done so far
    /// survives and `overhead` extra ticks (checkpoint + restart cost)
    /// are charged onto the remaining work. Without it (node failure, or
    /// kill-mode eviction) all progress since the segment start is lost
    /// and the job starts over from its full runtime.
    ///
    /// Accounting invariant (property-tested in rust/tests/prop_faults.rs):
    /// at completion, `executed == runtime + overhead + lost`.
    pub fn record_interruption(&mut self, now: SimTime, keep_progress: bool, overhead: SimDuration) {
        debug_assert!(
            self.state == JobState::Running,
            "job {} interrupted from state {:?}",
            self.id,
            self.state
        );
        let seg_start = self.last_start.expect("running job without a segment start");
        let elapsed = now - seg_start;
        self.executed = self.executed + elapsed;
        if keep_progress {
            self.remaining = (self.remaining - elapsed) + overhead;
            self.overhead = self.overhead + overhead;
        } else {
            // Starting over: everything executed so far that is not
            // already booked as overhead is lost work. (Assigning rather
            // than accumulating keeps the completion invariant exact
            // across mixed checkpoint/kill histories.)
            self.lost = self.executed - self.overhead;
            self.remaining = self.runtime;
        }
        self.state = JobState::Submitted;
        self.last_start = None;
    }

    /// Runtime estimate for the *next* run segment.
    ///
    /// Fresh jobs and jobs that start over after a kill (no checkpoint
    /// exists) carry only the user estimate — the scheduler must not see
    /// the actual runtime. A checkpoint-restored job's remaining work
    /// *is* known to the system (the checkpoint records its progress),
    /// so the restore segment uses `remaining`, the standard simulator
    /// treatment of checkpoint metadata.
    pub fn est_remaining(&self) -> SimDuration {
        let interrupted = self.preempt_count > 0 || self.fail_count > 0;
        if interrupted && self.remaining != self.runtime {
            self.remaining
        } else {
            self.est_runtime
        }
    }

    /// TaskEvent serialization (paper Listing 1): encode the full event
    /// state so it transfers losslessly across components/ranks.
    pub fn to_json(&self) -> Json {
        let state = match self.state {
            JobState::Submitted => "submitted",
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Completed => "completed",
            JobState::Rejected => "rejected",
        };
        let mut pairs = vec![
            ("id", Json::num(self.id as f64)),
            ("submit", Json::num(self.submit.ticks() as f64)),
            ("cores", Json::num(self.cores as f64)),
            ("memory_mb", Json::num(self.memory_mb as f64)),
            ("est_runtime", Json::num(self.est_runtime.ticks() as f64)),
            ("runtime", Json::num(self.runtime.ticks() as f64)),
            ("user", Json::num(self.user as f64)),
            ("group", Json::num(self.group as f64)),
            ("state", Json::str(state)),
        ];
        if self.priority != 0 {
            pairs.push(("priority", Json::num(self.priority as f64)));
        }
        if let Some(s) = self.start {
            pairs.push(("start", Json::num(s.ticks() as f64)));
        }
        if let Some(e) = self.end {
            pairs.push(("end", Json::num(e.ticks() as f64)));
        }
        // Fault/preemption lifecycle, only when the job was touched —
        // untouched jobs keep the paper's original TaskEvent shape.
        if self.preempt_count != 0 || self.fail_count != 0 {
            pairs.push(("remaining", Json::num(self.remaining.ticks() as f64)));
            pairs.push(("executed", Json::num(self.executed.ticks() as f64)));
            pairs.push(("overhead", Json::num(self.overhead.ticks() as f64)));
            pairs.push(("lost", Json::num(self.lost.ticks() as f64)));
            pairs.push(("preempt_count", Json::num(self.preempt_count as f64)));
            pairs.push(("fail_count", Json::num(self.fail_count as f64)));
        }
        Json::obj(pairs)
    }

    /// Inverse of [`Job::to_json`]. Returns `None` on malformed input.
    pub fn from_json(v: &Json) -> Option<Job> {
        let state = match v.get_str_or("state", "submitted") {
            "queued" => JobState::Queued,
            "running" => JobState::Running,
            "completed" => JobState::Completed,
            "rejected" => JobState::Rejected,
            _ => JobState::Submitted,
        };
        let runtime = SimDuration(v.get_u64_or("runtime", 0));
        let start = v.get("start").and_then(|x| x.as_u64()).map(SimTime);
        Some(Job {
            id: v.get("id")?.as_u64()?,
            submit: SimTime(v.get("submit")?.as_u64()?),
            cores: v.get("cores")?.as_u64()?,
            memory_mb: v.get_u64_or("memory_mb", 0),
            est_runtime: SimDuration(v.get_u64_or("est_runtime", 0)),
            runtime,
            user: v.get_u64_or("user", 0) as u32,
            group: v.get_u64_or("group", 0) as u32,
            priority: v.get_u64_or("priority", 0) as u8,
            state,
            start,
            end: v.get("end").and_then(|x| x.as_u64()).map(SimTime),
            last_start: if state == JobState::Running { start } else { None },
            remaining: SimDuration(v.get_u64_or("remaining", runtime.ticks())),
            executed: SimDuration(v.get_u64_or("executed", 0)),
            overhead: SimDuration(v.get_u64_or("overhead", 0)),
            lost: SimDuration(v.get_u64_or("lost", 0)),
            preempt_count: v.get_u64_or("preempt_count", 0) as u32,
            fail_count: v.get_u64_or("fail_count", 0) as u32,
            incarnation: 0,
        })
    }
}

/// A scheduling decision: start this job on these nodes now.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement {
    pub job_id: JobId,
    /// Node indices receiving the allocation.
    pub nodes: Vec<usize>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_and_metrics() {
        let mut j = Job::simple(1, 100, 8, 50);
        assert_eq!(j.state, JobState::Submitted);
        assert_eq!(j.wait_time(), None);
        j.state = JobState::Queued;
        j.mark_started(SimTime(130));
        assert_eq!(j.state, JobState::Running);
        assert_eq!(j.wait_time(), Some(SimDuration(30)));
        j.mark_completed(SimTime(180));
        assert_eq!(j.state, JobState::Completed);
        assert_eq!(j.turnaround(), Some(SimDuration(80)));
        assert_eq!(j.core_seconds(), 400.0);
    }

    #[test]
    fn bounded_slowdown_floors_at_one() {
        let mut j = Job::simple(1, 0, 1, 100);
        j.state = JobState::Queued;
        j.mark_started(SimTime(0));
        j.mark_completed(SimTime(100));
        assert_eq!(j.bounded_slowdown(10.0), Some(1.0));
    }

    #[test]
    fn bounded_slowdown_uses_tau_for_tiny_jobs() {
        let mut j = Job::simple(1, 0, 1, 1);
        j.state = JobState::Queued;
        j.mark_started(SimTime(99));
        j.mark_completed(SimTime(100));
        // turnaround=100, denom=max(1, 10)=10 -> 10.0
        assert_eq!(j.bounded_slowdown(10.0), Some(10.0));
    }

    #[test]
    fn task_event_serialization_roundtrip() {
        // Paper Listing 1: TaskEvent serialization across components.
        let mut j = Job::with_estimate(7, 5, 16, 300, 600);
        j.state = JobState::Queued;
        j.mark_started(SimTime(50));
        let text = j.to_json().to_string();
        let back = Job::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.id, 7);
        assert_eq!(back.cores, 16);
        assert_eq!(back.est_runtime, SimDuration(600));
        assert_eq!(back.runtime, SimDuration(300));
        assert_eq!(back.state, JobState::Running);
        assert_eq!(back.start, Some(SimTime(50)));
        assert_eq!(back.end, None);
    }

    #[test]
    fn from_json_rejects_malformed() {
        assert!(Job::from_json(&Json::parse(r#"{"id": 1}"#).unwrap()).is_none());
        assert!(Job::from_json(&Json::parse(r#"{"id": -1, "submit": 0, "cores": 1}"#).unwrap())
            .is_none());
    }

    #[test]
    fn checkpointed_interruption_keeps_progress_and_charges_overhead() {
        let mut j = Job::simple(1, 0, 2, 100);
        j.state = JobState::Queued;
        j.mark_started(SimTime(0));
        assert_eq!(j.incarnation, 1);
        // Evicted at t=40 with 7 ticks of checkpoint+restart overhead.
        j.record_interruption(SimTime(40), true, SimDuration(7));
        assert_eq!(j.remaining, SimDuration(67)); // 100 - 40 + 7
        assert_eq!(j.executed, SimDuration(40));
        assert_eq!(j.overhead, SimDuration(7));
        assert_eq!(j.lost, SimDuration::ZERO);
        // Restart; second segment runs to completion.
        j.state = JobState::Queued;
        j.mark_started(SimTime(200));
        assert_eq!(j.incarnation, 2);
        assert_eq!(j.start, Some(SimTime(0)), "first start preserved");
        assert_eq!(j.last_start, Some(SimTime(200)));
        j.mark_completed(SimTime(200 + 67));
        assert_eq!(j.executed, SimDuration(107));
        // Invariant: executed == runtime + overhead + lost.
        assert_eq!(
            j.executed.ticks(),
            j.runtime.ticks() + j.overhead.ticks() + j.lost.ticks()
        );
    }

    #[test]
    fn killed_interruption_loses_progress() {
        let mut j = Job::simple(1, 0, 4, 50);
        j.state = JobState::Queued;
        j.mark_started(SimTime(10));
        j.record_interruption(SimTime(40), false, SimDuration::ZERO);
        assert_eq!(j.remaining, SimDuration(50), "full runtime must be redone");
        assert_eq!(j.lost, SimDuration(30));
        j.state = JobState::Queued;
        j.mark_started(SimTime(100));
        j.mark_completed(SimTime(150));
        assert_eq!(j.executed, SimDuration(80));
        assert_eq!(
            j.executed.ticks(),
            j.runtime.ticks() + j.overhead.ticks() + j.lost.ticks()
        );
    }

    #[test]
    fn mixed_checkpoint_then_kill_accounting_stays_exact() {
        let mut j = Job::simple(1, 0, 1, 100);
        j.state = JobState::Queued;
        j.mark_started(SimTime(0));
        j.record_interruption(SimTime(20), true, SimDuration(5)); // ckpt
        j.state = JobState::Queued;
        j.mark_started(SimTime(30));
        j.record_interruption(SimTime(60), false, SimDuration::ZERO); // kill
        j.state = JobState::Queued;
        j.mark_started(SimTime(70));
        j.mark_completed(SimTime(170));
        assert_eq!(
            j.executed.ticks(),
            j.runtime.ticks() + j.overhead.ticks() + j.lost.ticks()
        );
    }

    #[test]
    fn est_remaining_switches_after_interruption() {
        let mut j = Job::with_estimate(1, 0, 1, 100, 500);
        assert_eq!(j.est_remaining(), SimDuration(500));
        j.state = JobState::Queued;
        j.mark_started(SimTime(0));
        j.record_interruption(SimTime(30), true, SimDuration(0));
        j.preempt_count += 1; // the driver tags the reason
        assert_eq!(j.est_remaining(), SimDuration(70));
    }

    #[test]
    #[cfg(debug_assertions)]
    fn bad_transition_panics_in_debug() {
        let mut j = Job::simple(1, 0, 1, 1);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            j.mark_completed(SimTime(5)); // never started
        }));
        assert!(r.is_err());
    }
}
