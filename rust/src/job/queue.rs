//! The wait queue: jobs submitted but not yet running.
//!
//! Insertion order is preserved (FCFS order is queue order); scheduling
//! algorithms reorder *views* of the queue, never the queue itself, so
//! algorithm choice cannot corrupt arrival history.

use crate::job::{Job, JobId, JobState};
use std::collections::HashMap;

/// FIFO wait queue with O(1) membership test and by-id removal.
/// `Clone` supports scheduler-state snapshots (`Engine::snapshot`);
/// iteration order is slot order, so a clone walks identically.
#[derive(Debug, Default, Clone)]
pub struct WaitQueue {
    /// Arrival order. Entries are `None` after removal (compacted lazily).
    slots: Vec<Option<Job>>,
    /// job id -> slot index.
    index: HashMap<JobId, usize>,
    /// Number of live entries.
    live: usize,
}

impl WaitQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    pub fn contains(&self, id: JobId) -> bool {
        self.index.contains_key(&id)
    }

    /// Enqueue in arrival order; marks the job `Queued`.
    pub fn push(&mut self, mut job: Job) {
        debug_assert!(!self.contains(job.id), "job {} already queued", job.id);
        job.state = JobState::Queued;
        let slot = self.slots.len();
        self.index.insert(job.id, slot);
        self.slots.push(Some(job));
        self.live += 1;
    }

    /// Remove a job by id (it was scheduled or cancelled).
    pub fn remove(&mut self, id: JobId) -> Option<Job> {
        let slot = self.index.remove(&id)?;
        let job = self.slots[slot].take();
        debug_assert!(job.is_some());
        self.live -= 1;
        self.maybe_compact();
        job
    }

    /// Jobs in arrival order.
    pub fn iter(&self) -> impl Iterator<Item = &Job> {
        self.slots.iter().filter_map(|s| s.as_ref())
    }

    pub fn get(&self, id: JobId) -> Option<&Job> {
        let slot = *self.index.get(&id)?;
        self.slots[slot].as_ref()
    }

    /// First job in arrival order (FCFS head).
    pub fn head(&self) -> Option<&Job> {
        self.iter().next()
    }

    /// Ids in arrival order (snapshot).
    pub fn ids(&self) -> Vec<JobId> {
        self.iter().map(|j| j.id).collect()
    }

    fn maybe_compact(&mut self) {
        // Compact when more than half the slots are dead and the vec is
        // non-trivial; keeps iteration O(live).
        if self.slots.len() >= 64 && self.live * 2 < self.slots.len() {
            let mut fresh: Vec<Option<Job>> = Vec::with_capacity(self.live);
            self.index.clear();
            for s in self.slots.drain(..) {
                if let Some(j) = s {
                    self.index.insert(j.id, fresh.len());
                    fresh.push(Some(j));
                }
            }
            self.slots = fresh;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q_with(ids: &[u64]) -> WaitQueue {
        let mut q = WaitQueue::new();
        for &id in ids {
            q.push(Job::simple(id, id, 1, 10));
        }
        q
    }

    #[test]
    fn preserves_arrival_order() {
        let q = q_with(&[3, 1, 2]);
        assert_eq!(q.ids(), vec![3, 1, 2]);
        assert_eq!(q.head().unwrap().id, 3);
    }

    #[test]
    fn push_marks_queued() {
        let q = q_with(&[1]);
        assert_eq!(q.get(1).unwrap().state, JobState::Queued);
    }

    #[test]
    fn remove_keeps_order() {
        let mut q = q_with(&[1, 2, 3, 4]);
        assert_eq!(q.remove(2).unwrap().id, 2);
        assert_eq!(q.ids(), vec![1, 3, 4]);
        assert_eq!(q.len(), 3);
        assert!(!q.contains(2));
        assert!(q.remove(2).is_none());
    }

    #[test]
    fn compaction_preserves_semantics() {
        let mut q = WaitQueue::new();
        for id in 0..200 {
            q.push(Job::simple(id, id, 1, 1));
        }
        for id in 0..150 {
            q.remove(id);
        }
        assert_eq!(q.len(), 50);
        assert_eq!(q.ids(), (150..200).collect::<Vec<_>>());
        // Everything still reachable by id after compaction.
        for id in 150..200 {
            assert_eq!(q.get(id).unwrap().id, id);
        }
    }

    #[test]
    fn head_after_head_removal() {
        let mut q = q_with(&[5, 6, 7]);
        q.remove(5);
        assert_eq!(q.head().unwrap().id, 6);
    }
}
