//! Configuration system: JSON experiment configs covering workload,
//! platform, scheduler and parallel-run parameters — the equivalent of
//! SST's Python configuration surface, so experiments are declarative
//! and reproducible (`sst-sched run --config experiment.json`).

use crate::sched::Policy;
use crate::trace::{Das2Model, SdscSp2Model, Workload};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};

/// Where jobs come from.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadSource {
    /// DAS-2-like synthetic model.
    Das2,
    /// SDSC-SP2-like synthetic model.
    SdscSp2,
    /// Parallel Workloads Archive file.
    Swf(String),
    /// Grid Workloads Archive file.
    Gwf(String),
}

/// Full experiment configuration.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub source: WorkloadSource,
    /// Jobs to generate (synthetic) or keep (trace prefix); 0 = all.
    pub jobs: usize,
    pub seed: u64,
    /// Inter-arrival scaling (< 1.0 = higher load).
    pub arrival_scale: f64,
    /// Platform override; `None` = the source's native machine.
    pub nodes: Option<usize>,
    pub cores_per_node: Option<u64>,
    pub mem_per_node: u64,
    pub policy: Policy,
    /// "native" or "xla".
    pub accel: String,
    /// Parallel-run parameters.
    pub ranks: usize,
    pub lookahead: u64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            source: WorkloadSource::Das2,
            jobs: 10_000,
            seed: 1,
            arrival_scale: 1.0,
            nodes: None,
            cores_per_node: None,
            mem_per_node: 0,
            policy: Policy::FcfsBackfill,
            accel: "native".to_string(),
            ranks: 1,
            lookahead: 3600,
        }
    }
}

impl ExperimentConfig {
    /// Parse from JSON text.
    pub fn parse(text: &str) -> Result<ExperimentConfig> {
        let v = Json::parse(text).context("parsing experiment config")?;
        let mut cfg = ExperimentConfig::default();
        if let Some(w) = v.get("workload") {
            let kind = w.get_str_or("kind", "das2");
            cfg.source = match kind {
                "das2" => WorkloadSource::Das2,
                "sdsc-sp2" | "sp2" => WorkloadSource::SdscSp2,
                "swf" => WorkloadSource::Swf(
                    w.get("path")
                        .and_then(|p| p.as_str())
                        .context("swf workload needs \"path\"")?
                        .to_string(),
                ),
                "gwf" => WorkloadSource::Gwf(
                    w.get("path")
                        .and_then(|p| p.as_str())
                        .context("gwf workload needs \"path\"")?
                        .to_string(),
                ),
                other => bail!("unknown workload kind {other:?}"),
            };
            cfg.jobs = w.get_u64_or("jobs", cfg.jobs as u64) as usize;
            cfg.seed = w.get_u64_or("seed", cfg.seed);
            cfg.arrival_scale = w.get_f64_or("arrival_scale", cfg.arrival_scale);
        }
        if let Some(p) = v.get("platform") {
            cfg.nodes = p.get("nodes").and_then(|x| x.as_u64()).map(|x| x as usize);
            cfg.cores_per_node = p.get("cores_per_node").and_then(|x| x.as_u64());
            cfg.mem_per_node = p.get_u64_or("mem_per_node", 0);
        }
        if let Some(s) = v.get("scheduler") {
            cfg.policy = s
                .get_str_or("policy", cfg.policy.as_str())
                .parse()
                .map_err(|e: String| anyhow::anyhow!(e))?;
            cfg.accel = s.get_str_or("accel", &cfg.accel).to_string();
            if !matches!(cfg.accel.as_str(), "native" | "xla" | "hybrid") {
                bail!("scheduler.accel must be native|xla|hybrid, got {:?}", cfg.accel);
            }
        }
        if let Some(p) = v.get("parallel") {
            cfg.ranks = p.get_u64_or("ranks", 1) as usize;
            cfg.lookahead = p.get_u64_or("lookahead", 3600);
        }
        Ok(cfg)
    }

    /// Load from a file.
    pub fn load(path: &str) -> Result<ExperimentConfig> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading config {path:?}"))?;
        Self::parse(&text)
    }

    /// Serialize (round-trips through [`ExperimentConfig::parse`]).
    pub fn to_json(&self) -> Json {
        let (kind, path) = match &self.source {
            WorkloadSource::Das2 => ("das2", None),
            WorkloadSource::SdscSp2 => ("sdsc-sp2", None),
            WorkloadSource::Swf(p) => ("swf", Some(p.clone())),
            WorkloadSource::Gwf(p) => ("gwf", Some(p.clone())),
        };
        let mut wl = vec![
            ("kind", Json::str(kind)),
            ("jobs", Json::num(self.jobs as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("arrival_scale", Json::num(self.arrival_scale)),
        ];
        if let Some(p) = path {
            wl.push(("path", Json::str(p)));
        }
        let mut platform = vec![("mem_per_node", Json::num(self.mem_per_node as f64))];
        if let Some(n) = self.nodes {
            platform.push(("nodes", Json::num(n as f64)));
        }
        if let Some(c) = self.cores_per_node {
            platform.push(("cores_per_node", Json::num(c as f64)));
        }
        Json::obj(vec![
            ("workload", Json::obj(wl)),
            ("platform", Json::obj(platform)),
            (
                "scheduler",
                Json::obj(vec![
                    ("policy", Json::str(self.policy.as_str())),
                    ("accel", Json::str(self.accel.clone())),
                ]),
            ),
            (
                "parallel",
                Json::obj(vec![
                    ("ranks", Json::num(self.ranks as f64)),
                    ("lookahead", Json::num(self.lookahead as f64)),
                ]),
            ),
        ])
    }

    /// Materialize the workload this config describes.
    pub fn build_workload(&self) -> Result<Workload> {
        let mut w = match &self.source {
            WorkloadSource::Das2 => Das2Model::default().generate(self.jobs.max(1), self.seed),
            WorkloadSource::SdscSp2 => {
                SdscSp2Model::default().generate(self.jobs.max(1), self.seed)
            }
            WorkloadSource::Swf(path) => {
                let jobs = crate::trace::swf::load_swf_file(path)?;
                let mut wl = Workload::new(path, jobs, 128, 1);
                if self.jobs > 0 {
                    wl = wl.truncate(self.jobs);
                }
                wl
            }
            WorkloadSource::Gwf(path) => {
                let jobs = crate::trace::gwf::load_gwf_file(path)?;
                let mut wl = Workload::new(path, jobs, 72, 2);
                if self.jobs > 0 {
                    wl = wl.truncate(self.jobs);
                }
                wl
            }
        };
        if let Some(n) = self.nodes {
            w.nodes = n;
        }
        if let Some(c) = self.cores_per_node {
            w.cores_per_node = c;
        }
        if (self.arrival_scale - 1.0).abs() > 1e-12 {
            w = w.scale_arrivals(self.arrival_scale);
        }
        Ok(w.drop_infeasible())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "workload": {"kind": "das2", "jobs": 500, "seed": 7, "arrival_scale": 0.8},
        "platform": {"nodes": 64, "cores_per_node": 2, "mem_per_node": 4096},
        "scheduler": {"policy": "fcfs-backfill", "accel": "native"},
        "parallel": {"ranks": 4, "lookahead": 1800}
    }"#;

    #[test]
    fn parses_full_config() {
        let c = ExperimentConfig::parse(SAMPLE).unwrap();
        assert_eq!(c.source, WorkloadSource::Das2);
        assert_eq!(c.jobs, 500);
        assert_eq!(c.seed, 7);
        assert_eq!(c.nodes, Some(64));
        assert_eq!(c.policy, Policy::FcfsBackfill);
        assert_eq!(c.ranks, 4);
        assert_eq!(c.lookahead, 1800);
    }

    #[test]
    fn defaults_for_empty() {
        let c = ExperimentConfig::parse("{}").unwrap();
        assert_eq!(c.jobs, 10_000);
        assert_eq!(c.policy, Policy::FcfsBackfill);
        assert_eq!(c.ranks, 1);
    }

    #[test]
    fn roundtrip() {
        let c = ExperimentConfig::parse(SAMPLE).unwrap();
        let text = c.to_json().to_pretty();
        let back = ExperimentConfig::parse(&text).unwrap();
        assert_eq!(back.jobs, c.jobs);
        assert_eq!(back.nodes, c.nodes);
        assert_eq!(back.policy, c.policy);
        assert_eq!(back.arrival_scale, c.arrival_scale);
    }

    #[test]
    fn build_workload_applies_overrides() {
        let c = ExperimentConfig::parse(SAMPLE).unwrap();
        let w = c.build_workload().unwrap();
        assert_eq!(w.nodes, 64);
        assert_eq!(w.cores_per_node, 2);
        assert!(w.jobs.len() <= 500);
        assert!(!w.jobs.is_empty());
    }

    #[test]
    fn bad_policy_rejected() {
        let e = ExperimentConfig::parse(r#"{"scheduler": {"policy": "magic"}}"#).unwrap_err();
        assert!(e.to_string().contains("magic"));
    }

    #[test]
    fn bad_accel_rejected() {
        assert!(ExperimentConfig::parse(r#"{"scheduler": {"accel": "gpu"}}"#).is_err());
    }

    #[test]
    fn swf_requires_path() {
        assert!(ExperimentConfig::parse(r#"{"workload": {"kind": "swf"}}"#).is_err());
    }
}
