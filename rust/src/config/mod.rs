//! Configuration system: JSON experiment configs covering workload,
//! platform, scheduler and parallel-run parameters — the equivalent of
//! SST's Python configuration surface, so experiments are declarative
//! and reproducible (`sst-sched run --config experiment.json`).

use crate::core::time::SimDuration;
use crate::sched::{OrderKind, Policy, PreemptionConfig};
use crate::sim::{
    AutoHorizonParams, FaultConfig, Horizon, ReservationSpec, Routing,
    DEFAULT_FAIRSHARE_HALF_LIFE,
};
use crate::trace::{Das2Model, SdscSp2Model, TraceFormat, Workload};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};

/// Where jobs come from.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadSource {
    /// DAS-2-like synthetic model.
    Das2,
    /// SDSC-SP2-like synthetic model.
    SdscSp2,
    /// Parallel Workloads Archive file.
    Swf(String),
    /// Grid Workloads Archive file.
    Gwf(String),
    /// Compact binary trace (see `crate::trace::stf`); always read
    /// through the byte scanner, machine taken from the file header.
    Stf(String),
}

/// Write-ahead-journal durability policy for the serve daemon
/// (`serve.durability` / `serve --durability`): how hard the daemon
/// tries to make each journaled request survive a crash. The full cost
/// model lives in `crate::runtime::journal`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Durability {
    /// `fsync` every record before applying its request; an
    /// acknowledged request survives any crash.
    Strict,
    /// Write every record to the OS immediately, `fsync` in batches;
    /// a process crash loses nothing, a machine crash at most one
    /// batch. The default.
    #[default]
    Batched,
    /// Buffer in user space, flush opportunistically; fastest, and a
    /// crash loses the buffered tail (bounded by mark compaction,
    /// which is always durable).
    Off,
}

impl Durability {
    /// Canonical config-file spelling.
    pub fn as_str(self) -> &'static str {
        match self {
            Durability::Strict => "strict",
            Durability::Batched => "batched",
            Durability::Off => "off",
        }
    }
}

impl std::fmt::Display for Durability {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for Durability {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "strict" => Ok(Durability::Strict),
            "batched" => Ok(Durability::Batched),
            "off" => Ok(Durability::Off),
            other => {
                Err(format!("unknown durability {other:?} (expected strict|batched|off)"))
            }
        }
    }
}

/// `sst-sched serve` daemon parameters (`serve.*` in the config file;
/// `--socket`, `--max-sims`, `--queue-depth`, `--state-dir`,
/// `--durability`, `--mark-interval` on the CLI).
#[derive(Debug, Clone, PartialEq)]
pub struct ServeOptions {
    /// Unix socket path the daemon binds (and unlinks on exit).
    pub socket: String,
    /// Admission control: maximum concurrently hosted simulations; a
    /// request that would create one more is refused with a `sim_limit`
    /// error instead of growing without bound.
    pub max_sims: usize,
    /// Per-connection bounded request-queue depth; when the queue is
    /// full the daemon replies with an explicit `backpressure` error
    /// rather than buffering (or silently dropping) the request.
    pub queue_depth: usize,
    /// Directory holding the write-ahead journal (`journal.sstj`).
    /// `None` (the default) keeps the daemon purely in-memory — a crash
    /// or restart loses every hosted sim, exactly the pre-journal
    /// behavior.
    pub state_dir: Option<String>,
    /// Journal durability policy; inert without `state_dir`.
    pub durability: Durability,
    /// Submits between `MARK` compaction checkpoints; 0 disables
    /// marking (the journal grows unboundedly — `sst-sched check`
    /// flags it). Inert without `state_dir`.
    pub mark_interval: u64,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            socket: "sst-sched.sock".to_string(),
            max_sims: 8,
            queue_depth: 64,
            state_dir: None,
            durability: Durability::Batched,
            mark_interval: 256,
        }
    }
}

/// Full experiment configuration.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub source: WorkloadSource,
    /// Jobs to generate (synthetic) or keep (trace prefix); 0 = all.
    pub jobs: usize,
    pub seed: u64,
    /// Inter-arrival scaling (< 1.0 = higher load).
    pub arrival_scale: f64,
    /// Platform override; `None` = the source's native machine.
    pub nodes: Option<usize>,
    pub cores_per_node: Option<u64>,
    pub mem_per_node: u64,
    pub policy: Policy,
    /// Queue-ordering override (`scheduler.order`); `None` = the
    /// policy's natural order (SJF = shortest-first, everything else =
    /// arrival).
    pub order: Option<OrderKind>,
    /// Fair-share usage-decay half-life in ticks (`fairshare.half_life`).
    pub fairshare_half_life: u64,
    /// Plan memory as a second availability-timeline dimension
    /// (`scheduler.memory_aware` / `--memory-aware`); needs
    /// `mem_per_node > 0` to have any effect.
    pub memory_aware: bool,
    /// "native" or "xla".
    pub accel: String,
    /// Parallel-run parameters.
    pub ranks: usize,
    pub lookahead: u64,
    /// Sharded federation engine (`federation.shards` / `--shards`):
    /// worker shards for the multi-domain run; 0 = off (single-cluster
    /// simulation).
    pub shards: usize,
    /// Meta-scheduler routing policy (`federation.routing`).
    pub routing: Routing,
    /// Router -> domain delivery latency in ticks
    /// (`federation.route_latency`); doubles as the conservative
    /// lookahead, so it must be >= 1.
    pub route_latency: u64,
    /// Node failure model (`faults.*`); disabled by default.
    pub faults: FaultConfig,
    /// Preemption layer (`preemption.*`); mode `none` by default.
    pub preemption: PreemptionConfig,
    /// Ingest text traces through the zero-copy byte scanner
    /// (`workload.fast_parse` / `--fast-parse`) instead of the scalar
    /// line parser. Same records, same order, same first-error message
    /// — the differential suite in `tests/prop_fastparse.rs` is the
    /// contract. `.stf` traces always use the scanner regardless.
    pub fast_parse: bool,
    /// Assign derived per-user priority bands (`job.user % bands`) to
    /// the loaded workload (`preemption.priority_bands`). Trace formats
    /// (SWF/GWF) carry no priorities, so priority-aware eviction is
    /// inert on them without this; 0 leaves priorities untouched (the
    /// synthetic models ship 3 bands of their own).
    pub priority_bands: u8,
    /// Advance reservations (`reservations[]`).
    pub reservations: Vec<ReservationSpec>,
    /// Availability-timeline planning-horizon policy
    /// (`planning.horizon`): a tick count (0 = unlimited, exact
    /// timeline), `"exact"`, or `"auto"` (clamp derived from live queue
    /// depth and median runtime estimate).
    pub planning_horizon: Horizon,
    /// `Horizon::Auto` tunables (`planning.auto_shallow_queue`,
    /// `planning.auto_horizon_estimates`, `planning.auto_min_horizon`);
    /// defaults are the engine constants. Inert unless
    /// `planning.horizon` is `"auto"`.
    pub auto_horizon: AutoHorizonParams,
    /// `sst-sched serve` daemon parameters (`serve.*`); inert for every
    /// other command.
    pub serve: ServeOptions,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            source: WorkloadSource::Das2,
            jobs: 10_000,
            seed: 1,
            arrival_scale: 1.0,
            nodes: None,
            cores_per_node: None,
            mem_per_node: 0,
            policy: Policy::FcfsBackfill,
            order: None,
            fairshare_half_life: DEFAULT_FAIRSHARE_HALF_LIFE,
            memory_aware: false,
            accel: "native".to_string(),
            ranks: 1,
            lookahead: 3600,
            shards: 0,
            routing: Routing::LeastLoaded,
            route_latency: 60,
            faults: FaultConfig::default(),
            preemption: PreemptionConfig::default(),
            fast_parse: false,
            priority_bands: 0,
            reservations: Vec::new(),
            planning_horizon: Horizon::Exact,
            auto_horizon: AutoHorizonParams::default(),
            serve: ServeOptions::default(),
        }
    }
}

impl ExperimentConfig {
    /// Parse from JSON text.
    pub fn parse(text: &str) -> Result<ExperimentConfig> {
        let v = Json::parse(text).context("parsing experiment config")?;
        let mut cfg = ExperimentConfig::default();
        if let Some(w) = v.get("workload") {
            let kind = w.get_str_or("kind", "das2");
            cfg.source = match kind {
                "das2" => WorkloadSource::Das2,
                "sdsc-sp2" | "sp2" => WorkloadSource::SdscSp2,
                "swf" => WorkloadSource::Swf(
                    w.get("path")
                        .and_then(|p| p.as_str())
                        .context("swf workload needs \"path\"")?
                        .to_string(),
                ),
                "gwf" => WorkloadSource::Gwf(
                    w.get("path")
                        .and_then(|p| p.as_str())
                        .context("gwf workload needs \"path\"")?
                        .to_string(),
                ),
                "stf" => WorkloadSource::Stf(
                    w.get("path")
                        .and_then(|p| p.as_str())
                        .context("stf workload needs \"path\"")?
                        .to_string(),
                ),
                other => bail!("unknown workload kind {other:?}"),
            };
            cfg.jobs = w.get_u64_or("jobs", cfg.jobs as u64) as usize;
            cfg.seed = w.get_u64_or("seed", cfg.seed);
            cfg.arrival_scale = w.get_f64_or("arrival_scale", cfg.arrival_scale);
            cfg.fast_parse = w.get_bool_or("fast_parse", cfg.fast_parse);
        }
        if let Some(p) = v.get("platform") {
            cfg.nodes = p.get("nodes").and_then(|x| x.as_u64()).map(|x| x as usize);
            cfg.cores_per_node = p.get("cores_per_node").and_then(|x| x.as_u64());
            cfg.mem_per_node = p.get_u64_or("mem_per_node", 0);
        }
        if let Some(s) = v.get("scheduler") {
            cfg.policy = s
                .get_str_or("policy", cfg.policy.as_str())
                .parse()
                .map_err(|e: String| anyhow::anyhow!(e))?;
            if let Some(o) = s.get("order").and_then(|x| x.as_str()) {
                cfg.order = Some(o.parse().map_err(|e: String| anyhow::anyhow!(e))?);
            }
            cfg.memory_aware = s.get_bool_or("memory_aware", cfg.memory_aware);
            cfg.accel = s.get_str_or("accel", &cfg.accel).to_string();
            if !matches!(cfg.accel.as_str(), "native" | "xla" | "hybrid") {
                bail!("scheduler.accel must be native|xla|hybrid, got {:?}", cfg.accel);
            }
        }
        if let Some(fs) = v.get("fairshare") {
            cfg.fairshare_half_life = fs.get_u64_or("half_life", cfg.fairshare_half_life);
            if cfg.fairshare_half_life == 0 {
                bail!("fairshare.half_life must be > 0 (0 would disable usage decay entirely)");
            }
        }
        if let Some(p) = v.get("parallel") {
            cfg.ranks = p.get_u64_or("ranks", 1) as usize;
            cfg.lookahead = p.get_u64_or("lookahead", 3600);
        }
        if let Some(fed) = v.get("federation") {
            cfg.shards = fed.get_u64_or("shards", cfg.shards as u64) as usize;
            cfg.routing = fed
                .get_str_or("routing", cfg.routing.as_str())
                .parse()
                .map_err(|e: String| anyhow::anyhow!(e))?;
            cfg.route_latency = fed.get_u64_or("route_latency", cfg.route_latency);
            if cfg.route_latency == 0 {
                bail!("federation.route_latency must be >= 1 (it is the conservative lookahead)");
            }
        }
        if let Some(fj) = v.get("faults") {
            cfg.faults.mtbf = fj.get_f64_or("mtbf", 0.0);
            cfg.faults.mttr = fj.get_f64_or("mttr", cfg.faults.mttr);
            cfg.faults.seed = fj.get_u64_or("seed", cfg.faults.seed);
            cfg.faults.until = fj.get("until").and_then(|x| x.as_u64());
            cfg.faults.distribution = fj
                .get_str_or("distribution", cfg.faults.distribution.as_str())
                .parse()
                .map_err(|e: String| anyhow::anyhow!(e))?;
            cfg.faults.shape = fj.get_f64_or("shape", cfg.faults.shape);
            if cfg.faults.mtbf < 0.0 || cfg.faults.mttr <= 0.0 {
                bail!("faults.mtbf must be >= 0 and faults.mttr > 0");
            }
            // Below ~0.1 the derived Weibull scale (mtbf / Γ(1 + 1/k))
            // collapses toward zero and the 1-tick gap floor turns the
            // model into a failure storm; real HPC fits are ~0.7-0.8.
            if cfg.faults.shape < 0.1 {
                bail!("faults.shape must be >= 0.1 (got {})", cfg.faults.shape);
            }
        }
        if let Some(pl) = v.get("planning") {
            if let Some(h) = pl.get("horizon") {
                cfg.planning_horizon = match h {
                    Json::Num(_) => Horizon::fixed(h.as_u64().context(
                        "planning.horizon must be a non-negative integer, \"auto\" or \"exact\"",
                    )?),
                    Json::Str(s) => s.parse().map_err(|e: String| anyhow::anyhow!(e))?,
                    _ => bail!("planning.horizon must be a number or \"auto\"/\"exact\""),
                };
            }
            // Auto-horizon tunables; the engine constants stay the
            // defaults (they were engineering picks — see ROADMAP).
            cfg.auto_horizon.shallow_queue =
                pl.get_u64_or("auto_shallow_queue", cfg.auto_horizon.shallow_queue as u64)
                    as usize;
            cfg.auto_horizon.estimates =
                pl.get_u64_or("auto_horizon_estimates", cfg.auto_horizon.estimates);
            cfg.auto_horizon.min_horizon =
                pl.get_u64_or("auto_min_horizon", cfg.auto_horizon.min_horizon);
            if cfg.auto_horizon.estimates == 0 {
                bail!("planning.auto_horizon_estimates must be >= 1 (0 would clamp the \
                       timeline to the floor alone)");
            }
        }
        if let Some(pj) = v.get("preemption") {
            cfg.preemption.mode = pj
                .get_str_or("mode", "none")
                .parse()
                .map_err(|e: String| anyhow::anyhow!(e))?;
            cfg.preemption.checkpoint_overhead =
                SimDuration(pj.get_u64_or("checkpoint_overhead", 0));
            cfg.preemption.restart_overhead = SimDuration(pj.get_u64_or("restart_overhead", 0));
            cfg.preemption.starvation_threshold =
                SimDuration(pj.get_u64_or("starvation_threshold", 0));
            cfg.priority_bands = pj.get_u64_or("priority_bands", 0) as u8;
        }
        if let Some(sv) = v.get("serve") {
            cfg.serve.socket = sv.get_str_or("socket", &cfg.serve.socket).to_string();
            cfg.serve.max_sims = sv.get_u64_or("max_sims", cfg.serve.max_sims as u64) as usize;
            cfg.serve.queue_depth =
                sv.get_u64_or("queue_depth", cfg.serve.queue_depth as u64) as usize;
            if cfg.serve.max_sims == 0 {
                bail!("serve.max_sims must be >= 1 (0 would refuse every simulation)");
            }
            if cfg.serve.queue_depth == 0 {
                bail!(
                    "serve.queue_depth must be >= 1 (it bounds the per-connection \
                     request queue)"
                );
            }
            cfg.serve.state_dir =
                sv.get("state_dir").and_then(|x| x.as_str()).map(|s| s.to_string());
            cfg.serve.durability = sv
                .get_str_or("durability", cfg.serve.durability.as_str())
                .parse()
                .map_err(|e: String| anyhow::anyhow!(e))?;
            cfg.serve.mark_interval = sv.get_u64_or("mark_interval", cfg.serve.mark_interval);
        }
        if let Some(rj) = v.get("reservations").and_then(|r| r.as_arr()) {
            for (i, r) in rj.iter().enumerate() {
                let nodes = r.get_u64_or("nodes", 0) as usize;
                let duration = r.get_u64_or("duration", 0);
                if nodes == 0 || duration == 0 {
                    bail!("reservations[{i}] needs nonzero \"nodes\" and \"duration\"");
                }
                cfg.reservations.push(ReservationSpec {
                    start: r.get_u64_or("start", 0),
                    duration,
                    nodes,
                });
            }
        }
        Ok(cfg)
    }

    /// Load from a file.
    pub fn load(path: &str) -> Result<ExperimentConfig> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading config {path:?}"))?;
        Self::parse(&text)
    }

    /// Static semantic validation for `sst-sched check`: problems a
    /// structurally valid config can still have, collected in one pass
    /// — every finding is reported at once, never fail-fast, so one
    /// `check` run fixes one config. Structural errors (unparseable
    /// JSON, unknown enum values, hard range violations) still surface
    /// through [`ExperimentConfig::parse`]'s error.
    pub fn check(text: &str) -> Result<Vec<String>> {
        let cfg = Self::parse(text)?;
        let v = Json::parse(text).expect("validated by parse above");
        let mut findings = Vec::new();

        // -- workload --------------------------------------------------
        if cfg.arrival_scale <= 0.0 || cfg.arrival_scale.is_nan() {
            findings.push(format!(
                "workload.arrival_scale must be > 0 (got {}): scaling arrivals by it \
                 would collapse every submit time",
                cfg.arrival_scale
            ));
        }
        let trace = match &cfg.source {
            WorkloadSource::Swf(p) => Some((p.as_str(), "swf")),
            WorkloadSource::Gwf(p) => Some((p.as_str(), "gwf")),
            WorkloadSource::Stf(p) => Some((p.as_str(), "stf")),
            WorkloadSource::Das2 | WorkloadSource::SdscSp2 => None,
        };
        if let Some((path, want)) = trace {
            let ext = std::path::Path::new(path)
                .extension()
                .and_then(|e| e.to_str())
                .map(|e| e.to_ascii_lowercase());
            if let Some(ext) = ext {
                if ext != want && ["swf", "gwf", "stf"].contains(&ext.as_str()) {
                    findings.push(format!(
                        "workload: kind is \"{want}\" but path {path:?} ends in .{ext} — \
                         trace format mismatch?"
                    ));
                }
            }
            if !std::path::Path::new(path).exists() {
                findings.push(format!("workload.path {path:?} does not exist"));
            }
        }

        // -- reservations vs machine size ------------------------------
        // `.stf` machines live in the file header, so without a platform
        // override there is no static size to check against.
        let machine_nodes = cfg.nodes.or(match &cfg.source {
            WorkloadSource::Das2 => Some(Das2Model::default().nodes),
            WorkloadSource::SdscSp2 => Some(SdscSp2Model::default().nodes),
            WorkloadSource::Swf(_) => Some(TraceFormat::Swf.default_machine().0),
            WorkloadSource::Gwf(_) => Some(TraceFormat::Gwf.default_machine().0),
            WorkloadSource::Stf(_) => None,
        });
        if let Some(n) = machine_nodes {
            for (i, r) in cfg.reservations.iter().enumerate() {
                if r.nodes > n {
                    findings.push(format!(
                        "reservations[{i}]: wants {} nodes but the machine has {n}",
                        r.nodes
                    ));
                }
            }
            // Sweep the window edges: at any instant the concurrently
            // reserved node count must fit the machine. Releases sort
            // before claims at the same tick (windows are end-exclusive).
            let mut edges: Vec<(u64, i64)> = Vec::new();
            for r in &cfg.reservations {
                edges.push((r.start, r.nodes as i64));
                edges.push((r.start.saturating_add(r.duration), -(r.nodes as i64)));
            }
            edges.sort_unstable();
            let mut active = 0i64;
            let mut worst = (0u64, 0i64);
            for (t, d) in edges {
                active += d;
                if active > worst.1 {
                    worst = (t, active);
                }
            }
            if worst.1 > n as i64 {
                findings.push(format!(
                    "reservations: {} nodes reserved concurrently at t={} but the \
                     machine has {n}",
                    worst.1, worst.0
                ));
            }
        }

        // -- faults ----------------------------------------------------
        if cfg.faults.enabled() {
            if cfg.faults.mtbf < cfg.faults.mttr {
                findings.push(format!(
                    "faults: mtbf {} < mttr {} — nodes spend more time under repair \
                     than in service; is this intended?",
                    cfg.faults.mtbf, cfg.faults.mttr
                ));
            }
            if cfg.faults.until == Some(0) {
                findings.push(
                    "faults.until = 0 disables injection entirely; drop the key or the \
                     faults section"
                        .to_string(),
                );
            }
        }

        // -- federation ------------------------------------------------
        if v.get("federation").is_some() && cfg.shards == 0 {
            findings.push(
                "federation: section present but shards = 0 keeps the sharded engine \
                 off; set federation.shards >= 1"
                    .to_string(),
            );
        }
        if cfg.shards > 0 && cfg.ranks > 1 {
            findings.push(format!(
                "federation.shards = {} and parallel.ranks = {} select two different \
                 parallel engines; pick one",
                cfg.shards, cfg.ranks
            ));
        }

        // -- scheduler knobs that silently do nothing ------------------
        if cfg.memory_aware && cfg.mem_per_node == 0 {
            findings.push(
                "scheduler.memory_aware = true has no effect with \
                 platform.mem_per_node = 0"
                    .to_string(),
            );
        }
        if cfg.priority_bands > 0 && !cfg.preemption.enabled() {
            findings.push(
                "preemption.priority_bands is set but preemption.mode = \"none\" — \
                 bands are assigned and never consulted"
                    .to_string(),
            );
        }

        // -- serve persistence -----------------------------------------
        if let Some(dir) = &cfg.serve.state_dir {
            let dirp = std::path::Path::new(dir);
            if dirp.exists() {
                if !dirp.is_dir() {
                    findings.push(format!(
                        "serve.state_dir {dir:?} exists but is not a directory"
                    ));
                } else {
                    if std::fs::metadata(dirp)
                        .map(|m| m.permissions().readonly())
                        .unwrap_or(false)
                    {
                        findings.push(format!(
                            "serve.state_dir {dir:?} is not writable — the daemon \
                             cannot append its journal there"
                        ));
                    }
                    let jpath = dirp.join(crate::runtime::journal::FILE_NAME);
                    if jpath.exists() {
                        match crate::runtime::journal::peek_header(&jpath) {
                            Ok(h) if h != cfg.semantic_hash() => findings.push(format!(
                                "serve.state_dir: journal {jpath:?} was written under a \
                                 different experiment config (header hash {h:016x}, this \
                                 config {:016x}) — `serve --resume` will refuse it",
                                cfg.semantic_hash()
                            )),
                            Ok(_) => {}
                            Err(e) => findings.push(format!(
                                "serve.state_dir: journal {jpath:?} is unreadable: {e:#}"
                            )),
                        }
                    }
                }
            } else if let Some(p) = dirp.parent() {
                if !p.as_os_str().is_empty() && !p.exists() {
                    findings.push(format!(
                        "serve.state_dir {dir:?}: parent directory {p:?} does not \
                         exist — likely a typo"
                    ));
                }
            }
            if cfg.serve.mark_interval == 0 {
                findings.push(
                    "serve.mark_interval = 0 disables MARK compaction — the journal \
                     grows without bound; set an interval or drop the key for the \
                     default"
                        .to_string(),
                );
            }
        } else {
            let d = ServeOptions::default();
            if cfg.serve.durability != d.durability || cfg.serve.mark_interval != d.mark_interval
            {
                findings.push(
                    "serve.durability / serve.mark_interval are set but \
                     serve.state_dir is not — journaling is off, so they do nothing"
                        .to_string(),
                );
            }
        }
        Ok(findings)
    }

    /// FNV-1a digest of the config's *scheduling-relevant* surface: the
    /// serialized config minus the `serve` block, so two configs that
    /// differ only in daemon plumbing (socket path, queue depth,
    /// durability knobs) hash identically. This is the hash a journal
    /// header records — resuming needs the same simulation semantics,
    /// not the same socket. Stable because [`ExperimentConfig::to_json`]
    /// serializes through a `BTreeMap` (sorted keys, deterministic
    /// number formatting).
    pub fn semantic_hash(&self) -> u64 {
        let mut j = self.to_json();
        if let Json::Obj(map) = &mut j {
            map.remove("serve");
        }
        crate::parallel::fnv1a(j.to_string().as_bytes())
    }

    /// Serialize (round-trips through [`ExperimentConfig::parse`]).
    pub fn to_json(&self) -> Json {
        let (kind, path) = match &self.source {
            WorkloadSource::Das2 => ("das2", None),
            WorkloadSource::SdscSp2 => ("sdsc-sp2", None),
            WorkloadSource::Swf(p) => ("swf", Some(p.clone())),
            WorkloadSource::Gwf(p) => ("gwf", Some(p.clone())),
            WorkloadSource::Stf(p) => ("stf", Some(p.clone())),
        };
        let mut wl = vec![
            ("kind", Json::str(kind)),
            ("jobs", Json::num(self.jobs as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("arrival_scale", Json::num(self.arrival_scale)),
        ];
        if let Some(p) = path {
            wl.push(("path", Json::str(p)));
        }
        if self.fast_parse {
            wl.push(("fast_parse", Json::Bool(true)));
        }
        let mut platform = vec![("mem_per_node", Json::num(self.mem_per_node as f64))];
        if let Some(n) = self.nodes {
            platform.push(("nodes", Json::num(n as f64)));
        }
        if let Some(c) = self.cores_per_node {
            platform.push(("cores_per_node", Json::num(c as f64)));
        }
        let mut sched = vec![
            ("policy", Json::str(self.policy.as_str())),
            ("accel", Json::str(self.accel.clone())),
        ];
        if let Some(o) = self.order {
            sched.push(("order", Json::str(o.as_str())));
        }
        if self.memory_aware {
            sched.push(("memory_aware", Json::Bool(true)));
        }
        let mut top = vec![
            ("workload", Json::obj(wl)),
            ("platform", Json::obj(platform)),
            ("scheduler", Json::obj(sched)),
            (
                "parallel",
                Json::obj(vec![
                    ("ranks", Json::num(self.ranks as f64)),
                    ("lookahead", Json::num(self.lookahead as f64)),
                ]),
            ),
        ];
        if self.shards > 0 {
            top.push((
                "federation",
                Json::obj(vec![
                    ("shards", Json::num(self.shards as f64)),
                    ("routing", Json::str(self.routing.as_str())),
                    ("route_latency", Json::num(self.route_latency as f64)),
                ]),
            ));
        }
        if self.faults.enabled() {
            let mut fj = vec![
                ("mtbf", Json::num(self.faults.mtbf)),
                ("mttr", Json::num(self.faults.mttr)),
                ("seed", Json::num(self.faults.seed as f64)),
                ("distribution", Json::str(self.faults.distribution.as_str())),
                ("shape", Json::num(self.faults.shape)),
            ];
            if let Some(u) = self.faults.until {
                fj.push(("until", Json::num(u as f64)));
            }
            top.push(("faults", Json::obj(fj)));
        }
        let mut planning = Vec::new();
        match self.planning_horizon {
            Horizon::Exact => {}
            Horizon::Fixed(t) => planning.push(("horizon", Json::num(t as f64))),
            Horizon::Auto => planning.push(("horizon", Json::str("auto"))),
        }
        let auto_defaults = AutoHorizonParams::default();
        if self.auto_horizon.shallow_queue != auto_defaults.shallow_queue {
            planning.push((
                "auto_shallow_queue",
                Json::num(self.auto_horizon.shallow_queue as f64),
            ));
        }
        if self.auto_horizon.estimates != auto_defaults.estimates {
            planning
                .push(("auto_horizon_estimates", Json::num(self.auto_horizon.estimates as f64)));
        }
        if self.auto_horizon.min_horizon != auto_defaults.min_horizon {
            planning.push(("auto_min_horizon", Json::num(self.auto_horizon.min_horizon as f64)));
        }
        if !planning.is_empty() {
            top.push(("planning", Json::obj(planning)));
        }
        if self.fairshare_half_life != DEFAULT_FAIRSHARE_HALF_LIFE {
            top.push((
                "fairshare",
                Json::obj(vec![("half_life", Json::num(self.fairshare_half_life as f64))]),
            ));
        }
        if self.preemption.enabled() {
            top.push((
                "preemption",
                Json::obj(vec![
                    ("mode", Json::str(self.preemption.mode.as_str())),
                    (
                        "checkpoint_overhead",
                        Json::num(self.preemption.checkpoint_overhead.ticks() as f64),
                    ),
                    (
                        "restart_overhead",
                        Json::num(self.preemption.restart_overhead.ticks() as f64),
                    ),
                    (
                        "starvation_threshold",
                        Json::num(self.preemption.starvation_threshold.ticks() as f64),
                    ),
                    ("priority_bands", Json::num(self.priority_bands as f64)),
                ]),
            ));
        }
        if self.serve != ServeOptions::default() {
            let mut sv = vec![
                ("durability", Json::str(self.serve.durability.as_str())),
                ("mark_interval", Json::num(self.serve.mark_interval as f64)),
                ("max_sims", Json::num(self.serve.max_sims as f64)),
                ("queue_depth", Json::num(self.serve.queue_depth as f64)),
                ("socket", Json::str(self.serve.socket.clone())),
            ];
            if let Some(d) = &self.serve.state_dir {
                sv.push(("state_dir", Json::str(d.clone())));
            }
            top.push(("serve", Json::obj(sv)));
        }
        if !self.reservations.is_empty() {
            top.push((
                "reservations",
                Json::Arr(
                    self.reservations
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("start", Json::num(r.start as f64)),
                                ("duration", Json::num(r.duration as f64)),
                                ("nodes", Json::num(r.nodes as f64)),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        Json::obj(top)
    }

    /// Materialize the workload this config describes.
    pub fn build_workload(&self) -> Result<Workload> {
        let mut w = match &self.source {
            WorkloadSource::Das2 => Das2Model::default().generate(self.jobs.max(1), self.seed),
            WorkloadSource::SdscSp2 => {
                SdscSp2Model::default().generate(self.jobs.max(1), self.seed)
            }
            WorkloadSource::Swf(path) => self.trace_workload(path, crate::trace::TraceFormat::Swf)?,
            WorkloadSource::Gwf(path) => self.trace_workload(path, crate::trace::TraceFormat::Gwf)?,
            WorkloadSource::Stf(path) => self.trace_workload(path, crate::trace::TraceFormat::Stf)?,
        };
        if let Some(n) = self.nodes {
            w.nodes = n;
        }
        if let Some(c) = self.cores_per_node {
            w.cores_per_node = c;
        }
        if (self.arrival_scale - 1.0).abs() > 1e-12 {
            w = w.scale_arrivals(self.arrival_scale);
        }
        if self.priority_bands > 0 {
            for j in w.jobs.iter_mut() {
                j.priority = (j.user % self.priority_bands as u32) as u8;
            }
        }
        Ok(w.drop_infeasible())
    }

    /// Load a trace file eagerly. Text formats use the scalar line
    /// parsers unless `fast_parse` is set; `.stf` always goes through
    /// the byte scanner and takes its machine from the file header.
    /// Either way the job sequence is identical (the parity contract).
    fn trace_workload(&self, path: &str, format: crate::trace::TraceFormat) -> Result<Workload> {
        use crate::trace::TraceFormat;
        let (jobs, (nodes, cores)) = if self.fast_parse || format == TraceFormat::Stf {
            let trace = crate::trace::FastTrace::open_as(path, format)?;
            let machine = trace.machine();
            (trace.parse()?, machine)
        } else {
            let jobs = match format {
                TraceFormat::Swf => crate::trace::swf::load_swf_file(path)?,
                TraceFormat::Gwf => crate::trace::gwf::load_gwf_file(path)?,
                TraceFormat::Stf => unreachable!("stf is routed to the byte scanner above"),
            };
            (jobs, format.default_machine())
        };
        let mut wl = Workload::new(path, jobs, nodes, cores);
        if self.jobs > 0 {
            wl = wl.truncate(self.jobs);
        }
        Ok(wl)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "workload": {"kind": "das2", "jobs": 500, "seed": 7, "arrival_scale": 0.8},
        "platform": {"nodes": 64, "cores_per_node": 2, "mem_per_node": 4096},
        "scheduler": {"policy": "fcfs-backfill", "accel": "native"},
        "parallel": {"ranks": 4, "lookahead": 1800}
    }"#;

    #[test]
    fn parses_full_config() {
        let c = ExperimentConfig::parse(SAMPLE).unwrap();
        assert_eq!(c.source, WorkloadSource::Das2);
        assert_eq!(c.jobs, 500);
        assert_eq!(c.seed, 7);
        assert_eq!(c.nodes, Some(64));
        assert_eq!(c.policy, Policy::FcfsBackfill);
        assert_eq!(c.ranks, 4);
        assert_eq!(c.lookahead, 1800);
    }

    #[test]
    fn defaults_for_empty() {
        let c = ExperimentConfig::parse("{}").unwrap();
        assert_eq!(c.jobs, 10_000);
        assert_eq!(c.policy, Policy::FcfsBackfill);
        assert_eq!(c.ranks, 1);
    }

    #[test]
    fn roundtrip() {
        let c = ExperimentConfig::parse(SAMPLE).unwrap();
        let text = c.to_json().to_pretty();
        let back = ExperimentConfig::parse(&text).unwrap();
        assert_eq!(back.jobs, c.jobs);
        assert_eq!(back.nodes, c.nodes);
        assert_eq!(back.policy, c.policy);
        assert_eq!(back.arrival_scale, c.arrival_scale);
    }

    #[test]
    fn build_workload_applies_overrides() {
        let c = ExperimentConfig::parse(SAMPLE).unwrap();
        let w = c.build_workload().unwrap();
        assert_eq!(w.nodes, 64);
        assert_eq!(w.cores_per_node, 2);
        assert!(w.jobs.len() <= 500);
        assert!(!w.jobs.is_empty());
    }

    #[test]
    fn order_and_memory_surface_roundtrips() {
        let c = ExperimentConfig::parse(
            r#"{
                "platform": {"mem_per_node": 4096},
                "scheduler": {"policy": "cons-backfill", "order": "fair-share",
                              "memory_aware": true},
                "fairshare": {"half_life": 7200}
            }"#,
        )
        .unwrap();
        assert_eq!(c.order, Some(OrderKind::FairShare));
        assert!(c.memory_aware);
        assert_eq!(c.fairshare_half_life, 7200);
        let back = ExperimentConfig::parse(&c.to_json().to_pretty()).unwrap();
        assert_eq!(back.order, c.order);
        assert_eq!(back.memory_aware, c.memory_aware);
        assert_eq!(back.fairshare_half_life, c.fairshare_half_life);
        assert_eq!(back.mem_per_node, 4096);
        // Defaults: no override, no memory awareness, day half-life.
        let d = ExperimentConfig::parse("{}").unwrap();
        assert_eq!(d.order, None);
        assert!(!d.memory_aware);
        assert_eq!(d.fairshare_half_life, DEFAULT_FAIRSHARE_HALF_LIFE);
        // Validation.
        assert!(ExperimentConfig::parse(r#"{"scheduler": {"order": "random"}}"#).is_err());
        assert!(ExperimentConfig::parse(r#"{"fairshare": {"half_life": 0}}"#).is_err());
    }

    #[test]
    fn bad_policy_rejected() {
        let e = ExperimentConfig::parse(r#"{"scheduler": {"policy": "magic"}}"#).unwrap_err();
        assert!(e.to_string().contains("magic"));
    }

    #[test]
    fn bad_accel_rejected() {
        assert!(ExperimentConfig::parse(r#"{"scheduler": {"accel": "gpu"}}"#).is_err());
    }

    #[test]
    fn swf_requires_path() {
        assert!(ExperimentConfig::parse(r#"{"workload": {"kind": "swf"}}"#).is_err());
        assert!(ExperimentConfig::parse(r#"{"workload": {"kind": "stf"}}"#).is_err());
    }

    #[test]
    fn stf_and_fast_parse_roundtrip() {
        let c = ExperimentConfig::parse(
            r#"{"workload": {"kind": "stf", "path": "t.stf", "fast_parse": true}}"#,
        )
        .unwrap();
        assert_eq!(c.source, WorkloadSource::Stf("t.stf".to_string()));
        assert!(c.fast_parse);
        let back = ExperimentConfig::parse(&c.to_json().to_pretty()).unwrap();
        assert_eq!(back.source, c.source);
        assert!(back.fast_parse);
        // Default: scalar parsing, not emitted.
        let d = ExperimentConfig::parse("{}").unwrap();
        assert!(!d.fast_parse);
        assert!(d.to_json().get("workload").unwrap().get("fast_parse").is_none());
    }

    const FAULTY: &str = r#"{
        "workload": {"kind": "sdsc-sp2", "jobs": 200, "seed": 3},
        "faults": {"mtbf": 40000, "mttr": 1800, "seed": 99, "until": 500000,
                   "distribution": "weibull", "shape": 0.8},
        "preemption": {"mode": "checkpoint", "checkpoint_overhead": 60,
                       "restart_overhead": 30, "starvation_threshold": 7200,
                       "priority_bands": 4},
        "reservations": [{"start": 1000, "duration": 5000, "nodes": 8}],
        "planning": {"horizon": 86400}
    }"#;

    #[test]
    fn parses_fault_subsystem_config() {
        let c = ExperimentConfig::parse(FAULTY).unwrap();
        assert!(c.faults.enabled());
        assert_eq!(c.faults.mtbf, 40000.0);
        assert_eq!(c.faults.mttr, 1800.0);
        assert_eq!(c.faults.seed, 99);
        assert_eq!(c.faults.until, Some(500000));
        assert_eq!(c.faults.distribution, crate::sim::FaultDistribution::Weibull);
        assert_eq!(c.faults.shape, 0.8);
        assert_eq!(c.planning_horizon, Horizon::Fixed(86400));
        assert_eq!(c.preemption.mode, crate::sched::PreemptionMode::Checkpoint);
        assert_eq!(c.preemption.checkpoint_overhead, SimDuration(60));
        assert_eq!(c.preemption.restart_overhead, SimDuration(30));
        assert_eq!(c.preemption.starvation_threshold, SimDuration(7200));
        assert_eq!(c.priority_bands, 4);
        // Priority bands reach the built workload.
        let w = c.build_workload().unwrap();
        assert!(w.jobs.iter().any(|j| j.priority > 0));
        assert!(w.jobs.iter().all(|j| j.priority < 4));
        assert_eq!(
            c.reservations,
            vec![ReservationSpec { start: 1000, duration: 5000, nodes: 8 }]
        );
    }

    #[test]
    fn fault_config_roundtrips() {
        let c = ExperimentConfig::parse(FAULTY).unwrap();
        let back = ExperimentConfig::parse(&c.to_json().to_pretty()).unwrap();
        assert_eq!(back.faults, c.faults);
        assert_eq!(back.preemption, c.preemption);
        assert_eq!(back.reservations, c.reservations);
        assert_eq!(back.planning_horizon, c.planning_horizon);
    }

    #[test]
    fn weibull_shape_validated_and_defaults_exp() {
        let c = ExperimentConfig::parse(r#"{"faults": {"mtbf": 10, "mttr": 5}}"#).unwrap();
        assert_eq!(c.faults.distribution, crate::sim::FaultDistribution::Exp);
        assert_eq!(c.faults.shape, 1.0);
        assert_eq!(c.planning_horizon, Horizon::Exact, "horizon defaults to unlimited");
        assert!(ExperimentConfig::parse(
            r#"{"faults": {"mtbf": 10, "mttr": 5, "shape": 0}}"#
        )
        .is_err());
        assert!(ExperimentConfig::parse(
            r#"{"faults": {"mtbf": 10, "mttr": 5, "shape": 0.05}}"#
        )
        .is_err());
        assert!(ExperimentConfig::parse(
            r#"{"faults": {"mtbf": 10, "mttr": 5, "distribution": "pareto"}}"#
        )
        .is_err());
    }

    #[test]
    fn planning_horizon_accepts_auto_and_exact() {
        let auto = ExperimentConfig::parse(r#"{"planning": {"horizon": "auto"}}"#).unwrap();
        assert_eq!(auto.planning_horizon, Horizon::Auto);
        let back = ExperimentConfig::parse(&auto.to_json().to_pretty()).unwrap();
        assert_eq!(back.planning_horizon, Horizon::Auto, "auto must survive a roundtrip");
        let exact = ExperimentConfig::parse(r#"{"planning": {"horizon": "exact"}}"#).unwrap();
        assert_eq!(exact.planning_horizon, Horizon::Exact);
        // A zero tick count normalizes to exact planning.
        let zero = ExperimentConfig::parse(r#"{"planning": {"horizon": 0}}"#).unwrap();
        assert_eq!(zero.planning_horizon, Horizon::Exact);
        assert!(ExperimentConfig::parse(r#"{"planning": {"horizon": "soonish"}}"#).is_err());
        assert!(ExperimentConfig::parse(r#"{"planning": {"horizon": -5}}"#).is_err());
    }

    #[test]
    fn auto_horizon_params_roundtrip_and_defaults() {
        // Defaults are the engine constants; absent keys leave them.
        let d = ExperimentConfig::parse(r#"{"planning": {"horizon": "auto"}}"#).unwrap();
        assert_eq!(d.auto_horizon, AutoHorizonParams::default());
        assert_eq!(d.auto_horizon.shallow_queue, crate::sim::components::AUTO_SHALLOW_QUEUE);
        assert_eq!(d.auto_horizon.estimates, crate::sim::components::AUTO_HORIZON_ESTIMATES);
        assert_eq!(d.auto_horizon.min_horizon, crate::sim::components::AUTO_MIN_HORIZON);
        // Overrides parse and survive a serialize/parse round-trip.
        let c = ExperimentConfig::parse(
            r#"{
                "planning": {"horizon": "auto", "auto_shallow_queue": 64,
                             "auto_horizon_estimates": 16, "auto_min_horizon": 600}
            }"#,
        )
        .unwrap();
        assert_eq!(c.planning_horizon, Horizon::Auto);
        assert_eq!(
            c.auto_horizon,
            AutoHorizonParams { shallow_queue: 64, estimates: 16, min_horizon: 600 }
        );
        let back = ExperimentConfig::parse(&c.to_json().to_pretty()).unwrap();
        assert_eq!(back.planning_horizon, c.planning_horizon);
        assert_eq!(back.auto_horizon, c.auto_horizon);
        // Auto keys round-trip even without a horizon entry (inert but
        // preserved), and a default config emits no planning object.
        let only_auto =
            ExperimentConfig::parse(r#"{"planning": {"auto_min_horizon": 120}}"#).unwrap();
        assert_eq!(only_auto.planning_horizon, Horizon::Exact);
        let back = ExperimentConfig::parse(&only_auto.to_json().to_pretty()).unwrap();
        assert_eq!(back.auto_horizon.min_horizon, 120);
        assert!(ExperimentConfig::parse("{}").unwrap().to_json().get("planning").is_none());
        // Validation: zero estimates would clamp planning to the floor.
        assert!(ExperimentConfig::parse(
            r#"{"planning": {"auto_horizon_estimates": 0}}"#
        )
        .is_err());
    }

    #[test]
    fn federation_section_roundtrips_and_validates() {
        let c = ExperimentConfig::parse(
            r#"{"federation": {"shards": 4, "routing": "rr", "route_latency": 120}}"#,
        )
        .unwrap();
        assert_eq!(c.shards, 4);
        assert_eq!(c.routing, Routing::RoundRobin);
        assert_eq!(c.route_latency, 120);
        let back = ExperimentConfig::parse(&c.to_json().to_pretty()).unwrap();
        assert_eq!(back.shards, c.shards);
        assert_eq!(back.routing, c.routing);
        assert_eq!(back.route_latency, c.route_latency);
        // Defaults: engine off, least-loaded routing, no emitted section.
        let d = ExperimentConfig::parse("{}").unwrap();
        assert_eq!(d.shards, 0);
        assert_eq!(d.routing, Routing::LeastLoaded);
        assert_eq!(d.route_latency, 60);
        assert!(d.to_json().get("federation").is_none());
        // Validation: zero latency breaks the conservative contract.
        assert!(ExperimentConfig::parse(
            r#"{"federation": {"shards": 2, "route_latency": 0}}"#
        )
        .is_err());
        assert!(ExperimentConfig::parse(
            r#"{"federation": {"routing": "tarot"}}"#
        )
        .is_err());
    }

    #[test]
    fn check_passes_clean_configs() {
        assert_eq!(ExperimentConfig::check(SAMPLE).unwrap(), Vec::<String>::new());
        assert_eq!(ExperimentConfig::check(FAULTY).unwrap(), Vec::<String>::new());
        assert_eq!(ExperimentConfig::check("{}").unwrap(), Vec::<String>::new());
    }

    #[test]
    fn check_collects_every_finding_at_once() {
        let bad = r#"{
            "workload": {"kind": "swf", "path": "missing.gwf", "arrival_scale": 0},
            "platform": {"nodes": 16},
            "scheduler": {"memory_aware": true},
            "federation": {"shards": 0},
            "faults": {"mtbf": 100, "mttr": 5000},
            "reservations": [{"start": 0, "duration": 100, "nodes": 99},
                             {"start": 50, "duration": 100, "nodes": 10},
                             {"start": 60, "duration": 100, "nodes": 10}]
        }"#;
        let f = ExperimentConfig::check(bad).unwrap();
        // One pass reports everything — not just the first problem.
        assert!(f.len() >= 8, "expected all findings at once, got {f:#?}");
        for needle in [
            "arrival_scale",
            "does not exist",
            "format mismatch",
            "wants 99 nodes",
            "reserved concurrently",
            "mtbf 100 < mttr 5000",
            "shards = 0",
            "memory_aware",
        ] {
            assert!(
                f.iter().any(|m| m.contains(needle)),
                "missing finding about {needle:?} in {f:#?}"
            );
        }
    }

    #[test]
    fn check_flags_window_overlap_but_not_disjoint_windows() {
        // Two 10-node reservations on a 16-node machine: fine apart,
        // flagged when their windows overlap.
        let disjoint = r#"{
            "platform": {"nodes": 16},
            "reservations": [{"start": 0, "duration": 100, "nodes": 10},
                             {"start": 100, "duration": 100, "nodes": 10}]
        }"#;
        assert_eq!(ExperimentConfig::check(disjoint).unwrap(), Vec::<String>::new());
        let overlapping = r#"{
            "platform": {"nodes": 16},
            "reservations": [{"start": 0, "duration": 150, "nodes": 10},
                             {"start": 100, "duration": 100, "nodes": 10}]
        }"#;
        let f = ExperimentConfig::check(overlapping).unwrap();
        assert_eq!(f.len(), 1, "{f:#?}");
        assert!(f[0].contains("20 nodes reserved concurrently at t=100"));
    }

    #[test]
    fn check_engine_conflict_and_inert_bands() {
        let f = ExperimentConfig::check(
            r#"{
                "parallel": {"ranks": 4},
                "federation": {"shards": 2},
                "preemption": {"priority_bands": 3}
            }"#,
        )
        .unwrap();
        assert!(f.iter().any(|m| m.contains("two different parallel engines")), "{f:#?}");
        assert!(f.iter().any(|m| m.contains("never consulted")), "{f:#?}");
        assert_eq!(f.len(), 2, "{f:#?}");
    }

    #[test]
    fn check_still_fails_fast_on_structural_errors() {
        assert!(ExperimentConfig::check("not json").is_err());
        assert!(ExperimentConfig::check(r#"{"scheduler": {"policy": "magic"}}"#).is_err());
    }

    #[test]
    fn serve_block_roundtrips_and_validates() {
        let c = ExperimentConfig::parse(
            r#"{"serve": {"socket": "/tmp/s.sock", "max_sims": 3, "queue_depth": 16,
                          "state_dir": "/tmp/sst-state", "durability": "strict",
                          "mark_interval": 32}}"#,
        )
        .unwrap();
        assert_eq!(c.serve.socket, "/tmp/s.sock");
        assert_eq!(c.serve.max_sims, 3);
        assert_eq!(c.serve.queue_depth, 16);
        assert_eq!(c.serve.state_dir.as_deref(), Some("/tmp/sst-state"));
        assert_eq!(c.serve.durability, Durability::Strict);
        assert_eq!(c.serve.mark_interval, 32);
        let back = ExperimentConfig::parse(&c.to_json().to_pretty()).unwrap();
        assert_eq!(back.serve, c.serve);
        // Defaults stay out of the emitted config, and zero limits are
        // rejected up front rather than refusing every request later.
        let plain = ExperimentConfig::parse("{}").unwrap();
        assert_eq!(plain.serve, ServeOptions::default());
        assert_eq!(plain.serve.state_dir, None);
        assert_eq!(plain.serve.durability, Durability::Batched);
        assert_eq!(plain.serve.mark_interval, 256);
        assert!(plain.to_json().get("serve").is_none());
        assert!(ExperimentConfig::parse(r#"{"serve": {"max_sims": 0}}"#).is_err());
        assert!(ExperimentConfig::parse(r#"{"serve": {"queue_depth": 0}}"#).is_err());
        assert!(ExperimentConfig::parse(r#"{"serve": {"durability": "paranoid"}}"#).is_err());
    }

    #[test]
    fn semantic_hash_ignores_serve_plumbing_only() {
        let base = ExperimentConfig::parse(SAMPLE).unwrap();
        // Daemon plumbing (socket, durability, state_dir...) must not
        // change the hash: a journal resumes under any of them.
        let mut plumbing = base.clone();
        plumbing.serve.socket = "/tmp/elsewhere.sock".to_string();
        plumbing.serve.durability = Durability::Off;
        plumbing.serve.state_dir = Some("/tmp/x".to_string());
        assert_eq!(base.semantic_hash(), plumbing.semantic_hash());
        // Simulation semantics must change it.
        let mut semantics = base.clone();
        semantics.seed = base.seed + 1;
        assert_ne!(base.semantic_hash(), semantics.semantic_hash());
        let mut policy = base.clone();
        policy.policy = Policy::Sjf;
        assert_ne!(base.semantic_hash(), policy.semantic_hash());
    }

    #[test]
    fn check_flags_serve_persistence_problems() {
        // Zero mark interval + a parent directory that does not exist.
        let f = ExperimentConfig::check(
            r#"{"serve": {"state_dir": "/nonexistent-sst-parent/state",
                          "mark_interval": 0}}"#,
        )
        .unwrap();
        assert!(f.iter().any(|m| m.contains("parent directory")), "{f:#?}");
        assert!(f.iter().any(|m| m.contains("mark_interval = 0")), "{f:#?}");
        assert_eq!(f.len(), 2, "{f:#?}");
        // Durability knobs without a state_dir are inert.
        let f = ExperimentConfig::check(r#"{"serve": {"durability": "strict"}}"#).unwrap();
        assert_eq!(f.len(), 1, "{f:#?}");
        assert!(f[0].contains("journaling is off"), "{}", f[0]);
        // A clean persistent config (existing writable dir) has no findings.
        let dir = std::env::temp_dir().join(format!("sst-check-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let text = format!(r#"{{"serve": {{"state_dir": {:?}}}}}"#, dir.to_str().unwrap());
        assert_eq!(ExperimentConfig::check(&text).unwrap(), Vec::<String>::new());
        // A journal written under a different config is flagged.
        let other = ExperimentConfig::parse(r#"{"workload": {"seed": 99}}"#).unwrap();
        drop(
            crate::runtime::journal::Journal::create(
                &dir,
                other.semantic_hash(),
                Durability::Strict,
            )
            .unwrap(),
        );
        let f = ExperimentConfig::check(&text).unwrap();
        assert_eq!(f.len(), 1, "{f:#?}");
        assert!(f[0].contains("different experiment config"), "{}", f[0]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fault_free_default_and_validation() {
        let c = ExperimentConfig::parse("{}").unwrap();
        assert!(!c.faults.enabled());
        assert!(!c.preemption.enabled());
        assert!(c.reservations.is_empty());
        assert!(ExperimentConfig::parse(r#"{"faults": {"mtbf": 10, "mttr": 0}}"#).is_err());
        assert!(ExperimentConfig::parse(r#"{"preemption": {"mode": "vaporize"}}"#).is_err());
        assert!(
            ExperimentConfig::parse(r#"{"reservations": [{"start": 5, "nodes": 0}]}"#).is_err()
        );
    }
}
