//! Deterministic pseudo-random numbers and the distributions the workload
//! models need.
//!
//! The simulator must be bit-reproducible across runs and across rank
//! counts (the parallel engine partitions work, it must not change the
//! workload), so we carry our own small generator instead of a crate:
//! xoshiro256++ seeded through SplitMix64, plus the handful of
//! distributions the DAS-2 / SDSC-SP2 workload models and the workflow
//! generators draw from.

/// xoshiro256++ PRNG (Blackman & Vigna), seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (e.g. one per workflow generator).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection for unbiased bounded ints.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// true with probability p.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with given rate (mean = 1/rate).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        // 1 - f64() is in (0,1], avoiding ln(0).
        -(1.0 - self.f64()).ln() / rate
    }

    /// Standard normal via Box-Muller (one value per call; simple > fast).
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = 1.0 - self.f64(); // (0,1]
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        mean + std * r * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Lognormal: exp(N(mu, sigma)). Heavy-tailed job runtimes.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Bounded Pareto on [lo, hi] with tail index alpha — job size tails.
    pub fn pareto(&mut self, alpha: f64, lo: f64, hi: f64) -> f64 {
        debug_assert!(alpha > 0.0 && lo > 0.0 && hi > lo);
        let u = self.f64();
        let la = lo.powf(alpha);
        let ha = hi.powf(alpha);
        (-(u * ha - u * la - ha) / (ha * la)).powf(-1.0 / alpha)
    }

    /// Weibull(shape k, scale lambda) — interarrival burstiness.
    pub fn weibull(&mut self, k: f64, lambda: f64) -> f64 {
        debug_assert!(k > 0.0 && lambda > 0.0);
        lambda * (-(1.0 - self.f64()).ln()).powf(1.0 / k)
    }

    /// Gamma(shape k, scale theta) via Marsaglia-Tsang, k >= 0.01.
    pub fn gamma(&mut self, k: f64, theta: f64) -> f64 {
        debug_assert!(k > 0.0 && theta > 0.0);
        if k < 1.0 {
            // Boost: Gamma(k) = Gamma(k+1) * U^(1/k)
            let g = self.gamma(k + 1.0, 1.0);
            return g * self.f64().powf(1.0 / k) * theta;
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal(0.0, 1.0);
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v * theta;
            }
        }
    }

    /// Index sampled from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        debug_assert!(!weights.is_empty());
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Power-of-two job size in [1, max_pow2], biased toward the weights
    /// (HPC traces are strongly power-of-two: Feitelson workload models).
    pub fn pow2_size(&mut self, weights: &[f64]) -> u64 {
        1u64 << self.weighted(weights)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(4);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let x = r.below(7) as usize;
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            let x = r.range(10, 12);
            assert!((10..=12).contains(&x));
        }
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(6);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exponential(0.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(3.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.05, "mean={mean}");
        assert!((var - 4.0).abs() < 0.15, "var={var}");
    }

    #[test]
    fn lognormal_positive() {
        let mut r = Rng::new(8);
        for _ in 0..1000 {
            assert!(r.lognormal(4.0, 1.5) > 0.0);
        }
    }

    #[test]
    fn pareto_bounded() {
        let mut r = Rng::new(9);
        for _ in 0..5000 {
            let x = r.pareto(1.2, 1.0, 512.0);
            assert!((1.0..=512.0 + 1e-9).contains(&x), "x={x}");
        }
    }

    #[test]
    fn gamma_mean() {
        let mut r = Rng::new(10);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.gamma(2.0, 3.0)).sum::<f64>() / n as f64;
        assert!((mean - 6.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn weighted_respects_zero_weight() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            let i = r.weighted(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }

    #[test]
    fn pow2_size_is_power_of_two() {
        let mut r = Rng::new(12);
        for _ in 0..1000 {
            let s = r.pow2_size(&[1.0, 2.0, 4.0, 2.0, 1.0]);
            assert!(s.is_power_of_two());
            assert!(s <= 16);
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(13);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(14);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }
}
