//! The discrete-event simulation core (SST-Core analogue).
//!
//! Pure, payload-generic DES machinery with no knowledge of jobs or
//! workflows: simulated time, a deterministic event queue, components
//! connected by latency links, a statistics framework, and a
//! reproducible RNG. Everything HPC-specific lives in the layers above
//! (`job`, `sched`, `resources`, `workflow`, `sim`).

pub mod component;
pub mod engine;
pub mod event;
pub mod link;
pub mod rng;
pub mod stats;
pub mod time;

pub use component::{Component, Ctx};
pub use engine::{Engine, RunReport};
pub use event::{ComponentId, EventQueue, Priority, Scheduled};
pub use link::LinkTable;
pub use rng::Rng;
pub use stats::{Accumulator, Histogram, Stat, StatRegistry, TimeSeries};
pub use time::{SimDuration, SimTime};
