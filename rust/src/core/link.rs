//! Links between components.
//!
//! SST connects components through explicitly configured links with fixed
//! latencies; the minimum link latency doubles as the conservative
//! lookahead of the parallel engine. We keep a sparse (from, to) -> latency
//! table with a configurable default for unlinked pairs.

use crate::core::event::ComponentId;
use crate::core::time::SimDuration;

/// Sparse directed link-latency table.
///
/// Component graphs are tiny (a handful of links) while `latency()` is
/// called on every event send, so storage is a linear-scanned vec — it
/// benches ~4x faster than a HashMap on the simulator hot path.
#[derive(Debug, Clone, Default)]
pub struct LinkTable {
    latencies: Vec<(ComponentId, ComponentId, SimDuration)>,
    default: SimDuration,
}

impl LinkTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Latency applied to pairs without an explicit link.
    pub fn with_default(default: SimDuration) -> Self {
        LinkTable { latencies: Vec::new(), default }
    }

    /// Configure a directed link `from -> to` (replaces an existing one).
    pub fn connect(&mut self, from: ComponentId, to: ComponentId, latency: SimDuration) {
        if let Some(e) = self.latencies.iter_mut().find(|e| e.0 == from && e.1 == to) {
            e.2 = latency;
        } else {
            self.latencies.push((from, to, latency));
        }
    }

    /// Configure both directions with the same latency.
    pub fn connect_bidi(&mut self, a: ComponentId, b: ComponentId, latency: SimDuration) {
        self.connect(a, b, latency);
        self.connect(b, a, latency);
    }

    /// Latency from `from` to `to`.
    #[inline]
    pub fn latency(&self, from: ComponentId, to: ComponentId) -> SimDuration {
        self.latencies
            .iter()
            .find(|e| e.0 == from && e.1 == to)
            .map(|e| e.2)
            .unwrap_or(self.default)
    }

    /// Minimum configured latency (conservative lookahead); `None` if no
    /// links are configured.
    pub fn min_latency(&self) -> Option<SimDuration> {
        self.latencies.iter().map(|e| e.2).min()
    }

    pub fn len(&self) -> usize {
        self.latencies.len()
    }

    pub fn is_empty(&self) -> bool {
        self.latencies.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_latency_for_unlinked() {
        let t = LinkTable::with_default(SimDuration(3));
        assert_eq!(t.latency(0, 1), SimDuration(3));
    }

    #[test]
    fn directed_links() {
        let mut t = LinkTable::new();
        t.connect(0, 1, SimDuration(5));
        assert_eq!(t.latency(0, 1), SimDuration(5));
        assert_eq!(t.latency(1, 0), SimDuration(0)); // default default = 0
    }

    #[test]
    fn bidi_links() {
        let mut t = LinkTable::new();
        t.connect_bidi(2, 3, SimDuration(7));
        assert_eq!(t.latency(2, 3), SimDuration(7));
        assert_eq!(t.latency(3, 2), SimDuration(7));
    }

    #[test]
    fn min_latency_is_lookahead() {
        let mut t = LinkTable::new();
        assert_eq!(t.min_latency(), None);
        t.connect(0, 1, SimDuration(5));
        t.connect(1, 2, SimDuration(2));
        assert_eq!(t.min_latency(), Some(SimDuration(2)));
    }
}
