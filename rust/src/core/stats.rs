//! Statistics framework (SST::Statistics analogue).
//!
//! Components register named statistics with the engine's [`StatRegistry`]
//! and record into them as the simulation runs. Three kinds cover
//! everything the paper reports:
//!
//! * [`Accumulator`] — streaming count/sum/min/max/mean/variance (Welford).
//! * [`Histogram`] — fixed-width bins with under/overflow.
//! * [`TimeSeries`] — (time, value) samples, e.g. node occupancy over time.

use crate::core::time::SimTime;
use std::collections::BTreeMap;

/// Streaming moments via Welford's algorithm.
#[derive(Debug, Clone, Default)]
pub struct Accumulator {
    n: u64,
    mean: f64,
    m2: f64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Accumulator {
    pub fn new() -> Self {
        Accumulator { n: 0, mean: 0.0, m2: 0.0, sum: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn record(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn sum(&self) -> f64 {
        self.sum
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Merge another accumulator into this one (parallel rank reduction).
    pub fn merge(&mut self, other: &Accumulator) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = (self.n + other.n) as f64;
        let d = other.mean - self.mean;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64) / n;
        self.mean = (self.mean * self.n as f64 + other.mean * other.n as f64) / n;
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Fixed-width histogram over [lo, hi) with `bins` buckets plus
/// underflow/overflow counters.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    width: f64,
    counts: Vec<u64>,
    underflow: u64,
    overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram { lo, width: (hi - lo) / bins as f64, counts: vec![0; bins], underflow: 0, overflow: 0 }
    }

    pub fn record(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
            return;
        }
        let idx = ((x - self.lo) / self.width) as usize;
        if idx >= self.counts.len() {
            self.overflow += 1;
        } else {
            self.counts[idx] += 1;
        }
    }

    pub fn bins(&self) -> &[u64] {
        &self.counts
    }
    pub fn underflow(&self) -> u64 {
        self.underflow
    }
    pub fn overflow(&self) -> u64 {
        self.overflow
    }
    pub fn total(&self) -> u64 {
        self.counts.iter().sum::<u64>() + self.underflow + self.overflow
    }

    /// Lower edge of bin i.
    pub fn edge(&self, i: usize) -> f64 {
        self.lo + self.width * i as f64
    }
}

/// (time, value) samples.
#[derive(Debug, Clone, Default)]
pub struct TimeSeries {
    pts: Vec<(SimTime, f64)>,
}

impl TimeSeries {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, t: SimTime, v: f64) {
        self.pts.push((t, v));
    }

    pub fn points(&self) -> &[(SimTime, f64)] {
        &self.pts
    }

    pub fn len(&self) -> usize {
        self.pts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pts.is_empty()
    }

    /// Time-weighted average of a step function sampled at these points,
    /// over [first, horizon). Each sample holds until the next one.
    pub fn time_weighted_mean(&self, horizon: SimTime) -> f64 {
        if self.pts.is_empty() {
            return 0.0;
        }
        let mut weighted = 0.0;
        let mut span = 0.0;
        for w in self.pts.windows(2) {
            let dt = (w[1].0 - w[0].0).as_f64();
            weighted += w[0].1 * dt;
            span += dt;
        }
        let last = self.pts[self.pts.len() - 1];
        if horizon > last.0 {
            let dt = (horizon - last.0).as_f64();
            weighted += last.1 * dt;
            span += dt;
        }
        if span == 0.0 {
            last.1
        } else {
            weighted / span
        }
    }

    /// Downsample to at most `n` evenly spaced points (for printing).
    pub fn downsample(&self, n: usize) -> Vec<(SimTime, f64)> {
        if self.pts.len() <= n || n == 0 {
            return self.pts.clone();
        }
        let stride = self.pts.len() as f64 / n as f64;
        (0..n)
            .map(|i| self.pts[(i as f64 * stride) as usize])
            .collect()
    }
}

/// A named statistic.
#[derive(Debug, Clone)]
pub enum Stat {
    Acc(Accumulator),
    Hist(Histogram),
    Series(TimeSeries),
}

/// Registry of named statistics, keyed "component.stat".
/// `Clone` supports engine snapshots (`Engine::snapshot`).
#[derive(Debug, Default, Clone)]
pub struct StatRegistry {
    stats: BTreeMap<String, Stat>,
}

impl StatRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn acc(&mut self, name: &str) -> &mut Accumulator {
        let e = self
            .stats
            .entry(name.to_string())
            .or_insert_with(|| Stat::Acc(Accumulator::new()));
        match e {
            Stat::Acc(a) => a,
            _ => panic!("stat {name} exists with a different kind"),
        }
    }

    pub fn hist(&mut self, name: &str, lo: f64, hi: f64, bins: usize) -> &mut Histogram {
        let e = self
            .stats
            .entry(name.to_string())
            .or_insert_with(|| Stat::Hist(Histogram::new(lo, hi, bins)));
        match e {
            Stat::Hist(h) => h,
            _ => panic!("stat {name} exists with a different kind"),
        }
    }

    pub fn series(&mut self, name: &str) -> &mut TimeSeries {
        let e = self
            .stats
            .entry(name.to_string())
            .or_insert_with(|| Stat::Series(TimeSeries::new()));
        match e {
            Stat::Series(s) => s,
            _ => panic!("stat {name} exists with a different kind"),
        }
    }

    pub fn get(&self, name: &str) -> Option<&Stat> {
        self.stats.get(name)
    }

    pub fn get_acc(&self, name: &str) -> Option<&Accumulator> {
        match self.stats.get(name) {
            Some(Stat::Acc(a)) => Some(a),
            _ => None,
        }
    }

    pub fn get_series(&self, name: &str) -> Option<&TimeSeries> {
        match self.stats.get(name) {
            Some(Stat::Series(s)) => Some(s),
            _ => None,
        }
    }

    pub fn get_hist(&self, name: &str) -> Option<&Histogram> {
        match self.stats.get(name) {
            Some(Stat::Hist(h)) => Some(h),
            _ => None,
        }
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.stats.keys().map(|s| s.as_str())
    }

    pub fn len(&self) -> usize {
        self.stats.len()
    }

    pub fn is_empty(&self) -> bool {
        self.stats.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulator_moments() {
        let mut a = Accumulator::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            a.record(x);
        }
        assert_eq!(a.count(), 5);
        assert_eq!(a.sum(), 15.0);
        assert!((a.mean() - 3.0).abs() < 1e-12);
        assert!((a.variance() - 2.5).abs() < 1e-12);
        assert_eq!(a.min(), 1.0);
        assert_eq!(a.max(), 5.0);
    }

    #[test]
    fn accumulator_empty_is_zeroes() {
        let a = Accumulator::new();
        assert_eq!(a.mean(), 0.0);
        assert_eq!(a.variance(), 0.0);
        assert_eq!(a.min(), 0.0);
        assert_eq!(a.max(), 0.0);
    }

    #[test]
    fn accumulator_merge_matches_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut whole = Accumulator::new();
        for &x in &xs {
            whole.record(x);
        }
        let mut left = Accumulator::new();
        let mut right = Accumulator::new();
        for &x in &xs[..37] {
            left.record(x);
        }
        for &x in &xs[37..] {
            right.record(x);
        }
        left.merge(&right);
        assert_eq!(left.count(), whole.count());
        assert!((left.mean() - whole.mean()).abs() < 1e-9);
        assert!((left.variance() - whole.variance()).abs() < 1e-9);
    }

    #[test]
    fn histogram_binning() {
        let mut h = Histogram::new(0.0, 10.0, 5);
        for x in [-1.0, 0.0, 1.9, 2.0, 9.99, 10.0, 55.0] {
            h.record(x);
        }
        assert_eq!(h.underflow(), 1);
        assert_eq!(h.overflow(), 2);
        assert_eq!(h.bins(), &[2, 1, 0, 0, 1]);
        assert_eq!(h.total(), 7);
        assert_eq!(h.edge(1), 2.0);
    }

    #[test]
    fn time_weighted_mean_step_function() {
        let mut s = TimeSeries::new();
        s.record(SimTime(0), 10.0); // holds for 10 ticks
        s.record(SimTime(10), 0.0); // holds for 10 ticks
        assert!((s.time_weighted_mean(SimTime(20)) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn downsample_keeps_bounds() {
        let mut s = TimeSeries::new();
        for i in 0..1000 {
            s.record(SimTime(i), i as f64);
        }
        let d = s.downsample(10);
        assert_eq!(d.len(), 10);
        assert_eq!(d[0].0, SimTime(0));
    }

    #[test]
    fn registry_kinds() {
        let mut r = StatRegistry::new();
        r.acc("sched.wait").record(5.0);
        r.acc("sched.wait").record(7.0);
        r.series("cluster.occupancy").record(SimTime(1), 3.0);
        r.hist("sched.wait_hist", 0.0, 100.0, 10).record(5.0);
        assert_eq!(r.get_acc("sched.wait").unwrap().count(), 2);
        assert_eq!(r.get_series("cluster.occupancy").unwrap().len(), 1);
        assert_eq!(r.get_hist("sched.wait_hist").unwrap().total(), 1);
        assert_eq!(r.len(), 3);
    }

    #[test]
    #[should_panic]
    fn registry_kind_mismatch_panics() {
        let mut r = StatRegistry::new();
        r.acc("x");
        r.series("x");
    }
}
