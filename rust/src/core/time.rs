//! Simulation time.
//!
//! The simulator uses a discrete integer clock. One tick corresponds to one
//! second by convention (workload traces — SWF/GWF — carry second
//! resolution), but nothing in the core assumes a unit: components only rely
//! on the total order and on tick arithmetic.

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// A point in simulated time, in ticks since simulation start.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(pub u64);

/// A span of simulated time, in ticks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimDuration(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);
    /// A time later than any event a simulation will ever schedule.
    pub const MAX: SimTime = SimTime(u64::MAX);

    #[inline]
    pub fn ticks(self) -> u64 {
        self.0
    }

    /// Saturating difference `self - earlier`.
    #[inline]
    pub fn since(self, earlier: SimTime) -> SimDuration {
        SimDuration(self.0.saturating_sub(earlier.0))
    }

    #[inline]
    pub fn as_f64(self) -> f64 {
        self.0 as f64
    }
}

impl SimDuration {
    pub const ZERO: SimDuration = SimDuration(0);

    #[inline]
    pub fn ticks(self) -> u64 {
        self.0
    }

    #[inline]
    pub fn as_f64(self) -> f64 {
        self.0 as f64
    }

    /// Construct from a float tick count, rounding to the nearest tick and
    /// clamping negatives to zero (sources: lognormal runtime samples).
    #[inline]
    pub fn from_f64(t: f64) -> SimDuration {
        if t <= 0.0 {
            SimDuration(0)
        } else {
            SimDuration(t.round() as u64)
        }
    }
}

impl Add<SimDuration> for SimTime {
    type Output = SimTime;
    #[inline]
    fn add(self, rhs: SimDuration) -> SimTime {
        SimTime(self.0.saturating_add(rhs.0))
    }
}

impl AddAssign<SimDuration> for SimTime {
    #[inline]
    fn add_assign(&mut self, rhs: SimDuration) {
        self.0 = self.0.saturating_add(rhs.0);
    }
}

impl Add for SimDuration {
    type Output = SimDuration;
    #[inline]
    fn add(self, rhs: SimDuration) -> SimDuration {
        SimDuration(self.0.saturating_add(rhs.0))
    }
}

impl Sub for SimTime {
    type Output = SimDuration;
    #[inline]
    fn sub(self, rhs: SimTime) -> SimDuration {
        self.since(rhs)
    }
}

impl Sub for SimDuration {
    type Output = SimDuration;
    /// Saturating difference (a partial run segment can never push the
    /// remaining work below zero).
    #[inline]
    fn sub(self, rhs: SimDuration) -> SimDuration {
        SimDuration(self.0.saturating_sub(rhs.0))
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "t={}", self.0)
    }
}

impl fmt::Display for SimDuration {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}s", self.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_duration() {
        assert_eq!(SimTime(10) + SimDuration(5), SimTime(15));
    }

    #[test]
    fn since_saturates() {
        assert_eq!(SimTime(5).since(SimTime(10)), SimDuration(0));
        assert_eq!(SimTime(10).since(SimTime(4)), SimDuration(6));
    }

    #[test]
    fn sub_is_since() {
        assert_eq!(SimTime(10) - SimTime(4), SimDuration(6));
    }

    #[test]
    fn duration_sub_saturates() {
        assert_eq!(SimDuration(10) - SimDuration(4), SimDuration(6));
        assert_eq!(SimDuration(4) - SimDuration(10), SimDuration(0));
    }

    #[test]
    fn from_f64_rounds_and_clamps() {
        assert_eq!(SimDuration::from_f64(-3.0), SimDuration(0));
        assert_eq!(SimDuration::from_f64(2.4), SimDuration(2));
        assert_eq!(SimDuration::from_f64(2.6), SimDuration(3));
    }

    #[test]
    fn ordering() {
        assert!(SimTime(1) < SimTime(2));
        assert!(SimTime::MAX > SimTime(u64::MAX - 1));
    }

    #[test]
    fn add_saturates_at_max() {
        assert_eq!(SimTime::MAX + SimDuration(1), SimTime::MAX);
    }
}
