//! Scheduled events and the central event queue.
//!
//! Mirrors SST-Core's event model: an event is a payload delivered to a
//! component at a simulated time. Ordering is total and deterministic:
//! (time, priority, sequence-number), so two runs of the same simulation
//! process events in exactly the same order.

use crate::core::time::SimTime;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Index of a component registered with an engine.
pub type ComponentId = usize;

/// Tie-break priority within a timestamp; lower runs first.
///
/// The simulator uses a small set of well-known priorities so that, e.g.,
/// completions at time t free resources before the scheduler runs at t.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Priority(pub u8);

impl Priority {
    /// Resource releases / job completions.
    pub const COMPLETE: Priority = Priority(0);
    /// Arrivals / submissions.
    pub const ARRIVE: Priority = Priority(1);
    /// Scheduler invocations.
    pub const SCHEDULE: Priority = Priority(2);
    /// Statistics sampling, reporting.
    pub const SAMPLE: Priority = Priority(3);
    pub const DEFAULT: Priority = Priority(2);
}

/// An event scheduled for delivery.
#[derive(Debug, Clone)]
pub struct Scheduled<P> {
    pub time: SimTime,
    pub priority: Priority,
    /// Monotone sequence number: FIFO among equal (time, priority).
    pub seq: u64,
    pub target: ComponentId,
    pub payload: P,
}

impl<P> PartialEq for Scheduled<P> {
    fn eq(&self, other: &Self) -> bool {
        self.key() == other.key()
    }
}
impl<P> Eq for Scheduled<P> {}

impl<P> Scheduled<P> {
    #[inline]
    fn key(&self) -> (SimTime, Priority, u64) {
        (self.time, self.priority, self.seq)
    }
}

impl<P> PartialOrd for Scheduled<P> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<P> Ord for Scheduled<P> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest first.
        other.key().cmp(&self.key())
    }
}

/// Min-heap of scheduled events with deterministic total order.
#[derive(Debug)]
pub struct EventQueue<P> {
    heap: BinaryHeap<Scheduled<P>>,
    next_seq: u64,
}

impl<P> Default for EventQueue<P> {
    fn default() -> Self {
        EventQueue { heap: BinaryHeap::new(), next_seq: 0 }
    }
}

impl<P> EventQueue<P> {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(cap: usize) -> Self {
        EventQueue { heap: BinaryHeap::with_capacity(cap), next_seq: 0 }
    }

    /// Schedule `payload` for `target` at absolute `time`.
    pub fn push(&mut self, time: SimTime, priority: Priority, target: ComponentId, payload: P) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Scheduled { time, priority, seq, target, payload });
    }

    /// Earliest pending timestamp, if any.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|e| e.time)
    }

    pub fn pop(&mut self) -> Option<Scheduled<P>> {
        self.heap.pop()
    }

    /// Pop the next event only if it is at or before `bound` (conservative
    /// window execution in the parallel engine).
    pub fn pop_at_or_before(&mut self, bound: SimTime) -> Option<Scheduled<P>> {
        match self.heap.peek() {
            Some(e) if e.time <= bound => self.heap.pop(),
            _ => None,
        }
    }

    /// Pop the next event only if it is strictly before `bound` (YAWNS
    /// windows are half-open: [start, bound)).
    pub fn pop_before(&mut self, bound: SimTime) -> Option<Scheduled<P>> {
        match self.heap.peek() {
            Some(e) if e.time < bound => self.heap.pop(),
            _ => None,
        }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(SimTime(5), Priority::DEFAULT, 0, "c");
        q.push(SimTime(1), Priority::DEFAULT, 0, "a");
        q.push(SimTime(3), Priority::DEFAULT, 0, "b");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|e| e.payload).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn priority_breaks_time_ties() {
        let mut q = EventQueue::new();
        q.push(SimTime(2), Priority::SCHEDULE, 0, "sched");
        q.push(SimTime(2), Priority::COMPLETE, 0, "complete");
        q.push(SimTime(2), Priority::ARRIVE, 0, "arrive");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|e| e.payload).collect();
        assert_eq!(order, vec!["complete", "arrive", "sched"]);
    }

    #[test]
    fn seq_breaks_full_ties_fifo() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.push(SimTime(7), Priority::DEFAULT, 0, i);
        }
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|e| e.payload).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn pop_at_or_before_respects_bound() {
        let mut q = EventQueue::new();
        q.push(SimTime(1), Priority::DEFAULT, 0, "a");
        q.push(SimTime(10), Priority::DEFAULT, 0, "b");
        assert_eq!(q.pop_at_or_before(SimTime(5)).unwrap().payload, "a");
        assert!(q.pop_at_or_before(SimTime(5)).is_none());
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop_at_or_before(SimTime(10)).unwrap().payload, "b");
    }

    #[test]
    fn peek_time() {
        let mut q = EventQueue::new();
        assert_eq!(q.peek_time(), None);
        q.push(SimTime(9), Priority::DEFAULT, 1, ());
        q.push(SimTime(4), Priority::DEFAULT, 1, ());
        assert_eq!(q.peek_time(), Some(SimTime(4)));
    }
}
