//! Scheduled events and the central event queue.
//!
//! Mirrors SST-Core's event model: an event is a payload delivered to a
//! component at a simulated time. Ordering is total and deterministic:
//! (time, priority, sequence-number), so two runs of the same simulation
//! process events in exactly the same order.
//!
//! # The ladder queue
//!
//! [`EventQueue`] is a ladder-queue-style tiered structure (Tang, Goh &
//! Thng 2005 — the classic amortized-O(1) DES priority queue) rather
//! than a binary heap. At million-event scale the heap's O(log n) sift
//! over `(time, priority, seq)` tuple keys *is* the engine hot path;
//! the ladder replaces it with bucketed batching:
//!
//! * **bottom rung** — the near future, a `Vec` sorted in *descending*
//!   key order so the next event to deliver is `bottom.last()` and a pop
//!   is `Vec::pop`. Filled a batch at a time by one unstable sort on the
//!   full `(time, priority, seq)` key. Same-tick self-sends (the
//!   engine's dispatch/submit chains) land here via a binary-searched
//!   insert whose memmove spans only the handful of same-tick events.
//! * **rungs** — the farther future, bucketed by time. Rungs nest:
//!   when a bucket comes due with more events than one batch sort
//!   should swallow, it spawns a child rung subdividing exactly that
//!   bucket's time range, innermost last. Each event is appended to a
//!   bucket in O(1) and is re-bucketed at most `O(log span)` times
//!   before its final batch sort.
//! * **top** — an unsorted overflow tail holding everything beyond the
//!   outermost rung; it is carved into a rung (or sorted straight into
//!   the bottom when small) only when the clock reaches it.
//!
//! ## Determinism contract
//!
//! Every event key `(time, priority, seq)` is unique (`seq` is a
//! per-queue monotone counter), so the total order is strict and the
//! pop sequence of *any* correct priority queue over these keys is
//! identical — including FIFO among equal `(time, priority)` pairs.
//! The ladder therefore produces byte-for-byte the event order the old
//! `BinaryHeap` produced; `rust/tests/prop_queue.rs` drives it against
//! a heap oracle to pin exactly that, and the engine fingerprints stay
//! byte-identical.
//!
//! The contract extends to *externally injected* events — arrivals a
//! parallel rank pushes into an engine mid-run (`SimInstance::submit`,
//! used by the sharded federation engine for routed and forwarded
//! jobs). An injection at time `t` gets the queue's next `seq`, so ties
//! at the same `(time, priority)` resolve by injection order. The
//! sharded engine keeps that order shard-count independent by
//! construction: router deliveries are the only `ARRIVE`-priority
//! events a federation domain ever sees, the router emits them in one
//! deterministic sequence, and cross-rank mailboxes are sorted before
//! draining — so a domain receives the same injections in the same
//! order whether its router runs on the same thread or another one.
//!
//! ## Degeneration
//!
//! Two shapes collapse the ladder into plain sorted-`Vec` behavior, by
//! design: batches at or below [`SORT_THRESHOLD`] events skip the rung
//! machinery entirely (one sort into the bottom — the common case for
//! the sparse tail of a draining simulation), and a batch whose events
//! all share one timestamp is sorted directly no matter its size, since
//! time-bucketing cannot split it further (the `(priority, seq)` sort
//! is the only order left to establish).

use crate::analysis::sanitizer;
use crate::core::time::SimTime;
use std::cmp::Ordering;

/// Index of a component registered with an engine.
pub type ComponentId = usize;

/// Tie-break priority within a timestamp; lower runs first.
///
/// The simulator uses a small set of well-known priorities so that, e.g.,
/// completions at time t free resources before the scheduler runs at t.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Priority(pub u8);

impl Priority {
    /// Resource releases / job completions.
    pub const COMPLETE: Priority = Priority(0);
    /// Arrivals / submissions.
    pub const ARRIVE: Priority = Priority(1);
    /// Scheduler invocations.
    pub const SCHEDULE: Priority = Priority(2);
    /// Statistics sampling, reporting.
    pub const SAMPLE: Priority = Priority(3);
    pub const DEFAULT: Priority = Priority(2);
}

/// An event scheduled for delivery.
#[derive(Debug, Clone)]
pub struct Scheduled<P> {
    pub time: SimTime,
    pub priority: Priority,
    /// Monotone sequence number: FIFO among equal (time, priority).
    pub seq: u64,
    pub target: ComponentId,
    pub payload: P,
}

impl<P> PartialEq for Scheduled<P> {
    fn eq(&self, other: &Self) -> bool {
        self.key() == other.key()
    }
}
impl<P> Eq for Scheduled<P> {}

impl<P> Scheduled<P> {
    #[inline]
    fn key(&self) -> (SimTime, Priority, u64) {
        (self.time, self.priority, self.seq)
    }
}

impl<P> PartialOrd for Scheduled<P> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<P> Ord for Scheduled<P> {
    /// Natural delivery order: earliest (time, priority, seq) first.
    /// (The heap era reversed this for `BinaryHeap`'s max-heap; the
    /// ladder compares keys directly, so the order is the natural one.)
    fn cmp(&self, other: &Self) -> Ordering {
        self.key().cmp(&other.key())
    }
}

/// Largest batch sorted straight into the bottom rung; bigger batches
/// spawn a refining child rung instead (unless single-timestamp — see
/// the module docs on degeneration).
const SORT_THRESHOLD: usize = 64;

/// One refinement rung: a bucket array subdividing `[start, end)` into
/// `width`-tick slots. `cur` is the first bucket that may still hold
/// events; earlier buckets were consumed (their range belongs to the
/// bottom rung now) or handed to a child rung.
#[derive(Debug, Clone)]
struct Rung<P> {
    /// Absolute time of bucket 0's left edge.
    start: u64,
    /// Bucket width in ticks (>= 1).
    width: u64,
    /// Exclusive end of the range this rung owns. For a child rung this
    /// is exactly the parent bucket's right edge — `start + width *
    /// buckets.len()` may overshoot it, and events beyond `end` belong
    /// to the parent, so routing checks `end`, never the bucket math.
    end: u64,
    /// First possibly-live bucket.
    cur: usize,
    buckets: Vec<Vec<Scheduled<P>>>,
}

impl<P> Rung<P> {
    /// Build a rung over `[start, end)` and distribute `events` (each
    /// with `start <= time < end`) into its buckets.
    fn from_events(start: u64, end: u64, events: Vec<Scheduled<P>>) -> Rung<P> {
        debug_assert!(end > start);
        let span = end - start;
        // ~8 events per bucket on average, so most buckets sort straight
        // into the bottom; bounded so a rung never allocates absurdly
        // (deep nesting carries the rest).
        let nb = ((events.len() / 8).clamp(16, 4096) as u64).min(span).max(1);
        let width = span.div_ceil(nb);
        let mut buckets: Vec<Vec<Scheduled<P>>> = Vec::with_capacity(nb as usize);
        buckets.resize_with(nb as usize, Vec::new);
        let mut rung = Rung { start, width, end, cur: 0, buckets };
        for ev in events {
            let idx = rung.bucket_of(ev.time.ticks());
            rung.buckets[idx].push(ev);
        }
        rung
    }

    #[inline]
    fn bucket_of(&self, t: u64) -> usize {
        debug_assert!(t >= self.start && t < self.end);
        let idx = ((t - self.start) / self.width) as usize;
        debug_assert!(idx < self.buckets.len());
        idx
    }
}

/// The deterministic central event queue (see the module docs for the
/// ladder structure). Every pending event lives in exactly one of
/// `bottom` / `rungs` / `top`, and the time axis is partitioned:
///
/// * `[0, bottom_until)` — bottom (sorted; includes anything pushed
///   into the past, which the engine never does but the queue tolerates),
/// * each rung's `[start, end)`, innermost (last) lowest,
/// * everything above the outermost rung — top.
///
/// `bottom_until` only grows: it is the right edge of the last bucket
/// batch the bottom absorbed, so every event still in rungs/top has
/// `time >= bottom_until` and `bottom.last()` is always the global
/// minimum. That single invariant is what makes `pop`/`peek` O(1) after
/// an amortized-O(1) `prepare_bottom`.
/// Cloning (requires `P: Clone`) preserves every tier *and* `next_seq`,
/// so a snapshot's future pushes receive the same sequence numbers the
/// original's would — the resume path stays byte-identical.
#[derive(Debug, Clone)]
pub struct EventQueue<P> {
    /// Near-future events in *descending* key order (next event last).
    bottom: Vec<Scheduled<P>>,
    /// Exclusive time bound of the bottom: pushes below it insert into
    /// `bottom`; everything at or above routes to rungs/top.
    bottom_until: u64,
    /// Nested refinement rungs, outermost first, innermost last.
    rungs: Vec<Rung<P>>,
    /// Unsorted far-future overflow (beyond the outermost rung).
    top: Vec<Scheduled<P>>,
    /// Min/max event time in `top` (meaningful only when non-empty).
    top_min: u64,
    top_max: u64,
    next_seq: u64,
    len: usize,
    /// Key of the last popped event, for the sanitizer's pop-order
    /// check (unused when `sanitizer::ACTIVE` is false).
    san_last_pop: Option<(u64, u8, u64)>,
}

impl<P> Default for EventQueue<P> {
    fn default() -> Self {
        EventQueue {
            bottom: Vec::new(),
            bottom_until: 0,
            rungs: Vec::new(),
            top: Vec::new(),
            top_min: 0,
            top_max: 0,
            next_seq: 0,
            len: 0,
            san_last_pop: None,
        }
    }
}

impl<P> EventQueue<P> {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(cap: usize) -> Self {
        let mut q = Self::default();
        // New events land in `top` (far future) or `bottom` (near);
        // reserving the tail covers the bulk-load pattern.
        q.top.reserve(cap);
        q
    }

    /// Schedule `payload` for `target` at absolute `time`.
    pub fn push(&mut self, time: SimTime, priority: Priority, target: ComponentId, payload: P) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.route(Scheduled { time, priority, seq, target, payload });
    }

    /// File one event into the tier that owns its timestamp.
    fn route(&mut self, ev: Scheduled<P>) {
        self.len += 1;
        let t = ev.time.ticks();
        if t < self.bottom_until {
            return self.insert_bottom(ev);
        }
        // Innermost rung first: rung ranges nest, so the first rung whose
        // `end` exceeds `t` owns it — unless `t` falls below its live
        // region (the gap left by skipped empty buckets, or below a
        // tightened child start), which means nothing pending precedes
        // it there and it belongs in the bottom.
        let mut i = self.rungs.len();
        while i > 0 {
            i -= 1;
            let rung = &mut self.rungs[i];
            if t < rung.end {
                if t >= rung.start {
                    let idx = rung.bucket_of(t);
                    if idx >= rung.cur {
                        rung.buckets[idx].push(ev);
                        return;
                    }
                }
                self.insert_bottom(ev);
                return;
            }
        }
        if self.top.is_empty() {
            self.top_min = t;
            self.top_max = t;
        } else {
            self.top_min = self.top_min.min(t);
            self.top_max = self.top_max.max(t);
        }
        self.top.push(ev);
    }

    /// Sorted insert into the descending bottom rung. The memmove spans
    /// only events with a *smaller* key — for the engine's same-tick
    /// self-sends that is the few same-tick events still pending.
    fn insert_bottom(&mut self, ev: Scheduled<P>) {
        let k = ev.key();
        let idx = self.bottom.partition_point(|e| e.key() > k);
        self.bottom.insert(idx, ev);
    }

    /// Move the next batch of events into the bottom rung so that
    /// `bottom.last()` is the global minimum (no-op while the bottom is
    /// non-empty). Amortized O(1) per event: each event is re-bucketed
    /// at most O(log span) times and batch-sorted once.
    fn prepare_bottom(&mut self) {
        while self.bottom.is_empty() {
            if !self.rungs.is_empty() {
                let last = self.rungs.len() - 1;
                let rung = &mut self.rungs[last];
                // Advance to the first live bucket; an exhausted rung
                // pops off the ladder and its parent resumes.
                while rung.cur < rung.buckets.len() && rung.buckets[rung.cur].is_empty() {
                    rung.cur += 1;
                }
                if rung.cur == rung.buckets.len() {
                    self.rungs.pop();
                    continue;
                }
                let lo = rung.start + rung.cur as u64 * rung.width;
                let hi = lo.saturating_add(rung.width).min(rung.end);
                let batch = std::mem::take(&mut rung.buckets[rung.cur]);
                rung.cur += 1;
                if let Some((mn, mx)) = refine_range(&batch) {
                    // Oversized multi-timestamp bucket: subdivide it.
                    // The child owns through `hi` (future pushes in the
                    // bucket's range must land in it), but its start is
                    // tightened to the earliest actual event — pushes
                    // below that precede everything and go to bottom.
                    debug_assert!(lo <= mn && mx < hi);
                    self.rungs.push(Rung::from_events(mn, hi, batch));
                    continue;
                }
                // Consumed range: future pushes below `hi` go to bottom.
                self.bottom_until = self.bottom_until.max(hi);
                self.fill_bottom(batch);
            } else if !self.top.is_empty() {
                let batch = std::mem::take(&mut self.top);
                let (mn, mx) = (self.top_min, self.top_max);
                if refine_range(&batch).is_some() && mx < u64::MAX {
                    self.rungs.push(Rung::from_events(mn, mx + 1, batch));
                    continue;
                }
                self.bottom_until = self.bottom_until.max(mx.saturating_add(1));
                self.fill_bottom(batch);
            } else {
                return; // queue empty
            }
        }
    }

    /// One batched unstable sort on the full key, descending, so pops
    /// come off the back. Called only with an empty bottom.
    fn fill_bottom(&mut self, mut batch: Vec<Scheduled<P>>) {
        debug_assert!(self.bottom.is_empty());
        batch.sort_unstable_by(|a, b| b.key().cmp(&a.key()));
        self.bottom = batch;
    }

    /// Earliest pending timestamp, if any.
    pub fn peek_time(&mut self) -> Option<SimTime> {
        self.prepare_bottom();
        self.bottom.last().map(|e| e.time)
    }

    /// Pop-order sanitizer hook: total-order keys never regress across
    /// pops (compiles to nothing in ordinary release builds).
    #[inline]
    fn note_pop(&mut self, ev: &Scheduled<P>) {
        if sanitizer::ACTIVE {
            sanitizer::check_pop_order(
                &mut self.san_last_pop,
                ev.time.ticks(),
                ev.priority.0,
                ev.seq,
            );
        }
    }

    pub fn pop(&mut self) -> Option<Scheduled<P>> {
        self.prepare_bottom();
        let ev = self.bottom.pop();
        if let Some(e) = &ev {
            self.len -= 1;
            self.note_pop(e);
        }
        ev
    }

    /// Pop the next event only if it is at or before `bound` (inclusive
    /// window execution in the sequential engine). One time compare on
    /// the prepared bottom — no key re-comparison, no sift.
    #[inline]
    pub fn pop_at_or_before(&mut self, bound: SimTime) -> Option<Scheduled<P>> {
        self.prepare_bottom();
        match self.bottom.last() {
            Some(e) if e.time <= bound => {
                self.len -= 1;
                let ev = self.bottom.pop().expect("peeked event vanished");
                self.note_pop(&ev);
                Some(ev)
            }
            _ => None,
        }
    }

    /// Pop the next event only if it is strictly before `bound` (YAWNS
    /// windows are half-open: [start, bound)).
    #[inline]
    pub fn pop_before(&mut self, bound: SimTime) -> Option<Scheduled<P>> {
        self.prepare_bottom();
        match self.bottom.last() {
            Some(e) if e.time < bound => {
                self.len -= 1;
                let ev = self.bottom.pop().expect("peeked event vanished");
                self.note_pop(&ev);
                Some(ev)
            }
            _ => None,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// `Some((min, max))` when `events` is worth refining into a child rung:
/// more than [`SORT_THRESHOLD`] events spread over more than one
/// timestamp. `None` means "sort it into the bottom now" — the
/// sorted-vec degeneration (small batch, or a single-timestamp storm
/// that bucketing cannot split).
fn refine_range<P>(events: &[Scheduled<P>]) -> Option<(u64, u64)> {
    if events.len() <= SORT_THRESHOLD {
        return None;
    }
    let mut mn = u64::MAX;
    let mut mx = 0u64;
    for e in events {
        let t = e.time.ticks();
        mn = mn.min(t);
        mx = mx.max(t);
    }
    if mn < mx {
        Some((mn, mx))
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(SimTime(5), Priority::DEFAULT, 0, "c");
        q.push(SimTime(1), Priority::DEFAULT, 0, "a");
        q.push(SimTime(3), Priority::DEFAULT, 0, "b");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|e| e.payload).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn priority_breaks_time_ties() {
        let mut q = EventQueue::new();
        q.push(SimTime(2), Priority::SCHEDULE, 0, "sched");
        q.push(SimTime(2), Priority::COMPLETE, 0, "complete");
        q.push(SimTime(2), Priority::ARRIVE, 0, "arrive");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|e| e.payload).collect();
        assert_eq!(order, vec!["complete", "arrive", "sched"]);
    }

    #[test]
    fn seq_breaks_full_ties_fifo() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.push(SimTime(7), Priority::DEFAULT, 0, i);
        }
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|e| e.payload).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn pop_at_or_before_respects_bound() {
        let mut q = EventQueue::new();
        q.push(SimTime(1), Priority::DEFAULT, 0, "a");
        q.push(SimTime(10), Priority::DEFAULT, 0, "b");
        assert_eq!(q.pop_at_or_before(SimTime(5)).unwrap().payload, "a");
        assert!(q.pop_at_or_before(SimTime(5)).is_none());
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop_at_or_before(SimTime(10)).unwrap().payload, "b");
    }

    #[test]
    fn peek_time() {
        let mut q = EventQueue::new();
        assert_eq!(q.peek_time(), None);
        q.push(SimTime(9), Priority::DEFAULT, 1, ());
        q.push(SimTime(4), Priority::DEFAULT, 1, ());
        assert_eq!(q.peek_time(), Some(SimTime(4)));
    }

    /// Enough far-future events to force rung spawning (and nesting),
    /// then a full drain: order must be exactly ascending by key.
    #[test]
    fn rung_spawning_preserves_total_order() {
        let mut q = EventQueue::new();
        // Deterministic scattered times over a wide range, with dense
        // clusters (forces child rungs) and unique payload = push index.
        let mut s = 0x12345678u64;
        let n = 5_000u64;
        for i in 0..n {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            let t = match s % 4 {
                0 => s % 50,                  // near cluster
                1 => 10_000 + s % 100,        // dense mid cluster
                2 => 10_000 + s % 1_000_000,  // broad mid range
                _ => s % 1_000_000_000,       // far tail
            };
            q.push(SimTime(t), Priority(((s >> 32) % 4) as u8), 0, i);
        }
        assert_eq!(q.len(), n as usize);
        let mut last: Option<(SimTime, Priority, u64)> = None;
        let mut popped = 0;
        while let Some(e) = q.pop() {
            let k = (e.time, e.priority, e.seq);
            if let Some(prev) = last {
                assert!(prev < k, "order violation: {prev:?} then {k:?}");
            }
            last = Some(k);
            popped += 1;
        }
        assert_eq!(popped, n);
        assert!(q.is_empty());
    }

    /// Interleaved push/pop with pushes into the already-consumed range
    /// (the engine's same-tick self-sends) keeps the order total.
    #[test]
    fn same_tick_pushes_during_drain_pop_in_order() {
        let mut q = EventQueue::new();
        for i in 0..200u64 {
            q.push(SimTime(i * 10), Priority::COMPLETE, 0, i);
        }
        let mut popped = Vec::new();
        while let Some(e) = q.pop() {
            // Every third pop schedules a same-tick follow-up (higher
            // priority value — runs after all same-tick COMPLETEs).
            if e.payload % 3 == 0 && e.payload < 1_000 {
                q.push(e.time, Priority::SCHEDULE, 0, 10_000 + e.payload);
            }
            popped.push((e.time.ticks(), e.priority.0, e.payload));
        }
        // Follow-ups pop at their tick, after the COMPLETE that spawned
        // them, and the whole sequence is sorted by (time, priority, seq
        // as reflected in push order).
        let mut sorted = popped.clone();
        sorted.sort();
        assert_eq!(popped, sorted);
        assert_eq!(popped.len(), 200 + popped.iter().filter(|p| p.2 >= 10_000).count());
    }

    /// A single-timestamp storm larger than any batch threshold must
    /// degenerate to one sort (not recurse) and stay FIFO.
    #[test]
    fn same_time_storm_degenerates_to_sorted_vec() {
        let mut q = EventQueue::new();
        // Push a far-future marker so the storm lands in rungs/top.
        q.push(SimTime(1_000_000), Priority::DEFAULT, 0, u64::MAX);
        for i in 0..1_000u64 {
            q.push(SimTime(777), Priority::DEFAULT, 0, i);
        }
        let order: Vec<u64> =
            std::iter::from_fn(|| q.pop()).map(|e| e.payload).take(1_000).collect();
        assert_eq!(order, (0..1_000).collect::<Vec<_>>(), "same-key FIFO broken");
        assert_eq!(q.pop().unwrap().payload, u64::MAX);
    }

    #[test]
    fn len_tracks_push_pop_across_tiers() {
        let mut q = EventQueue::new();
        for i in 0..300u64 {
            q.push(SimTime(i * 997 % 5_000), Priority::DEFAULT, 0, i);
        }
        assert_eq!(q.len(), 300);
        for _ in 0..120 {
            q.pop().unwrap();
        }
        assert_eq!(q.len(), 180);
        q.push(SimTime(0), Priority::DEFAULT, 0, 999); // into the past
        assert_eq!(q.len(), 181);
        assert_eq!(q.pop().unwrap().payload, 999, "past push pops first");
        let mut rest = 0;
        while q.pop().is_some() {
            rest += 1;
        }
        assert_eq!(rest, 180);
        assert!(q.is_empty());
        assert_eq!(q.peek_time(), None);
    }
}
