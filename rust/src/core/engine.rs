//! The sequential discrete-event engine (SST-Core analogue).
//!
//! Owns the component table, link table, event queue, statistics registry
//! and RNG. Delivery order is deterministic: (time, priority, sequence).
//! The parallel engine in `crate::parallel` runs one of these per rank.
//!
//! The tick loop runs off the ladder queue's prepared bottom rung
//! ([`crate::core::event::EventQueue`]): a pop is one cached time
//! compare plus `Vec::pop` — no heap sift, no tuple-key re-comparison —
//! and same-timestamp runs drain off the back of one sorted batch. The
//! inclusive/exclusive window mode is folded into a single half-open
//! cut *before* the loop, so the per-event path has exactly one branch.

use crate::analysis::sanitizer;
use crate::core::component::{Component, Ctx, Emit};
use crate::core::event::{ComponentId, EventQueue, Priority};
use crate::core::link::LinkTable;
use crate::core::rng::Rng;
use crate::core::stats::StatRegistry;
use crate::core::time::{SimDuration, SimTime};
use std::collections::HashMap;

/// Outcome of a run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RunReport {
    /// Events delivered.
    pub events: u64,
    /// Clock value when the run stopped.
    pub end_time: SimTime,
    /// True if the run stopped because a component requested it or the
    /// horizon was reached (false = queue drained).
    pub stopped_early: bool,
}

/// Sequential discrete-event engine.
pub struct Engine<P> {
    components: Vec<Box<dyn Component<P>>>,
    names: HashMap<String, ComponentId>,
    queue: EventQueue<P>,
    links: LinkTable,
    stats: StatRegistry,
    rng: Rng,
    now: SimTime,
    events_processed: u64,
    emit_buf: Vec<Emit<P>>,
    initialized: bool,
}

impl<P> Engine<P> {
    pub fn new(seed: u64) -> Self {
        Engine {
            components: Vec::new(),
            names: HashMap::new(),
            queue: EventQueue::new(),
            links: LinkTable::new(),
            stats: StatRegistry::new(),
            rng: Rng::new(seed),
            now: SimTime::ZERO,
            events_processed: 0,
            emit_buf: Vec::new(),
            initialized: false,
        }
    }

    /// Register a component; returns its id.
    pub fn add(&mut self, c: Box<dyn Component<P>>) -> ComponentId {
        let id = self.components.len();
        let prev = self.names.insert(c.name().to_string(), id);
        assert!(prev.is_none(), "duplicate component name {:?}", c.name());
        self.components.push(c);
        id
    }

    /// Look up a component id by name.
    pub fn id_of(&self, name: &str) -> Option<ComponentId> {
        self.names.get(name).copied()
    }

    /// Configure a directed link.
    pub fn connect(&mut self, from: ComponentId, to: ComponentId, latency: SimDuration) {
        self.links.connect(from, to, latency);
    }

    pub fn links(&self) -> &LinkTable {
        &self.links
    }

    /// Schedule an event from outside any component (initial stimuli).
    pub fn schedule(
        &mut self,
        time: SimTime,
        priority: Priority,
        target: ComponentId,
        payload: P,
    ) {
        self.queue.push(time, priority, target, payload);
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    pub fn stats(&self) -> &StatRegistry {
        &self.stats
    }

    pub fn stats_mut(&mut self) -> &mut StatRegistry {
        &mut self.stats
    }

    pub fn pending_events(&self) -> usize {
        self.queue.len()
    }

    /// Borrow a component for result extraction (downcast via `as_any`).
    pub fn component(&self, id: ComponentId) -> &dyn Component<P> {
        self.components[id].as_ref()
    }

    /// Typed accessor: `engine.get::<JobExecutor>(id)`.
    pub fn get<T: 'static>(&self, id: ComponentId) -> Option<&T> {
        self.components[id].as_any().downcast_ref::<T>()
    }

    pub fn get_mut<T: 'static>(&mut self, id: ComponentId) -> Option<&mut T> {
        self.components[id].as_any_mut().downcast_mut::<T>()
    }

    fn init_components(&mut self) {
        if self.initialized {
            return;
        }
        self.initialized = true;
        let mut stop = false;
        for id in 0..self.components.len() {
            let mut ctx = Ctx {
                now: self.now,
                self_id: id,
                out: &mut self.emit_buf,
                links: &self.links,
                stats: &mut self.stats,
                rng: &mut self.rng,
                stop: &mut stop,
            };
            self.components[id].init(&mut ctx);
        }
        for e in self.emit_buf.drain(..) {
            self.queue.push(e.time, e.priority, e.target, e.payload);
        }
    }

    /// Run until the queue drains or `horizon` is passed.
    pub fn run(&mut self, horizon: Option<SimTime>) -> RunReport {
        self.init_components();
        let bound = horizon.unwrap_or(SimTime::MAX);
        let mut stopped_early = self.drain_until(bound, true);
        if !stopped_early && !self.queue.is_empty() {
            // Horizon cut the run short.
            stopped_early = true;
            self.now = bound;
        }
        self.finish_components();
        RunReport { events: self.events_processed, end_time: self.now, stopped_early }
    }

    /// Earliest pending event time (parallel LBTS computation).
    pub fn next_event_time(&mut self) -> Option<SimTime> {
        self.init_components(); // init may seed the queue
        self.queue.peek_time()
    }

    /// Conservative window step: process every event with time < `bound`
    /// (half-open YAWNS window), then return. Does NOT run `finish`
    /// hooks — call [`Engine::finish`] when the whole parallel run ends.
    pub fn run_window(&mut self, bound: SimTime) -> u64 {
        self.init_components();
        let before = self.events_processed;
        let mut stop = false;
        while let Some(ev) = self.queue.pop_before(bound) {
            if sanitizer::ACTIVE {
                sanitizer::check_engine_time(self.now.ticks(), ev.time.ticks());
            }
            self.now = ev.time;
            self.events_processed += 1;
            let mut ctx = Ctx {
                now: self.now,
                self_id: ev.target,
                out: &mut self.emit_buf,
                links: &self.links,
                stats: &mut self.stats,
                rng: &mut self.rng,
                stop: &mut stop,
            };
            self.components[ev.target].handle(ev.payload, &mut ctx);
            for e in self.emit_buf.drain(..) {
                self.queue.push(e.time, e.priority, e.target, e.payload);
            }
            if stop {
                break;
            }
        }
        self.events_processed - before
    }

    /// Run `finish` hooks (close statistics) after windowed execution.
    pub fn finish(&mut self) {
        self.finish_components();
    }

    /// Resumable stepping: deliver every event with `time <= bound`
    /// (inclusive), then return how many were delivered. Unlike
    /// [`Engine::run`] this neither runs `finish` hooks nor advances
    /// `now` past the last delivered event, so stepping through any
    /// partition of bounds replays the exact event sequence — and
    /// therefore the exact end state — of one uninterrupted run.
    pub fn step_until(&mut self, bound: SimTime) -> u64 {
        self.init_components();
        let before = self.events_processed;
        self.drain_until(bound, true);
        self.events_processed - before
    }

    /// Deep-copy the whole engine — components, pending events, link
    /// and name tables, statistics, RNG and clock — so the copy can
    /// run forward without perturbing the original (what-if wait-time
    /// speculation, resumable serving). The event queue clone keeps
    /// its sequence counter, so the copy's future pushes tie-break
    /// identically; byte-identity of `snapshot -> resume -> run` with
    /// an uninterrupted run is pinned by `tests/snapshot.rs`.
    ///
    /// Errors (naming the component) when any component is not
    /// snapshotable — see [`Component::snapshot_box`]; a streamed job
    /// source is the one stock example.
    pub fn snapshot(&self) -> Result<Engine<P>, String>
    where
        P: Clone,
    {
        let mut components: Vec<Box<dyn Component<P>>> =
            Vec::with_capacity(self.components.len());
        for c in &self.components {
            match c.snapshot_box() {
                Some(copy) => components.push(copy),
                None => {
                    return Err(format!(
                        "component {:?} cannot be snapshotted (non-cloneable state)",
                        c.name()
                    ))
                }
            }
        }
        Ok(Engine {
            components,
            names: self.names.clone(),
            queue: self.queue.clone(),
            links: self.links.clone(),
            stats: self.stats.clone(),
            rng: self.rng.clone(),
            now: self.now,
            events_processed: self.events_processed,
            // Always empty between events; a snapshot is only taken
            // at an event boundary.
            emit_buf: Vec::new(),
            initialized: self.initialized,
        })
    }

    /// Inclusive-bound event loop shared by `run`; returns true if a
    /// component requested stop. The window mode is normalized to one
    /// half-open cut up front so each pop is a single time compare on
    /// the ladder queue's prepared bottom — the tick loop never
    /// re-evaluates the mode or re-compares tuple keys. (An inclusive
    /// bound of `SimTime::MAX` saturates: an event at exactly
    /// `u64::MAX` ticks is unreachable by construction — links and
    /// runtimes would overflow long before.)
    fn drain_until(&mut self, bound: SimTime, inclusive: bool) -> bool {
        let cut = if inclusive { SimTime(bound.ticks().saturating_add(1)) } else { bound };
        let mut stop = false;
        loop {
            let Some(ev) = self.queue.pop_before(cut) else { break };
            if sanitizer::ACTIVE {
                sanitizer::check_engine_time(self.now.ticks(), ev.time.ticks());
            }
            self.now = ev.time;
            self.events_processed += 1;
            let mut ctx = Ctx {
                now: self.now,
                self_id: ev.target,
                out: &mut self.emit_buf,
                links: &self.links,
                stats: &mut self.stats,
                rng: &mut self.rng,
                stop: &mut stop,
            };
            self.components[ev.target].handle(ev.payload, &mut ctx);
            for e in self.emit_buf.drain(..) {
                self.queue.push(e.time, e.priority, e.target, e.payload);
            }
            if stop {
                return true;
            }
        }
        false
    }

    fn finish_components(&mut self) {
        let mut stop = false;
        for id in 0..self.components.len() {
            let mut ctx = Ctx {
                now: self.now,
                self_id: id,
                out: &mut self.emit_buf,
                links: &self.links,
                stats: &mut self.stats,
                rng: &mut self.rng,
                stop: &mut stop,
            };
            self.components[id].finish(&mut ctx);
        }
        self.emit_buf.clear(); // finish() may not schedule new work
    }

    /// Events delivered so far.
    pub fn events_processed(&self) -> u64 {
        self.events_processed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::any::Any;

    /// Ping-pong pair: A sends to B, B replies, N rounds.
    struct Pinger {
        name: String,
        peer: ComponentId,
        rounds_left: u32,
        seen: Vec<SimTime>,
    }

    impl Component<u32> for Pinger {
        fn name(&self) -> &str {
            &self.name
        }
        fn handle(&mut self, v: u32, ctx: &mut Ctx<u32>) {
            self.seen.push(ctx.now());
            if self.rounds_left > 0 {
                self.rounds_left -= 1;
                ctx.send(self.peer, Priority::DEFAULT, v + 1);
            }
        }
        fn as_any(&self) -> &dyn Any {
            self
        }
        fn as_any_mut(&mut self) -> &mut dyn Any {
            self
        }
    }

    fn pingpong(latency: u64) -> (Engine<u32>, ComponentId, ComponentId) {
        let mut e = Engine::new(1);
        let a = e.add(Box::new(Pinger {
            name: "a".into(),
            peer: 1,
            rounds_left: 3,
            seen: vec![],
        }));
        let b = e.add(Box::new(Pinger {
            name: "b".into(),
            peer: 0,
            rounds_left: 3,
            seen: vec![],
        }));
        e.connect(a, b, SimDuration(latency));
        e.connect(b, a, SimDuration(latency));
        (e, a, b)
    }

    #[test]
    fn pingpong_advances_clock_by_latency() {
        let (mut e, a, _b) = pingpong(5);
        e.schedule(SimTime(0), Priority::DEFAULT, a, 0);
        let r = e.run(None);
        // a@0, b@5, a@10, b@15, a@20, b@25, a@30 = 7 deliveries
        assert_eq!(r.events, 7);
        assert_eq!(r.end_time, SimTime(30));
        assert!(!r.stopped_early);
        let pa = e.get::<Pinger>(a).unwrap();
        assert_eq!(pa.seen, vec![SimTime(0), SimTime(10), SimTime(20), SimTime(30)]);
    }

    #[test]
    fn horizon_stops_run() {
        let (mut e, a, _) = pingpong(5);
        e.schedule(SimTime(0), Priority::DEFAULT, a, 0);
        let r = e.run(Some(SimTime(12)));
        assert!(r.stopped_early);
        assert_eq!(r.events, 3); // t=0,5,10
        assert_eq!(r.end_time, SimTime(12));
    }

    #[test]
    fn duplicate_names_panic() {
        let mut e: Engine<u32> = Engine::new(0);
        e.add(Box::new(Pinger { name: "x".into(), peer: 0, rounds_left: 0, seen: vec![] }));
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            e.add(Box::new(Pinger { name: "x".into(), peer: 0, rounds_left: 0, seen: vec![] }));
        }));
        assert!(res.is_err());
    }

    #[test]
    fn id_lookup() {
        let (e, a, b) = pingpong(1);
        assert_eq!(e.id_of("a"), Some(a));
        assert_eq!(e.id_of("b"), Some(b));
        assert_eq!(e.id_of("c"), None);
    }

    struct Stopper {
        at: u32,
    }
    impl Component<u32> for Stopper {
        fn name(&self) -> &str {
            "stopper"
        }
        fn handle(&mut self, v: u32, ctx: &mut Ctx<u32>) {
            if v >= self.at {
                ctx.request_stop();
            } else {
                ctx.schedule_self(SimDuration(1), Priority::DEFAULT, v + 1);
            }
        }
        fn as_any(&self) -> &dyn Any {
            self
        }
        fn as_any_mut(&mut self) -> &mut dyn Any {
            self
        }
    }

    #[test]
    fn request_stop_halts() {
        let mut e = Engine::new(0);
        let s = e.add(Box::new(Stopper { at: 5 }));
        e.schedule(SimTime(0), Priority::DEFAULT, s, 0);
        let r = e.run(None);
        assert!(r.stopped_early);
        assert_eq!(r.end_time, SimTime(5));
    }

    struct Initter {
        fired: bool,
    }
    impl Component<u32> for Initter {
        fn name(&self) -> &str {
            "initter"
        }
        fn init(&mut self, ctx: &mut Ctx<u32>) {
            ctx.schedule_self(SimDuration(3), Priority::DEFAULT, 99);
        }
        fn handle(&mut self, v: u32, _ctx: &mut Ctx<u32>) {
            assert_eq!(v, 99);
            self.fired = true;
        }
        fn as_any(&self) -> &dyn Any {
            self
        }
        fn as_any_mut(&mut self) -> &mut dyn Any {
            self
        }
    }

    #[test]
    fn init_can_schedule() {
        let mut e = Engine::new(0);
        let i = e.add(Box::new(Initter { fired: false }));
        let r = e.run(None);
        assert_eq!(r.events, 1);
        assert!(e.get::<Initter>(i).unwrap().fired);
    }

    #[test]
    fn deterministic_event_counts() {
        let run = |seed| {
            let (mut e, a, _) = pingpong(2);
            let _ = seed;
            e.schedule(SimTime(0), Priority::DEFAULT, a, 0);
            e.run(None).events
        };
        assert_eq!(run(1), run(2));
    }
}
