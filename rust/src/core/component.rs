//! Components and the context handed to them (SST-Elements analogue).
//!
//! A component is a state machine that receives timestamped payloads and
//! may emit new ones. All interaction with the engine goes through
//! [`Ctx`]: reading the clock, sending events over links, self-scheduling,
//! recording statistics, and drawing random numbers.

use crate::core::event::{ComponentId, Priority};
use crate::core::link::LinkTable;
use crate::core::rng::Rng;
use crate::core::stats::StatRegistry;
use crate::core::time::{SimDuration, SimTime};
use std::any::Any;

/// An event buffered by [`Ctx`] for the engine to enqueue.
#[derive(Debug)]
pub(crate) struct Emit<P> {
    pub time: SimTime,
    pub priority: Priority,
    pub target: ComponentId,
    pub payload: P,
}

/// Execution context passed to a component for one event delivery.
pub struct Ctx<'a, P> {
    pub(crate) now: SimTime,
    pub(crate) self_id: ComponentId,
    pub(crate) out: &'a mut Vec<Emit<P>>,
    pub(crate) links: &'a LinkTable,
    /// Engine-wide statistics registry.
    pub stats: &'a mut StatRegistry,
    /// Engine-wide deterministic RNG.
    pub rng: &'a mut Rng,
    pub(crate) stop: &'a mut bool,
}

impl<'a, P> Ctx<'a, P> {
    /// Current simulated time.
    #[inline]
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// This component's id.
    #[inline]
    pub fn self_id(&self) -> ComponentId {
        self.self_id
    }

    /// Send `payload` to `target` over the configured link; it arrives
    /// after the link latency (0 if the pair is unlinked).
    pub fn send(&mut self, target: ComponentId, priority: Priority, payload: P) {
        let lat = self.links.latency(self.self_id, target);
        self.send_in(target, lat, priority, payload);
    }

    /// Send with an additional delay on top of the link latency.
    pub fn send_after(
        &mut self,
        target: ComponentId,
        delay: SimDuration,
        priority: Priority,
        payload: P,
    ) {
        let lat = self.links.latency(self.self_id, target);
        self.send_in(target, lat + delay, priority, payload);
    }

    /// Deliver to self after `delay` (timers, periodic sampling).
    pub fn schedule_self(&mut self, delay: SimDuration, priority: Priority, payload: P) {
        self.send_in(self.self_id, delay, priority, payload);
    }

    fn send_in(
        &mut self,
        target: ComponentId,
        delay: SimDuration,
        priority: Priority,
        payload: P,
    ) {
        self.out.push(Emit { time: self.now + delay, priority, target, payload });
    }

    /// Ask the engine to stop after the current event is processed.
    pub fn request_stop(&mut self) {
        *self.stop = true;
    }
}

/// A simulation component.
pub trait Component<P> {
    /// Stable name, used for stat prefixes and debugging.
    fn name(&self) -> &str;

    /// Called once before the first event, at t=0.
    fn init(&mut self, _ctx: &mut Ctx<P>) {}

    /// Handle one delivered payload.
    fn handle(&mut self, payload: P, ctx: &mut Ctx<P>);

    /// Called once after the run ends (flush final statistics).
    fn finish(&mut self, _ctx: &mut Ctx<P>) {}

    /// Downcast support for extracting results after a run.
    fn as_any(&self) -> &dyn Any;
    fn as_any_mut(&mut self) -> &mut dyn Any;

    /// Deep-copy this component for an engine snapshot
    /// ([`crate::core::engine::Engine::snapshot`]). `None` (the
    /// default) marks the component non-snapshotable — e.g. one
    /// draining a non-rewindable job stream — which makes the whole
    /// snapshot fail with an error naming it. Implementations must
    /// copy *all* state that influences future decisions; sharing any
    /// of it would let speculation perturb the original run.
    fn snapshot_box(&self) -> Option<Box<dyn Component<P>>> {
        None
    }
}
