//! Sharded multi-domain federation engine: each cluster of a
//! [`MetaScheduler`]-style federation becomes an autonomous scheduler
//! *domain* — a full `SimInstance` with its own ladder event queue —
//! and domains are packed onto worker *shards* (ranks) driven by the
//! conservative YAWNS window runner in [`crate::parallel`].
//!
//! The meta-scheduler router runs as part of rank 0. Instead of the old
//! serial route-then-bucket pass, every routing decision happens at the
//! job's submit time inside a window and becomes a timestamped message:
//! the job is delivered to its domain at `submit + route_latency`. With
//! `lookahead == route_latency` the conservative contract holds by
//! construction — a job routed at `t >= bound - lookahead` is delivered
//! at `t + route_latency >= bound`, i.e. never inside the current
//! window.
//!
//! Determinism across shard counts is the load-bearing contract (the
//! paper's "parallel == serial, byte for byte"): router deliveries are
//! the only `Priority::ARRIVE` events a domain ever sees, so ties at
//! one timestamp resolve by queue insertion order, which equals routing
//! order whether the job was injected locally (same rank) or delivered
//! through a sorted mailbox (cross-rank). The per-domain report
//! fingerprints — and hence [`ShardedReport::fingerprint`] — are
//! byte-identical for any `shards` in 1..=domains, asserted by the
//! shard-count matrix regression tests.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::analysis::sanitizer;
use crate::core::time::{SimDuration, SimTime};
use crate::job::Job;
use crate::metrics::wait_stats;
use crate::parallel::job_rank::RankSimOpts;
use crate::parallel::{
    fnv1a, run_parallel, run_parallel_modeled, RankLogic, RankSummary, BARRIER_COST,
};
use crate::sched::Policy;
use crate::sim::multicluster::{ClusterSpec, MultiClusterReport, RouterState, Routing};
use crate::sim::{SimInstance, SimReport, Simulation};
use crate::trace::Workload;

const FNV_OFFSET: u64 = 0xcbf29ce484222325;

fn fnv_step(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Configuration of a sharded federation run.
#[derive(Clone)]
pub struct ShardOpts {
    /// Federation members; each becomes one scheduler domain.
    pub clusters: Vec<ClusterSpec>,
    pub routing: Routing,
    pub policy: Policy,
    /// Worker shards (threads). Domains map to shards round-robin
    /// (`domain % shards`); `1` is the serial engine, values above the
    /// domain count are clamped.
    pub shards: usize,
    /// Meta-scheduler -> domain delivery latency in ticks; doubles as
    /// the conservative lookahead (must be >= 1).
    pub route_latency: u64,
    /// Per-domain simulation options (faults, preemption, reservations,
    /// planning horizon, ordering); rescaled per domain exactly like
    /// the partitioned-replay ranks.
    pub sim: RankSimOpts,
}

/// A routed job in flight to its domain. Ordered by routing sequence
/// number so sorted mailbox delivery reproduces routing order exactly
/// (deliver times tie whenever two jobs are routed in one window).
pub struct RouteMsg {
    seq: u64,
    domain: usize,
    job: Box<Job>,
}

impl PartialEq for RouteMsg {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl Eq for RouteMsg {}
impl PartialOrd for RouteMsg {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for RouteMsg {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.seq.cmp(&other.seq)
    }
}

/// Rank-0 router component: feeds pending arrivals through a
/// [`RouterState`] as simulated time reaches them.
struct Router {
    /// Reverse-sorted by submit (stable), so `pop()` yields the
    /// earliest arrival and preserves original order within ties.
    pending: Vec<Job>,
    state: RouterState,
    seq: u64,
    routed: u64,
    rejected: u64,
    /// Incremental FNV-1a over (job id, chosen domain) pairs — the
    /// routing-decision digest.
    fp: u64,
}

/// What the router reports at the end of a run.
#[derive(Debug, Clone, Copy, Default)]
struct RouterOutcome {
    routed: u64,
    rejected: u64,
    fingerprint: u64,
}

/// One domain's complete result.
#[derive(Debug, Clone)]
pub struct DomainOutcome {
    pub domain: usize,
    pub name: String,
    pub report: SimReport,
    /// FNV-1a of [`SimReport::fingerprint`] — the domain's schedule
    /// digest.
    pub fingerprint: u64,
}

struct DomainSim {
    id: usize,
    name: String,
    inst: SimInstance,
}

/// Blueprint for one shard, built on the coordinating thread; the
/// simulations themselves are constructed inside the worker thread.
struct RankPlan {
    domains: Vec<(usize, ClusterSpec, RankSimOpts)>,
    router: Option<RouterPlan>,
}

struct RouterPlan {
    jobs: Vec<Job>,
    clusters: Vec<ClusterSpec>,
    routing: Routing,
}

struct ShardRank {
    me: usize,
    shards: usize,
    route_latency: u64,
    router: Option<Router>,
    domains: Vec<DomainSim>,
    collector: Arc<Mutex<Vec<Option<DomainOutcome>>>>,
    router_out: Arc<Mutex<RouterOutcome>>,
    /// Right edge of the last completed YAWNS window, for the
    /// sanitizer's conservative-delivery check.
    san_window_bound: u64,
}

impl ShardRank {
    fn from_plan(
        plan: RankPlan,
        policy: Policy,
        me: usize,
        shards: usize,
        route_latency: u64,
        collector: Arc<Mutex<Vec<Option<DomainOutcome>>>>,
        router_out: Arc<Mutex<RouterOutcome>>,
    ) -> ShardRank {
        let domains = plan
            .domains
            .into_iter()
            .map(|(id, spec, o)| {
                let w = Workload::machine(&spec.name, spec.nodes, spec.cores_per_node);
                let mut sim = Simulation::new(w, policy)
                    .with_seed(o.seed)
                    .with_faults(o.faults)
                    .with_preemption(o.preemption)
                    .with_reservations(o.reservations)
                    .with_horizon(o.planning_horizon)
                    .with_auto_horizon_params(o.auto_horizon)
                    .with_fairshare_half_life(o.fairshare_half_life)
                    .with_mem_per_node(o.mem_per_node)
                    .with_memory_aware(o.memory_aware);
                if let Some(order) = o.order {
                    sim = sim.with_order(order);
                }
                DomainSim { id, name: spec.name, inst: sim.build() }
            })
            .collect();
        let router = plan.router.map(|r| {
            let state = RouterState::new(&r.clusters, r.routing);
            Router {
                pending: r.jobs,
                state,
                seq: 0,
                routed: 0,
                rejected: 0,
                fp: FNV_OFFSET,
            }
        });
        ShardRank {
            me,
            shards,
            route_latency,
            router,
            domains,
            collector,
            router_out,
            san_window_bound: 0,
        }
    }
}

impl RankLogic for ShardRank {
    type Msg = RouteMsg;

    fn next_time(&mut self) -> Option<u64> {
        let mut min: Option<u64> = None;
        if let Some(r) = &self.router {
            if let Some(j) = r.pending.last() {
                min = Some(j.submit.ticks());
            }
        }
        for d in &mut self.domains {
            if let Some(t) = d.inst.next_time() {
                let t = t.ticks();
                min = Some(min.map_or(t, |m| m.min(t)));
            }
        }
        min
    }

    fn run_window(&mut self, bound: u64, outbox: &mut Vec<(usize, u64, RouteMsg)>) {
        let ShardRank { me, shards, route_latency, router, domains, san_window_bound, .. } = self;
        if let Some(r) = router {
            // Route every arrival inside this window. Delivery at
            // `t + route_latency >= bound` keeps the send conservative
            // whether it stays local or crosses shards.
            while r.pending.last().map_or(false, |j| j.submit.ticks() < bound) {
                let job = r.pending.pop().unwrap();
                let t = job.submit.ticks();
                match r.state.route_one(&job) {
                    None => r.rejected += 1,
                    Some(dom) => {
                        r.routed += 1;
                        r.fp = fnv_step(r.fp, &job.id.to_le_bytes());
                        r.fp = fnv_step(r.fp, &(dom as u64).to_le_bytes());
                        let deliver = t + *route_latency;
                        let dest = dom % *shards;
                        if dest == *me {
                            let d = domains
                                .iter_mut()
                                .find(|d| d.id == dom)
                                .expect("routed domain lives on its mapped shard");
                            d.inst.submit(SimTime(deliver), job);
                        } else {
                            outbox.push((
                                dest,
                                deliver,
                                RouteMsg { seq: r.seq, domain: dom, job: Box::new(job) },
                            ));
                        }
                        r.seq += 1;
                    }
                }
            }
        }
        for d in domains {
            d.inst.run_window(SimTime(bound));
        }
        *san_window_bound = bound;
    }

    fn receive(&mut self, time: u64, msg: RouteMsg) {
        if sanitizer::ACTIVE {
            sanitizer::check_delivery(time, self.san_window_bound, self.me);
        }
        let d = self
            .domains
            .iter_mut()
            .find(|d| d.id == msg.domain)
            .expect("message routed to the shard owning its domain");
        d.inst.submit(SimTime(time), *msg.job);
    }

    fn finish(&mut self) -> RankSummary {
        let mut events = 0u64;
        let mut end = 0u64;
        let mut completed = 0u64;
        let mut wait_sum = 0.0f64;
        let mut buf = Vec::new();
        for d in self.domains.drain(..) {
            let report = d.inst.finalize();
            let fp = fnv1a(report.fingerprint().as_bytes());
            events += report.events;
            end = end.max(report.end_time.ticks());
            completed += report.completed_count;
            wait_sum += report.wait_ticks_total;
            buf.extend_from_slice(&(d.id as u64).to_le_bytes());
            buf.extend_from_slice(&fp.to_le_bytes());
            self.collector.lock().unwrap()[d.id] =
                Some(DomainOutcome { domain: d.id, name: d.name, report, fingerprint: fp });
        }
        if let Some(r) = self.router.take() {
            *self.router_out.lock().unwrap() =
                RouterOutcome { routed: r.routed, rejected: r.rejected, fingerprint: r.fp };
        }
        RankSummary { events, end_time: end, completed, wait_sum, fingerprint: fnv1a(&buf) }
    }
}

/// Aggregate result of a sharded federation run.
#[derive(Debug, Clone)]
pub struct ShardedReport {
    pub shards: usize,
    pub routing: Routing,
    pub route_latency: u64,
    pub windows: u64,
    pub wall: Duration,
    /// Set by the modeled (non-threaded) runner: single-core time spent
    /// executing all shards serially.
    pub serial_wall: Option<Duration>,
    /// Jobs the router sent to a domain.
    pub routed: u64,
    /// Jobs fitting no cluster.
    pub rejected: u64,
    /// FNV-1a over (job id, domain) routing decisions in order.
    pub router_fingerprint: u64,
    /// Per-domain results, in domain order.
    pub domains: Vec<DomainOutcome>,
    pub summaries: Vec<RankSummary>,
}

impl ShardedReport {
    pub fn total_events(&self) -> u64 {
        self.domains.iter().map(|d| d.report.events).sum()
    }

    pub fn total_completed(&self) -> u64 {
        self.domains.iter().map(|d| d.report.completed_count).sum()
    }

    pub fn end_time(&self) -> SimTime {
        self.domains.iter().map(|d| d.report.end_time).max().unwrap_or(SimTime::ZERO)
    }

    pub fn mean_wait(&self) -> f64 {
        let n = self.total_completed();
        if n == 0 {
            0.0
        } else {
            self.domains.iter().map(|d| d.report.wait_ticks_total).sum::<f64>() / n as f64
        }
    }

    /// Events per wall-second (the Fig 5 scaling metric).
    pub fn event_rate(&self) -> f64 {
        self.total_events() as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    /// The decision digest: routing decisions + every domain's schedule
    /// digest, folded in domain order — independent of how domains were
    /// mapped onto shards. Byte-identical across shard counts.
    pub fn fingerprint(&self) -> u64 {
        let mut buf = Vec::with_capacity(8 + self.domains.len() * 8);
        buf.extend_from_slice(&self.router_fingerprint.to_le_bytes());
        for d in &self.domains {
            buf.extend_from_slice(&d.fingerprint.to_le_bytes());
        }
        fnv1a(&buf)
    }

    /// Downgrade to the legacy federation report shape.
    pub fn into_multicluster(self) -> MultiClusterReport {
        let fingerprint = self.fingerprint();
        let mut per_cluster = Vec::with_capacity(self.domains.len());
        let mut all_jobs = Vec::new();
        let mut rejected = self.rejected;
        let mut end = SimTime::ZERO;
        for d in self.domains {
            per_cluster.push((
                d.name,
                wait_stats(&d.report.completed),
                d.report.mean_utilization,
            ));
            rejected += d.report.rejected;
            end = end.max(d.report.end_time);
            all_jobs.extend(d.report.completed);
        }
        MultiClusterReport {
            routing: self.routing,
            per_cluster,
            all_jobs,
            rejected,
            end_time: end,
            fingerprint,
        }
    }
}

/// Run a federation on the sharded conservative engine.
///
/// `jobs` may arrive in any order; they are stably sorted by submit
/// time (the order every router implementation requires). `threaded`
/// picks real worker threads vs the serial modeled runner — identical
/// results either way (asserted by the determinism tests).
pub fn run_sharded(opts: &ShardOpts, mut jobs: Vec<Job>, threaded: bool) -> ShardedReport {
    assert!(!opts.clusters.is_empty(), "federation needs at least one cluster");
    let n_domains = opts.clusters.len();
    let shards = opts.shards.max(1).min(n_domains);
    let route_latency = opts.route_latency.max(1);

    jobs.sort_by_key(|j| j.submit); // stable: ties keep input order
    let last_submit = jobs.last().map(|j| j.submit.ticks()).unwrap_or(0);
    jobs.reverse(); // pop() = earliest

    // Domain workloads are empty machine shells, so the builder's
    // derived fault horizon (`last submit + 4 x mttr`) would collapse
    // to `4 x mttr`. Derive it here from the global trace instead —
    // identically for every domain and every shard count.
    let derived_until = if opts.sim.faults.enabled() && opts.sim.faults.until.is_none() {
        Some(
            (last_submit + route_latency)
                + SimDuration::from_f64(4.0 * opts.sim.faults.mttr).ticks(),
        )
    } else {
        opts.sim.faults.until
    };

    let collector: Arc<Mutex<Vec<Option<DomainOutcome>>>> =
        Arc::new(Mutex::new((0..n_domains).map(|_| None).collect()));
    let router_out = Arc::new(Mutex::new(RouterOutcome::default()));

    let mut plans: Vec<RankPlan> =
        (0..shards).map(|_| RankPlan { domains: Vec::new(), router: None }).collect();
    for (d, spec) in opts.clusters.iter().enumerate() {
        let mut o = opts.sim.for_rank(d, n_domains);
        o.faults.until = derived_until;
        plans[d % shards].domains.push((d, spec.clone(), o));
    }
    plans[0].router = Some(RouterPlan {
        jobs,
        clusters: opts.clusters.clone(),
        routing: opts.routing,
    });

    let policy = opts.policy;
    let builders: Vec<_> = plans
        .into_iter()
        .enumerate()
        .map(|(i, plan)| {
            let collector = Arc::clone(&collector);
            let router_out = Arc::clone(&router_out);
            move |_i: usize| {
                ShardRank::from_plan(
                    plan,
                    policy,
                    i,
                    shards,
                    route_latency,
                    collector,
                    router_out,
                )
            }
        })
        .collect();

    let par = if threaded {
        run_parallel(builders, route_latency)
    } else {
        run_parallel_modeled(builders, route_latency, BARRIER_COST)
    };

    let outcome = *router_out.lock().unwrap();
    let mut domains: Vec<DomainOutcome> = collector
        .lock()
        .unwrap()
        .drain(..)
        .map(|d| d.expect("every domain reports an outcome"))
        .collect();
    domains.sort_by_key(|d| d.domain);

    ShardedReport {
        shards,
        routing: opts.routing,
        route_latency,
        windows: par.windows,
        wall: par.wall,
        serial_wall: par.serial_wall,
        routed: outcome.routed,
        rejected: outcome.rejected,
        router_fingerprint: outcome.fingerprint,
        domains,
        summaries: par.summaries,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::MetaScheduler;
    use crate::trace::Das2Model;

    fn opts(routing: Routing, shards: usize) -> ShardOpts {
        ShardOpts {
            clusters: MetaScheduler::das2_federation(routing, Policy::FcfsBackfill).clusters,
            routing,
            policy: Policy::FcfsBackfill,
            shards,
            route_latency: 60,
            sim: RankSimOpts::default(),
        }
    }

    fn jobs(n: usize, seed: u64) -> Vec<Job> {
        Das2Model::default().generate(n, seed).scale_arrivals(0.3).jobs
    }

    #[test]
    fn completes_everything_feasible() {
        let js = jobs(1_500, 7);
        let n = js.len() as u64;
        let r = run_sharded(&opts(Routing::LeastLoaded, 2), js, true);
        assert_eq!(r.total_completed() + r.rejected, n);
        assert_eq!(r.routed + r.rejected, n);
        assert_eq!(r.domains.len(), 5);
    }

    #[test]
    fn threaded_matches_modeled() {
        let js = jobs(800, 8);
        let a = run_sharded(&opts(Routing::RoundRobin, 3), js.clone(), true);
        let b = run_sharded(&opts(Routing::RoundRobin, 3), js, false);
        assert_eq!(a.fingerprint(), b.fingerprint());
        assert_eq!(a.windows, b.windows);
        assert_eq!(a.total_events(), b.total_events());
    }

    #[test]
    fn shards_clamp_to_domain_count() {
        let js = jobs(200, 9);
        let r = run_sharded(&opts(Routing::BestFitCluster, 64), js, true);
        assert_eq!(r.shards, 5);
        assert_eq!(r.total_completed() + r.rejected, 200);
    }
}
