//! Conservative parallel engine (SST PDES analogue; paper Figs 5, 6).
//!
//! SST parallelizes by partitioning components across MPI ranks and
//! synchronizing conservatively with the minimum link latency as
//! lookahead. This module reproduces that execution model with worker
//! threads standing in for ranks, using YAWNS-style barrier windows:
//!
//! 1. every rank publishes its earliest pending event time;
//! 2. the window bound is `min(next_times) + lookahead` (LBTS);
//! 3. every rank processes its local events strictly below the bound;
//! 4. cross-rank messages (timestamped `send_time + lookahead`, hence
//!    provably >= the bound) are exchanged; repeat.
//!
//! Each rank's logic is pluggable ([`RankLogic`]), and three rank kinds
//! exist:
//!
//! * [`shard`] — the sharded federation engine: every cluster of a
//!   multi-cluster federation is an autonomous scheduler *domain* (a
//!   full simulation with its own ladder event queue), domains are
//!   packed onto shards, and the meta-scheduler router on rank 0 turns
//!   each routing decision into a conservative cross-rank message
//!   delivered `route_latency` ticks after submission (the lookahead).
//!   Decision fingerprints are byte-identical across shard counts.
//! * [`job_rank`] — partitioned replay (Fig 5): the workload is split
//!   into independent sub-cluster streams with no cross-rank traffic.
//! * [`workflow_rank`] — one workflow's tasks distributed across ranks
//!   with real cross-rank dependency traffic (Fig 6).

pub mod job_rank;
pub mod shard;
pub mod workflow_rank;

pub use job_rank::{
    partition_workload, run_jobs_parallel, run_jobs_parallel_modeled, run_jobs_parallel_opts,
    RankSimOpts,
};
pub use shard::{run_sharded, DomainOutcome, RouteMsg, ShardOpts, ShardedReport};
pub use workflow_rank::{run_workflow_parallel, run_workflow_parallel_modeled};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Barrier, Mutex};
use std::time::{Duration, Instant};

/// Per-rank simulation logic driven by the window runner.
pub trait RankLogic {
    /// Cross-rank message type. `Ord` so deliveries can be sorted into a
    /// deterministic order regardless of thread interleaving.
    type Msg: Send + Ord;

    /// Earliest pending local event time; `None` when drained.
    fn next_time(&mut self) -> Option<u64>;

    /// Process all local events with time strictly below `bound`,
    /// pushing cross-rank sends as `(dest_rank, deliver_time, msg)`.
    /// Deliver times MUST be >= `bound` (conservative contract; the
    /// runner asserts it).
    fn run_window(&mut self, bound: u64, outbox: &mut Vec<(usize, u64, Self::Msg)>);

    /// Accept a message from another rank.
    fn receive(&mut self, time: u64, msg: Self::Msg);

    /// Called once when the whole parallel run ends.
    fn finish(&mut self) -> RankSummary;
}

/// What each rank reports at the end.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RankSummary {
    pub events: u64,
    pub end_time: u64,
    pub completed: u64,
    /// Sum of wait times (for aggregate means).
    pub wait_sum: f64,
    /// Order-independent digest of the rank's results (0 when the rank
    /// logic does not compute one). Byte-equal digests across thread
    /// counts and runs are what the determinism regression tests assert.
    pub fingerprint: u64,
}

/// FNV-1a, the crate-wide helper for result digests.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Aggregate outcome of a parallel run.
#[derive(Debug, Clone)]
pub struct ParallelReport {
    pub ranks: usize,
    pub lookahead: u64,
    pub windows: u64,
    /// For [`run_parallel`]: measured wall time of the threaded run. For
    /// [`run_parallel_modeled`]: the modeled parallel wall time (see
    /// there).
    pub wall: Duration,
    /// Set by [`run_parallel_modeled`]: actual single-core time spent
    /// executing all ranks serially (the sequential comparator).
    pub serial_wall: Option<Duration>,
    pub summaries: Vec<RankSummary>,
}

impl ParallelReport {
    pub fn total_events(&self) -> u64 {
        self.summaries.iter().map(|s| s.events).sum()
    }

    pub fn total_completed(&self) -> u64 {
        self.summaries.iter().map(|s| s.completed).sum()
    }

    pub fn end_time(&self) -> u64 {
        self.summaries.iter().map(|s| s.end_time).max().unwrap_or(0)
    }

    pub fn mean_wait(&self) -> f64 {
        let n = self.total_completed();
        if n == 0 {
            0.0
        } else {
            self.summaries.iter().map(|s| s.wait_sum).sum::<f64>() / n as f64
        }
    }

    /// Events per wall-second (the scalability metric behind Fig 5).
    pub fn event_rate(&self) -> f64 {
        self.total_events() as f64 / self.wall.as_secs_f64().max(1e-9)
    }
}

/// Run `builders.len()` ranks to completion. Each builder constructs its
/// rank logic *inside* its worker thread (so rank state never needs to be
/// `Send`). `lookahead` must be >= 1 tick.
pub fn run_parallel<R, F>(builders: Vec<F>, lookahead: u64) -> ParallelReport
where
    R: RankLogic,
    R::Msg: Send,
    F: FnOnce(usize) -> R + Send,
{
    assert!(lookahead >= 1, "conservative lookahead must be at least one tick");
    let n = builders.len();
    assert!(n >= 1);
    let next_times: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(u64::MAX)).collect();
    let mailboxes: Vec<Mutex<Vec<(u64, R::Msg)>>> = (0..n).map(|_| Mutex::new(Vec::new())).collect();
    let summaries: Vec<Mutex<RankSummary>> =
        (0..n).map(|_| Mutex::new(RankSummary::default())).collect();
    let barrier = Barrier::new(n);
    let bound = AtomicU64::new(0);
    let windows = AtomicU64::new(0);

    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for (i, builder) in builders.into_iter().enumerate() {
            let next_times = &next_times;
            let mailboxes = &mailboxes;
            let summaries = &summaries;
            let barrier = &barrier;
            let bound = &bound;
            let windows = &windows;
            scope.spawn(move || {
                let mut rank = builder(i);
                let mut outbox: Vec<(usize, u64, R::Msg)> = Vec::new();
                loop {
                    // Phase A: publish local LBTS input.
                    let nt = rank.next_time().map(|t| t).unwrap_or(u64::MAX);
                    next_times[i].store(nt, Ordering::SeqCst);
                    barrier.wait();
                    // Phase B: rank 0 computes the window bound.
                    if i == 0 {
                        let min = next_times
                            .iter()
                            .map(|a| a.load(Ordering::SeqCst))
                            .min()
                            .unwrap();
                        let w = if min == u64::MAX {
                            u64::MAX
                        } else {
                            windows.fetch_add(1, Ordering::SeqCst);
                            min.saturating_add(lookahead)
                        };
                        bound.store(w, Ordering::SeqCst);
                    }
                    barrier.wait();
                    let w = bound.load(Ordering::SeqCst);
                    if w == u64::MAX {
                        break; // every rank drained and no mail in flight
                    }
                    // Phase C: process the window, route outgoing mail.
                    rank.run_window(w, &mut outbox);
                    for (dest, t, msg) in outbox.drain(..) {
                        debug_assert!(
                            t >= w,
                            "conservative violation: msg for t={t} inside window bound {w}"
                        );
                        debug_assert!(dest != i, "self-messages must stay local");
                        mailboxes[dest].lock().unwrap().push((t, msg));
                    }
                    barrier.wait();
                    // Phase D: drain own mailbox (deliveries for >= w).
                    // Sorted so delivery order is deterministic no matter
                    // how the sending threads interleaved.
                    let mut mail: Vec<(u64, R::Msg)> =
                        mailboxes[i].lock().unwrap().drain(..).collect();
                    mail.sort();
                    for (t, msg) in mail {
                        rank.receive(t, msg);
                    }
                    // Loop back to Phase A (its barrier orders D before B).
                }
                *summaries[i].lock().unwrap() = rank.finish();
            });
        }
    });
    let wall = t0.elapsed();

    ParallelReport {
        ranks: n,
        lookahead,
        windows: windows.load(Ordering::SeqCst),
        wall,
        serial_wall: None,
        summaries: summaries.into_iter().map(|m| m.into_inner().unwrap()).collect(),
    }
}

/// Default per-window synchronization cost charged by
/// [`run_parallel_modeled`]: one barrier round on a small MPI/shared-mem
/// cluster (measured `std::sync::Barrier` round-trips land in the same
/// few-microsecond range).
pub const BARRIER_COST: Duration = Duration::from_micros(5);

/// Modeled conservative-parallel run for hosts without enough cores to
/// *measure* PDES speedup (this container exposes a single CPU; the
/// paper's Figs 5-6 used multi-rank MPI).
///
/// All ranks execute serially on one core, but each rank's per-window
/// execution time is measured individually; the modeled parallel wall
/// time is the conservative-window critical path
///
/// ```text
///   wall = sum over windows of ( max over ranks of t(window, rank)
///                                + barrier_cost )
/// ```
///
/// which is exactly what a YAWNS execution with one rank per core costs,
/// ignoring memory-bandwidth sharing. Results (events, completions,
/// waits) are identical to [`run_parallel`] — same windows, same sorted
/// message delivery. EXPERIMENTS.md reports both this model and the
/// threaded measurement.
pub fn run_parallel_modeled<R, F>(
    builders: Vec<F>,
    lookahead: u64,
    barrier_cost: Duration,
) -> ParallelReport
where
    R: RankLogic,
    F: FnOnce(usize) -> R,
{
    assert!(lookahead >= 1, "conservative lookahead must be at least one tick");
    let n = builders.len();
    assert!(n >= 1);
    let serial_t0 = Instant::now();
    let mut ranks: Vec<R> =
        builders.into_iter().enumerate().map(|(i, b)| b(i)).collect();
    let mut mailboxes: Vec<Vec<(u64, R::Msg)>> = (0..n).map(|_| Vec::new()).collect();
    let mut modeled = Duration::ZERO;
    let mut windows = 0u64;
    let mut outbox: Vec<(usize, u64, R::Msg)> = Vec::new();
    loop {
        let min = ranks
            .iter_mut()
            .map(|r| r.next_time().unwrap_or(u64::MAX))
            .min()
            .unwrap();
        if min == u64::MAX {
            break;
        }
        let bound = min.saturating_add(lookahead);
        windows += 1;
        let mut max_dt = Duration::ZERO;
        for (i, rank) in ranks.iter_mut().enumerate() {
            let t0 = Instant::now();
            rank.run_window(bound, &mut outbox);
            max_dt = max_dt.max(t0.elapsed());
            for (dest, t, msg) in outbox.drain(..) {
                debug_assert!(t >= bound, "conservative violation");
                debug_assert!(dest != i);
                mailboxes[dest].push((t, msg));
            }
        }
        for (i, rank) in ranks.iter_mut().enumerate() {
            let mut mail = std::mem::take(&mut mailboxes[i]);
            mail.sort();
            for (t, msg) in mail {
                rank.receive(t, msg);
            }
        }
        modeled += max_dt + barrier_cost;
    }
    let serial_wall = serial_t0.elapsed();
    ParallelReport {
        ranks: n,
        lookahead,
        windows,
        wall: modeled,
        serial_wall: Some(serial_wall),
        summaries: ranks.iter_mut().map(|r| r.finish()).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A rank that counts down `k` self-events spaced `gap` apart and
    /// sends a token to the next rank on each event (ring traffic).
    struct Ring {
        me: usize,
        n: usize,
        pending: Vec<u64>, // local event times
        received: Vec<(u64, usize)>,
        events: u64,
        clock: u64,
    }

    impl RankLogic for Ring {
        type Msg = usize;

        fn next_time(&mut self) -> Option<u64> {
            self.pending.iter().copied().min()
        }

        fn run_window(&mut self, bound: u64, outbox: &mut Vec<(usize, u64, usize)>) {
            self.pending.sort_unstable();
            while let Some(&t) = self.pending.first() {
                if t >= bound {
                    break;
                }
                self.pending.remove(0);
                assert!(t >= self.clock, "causality violated");
                self.clock = t;
                self.events += 1;
                let dest = (self.me + 1) % self.n;
                if dest != self.me {
                    outbox.push((dest, t + 10, self.me)); // latency = lookahead
                }
            }
        }

        fn receive(&mut self, time: u64, msg: usize) {
            self.received.push((time, msg));
            // Each token triggers one more local event (bounded chain).
            if self.received.len() <= 3 {
                self.pending.push(time);
            }
        }

        fn finish(&mut self) -> RankSummary {
            RankSummary {
                events: self.events,
                end_time: self.clock,
                completed: self.received.len() as u64,
                wait_sum: 0.0,
                fingerprint: 0,
            }
        }
    }

    fn ring(n: usize) -> ParallelReport {
        let builders: Vec<_> = (0..n)
            .map(|_| {
                move |i: usize| Ring {
                    me: i,
                    n,
                    pending: vec![i as u64 * 3],
                    received: vec![],
                    events: 0,
                    clock: 0,
                }
            })
            .collect();
        run_parallel(builders, 10)
    }

    #[test]
    fn single_rank_terminates() {
        let r = ring(1);
        assert_eq!(r.ranks, 1);
        assert_eq!(r.summaries[0].events, 1); // no self-messages
    }

    #[test]
    fn ring_delivers_and_terminates() {
        let r = ring(4);
        // Each rank fires its seed event + 3 received-token events.
        assert_eq!(r.total_events(), 4 * 4);
        for s in &r.summaries {
            assert_eq!(s.completed, 4); // 3 accepted + 1 dropped token
        }
        assert!(r.windows > 0);
    }

    #[test]
    fn deterministic_event_totals_across_runs() {
        let a = ring(4);
        let b = ring(4);
        assert_eq!(a.total_events(), b.total_events());
        assert_eq!(a.end_time(), b.end_time());
    }

    #[test]
    #[should_panic(expected = "lookahead")]
    fn zero_lookahead_rejected() {
        let builders = vec![|i: usize| Ring {
            me: i,
            n: 1,
            pending: vec![],
            received: vec![],
            events: 0,
            clock: 0,
        }];
        run_parallel(builders, 0);
    }
}
