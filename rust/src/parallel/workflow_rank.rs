//! Distributed workflow execution (paper Fig 6): one workflow's tasks are
//! partitioned across ranks (owner = task id mod ranks, as SST partitions
//! components); dependency edges that cross ranks become real
//! conservative messages with the link latency as lookahead.

use crate::core::event::{EventQueue, Priority};
use crate::core::time::SimTime;
use crate::parallel::{run_parallel, run_parallel_modeled, ParallelReport, RankLogic, RankSummary, BARRIER_COST};
use crate::workflow::task::TaskId;
use crate::workflow::Workflow;
use std::collections::{BTreeMap, VecDeque};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum LEv {
    /// A running task finished.
    Done(TaskId),
    /// A task's dependencies were satisfied at this time.
    Ready(TaskId),
}

struct WorkflowRank {
    me: usize,
    ranks: usize,
    latency: u64,
    wf: Workflow,
    /// Remaining dependency count for owned tasks.
    pending: BTreeMap<TaskId, usize>,
    /// The shared ladder event queue — same `(time, priority, seq)`
    /// total order the sequential engine uses (the rank's old private
    /// `BinaryHeap<Reverse<(t, seq, ev)>>` keyed identically: one
    /// priority level, FIFO by push order, so the migration is
    /// order-preserving by construction).
    queue: EventQueue<LEv>,
    /// (task, became ready at) in FIFO order.
    ready: VecDeque<(TaskId, u64)>,
    free_cpu: u64,
    clock: u64,
    events: u64,
    completed: u64,
    wait_sum: f64,
}

impl WorkflowRank {
    fn new(wf: Workflow, me: usize, ranks: usize, cpu: u64, latency: u64) -> WorkflowRank {
        let mut pending = BTreeMap::new();
        let mut queue = EventQueue::new();
        for (&id, task) in &wf.tasks {
            if id as usize % ranks != me {
                continue;
            }
            assert!(
                task.resources.cpu <= cpu,
                "task {id} needs {} cpu but rank pool is {cpu}",
                task.resources.cpu
            );
            let deg = task.dependencies.len();
            pending.insert(id, deg);
            if deg == 0 {
                queue.push(SimTime(0), Priority::DEFAULT, 0, LEv::Ready(id));
            }
        }
        WorkflowRank {
            me,
            ranks,
            latency,
            wf,
            pending,
            queue,
            ready: VecDeque::new(),
            free_cpu: cpu,
            clock: 0,
            events: 0,
            completed: 0,
            wait_sum: 0.0,
        }
    }

    fn owner(&self, id: TaskId) -> usize {
        id as usize % self.ranks
    }

    fn push(&mut self, t: u64, ev: LEv) {
        self.queue.push(SimTime(t), Priority::DEFAULT, 0, ev);
    }

    /// Start every ready task that fits, FIFO (list scheduling, same
    /// discipline as `workflow::exec`). Early-exits once the pool is
    /// exhausted so a long blocked queue is not rescanned per event.
    fn try_start(&mut self, now: u64) {
        let mut k = 0;
        while k < self.ready.len() {
            if self.free_cpu == 0 {
                return;
            }
            let (id, ready_at) = self.ready[k];
            let (cpu, dur) = {
                let t = &self.wf.tasks[&id];
                (t.resources.cpu, t.execution_time.ticks())
            };
            if cpu <= self.free_cpu {
                self.ready.remove(k);
                self.free_cpu -= cpu;
                self.wait_sum += (now - ready_at) as f64;
                self.push(now + dur, LEv::Done(id));
            } else {
                k += 1;
            }
        }
    }
}

impl RankLogic for WorkflowRank {
    /// Message: "this parent task completed" (dependency trigger).
    type Msg = TaskId;

    fn next_time(&mut self) -> Option<u64> {
        self.queue.peek_time().map(|t| t.ticks())
    }

    fn run_window(&mut self, bound: u64, outbox: &mut Vec<(usize, u64, TaskId)>) {
        // Rung-local scan: the half-open window pops straight off the
        // ladder's prepared bottom — one time compare per event, no
        // peek/pop double traversal.
        while let Some(sched) = self.queue.pop_before(SimTime(bound)) {
            let (t, ev) = (sched.time.ticks(), sched.payload);
            debug_assert!(t >= self.clock);
            self.clock = t;
            self.events += 1;
            match ev {
                LEv::Ready(id) => {
                    self.ready.push_back((id, t));
                    self.try_start(t);
                }
                LEv::Done(id) => {
                    self.free_cpu += self.wf.tasks[&id].resources.cpu;
                    self.completed += 1;
                    // Trigger dependents: local decrement, remote message
                    // (one per owning rank).
                    let mut remote: Vec<usize> = Vec::new();
                    let children = self.wf.dag.children(id).to_vec();
                    for child in children {
                        let o = self.owner(child);
                        if o == self.me {
                            let p = self.pending.get_mut(&child).unwrap();
                            *p -= 1;
                            if *p == 0 {
                                self.push(t, LEv::Ready(child));
                            }
                        } else if !remote.contains(&o) {
                            remote.push(o);
                        }
                    }
                    for o in remote {
                        outbox.push((o, t + self.latency, id));
                    }
                    self.try_start(t);
                }
            }
        }
    }

    fn receive(&mut self, time: u64, parent: TaskId) {
        for &child in self.wf.dag.children(parent).to_vec().iter() {
            if self.owner(child) != self.me {
                continue;
            }
            let p = self.pending.get_mut(&child).unwrap();
            debug_assert!(*p > 0, "double trigger for task {child}");
            *p -= 1;
            if *p == 0 {
                self.push(time, LEv::Ready(child));
            }
        }
    }

    fn finish(&mut self) -> RankSummary {
        RankSummary {
            events: self.events,
            end_time: self.clock,
            completed: self.completed,
            wait_sum: self.wait_sum,
            fingerprint: 0,
        }
    }
}

/// Execute `workflow` across `ranks` threads; total CPU pool is divided
/// evenly; cross-rank dependency latency = `lookahead` ticks.
pub fn run_workflow_parallel(
    workflow: &Workflow,
    ranks: usize,
    total_cpu: u64,
    lookahead: u64,
) -> ParallelReport {
    let r = ranks.max(1);
    let cpu_each = (total_cpu / r as u64).max(1);
    let builders: Vec<_> = (0..r)
        .map(|_| {
            let wf = workflow.clone();
            move |i: usize| WorkflowRank::new(wf, i, r, cpu_each, lookahead)
        })
        .collect();
    run_parallel(builders, lookahead)
}

/// Modeled-speedup variant (single-core hosts): see
/// [`crate::parallel::run_parallel_modeled`].
pub fn run_workflow_parallel_modeled(
    workflow: &Workflow,
    ranks: usize,
    total_cpu: u64,
    lookahead: u64,
) -> ParallelReport {
    let r = ranks.max(1);
    let cpu_each = (total_cpu / r as u64).max(1);
    let builders: Vec<_> = (0..r)
        .map(|_| {
            let wf = workflow.clone();
            move |i: usize| WorkflowRank::new(wf, i, r, cpu_each, lookahead)
        })
        .collect();
    run_parallel_modeled(builders, lookahead, BARRIER_COST)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::generators::{epigenomics, montage, sipht};
    use crate::workflow::task::Task;

    fn diamond() -> Workflow {
        Workflow::new(
            1,
            "d",
            vec![
                Task::new(1, 100, 1, 0),
                Task::new(2, 150, 1, 0).with_deps(vec![1]),
                Task::new(3, 200, 1, 0).with_deps(vec![1]),
                Task::new(4, 300, 1, 0).with_deps(vec![2, 3]),
            ],
        )
        .unwrap()
    }

    #[test]
    fn single_rank_matches_sequential_executor() {
        let w = diamond();
        let seq = crate::workflow::WorkflowExecutor::new(8, u64::MAX).run(w.clone());
        let par = run_workflow_parallel(&w, 1, 8, 1);
        assert_eq!(par.total_completed(), 4);
        assert_eq!(par.end_time(), seq.makespan.ticks());
    }

    #[test]
    fn all_tasks_complete_across_rank_counts() {
        let w = montage(24, 1, true);
        let n = w.len() as u64;
        for ranks in [1usize, 2, 4] {
            let r = run_workflow_parallel(&w, ranks, 32, 5);
            assert_eq!(r.total_completed(), n, "ranks={ranks}");
        }
    }

    #[test]
    fn cross_rank_latency_only_stretches_makespan() {
        // With 2 ranks the diamond's edges cross ranks (1->2, 2->4 etc.);
        // each crossing adds `lookahead` latency, so the parallel makespan
        // is bounded by sequential + depth * latency and is never shorter
        // than the critical path.
        let w = diamond();
        let crit = w.critical_path_time() as u64;
        let par = run_workflow_parallel(&w, 2, 8, 7);
        assert!(par.end_time() >= crit);
        assert!(par.end_time() <= crit + 7 * 3, "end {}", par.end_time());
    }

    #[test]
    fn dependencies_respected_under_distribution() {
        // Implicitly checked by pending counters (debug_assert double
        // trigger) and completion totals; run a deeper DAG for coverage.
        let w = epigenomics(4, 3, 1, true);
        let n = w.len() as u64;
        let r = run_workflow_parallel(&w, 4, 16, 3);
        assert_eq!(r.total_completed(), n);
        // End time never below the critical path.
        assert!(r.end_time() as f64 >= w.critical_path_time());
    }

    #[test]
    fn sipht_runs_distributed() {
        let w = sipht(2, 1, true);
        let r = run_workflow_parallel(&w, 3, 12, 2);
        assert_eq!(r.total_completed(), w.len() as u64);
        assert!(r.windows > 0);
    }

    #[test]
    fn deterministic() {
        let w = montage(16, 2, false);
        let a = run_workflow_parallel(&w, 4, 16, 5);
        let b = run_workflow_parallel(&w, 4, 16, 5);
        assert_eq!(a.end_time(), b.end_time());
        assert_eq!(a.total_events(), b.total_events());
        assert_eq!(a.mean_wait(), b.mean_wait());
    }
}
