//! Job-scheduling ranks (paper Fig 5): the workload is partitioned into
//! independent sub-cluster streams — exactly how the DAS-2 grid the trace
//! comes from was operated (five autonomous clusters) and how SST
//! partitions component graphs with no cross-partition links. Each rank
//! runs a complete scheduler+executor simulation over its share; the
//! conservative runner provides the barrier-window execution whose cost
//! (windows x barriers) is what limits speedup, as in SST.

use crate::parallel::{
    fnv1a, run_parallel, run_parallel_modeled, ParallelReport, RankLogic, RankSummary,
    BARRIER_COST,
};
use crate::sched::{OrderKind, Policy, PreemptionConfig};
use crate::sim::{
    AutoHorizonParams, FaultConfig, Horizon, ReservationSpec, SimInstance, Simulation,
    DEFAULT_FAIRSHARE_HALF_LIFE,
};
use crate::trace::Workload;

/// Per-rank simulation options for fault-aware parallel runs.
///
/// `faults` and `reservations` describe the *whole* cluster; the runner
/// rescales them per rank so aggregate behavior matches the serial run
/// of the same config: each of the R sub-clusters gets `mtbf x R`
/// (preserving the total failure rate), a rank-derived injector seed
/// (decorrelating failure instants across ranks), and
/// `ceil(nodes / R)` of every reservation.
#[derive(Debug, Clone)]
pub struct RankSimOpts {
    pub seed: u64,
    pub faults: FaultConfig,
    pub preemption: PreemptionConfig,
    pub reservations: Vec<ReservationSpec>,
    /// Availability-timeline planning-horizon policy. Applied per rank
    /// unchanged — the horizon is a fidelity knob, not a capacity, so it
    /// does not rescale with the rank count (auto derives from each
    /// rank's own queue).
    pub planning_horizon: Horizon,
    /// `Horizon::Auto` tunables (`planning.auto_*`); per rank unchanged
    /// for the same reason.
    pub auto_horizon: AutoHorizonParams,
    /// Queue-ordering override; applied per rank unchanged (fair-share
    /// usage is per-rank state, exactly like the per-cluster queues the
    /// partitioning models).
    pub order: Option<OrderKind>,
    /// Fair-share usage-decay half-life (ticks).
    pub fairshare_half_life: u64,
    /// Per-node memory (MB); identical on every rank (nodes are divided,
    /// not shrunk).
    pub mem_per_node: u64,
    /// Plan memory as a second timeline dimension.
    pub memory_aware: bool,
}

impl RankSimOpts {
    /// The slice of this cluster-wide config that rank `i` of `ranks`
    /// simulates (see the type docs). Also used by the sharded
    /// federation engine to derive per-domain options.
    pub(crate) fn for_rank(&self, i: usize, ranks: usize) -> RankSimOpts {
        let r = ranks.max(1);
        let mut o = self.clone();
        o.faults.mtbf *= r as f64;
        o.faults.seed = self
            .faults
            .seed
            .wrapping_add((i as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15));
        for resv in &mut o.reservations {
            resv.nodes = resv.nodes.div_ceil(r);
        }
        o
    }
}

impl Default for RankSimOpts {
    fn default() -> Self {
        RankSimOpts {
            seed: 1,
            faults: FaultConfig::default(),
            preemption: PreemptionConfig::default(),
            reservations: Vec::new(),
            planning_horizon: Horizon::Exact,
            auto_horizon: AutoHorizonParams::default(),
            order: None,
            fairshare_half_life: DEFAULT_FAIRSHARE_HALF_LIFE,
            mem_per_node: 0,
            memory_aware: false,
        }
    }
}

/// Split a workload into `ranks` sub-workloads: jobs round-robin (keeping
/// every stream's arrival mix representative), nodes divided evenly.
pub fn partition_workload(w: &Workload, ranks: usize) -> Vec<Workload> {
    let r = ranks.max(1);
    let nodes_each = (w.nodes / r).max(1);
    let mut parts: Vec<Vec<crate::job::Job>> = vec![Vec::new(); r];
    for (i, job) in w.jobs.iter().enumerate() {
        let mut j = job.clone();
        // Clamp to the sub-cluster size so partitioning never creates
        // infeasible jobs (mirrors per-cluster queues on real grids).
        j.cores = j.cores.min(nodes_each as u64 * w.cores_per_node);
        parts[i % r].push(j);
    }
    parts
        .into_iter()
        .enumerate()
        .map(|(i, jobs)| {
            Workload::new(&format!("{}-rank{}", w.name, i), jobs, nodes_each, w.cores_per_node)
        })
        .collect()
}

/// One rank = one full simulation instance.
struct JobRank {
    inst: SimInstance,
}

impl RankLogic for JobRank {
    type Msg = (); // no cross-cluster traffic in this partitioning

    fn next_time(&mut self) -> Option<u64> {
        self.inst.next_time().map(|t| t.ticks())
    }

    fn run_window(&mut self, bound: u64, _outbox: &mut Vec<(usize, u64, ())>) {
        self.inst.run_window(crate::core::time::SimTime(bound));
    }

    fn receive(&mut self, _time: u64, _msg: ()) {
        unreachable!("job ranks exchange no messages");
    }

    fn finish(&mut self) -> RankSummary {
        let events = self.inst.engine.events_processed();
        let end = self.inst.engine.now().ticks();
        // Extract waits without consuming the instance.
        let sched = self
            .inst
            .engine
            .get::<crate::sim::SchedulerComponent>(self.inst.engine.id_of("scheduler").unwrap())
            .unwrap();
        let completed = sched.completed.len() as u64;
        let wait_sum: f64 = sched
            .completed
            .iter()
            .filter_map(|j| j.wait_time())
            .map(|w| w.as_f64())
            .sum();
        // Digest the full per-job lifecycle so determinism tests can
        // compare threaded vs modeled vs repeated runs byte-exactly.
        let mut jobs: Vec<&crate::job::Job> = sched.completed.iter().collect();
        jobs.sort_by_key(|j| j.id);
        let mut buf = Vec::with_capacity(jobs.len() * 40);
        for j in jobs {
            for v in [
                j.id,
                j.start.map(|t| t.ticks()).unwrap_or(u64::MAX),
                j.end.map(|t| t.ticks()).unwrap_or(u64::MAX),
                j.executed.ticks(),
                j.overhead.ticks(),
                j.lost.ticks(),
                j.preempt_count as u64,
                j.fail_count as u64,
            ] {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        for v in [
            sched.fault_counters.failures,
            sched.fault_counters.repairs,
            sched.fault_counters.preemptions,
            sched.fault_counters.requeues,
        ] {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        RankSummary { events, end_time: end, completed, wait_sum, fingerprint: fnv1a(&buf) }
    }
}

/// Run `workload` under `policy` across `ranks` threads with the given
/// conservative lookahead (ticks).
pub fn run_jobs_parallel(
    workload: &Workload,
    policy: Policy,
    ranks: usize,
    lookahead: u64,
) -> ParallelReport {
    run_jobs_parallel_opts(workload, policy, ranks, lookahead, &RankSimOpts::default(), true)
}

/// Modeled-speedup variant (single-core hosts): see
/// [`crate::parallel::run_parallel_modeled`].
pub fn run_jobs_parallel_modeled(
    workload: &Workload,
    policy: Policy,
    ranks: usize,
    lookahead: u64,
) -> ParallelReport {
    run_jobs_parallel_opts(workload, policy, ranks, lookahead, &RankSimOpts::default(), false)
}

/// Fault-aware parallel run: every rank simulates its partition under
/// the same seeded failure model / preemption mode / reservations.
/// `threaded` picks real worker threads vs the serial modeled runner —
/// both produce identical results (asserted by the determinism tests).
pub fn run_jobs_parallel_opts(
    workload: &Workload,
    policy: Policy,
    ranks: usize,
    lookahead: u64,
    opts: &RankSimOpts,
    threaded: bool,
) -> ParallelReport {
    let parts = partition_workload(workload, ranks);
    let n_parts = parts.len();
    let builders: Vec<_> = parts
        .into_iter()
        .enumerate()
        .map(|(i, part)| {
            let opts = opts.for_rank(i, n_parts);
            move |_i: usize| {
                let mut sim = Simulation::new(part, policy)
                    .with_seed(opts.seed)
                    .with_faults(opts.faults)
                    .with_preemption(opts.preemption)
                    .with_reservations(opts.reservations)
                    .with_horizon(opts.planning_horizon)
                    .with_auto_horizon_params(opts.auto_horizon)
                    .with_fairshare_half_life(opts.fairshare_half_life)
                    .with_mem_per_node(opts.mem_per_node)
                    .with_memory_aware(opts.memory_aware);
                if let Some(order) = opts.order {
                    sim = sim.with_order(order);
                }
                JobRank { inst: sim.build() }
            }
        })
        .collect();
    if threaded {
        run_parallel(builders, lookahead)
    } else {
        run_parallel_modeled(builders, lookahead, BARRIER_COST)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::Das2Model;

    #[test]
    fn partition_preserves_jobs_and_divides_nodes() {
        let w = Das2Model::default().generate(1000, 3);
        let parts = partition_workload(&w, 4);
        assert_eq!(parts.len(), 4);
        assert_eq!(parts.iter().map(|p| p.jobs.len()).sum::<usize>(), 1000);
        for p in &parts {
            assert_eq!(p.nodes, w.nodes / 4);
            // No infeasible jobs after clamping.
            for j in &p.jobs {
                assert!(j.cores <= p.total_cores());
            }
        }
    }

    #[test]
    fn parallel_completes_everything_any_rank_count() {
        let w = Das2Model::default().generate(400, 9);
        for ranks in [1usize, 2, 4] {
            let r = run_jobs_parallel(&w, Policy::Fcfs, ranks, 3600);
            assert_eq!(r.total_completed(), 400, "ranks={ranks} lost jobs");
            // Event totals vary slightly with partitioning (dispatch
            // batching), but stay within the per-job event-chain bounds:
            // at least submit+start+complete, at most a few dispatches per
            // job.
            assert!(r.total_events() >= 3 * 400, "too few events");
            assert!(r.total_events() <= 10 * 400, "event explosion");
        }
    }

    #[test]
    fn rank_results_match_sequential_per_partition() {
        // Each rank must produce exactly what a sequential run of its
        // partition produces (PDES does not change results).
        let w = Das2Model::default().generate(300, 4);
        let parts = partition_workload(&w, 2);
        let par = run_jobs_parallel(&w, Policy::FcfsBackfill, 2, 3600);
        for (i, part) in parts.into_iter().enumerate() {
            let seq = crate::sim::run_policy(part, Policy::FcfsBackfill);
            assert_eq!(
                par.summaries[i].completed,
                seq.completed.len() as u64,
                "rank {i} completion mismatch"
            );
            let seq_wait: f64 = seq
                .completed
                .iter()
                .filter_map(|j| j.wait_time())
                .map(|x| x.as_f64())
                .sum();
            assert!(
                (par.summaries[i].wait_sum - seq_wait).abs() < 1e-9,
                "rank {i} wait mismatch"
            );
        }
    }
}
