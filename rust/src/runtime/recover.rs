//! Deterministic replay recovery for the serve daemon
//! (`sst-sched serve --resume <dir>`).
//!
//! Recovery inverts the write-ahead journal
//! ([`crate::runtime::journal`]): the daemon's state is a pure function
//! of `(ExperimentConfig, ordered mutating-request log)`, so rebuilding
//! it is (1) restore every sim from the latest `MARK` checkpoint — the
//! recorded step bound, not t=0 — by re-submitting its job list in
//! order, (2) re-dispatch the suffix records through the exact same
//! [`ServerCore`] request path the live daemon used, and (3) assert the
//! FNV digest of each recovered sim's fingerprint against the digest
//! the mark recorded. A mismatch is a refusal, not a warning: the
//! determinism contract makes byte-identical recovery the only
//! acceptable outcome.
//!
//! Torn tails (a crash mid-append) are detected by checksum, reported,
//! and cleanly discarded — the journal file is truncated to its intact
//! prefix before the recovered daemon appends to it. Corrupt mid-file
//! records fail hard with the record index and byte offset (see the
//! journal module's corruption taxonomy).
//!
//! What is *not* recovered, by design: daemon metrics counters restart
//! at the replayed-request counts, the draining flag (a resumed daemon
//! is a fresh serve lifetime), and in-flight connections.

use crate::config::ExperimentConfig;
use crate::runtime::journal::{self, Journal, Record};
use crate::runtime::serve::ServerCore;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// What recovery did — surfaced in the daemon's startup line and
/// asserted by the crash-fault chaos harness.
#[derive(Debug, Clone, Default)]
pub struct RecoveryReport {
    /// Intact records read from the journal.
    pub records: usize,
    /// True when replay started from a `MARK` checkpoint instead of an
    /// empty daemon (t=0).
    pub from_mark: bool,
    /// Highest sim clock recorded in the mark — the step bound replay
    /// started from (0 without a mark).
    pub mark_step_bound: u64,
    /// Jobs restored directly from the mark's per-sim checkpoints.
    pub marked_jobs: usize,
    /// `submit` records re-dispatched after the mark.
    pub replayed_submits: usize,
    /// `create` records re-applied after the mark.
    pub replayed_creates: usize,
    /// Clean-shutdown records seen (the journal was closed gracefully).
    pub shutdowns: usize,
    /// Sims hosted after recovery.
    pub sims: usize,
    /// Sims whose recovered fingerprint was verified against the mark.
    pub verified_sims: usize,
    /// Description of a discarded torn tail, if the crash tore one.
    pub torn_tail: Option<String>,
}

impl RecoveryReport {
    /// One-line human summary for the daemon's startup banner.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "{} sim(s) from {} journal record(s)",
            self.sims, self.records
        );
        if self.from_mark {
            s.push_str(&format!(
                ", mark at step bound {} ({} job(s) checkpointed, {} verified)",
                self.mark_step_bound, self.marked_jobs, self.verified_sims
            ));
        }
        if self.replayed_submits + self.replayed_creates > 0 {
            s.push_str(&format!(
                ", {} submit(s) + {} create(s) replayed",
                self.replayed_submits, self.replayed_creates
            ));
        }
        if let Some(t) = &self.torn_tail {
            s.push_str(&format!(", torn tail discarded ({t})"));
        }
        s
    }
}

/// Replay the journal in `dir` over `cfg` and return a live
/// [`ServerCore`] with the journal reattached for appending (the torn
/// tail, if any, is truncated away first). Fails — never
/// half-recovers — on a missing journal, a config-hash mismatch,
/// mid-file corruption, or a fingerprint that does not reproduce the
/// mark's digest.
pub fn recover(cfg: &ExperimentConfig, dir: &Path) -> Result<(ServerCore, RecoveryReport)> {
    let path = dir.join(journal::FILE_NAME);
    if !path.exists() {
        bail!(
            "journal: nothing to resume — {path:?} does not exist (start without --resume \
             to begin a fresh journal)"
        );
    }
    let bytes = std::fs::read(&path).with_context(|| format!("journal: reading {path:?}"))?;
    let img = journal::read_image(&bytes)?;
    let want = cfg.semantic_hash();
    if img.config_hash != want {
        bail!(
            "journal: {path:?} was written under a different experiment config \
             (header hash {:016x}, this config {:016x}) — replaying it here would \
             rebuild different state; resume with the original config or remove the journal",
            img.config_hash,
            want
        );
    }

    let mut report = RecoveryReport {
        records: img.records.len(),
        torn_tail: img.torn.as_ref().map(|t| t.reason.clone()),
        ..RecoveryReport::default()
    };
    let mut core = ServerCore::new(cfg.clone());

    // Replay starts at the latest MARK: it losslessly supersedes every
    // record before it (compaction keeps at most one, as record 0, but
    // the reader does not rely on that).
    let mark_idx = img.records.iter().rposition(|r| matches!(r, Record::Mark(_)));
    let start = match mark_idx {
        Some(i) => {
            let mark = match &img.records[i] {
                Record::Mark(m) => m,
                _ => unreachable!("rposition matched a mark"),
            };
            report.from_mark = true;
            for sm in &mark.sims {
                core.restore_sim(sm)
                    .map_err(|e| anyhow::anyhow!("journal: restoring sim {:?}: {e}", sm.name))?;
                report.marked_jobs += sm.jobs.len();
                report.mark_step_bound = report.mark_step_bound.max(sm.clock);
                let got = journal::mark_fingerprint(core.sim_instance(&sm.name).expect("just restored"))
                    .map_err(|e| anyhow::anyhow!("journal: fingerprinting recovered sim {:?}: {e}", sm.name))?;
                if got != sm.fp_hash {
                    bail!(
                        "journal: recovered state of sim {:?} does not reproduce the mark's \
                         fingerprint digest (mark {:016x}, replay {:016x}) — the journal and \
                         this build/config disagree; refusing to resume a diverged journal",
                        sm.name,
                        sm.fp_hash,
                        got
                    );
                }
                report.verified_sims += 1;
            }
            i + 1
        }
        None => 0,
    };

    // Re-dispatch the suffix through the same request path the live
    // daemon used. Failures (e.g. a journaled request that was refused
    // live) re-fail deterministically; that *is* the replay.
    for (n, rec) in img.records[start..].iter().enumerate() {
        match rec {
            Record::Create(name) => {
                core.replay_create(name);
                report.replayed_creates += 1;
            }
            Record::Submit(line) => {
                let _ = core.handle_line(n as u64 + 1, line);
                report.replayed_submits += 1;
            }
            Record::Shutdown => {
                // A clean close last lifetime; a resumed daemon starts
                // un-drained.
                report.shutdowns += 1;
            }
            Record::Mark(_) => unreachable!("no mark after the last mark"),
        }
    }
    report.sims = core.sim_names().len();

    // Reattach for appending: truncate the torn tail away, keep the
    // mark cadence counting from the recovered suffix.
    let journal = Journal::open_append(
        dir,
        img.config_hash,
        cfg.serve.durability,
        img.valid_len,
        img.records.len() as u64,
        report.replayed_submits as u64,
    )?;
    core.attach_journal(journal);
    Ok((core, report))
}
