//! Write-ahead journal for the serve daemon: the durable half of the
//! scheduler-as-a-service story.
//!
//! The insight the whole design rides on: a daemon-hosted sim's entire
//! state is a pure function of `(ExperimentConfig, ordered
//! mutating-request log)` — the determinism contract (byte-identical
//! fingerprints, `rust/tests/snapshot.rs`) makes recovery-by-replay
//! provably exact, not best-effort. So the journal records *requests*,
//! not engine state: every state-mutating request is appended (and,
//! depending on [`Durability`], fsynced) *before* it is applied, and
//! [`crate::runtime::recover`] rebuilds the daemon by replaying the log
//! over the same config.
//!
//! ## File layout (`<state-dir>/journal.sstj`, all integers little-endian)
//!
//! The binary conventions mirror `trace::stf`: fixed magic, a version
//! gate, fixed-offset little-endian fields, and locate-the-problem
//! errors carrying the record index and byte offset.
//!
//! 32-byte header:
//!
//! | offset | size | field                                     |
//! |--------|------|-------------------------------------------|
//! | 0      | 4    | magic `b"SSTJ"`                           |
//! | 4      | 2    | version (currently 1)                     |
//! | 6      | 2    | flags (reserved, zero)                    |
//! | 8      | 8    | config hash ([`crate::config::ExperimentConfig::semantic_hash`]) |
//! | 16     | 16   | reserved (zero)                           |
//!
//! then length-prefixed, checksummed records:
//!
//! | offset | size | field                                    |
//! |--------|------|------------------------------------------|
//! | 0      | 1    | kind (1 create, 2 submit, 3 shutdown, 4 mark) |
//! | 1      | 4    | payload length                           |
//! | 5      | 8    | FNV-1a checksum of kind byte + payload   |
//! | 13     | n    | payload                                  |
//!
//! ## Corruption taxonomy
//!
//! * **Torn tail** — the file ends inside a record (a crash mid-append).
//!   The intact prefix is returned, the tail is reported in
//!   [`JournalImage::torn`] and cleanly discarded by recovery (the file
//!   is truncated to [`JournalImage::valid_len`] before appending
//!   resumes).
//! * **Checksum mismatch on a complete record** — records are written
//!   with a single `write_all`, so a crash truncates but never
//!   scrambles; a complete record whose checksum fails is real
//!   corruption and a hard error carrying the record index and byte
//!   offset, like the stf reader's diagnostics.
//! * **Bad magic / version / short header** — hard errors up front.
//!
//! ## MARK records and compaction
//!
//! Serve arrivals are monotone (`at >= now` is enforced, and every
//! submit steps the engine through its arrival), so a sim's full
//! request history *is* its ordered job list plus the clock bound it
//! advanced to. A `MARK` record snapshots exactly that for every hosted
//! sim — ordered jobs, `next_job_id`, clock, and an FNV digest of the
//! sim's future fingerprint — which makes it a *lossless compaction* of
//! every record before it. Writing a mark atomically rewrites the
//! journal as `header + MARK` (tmp file + rename), so the file holds at
//! most one mark and recovery replays from the mark's step bound
//! instead of t=0. The fingerprint digest is asserted after replay:
//! a diverged journal is refused, never silently half-recovered.

use crate::config::Durability;
use crate::sim::SimInstance;
use anyhow::{bail, Context, Result};
use std::io::Write as _;
use std::path::{Path, PathBuf};

/// File magic: the first four bytes of every serve journal.
pub const MAGIC: [u8; 4] = *b"SSTJ";
/// Format version this reader/writer speaks.
pub const VERSION: u16 = 1;
/// Header size in bytes.
pub const HEADER_BYTES: usize = 32;
/// Fixed per-record prefix: kind (1) + payload length (4) + checksum (8).
pub const RECORD_HEADER_BYTES: usize = 13;
/// Journal file name inside the daemon's state directory.
pub const FILE_NAME: &str = "journal.sstj";

/// Byte offset of the config hash within the header.
const CONFIG_HASH_OFFSET: usize = 8;

const KIND_CREATE: u8 = 1;
const KIND_SUBMIT: u8 = 2;
const KIND_SHUTDOWN: u8 = 3;
const KIND_MARK: u8 = 4;

/// `fsync` cadence in `batched` mode: records between `sync_data` calls.
const BATCH_SYNC_EVERY: u64 = 16;
/// User-space buffer high-water mark in `off` mode: bytes buffered
/// before an opportunistic write to the OS.
const OFF_FLUSH_BYTES: usize = 64 * 1024;

/// One journaled event. `Create`/`Submit` carry the raw request
/// material and replay through the same dispatch path the live daemon
/// uses; `Mark` is a lossless checkpoint (see the module docs).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Record {
    /// A sim was created by a non-submit request (`predict_wait` on a
    /// fresh name); payload is the sim name.
    Create(String),
    /// A `submit` request, journaled before it was applied; payload is
    /// the raw JSON request line.
    Submit(String),
    /// A `shutdown` request was accepted: the journal was closed
    /// cleanly. Replay restores the sims but not the draining flag —
    /// a resumed daemon starts a fresh serve lifetime.
    Shutdown,
    /// Checkpoint of every hosted sim; supersedes all earlier records.
    Mark(Mark),
}

/// Payload of a `MARK` record: one checkpoint per hosted sim.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Mark {
    /// Every hosted sim at mark time, in name order.
    pub sims: Vec<SimMark>,
}

/// One sim's lossless checkpoint inside a [`Mark`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SimMark {
    /// Sim name (the `"sim"` request field).
    pub name: String,
    /// Next job id the allocator would hand out.
    pub next_job_id: u64,
    /// Clock the sim had advanced to (the replay step bound).
    pub clock: u64,
    /// FNV-1a digest of the sim's future fingerprint
    /// ([`mark_fingerprint`]); recovery asserts the replayed state
    /// reproduces it byte for byte.
    pub fp_hash: u64,
    /// Every job ever submitted to this sim, in submit order.
    pub jobs: Vec<JobRec>,
}

/// One submitted job inside a [`SimMark`] — the full u64 field widths
/// of the serve protocol, not stf's range-checked u32 slots.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct JobRec {
    /// Arrival tick the submit committed (equals the job's submit time).
    pub submit: u64,
    /// Daemon-assigned job id.
    pub id: u64,
    /// Cores requested.
    pub cores: u64,
    /// Memory requested (MB).
    pub mem: u64,
    /// Runtime estimate in ticks.
    pub est: u64,
    /// Actual runtime in ticks.
    pub runtime: u64,
    /// Submitting user id.
    pub user: u32,
    /// Group id.
    pub group: u32,
}

/// Encoded size of one [`JobRec`].
const JOB_REC_BYTES: usize = 56;

/// FNV-1a over the kind byte followed by the payload — the per-record
/// checksum (same constants as [`crate::parallel::fnv1a`]).
fn record_checksum(kind: u8, payload: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    h ^= kind as u64;
    h = h.wrapping_mul(0x100000001b3);
    for &b in payload {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Fingerprint a sim for a `MARK` record: snapshot the live engine, run
/// the clone to completion, digest the report fingerprint. This is the
/// journaled daemon's gate on what it can host: a streamed
/// (`with_job_stream`) sim cannot be snapshotted, so this propagates
/// the same clear by-name error [`SimInstance::snapshot`] already
/// reports — streamed sims are rejected from journaled serve, never
/// half-journaled.
pub fn mark_fingerprint(inst: &SimInstance) -> Result<u64, String> {
    let snap = inst.snapshot()?;
    let fp = SimInstance::resume(snap).run_to_completion(None).fingerprint();
    Ok(crate::parallel::fnv1a(fp.as_bytes()))
}

/// Encode the fixed header.
pub fn encode_header(config_hash: u64) -> [u8; HEADER_BYTES] {
    let mut h = [0u8; HEADER_BYTES];
    h[0..4].copy_from_slice(&MAGIC);
    h[4..6].copy_from_slice(&VERSION.to_le_bytes());
    h[CONFIG_HASH_OFFSET..CONFIG_HASH_OFFSET + 8].copy_from_slice(&config_hash.to_le_bytes());
    h
}

/// Decode and validate a header prefix; returns the config hash.
pub fn decode_header(bytes: &[u8]) -> Result<u64> {
    if bytes.len() < HEADER_BYTES {
        bail!(
            "journal: file too short for a header ({} bytes, need {HEADER_BYTES})",
            bytes.len()
        );
    }
    if bytes[0..4] != MAGIC {
        bail!("journal: bad magic {:?} (not a serve journal)", &bytes[0..4]);
    }
    let version = u16::from_le_bytes([bytes[4], bytes[5]]);
    if version != VERSION {
        bail!("journal: unsupported version {version} (this reader speaks {VERSION})");
    }
    Ok(u64::from_le_bytes(
        bytes[CONFIG_HASH_OFFSET..CONFIG_HASH_OFFSET + 8].try_into().unwrap(),
    ))
}

/// Read just the header of a journal file (config-hash compatibility
/// checks, `sst-sched check`).
pub fn peek_header(path: &Path) -> Result<u64> {
    let bytes = std::fs::read(path).with_context(|| format!("journal: reading {path:?}"))?;
    decode_header(&bytes)
}

fn encode_mark(m: &Mark) -> Vec<u8> {
    let mut out = Vec::with_capacity(16 + m.sims.iter().map(|s| 32 + s.name.len() + s.jobs.len() * JOB_REC_BYTES).sum::<usize>());
    out.extend_from_slice(&(m.sims.len() as u32).to_le_bytes());
    for s in &m.sims {
        out.extend_from_slice(&(s.name.len() as u32).to_le_bytes());
        out.extend_from_slice(s.name.as_bytes());
        out.extend_from_slice(&s.next_job_id.to_le_bytes());
        out.extend_from_slice(&s.clock.to_le_bytes());
        out.extend_from_slice(&s.fp_hash.to_le_bytes());
        out.extend_from_slice(&(s.jobs.len() as u32).to_le_bytes());
        for j in &s.jobs {
            out.extend_from_slice(&j.submit.to_le_bytes());
            out.extend_from_slice(&j.id.to_le_bytes());
            out.extend_from_slice(&j.cores.to_le_bytes());
            out.extend_from_slice(&j.mem.to_le_bytes());
            out.extend_from_slice(&j.est.to_le_bytes());
            out.extend_from_slice(&j.runtime.to_le_bytes());
            out.extend_from_slice(&j.user.to_le_bytes());
            out.extend_from_slice(&j.group.to_le_bytes());
        }
    }
    out
}

/// Bounds-checked little-endian cursor for mark payload decoding.
struct Cur<'a> {
    b: &'a [u8],
    off: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if self.b.len() - self.off < n {
            bail!("journal: mark payload truncated reading {what} at payload byte {}", self.off);
        }
        let s = &self.b[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }
}

fn decode_mark(payload: &[u8]) -> Result<Mark> {
    let mut c = Cur { b: payload, off: 0 };
    let sims = c.u32("sim count")?;
    let mut out = Mark { sims: Vec::with_capacity(sims as usize) };
    for _ in 0..sims {
        let name_len = c.u32("sim name length")? as usize;
        let name = std::str::from_utf8(c.take(name_len, "sim name")?)
            .context("journal: mark sim name is not UTF-8")?
            .to_string();
        let next_job_id = c.u64("next_job_id")?;
        let clock = c.u64("clock")?;
        let fp_hash = c.u64("fingerprint hash")?;
        let njobs = c.u32("job count")?;
        let mut jobs = Vec::with_capacity(njobs as usize);
        for _ in 0..njobs {
            jobs.push(JobRec {
                submit: c.u64("job submit")?,
                id: c.u64("job id")?,
                cores: c.u64("job cores")?,
                mem: c.u64("job mem")?,
                est: c.u64("job est")?,
                runtime: c.u64("job runtime")?,
                user: c.u32("job user")?,
                group: c.u32("job group")?,
            });
        }
        out.sims.push(SimMark { name, next_job_id, clock, fp_hash, jobs });
    }
    if c.off != payload.len() {
        bail!("journal: mark payload has {} trailing byte(s)", payload.len() - c.off);
    }
    Ok(out)
}

/// Encode one record (prefix + payload) into `out`.
pub fn encode_record_into(out: &mut Vec<u8>, rec: &Record) {
    let (kind, payload): (u8, Vec<u8>) = match rec {
        Record::Create(name) => (KIND_CREATE, name.as_bytes().to_vec()),
        Record::Submit(line) => (KIND_SUBMIT, line.as_bytes().to_vec()),
        Record::Shutdown => (KIND_SHUTDOWN, Vec::new()),
        Record::Mark(m) => (KIND_MARK, encode_mark(m)),
    };
    out.push(kind);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&record_checksum(kind, &payload).to_le_bytes());
    out.extend_from_slice(&payload);
}

/// A torn/truncated tail: the byte offset where the intact prefix ends
/// and why the tail could not be read. Recoverable by design — a crash
/// mid-append is exactly this shape.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TornTail {
    /// File offset of the first byte past the last intact record.
    pub offset: u64,
    /// What was wrong with the tail.
    pub reason: String,
}

/// A fully scanned journal: header fields, every intact record, and
/// whether a torn tail was discarded.
#[derive(Debug)]
pub struct JournalImage {
    /// Config hash from the header.
    pub config_hash: u64,
    /// Every intact record, in append order.
    pub records: Vec<Record>,
    /// `Some` when the file ends inside a record (crash mid-append);
    /// the tail is not part of [`JournalImage::records`].
    pub torn: Option<TornTail>,
    /// Byte length of the intact prefix — recovery truncates the file
    /// here before appending resumes.
    pub valid_len: u64,
}

/// Scan a whole journal image. Torn tails are tolerated and reported;
/// a checksum mismatch on a *complete* record, an unknown record kind,
/// or a malformed mark payload is a hard error carrying the record
/// index and byte offset (records are written with a single `write_all`,
/// so a crash truncates — it never scrambles a complete record).
pub fn read_image(bytes: &[u8]) -> Result<JournalImage> {
    let config_hash = decode_header(bytes)?;
    let mut records = Vec::new();
    let mut torn = None;
    let mut off = HEADER_BYTES;
    let mut idx = 0usize;
    while off < bytes.len() {
        let rem = bytes.len() - off;
        if rem < RECORD_HEADER_BYTES {
            torn = Some(TornTail {
                offset: off as u64,
                reason: format!(
                    "record {idx} prefix truncated at byte {off} ({rem} of {RECORD_HEADER_BYTES} bytes)"
                ),
            });
            break;
        }
        let kind = bytes[off];
        let plen =
            u32::from_le_bytes(bytes[off + 1..off + 5].try_into().unwrap()) as usize;
        let stored =
            u64::from_le_bytes(bytes[off + 5..off + 13].try_into().unwrap());
        if rem < RECORD_HEADER_BYTES + plen {
            torn = Some(TornTail {
                offset: off as u64,
                reason: format!(
                    "record {idx} payload truncated at byte {off} ({} of {plen} payload bytes)",
                    rem - RECORD_HEADER_BYTES
                ),
            });
            break;
        }
        let payload = &bytes[off + RECORD_HEADER_BYTES..off + RECORD_HEADER_BYTES + plen];
        let computed = record_checksum(kind, payload);
        if computed != stored {
            bail!(
                "journal: record {idx} at byte {off} fails its checksum \
                 (stored {stored:016x}, computed {computed:016x}) — the journal is \
                 corrupt mid-file, not merely truncated; refusing to replay it"
            );
        }
        let rec = match kind {
            KIND_CREATE => Record::Create(
                std::str::from_utf8(payload)
                    .with_context(|| format!("journal: record {idx} at byte {off}: create payload is not UTF-8"))?
                    .to_string(),
            ),
            KIND_SUBMIT => Record::Submit(
                std::str::from_utf8(payload)
                    .with_context(|| format!("journal: record {idx} at byte {off}: submit payload is not UTF-8"))?
                    .to_string(),
            ),
            KIND_SHUTDOWN => Record::Shutdown,
            KIND_MARK => Record::Mark(
                decode_mark(payload)
                    .with_context(|| format!("journal: record {idx} at byte {off}: bad mark payload"))?,
            ),
            other => bail!("journal: record {idx} at byte {off} has unknown kind {other}"),
        };
        records.push(rec);
        off += RECORD_HEADER_BYTES + plen;
        idx += 1;
    }
    Ok(JournalImage { config_hash, records, torn, valid_len: off.min(bytes.len()) as u64 })
}

/// Read and scan a journal file.
pub fn read_file(path: &Path) -> Result<JournalImage> {
    let bytes = std::fs::read(path).with_context(|| format!("journal: reading {path:?}"))?;
    read_image(&bytes)
}

/// Append-side handle on a journal file. Owns the durability policy:
///
/// * `strict` — every record is written and fsynced before the request
///   is applied; an acknowledged request survives any crash.
/// * `batched` — every record reaches the OS immediately (a *process*
///   crash loses nothing) and `fsync` runs every
///   [`BATCH_SYNC_EVERY`] records (a machine crash loses at most one
///   batch). The default.
/// * `off` — records buffer in user space and reach the OS
///   opportunistically; fastest, and a crash loses the buffered tail.
///   Recovery still yields a consistent prefix, and MARK compaction is
///   always written durably (tmp file + rename + fsync), so loss is
///   bounded by the mark interval.
///
/// Dropping a `Journal` flushes and fsyncs (graceful close);
/// [`Journal::abandon`] drops the user-space buffer unflushed — the
/// crash-fault harness uses it to simulate a crash.
pub struct Journal {
    path: PathBuf,
    file: std::fs::File,
    /// User-space buffer of encoded-but-unwritten records (`off` mode).
    buf: Vec<u8>,
    durability: Durability,
    config_hash: u64,
    records: u64,
    submits_since_mark: u64,
    pending_sync: u64,
}

impl Journal {
    /// Create a fresh journal at `<dir>/journal.sstj` (the directory is
    /// created if missing). Refuses to overwrite an existing journal —
    /// resuming or removing it is the caller's explicit decision.
    pub fn create(dir: &Path, config_hash: u64, durability: Durability) -> Result<Journal> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("journal: creating state dir {dir:?}"))?;
        let path = dir.join(FILE_NAME);
        let mut file = std::fs::OpenOptions::new()
            .write(true)
            .create_new(true)
            .open(&path)
            .with_context(|| format!("journal: creating {path:?}"))?;
        file.write_all(&encode_header(config_hash))
            .with_context(|| format!("journal: writing header to {path:?}"))?;
        file.sync_data().with_context(|| format!("journal: syncing {path:?}"))?;
        Ok(Journal {
            path,
            file,
            buf: Vec::new(),
            durability,
            config_hash,
            records: 0,
            submits_since_mark: 0,
            pending_sync: 0,
        })
    }

    /// Reopen an existing journal for appending after recovery. The
    /// file is truncated to `valid_len` first, discarding a torn tail;
    /// `records` / `submits_since_mark` seed the mark cadence from the
    /// recovered image.
    pub fn open_append(
        dir: &Path,
        config_hash: u64,
        durability: Durability,
        valid_len: u64,
        records: u64,
        submits_since_mark: u64,
    ) -> Result<Journal> {
        let path = dir.join(FILE_NAME);
        let file = std::fs::OpenOptions::new()
            .append(true)
            .open(&path)
            .with_context(|| format!("journal: reopening {path:?}"))?;
        file.set_len(valid_len)
            .with_context(|| format!("journal: truncating {path:?} to its intact prefix"))?;
        file.sync_data().with_context(|| format!("journal: syncing {path:?}"))?;
        Ok(Journal {
            path,
            file,
            buf: Vec::new(),
            durability,
            config_hash,
            records,
            submits_since_mark,
            pending_sync: 0,
        })
    }

    /// The journal file path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Records appended over the journal's lifetime (marks included).
    pub fn records(&self) -> u64 {
        self.records
    }

    fn write_buf(&mut self) -> Result<()> {
        if !self.buf.is_empty() {
            self.file
                .write_all(&self.buf)
                .with_context(|| format!("journal: writing {:?}", self.path))?;
            self.buf.clear();
        }
        Ok(())
    }

    /// Append one record under the durability policy. Call *before*
    /// applying the request it records (write-ahead).
    pub fn append(&mut self, rec: &Record) -> Result<()> {
        let mut encoded = Vec::new();
        encode_record_into(&mut encoded, rec);
        match self.durability {
            Durability::Off => {
                self.buf.extend_from_slice(&encoded);
                if self.buf.len() >= OFF_FLUSH_BYTES {
                    self.write_buf()?;
                }
            }
            Durability::Batched => {
                self.file
                    .write_all(&encoded)
                    .with_context(|| format!("journal: writing {:?}", self.path))?;
                self.pending_sync += 1;
                if self.pending_sync >= BATCH_SYNC_EVERY {
                    self.file
                        .sync_data()
                        .with_context(|| format!("journal: syncing {:?}", self.path))?;
                    self.pending_sync = 0;
                }
            }
            Durability::Strict => {
                self.file
                    .write_all(&encoded)
                    .with_context(|| format!("journal: writing {:?}", self.path))?;
                self.file
                    .sync_data()
                    .with_context(|| format!("journal: syncing {:?}", self.path))?;
            }
        }
        self.records += 1;
        if matches!(rec, Record::Submit(_)) {
            self.submits_since_mark += 1;
        }
        Ok(())
    }

    /// True when `interval` submits have been journaled since the last
    /// mark (0 disables marking — flagged by `sst-sched check`).
    pub fn should_mark(&self, interval: u64) -> bool {
        interval > 0 && self.submits_since_mark >= interval
    }

    /// Write a `MARK` checkpoint and compact: the journal is atomically
    /// rewritten as `header + MARK` (tmp file, fsync, rename), because
    /// the mark losslessly supersedes every record before it. Always
    /// durable regardless of the durability mode — compaction is the
    /// loss bound for `off`/`batched`.
    pub fn mark_and_compact(&mut self, mark: &Mark) -> Result<()> {
        let mut bytes = encode_header(self.config_hash).to_vec();
        encode_record_into(&mut bytes, &Record::Mark(mark.clone()));
        let tmp = self.path.with_extension("sstj.tmp");
        {
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("journal: creating compaction file {tmp:?}"))?;
            f.write_all(&bytes)
                .with_context(|| format!("journal: writing compaction file {tmp:?}"))?;
            f.sync_data()
                .with_context(|| format!("journal: syncing compaction file {tmp:?}"))?;
        }
        std::fs::rename(&tmp, &self.path)
            .with_context(|| format!("journal: renaming {tmp:?} over {:?}", self.path))?;
        self.file = std::fs::OpenOptions::new()
            .append(true)
            .open(&self.path)
            .with_context(|| format!("journal: reopening {:?} after compaction", self.path))?;
        // Everything buffered was subsumed by the mark.
        self.buf.clear();
        self.records = 1;
        self.submits_since_mark = 0;
        self.pending_sync = 0;
        Ok(())
    }

    /// Graceful flush: push the user-space buffer to the OS and fsync.
    pub fn flush(&mut self) -> Result<()> {
        self.write_buf()?;
        self.file
            .sync_data()
            .with_context(|| format!("journal: syncing {:?}", self.path))?;
        self.pending_sync = 0;
        Ok(())
    }

    /// Drop the journal *without* flushing the user-space buffer — a
    /// process crash, as one call. The crash-fault chaos harness
    /// (`rust/tests/crash_recovery.rs`) is the intended caller; a
    /// graceful close is just `drop`.
    pub fn abandon(mut self) {
        self.buf.clear();
    }
}

impl Drop for Journal {
    fn drop(&mut self) {
        // Graceful close: best-effort flush + fsync, so a clean daemon
        // exit is durable even in `off` mode.
        let _ = self.write_buf();
        let _ = self.file.sync_data();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_mark() -> Mark {
        Mark {
            sims: vec![SimMark {
                name: "default".to_string(),
                next_job_id: 3,
                clock: 120,
                fp_hash: 0xdead_beef_cafe_f00d,
                jobs: vec![
                    JobRec { submit: 0, id: 1, cores: 4, mem: 0, est: 100, runtime: 100, user: 0, group: 0 },
                    JobRec { submit: 120, id: 2, cores: 2, mem: 512, est: 60, runtime: 50, user: 7, group: 3 },
                ],
            }],
        }
    }

    fn image_of(records: &[Record]) -> Vec<u8> {
        let mut bytes = encode_header(42).to_vec();
        for r in records {
            encode_record_into(&mut bytes, r);
        }
        bytes
    }

    #[test]
    fn every_record_kind_roundtrips() {
        let recs = vec![
            Record::Create("a".to_string()),
            Record::Submit(r#"{"req":"submit","job":{"cores":1,"runtime":5}}"#.to_string()),
            Record::Shutdown,
            Record::Mark(sample_mark()),
        ];
        let img = read_image(&image_of(&recs)).unwrap();
        assert_eq!(img.config_hash, 42);
        assert_eq!(img.records, recs);
        assert!(img.torn.is_none());
        assert_eq!(img.valid_len, image_of(&recs).len() as u64);
    }

    #[test]
    fn empty_journal_is_valid_and_empty() {
        let img = read_image(&encode_header(7)).unwrap();
        assert_eq!(img.config_hash, 7);
        assert!(img.records.is_empty());
        assert!(img.torn.is_none());
        // A zero-byte file, by contrast, has no header at all.
        let e = read_image(&[]).unwrap_err().to_string();
        assert!(e.contains("too short"), "{e}");
    }

    #[test]
    fn truncated_tail_is_recovered_not_fatal() {
        let recs = vec![
            Record::Submit("line one".to_string()),
            Record::Submit("line two, about to be torn".to_string()),
        ];
        let full = image_of(&recs);
        // Cut into the second record's payload: prefix survives.
        let img = read_image(&full[..full.len() - 5]).unwrap();
        assert_eq!(img.records, vec![Record::Submit("line one".to_string())]);
        let torn = img.torn.expect("tail must be reported");
        assert!(torn.reason.contains("record 1"), "{}", torn.reason);
        assert!(torn.reason.contains("truncated"), "{}", torn.reason);
        // valid_len points at the start of the torn record.
        let one = image_of(&recs[..1]);
        assert_eq!(img.valid_len, one.len() as u64);
        // Cutting into the 13-byte record prefix is also just a torn tail.
        let img2 = read_image(&full[..one.len() + 4]).unwrap();
        assert_eq!(img2.records.len(), 1);
        assert!(img2.torn.unwrap().reason.contains("prefix truncated"));
    }

    #[test]
    fn checksum_flip_mid_file_is_a_hard_error_with_index_and_offset() {
        let recs = vec![
            Record::Submit("first".to_string()),
            Record::Submit("second".to_string()),
        ];
        let mut bytes = image_of(&recs);
        // Flip one payload byte of record 0 (payload starts right after
        // the header + record prefix).
        bytes[HEADER_BYTES + RECORD_HEADER_BYTES] ^= 0x01;
        let e = read_image(&bytes).unwrap_err().to_string();
        assert!(e.contains("record 0"), "{e}");
        assert!(e.contains(&format!("byte {HEADER_BYTES}")), "{e}");
        assert!(e.contains("checksum"), "{e}");
        assert!(e.contains("corrupt mid-file"), "{e}");
    }

    #[test]
    fn version_and_magic_mismatches_are_hard_errors() {
        let mut v2 = image_of(&[Record::Shutdown]);
        v2[4] = 9;
        let e = read_image(&v2).unwrap_err().to_string();
        assert!(e.contains("version 9"), "{e}");
        let mut bad = image_of(&[Record::Shutdown]);
        bad[0] = b'X';
        assert!(read_image(&bad).unwrap_err().to_string().contains("magic"));
        // Unknown record kind: hard error, not a skip.
        let mut unk = encode_header(1).to_vec();
        let kind = 200u8;
        unk.push(kind);
        unk.extend_from_slice(&0u32.to_le_bytes());
        unk.extend_from_slice(&record_checksum(kind, &[]).to_le_bytes());
        let e = read_image(&unk).unwrap_err().to_string();
        assert!(e.contains("unknown kind 200"), "{e}");
    }

    #[test]
    fn writer_roundtrips_through_a_real_file() {
        let dir = std::env::temp_dir().join(format!("sst-journal-test-{}-w", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut j = Journal::create(&dir, 99, Durability::Strict).unwrap();
        j.append(&Record::Create("a".to_string())).unwrap();
        j.append(&Record::Submit("req".to_string())).unwrap();
        assert_eq!(j.records(), 2);
        assert!(!j.should_mark(5));
        assert!(j.should_mark(1));
        drop(j);
        let img = read_file(&dir.join(FILE_NAME)).unwrap();
        assert_eq!(img.config_hash, 99);
        assert_eq!(img.records.len(), 2);
        // A second create on the same dir must refuse to clobber.
        assert!(Journal::create(&dir, 99, Durability::Strict).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn abandon_drops_the_unflushed_tail_in_off_mode() {
        let dir = std::env::temp_dir().join(format!("sst-journal-test-{}-o", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut j = Journal::create(&dir, 5, Durability::Off).unwrap();
        j.append(&Record::Submit("buffered, then lost".to_string())).unwrap();
        j.abandon();
        let img = read_file(&dir.join(FILE_NAME)).unwrap();
        assert!(img.records.is_empty(), "off-mode buffer must die with the crash");
        // Same sequence with a graceful drop keeps the record.
        let _ = std::fs::remove_dir_all(&dir);
        let mut j = Journal::create(&dir, 5, Durability::Off).unwrap();
        j.append(&Record::Submit("buffered, then flushed".to_string())).unwrap();
        drop(j);
        assert_eq!(read_file(&dir.join(FILE_NAME)).unwrap().records.len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mark_compaction_rewrites_to_header_plus_mark() {
        let dir = std::env::temp_dir().join(format!("sst-journal-test-{}-m", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut j = Journal::create(&dir, 11, Durability::Batched).unwrap();
        for i in 0..6 {
            j.append(&Record::Submit(format!("submit {i}"))).unwrap();
        }
        j.mark_and_compact(&sample_mark()).unwrap();
        j.append(&Record::Submit("after the mark".to_string())).unwrap();
        drop(j);
        let img = read_file(&dir.join(FILE_NAME)).unwrap();
        assert_eq!(img.records.len(), 2, "compaction must drop the superseded prefix");
        assert!(matches!(img.records[0], Record::Mark(_)));
        assert_eq!(img.records[1], Record::Submit("after the mark".to_string()));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
