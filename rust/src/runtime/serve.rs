//! Scheduler-as-a-service: the `sst-sched serve` daemon.
//!
//! The daemon hosts named, long-lived [`SimInstance`]s and speaks a
//! JSON-lines protocol over a Unix domain socket (one request object
//! per line, one response object per line — see `docs/PROTOCOL.md` for
//! every shape). Five request kinds:
//!
//! * `submit` — commit a job arrival into a live timeline; the engine
//!   steps to the arrival time, so state advances as requests come in.
//! * `predict_wait` — speculatively place a hypothetical job: snapshot
//!   the live engine ([`SimInstance::snapshot`]), inject the job into
//!   the clone, run the clone to completion, and report the predicted
//!   start/wait. The live run is untouched (pinned by `tests/serve.rs`).
//! * `status` — clock, queue depth, running/completed counts of one sim.
//! * `metrics` — daemon-wide counters.
//! * `shutdown` — stop accepting work and drain gracefully (SIGTERM and
//!   SIGINT do the same).
//!
//! Robustness guarantees: per-connection request queues are bounded
//! ([`crate::config::ServeOptions::queue_depth`]) and a full queue gets
//! an explicit `backpressure` error reply (carrying a machine-readable
//! `retry_after_ms` back-off hint) instead of unbounded buffering; sim
//! creation is admission-controlled (`--max-sims`); malformed requests
//! are answered with the line number and byte offset of the error, like
//! the trace parsers report theirs.
//!
//! Crash safety: with `serve.state_dir` set (`--state-dir`), every
//! state-mutating request is appended to a write-ahead journal
//! ([`crate::runtime::journal`]) *before* it is applied, and
//! `--resume <dir>` rebuilds the daemon by deterministic replay
//! ([`crate::runtime::recover`]). A journal-write failure degrades the
//! daemon to in-memory operation with a logged warning — it never kills
//! live sims. See `docs/OPERATIONS.md` for the operational contract.
//!
//! [`ServerCore`] is the transport-free request handler — the socket
//! loop, the integration tests, and the bench suite all drive the same
//! code path.

#![warn(missing_docs)]

use crate::config::ExperimentConfig;
use crate::core::time::{SimDuration, SimTime};
use crate::job::Job;
use crate::runtime::journal::{self, Journal};
use crate::sim::{SimInstance, Simulation};
use crate::trace::Workload;
use crate::util::json::Json;
use std::collections::BTreeMap;

#[cfg(unix)]
use anyhow::Context as _;
#[cfg(unix)]
use std::io::{BufRead as _, BufReader, ErrorKind, Write as _};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
#[cfg(unix)]
use std::sync::atomic::{AtomicBool, Ordering};
#[cfg(unix)]
use std::sync::{mpsc, Arc, Mutex};
#[cfg(unix)]
use std::time::Duration;

/// Machine shape for daemon-hosted simulations when the config carries
/// no platform override (`--nodes`): nodes.
pub const DEFAULT_NODES: usize = 64;

/// Default cores per node for daemon-hosted simulations (`--cores`).
pub const DEFAULT_CORES_PER_NODE: u64 = 8;

/// A request-level failure: the error `code` in the reply, a human
/// message, and (for parse failures) the byte offset inside the line.
struct ReqError {
    code: &'static str,
    message: String,
    byte: Option<u64>,
}

impl ReqError {
    fn bad(message: impl Into<String>) -> ReqError {
        ReqError { code: "bad_request", message: message.into(), byte: None }
    }

    fn at(code: &'static str, message: impl Into<String>) -> ReqError {
        ReqError { code, message: message.into(), byte: None }
    }
}

/// One hosted simulation plus its monotone job-id allocator. Predictions
/// peek the next id without consuming it, so a prediction followed by a
/// real submission of the same job replays under the same identity.
/// `submitted` (filled only while a journal is attached) is the sim's
/// ordered job history — the material of MARK checkpoints, which a
/// lossless compaction needs because serve arrivals are monotone and a
/// sim's state is exactly `f(config, ordered submits)`.
struct SimEntry {
    inst: SimInstance,
    next_job_id: u64,
    submitted: Vec<journal::JobRec>,
}

/// Transport-free request handler for the serve protocol: feed it one
/// request line at a time ([`ServerCore::handle_line`]) and write back
/// the returned JSON. The socket daemon wraps this in a mutex shared by
/// all connections; tests and the bench suite drive it directly.
pub struct ServerCore {
    cfg: ExperimentConfig,
    sims: BTreeMap<String, SimEntry>,
    requests: u64,
    submits: u64,
    predicts: u64,
    errors: u64,
    throttled: u64,
    draining: bool,
    /// Write-ahead journal; `None` for in-memory daemons (and after a
    /// journal-write failure degraded the daemon, see
    /// [`ServerCore::journal_append`]).
    journal: Option<Journal>,
}

impl ServerCore {
    /// Build a daemon core; `cfg` supplies the default machine shape,
    /// policy and every simulation knob for sims created on demand, and
    /// `cfg.serve` the admission/queue limits.
    pub fn new(cfg: ExperimentConfig) -> ServerCore {
        ServerCore {
            cfg,
            sims: BTreeMap::new(),
            requests: 0,
            submits: 0,
            predicts: 0,
            errors: 0,
            throttled: 0,
            draining: false,
            journal: None,
        }
    }

    /// Attach a write-ahead journal: every mutating request is appended
    /// (write-ahead) from here on, and MARK checkpoints compact the file
    /// every `cfg.serve.mark_interval` submits.
    pub fn attach_journal(&mut self, journal: Journal) {
        self.journal = Some(journal);
    }

    /// True while a journal is attached (false after a write failure
    /// degraded the daemon to in-memory operation).
    pub fn journal_active(&self) -> bool {
        self.journal.is_some()
    }

    /// Names of every hosted sim, in deterministic (sorted) order.
    pub fn sim_names(&self) -> Vec<String> {
        self.sims.keys().cloned().collect()
    }

    /// Borrow a hosted sim's live instance (recovery verification).
    pub fn sim_instance(&self, name: &str) -> Option<&SimInstance> {
        self.sims.get(name).map(|e| &e.inst)
    }

    /// True once a `shutdown` request was accepted: the daemon stops
    /// reading new requests and drains what is already queued.
    pub fn draining(&self) -> bool {
        self.draining
    }

    /// Simulate a process crash: drop the core *without* the graceful
    /// journal flush a normal drop performs, so the journal's user-space
    /// buffer dies exactly as it would with the process. The crash-fault
    /// chaos harness (`rust/tests/crash_recovery.rs`) is the intended
    /// caller.
    pub fn crash(mut self) {
        if let Some(j) = self.journal.take() {
            j.abandon();
        }
    }

    /// Record one backpressure rejection (the connection reader replies
    /// without going through [`ServerCore::handle_line`]).
    pub fn note_throttled(&mut self) {
        self.throttled += 1;
    }

    /// Handle one request line and return the response object. Never
    /// panics on bad input: malformed requests produce an `ok: false`
    /// reply carrying `line_no` (1-based) and, for JSON syntax errors,
    /// the byte offset within the line.
    pub fn handle_line(&mut self, line_no: u64, line: &str) -> Json {
        self.requests += 1;
        match self.dispatch(line) {
            Ok(resp) => resp,
            Err(e) => {
                self.errors += 1;
                error_json(line_no, &e)
            }
        }
    }

    /// Deterministic digest of one sim's *future*: snapshot the live
    /// engine, run the clone to completion, and fingerprint the report
    /// ([`crate::sim::SimReport::fingerprint`]). Does not perturb the
    /// live run — the non-perturbation property tests compare this
    /// before and after speculative requests.
    pub fn fingerprint(&self, sim: &str) -> Result<String, String> {
        let entry =
            self.sims.get(sim).ok_or_else(|| format!("no simulation named {sim:?}"))?;
        let snap = entry.inst.snapshot()?;
        Ok(SimInstance::resume(snap).run_to_completion(None).fingerprint())
    }

    fn dispatch(&mut self, line: &str) -> Result<Json, ReqError> {
        let v = Json::parse(line).map_err(|e| ReqError {
            code: "parse",
            message: e.message,
            byte: Some(e.offset as u64),
        })?;
        if v.as_obj().is_none() {
            return Err(ReqError::bad("request must be a JSON object"));
        }
        let req = v
            .get("req")
            .and_then(|r| r.as_str())
            .ok_or_else(|| {
                ReqError::bad("missing \"req\" (submit|predict_wait|status|metrics|shutdown)")
            })?
            .to_string();
        match req.as_str() {
            "submit" => {
                // Write-ahead: the raw request is durable before it is
                // applied. A refused submit replays to the same refusal
                // — replay is the same dispatch path. (Gated so the
                // in-memory daemon never pays the line clone.)
                if self.journal.is_some() {
                    self.journal_append(journal::Record::Submit(line.to_string()));
                }
                let resp = self.handle_submit(&v);
                if resp.is_ok() {
                    self.maybe_mark();
                }
                resp
            }
            "predict_wait" => self.handle_predict(&v),
            "status" => self.handle_status(&v),
            "metrics" => Ok(self.metrics_json()),
            "shutdown" => {
                self.journal_append(journal::Record::Shutdown);
                // Make the clean close durable even in `off` mode.
                if let Some(j) = self.journal.as_mut() {
                    let _ = j.flush();
                }
                self.draining = true;
                Ok(Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("req", Json::str("shutdown")),
                    ("draining", Json::Bool(true)),
                ]))
            }
            other => Err(ReqError::bad(format!(
                "unknown req {other:?} (submit|predict_wait|status|metrics|shutdown)"
            ))),
        }
    }

    /// Create the named sim on first use, under admission control.
    fn ensure_sim(&mut self, name: &str) -> Result<(), ReqError> {
        if self.sims.contains_key(name) {
            return Ok(());
        }
        if self.sims.len() >= self.cfg.serve.max_sims {
            return Err(ReqError::at(
                "sim_limit",
                format!(
                    "admission control: {} simulation(s) already hosted (max_sims = {}); \
                     reuse an existing sim or restart with a higher --max-sims",
                    self.sims.len(),
                    self.cfg.serve.max_sims
                ),
            ));
        }
        let inst = blank_instance(&self.cfg, name);
        self.sims
            .insert(name.to_string(), SimEntry { inst, next_job_id: 1, submitted: Vec::new() });
        Ok(())
    }

    /// Replay-side `Create`: re-run sim creation under the same
    /// admission control the live daemon applied (a refused create
    /// re-fails deterministically, which is exactly what replay wants).
    pub(crate) fn replay_create(&mut self, name: &str) {
        let _ = self.ensure_sim(name);
    }

    /// Restore one sim from a MARK checkpoint: rebuild the blank
    /// instance the daemon would have created, then re-submit the job
    /// history in order — each submit stepping the engine through its
    /// arrival exactly as the live `submit` handler did — and advance
    /// to the recorded step bound.
    pub(crate) fn restore_sim(&mut self, sm: &journal::SimMark) -> Result<(), String> {
        if self.sims.contains_key(&sm.name) {
            return Err(format!("a simulation named {:?} already exists", sm.name));
        }
        let mut inst = blank_instance(&self.cfg, &sm.name);
        for j in &sm.jobs {
            let job = Job::new(
                j.id,
                SimTime(j.submit),
                j.cores,
                j.mem,
                SimDuration(j.est),
                SimDuration(j.runtime),
                j.user,
                j.group,
            );
            inst.submit(SimTime(j.submit), job);
            inst.step_until(SimTime(j.submit));
        }
        inst.step_until(SimTime(sm.clock));
        self.sims.insert(
            sm.name.clone(),
            SimEntry { inst, next_job_id: sm.next_job_id, submitted: sm.jobs.clone() },
        );
        Ok(())
    }

    /// Append one record to the journal, degrading gracefully: a write
    /// failure logs a warning and detaches the journal — live sims keep
    /// running in memory; they are never killed over a full disk.
    fn journal_append(&mut self, rec: journal::Record) {
        if let Some(mut j) = self.journal.take() {
            match j.append(&rec) {
                Ok(()) => self.journal = Some(j),
                Err(e) => eprintln!(
                    "sst-sched serve: journal write failed ({e:#}); continuing IN MEMORY — \
                     state after this point will not survive a restart"
                ),
            }
        }
    }

    /// Write a MARK checkpoint (and compact the journal) once
    /// `serve.mark_interval` submits have been journaled. A sim that
    /// cannot be fingerprinted (a non-snapshotable source) cannot be
    /// journaled — the daemon degrades to in-memory with the snapshot
    /// layer's by-name error in the warning.
    fn maybe_mark(&mut self) {
        let interval = self.cfg.serve.mark_interval;
        let due = match &self.journal {
            Some(j) => j.should_mark(interval),
            None => false,
        };
        if !due {
            return;
        }
        let mut sims = Vec::with_capacity(self.sims.len());
        for (name, entry) in &self.sims {
            let fp_hash = match journal::mark_fingerprint(&entry.inst) {
                Ok(h) => h,
                Err(e) => {
                    eprintln!(
                        "sst-sched serve: sim {name:?} cannot be journaled ({e}); \
                         journaling disabled, continuing IN MEMORY"
                    );
                    self.journal = None;
                    return;
                }
            };
            sims.push(journal::SimMark {
                name: name.clone(),
                next_job_id: entry.next_job_id,
                clock: entry.inst.now().ticks(),
                fp_hash,
                jobs: entry.submitted.clone(),
            });
        }
        if let Some(mut j) = self.journal.take() {
            match j.mark_and_compact(&journal::Mark { sims }) {
                Ok(()) => self.journal = Some(j),
                Err(e) => eprintln!(
                    "sst-sched serve: journal compaction failed ({e:#}); continuing IN MEMORY — \
                     state after this point will not survive a restart"
                ),
            }
        }
    }

    /// Arrival time for a request: explicit `at`, else the sim clock;
    /// arrivals cannot land in the simulated past.
    fn arrival_time(v: &Json, now: SimTime) -> Result<SimTime, ReqError> {
        let at = match opt_u64(v, "at")? {
            Some(t) => SimTime(t),
            None => now,
        };
        if at < now {
            return Err(ReqError::at(
                "time_regression",
                format!(
                    "\"at\" = {} is before the simulation clock {} — arrivals cannot be \
                     scheduled in the past",
                    at.ticks(),
                    now.ticks()
                ),
            ));
        }
        Ok(at)
    }

    fn handle_submit(&mut self, v: &Json) -> Result<Json, ReqError> {
        let name = v.get_str_or("sim", "default").to_string();
        self.ensure_sim(&name)?;
        let journaling = self.journal.is_some();
        let entry = self.sims.get_mut(&name).expect("just ensured");
        let at = Self::arrival_time(v, entry.inst.now())?;
        let id = entry.next_job_id;
        let job = job_from(v, id, at)?;
        entry.next_job_id += 1;
        if journaling {
            entry.submitted.push(journal::JobRec {
                submit: at.ticks(),
                id,
                cores: job.cores,
                mem: job.memory_mb,
                est: job.est_runtime.ticks(),
                runtime: job.runtime.ticks(),
                user: job.user,
                group: job.group,
            });
        }
        entry.inst.submit(at, job);
        // Commit point: the live timeline advances through the arrival
        // (and everything it causes at that tick), so status reflects it
        // and later arrivals are appended behind it.
        entry.inst.step_until(at);
        self.submits += 1;
        Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("req", Json::str("submit")),
            ("sim", Json::str(name)),
            ("job_id", Json::num(id as f64)),
            ("at", Json::num(at.ticks() as f64)),
        ]))
    }

    fn handle_predict(&mut self, v: &Json) -> Result<Json, ReqError> {
        let name = v.get_str_or("sim", "default").to_string();
        if !self.sims.contains_key(&name) {
            // The only mutation a prediction can make is creating the
            // named sim — journal that (write-ahead), not the whole
            // speculative request.
            self.journal_append(journal::Record::Create(name.clone()));
        }
        self.ensure_sim(&name)?;
        let entry = self.sims.get_mut(&name).expect("just ensured");
        let at = Self::arrival_time(v, entry.inst.now())?;
        // Peek — not consume — the id: a real submit right after the
        // prediction replays the same job under the same identity.
        let id = entry.next_job_id;
        let job = job_from(v, id, at)?;
        let snap = entry
            .inst
            .snapshot()
            .map_err(|m| ReqError::at("snapshot", m))?;
        let mut clone = SimInstance::resume(snap);
        clone.submit(at, job);
        let report = clone.run_to_completion(None);
        self.predicts += 1;
        let started = report.completed.iter().find(|j| j.id == id).and_then(|j| j.start);
        match started {
            Some(s) => Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("req", Json::str("predict_wait")),
                ("sim", Json::str(name)),
                ("job_id", Json::num(id as f64)),
                ("predicted_start", Json::num(s.ticks() as f64)),
                ("predicted_wait", Json::num((s - at).ticks() as f64)),
            ])),
            None => Err(ReqError::at(
                "unplaceable",
                "the hypothetical job never starts (larger than the machine, or the \
                 speculative run ended first)",
            )),
        }
    }

    fn handle_status(&self, v: &Json) -> Result<Json, ReqError> {
        let name = v.get_str_or("sim", "default");
        let entry = self.sims.get(name).ok_or_else(|| {
            ReqError::at(
                "unknown_sim",
                format!("no simulation named {name:?} (submit or predict_wait creates one)"),
            )
        })?;
        Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("req", Json::str("status")),
            ("sim", Json::str(name)),
            ("policy", Json::str(entry.inst.policy_name())),
            ("now", Json::num(entry.inst.now().ticks() as f64)),
            ("queue_len", Json::num(entry.inst.queue_len() as f64)),
            ("running", Json::num(entry.inst.running_len() as f64)),
            ("completed", Json::num(entry.inst.completed_count() as f64)),
        ]))
    }

    fn metrics_json(&self) -> Json {
        Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("req", Json::str("metrics")),
            ("sims", Json::num(self.sims.len() as f64)),
            ("max_sims", Json::num(self.cfg.serve.max_sims as f64)),
            ("requests", Json::num(self.requests as f64)),
            ("submits", Json::num(self.submits as f64)),
            ("predicts", Json::num(self.predicts as f64)),
            ("errors", Json::num(self.errors as f64)),
            ("throttled", Json::num(self.throttled as f64)),
        ])
    }
}

/// Wire a fresh, empty simulation for the daemon: the config's machine
/// shape ([`DEFAULT_NODES`] x [`DEFAULT_CORES_PER_NODE`] unless
/// overridden) and every simulation knob the batch commands honor, but
/// no workload — jobs arrive only through requests.
fn blank_instance(cfg: &ExperimentConfig, name: &str) -> SimInstance {
    let nodes = cfg.nodes.unwrap_or(DEFAULT_NODES);
    let cores = cfg.cores_per_node.unwrap_or(DEFAULT_CORES_PER_NODE);
    let mut sim = Simulation::new(Workload::machine(name, nodes, cores), cfg.policy)
        .with_seed(cfg.seed)
        .with_faults(cfg.faults)
        .with_preemption(cfg.preemption)
        .with_reservations(cfg.reservations.clone())
        .with_horizon(cfg.planning_horizon)
        .with_auto_horizon_params(cfg.auto_horizon)
        .with_mem_per_node(cfg.mem_per_node)
        .with_memory_aware(cfg.memory_aware)
        .with_fairshare_half_life(cfg.fairshare_half_life);
    if let Some(order) = cfg.order {
        sim = sim.with_order(order);
    }
    sim.build()
}

/// Optional non-negative integer field; present-but-wrong-typed is an
/// error, not a silent default.
fn opt_u64(v: &Json, key: &str) -> Result<Option<u64>, ReqError> {
    match v.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(x) => x.as_u64().map(Some).ok_or_else(|| {
            ReqError::bad(format!("{key:?} must be a non-negative integer"))
        }),
    }
}

/// Build the submitted/hypothetical job from the request's `job` object:
/// `cores` and `runtime` required, `est` defaults to `runtime` (a
/// perfect estimate), `mem` to 0, `user` to 0.
fn job_from(v: &Json, id: u64, submit: SimTime) -> Result<Job, ReqError> {
    let j = v.get("job").ok_or_else(|| ReqError::bad("missing \"job\" object"))?;
    if j.as_obj().is_none() {
        return Err(ReqError::bad("\"job\" must be an object"));
    }
    let cores = opt_u64(j, "cores")?
        .ok_or_else(|| ReqError::bad("job.cores must be a positive integer"))?;
    let runtime = opt_u64(j, "runtime")?
        .ok_or_else(|| ReqError::bad("job.runtime must be a positive integer"))?;
    if cores == 0 || runtime == 0 {
        return Err(ReqError::bad("job.cores and job.runtime must be >= 1"));
    }
    let est = opt_u64(j, "est")?.unwrap_or(runtime);
    let mem = opt_u64(j, "mem")?.unwrap_or(0);
    let user = opt_u64(j, "user")?.unwrap_or(0) as u32;
    Ok(Job::new(id, submit, cores, mem, SimDuration(est), SimDuration(runtime), user, 0))
}

/// Error reply: `{"error": {...}, "ok": false}` with the request's line
/// number and, for parse errors, the byte offset inside the line — the
/// same locate-the-problem contract the trace parsers follow.
fn error_json(line_no: u64, e: &ReqError) -> Json {
    let mut err = vec![
        ("code", Json::str(e.code)),
        ("line", Json::num(line_no as f64)),
        ("message", Json::str(e.message.clone())),
    ];
    if let Some(b) = e.byte {
        err.push(("byte", Json::num(b as f64)));
    }
    Json::obj(vec![("error", Json::obj(err)), ("ok", Json::Bool(false))])
}

/// Initial client back-off hint carried by backpressure replies
/// (`retry_after_ms`): wait this long before the first resend, then
/// back off exponentially while the queue stays full — the retry
/// contract is documented in `docs/PROTOCOL.md`.
pub const RETRY_AFTER_MS: u64 = 25;

/// The explicit backpressure reply a connection sends when its bounded
/// request queue (depth `depth`) is full — the request is refused, not
/// buffered, so a flooding client cannot grow daemon memory. Carries a
/// machine-readable `retry_after_ms` so clients can back off without
/// parsing the message.
pub fn backpressure_json(line_no: u64, depth: usize) -> Json {
    Json::obj(vec![
        (
            "error",
            Json::obj(vec![
                ("code", Json::str("backpressure")),
                ("line", Json::num(line_no as f64)),
                (
                    "message",
                    Json::str(format!(
                        "request queue full ({depth} pending); retry after the daemon catches up"
                    )),
                ),
                ("retry_after_ms", Json::num(RETRY_AFTER_MS as f64)),
            ]),
        ),
        ("ok", Json::Bool(false)),
    ])
}

#[cfg(unix)]
static SHUTDOWN: AtomicBool = AtomicBool::new(false);

/// Route SIGTERM/SIGINT to the drain flag. The handler only stores an
/// atomic (async-signal-safe); the accept loop and connection readers
/// poll the flag and wind down.
#[cfg(unix)]
fn install_signal_handlers() {
    extern "C" fn on_signal(_sig: i32) {
        SHUTDOWN.store(true, Ordering::Relaxed);
    }
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    // SAFETY: `signal` is the C library's; the handler is an extern "C"
    // fn that performs a single atomic store.
    unsafe {
        signal(SIGTERM, on_signal);
        signal(SIGINT, on_signal);
    }
}

#[cfg(unix)]
fn is_draining(core: &Mutex<ServerCore>) -> bool {
    // A poisoned lock (a panicked connection) also drains the daemon.
    core.lock().map(|c| c.draining()).unwrap_or(true)
}

#[cfg(unix)]
fn write_line(writer: &Mutex<UnixStream>, resp: &Json) -> std::io::Result<()> {
    let mut s = resp.to_string();
    s.push('\n');
    let mut w = writer.lock().map_err(|_| std::io::Error::other("writer lock poisoned"))?;
    w.write_all(s.as_bytes())
}

/// Push one request line into the connection's bounded queue; on a full
/// queue, reply with [`backpressure_json`] immediately instead of
/// blocking the reader. Returns false when the connection is done.
#[cfg(unix)]
fn enqueue(
    tx: &mpsc::SyncSender<(u64, String)>,
    core: &Mutex<ServerCore>,
    writer: &Mutex<UnixStream>,
    line_no: u64,
    line: &str,
    depth: usize,
) -> bool {
    match tx.try_send((line_no, line.to_string())) {
        Ok(()) => true,
        Err(mpsc::TrySendError::Full(_)) => {
            if let Ok(mut c) = core.lock() {
                c.note_throttled();
            }
            write_line(writer, &backpressure_json(line_no, depth)).is_ok()
        }
        Err(mpsc::TrySendError::Disconnected(_)) => false,
    }
}

/// One connection: a reader loop feeding a bounded queue, a worker
/// thread consuming it through the shared [`ServerCore`]. The read
/// timeout keeps the reader responsive to drain/SIGTERM even when the
/// client holds the socket open silently.
#[cfg(unix)]
fn handle_conn(stream: UnixStream, core: Arc<Mutex<ServerCore>>, depth: usize) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let writer = match stream.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(_) => return,
    };
    let (tx, rx) = mpsc::sync_channel::<(u64, String)>(depth);
    let worker_core = Arc::clone(&core);
    let worker_writer = Arc::clone(&writer);
    let worker = std::thread::spawn(move || {
        for (line_no, line) in rx {
            let resp = match worker_core.lock() {
                Ok(mut c) => c.handle_line(line_no, &line),
                Err(_) => break,
            };
            if write_line(&worker_writer, &resp).is_err() {
                break;
            }
        }
    });
    let mut reader = BufReader::new(stream);
    let mut buf = String::new();
    let mut line_no = 0u64;
    loop {
        match reader.read_line(&mut buf) {
            Ok(0) => {
                // EOF; a final unterminated line is still a request.
                if !buf.trim().is_empty() {
                    line_no += 1;
                    let line = buf.trim().to_string();
                    enqueue(&tx, &core, &writer, line_no, &line, depth);
                }
                break;
            }
            Ok(_) => {
                let line = buf.trim().to_string();
                buf.clear();
                if !line.is_empty() {
                    line_no += 1;
                    if !enqueue(&tx, &core, &writer, line_no, &line, depth) {
                        break;
                    }
                }
                if SHUTDOWN.load(Ordering::Relaxed) || is_draining(&core) {
                    break;
                }
            }
            // Read timeout: `buf` keeps any partial line already read.
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                if SHUTDOWN.load(Ordering::Relaxed) || is_draining(&core) {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    // Close the queue; the worker drains what was accepted, then exits.
    drop(tx);
    let _ = worker.join();
}

/// Accept-loop poll backoff: start here when idle...
#[cfg(unix)]
const IDLE_POLL_MIN_MS: u64 = 1;
/// ...and double up to this cap, which bounds drain/SIGTERM latency the
/// same way the 200 ms connection read timeout does. An idle daemon
/// polls ~5×/s instead of the old fixed 20 ms busy-poll's 50×/s, and
/// any accepted connection snaps the interval back to the minimum.
#[cfg(unix)]
const IDLE_POLL_MAX_MS: u64 = 200;

/// Run the daemon: bind `cfg.serve.socket`, accept JSON-lines
/// connections, and serve until a `shutdown` request or SIGTERM/SIGINT;
/// then drain queued requests, join every connection, and unlink the
/// socket. Blocks the calling thread for the daemon's lifetime.
/// Equivalent to [`serve_opts`] with `resume = false`.
#[cfg(unix)]
pub fn serve(cfg: ExperimentConfig) -> anyhow::Result<()> {
    serve_opts(cfg, false)
}

/// Build the daemon core, honoring persistence: no `state_dir` → plain
/// in-memory core; `state_dir` + `resume` → recover by journal replay
/// and keep appending; `state_dir` fresh → create a new journal
/// (refusing to clobber an existing one — that is `--resume`'s job).
#[cfg(unix)]
fn build_core(cfg: &ExperimentConfig, resume: bool) -> anyhow::Result<ServerCore> {
    let dir = match &cfg.serve.state_dir {
        None => {
            if resume {
                anyhow::bail!("--resume needs a state directory (serve --resume <dir>)");
            }
            return Ok(ServerCore::new(cfg.clone()));
        }
        Some(d) => std::path::PathBuf::from(d),
    };
    if resume {
        let (core, report) = crate::runtime::recover::recover(cfg, &dir)?;
        eprintln!("sst-sched serve: recovered {}", report.summary());
        Ok(core)
    } else {
        let jpath = dir.join(journal::FILE_NAME);
        if jpath.exists() {
            anyhow::bail!(
                "state dir {dir:?} already holds a journal; resume it with \
                 `serve --resume {}` or remove {jpath:?} to start fresh",
                dir.display()
            );
        }
        let j = Journal::create(&dir, cfg.semantic_hash(), cfg.serve.durability)?;
        eprintln!(
            "sst-sched serve: journaling to {:?} (durability {}, mark interval {})",
            j.path(),
            cfg.serve.durability,
            cfg.serve.mark_interval
        );
        let mut core = ServerCore::new(cfg.clone());
        core.attach_journal(j);
        Ok(core)
    }
}

/// [`serve`] with an explicit resume flag (`sst-sched serve --resume`):
/// when `resume` is true the daemon recovers its sims from the journal
/// in `cfg.serve.state_dir` before accepting connections.
#[cfg(unix)]
pub fn serve_opts(cfg: ExperimentConfig, resume: bool) -> anyhow::Result<()> {
    let path = cfg.serve.socket.clone();
    let depth = cfg.serve.queue_depth;
    let max_sims = cfg.serve.max_sims;
    let core = build_core(&cfg, resume)?;
    if std::path::Path::new(&path).exists() {
        std::fs::remove_file(&path)
            .with_context(|| format!("removing stale socket {path:?}"))?;
    }
    let listener =
        UnixListener::bind(&path).with_context(|| format!("binding socket {path:?}"))?;
    listener
        .set_nonblocking(true)
        .context("setting the serve listener non-blocking")?;
    install_signal_handlers();
    let core = Arc::new(Mutex::new(core));
    eprintln!(
        "sst-sched serve: listening on {path} (max_sims {max_sims}, queue depth {depth})"
    );
    let mut conns = Vec::new();
    let mut idle_ms = IDLE_POLL_MIN_MS;
    loop {
        if SHUTDOWN.load(Ordering::Relaxed) || is_draining(&core) {
            break;
        }
        match listener.accept() {
            Ok((stream, _addr)) => {
                idle_ms = IDLE_POLL_MIN_MS;
                let conn_core = Arc::clone(&core);
                conns.push(std::thread::spawn(move || handle_conn(stream, conn_core, depth)));
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                // Exponential idle backoff instead of a fixed busy-poll.
                std::thread::sleep(Duration::from_millis(idle_ms));
                idle_ms = (idle_ms * 2).min(IDLE_POLL_MAX_MS);
            }
            Err(e) => return Err(e).context("accepting on the serve socket"),
        }
    }
    // Graceful drain: no new connections; live ones finish their queues.
    for c in conns {
        let _ = c.join();
    }
    let _ = std::fs::remove_file(&path);
    eprintln!("sst-sched serve: drained, socket removed");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::Policy;

    fn tiny_core() -> ServerCore {
        ServerCore::new(ExperimentConfig {
            nodes: Some(2),
            cores_per_node: Some(4),
            policy: Policy::Fcfs,
            ..ExperimentConfig::default()
        })
    }

    #[test]
    fn submit_assigns_monotone_ids_and_advances() {
        let mut c = tiny_core();
        let r1 = c.handle_line(1, r#"{"req":"submit","job":{"cores":4,"runtime":100}}"#);
        assert!(r1.get_bool_or("ok", false), "{r1:?}");
        assert_eq!(r1.get_u64_or("job_id", 0), 1);
        let r2 =
            c.handle_line(2, r#"{"req":"submit","at":10,"job":{"cores":4,"runtime":100}}"#);
        assert_eq!(r2.get_u64_or("job_id", 0), 2);
        let st = c.handle_line(3, r#"{"req":"status"}"#);
        assert_eq!(st.get_u64_or("now", 999), 10);
        assert_eq!(st.get_u64_or("running", 0), 2);
        assert_eq!(st.get_u64_or("queue_len", 9), 0);
    }

    #[test]
    fn predict_matches_quiet_system_reality() {
        let mut c = tiny_core();
        // Fill the machine until t=100.
        c.handle_line(1, r#"{"req":"submit","job":{"cores":4,"runtime":100}}"#);
        c.handle_line(2, r#"{"req":"submit","job":{"cores":4,"runtime":100}}"#);
        let p = c.handle_line(3, r#"{"req":"predict_wait","job":{"cores":4,"runtime":50}}"#);
        assert!(p.get_bool_or("ok", false), "{p:?}");
        assert_eq!(p.get_u64_or("predicted_start", 0), 100);
        assert_eq!(p.get_u64_or("predicted_wait", 0), 100);
        // Really submit the same job; the finished timeline must start
        // it exactly where the prediction said.
        let s = c.handle_line(4, r#"{"req":"submit","job":{"cores":4,"runtime":50}}"#);
        assert_eq!(s.get_u64_or("job_id", 0), p.get_u64_or("job_id", 99));
        let fp = c.fingerprint("default").unwrap();
        let line = fp
            .lines()
            .find(|l| l.starts_with("3:"))
            .expect("job 3 in fingerprint");
        let start: u64 = line.split(':').nth(1).unwrap().parse().unwrap();
        assert_eq!(start, 100);
    }

    #[test]
    fn predict_does_not_perturb_the_live_run() {
        let mut c = tiny_core();
        c.handle_line(1, r#"{"req":"submit","job":{"cores":3,"runtime":70}}"#);
        c.handle_line(2, r#"{"req":"submit","at":5,"job":{"cores":4,"runtime":40}}"#);
        let before = c.fingerprint("default").unwrap();
        for i in 0..4 {
            let p = c.handle_line(
                3 + i,
                r#"{"req":"predict_wait","job":{"cores":2,"runtime":30}}"#,
            );
            assert!(p.get_bool_or("ok", false), "{p:?}");
        }
        assert_eq!(before, c.fingerprint("default").unwrap());
    }

    #[test]
    fn admission_control_refuses_extra_sims() {
        let cfg = ExperimentConfig {
            nodes: Some(1),
            cores_per_node: Some(4),
            serve: crate::config::ServeOptions { max_sims: 1, ..Default::default() },
            ..ExperimentConfig::default()
        };
        let mut c = ServerCore::new(cfg);
        let ok = c.handle_line(1, r#"{"req":"submit","job":{"cores":1,"runtime":5}}"#);
        assert!(ok.get_bool_or("ok", false));
        let no =
            c.handle_line(2, r#"{"req":"submit","sim":"b","job":{"cores":1,"runtime":5}}"#);
        assert!(!no.get_bool_or("ok", true));
        assert_eq!(no.get("error").unwrap().get_str_or("code", ""), "sim_limit");
    }

    #[test]
    fn errors_carry_line_and_byte_offsets() {
        let mut c = tiny_core();
        let e = c.handle_line(7, "{\"req\": }");
        let err = e.get("error").unwrap();
        assert_eq!(err.get_str_or("code", ""), "parse");
        assert_eq!(err.get_u64_or("line", 0), 7);
        assert_eq!(err.get_u64_or("byte", 0), 8);
        let e2 = c.handle_line(8, r#"{"req":"submit","at":3,"job":{"cores":4,"runtime":9}}"#);
        assert!(e2.get_bool_or("ok", false));
        let e3 = c.handle_line(9, r#"{"req":"submit","at":1,"job":{"cores":1,"runtime":9}}"#);
        assert_eq!(e3.get("error").unwrap().get_str_or("code", ""), "time_regression");
    }

    #[test]
    fn backpressure_reply_shape() {
        let b = backpressure_json(9, 2);
        assert!(!b.get_bool_or("ok", true));
        let err = b.get("error").unwrap();
        assert_eq!(err.get_str_or("code", ""), "backpressure");
        assert_eq!(err.get_u64_or("line", 0), 9);
        assert_eq!(err.get_u64_or("retry_after_ms", 0), RETRY_AFTER_MS);
    }

    #[test]
    fn shutdown_flips_draining() {
        let mut c = tiny_core();
        assert!(!c.draining());
        let r = c.handle_line(1, r#"{"req":"shutdown"}"#);
        assert!(r.get_bool_or("draining", false));
        assert!(c.draining());
    }
}
