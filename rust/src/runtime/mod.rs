//! Execution services: the [`serve`] scheduler-as-a-service daemon,
//! its crash-safety layer (the [`journal`] write-ahead log and
//! [`recover`] deterministic replay recovery), and the PJRT bridge for
//! the AOT-compiled JAX/Pallas scoring artifact.
//!
//! ## PJRT runtime
//!
//! `make artifacts` lowers the L2 scoring model (python/compile/model.py,
//! which embeds the L1 Pallas fit kernel) to HLO *text*; this module loads
//! that text with `HloModuleProto::from_text_file`, compiles it once on
//! the PJRT CPU client, and exposes it as a [`QueueScorer`] the backfill
//! scheduler can call on its hot path. Python never runs at simulation
//! time — the binary is self-contained once `artifacts/` exists.
//!
//! HLO text (not a serialized proto) is the interchange format because
//! jax >= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see python/compile/aot.py).

//! The PJRT path needs an `xla` binding crate that is not part of the
//! offline crate set, so everything touching it is gated behind the
//! `xla` cargo feature; the default build keeps the [`Accel`] selector
//! and reports a clear error when an XLA backend is requested.

pub mod journal;
pub mod recover;
pub mod serve;

#[cfg(feature = "xla")]
use crate::sched::scorer::{QueueScorer, ScoreParams, Scores};
use anyhow::Result;
#[cfg(feature = "xla")]
use anyhow::{bail, Context};

/// Padded shapes baked into the artifact — keep in sync with
/// python/compile/model.py (Q_PAD, N_PAD).
pub const Q_PAD: usize = 256;
pub const N_PAD: usize = 512;

/// Default artifact location relative to the repo root.
pub const DEFAULT_ARTIFACT: &str = "artifacts/model.hlo.txt";

/// XLA-backed queue scorer (PJRT CPU client).
#[cfg(feature = "xla")]
pub struct XlaScorer {
    /// Kept alive for the executable's lifetime.
    _client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    q_pad: usize,
    n_pad: usize,
    /// Executions performed (for reporting).
    pub calls: u64,
}

#[cfg(feature = "xla")]
impl XlaScorer {
    /// Load and compile the artifact at `path`.
    pub fn load(path: &str) -> Result<XlaScorer> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path:?} (run `make artifacts`?)"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("compiling HLO on PJRT")?;
        Ok(XlaScorer { _client: client, exe, q_pad: Q_PAD, n_pad: N_PAD, calls: 0 })
    }

    /// Load the default artifact.
    pub fn load_default() -> Result<XlaScorer> {
        Self::load(DEFAULT_ARTIFACT)
    }

    /// One padded execution over at most `q_pad` jobs.
    fn execute_chunk(
        &mut self,
        req: &[f32],
        est: &[f32],
        wait: &[f32],
        free_padded: &[f32],
        params: [f32; 4],
        out: &mut Scores,
    ) -> Result<()> {
        debug_assert!(req.len() <= self.q_pad);
        let q = req.len();
        let pad = |xs: &[f32]| {
            let mut v = xs.to_vec();
            v.resize(self.q_pad, 0.0);
            xla::Literal::vec1(&v)
        };
        let lit_req = pad(req);
        let lit_est = pad(est);
        let lit_wait = pad(wait);
        let lit_free = xla::Literal::vec1(free_padded);
        let lit_params = xla::Literal::vec1(&params);
        let result = self
            .exe
            .execute::<xla::Literal>(&[lit_req, lit_est, lit_wait, lit_free, lit_params])
            .context("executing scorer artifact")?[0][0]
            .to_literal_sync()
            .context("fetching scorer result")?;
        self.calls += 1;
        let (waste, ok, prio) = result.to_tuple3().context("unpacking scorer tuple")?;
        let waste = waste.to_vec::<f32>()?;
        let ok = ok.to_vec::<f32>()?;
        let prio = prio.to_vec::<f32>()?;
        out.waste.extend_from_slice(&waste[..q]);
        out.backfill_ok.extend_from_slice(&ok[..q]);
        out.priority.extend_from_slice(&prio[..q]);
        Ok(())
    }

    /// Score arbitrarily long queues by chunking in `q_pad` batches.
    /// Node count must fit the artifact's padded width.
    pub fn score_checked(
        &mut self,
        job_req: &[f32],
        job_est: &[f32],
        job_wait: &[f32],
        node_free: &[f32],
        params: ScoreParams,
    ) -> Result<Scores> {
        if node_free.len() > self.n_pad {
            bail!(
                "cluster has {} nodes but the artifact is padded for {} — \
                 re-run `make artifacts` with a larger --n",
                node_free.len(),
                self.n_pad
            );
        }
        let mut free = node_free.to_vec();
        free.resize(self.n_pad, 0.0);
        let q = job_req.len();
        let mut out = Scores {
            waste: Vec::with_capacity(q),
            backfill_ok: Vec::with_capacity(q),
            priority: Vec::with_capacity(q),
        };
        let p = params.as_array();
        let mut i = 0;
        while i < q {
            let j = (i + self.q_pad).min(q);
            self.execute_chunk(
                &job_req[i..j],
                &job_est[i..j],
                &job_wait[i..j],
                &free,
                p,
                &mut out,
            )?;
            i = j;
        }
        Ok(out)
    }
}

#[cfg(feature = "xla")]
impl QueueScorer for XlaScorer {
    fn score(
        &mut self,
        job_req: &[f32],
        job_est: &[f32],
        job_wait: &[f32],
        node_free: &[f32],
        params: ScoreParams,
    ) -> Scores {
        self.score_checked(job_req, job_est, job_wait, node_free, params)
            .expect("XLA scorer execution failed")
    }

    fn backend(&self) -> &'static str {
        "xla"
    }
}

/// Hybrid scorer: PJRT dispatch costs ~150 us per call regardless of
/// batch size (EXPERIMENTS.md §Perf), so small queues are scored by the
/// native implementation and only large ones go to the artifact. Both
/// backends produce identical decisions (xla_parity tests), so the
/// crossover is purely a latency knob.
#[cfg(feature = "xla")]
pub struct HybridScorer {
    native: crate::sched::NativeScorer,
    xla: XlaScorer,
    /// Queue length at which the XLA path wins (measured crossover).
    pub threshold: usize,
}

#[cfg(feature = "xla")]
impl HybridScorer {
    pub fn load_default() -> Result<HybridScorer> {
        Ok(HybridScorer {
            native: crate::sched::NativeScorer::new(),
            xla: XlaScorer::load_default()?,
            threshold: 512,
        })
    }

    /// Fraction of calls that went to the XLA path.
    pub fn xla_calls(&self) -> u64 {
        self.xla.calls
    }
}

#[cfg(feature = "xla")]
impl QueueScorer for HybridScorer {
    fn score(
        &mut self,
        job_req: &[f32],
        job_est: &[f32],
        job_wait: &[f32],
        node_free: &[f32],
        params: ScoreParams,
    ) -> Scores {
        if job_req.len() >= self.threshold {
            self.xla.score(job_req, job_est, job_wait, node_free, params)
        } else {
            self.native.score(job_req, job_est, job_wait, node_free, params)
        }
    }

    fn backend(&self) -> &'static str {
        "hybrid"
    }
}

/// Scorer backend selector (CLI `--accel`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Accel {
    /// Pure-Rust scorer (always available).
    #[default]
    Native,
    /// AOT-compiled JAX/Pallas artifact via PJRT.
    Xla,
    /// Native below the measured batch-size crossover, XLA above.
    Hybrid,
}

impl std::str::FromStr for Accel {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Ok(Accel::Native),
            "xla" => Ok(Accel::Xla),
            "hybrid" => Ok(Accel::Hybrid),
            other => Err(format!("unknown accel {other:?} (expected native|xla|hybrid)")),
        }
    }
}

/// Build a backfill scheduler with the requested scorer backend.
#[cfg(feature = "xla")]
pub fn backfill_with_accel(accel: Accel) -> Result<crate::sched::BackfillScheduler> {
    Ok(match accel {
        Accel::Native => crate::sched::BackfillScheduler::new(),
        Accel::Xla => crate::sched::BackfillScheduler::with_scorer(Box::new(
            XlaScorer::load_default()?,
        )),
        Accel::Hybrid => crate::sched::BackfillScheduler::with_scorer(Box::new(
            HybridScorer::load_default()?,
        )),
    })
}

/// Without the `xla` feature only the native scorer is available; the
/// XLA backends fail with an actionable message instead of a link error.
#[cfg(not(feature = "xla"))]
pub fn backfill_with_accel(accel: Accel) -> Result<crate::sched::BackfillScheduler> {
    match accel {
        Accel::Native => Ok(crate::sched::BackfillScheduler::new()),
        Accel::Xla | Accel::Hybrid => Err(anyhow::anyhow!(
            "this build has no XLA/PJRT support (rebuild with `--features xla` \
             and a vendored `xla` crate); use --accel native"
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accel_parses() {
        assert_eq!("xla".parse::<Accel>().unwrap(), Accel::Xla);
        assert_eq!("NATIVE".parse::<Accel>().unwrap(), Accel::Native);
        assert_eq!("hybrid".parse::<Accel>().unwrap(), Accel::Hybrid);
        assert!("gpu".parse::<Accel>().is_err());
    }

    #[test]
    fn native_backend_always_builds() {
        let s = backfill_with_accel(Accel::Native).unwrap();
        assert_eq!(s.scorer_backend(), "native");
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn xla_backend_errors_without_feature() {
        for accel in [Accel::Xla, Accel::Hybrid] {
            let err = backfill_with_accel(accel).unwrap_err().to_string();
            assert!(err.contains("xla"), "{err}");
        }
    }
}

#[cfg(all(test, feature = "xla"))]
mod xla_tests {
    use super::*;
    use crate::sched::scorer::{NativeScorer, NOFIT};

    fn artifact_available() -> bool {
        std::path::Path::new(DEFAULT_ARTIFACT).exists()
    }

    fn params() -> ScoreParams {
        ScoreParams { shadow_time: 120.0, extra_cores: 8.0, aging_weight: 1.0, waste_weight: 0.5 }
    }

    #[test]
    fn hybrid_routes_by_batch_size() {
        if !artifact_available() {
            return;
        }
        let mut h = HybridScorer::load_default().unwrap();
        h.threshold = 64;
        let small: Vec<f32> = vec![1.0; 32];
        let free = vec![8.0; 16];
        let _ = h.score(&small, &small, &small, &free, params());
        assert_eq!(h.xla_calls(), 0, "small batch must stay native");
        let big: Vec<f32> = vec![1.0; 128];
        let _ = h.score(&big, &big, &big, &free, params());
        assert!(h.xla_calls() > 0, "large batch must use XLA");
    }

    #[test]
    fn xla_matches_native_scorer() {
        if !artifact_available() {
            eprintln!("skipping: artifacts/model.hlo.txt missing (run `make artifacts`)");
            return;
        }
        let mut xs = XlaScorer::load_default().unwrap();
        let mut ns = NativeScorer::new();
        let req: Vec<f32> = (0..40).map(|i| (i % 9) as f32).collect();
        let est: Vec<f32> = (0..40).map(|i| 30.0 * (1 + i % 7) as f32).collect();
        let wait: Vec<f32> = (0..40).map(|i| 10.0 * i as f32).collect();
        let free: Vec<f32> = (0..72).map(|i| (i % 3) as f32 * 4.0).collect();
        let a = xs.score(&req, &est, &wait, &free, params());
        let b = ns.score(&req, &est, &wait, &free, params());
        assert_eq!(a.backfill_ok, b.backfill_ok);
        for (x, y) in a.waste.iter().zip(&b.waste) {
            assert!((x - y).abs() <= 1e-4 * y.abs().max(1.0), "waste {x} vs {y}");
        }
        for (x, y) in a.priority.iter().zip(&b.priority) {
            assert!((x - y).abs() <= 1e-3 * y.abs().max(1.0), "prio {x} vs {y}");
        }
    }

    #[test]
    fn xla_chunking_handles_long_queues() {
        if !artifact_available() {
            return;
        }
        let mut xs = XlaScorer::load_default().unwrap();
        let q = Q_PAD * 2 + 37; // forces 3 chunks
        let req: Vec<f32> = (0..q).map(|i| (i % 16 + 1) as f32).collect();
        let est = vec![60.0; q];
        let wait = vec![0.0; q];
        let free = vec![8.0; 64];
        let s = xs.score(&req, &est, &wait, &free, params());
        assert_eq!(s.waste.len(), q);
        assert_eq!(xs.calls, 3);
        // Spot-check semantics: a 1-core job's best single-node slack is 7.
        assert_eq!(s.waste[0], 7.0);
        // 9..16-core jobs fit no single 8-core node.
        assert_eq!(s.waste[8], NOFIT);
    }

    #[test]
    fn too_many_nodes_is_a_clear_error() {
        if !artifact_available() {
            return;
        }
        let mut xs = XlaScorer::load_default().unwrap();
        let free = vec![1.0; N_PAD + 1];
        let err = xs
            .score_checked(&[1.0], &[1.0], &[0.0], &free, params())
            .unwrap_err()
            .to_string();
        assert!(err.contains("padded"), "{err}");
    }
}
