//! Streaming trace ingestion — the constant-memory half of the
//! million-job scale path.
//!
//! [`JobStream`] parses one archive record at a time off any
//! [`BufRead`]: the trace is never materialized as a `Vec<Job>` (the
//! eager `parse_swf`/`parse_gwf` collectors are now thin wrappers over
//! the same per-line parsers), so peak memory is one line buffer plus
//! one `Job`, independent of trace length. Pair it with
//! [`crate::sim::Simulation::with_job_stream`] to feed the simulator's
//! arrival queue incrementally: the source pulls the next record only
//! when simulated time reaches it, keeping peak RSS O(active jobs).
//!
//! Both archive formats guarantee submit-sorted records (the Parallel
//! Workloads Archive and Grid Workloads Archive sort their logs), which
//! is what lets the source run off a one-job lookahead instead of a
//! reorder buffer; a late record is emitted immediately rather than
//! reordered.

use crate::job::Job;
use crate::trace::{gwf, swf};
use anyhow::{Context, Result};
use std::fs::File;
use std::io::{BufRead, BufReader};

/// Which archive format a stream parses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceFormat {
    Swf,
    Gwf,
}

impl TraceFormat {
    /// Pick the format from a file name (`.gwf` = GWF, anything else =
    /// SWF — the same rule the CLI `--trace` flag applies).
    pub fn from_path(path: &str) -> TraceFormat {
        if path.ends_with(".gwf") {
            TraceFormat::Gwf
        } else {
            TraceFormat::Swf
        }
    }

    fn parse_line(self, line: &str, lineno: usize) -> Result<Option<Job>> {
        match self {
            TraceFormat::Swf => swf::parse_swf_line(line, lineno),
            TraceFormat::Gwf => gwf::parse_gwf_line(line, lineno),
        }
    }
}

/// A line-buffered job stream over any reader. Yields `Ok(job)` per
/// valid record, skips comments/blanks/cancelled records silently, and
/// yields one `Err` (then ends) on the first structurally broken line —
/// exactly the records and the error the eager parser produces, in the
/// same order.
pub struct JobStream<R: BufRead> {
    reader: R,
    format: TraceFormat,
    lineno: usize,
    /// Reused line buffer — the only per-record allocation high-water
    /// mark in the stream.
    line: String,
    yielded: u64,
    done: bool,
}

impl<R: BufRead> JobStream<R> {
    pub fn new(reader: R, format: TraceFormat) -> JobStream<R> {
        JobStream { reader, format, lineno: 0, line: String::new(), yielded: 0, done: false }
    }

    /// Records yielded so far (observability; the debug-counter tests).
    pub fn yielded(&self) -> u64 {
        self.yielded
    }
}

impl<R: BufRead> Iterator for JobStream<R> {
    type Item = Result<Job>;

    fn next(&mut self) -> Option<Result<Job>> {
        while !self.done {
            self.line.clear();
            match self.reader.read_line(&mut self.line) {
                Ok(0) => self.done = true,
                Ok(_) => {
                    self.lineno += 1;
                    match self.format.parse_line(&self.line, self.lineno) {
                        Ok(None) => {}
                        Ok(Some(job)) => {
                            self.yielded += 1;
                            return Some(Ok(job));
                        }
                        Err(e) => {
                            self.done = true;
                            return Some(Err(e));
                        }
                    }
                }
                Err(e) => {
                    self.done = true;
                    let err = anyhow::Error::from(e)
                        .context(format!("reading trace line {}", self.lineno + 1));
                    return Some(Err(err));
                }
            }
        }
        None
    }
}

/// Open `path` as a job stream, auto-detecting the format from the
/// extension.
pub fn stream_trace_file(path: &str) -> Result<JobStream<BufReader<File>>> {
    let file = File::open(path).with_context(|| format!("opening trace file {path:?}"))?;
    Ok(JobStream::new(BufReader::new(file), TraceFormat::from_path(path)))
}

/// Open `path` as an SWF job stream.
pub fn stream_swf_file(path: &str) -> Result<JobStream<BufReader<File>>> {
    let file = File::open(path).with_context(|| format!("opening SWF file {path:?}"))?;
    Ok(JobStream::new(BufReader::new(file), TraceFormat::Swf))
}

/// Open `path` as a GWF job stream.
pub fn stream_gwf_file(path: &str) -> Result<JobStream<BufReader<File>>> {
    let file = File::open(path).with_context(|| format!("opening GWF file {path:?}"))?;
    Ok(JobStream::new(BufReader::new(file), TraceFormat::Gwf))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    const SWF_SAMPLE: &str = "\
; header comment
1 0 10 120 4 -1 -1 4 600 -1 1 12 3 -1 -1 -1 -1 -1

2 30 -1 60 -1 -1 -1 8 100 2048 1 7 1 -1 -1 -1 -1 -1
3 60 5 -1 4 -1 -1 4 600 -1 0 2 1 -1 -1 -1 -1 -1
";

    fn stream(text: &str, format: TraceFormat) -> JobStream<Cursor<Vec<u8>>> {
        JobStream::new(Cursor::new(text.as_bytes().to_vec()), format)
    }

    #[test]
    fn stream_yields_what_eager_parses() {
        let streamed: Vec<Job> =
            stream(SWF_SAMPLE, TraceFormat::Swf).map(|j| j.unwrap()).collect();
        let eager = crate::trace::parse_swf(SWF_SAMPLE).unwrap();
        assert_eq!(streamed.len(), eager.len());
        for (a, b) in streamed.iter().zip(&eager) {
            assert_eq!(
                (a.id, a.submit, a.cores, a.memory_mb),
                (b.id, b.submit, b.cores, b.memory_mb)
            );
            assert_eq!(
                (a.est_runtime, a.runtime, a.user, a.group),
                (b.est_runtime, b.runtime, b.user, b.group)
            );
        }
    }

    #[test]
    fn stream_counts_yielded_records() {
        let mut s = stream(SWF_SAMPLE, TraceFormat::Swf);
        assert_eq!(s.yielded(), 0);
        for r in s.by_ref() {
            r.unwrap();
        }
        assert_eq!(s.yielded(), 2, "jobs 1 and 2 parse; job 3 is cancelled");
    }

    #[test]
    fn broken_line_errors_once_then_ends() {
        let text = "1 0 10 120 4 -1 -1 4 600 -1 1 12 3 -1 -1 -1 -1 -1\n1 2 3\n";
        let mut s = stream(text, TraceFormat::Swf);
        assert!(s.next().unwrap().is_ok());
        assert!(s.next().unwrap().is_err());
        assert!(s.next().is_none(), "a broken stream must end after its error");
    }

    #[test]
    fn gwf_format_detected_and_parsed() {
        assert_eq!(TraceFormat::from_path("x.gwf"), TraceFormat::Gwf);
        assert_eq!(TraceFormat::from_path("x.swf"), TraceFormat::Swf);
        assert_eq!(TraceFormat::from_path("plain"), TraceFormat::Swf);
        let text = "# c\n0 0 2 33.0 1 32.9 -1 1 900 -1 1 3 1 14 -1\n";
        let jobs: Vec<Job> = stream(text, TraceFormat::Gwf).map(|j| j.unwrap()).collect();
        assert_eq!(jobs.len(), 1);
        assert_eq!(jobs[0].runtime.ticks(), 33);
    }
}
