//! Streaming trace ingestion — the constant-memory half of the
//! million-job scale path.
//!
//! [`JobStream`] parses one archive record at a time off any
//! [`BufRead`]: the trace is never materialized as a `Vec<Job>` (the
//! eager `parse_swf`/`parse_gwf` collectors are now thin wrappers over
//! the same per-line parsers), so peak memory is one line buffer plus
//! one `Job`, independent of trace length. Pair it with
//! [`crate::sim::Simulation::with_job_stream`] to feed the simulator's
//! arrival queue incrementally: the source pulls the next record only
//! when simulated time reaches it, keeping peak RSS O(active jobs).
//!
//! Both archive formats guarantee submit-sorted records (the Parallel
//! Workloads Archive and Grid Workloads Archive sort their logs), which
//! is what lets the source run off a one-job lookahead instead of a
//! reorder buffer; a late record is emitted immediately rather than
//! reordered.

use crate::job::Job;
use crate::trace::{fast, gwf, swf};
use anyhow::{bail, Context, Result};
use std::fs::File;
use std::io::{BufRead, BufReader};

/// Which trace format a path or stream carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceFormat {
    /// Standard Workload Format (Parallel Workloads Archive text).
    Swf,
    /// Grid Workloads Format (Grid Workloads Archive text).
    Gwf,
    /// Compact binary format (see [`crate::trace::stf`]); always read
    /// through the byte scanner, never the line parser.
    Stf,
}

impl TraceFormat {
    /// Pick the format from a file name by its extension,
    /// case-insensitively: `.gwf` = GWF, `.stf` = binary, anything else
    /// (including the explicit `.swf`) = SWF — the rule the CLI
    /// `--trace` flag and `sst-sched convert` both apply. Archives ship
    /// uppercase names (`DAS2.GWF`), which a case-sensitive match used
    /// to mis-route into the SWF parser.
    pub fn from_path(path: &str) -> TraceFormat {
        let ext = path.rsplit('.').next().unwrap_or("");
        if ext.eq_ignore_ascii_case("gwf") {
            TraceFormat::Gwf
        } else if ext.eq_ignore_ascii_case("stf") {
            TraceFormat::Stf
        } else {
            TraceFormat::Swf
        }
    }

    /// Default `(nodes, cores_per_node)` a bare trace of this format
    /// targets: SWF defaults to the paper's SDSC-SP2 platform (128
    /// nodes), GWF to the GWA-DAS2 platform (72 dual-core nodes). An
    /// stf trace normally carries its machine in the header; this is
    /// only the fallback when the producer did not record one.
    pub fn default_machine(self) -> (usize, u64) {
        match self {
            TraceFormat::Swf => (128, 1),
            TraceFormat::Gwf => (72, 2),
            TraceFormat::Stf => (128, 1),
        }
    }

    fn parse_line(self, line: &str, lineno: usize) -> Result<Option<Job>> {
        match self {
            TraceFormat::Swf => swf::parse_swf_line(line, lineno),
            TraceFormat::Gwf => gwf::parse_gwf_line(line, lineno),
            TraceFormat::Stf => {
                bail!("stf is a binary format; open it through trace::fast, not a line stream")
            }
        }
    }
}

/// A line-buffered job stream over any reader. Yields `Ok(job)` per
/// valid record, skips comments/blanks/cancelled records silently, and
/// yields one `Err` (then ends) on the first structurally broken line —
/// exactly the records and the error the eager parser produces, in the
/// same order.
pub struct JobStream<R: BufRead> {
    reader: R,
    format: TraceFormat,
    lineno: usize,
    /// Byte offset of the next unread line — so a mid-stream parse
    /// error can report *where* in the file it happened, not just on
    /// which line.
    offset: u64,
    /// Reused line buffer — the only per-record allocation high-water
    /// mark in the stream.
    line: String,
    yielded: u64,
    done: bool,
}

impl<R: BufRead> JobStream<R> {
    pub fn new(reader: R, format: TraceFormat) -> JobStream<R> {
        JobStream {
            reader,
            format,
            lineno: 0,
            offset: 0,
            line: String::new(),
            yielded: 0,
            done: false,
        }
    }

    /// Records yielded so far (observability; the debug-counter tests).
    pub fn yielded(&self) -> u64 {
        self.yielded
    }
}

impl<R: BufRead> Iterator for JobStream<R> {
    type Item = Result<Job>;

    fn next(&mut self) -> Option<Result<Job>> {
        while !self.done {
            self.line.clear();
            match self.reader.read_line(&mut self.line) {
                Ok(0) => self.done = true,
                Ok(n) => {
                    self.lineno += 1;
                    let line_start = self.offset;
                    self.offset += n as u64;
                    match self.format.parse_line(&self.line, self.lineno) {
                        Ok(None) => {}
                        Ok(Some(job)) => {
                            self.yielded += 1;
                            return Some(Ok(job));
                        }
                        Err(e) => {
                            self.done = true;
                            // Same error envelope the byte scanner
                            // applies — the differential tests compare
                            // these strings verbatim.
                            return Some(Err(e.context(format!(
                                "trace line {} at byte offset {}",
                                self.lineno, line_start
                            ))));
                        }
                    }
                }
                Err(e) => {
                    self.done = true;
                    let err = anyhow::Error::from(e)
                        .context(format!("reading trace line {}", self.lineno + 1));
                    return Some(Err(err));
                }
            }
        }
        None
    }
}

/// Open `path` as a *text* job stream, auto-detecting SWF vs GWF from
/// the extension. Binary `.stf` traces have no line structure — this
/// returns an error for them; use [`open_trace_stream_with_machine`]
/// (or [`crate::trace::fast::FastTrace`] directly), which routes every
/// format.
pub fn stream_trace_file(path: &str) -> Result<JobStream<BufReader<File>>> {
    let format = TraceFormat::from_path(path);
    if format == TraceFormat::Stf {
        bail!("{path:?} is a binary stf trace; open it through trace::fast, not a line stream");
    }
    let file = File::open(path).with_context(|| format!("opening trace file {path:?}"))?;
    Ok(JobStream::new(BufReader::new(file), format))
}

/// Open any trace as a boxed job stream plus the `(nodes,
/// cores_per_node)` machine it targets — the single entry point the
/// streamed CLI run and `sst-sched convert` share.
///
/// Format routing: `.stf` always goes through the byte scanner (its
/// machine comes from the file header); text formats go through the
/// scalar [`JobStream`] unless `fast` is set, in which case the whole
/// file is loaded once and scanned by [`crate::trace::fast`]. Either
/// way the stream yields the same records in the same order with the
/// same first-error message — that is the parity contract
/// `tests/prop_fastparse.rs` enforces.
pub fn open_trace_stream_with_machine(
    path: &str,
    fast: bool,
) -> Result<(Box<dyn Iterator<Item = Result<Job>> + Send>, (usize, u64))> {
    let format = TraceFormat::from_path(path);
    if fast || format == TraceFormat::Stf {
        let trace = fast::FastTrace::open(path)?;
        let machine = trace.machine();
        Ok((Box::new(trace.into_stream()), machine))
    } else {
        Ok((Box::new(stream_trace_file(path)?), format.default_machine()))
    }
}

/// Open `path` as an SWF job stream.
pub fn stream_swf_file(path: &str) -> Result<JobStream<BufReader<File>>> {
    let file = File::open(path).with_context(|| format!("opening SWF file {path:?}"))?;
    Ok(JobStream::new(BufReader::new(file), TraceFormat::Swf))
}

/// Open `path` as a GWF job stream.
pub fn stream_gwf_file(path: &str) -> Result<JobStream<BufReader<File>>> {
    let file = File::open(path).with_context(|| format!("opening GWF file {path:?}"))?;
    Ok(JobStream::new(BufReader::new(file), TraceFormat::Gwf))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    const SWF_SAMPLE: &str = "\
; header comment
1 0 10 120 4 -1 -1 4 600 -1 1 12 3 -1 -1 -1 -1 -1

2 30 -1 60 -1 -1 -1 8 100 2048 1 7 1 -1 -1 -1 -1 -1
3 60 5 -1 4 -1 -1 4 600 -1 0 2 1 -1 -1 -1 -1 -1
";

    fn stream(text: &str, format: TraceFormat) -> JobStream<Cursor<Vec<u8>>> {
        JobStream::new(Cursor::new(text.as_bytes().to_vec()), format)
    }

    #[test]
    fn stream_yields_what_eager_parses() {
        let streamed: Vec<Job> =
            stream(SWF_SAMPLE, TraceFormat::Swf).map(|j| j.unwrap()).collect();
        let eager = crate::trace::parse_swf(SWF_SAMPLE).unwrap();
        assert_eq!(streamed.len(), eager.len());
        for (a, b) in streamed.iter().zip(&eager) {
            assert_eq!(
                (a.id, a.submit, a.cores, a.memory_mb),
                (b.id, b.submit, b.cores, b.memory_mb)
            );
            assert_eq!(
                (a.est_runtime, a.runtime, a.user, a.group),
                (b.est_runtime, b.runtime, b.user, b.group)
            );
        }
    }

    #[test]
    fn stream_counts_yielded_records() {
        let mut s = stream(SWF_SAMPLE, TraceFormat::Swf);
        assert_eq!(s.yielded(), 0);
        for r in s.by_ref() {
            r.unwrap();
        }
        assert_eq!(s.yielded(), 2, "jobs 1 and 2 parse; job 3 is cancelled");
    }

    #[test]
    fn broken_line_errors_once_then_ends() {
        let text = "1 0 10 120 4 -1 -1 4 600 -1 1 12 3 -1 -1 -1 -1 -1\n1 2 3\n";
        let mut s = stream(text, TraceFormat::Swf);
        assert!(s.next().unwrap().is_ok());
        let e = s.next().unwrap().unwrap_err().to_string();
        assert!(e.contains("trace line 2 at byte offset 50"), "{e}");
        assert!(e.contains("swf line 2"), "{e}");
        assert!(s.next().is_none(), "a broken stream must end after its error");
    }

    #[test]
    fn format_detected_from_extension_case_insensitively() {
        assert_eq!(TraceFormat::from_path("x.gwf"), TraceFormat::Gwf);
        assert_eq!(TraceFormat::from_path("DAS2.GWF"), TraceFormat::Gwf);
        assert_eq!(TraceFormat::from_path("mixed.Gwf"), TraceFormat::Gwf);
        assert_eq!(TraceFormat::from_path("x.swf"), TraceFormat::Swf);
        assert_eq!(TraceFormat::from_path("SDSC.SWF"), TraceFormat::Swf);
        assert_eq!(TraceFormat::from_path("x.stf"), TraceFormat::Stf);
        assert_eq!(TraceFormat::from_path("X.STF"), TraceFormat::Stf);
        assert_eq!(TraceFormat::from_path("plain"), TraceFormat::Swf);
        assert_eq!(TraceFormat::from_path("dir.gwf/trace"), TraceFormat::Swf);
        assert_eq!(TraceFormat::from_path(""), TraceFormat::Swf);
    }

    #[test]
    fn gwf_stream_parses() {
        let text = "# c\n0 0 2 33.0 1 32.9 -1 1 900 -1 1 3 1 14 -1\n";
        let jobs: Vec<Job> = stream(text, TraceFormat::Gwf).map(|j| j.unwrap()).collect();
        assert_eq!(jobs.len(), 1);
        assert_eq!(jobs[0].runtime.ticks(), 33);
    }

    #[test]
    fn default_machines_per_format() {
        assert_eq!(TraceFormat::Swf.default_machine(), (128, 1));
        assert_eq!(TraceFormat::Gwf.default_machine(), (72, 2));
        assert_eq!(TraceFormat::Stf.default_machine(), (128, 1));
    }

    #[test]
    fn stf_rejected_by_line_stream() {
        let e = TraceFormat::Stf.parse_line("anything", 1).unwrap_err().to_string();
        assert!(e.contains("binary"), "{e}");
        assert!(stream_trace_file("nonexistent.stf").is_err());
    }
}
