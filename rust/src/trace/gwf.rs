//! Grid Workloads Format (GWF) — the Grid Workloads Archive format of the
//! GWA-DAS2 trace the paper validates against (§4.1).
//!
//! GWF lines carry 29 `\t`-or-space-separated fields; the first 14 mirror
//! SWF semantics: JobID SubmitTime WaitTime RunTime NProc AverageCPUTime
//! UsedMemory ReqNProcs ReqTime ReqMemory Status UserID GroupID
//! ExecutableID ... Comments start with `#`.

use crate::core::time::{SimDuration, SimTime};
use crate::job::Job;
use anyhow::{bail, Context, Result};

/// Fold the nine numeric GWF fields into a job, or `None` for a
/// skipped record (cancelled or failed grid submissions with
/// non-positive runtime/processor counts). The *semantic* half of
/// record parsing, shared by the scalar [`parse_gwf_line`] and the
/// byte scanner in [`crate::trace::fast`], so the two ingestion paths
/// can only disagree about tokenization, never about rounding or
/// record skipping.
#[allow(clippy::too_many_arguments)]
pub(crate) fn job_from_gwf_fields(
    id: f64,
    submit: f64,
    run: f64,
    nproc: f64,
    req_n: f64,
    req_time: f64,
    req_mem: f64,
    user: f64,
    group: f64,
) -> Option<Job> {
    let procs = if req_n > 0.0 { req_n } else { nproc };
    if run <= 0.0 || procs <= 0.0 || id < 0.0 || submit < 0.0 {
        return None;
    }
    let est = if req_time > 0.0 { req_time } else { run };
    Some(Job::new(
        id as u64,
        SimTime(submit as u64),
        procs as u64,
        req_mem.max(0.0) as u64,
        SimDuration(est.round() as u64),
        SimDuration(run.round() as u64),
        user.max(0.0) as u32,
        group.max(0.0) as u32,
    ))
}

/// Parse one GWF line. `Ok(None)` for comments, blanks and skipped
/// records (see [`job_from_gwf_fields`]); `Err` only for structurally
/// broken lines. `lineno` is 1-based. Shared by the eager [`parse_gwf`]
/// and the streaming [`crate::trace::JobStream`].
pub fn parse_gwf_line(line: &str, lineno: usize) -> Result<Option<Job>> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(None);
    }
    let f: Vec<&str> = line.split_whitespace().collect();
    if f.len() < 13 {
        bail!("gwf line {}: expected >= 13 fields, got {}", lineno, f.len());
    }
    let num = |idx: usize| -> Result<f64> {
        f[idx]
            .parse::<f64>()
            .with_context(|| format!("gwf line {}: field {} = {:?}", lineno, idx + 1, f[idx]))
    };
    let id = num(0)?;
    let submit = num(1)?;
    let run = num(3)?;
    let nproc = num(4)?;
    let req_n = num(7)?;
    let req_time = num(8)?;
    let req_mem = num(9)?;
    let user = num(11)?;
    let group = num(12)?;
    Ok(job_from_gwf_fields(id, submit, run, nproc, req_n, req_time, req_mem, user, group))
}

/// Parse GWF text into jobs (eager path: a thin collect over
/// [`parse_gwf_line`]).
pub fn parse_gwf(text: &str) -> Result<Vec<Job>> {
    let mut jobs = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        if let Some(job) = parse_gwf_line(line, lineno + 1)? {
            jobs.push(job);
        }
    }
    Ok(jobs)
}

/// Read and parse a GWF file (eager: collects the stream — use
/// [`crate::trace::stream_trace_file`] to keep memory O(1) in the trace
/// length).
pub fn load_gwf_file(path: &str) -> Result<Vec<Job>> {
    crate::trace::stream::stream_gwf_file(path)?
        .collect::<Result<Vec<Job>>>()
        .with_context(|| format!("reading GWF file {path:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# GWA-DAS2 sample
# JobID SubmitTime WaitTime RunTime NProc AvgCPU UsedMem ReqNProcs ReqTime ReqMem Status UserID GroupID ExecID
0 0 2 33.0 1 32.9 -1 1 900 -1 1 3 1 14 -1 -1 -1 -1 -1
1 12 0 61.5 2 60.0 -1 2 900 512 1 5 1 14 -1 -1 -1 -1 -1
2 40 0 -1 1 -1 -1 1 900 -1 0 5 1 14 -1 -1 -1 -1 -1
";

    #[test]
    fn parses_valid_records() {
        let jobs = parse_gwf(SAMPLE).unwrap();
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[0].id, 0);
        assert_eq!(jobs[0].cores, 1);
        assert_eq!(jobs[0].runtime, SimDuration(33));
        assert_eq!(jobs[0].est_runtime, SimDuration(900));
        assert_eq!(jobs[1].memory_mb, 512);
        assert_eq!(jobs[1].runtime, SimDuration(62)); // 61.5 rounded
        assert_eq!(jobs[1].user, 5);
    }

    #[test]
    fn cancelled_records_skipped() {
        let jobs = parse_gwf(SAMPLE).unwrap();
        assert!(jobs.iter().all(|j| j.id != 2));
    }

    #[test]
    fn short_lines_error() {
        assert!(parse_gwf("1 2 3 4\n").is_err());
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let jobs = parse_gwf("# hi\n\n# more\n").unwrap();
        assert!(jobs.is_empty());
    }
}
