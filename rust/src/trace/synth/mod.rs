//! Statistically calibrated synthetic workload models.
//!
//! The paper drives its experiments with the GWA-DAS2 trace (1,124,772
//! grid jobs) and the SDSC-SP2 log (73,496 jobs). Those logs are not
//! redistributable here, so these models generate workloads with the
//! published marginal statistics of each log (job-size power-of-two bias,
//! heavy-tailed runtimes, diurnal arrival modulation, over-estimated user
//! runtimes). Both are deterministic in the seed, so every experiment in
//! EXPERIMENTS.md is exactly reproducible.

pub mod das2;
pub mod sdsc_sp2;

use crate::core::rng::Rng;
use crate::core::time::SimTime;

/// Shared arrival process: exponential inter-arrivals modulated by a
/// diurnal cycle (day traffic ~3x night traffic, as grid/HPC logs show).
pub(crate) fn next_arrival(rng: &mut Rng, now: u64, mean_interarrival: f64) -> u64 {
    // Diurnal modulation: rate multiplier in [0.5, 1.5] over a 86400 s day.
    let phase = (now % 86_400) as f64 / 86_400.0 * std::f64::consts::TAU;
    let rate_mult = 1.0 + 0.5 * phase.sin();
    let gap = rng.exponential(rate_mult / mean_interarrival);
    now + gap.round().max(1.0) as u64
}

/// Shared user-estimate model: users pad actual runtimes by a factor and
/// round up to "charge buckets" (15 min granularity), capped at the
/// queue's max runtime. This is what makes backfilling interesting.
pub(crate) fn user_estimate(rng: &mut Rng, actual: u64, max_runtime: u64) -> u64 {
    let factor = 1.0 + rng.exponential(1.0 / 1.5); // mean pad ~2.5x
    let padded = (actual as f64 * factor).ceil() as u64;
    let bucket = 900; // 15 minutes
    let rounded = padded.div_ceil(bucket) * bucket;
    rounded.clamp(actual.max(1), max_runtime)
}

/// Truncate a sample into [lo, hi].
pub(crate) fn clamp_u64(x: f64, lo: u64, hi: u64) -> u64 {
    (x.round().max(lo as f64) as u64).min(hi)
}

/// Common statistics over a generated job set (used by calibration tests).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkloadStats {
    pub jobs: usize,
    pub mean_cores: f64,
    pub median_runtime: f64,
    pub mean_runtime: f64,
    pub mean_interarrival: f64,
    pub pow2_fraction: f64,
}

pub fn stats(jobs: &[crate::job::Job]) -> WorkloadStats {
    let n = jobs.len().max(1);
    let mean_cores = jobs.iter().map(|j| j.cores as f64).sum::<f64>() / n as f64;
    let mut rts: Vec<u64> = jobs.iter().map(|j| j.runtime.ticks()).collect();
    rts.sort_unstable();
    let median_runtime = rts.get(n / 2).copied().unwrap_or(0) as f64;
    let mean_runtime = rts.iter().sum::<u64>() as f64 / n as f64;
    let mean_interarrival = if jobs.len() > 1 {
        let span = (jobs.last().unwrap().submit - jobs[0].submit).as_f64();
        span / (jobs.len() - 1) as f64
    } else {
        0.0
    };
    let pow2 =
        jobs.iter().filter(|j| j.cores.is_power_of_two()).count() as f64 / n as f64;
    WorkloadStats {
        jobs: jobs.len(),
        mean_cores,
        median_runtime,
        mean_runtime,
        mean_interarrival,
        pow2_fraction: pow2,
    }
}

/// First submit time used by both models (simulations start at t=0 with a
/// small offset so init events sort before the first arrival).
pub(crate) const FIRST_ARRIVAL: SimTime = SimTime(10);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_advance_monotonically() {
        let mut rng = Rng::new(1);
        let mut t = 0;
        for _ in 0..1000 {
            let next = next_arrival(&mut rng, t, 60.0);
            assert!(next > t);
            t = next;
        }
    }

    #[test]
    fn user_estimate_at_least_actual_and_bucketed() {
        let mut rng = Rng::new(2);
        for _ in 0..1000 {
            let actual = rng.range(1, 10_000);
            let est = user_estimate(&mut rng, actual, 86_400);
            assert!(est >= actual);
            assert!(est <= 86_400);
            // Bucketed unless clamped by actual or cap.
            if est > actual && est < 86_400 {
                assert_eq!(est % 900, 0, "estimate {est} not on a 15-min bucket");
            }
        }
    }

    #[test]
    fn clamp_bounds() {
        assert_eq!(clamp_u64(-5.0, 1, 10), 1);
        assert_eq!(clamp_u64(5.4, 1, 10), 5);
        assert_eq!(clamp_u64(50.0, 1, 10), 10);
    }

    #[test]
    fn stats_of_empty() {
        let s = stats(&[]);
        assert_eq!(s.jobs, 0);
    }
}
