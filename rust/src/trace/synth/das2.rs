//! DAS-2-like workload model.
//!
//! DAS-2 was the Dutch five-cluster research grid (one 72-node head
//! cluster + four 32-node clusters, dual-CPU nodes). The GWA-DAS2 trace is
//! dominated by small, short grid jobs: ~85% power-of-two sizes, median
//! runtime well under a minute, strongly bursty arrivals. The model below
//! reproduces those marginals (Iosup et al. 2008, "The Grid Workloads
//! Archive"):
//!
//! * sizes: power-of-two weighted toward 1-4 procs, max one cluster;
//! * runtimes: lognormal body (mu=3.3, sigma=1.6 -> median ~27 s) with a
//!   5% Pareto tail reaching hours;
//! * arrivals: exponential gaps + diurnal modulation;
//! * estimates: 15-min-bucketed over-estimates, capped at 12 h.

use super::{clamp_u64, next_arrival, stats, user_estimate, WorkloadStats, FIRST_ARRIVAL};
use crate::core::rng::Rng;
use crate::core::time::{SimDuration, SimTime};
use crate::job::Job;
use crate::trace::Workload;

/// DAS-2-like generator parameters (defaults calibrated per module docs).
#[derive(Debug, Clone)]
pub struct Das2Model {
    /// Cluster size in nodes (the 72-node DAS-2 head cluster).
    pub nodes: usize,
    /// Dual-CPU nodes.
    pub cores_per_node: u64,
    /// Mean inter-arrival gap in seconds (controls offered load).
    pub mean_interarrival: f64,
    /// Lognormal runtime body parameters.
    pub runtime_mu: f64,
    pub runtime_sigma: f64,
    /// Fraction of jobs drawn from the heavy Pareto tail.
    pub tail_fraction: f64,
    /// Max runtime (queue limit), seconds.
    pub max_runtime: u64,
    /// Power-of-two size weights for 2^0 .. 2^6 (1..64 procs).
    pub size_weights: [f64; 7],
    /// Probability a job size is *not* rounded to a power of two.
    pub odd_size_fraction: f64,
    /// Number of distinct users/groups for trace realism.
    pub users: u32,
}

impl Default for Das2Model {
    fn default() -> Self {
        Das2Model {
            nodes: 72,
            cores_per_node: 2,
            mean_interarrival: 35.0,
            runtime_mu: 3.3,
            runtime_sigma: 1.6,
            tail_fraction: 0.05,
            max_runtime: 12 * 3600,
            // 1,2,4 dominate; 8-64 shrink geometrically (GWA-DAS2 shape).
            size_weights: [0.38, 0.22, 0.18, 0.10, 0.06, 0.04, 0.02],
            odd_size_fraction: 0.15,
            users: 64,
        }
    }
}

impl Das2Model {
    /// Generate `n` jobs deterministically from `seed`.
    pub fn generate(&self, n: usize, seed: u64) -> Workload {
        let mut rng = Rng::new(seed ^ 0xDA52_DA52);
        let mut jobs = Vec::with_capacity(n);
        let mut t = FIRST_ARRIVAL.ticks();
        let max_cores = self.nodes as u64 * self.cores_per_node;
        for id in 0..n {
            t = next_arrival(&mut rng, t, self.mean_interarrival);
            let mut cores = rng.pow2_size(&self.size_weights);
            if rng.chance(self.odd_size_fraction) && cores > 1 {
                // Grid users occasionally ask for odd sizes (e.g. 3, 6, 12).
                cores = rng.range(cores / 2 + 1, cores.saturating_sub(1).max(cores / 2 + 1));
            }
            cores = cores.clamp(1, max_cores);
            let runtime = if rng.chance(self.tail_fraction) {
                clamp_u64(rng.pareto(1.1, 600.0, self.max_runtime as f64), 600, self.max_runtime)
            } else {
                clamp_u64(
                    rng.lognormal(self.runtime_mu, self.runtime_sigma),
                    1,
                    self.max_runtime,
                )
            };
            let est = user_estimate(&mut rng, runtime, self.max_runtime);
            let user = rng.below(self.users as u64) as u32;
            let mut job = Job::new(
                id as u64 + 1,
                SimTime(t),
                cores,
                0,
                SimDuration(est),
                SimDuration(runtime),
                user,
                user % 8,
            );
            // Deterministic per-user priority band (0..=2) so the
            // preemption subsystem's priority-aware policies are
            // exercisable on synthetic workloads. Derived from the user
            // id — no extra RNG draws, so seeded workloads are unchanged
            // and priority is inert unless preemption is enabled.
            job.priority = (user % 3) as u8;
            jobs.push(job);
        }
        Workload::new("das2-synth", jobs, self.nodes, self.cores_per_node)
    }

    pub fn stats(&self, n: usize, seed: u64) -> WorkloadStats {
        stats(&self.generate(n, seed).jobs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_seed() {
        let m = Das2Model::default();
        let a = m.generate(500, 42);
        let b = m.generate(500, 42);
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.submit, y.submit);
            assert_eq!(x.cores, y.cores);
            assert_eq!(x.runtime, y.runtime);
        }
        let c = m.generate(500, 43);
        assert!(a.jobs.iter().zip(&c.jobs).any(|(x, y)| x.runtime != y.runtime));
    }

    #[test]
    fn marginals_match_das2_shape() {
        let m = Das2Model::default();
        let s = m.stats(20_000, 7);
        assert_eq!(s.jobs, 20_000);
        // Grid jobs are small: mean size a few processors.
        assert!(s.mean_cores > 1.5 && s.mean_cores < 8.0, "mean_cores={}", s.mean_cores);
        // Short median (tens of seconds), heavy mean (minutes).
        assert!(s.median_runtime > 5.0 && s.median_runtime < 120.0,
            "median_runtime={}", s.median_runtime);
        assert!(s.mean_runtime > s.median_runtime * 2.0, "tail too light");
        // Mostly power-of-two sizes.
        assert!(s.pow2_fraction > 0.75, "pow2={}", s.pow2_fraction);
        // Arrival rate near configuration.
        assert!((s.mean_interarrival - 35.0).abs() < 8.0,
            "interarrival={}", s.mean_interarrival);
    }

    #[test]
    fn all_jobs_fit_machine_and_bounds() {
        let m = Das2Model::default();
        let w = m.generate(5000, 1);
        let cap = w.total_cores();
        for j in &w.jobs {
            assert!(j.cores >= 1 && j.cores <= cap);
            assert!(j.runtime.ticks() >= 1 && j.runtime.ticks() <= m.max_runtime);
            assert!(j.est_runtime >= j.runtime.min(j.est_runtime));
            assert!(j.est_runtime.ticks() <= m.max_runtime);
        }
    }

    #[test]
    fn submits_sorted_and_ids_unique() {
        let w = Das2Model::default().generate(2000, 3);
        for pair in w.jobs.windows(2) {
            assert!(pair[0].submit <= pair[1].submit);
            assert!(pair[0].id != pair[1].id);
        }
    }

    #[test]
    fn offered_load_is_plausible() {
        // DAS-2 ran at low utilization (grid!); our default should offer
        // modest load so validation runs drain queues.
        let w = Das2Model::default().generate(10_000, 11);
        let load = w.offered_load();
        assert!(load > 0.05 && load < 1.5, "offered load {load}");
    }
}
