//! SDSC-SP2-like workload model.
//!
//! The San Diego Supercomputer Center IBM SP2 log (Parallel Workloads
//! Archive, 1998-2000) covers 73,496 jobs on a 128-node machine. Compared
//! to DAS-2 it is a classic capability-HPC profile: larger jobs (up to the
//! full machine), much longer runtimes (median ~10 min, tail to 18 h),
//! higher utilization (~83%), and slower arrivals. Model calibrated to
//! the published log summary:
//!
//! * sizes: power-of-two weighted toward 1-16, occasional full-machine;
//! * runtimes: lognormal body (mu=5.9, sigma=1.9 -> median ~6 min) with an
//!   8% Pareto tail to 18 h;
//! * arrivals: exponential gaps (mean ~13 min) + diurnal modulation;
//! * estimates: 15-min buckets, capped at the 18 h queue limit.

use super::{clamp_u64, next_arrival, stats, user_estimate, WorkloadStats, FIRST_ARRIVAL};
use crate::core::rng::Rng;
use crate::core::time::{SimDuration, SimTime};
use crate::job::Job;
use crate::trace::Workload;

/// SDSC-SP2-like generator parameters.
#[derive(Debug, Clone)]
pub struct SdscSp2Model {
    /// 128 thin nodes.
    pub nodes: usize,
    /// One processor per SP2 thin node (jobs request processors=nodes).
    pub cores_per_node: u64,
    pub mean_interarrival: f64,
    pub runtime_mu: f64,
    pub runtime_sigma: f64,
    pub tail_fraction: f64,
    /// 18-hour queue limit of the SP2.
    pub max_runtime: u64,
    /// Power-of-two weights for 2^0 .. 2^7 (1..128 procs).
    pub size_weights: [f64; 8],
    pub odd_size_fraction: f64,
    pub users: u32,
}

impl Default for SdscSp2Model {
    fn default() -> Self {
        SdscSp2Model {
            nodes: 128,
            cores_per_node: 1,
            mean_interarrival: 780.0,
            runtime_mu: 5.9,
            runtime_sigma: 1.9,
            tail_fraction: 0.08,
            max_runtime: 18 * 3600,
            // 1..16 dominate, 32/64 substantial, 128 rare (SP2 shape).
            size_weights: [0.22, 0.14, 0.14, 0.16, 0.14, 0.10, 0.07, 0.03],
            odd_size_fraction: 0.10,
            users: 437, // the log's published user count
        }
    }
}

impl SdscSp2Model {
    /// Generate `n` jobs deterministically from `seed`.
    pub fn generate(&self, n: usize, seed: u64) -> Workload {
        let mut rng = Rng::new(seed ^ 0x5D5C_5B2);
        let mut jobs = Vec::with_capacity(n);
        let mut t = FIRST_ARRIVAL.ticks();
        let max_cores = self.nodes as u64 * self.cores_per_node;
        for id in 0..n {
            t = next_arrival(&mut rng, t, self.mean_interarrival);
            let mut cores = rng.pow2_size(&self.size_weights);
            if rng.chance(self.odd_size_fraction) && cores > 2 {
                cores = rng.range(cores / 2 + 1, cores - 1);
            }
            cores = cores.clamp(1, max_cores);
            let runtime = if rng.chance(self.tail_fraction) {
                clamp_u64(
                    rng.pareto(1.2, 3600.0, self.max_runtime as f64),
                    3600,
                    self.max_runtime,
                )
            } else {
                clamp_u64(
                    rng.lognormal(self.runtime_mu, self.runtime_sigma),
                    1,
                    self.max_runtime,
                )
            };
            let est = user_estimate(&mut rng, runtime, self.max_runtime);
            let user = rng.below(self.users as u64) as u32;
            let mut job = Job::new(
                id as u64 + 1,
                SimTime(t),
                cores,
                0,
                SimDuration(est),
                SimDuration(runtime),
                user,
                user % 16,
            );
            // Per-user priority band (0..=2); see das2.rs — derived, not
            // drawn, so seeded workloads are byte-identical to before.
            job.priority = (user % 3) as u8;
            jobs.push(job);
        }
        Workload::new("sdsc-sp2-synth", jobs, self.nodes, self.cores_per_node)
    }

    pub fn stats(&self, n: usize, seed: u64) -> WorkloadStats {
        stats(&self.generate(n, seed).jobs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_seed() {
        let m = SdscSp2Model::default();
        let a = m.generate(300, 9);
        let b = m.generate(300, 9);
        assert_eq!(a.jobs.len(), b.jobs.len());
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!((x.submit, x.cores, x.runtime), (y.submit, y.cores, y.runtime));
        }
    }

    #[test]
    fn marginals_match_sp2_shape() {
        let s = SdscSp2Model::default().stats(20_000, 5);
        // Bigger jobs than DAS-2.
        assert!(s.mean_cores > 8.0 && s.mean_cores < 32.0, "mean_cores={}", s.mean_cores);
        // Median runtime minutes, not seconds.
        assert!(
            s.median_runtime > 120.0 && s.median_runtime < 3600.0,
            "median_runtime={}",
            s.median_runtime
        );
        // Heavy tail pulls the mean far above the median.
        assert!(s.mean_runtime > 2.0 * s.median_runtime);
        assert!(s.pow2_fraction > 0.8);
        assert!((s.mean_interarrival - 780.0).abs() < 120.0,
            "interarrival={}", s.mean_interarrival);
    }

    #[test]
    fn bounds_respected() {
        let m = SdscSp2Model::default();
        let w = m.generate(5000, 2);
        for j in &w.jobs {
            assert!(j.cores >= 1 && j.cores <= 128);
            assert!(j.runtime.ticks() <= m.max_runtime);
            assert!(j.est_runtime.ticks() <= m.max_runtime);
        }
    }

    #[test]
    fn higher_load_than_das2() {
        // SP2 ran hot (~83% utilization); the offered load should be
        // substantially higher than the DAS-2 model's.
        let sp2 = SdscSp2Model::default().generate(10_000, 3).offered_load();
        let das2 = crate::trace::synth::das2::Das2Model::default()
            .generate(10_000, 3)
            .offered_load();
        assert!(sp2 > das2, "sp2={sp2} das2={das2}");
        assert!(sp2 > 0.4 && sp2 < 2.0, "sp2 load {sp2}");
    }

    #[test]
    fn full_machine_jobs_exist_but_rare() {
        let w = SdscSp2Model::default().generate(20_000, 4);
        let full = w.jobs.iter().filter(|j| j.cores == 128).count();
        assert!(full > 0, "no full-machine jobs generated");
        assert!((full as f64) < 0.08 * w.jobs.len() as f64, "too many: {full}");
    }
}
