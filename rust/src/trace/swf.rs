//! Standard Workload Format (SWF) — the Parallel Workloads Archive format
//! of the SDSC-SP2 log the paper scales with (§4.1).
//!
//! 18 whitespace-separated fields per line; `;` starts a comment. Field
//! meanings (1-based, per the PWA spec):
//!  1 job number, 2 submit time, 3 wait time, 4 run time, 5 allocated
//!  processors, 6 average CPU time, 7 used memory, 8 requested processors,
//!  9 requested time, 10 requested memory, 11 status, 12 user, 13 group,
//!  14 executable, 15 queue, 16 partition, 17 preceding job, 18 think time.
//! Missing values are `-1`.

use crate::core::time::{SimDuration, SimTime};
use crate::job::Job;
use anyhow::{bail, Context, Result};

/// Fold the nine numeric SWF fields into a job, or `None` for a
/// skipped record (cancelled/failed entries with non-positive runtime
/// or processor count, matching how CQsim-style simulators consume
/// these logs). This is the *semantic* half of record parsing, shared
/// by the scalar [`parse_swf_line`] and the byte scanner in
/// [`crate::trace::fast`]: the two ingestion paths can only disagree
/// about tokenization, never about which fields become which jobs.
#[allow(clippy::too_many_arguments)]
pub(crate) fn job_from_swf_fields(
    id: i64,
    submit: i64,
    run: i64,
    used_procs: i64,
    req_procs: i64,
    req_time: i64,
    req_mem: i64,
    user: i64,
    group: i64,
) -> Option<Job> {
    let procs = if req_procs > 0 { req_procs } else { used_procs };
    if run <= 0 || procs <= 0 || id < 0 || submit < 0 {
        return None; // cancelled / failed / malformed record
    }
    let est = if req_time > 0 { req_time } else { run };
    Some(Job::new(
        id as u64,
        SimTime(submit as u64),
        procs as u64,
        req_mem.max(0) as u64,
        SimDuration(est as u64),
        SimDuration(run as u64),
        user.max(0) as u32,
        group.max(0) as u32,
    ))
}

/// Parse one SWF line. `Ok(None)` for comments, blanks and skipped
/// records (see [`job_from_swf_fields`]); `Err` only for structurally
/// broken lines. `lineno` is 1-based (error context). This is the
/// single record parser both the eager [`parse_swf`] and the streaming
/// [`crate::trace::JobStream`] share — what makes stream == eager hold
/// by construction.
pub fn parse_swf_line(line: &str, lineno: usize) -> Result<Option<Job>> {
    let line = line.trim();
    if line.is_empty() || line.starts_with(';') {
        return Ok(None);
    }
    let f: Vec<&str> = line.split_whitespace().collect();
    if f.len() < 11 {
        bail!("swf line {}: expected >= 11 fields, got {}", lineno, f.len());
    }
    let get_i64 = |idx: usize| -> Result<i64> {
        f[idx]
            .parse::<i64>()
            .with_context(|| format!("swf line {}: field {} = {:?}", lineno, idx + 1, f[idx]))
    };
    let id = get_i64(0)?;
    let submit = get_i64(1)?;
    let run = get_i64(3)?;
    let used_procs = get_i64(4)?;
    let req_procs = get_i64(7)?;
    let req_time = get_i64(8)?;
    let req_mem = get_i64(9)?;
    let user = if f.len() > 11 { get_i64(11)? } else { -1 };
    let group = if f.len() > 12 { get_i64(12)? } else { -1 };
    Ok(job_from_swf_fields(id, submit, run, used_procs, req_procs, req_time, req_mem, user, group))
}

/// Parse SWF text into jobs (eager path: a thin collect over
/// [`parse_swf_line`]).
pub fn parse_swf(text: &str) -> Result<Vec<Job>> {
    let mut jobs = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        if let Some(job) = parse_swf_line(line, lineno + 1)? {
            jobs.push(job);
        }
    }
    Ok(jobs)
}

/// Write jobs as SWF (the fields we track; the rest are -1). Inverse of
/// [`parse_swf`] for the tracked fields.
pub fn write_swf(jobs: &[Job], header_comment: &str) -> String {
    let mut out = String::new();
    for line in header_comment.lines() {
        out.push_str("; ");
        out.push_str(line);
        out.push('\n');
    }
    for j in jobs {
        let wait = j.wait_time().map(|w| w.ticks() as i64).unwrap_or(-1);
        out.push_str(&format!(
            "{} {} {} {} {} -1 -1 {} {} {} 1 {} {} -1 -1 -1 -1 -1\n",
            j.id,
            j.submit.ticks(),
            wait,
            j.runtime.ticks(),
            j.cores,
            j.cores,
            j.est_runtime.ticks(),
            if j.memory_mb == 0 { -1 } else { j.memory_mb as i64 },
            j.user,
            j.group,
        ));
    }
    out
}

/// Read and parse an SWF file (eager: collects the stream — use
/// [`crate::trace::stream_trace_file`] to keep memory O(1) in the trace
/// length).
pub fn load_swf_file(path: &str) -> Result<Vec<Job>> {
    crate::trace::stream::stream_swf_file(path)?
        .collect::<Result<Vec<Job>>>()
        .with_context(|| format!("reading SWF file {path:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
; SDSC SP2 sample
; UnixStartTime: 0
1 0 10 120 4 -1 -1 4 600 -1 1 12 3 -1 -1 -1 -1 -1
2 30 -1 60 -1 -1 -1 8 100 2048 1 7 1 -1 -1 -1 -1 -1
3 60 5 -1 4 -1 -1 4 600 -1 0 2 1 -1 -1 -1 -1 -1
4 90 5 50 0 -1 -1 0 600 -1 0 2 1 -1 -1 -1 -1 -1
";

    #[test]
    fn parses_valid_records() {
        let jobs = parse_swf(SAMPLE).unwrap();
        // Jobs 3 (run=-1) and 4 (procs=0) are skipped.
        assert_eq!(jobs.len(), 2);
        let j = &jobs[0];
        assert_eq!(j.id, 1);
        assert_eq!(j.submit, SimTime(0));
        assert_eq!(j.cores, 4);
        assert_eq!(j.runtime, SimDuration(120));
        assert_eq!(j.est_runtime, SimDuration(600));
        assert_eq!(j.user, 12);
        assert_eq!(j.group, 3);
        // Requested memory captured.
        assert_eq!(jobs[1].memory_mb, 2048);
    }

    #[test]
    fn requested_procs_preferred_over_used() {
        let jobs = parse_swf("1 0 0 10 2 -1 -1 16 20 -1 1 0 0 -1 -1 -1 -1 -1\n").unwrap();
        assert_eq!(jobs[0].cores, 16);
    }

    #[test]
    fn falls_back_to_used_procs() {
        let jobs = parse_swf("1 0 0 10 2 -1 -1 -1 20 -1 1 0 0 -1 -1 -1 -1 -1\n").unwrap();
        assert_eq!(jobs[0].cores, 2);
    }

    #[test]
    fn estimate_falls_back_to_runtime() {
        let jobs = parse_swf("1 0 0 77 2 -1 -1 2 -1 -1 1 0 0 -1 -1 -1 -1 -1\n").unwrap();
        assert_eq!(jobs[0].est_runtime, SimDuration(77));
    }

    #[test]
    fn short_lines_error() {
        assert!(parse_swf("1 2 3\n").is_err());
    }

    #[test]
    fn bad_numbers_error() {
        assert!(parse_swf("x 0 0 10 2 -1 -1 2 20 -1 1 0 0 -1 -1 -1 -1 -1\n").is_err());
    }

    #[test]
    fn write_parse_roundtrip() {
        let jobs = parse_swf(SAMPLE).unwrap();
        let text = write_swf(&jobs, "roundtrip test");
        let back = parse_swf(&text).unwrap();
        assert_eq!(back.len(), jobs.len());
        for (a, b) in jobs.iter().zip(&back) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.submit, b.submit);
            assert_eq!(a.cores, b.cores);
            assert_eq!(a.runtime, b.runtime);
            assert_eq!(a.est_runtime, b.est_runtime);
            assert_eq!(a.user, b.user);
        }
    }
}
